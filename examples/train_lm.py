"""Train a ~100M-parameter llama-family model for a few hundred steps on the
synthetic Markov-chain corpus; loss must fall well below the unigram entropy.

    PYTHONPATH=src python examples/train_lm.py --steps 200
"""

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.data import make_batch_iterator
from repro.models import model
from repro.models.config import ModelConfig
from repro.optim import adamw_init, adamw_update, cosine_schedule

CFG_100M = ModelConfig(
    name="llama-100m", family="dense",
    n_layers=8, d_model=512, n_heads=8, n_kv_heads=4, head_dim=64,
    d_ff=2048, vocab=8192, mlp="swiglu", dtype="float32",
)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    args = ap.parse_args()

    cfg = CFG_100M
    print(f"[train_lm] {cfg.name}: {cfg.param_count()/1e6:.1f}M params, "
          f"{args.steps} steps @ batch {args.batch} × seq {args.seq}")

    params = model.init_params(cfg, jax.random.PRNGKey(0))
    opt = adamw_init(params, cfg.opt_dtype)
    data = make_batch_iterator(cfg, args.batch, args.seq, seed=0)

    @jax.jit
    def step(params, opt, batch):
        loss, grads = jax.value_and_grad(lambda p: model.loss_fn(p, batch, cfg))(params)
        lr = cosine_schedule(opt["step"], peak_lr=args.lr, warmup=20, total=args.steps)
        params, opt = adamw_update(params, grads, opt, lr=lr)
        return params, opt, loss

    t0, losses = time.time(), []
    for i in range(args.steps):
        batch = {k: jnp.asarray(v) for k, v in next(data).items()}
        params, opt, loss = step(params, opt, batch)
        losses.append(float(loss))
        if i % 20 == 0 or i == args.steps - 1:
            print(f"  step {i:4d}  loss {losses[-1]:.4f}  "
                  f"({(time.time()-t0)/(i+1):.2f}s/step)")

    # the order-2 Markov corpus has ~log(branching)=1.39 nats conditional
    # entropy vs log(vocab)=9.0 for random guessing
    first, last = np.mean(losses[:5]), np.mean(losses[-5:])
    print(f"[train_lm] loss {first:.3f} -> {last:.3f} "
          f"(unigram entropy ≈ {np.log(cfg.vocab):.2f}, "
          f"markov floor ≈ 1.39)")
    assert last < first - 0.5, "model failed to learn"
    print("[train_lm] OK — model is learning the synthetic grammar")


if __name__ == "__main__":
    main()
