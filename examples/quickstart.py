"""Quickstart: the paper's core result in 30 seconds.

Simulates a 50-GPU MIG cluster under heavy multi-tenant load and compares
the paper's MFI scheduler against all four baselines on acceptance rate,
allocated workloads and fragmentation severity.

    PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro import api
from repro.core import mig, fragmentation
from repro.sim import SimConfig

PID = {n: i for i, n in enumerate(mig.PROFILE_NAMES)}


def worked_example():
    """The paper's Fig. 3a fragmentation-score example, reproduced."""
    g2 = mig.GPUState(2)
    g2.allocate(1, PID["2g.20gb"], 0)
    g2.allocate(2, PID["1g.10gb"], 5)
    g1 = mig.GPUState(1)
    g1.allocate(3, PID["2g.20gb"], 2)
    f2 = fragmentation.fragmentation_score(g2, "partial")
    f1 = fragmentation.fragmentation_score(g1, "partial")
    print(f"paper worked example: F(GPU2) = {f2:.0f} (paper: 16), "
          f"F(GPU1) = {f1:.0f} (paper: 8)")


def main():
    worked_example()
    print("\nMonte-Carlo, 50 GPUs, uniform profiles, 85% offered load, 10 runs")
    print("(every policy registered in repro.core.policy — a custom "
          "register_policy() spec would show up here automatically):")
    print(f"{'scheduler':10s} {'accept':>7s} {'alloc':>6s} {'util':>6s} "
          f"{'gpus':>5s} {'frag':>6s}")
    cfg = SimConfig(num_gpus=50, distribution="uniform", offered_load=0.85, seed=0)
    for name in api.list_policies():
        r = api.simulate(name, cfg=cfg, runs=10)
        print(f"{name:10s} {r['acceptance_rate']:7.3f} {r['allocated_workloads']:6.0f} "
              f"{r['utilization']:6.3f} {r['active_gpus']:5.1f} {r['frag_severity']:6.2f}")
    print("\nMFI should have the best (or tied-best) acceptance and the lowest "
          "fragmentation — the paper's headline claim.  mfi-defrag is this "
          "repo's beyond-paper extension (single-migration defragmentation).  "
          "See docs/POLICIES.md to define your own policy in ~10 lines.")


if __name__ == "__main__":
    main()
