"""Reproduce all three paper evaluation figures with full Monte-Carlo runs.

    PYTHONPATH=src:. python examples/paper_figures.py --runs 100

(The paper uses 500 runs; 30-100 gives the same ordering with tight CIs.
``--engine batched`` runs fig4/fig5 sweep points through the batched JAX
engine — paper-scale 500-replica sweeps become practical on CPU.)
"""

import argparse


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--runs", type=int, default=50)
    ap.add_argument("--engine", choices=("python", "batched"), default="python")
    args = ap.parse_args()

    from benchmarks import fig4_load_sweep, fig5_distributions, fig6_fragscore

    print("=" * 70)
    print("Fig. 4 — load sweep, uniform distribution")
    print("=" * 70)
    fig4_load_sweep.main(runs=args.runs, engine=args.engine)
    print("=" * 70)
    print("Fig. 5 — four distributions at 85% load")
    print("=" * 70)
    fig5_distributions.main(runs=args.runs, engine=args.engine)
    print("=" * 70)
    print("Fig. 6 — fragmentation severity")
    print("=" * 70)
    fig6_fragscore.main(runs=args.runs)


if __name__ == "__main__":
    main()
