"""Reproduce all three paper evaluation figures with full Monte-Carlo runs.

    PYTHONPATH=src:. python examples/paper_figures.py --runs 100

(The paper uses 500 runs; 30-100 gives the same ordering with tight CIs.
``--engine batched`` runs fig4/fig5 sweep points through the batched JAX
engine — paper-scale 500-replica sweeps become practical on CPU.
``--cluster mixed`` re-runs the evaluation on a heterogeneous four-model
fleet — A100-80GB/A100-40GB/H100-96GB/H100-80GB, a beyond-paper scenario;
any explicit spec string like ``a100-80:40,a100-40:40,h100-96:20`` works
too.)
"""

import argparse


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--runs", type=int, default=50)
    ap.add_argument("--engine", choices=("python", "batched"), default="python")
    ap.add_argument(
        "--cluster", default=None,
        help="fleet scenario for fig4/fig5: 'homogeneous' (default), "
        "'mixed', or a spec string 'a100-80:50,a100-40:50'",
    )
    args = ap.parse_args()

    from benchmarks import fig4_load_sweep, fig5_distributions, fig6_fragscore

    fleet = args.cluster or "homogeneous"
    print("=" * 70)
    print(f"Fig. 4 — load sweep, uniform distribution ({fleet} fleet)")
    print("=" * 70)
    fig4_load_sweep.main(runs=args.runs, engine=args.engine, cluster=args.cluster)
    print("=" * 70)
    print(f"Fig. 5 — four distributions at 85% load ({fleet} fleet)")
    print("=" * 70)
    fig5_distributions.main(runs=args.runs, engine=args.engine, cluster=args.cluster)
    print("=" * 70)
    print("Fig. 6 — fragmentation severity")
    print("=" * 70)
    fig6_fragscore.main(runs=args.runs)


if __name__ == "__main__":
    main()
