"""END-TO-END DRIVER: multi-tenant serving with MIG admission control.

A small llama-family model serves batched generation requests.  Each request
is a tenant workload demanding a MIG profile (sampled from the paper's
distributions); the MFI scheduler places it on a simulated A100 fleet, the
engine runs real jitted prefill+decode steps, and completion frees the MIG
slices.  Compares MFI admission against First-Fit on the same request
stream, then re-runs MFI with the **queued** front-end: requests carry
`(tenant, priority, patience)`, over-capacity arrivals wait in the
priority/wait-age-ordered admission queue instead of dropping, and
releases at wave boundaries re-drive admission — the serving-side view of
the simulator's `steady-queued` protocol.

    PYTHONPATH=src python examples/serve_cluster.py
"""

import time

import jax
import numpy as np

from repro.configs import SMOKES
from repro.core import mig
from repro.models import model
from repro.serving import Request, ServingEngine
from repro.sim import distributions

TENANTS = ("acme", "globex", "initech")


def make_requests(cfg, n, rng, patience=0):
    profiles = distributions.sample_profiles("bimodal", n, rng)
    return [
        Request(
            request_id=i,
            prompt=rng.integers(0, cfg.vocab, 32).astype(np.int32),
            max_new_tokens=8,
            profile=mig.PROFILE_NAMES[profiles[i]],
            tenant=TENANTS[i % len(TENANTS)],
            priority=i % 2,  # alternate urgent / background
            patience=patience,
        )
        for i in range(n)
    ]


def run_stream(cfg, params, policy, patience=0):
    rng = np.random.default_rng(7)  # same stream for every variant
    requests = make_requests(cfg, 24, rng, patience=patience)
    engine = ServingEngine(
        cfg, params, num_slots=4, max_len=48, num_gpus=3, policy=policy
    )
    t0 = time.time()
    stats = engine.run(requests)
    served = sum(r.admitted and r.finished for r in requests)
    rejected = sum(r.rejected for r in requests)
    toks = sum(len(r.output or []) for r in requests)
    label = f"{policy}+queue" if patience else policy
    print(f"[{label:9s}] served={served:2d} rejected={rejected:2d} "
          f"acceptance={stats['acceptance_rate']:.2f} tokens={toks} "
          f"wait_p99={stats['wait_p99']:.1f} "
          f"fairness={stats['fairness']:.3f} ({time.time()-t0:.1f}s)")
    return stats


def main():
    cfg = SMOKES["llama3.2-1b"]
    params = model.init_params(cfg, jax.random.PRNGKey(0))
    print(f"model: {cfg.name} ({cfg.param_count()/1e6:.1f}M params), "
          f"cluster: 3 GPUs, requests: 24 (bimodal MIG profiles, "
          f"{len(TENANTS)} tenants)")

    for policy in ("mfi", "ff"):
        run_stream(cfg, params, policy)
    drop = run_stream(cfg, params, "mfi")
    queued = run_stream(cfg, params, "mfi", patience=6)

    print("\nMFI should accept >= FF on the same stream (fewer fragmentation "
          "rejections of large profiles); with patience, waiting requests "
          "ride out full waves instead of dropping "
          f"(acceptance {drop['acceptance_rate']:.2f} -> "
          f"{queued['acceptance_rate']:.2f}).")


if __name__ == "__main__":
    main()
