"""END-TO-END DRIVER: multi-tenant serving with MIG admission control.

A small llama-family model serves batched generation requests.  Each request
is a tenant workload demanding a MIG profile (sampled from the paper's
distributions); the MFI scheduler places it on a simulated A100 fleet, the
engine runs real jitted prefill+decode steps, and completion frees the MIG
slices.  Compares MFI admission against First-Fit on the same request stream.

    PYTHONPATH=src python examples/serve_cluster.py
"""

import time

import jax
import numpy as np

from repro.configs import SMOKES
from repro.core import mig
from repro.models import model
from repro.serving import Request, ServingEngine
from repro.sim import distributions


def make_requests(cfg, n, rng):
    profiles = distributions.sample_profiles("bimodal", n, rng)
    return [
        Request(
            request_id=i,
            prompt=rng.integers(0, cfg.vocab, 32).astype(np.int32),
            max_new_tokens=8,
            profile=mig.PROFILE_NAMES[profiles[i]],
        )
        for i in range(n)
    ]


def main():
    cfg = SMOKES["llama3.2-1b"]
    params = model.init_params(cfg, jax.random.PRNGKey(0))
    print(f"model: {cfg.name} ({cfg.param_count()/1e6:.1f}M params), "
          f"cluster: 3 GPUs, requests: 24 (bimodal MIG profiles)")

    for policy in ("mfi", "ff"):
        rng = np.random.default_rng(7)  # same stream for both policies
        requests = make_requests(cfg, 24, rng)
        engine = ServingEngine(
            cfg, params, num_slots=4, max_len=48, num_gpus=3, policy=policy
        )
        t0 = time.time()
        stats = engine.run(requests)
        served = sum(r.admitted and r.finished for r in requests)
        rejected = sum(r.rejected for r in requests)
        toks = sum(len(r.output or []) for r in requests)
        print(f"[{policy:5s}] served={served:2d} rejected={rejected:2d} "
              f"acceptance={stats['acceptance_rate']:.2f} tokens={toks} "
              f"({time.time()-t0:.1f}s)")

    print("\nMFI should accept >= FF on the same stream (fewer fragmentation "
          "rejections of large profiles).")


if __name__ == "__main__":
    main()
