"""Staged batched-engine core tests (the PR-4 tentpole).

Four layers of guarantees over :mod:`repro.sim.batched`'s staged pipeline
(``arrival → select → migrate → commit → expire → measure``):

* **bit-for-bit regression** — the steady homogeneous and mixed traces
  recorded *before* the monolithic event step was split into stages
  reproduce exactly through the staged pipeline (golden aggregates and
  SHA-256 trace hashes, captured at commit ``ca345a6``);
* **batched ``mfi-defrag``** — the migrate stage matches the host
  scheduler's canonical ``(total F, victim gpu, victim anchor)`` search
  single-step AND decision-for-decision over whole streams, migrations
  included, and migrated trajectories pass the replay invariants (a
  migration never double-books or strands a workload);
* **cumulative protocol** — batched demand-grid traces match the Python
  simulator on the *same* per-replica RNG streams;
* **satellites** — per-model demand mixes, the non-8-slice H200-141GB
  geometry, and replica-axis sharding (subprocess, 8 host devices).
"""

import hashlib
import json
import subprocess
import sys
import textwrap

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import mig
from repro.core.schedulers import MFIDefrag
from repro.sim import SimConfig, request_probs, run_many
from repro.sim import batched, replay
from repro.sim.distributions import DISTRIBUTIONS, resolve_probs

PID = {name: i for i, name in enumerate(mig.PROFILE_NAMES)}

MIXED = mig.ClusterSpec(((mig.A100_80GB, 3), (mig.A100_40GB, 3)))
FOUR_MODEL = mig.ClusterSpec(
    (
        (mig.A100_80GB, 2),
        (mig.A100_40GB, 2),
        (mig.H100_96GB, 2),
        (mig.H100_80GB, 2),
    )
)
H200_MIX = mig.ClusterSpec(
    ((mig.A100_80GB, 2), (mig.H200_141GB, 2), (mig.A100_40GB, 1))
)


def _sim(policy, cfg, spec=None, runs=2, protocol="steady"):
    presample = (
        batched.presample_arrivals
        if protocol == "steady"
        else batched.presample_cumulative
    )
    events, meta, rr, rc = presample(cfg, runs=runs)
    kw = {}
    if spec is not None:
        kw = dict(
            midx=jnp.asarray(spec.model_index), tables=batched.spec_tables(spec)
        )
    final, trace = jax.device_get(
        batched._simulate(
            jax.tree.map(jnp.asarray, events),
            policy=policy,
            metric=cfg.metric,
            num_gpus=cfg.num_gpus,
            ring_rows=rr,
            ring_cols=rc,
            use_kernel=False,
            protocol=protocol,
            **kw,
        )
    )
    return events, meta, trace, final


# ---------------------------------------------------------------------------
# Bit-for-bit regression vs the pre-refactor monolithic event step
# ---------------------------------------------------------------------------


#: aggregates + decision-trace hashes recorded on the pre-refactor engine
#: (monolithic `_event_step`, commit ca345a6) — the staged pipeline must
#: reproduce them exactly, not approximately
GOLDEN_AGGREGATES = {
    ("homog_m6", "mfi"): {
        "acceptance_rate": 0.835978120978121,
        "active_gpus": 5.0,
        "allocated_workloads": 37.25,
        "frag_severity": 7.736111243565877,
        "utilization": 0.6440972222222222,
    },
    ("mixed_k2", "rr"): {
        "acceptance_rate": 0.705775877918735,
        "active_gpus": 5.583333333333333,
        "allocated_workloads": 31.25,
        "frag_severity": 8.333333651224772,
        "utilization": 0.6458333333333334,
    },
    ("four_k4", "bf-bi"): {
        "acceptance_rate": 0.8497768071971659,
        "active_gpus": 7.1875,
        "allocated_workloads": 53.25,
        "frag_severity": 7.015625,
        "utilization": 0.68359375,
    },
}

GOLDEN_CONFIGS = {
    "homog_m6": lambda: SimConfig(num_gpus=6, offered_load=0.9, seed=12),
    "mixed_k2": lambda: SimConfig(cluster_spec=MIXED, offered_load=0.9, seed=12),
    "four_k4": lambda: SimConfig(
        cluster_spec=FOUR_MODEL, offered_load=0.85, seed=3
    ),
}

GOLDEN_TRACE_HASHES = {
    "homog": "3f61871a2075ffe549c554a6820d3bccc437d8606c80dd6e471e9daa0ad00705",
    "mixed": "fc5a944c82ab6c74ca8a49b6a1ca19981d1d3fe8953f9b35cce26e67a8678d62",
}


class TestPreRefactorBitForBit:
    @pytest.mark.parametrize("tag,policy", sorted(GOLDEN_AGGREGATES))
    def test_steady_aggregates_reproduce_exactly(self, tag, policy):
        r = batched.run_batched(policy, GOLDEN_CONFIGS[tag](), runs=4)
        for key, want in GOLDEN_AGGREGATES[(tag, policy)].items():
            assert r[key] == want, f"{tag}/{policy}/{key}: {r[key]!r} != {want!r}"

    @pytest.mark.parametrize(
        "tag,cfg_fn,spec",
        [
            ("homog", lambda: SimConfig(num_gpus=5, offered_load=1.1, seed=7), None),
            (
                "mixed",
                lambda: SimConfig(cluster_spec=MIXED, offered_load=1.0, seed=9),
                MIXED,
            ),
        ],
    )
    def test_steady_decision_traces_hash_identically(self, tag, cfg_fn, spec):
        _, _, trace, _ = _sim("mfi", cfg_fn(), spec, runs=3)
        h = hashlib.sha256()
        for a in (
            trace.ok, trace.gpu, trace.aidx, trace.free_sum, trace.active,
            trace.frag,
        ):
            h.update(np.ascontiguousarray(np.asarray(a)).tobytes())
        assert h.hexdigest() == GOLDEN_TRACE_HASHES[tag]


# ---------------------------------------------------------------------------
# Protocol descriptor
# ---------------------------------------------------------------------------


class TestProtocolDescriptor:
    def test_registry(self):
        steady = batched.resolve_protocol("steady")
        cumulative = batched.resolve_protocol("cumulative")
        assert steady.boundary_metrics and not steady.post_metrics
        assert cumulative.post_metrics and not cumulative.boundary_metrics
        assert batched.resolve_protocol(steady) is steady
        with pytest.raises(ValueError, match="unknown protocol"):
            batched.resolve_protocol("bursty")

    def test_trace_fields_follow_protocol(self):
        cfg = SimConfig(num_gpus=3, offered_load=0.8, seed=1)
        _, _, steady_trace, _ = _sim("ff", cfg, runs=2)
        assert steady_trace.free_sum is not None
        assert steady_trace.post_free is None and steady_trace.mig is None
        ccfg = SimConfig(num_gpus=3, protocol="cumulative", seed=1)
        _, _, cum_trace, _ = _sim("ff", ccfg, runs=2, protocol="cumulative")
        assert cum_trace.post_free is not None
        assert cum_trace.free_sum is None


# ---------------------------------------------------------------------------
# Batched mfi-defrag: the migrate stage
# ---------------------------------------------------------------------------


class TestBatchedDefrag:
    def test_single_step_matches_host_search(self):
        """The textbook scenario: a misplaced 1g.10gb blocks a 4g.40gb;
        both engines choose the same victim and target."""
        cl = mig.ClusterState(2)
        cl.allocate(1, PID["1g.10gb"], 0, 1)
        cl.allocate(2, PID["4g.40gb"], 1, 0)
        cl.allocate(3, PID["2g.20gb"], 1, 4)
        d = MFIDefrag(max_candidates=None)
        sel = d.select(cl, PID["4g.40gb"])
        assert sel is not None and d.pending_migration is not None
        vwid, vg, va = d.pending_migration
        workloads = [
            (g.gpu_id, a.profile_id, a.anchor)
            for g in cl.gpus
            for a in g.allocations.values()
        ]
        res = batched.policy_select_full(
            jnp.asarray(cl.occupancy_matrix()), jnp.int32(PID["4g.40gb"]),
            "mfi-defrag", workloads=workloads,
        )
        assert bool(res.ok) and bool(res.mig)
        assert (int(res.gpu), int(res.anchor)) == sel
        assert (int(res.new_gpu), int(res.new_anchor)) == (vg, va)
        assert (int(res.vic_gpu), int(res.vic_anchor)) == (0, 1)

    def test_randomized_single_step_parity(self):
        """Random clusters (homogeneous + mixed): decision AND migration
        agree with the host's canonical unbounded search."""
        rng = np.random.default_rng(17)
        migrations = 0
        for trial in range(40):
            spec = None if trial % 2 == 0 else MIXED
            cl = (
                mig.ClusterState(int(rng.integers(1, 6)))
                if spec is None
                else mig.ClusterState(spec=spec)
            )
            wid = 0
            density = rng.random() * 1.2
            for g in range(cl.num_gpus):
                for pid in rng.permutation(mig.NUM_PROFILES):
                    if rng.random() < density:
                        anchors = cl.gpus[g].feasible_anchors(int(pid))
                        if anchors:
                            cl.allocate(wid, int(pid), g, int(rng.choice(anchors)))
                            wid += 1
            pid = int(rng.integers(0, mig.NUM_PROFILES))
            d = MFIDefrag(max_candidates=None)
            ref = d.select(cl, pid)
            workloads = [
                (g.gpu_id, a.profile_id, a.anchor)
                for g in cl.gpus
                for a in g.allocations.values()
            ]
            res = batched.policy_select_full(
                jnp.asarray(cl.occupancy_matrix()), jnp.int32(pid),
                "mfi-defrag", spec=spec, workloads=workloads,
            )
            got = (int(res.gpu), int(res.anchor)) if bool(res.ok) else None
            assert got == ref, f"trial {trial}: host={ref} batched={got}"
            if d.pending_migration is not None:
                migrations += 1
                vwid, vg, va = d.pending_migration
                old = next(
                    (g.gpu_id, a.anchor)
                    for g in cl.gpus
                    for w, a in g.allocations.items()
                    if w == vwid
                )
                assert bool(res.mig)
                assert (int(res.vic_gpu), int(res.vic_anchor)) == old
                assert (int(res.new_gpu), int(res.new_anchor)) == (vg, va)
            else:
                assert not bool(res.mig)
        assert migrations >= 2  # the fuzz actually exercised the search

    @pytest.mark.parametrize("spec", [None, MIXED], ids=["homog", "mixed"])
    def test_same_stream_decisions_and_migrations_match(self, spec):
        cfg = (
            SimConfig(num_gpus=4, offered_load=1.1, seed=3)
            if spec is None
            else SimConfig(cluster_spec=spec, offered_load=1.0, seed=3)
        )
        events, meta, trace, _ = _sim("mfi-defrag", cfg, spec, runs=2)
        assert np.asarray(trace.mig).sum() > 0  # migrations actually happened
        ref = replay.host_decisions_full(
            events, meta, "mfi-defrag", cfg.num_gpus, spec=spec,
            max_candidates=None,
        )
        ok = np.asarray(trace.ok)
        np.testing.assert_array_equal(ok, ref.ok)
        np.testing.assert_array_equal(np.asarray(trace.gpu)[ok], ref.gpu[ok])
        np.testing.assert_array_equal(np.asarray(trace.mig), ref.mig)
        m = np.asarray(trace.mig)
        for dev, host in (
            (trace.mig_from_gpu, ref.mig_from_gpu),
            (trace.mig_from_anchor, ref.mig_from_anchor),
            (trace.mig_to_gpu, ref.mig_to_gpu),
            (trace.mig_to_anchor, ref.mig_to_anchor),
        ):
            np.testing.assert_array_equal(np.asarray(dev)[m], host[m])

    def test_migration_invariants_via_replay(self):
        """Deterministic form of the hypothesis invariant: a migration
        never double-books a slice and never strands a workload (the
        migrated victim still drains exactly from its new placement)."""
        for seed in (3, 5, 11):
            cfg = SimConfig(num_gpus=4, offered_load=1.2, seed=seed)
            events, meta, trace, final = _sim("mfi-defrag", cfg, runs=2)
            occ = replay.replay(events, meta, trace, cfg.num_gpus)
            w = np.asarray(mig.PLACEMENT_MASKS, np.float32)
            np.testing.assert_allclose(final.base, occ.astype(np.float32) @ w.T)
            _, drained = replay.drain_all(events, meta, trace, cfg.num_gpus)
            np.testing.assert_array_equal(drained, 0)

    def test_defrag_dominates_mfi_single_step(self):
        """At any fixed cluster state, mfi-defrag accepts whenever plain MFI
        does (it only ADDS acceptances via migration) — the single-step
        dominance property.  Run-level acceptance is not monotone (a greedy
        migration can worsen the future state), so this is the invariant.
        """
        rng = np.random.default_rng(23)
        extra = 0
        for _ in range(30):
            cl = mig.ClusterState(3)
            wid = 0
            for g in range(3):
                for pid in rng.permutation(mig.NUM_PROFILES):
                    if rng.random() < 0.7:
                        anchors = cl.gpus[g].feasible_anchors(int(pid))
                        if anchors:
                            cl.allocate(wid, int(pid), g, int(rng.choice(anchors)))
                            wid += 1
            occ = jnp.asarray(cl.occupancy_matrix())
            workloads = [
                (g.gpu_id, a.profile_id, a.anchor)
                for g in cl.gpus
                for a in g.allocations.values()
            ]
            for pid in range(mig.NUM_PROFILES):
                _, _, ok_mfi = batched.policy_select(occ, jnp.int32(pid), "mfi")
                _, _, ok_d = batched.policy_select(
                    occ, jnp.int32(pid), "mfi-defrag", workloads=workloads
                )
                assert bool(ok_d) >= bool(ok_mfi)
                extra += int(bool(ok_d) and not bool(ok_mfi))
        assert extra > 0  # the migration search actually rescued rejects

    def test_facade_runs_defrag_on_batched_engine(self):
        from repro import api

        r = api.simulate("mfi-defrag", engine="batched", num_gpus=3, runs=2)
        assert 0.0 < r["acceptance_rate"] <= 1.0


# ---------------------------------------------------------------------------
# Cumulative protocol on the batched engine
# ---------------------------------------------------------------------------


class TestBatchedCumulative:
    @pytest.mark.parametrize("policy", ["mfi", "ff", "rr"])
    def test_traces_match_python_simulator_same_stream(self, policy):
        """Replica r consumes the same RNG stream as run_many's run r, so
        the demand-grid traces must agree to float tolerance — not just
        statistically."""
        cfg = SimConfig(num_gpus=4, protocol="cumulative", seed=5)
        rb = batched.run_batched(policy, cfg, runs=3)
        rp = run_many(policy, cfg, runs=3)
        for k in (
            "acceptance_rate", "allocated_workloads", "active_gpus",
            "utilization", "frag_severity",
        ):
            np.testing.assert_allclose(rb[k], rp[k], rtol=1e-6, atol=1e-6)
            np.testing.assert_allclose(
                rb["traces"][k], rp["traces"][k], rtol=1e-6, atol=1e-6
            )
        np.testing.assert_array_equal(rb["demand_grid"], rp["demand_grid"])
        np.testing.assert_allclose(
            rb["rejects_by_profile"], rp["rejects_by_profile"]
        )

    def test_mixed_fleet_same_stream_decisions(self):
        cfg = SimConfig(cluster_spec=MIXED, protocol="cumulative", seed=2)
        events, meta, trace, _ = _sim(
            "mfi", cfg, MIXED, runs=2, protocol="cumulative"
        )
        ok_ref, gpu_ref, _ = replay.host_decisions(
            events, meta, "mfi", cfg.num_gpus, spec=MIXED
        )
        ok = np.asarray(trace.ok)
        np.testing.assert_array_equal(ok, ok_ref)
        np.testing.assert_array_equal(np.asarray(trace.gpu)[ok], gpu_ref[ok])
        replay.replay(events, meta, trace, cfg.num_gpus, spec=MIXED)

    def test_cumulative_defrag_composes(self):
        """Protocol descriptor × defrag spec: both stages compile together;
        the host reference (with the cumulative migration fix) agrees."""
        cfg = SimConfig(num_gpus=2, protocol="cumulative", seed=8)
        rb = batched.run_batched("mfi-defrag", cfg, runs=2)
        rp = run_many("mfi-defrag", cfg, runs=2)
        np.testing.assert_allclose(
            rb["acceptance_rate"], rp["acceptance_rate"], rtol=1e-6
        )
        np.testing.assert_allclose(
            rb["traces"]["utilization"], rp["traces"]["utilization"],
            rtol=1e-6, atol=1e-6,
        )


# ---------------------------------------------------------------------------
# Factored migrate-stage lowering (the PR-5 tentpole)
# ---------------------------------------------------------------------------


def _random_cluster(rng, spec=None, num_gpus=None, density=0.7):
    """A randomized occupancy state + its (gpu, pid, anchor) workload list."""
    cl = (
        mig.ClusterState(num_gpus)
        if spec is None
        else mig.ClusterState(spec=spec)
    )
    wid = 0
    for g in range(cl.num_gpus):
        for pid in rng.permutation(mig.NUM_PROFILES):
            if rng.random() < density:
                anchors = cl.gpus[g].feasible_anchors(int(pid))
                if anchors:
                    cl.allocate(wid, int(pid), g, int(rng.choice(anchors)))
                    wid += 1
    workloads = [
        (g.gpu_id, a.profile_id, a.anchor)
        for g in cl.gpus
        for a in g.allocations.values()
    ]
    return cl, workloads


def _search_args(cl, workloads, pid, ring_shape, rng, metric="blocked"):
    """Scatter the workloads into a random ring layout and derive the
    window-count state `_migrate_search` consumes."""
    spec = cl.spec
    tables = batched.spec_tables(spec)
    midx = jnp.asarray(spec.model_index)
    occ = cl.occupancy_matrix()
    base = jnp.einsum(
        "ms,mns->mn", jnp.asarray(occ, jnp.float32), tables.W[midx]
    )
    free = tables.slices[midx] - occ.sum(axis=1).astype(np.int32)
    vg = tables.V[midx]
    f = batched._frag_from_base(base, free, metric, vg)

    rows, cols = ring_shape
    s = int(tables.W.shape[2])
    ring_gpu = np.zeros((rows, cols), np.int32)
    ring_mask = np.zeros((rows, cols, s), np.int32)
    ring_pid = np.zeros((rows, cols), np.int32)
    ring_aidx = np.zeros((rows, cols), np.int32)
    slots = rng.choice(rows * cols, size=len(workloads), replace=False)
    for slot, (g, p, anchor) in zip(slots, workloads):
        model = spec.model_of(int(g))
        j = model.profiles[int(p)].anchors.index(int(anchor))
        r, c = divmod(int(slot), cols)
        ring_gpu[r, c] = g
        ring_mask[r, c, anchor:anchor + model.profiles[int(p)].mem] = 1
        ring_pid[r, c] = p
        ring_aidx[r, c] = j
    return dict(
        spec=batched.resolve("mfi-defrag"),
        metric=metric,
        tables=tables,
        midx=midx,
        vg=vg,
        base=base,
        free=free,
        f=f,
        ring_gpu=jnp.asarray(ring_gpu),
        ring_mask=jnp.asarray(ring_mask),
        ring_pid=jnp.asarray(ring_pid),
        ring_aidx=jnp.asarray(ring_aidx),
        pid_c=jnp.int32(pid),
        cursor=jnp.int32(0),
        want=jnp.asarray(True),
    )


class TestFactoredMigrateSearch:
    """The factored lowering must return the *same* MigrationResult as the
    dense (C, M, A) reference on arbitrary states — including rings much
    larger than the live-entry budget (the compaction path)."""

    FIELDS = [
        "gpu", "aidx", "vic_row", "vic_col", "vic_gpu", "vic_anchor",
        "vic_pid", "new_gpu", "new_aidx", "new_anchor", "old_mask",
        "old_mwin", "new_mask", "new_mwin",
    ]

    @pytest.mark.parametrize("metric", ["blocked", "partial"])
    @pytest.mark.parametrize(
        "spec", [None, MIXED, H200_MIX], ids=["homog", "mixed", "h200"]
    )
    def test_equivalence_randomized(self, spec, metric):
        rng = np.random.default_rng(29)
        migrations = 0
        for trial in range(25):
            cl, workloads = _random_cluster(
                rng,
                spec=spec,
                num_gpus=int(rng.integers(2, 6)) if spec is None else None,
                density=rng.random() * 1.2,
            )
            if not workloads:
                continue
            pid = int(rng.integers(0, mig.NUM_PROFILES))
            # ring deliberately oversized: mostly dead slots -> the factored
            # search must compact them away without changing the decision
            rows = int(rng.integers(1, 40))
            cols = -(-max(1, len(workloads)) // rows) + int(rng.integers(0, 4))
            args = _search_args(cl, workloads, pid, (rows, cols), rng, metric)
            got = batched._migrate_search(**args)
            want = batched._migrate_search_dense(**args)
            assert bool(got.mig) == bool(want.mig), f"trial {trial}"
            if bool(want.mig):
                migrations += 1
                for field in self.FIELDS:
                    np.testing.assert_array_equal(
                        np.asarray(getattr(got, field)),
                        np.asarray(getattr(want, field)),
                        err_msg=f"trial {trial}: {field}",
                    )
        assert migrations >= 3  # the fuzz actually exercised the search

    def test_want_false_is_noop(self):
        rng = np.random.default_rng(5)
        cl, workloads = _random_cluster(rng, num_gpus=3)
        args = _search_args(cl, workloads, 0, (4, max(1, len(workloads))), rng)
        args["want"] = jnp.asarray(False)
        assert not bool(batched._migrate_search(**args).mig)

    def test_compaction_budget_bounds_live_entries(self):
        """Every running workload occupies >= 1 slice, so M*S bounds the
        live-entry count: a full cluster's workload list always fits the
        static budget."""
        rng = np.random.default_rng(11)
        cl, workloads = _random_cluster(rng, num_gpus=4, density=1.2)
        spec = cl.spec
        assert len(workloads) <= spec.num_gpus * spec.num_mem_slices


# ---------------------------------------------------------------------------
# Pallas kernel lowering of the ΔF hot path (use_kernel end to end)
# ---------------------------------------------------------------------------


class TestKernelLowering:
    """`use_kernel=True` (interpret mode on CPU) must reproduce the pure-jnp
    decisions bit-for-bit — homogeneous and mixed fleets, defrag included."""

    @pytest.mark.parametrize(
        "policy,spec",
        [("mfi", None), ("mfi", MIXED), ("mfi-defrag", None),
         ("mfi-defrag", H200_MIX)],
        ids=["mfi-homog", "mfi-mixed", "defrag-homog", "defrag-h200"],
    )
    def test_same_decisions_as_pure_jnp(self, policy, spec):
        cfg = (
            SimConfig(num_gpus=4, offered_load=1.0, seed=3)
            if spec is None
            else SimConfig(cluster_spec=spec, offered_load=1.0, seed=3)
        )
        cspec = cfg.spec()
        events, meta, rr, rc = batched.presample_arrivals(cfg, runs=2)
        dev = jax.tree.map(jnp.asarray, events)
        kw = dict(
            policy=policy, metric=cfg.metric, num_gpus=cfg.num_gpus,
            ring_rows=rr, ring_cols=rc,
            midx=jnp.asarray(cspec.model_index),
            tables=batched.spec_tables(cspec),
        )
        _, ref = jax.device_get(batched._simulate(dev, use_kernel=False, **kw))
        _, got = jax.device_get(
            batched._simulate(dev, use_kernel=True, kernel_spec=cspec, **kw)
        )
        ok = np.asarray(ref.ok)
        np.testing.assert_array_equal(np.asarray(got.ok), ok)
        np.testing.assert_array_equal(
            np.asarray(got.gpu)[ok], np.asarray(ref.gpu)[ok]
        )
        np.testing.assert_array_equal(np.asarray(got.frag), np.asarray(ref.frag))
        if ref.mig is not None:
            np.testing.assert_array_equal(
                np.asarray(got.mig), np.asarray(ref.mig)
            )
            m = np.asarray(ref.mig)
            np.testing.assert_array_equal(
                np.asarray(got.mig_to_gpu)[m], np.asarray(ref.mig_to_gpu)[m]
            )

    def test_run_batched_kernel_on_mixed_fleet(self):
        """The former homogeneous-only restriction is gone: mixed fleets
        dispatch the ΔF kernel per model group."""
        cfg = SimConfig(cluster_spec=MIXED, offered_load=0.9, seed=1)
        r_k = batched.run_batched("mfi", cfg, runs=2, use_kernel=True)
        r_j = batched.run_batched("mfi", cfg, runs=2, use_kernel=False)
        assert r_k["acceptance_rate"] == r_j["acceptance_rate"]

    def test_kernel_lowering_opt_out(self):
        from repro.core.policy import PolicySpec

        no_kernel = PolicySpec(
            name="no-kernel", keys=("frag-delta", "gpu", "anchor"),
            kernel_lowering=False,
        )
        cfg = SimConfig(num_gpus=2, offered_load=0.8, seed=0)
        with pytest.raises(ValueError, match="opts out of Pallas kernel"):
            batched.run_batched(no_kernel, cfg, runs=1, use_kernel=True)
        # auto never picks the kernel for an opted-out spec
        r = batched.run_batched(no_kernel, cfg, runs=1)
        assert 0.0 <= r["acceptance_rate"] <= 1.0


# ---------------------------------------------------------------------------
# Fused select/migrate lowering (in-kernel lexicographic argmin)
# ---------------------------------------------------------------------------


class TestFusedLowering:
    """The fused per-model select/migrate kernels behind `use_kernel=True`
    must reproduce every pinned golden artifact bit-for-bit — the `(M, A)`
    score table never leaving VMEM is a pure implementation detail."""

    @pytest.mark.parametrize(
        "tag,cfg_fn,spec",
        [
            ("homog", lambda: SimConfig(num_gpus=5, offered_load=1.1, seed=7), None),
            (
                "mixed",
                lambda: SimConfig(cluster_spec=MIXED, offered_load=1.0, seed=9),
                MIXED,
            ),
        ],
    )
    @pytest.mark.slow
    def test_fused_traces_reproduce_golden_hashes(self, tag, cfg_fn, spec):
        cfg = cfg_fn()
        cspec = cfg.spec()
        events, _, rr, rc = batched.presample_arrivals(cfg, runs=3)
        _, trace = jax.device_get(
            batched._simulate(
                jax.tree.map(jnp.asarray, events),
                policy="mfi", metric=cfg.metric, num_gpus=cfg.num_gpus,
                ring_rows=rr, ring_cols=rc,
                use_kernel=True, kernel_spec=cspec,
                midx=jnp.asarray(cspec.model_index),
                tables=batched.spec_tables(cspec),
            )
        )
        h = hashlib.sha256()
        for a in (
            trace.ok, trace.gpu, trace.aidx, trace.free_sum, trace.active,
            trace.frag,
        ):
            h.update(np.ascontiguousarray(np.asarray(a)).tobytes())
        assert h.hexdigest() == GOLDEN_TRACE_HASHES[tag]

    @pytest.mark.slow
    def test_fused_golden_aggregates_reproduce(self):
        for tag, policy in [("homog_m6", "mfi")]:
            r = batched.run_batched(
                policy, GOLDEN_CONFIGS[tag](), runs=4, use_kernel=True
            )
            for key, want in GOLDEN_AGGREGATES[(tag, policy)].items():
                assert r[key] == want, f"{tag}/{policy}/{key}"

    @pytest.mark.slow
    def test_queued_fused_matches_jnp(self):
        cfg = SimConfig(
            num_gpus=4, offered_load=1.2, seed=7, protocol="steady-queued",
            wait_capacity=8, wait_patience=3,
        )
        cspec = cfg.spec()
        events, _, rr, rc = batched.presample_arrivals(cfg, runs=2, queued=True)
        kw = dict(
            policy="mfi-queued", metric=cfg.metric, num_gpus=cfg.num_gpus,
            ring_rows=rr, ring_cols=rc, protocol="steady-queued",
            wait_slots=cfg.wait_capacity, wait_patience=cfg.wait_patience,
            midx=jnp.asarray(cspec.model_index),
            tables=batched.spec_tables(cspec),
        )
        dev = jax.tree.map(jnp.asarray, events)
        _, ref = jax.device_get(batched._simulate(dev, use_kernel=False, **kw))
        _, got = jax.device_get(
            batched._simulate(dev, use_kernel=True, kernel_spec=cspec, **kw)
        )
        for field in ("ok", "gpu", "aidx", "frag", "free_sum", "active"):
            np.testing.assert_array_equal(
                np.asarray(getattr(got, field)),
                np.asarray(getattr(ref, field)), err_msg=field,
            )

    @pytest.mark.slow
    def test_delta_free_fusable_spec_lowers(self):
        """bf-bi consumes no ΔF table yet its keys are argmin-fusable: the
        fused select carries `use_kernel=True` alone (no delta_fn)."""
        core, _, _ = batched._build_core(
            policy="bf-bi", metric="blocked", num_gpus=4, use_kernel=True,
        )
        assert core.select_fn is not None and core.delta_fn is None
        cfg = SimConfig(num_gpus=4, offered_load=1.0, seed=3)
        r_k = batched.run_batched("bf-bi", cfg, runs=2, use_kernel=True)
        r_j = batched.run_batched("bf-bi", cfg, runs=2, use_kernel=False)
        assert {k: v for k, v in r_k.items() if np.isscalar(v)} == {
            k: v for k, v in r_j.items() if np.isscalar(v)
        }

    def test_build_core_dispatch_rules(self):
        """kernel_lowering picks the stage: "delta" stops at the ΔF kernel,
        True/"fused" wire select_fn (and migrate_fn on defrag specs)."""
        from repro.core.policy import PolicySpec

        mk = lambda **kw: batched._build_core(  # noqa: E731
            metric="blocked", num_gpus=4, use_kernel=True, **kw
        )[0]
        core = mk(policy="mfi")
        assert core.select_fn is not None and core.migrate_fn is None
        core = mk(policy="mfi-defrag")
        assert core.select_fn is not None and core.migrate_fn is not None
        delta_only = PolicySpec(
            name="mfi-delta-only", keys=("frag-delta", "gpu", "anchor"),
            kernel_lowering="delta",
        )
        core = mk(policy=delta_only)
        assert core.delta_fn is not None and core.select_fn is None
        assert core.migrate_fn is None

    @pytest.mark.slow
    def test_delta_lowering_matches_fused(self):
        """kernel_lowering="delta" (ΔF kernel + jnp argmin) and the fused
        path make identical decisions."""
        from repro.core.policy import PolicySpec

        delta_only = PolicySpec(
            name="mfi-delta-only", keys=("frag-delta", "gpu", "anchor"),
            kernel_lowering="delta",
        )
        cfg = SimConfig(num_gpus=4, offered_load=1.0, seed=3)
        r_d = batched.run_batched(delta_only, cfg, runs=2, use_kernel=True)
        r_f = batched.run_batched("mfi", cfg, runs=2, use_kernel=True)
        assert {k: v for k, v in r_d.items() if np.isscalar(v)} == {
            k: v for k, v in r_f.items() if np.isscalar(v)
        }


class TestFusedMigrateSearch:
    """`migrate_fn` plugged into `_migrate_search` must reproduce the dense
    reference oracle decision-for-decision (randomized occupancy, mixed and
    padded-geometry fleets included)."""

    @pytest.mark.slow
    @pytest.mark.parametrize(
        "spec", [None, MIXED, H200_MIX], ids=["homog", "mixed", "h200"]
    )
    def test_equivalence_randomized(self, spec):
        rng = np.random.default_rng(31)
        pspec = batched.resolve("mfi-defrag")
        migrations = 0
        for trial in range(10):
            cl, workloads = _random_cluster(
                rng,
                spec=spec,
                num_gpus=int(rng.integers(2, 6)) if spec is None else None,
                density=rng.random() * 1.2,
            )
            if not workloads:
                continue
            pid = int(rng.integers(0, mig.NUM_PROFILES))
            rows = int(rng.integers(1, 40))
            cols = -(-max(1, len(workloads)) // rows) + int(rng.integers(0, 4))
            args = _search_args(cl, workloads, pid, (rows, cols), rng)
            want = batched._migrate_search_dense(**args)
            args["migrate_fn"] = batched.make_migrate_fn(
                cl.spec, pspec, interpret=True
            )
            got = batched._migrate_search(**args)
            assert bool(got.mig) == bool(want.mig), f"trial {trial}"
            if bool(want.mig):
                migrations += 1
                for field in TestFactoredMigrateSearch.FIELDS:
                    np.testing.assert_array_equal(
                        np.asarray(getattr(got, field)),
                        np.asarray(getattr(want, field)),
                        err_msg=f"trial {trial}: {field}",
                    )
        assert migrations >= 2


class TestLexTop2:
    """`_lex_top2` edge cases (the migrate stage's per-class best/runner-up
    reduction) — semantics the fused kernels' host merge must mirror."""

    def test_duplicate_best_keys(self):
        """Two columns with identical key tuples: best = lowest column,
        runner-up = the second tied column."""
        keys = jnp.asarray(
            [[[2.0, 1.0], [1.0, 0.0], [1.0, 0.0], [3.0, 9.0]]]
        )
        ok = jnp.ones((1, 4), bool)
        g1, ok1, g2, ok2 = batched._lex_top2(keys, ok)
        assert (int(g1[0]), bool(ok1[0])) == (1, True)
        assert (int(g2[0]), bool(ok2[0])) == (2, True)

    def test_all_infeasible_row(self):
        keys = jnp.zeros((1, 3, 2))
        ok = jnp.zeros((1, 3), bool)
        g1, ok1, g2, ok2 = batched._lex_top2(keys, ok)
        assert not bool(ok1[0]) and not bool(ok2[0])
        # no winner exists: the runner-up must NOT exclude the
        # placeholder column, so both carry argmax-of-empty-mask (0)
        assert int(g1[0]) == 0 and int(g2[0]) == 0

    def test_single_candidate_row(self):
        keys = jnp.asarray([[[5.0], [1.0], [7.0]]])
        ok = jnp.asarray([[False, True, False]])
        g1, ok1, g2, ok2 = batched._lex_top2(keys, ok)
        assert (int(g1[0]), bool(ok1[0])) == (1, True)
        assert not bool(ok2[0])

    def test_fused_merge_agrees_on_ties(self):
        """The fused path's cross-tile `_merge_top2` resolves duplicate-key
        ties to the same (lowest-gpu) pair as `_lex_top2`."""
        l = 2
        # two tiles' candidate rows for one class: [k0, k1, gpu, col, ok]
        cand = jnp.asarray(
            [[
                [1.0, 0.0, 4.0, 2.0, 1.0],   # tied best, higher gpu
                [2.0, 1.0, 0.0, 0.0, 1.0],
                [1.0, 0.0, 1.0, 3.0, 1.0],   # tied best, lowest gpu
                [3.0, 9.0, 2.0, 1.0, 1.0],
            ]]
        )
        g1, ok1, a1, _, g2, ok2, a2, _ = batched._merge_top2(cand, l)
        assert (int(g1[0]), int(a1[0]), bool(ok1[0])) == (1, 3, True)
        assert (int(g2[0]), int(a2[0]), bool(ok2[0])) == (4, 2, True)
        t1, tok1, t2, tok2 = batched._lex_top2(
            cand[..., :l + 1], cand[..., l + 2] > 0
        )
        # _lex_top2 ranks by column index; map through the gpu column
        assert int(cand[0, int(t1[0]), l]) == int(g1[0]) and bool(tok1[0])
        assert int(cand[0, int(t2[0]), l]) == int(g2[0]) and bool(tok2[0])


# ---------------------------------------------------------------------------
# Satellite: per-model request distributions
# ---------------------------------------------------------------------------


class TestPerModelDistributions:
    def test_mixture_is_capacity_weighted(self):
        spec = mig.ClusterSpec(((mig.A100_80GB, 1), (mig.A100_40GB, 3)))
        probs = resolve_probs(
            "uniform", spec, {"a100-40": "skew-small"}
        )
        want = (8 / 32) * DISTRIBUTIONS["uniform"] + (24 / 32) * DISTRIBUTIONS[
            "skew-small"
        ]
        np.testing.assert_allclose(probs, want)

    def test_default_is_exact_named_mix(self):
        cfg = SimConfig(num_gpus=4, distribution="skew-big")
        assert request_probs(cfg) is DISTRIBUTIONS["skew-big"]

    def test_validation(self):
        spec = mig.ClusterSpec.homogeneous(mig.A100_80GB, 2)
        with pytest.raises(ValueError, match="unknown device model"):
            resolve_probs("uniform", spec, {"v100": "uniform"})
        with pytest.raises(ValueError, match="not in the fleet"):
            resolve_probs("uniform", spec, {"h100-96": "uniform"})
        with pytest.raises(ValueError, match="unknown distribution"):
            resolve_probs("uniform", spec, {"a100-80": "weird"})

    def test_same_stream_parity_with_model_mixes(self):
        """Both engines draw from the same mixture, so decision-for-decision
        parity holds under per-model mixes too."""
        cfg = SimConfig(
            cluster_spec=MIXED,
            offered_load=0.9,
            seed=4,
            model_distributions={"a100-40": "skew-small", "a100-80": "skew-big"},
        )
        events, meta, trace, _ = _sim("mfi", cfg, MIXED, runs=2)
        ok_ref, gpu_ref, _ = replay.host_decisions(
            events, meta, "mfi", cfg.num_gpus, spec=MIXED
        )
        ok = np.asarray(trace.ok)
        np.testing.assert_array_equal(ok, ok_ref)
        np.testing.assert_array_equal(np.asarray(trace.gpu)[ok], gpu_ref[ok])

    def test_mix_shifts_the_sampled_classes(self):
        cfg_small = SimConfig(
            cluster_spec=MIXED, seed=0,
            model_distributions={m.name: "skew-small" for m in MIXED.models},
        )
        cfg_big = SimConfig(
            cluster_spec=MIXED, seed=0,
            model_distributions={m.name: "skew-big" for m in MIXED.models},
        )
        ev_s, *_ = batched.presample_arrivals(cfg_small, runs=4)
        ev_b, *_ = batched.presample_arrivals(cfg_big, runs=4)
        mean_s = mig.PROFILE_MEM[ev_s.pid[ev_s.pid >= 0]].mean()
        mean_b = mig.PROFILE_MEM[ev_b.pid[ev_b.pid >= 0]].mean()
        assert mean_s < mean_b  # small-skewed demand really is smaller


# ---------------------------------------------------------------------------
# Satellite: non-8-slice H200-141GB geometry
# ---------------------------------------------------------------------------


class TestH200Geometry:
    def test_registry_and_tables(self):
        assert mig.DEVICE_MODELS["h200-141"] is mig.H200_141GB
        m = mig.H200_141GB
        assert m.num_mem_slices == 12
        for prof in m.profiles:
            for a in prof.anchors:
                assert a + prof.mem <= 12
        np.testing.assert_array_equal(
            m.placement_masks.sum(axis=1), m.placement_mem
        )
        assert m.num_placements == 1 + 3 + 3 + 6 + 6 + 12
        assert m.max_anchors == 12

    def test_padded_width_tables(self):
        tables = batched.spec_tables(H200_MIX)
        assert tables.W.shape[2] == 12  # padded to the widest model
        assert H200_MIX.num_mem_slices == 12
        # A100 rows can never occupy the padding columns
        k_a100 = H200_MIX.models.index(mig.A100_80GB)
        assert np.asarray(tables.W)[k_a100, :, 8:].sum() == 0

    def test_mixed_fleet_same_stream_parity(self):
        cfg = SimConfig(cluster_spec=H200_MIX, offered_load=1.0, seed=6)
        for policy in ("mfi", "bf-bi"):
            events, meta, trace, _ = _sim(policy, cfg, H200_MIX, runs=2)
            ok_ref, gpu_ref, _ = replay.host_decisions(
                events, meta, policy, cfg.num_gpus, spec=H200_MIX
            )
            ok = np.asarray(trace.ok)
            np.testing.assert_array_equal(ok, ok_ref)
            np.testing.assert_array_equal(np.asarray(trace.gpu)[ok], gpu_ref[ok])
            replay.replay(events, meta, trace, cfg.num_gpus, spec=H200_MIX)
            _, drained = replay.drain_all(
                events, meta, trace, cfg.num_gpus, spec=H200_MIX
            )
            np.testing.assert_array_equal(drained, 0)


# ---------------------------------------------------------------------------
# Satellite: replica-axis sharding
# ---------------------------------------------------------------------------


class TestReplicaSharding:
    def test_single_device_fallbacks(self):
        if len(jax.devices()) > 1:
            pytest.skip("test targets the single-device fallback")
        events, _, _, _ = batched.presample_arrivals(
            SimConfig(num_gpus=2, seed=0), runs=2
        )
        dev = jax.tree.map(jnp.asarray, events)
        assert batched.shard_events(dev, 2, None) is dev  # auto: no-op
        assert batched.shard_events(dev, 2, False) is dev
        with pytest.raises(ValueError, match="only one device"):
            batched.shard_events(dev, 2, True)

    @pytest.mark.slow
    def test_multi_device_results_identical(self):
        """8 forced host devices: the sharded run must produce bitwise the
        same aggregates as the unsharded one (subprocess so the XLA_FLAGS
        override never pollutes this process)."""
        code = textwrap.dedent("""
            import os
            os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
            import sys, json
            sys.path.insert(0, "src")
            import jax
            from repro.sim import SimConfig
            from repro.sim.batched import run_batched
            assert len(jax.devices()) == 8
            cfg = SimConfig(num_gpus=4, offered_load=0.9, seed=2)
            r_sharded = run_batched("mfi", cfg, runs=8, shard=True)
            r_plain = run_batched("mfi", cfg, runs=8, shard=False)
            print(json.dumps({
                "sharded": {k: r_sharded[k] for k in
                            ("acceptance_rate", "utilization", "frag_severity")},
                "plain": {k: r_plain[k] for k in
                          ("acceptance_rate", "utilization", "frag_severity")},
            }))
        """)
        out = subprocess.run(
            [sys.executable, "-c", code], capture_output=True, text=True,
            timeout=600,
        )
        assert out.returncode == 0, out.stderr[-2000:]
        res = json.loads(out.stdout.strip().splitlines()[-1])
        assert res["sharded"] == res["plain"]


# ---------------------------------------------------------------------------
# Queued protocol: the wait/park stages
# ---------------------------------------------------------------------------


def _sim_queued(policy, cfg, spec=None, runs=3):
    events, meta, rr, rc = batched.presample_arrivals(cfg, runs=runs, queued=True)
    kw = {}
    if spec is not None:
        kw = dict(
            midx=jnp.asarray(spec.model_index), tables=batched.spec_tables(spec)
        )
    final, trace = jax.device_get(
        batched._simulate(
            jax.tree.map(
                lambda x: jnp.asarray(x) if x is not None else None, events
            ),
            policy=policy,
            metric=cfg.metric,
            num_gpus=cfg.num_gpus,
            ring_rows=rr,
            ring_cols=rc,
            use_kernel=False,
            protocol="steady-queued",
            wait_slots=cfg.wait_capacity,
            wait_patience=cfg.wait_patience,
            **kw,
        )
    )
    return events, meta, trace, final


#: decision-trace hashes of the queued protocol at introduction — the wait
#: ring and park/admit stages must stay bit-for-bit reproducible
GOLDEN_QUEUED_TRACE_HASHES = {
    "homog": "e3d1a83fced05aaa968ff95c2d9e3ed5d71839e2e12d4c6634e0389f80918925",
    "mixed": "e368416188f84d500dbb7115410d3a24152fa06eac0dce525001032273a9f32f",
}


class TestQueuedEngine:
    def test_protocol_registered(self):
        proto = batched.resolve_protocol("steady-queued")
        assert proto.queued and proto.boundary_metrics and not proto.post_metrics
        assert not batched.resolve_protocol("steady").queued

    def test_steady_stream_unchanged_by_queued_draws(self):
        """Tenant/priority sampling happens strictly after the shared rng
        stream: the arrival stream itself must stay byte-identical, keeping
        every existing steady golden valid."""
        cfg = SimConfig(num_gpus=5, offered_load=1.1, seed=7)
        ev_plain, meta_plain, *_ = batched.presample_arrivals(cfg, runs=3)
        ev_q, meta_q, *_ = batched.presample_arrivals(cfg, runs=3, queued=True)
        np.testing.assert_array_equal(ev_plain.pid, ev_q.pid)
        np.testing.assert_array_equal(ev_plain.exp_row, ev_q.exp_row)
        np.testing.assert_array_equal(meta_plain.slot, meta_q.slot)
        np.testing.assert_array_equal(meta_plain.end, meta_q.end)
        assert ev_plain.prio is None and ev_q.prio is not None

    @pytest.mark.parametrize(
        "tag,cfg_fn,spec,policy",
        [
            (
                "homog",
                lambda: SimConfig(num_gpus=5, offered_load=1.2, seed=7),
                None,
                "mfi",
            ),
            (
                "mixed",
                lambda: SimConfig(cluster_spec=MIXED, offered_load=1.1, seed=9),
                MIXED,
                "mfi-queued",
            ),
        ],
    )
    def test_same_stream_queued_host_parity(self, tag, cfg_fn, spec, policy):
        """Every in-place decision, park, wait-admission (origin AND
        placement) matches the independent host reference."""
        cfg = cfg_fn()
        events, meta, trace, _ = _sim_queued(policy, cfg, spec)
        ref = replay.queued_host_decisions(
            events, meta, policy, cfg.num_gpus, metric=cfg.metric, spec=spec,
            capacity=cfg.wait_capacity, patience=cfg.wait_patience,
        )
        np.testing.assert_array_equal(np.asarray(trace.ok), ref.ok)
        np.testing.assert_array_equal(np.asarray(trace.parked), ref.parked)
        acc = ref.ok
        np.testing.assert_array_equal(np.asarray(trace.gpu)[acc], ref.gpu[acc])
        np.testing.assert_array_equal(
            np.asarray(trace.wadm_eidx), ref.wadm_eidx
        )
        adm = ref.wadm_eidx >= 0
        np.testing.assert_array_equal(
            np.asarray(trace.wadm_gpu)[adm], ref.wadm_gpu[adm]
        )
        assert adm.sum() > 0, "stream exercised no wait admissions"

    def test_queued_replay_invariants(self):
        """The replay walk re-executes wait admissions (legal anchors, no
        double-booking, lease not expired) and drains cleanly."""
        cfg = SimConfig(num_gpus=5, offered_load=1.2, seed=7)
        events, meta, trace, _ = _sim_queued("mfi", cfg, None)
        replay.replay(events, meta, trace, cfg.num_gpus)
        _, drained = replay.drain_all(events, meta, trace, cfg.num_gpus)
        assert (drained == 0).all()

    def test_run_batched_queued_metrics(self):
        cfg = SimConfig(
            num_gpus=8, offered_load=1.2, seed=5, protocol="steady-queued"
        )
        r = batched.run_batched("mfi", cfg, runs=3)
        for k in ("wait_p50", "wait_p99", "fairness", "queue_admits"):
            assert k in r
        assert 0.0 <= r["wait_p50"] <= r["wait_p99"] <= cfg.wait_patience
        assert 0.0 < r["fairness"] <= 1.0
        assert r["acceptance_rate"] > 0.0
        # queueing can only help acceptance on the same stream shape
        plain = batched.run_batched(
            "mfi", SimConfig(num_gpus=8, offered_load=1.2, seed=5), runs=3
        )
        assert r["acceptance_rate"] >= plain["acceptance_rate"]

    def test_queued_rejects_defrag(self):
        cfg = SimConfig(
            num_gpus=4, offered_load=1.0, seed=1, protocol="steady-queued"
        )
        with pytest.raises(ValueError, match="defrag"):
            batched.run_batched("mfi-defrag", cfg, runs=2)

    def test_queued_requires_wait_slots(self):
        cfg = SimConfig(num_gpus=3, offered_load=1.0, seed=1)
        events, meta, rr, rc = batched.presample_arrivals(
            cfg, runs=2, queued=True
        )
        with pytest.raises(ValueError, match="wait_slots"):
            batched._simulate(
                jax.tree.map(
                    lambda x: jnp.asarray(x) if x is not None else None, events
                ),
                policy="mfi",
                metric=cfg.metric,
                num_gpus=cfg.num_gpus,
                ring_rows=rr,
                ring_cols=rc,
                use_kernel=False,
                protocol="steady-queued",
                wait_slots=0,
            )

    @pytest.mark.parametrize("tag", sorted(GOLDEN_QUEUED_TRACE_HASHES))
    def test_queued_decision_traces_hash_identically(self, tag):
        cfg, spec, policy = {
            "homog": (
                SimConfig(num_gpus=5, offered_load=1.2, seed=7), None, "mfi"
            ),
            "mixed": (
                SimConfig(cluster_spec=MIXED, offered_load=1.1, seed=9),
                MIXED,
                "mfi-queued",
            ),
        }[tag]
        _, _, trace, _ = _sim_queued(policy, cfg, spec)
        h = hashlib.sha256()
        for a in (
            trace.ok, trace.gpu, trace.aidx, trace.parked, trace.wadm_eidx,
            trace.wadm_gpu, trace.wadm_aidx, trace.free_sum, trace.active,
            trace.frag,
        ):
            h.update(np.ascontiguousarray(np.asarray(a)).tobytes())
        assert h.hexdigest() == GOLDEN_QUEUED_TRACE_HASHES[tag]
