"""Serving engine + admission control integration tests."""

import numpy as np
import pytest

import jax

from repro.configs import SMOKES
from repro.core import mig
from repro.models import model
from repro.serving import AdmissionController, Request, ServingEngine
from repro.serving.admission import profile_for_model


class TestAdmission:
    def test_admit_release_cycle(self):
        ac = AdmissionController(num_gpus=2, policy="mfi")
        p = ac.admit(1, "3g.40gb")
        assert p is not None
        assert ac.cluster.used_mem_slices == 4
        ac.release(1)
        assert ac.cluster.used_mem_slices == 0

    def test_rejection_when_full(self):
        ac = AdmissionController(num_gpus=1, policy="mfi")
        assert ac.admit(1, "7g.80gb") is not None
        assert ac.admit(2, "1g.10gb") is None
        assert ac.rejected == 1

    def test_policy_selectable(self):
        for policy in ("ff", "rr", "bf-bi", "wf-bi", "mfi"):
            ac = AdmissionController(num_gpus=2, policy=policy)
            assert ac.admit(1, "1g.10gb") is not None

    def test_profile_for_model(self):
        assert profile_for_model(int(5e9)) == "1g.10gb"
        assert profile_for_model(int(15e9)) == "1g.20gb"
        assert profile_for_model(int(15e9), compute_heavy=True) == "2g.20gb"
        assert profile_for_model(int(70e9)) == "7g.80gb"

    def test_profile_for_model_unplaceable_raises(self):
        """Footprints past the largest profile must fail loudly, not be
        silently mapped to a 7g.80gb that cannot hold them."""
        with pytest.raises(ValueError, match="exceeds the largest"):
            profile_for_model(int(100e9))
        # the drifted module-level GiB table is gone
        import repro.serving.admission as adm

        assert not hasattr(adm, "_PROFILE_BY_GIB")

    def test_duplicate_workload_id_rejected(self):
        """A second admit of a live workload id must raise instead of
        silently orphaning the first placement's slices."""
        ac = AdmissionController(num_gpus=2, policy="mfi")
        assert ac.admit(1, "1g.10gb") is not None
        before = ac.cluster.used_mem_slices
        with pytest.raises(ValueError, match="already placed"):
            ac.admit(1, "1g.10gb")
        assert ac.cluster.used_mem_slices == before
        ac.release(1)
        assert ac.cluster.used_mem_slices == 0

    def test_release_unknown_workload_raises(self):
        ac = AdmissionController(num_gpus=2)
        with pytest.raises(KeyError, match="no active placement"):
            ac.release(99)
        # ClusterState itself also validates
        with pytest.raises(KeyError, match="not placed"):
            ac.cluster.release(99)
        with pytest.raises(ValueError, match="already placed"):
            ac.cluster.allocate(5, 0, 0, 0)
            ac.cluster.allocate(5, 0, 1, 0)

    def test_unknown_profile_rejected(self):
        ac = AdmissionController(num_gpus=1)
        with pytest.raises(ValueError, match="unknown MIG profile"):
            ac.submit(1, "9g.90gb")

    def test_stats(self):
        ac = AdmissionController(num_gpus=2)
        ac.admit(1, "1g.10gb")
        s = ac.stats()
        assert s["accepted"] == 1 and s["active_gpus"] == 1

    def test_defrag_policy_applies_migration(self):
        """mfi-defrag admission migrates the blocking victim (and keeps its
        placement record current) instead of double-booking."""
        ac = AdmissionController(num_gpus=2, policy="mfi-defrag")
        assert ac.admit(1, "1g.10gb") is not None
        # misplace the blocker exactly like the scheduler-unit scenario
        ac.release(1)
        ac.cluster.allocate(1, mig.PROFILE_NAMES.index("1g.10gb"), 0, 1)
        from repro.serving.admission import Placement

        ac.placements[1] = Placement(1, "1g.10gb", 0, 1)
        assert ac.admit(2, "4g.40gb") is not None
        assert ac.admit(3, "2g.20gb") is not None
        p = ac.admit(4, "4g.40gb")  # only feasible via migrating workload 1
        assert p is not None
        moved = ac.placements[1]
        assert (moved.gpu, moved.anchor) != (0, 1)
        # occupancy stays consistent with the placement table
        for g in ac.cluster.gpus:
            expect = np.zeros(mig.NUM_MEM_SLICES, np.int32)
            for a in g.allocations.values():
                expect[a.anchor : a.anchor + mig.PROFILES[a.profile_id].mem] = 1
            np.testing.assert_array_equal(g.occupancy, expect)


class TestQueuedAdmission:
    def test_parked_request_dispatches_on_release(self):
        ac = AdmissionController(num_gpus=1, policy="mfi")
        assert ac.submit(1, "7g.80gb") is not None
        assert ac.submit(2, "7g.80gb", patience=4) is None
        assert ac.in_queue(2) and ac.queue_depth == 1
        ac.release(1)  # re-drives admission from the queue
        dispatched = ac.drain_dispatched()
        assert [p.workload_id for p in dispatched] == [2]
        assert not ac.in_queue(2)
        assert ac.accepted == 2 and ac.rejected == 0

    def test_priority_orders_the_queue(self):
        """Lower priority value = more urgent; it overtakes FIFO order."""
        ac = AdmissionController(num_gpus=1, policy="mfi")
        assert ac.submit(1, "7g.80gb") is not None
        assert ac.submit(2, "7g.80gb", priority=1, patience=8) is None
        assert ac.submit(3, "7g.80gb", priority=0, patience=8) is None
        ac.release(1)
        assert [p.workload_id for p in ac.drain_dispatched()] == [3]
        assert ac.in_queue(2)

    def test_patience_expiry_final_reject(self):
        ac = AdmissionController(num_gpus=1, policy="mfi")
        assert ac.submit(1, "7g.80gb") is not None
        assert ac.submit(2, "1g.10gb", patience=2) is None
        ac.tick(3)  # clock passes the patience budget
        assert ac.drain_expired() == [2]
        assert ac.rejected == 1 and ac.queue_depth == 0

    def test_zero_patience_is_accept_or_drop(self):
        ac = AdmissionController(num_gpus=1, policy="mfi")
        assert ac.submit(1, "7g.80gb") is not None
        assert ac.submit(2, "1g.10gb") is None
        assert ac.queue_depth == 0 and ac.rejected == 1

    def test_tenant_quota_parks_over_quota_requests(self):
        ac = AdmissionController(
            num_gpus=2, policy="mfi", tenant_quotas={"a": 1}
        )
        assert ac.submit(1, "1g.10gb", tenant="a") is not None
        # capacity exists, but tenant "a" is at quota -> parks
        assert ac.submit(2, "1g.10gb", tenant="a", patience=4) is None
        assert ac.in_queue(2)
        # another tenant is unaffected
        assert ac.submit(3, "1g.10gb", tenant="b") is not None
        ac.release(1)
        assert [p.workload_id for p in ac.drain_dispatched()] == [2]

    def test_queue_capacity_bounds_parking(self):
        ac = AdmissionController(num_gpus=1, policy="mfi", queue_capacity=1)
        assert ac.submit(1, "7g.80gb") is not None
        assert ac.submit(2, "1g.10gb", patience=4) is None
        assert ac.submit(3, "1g.10gb", patience=4) is None  # queue full
        assert ac.queue_depth == 1 and ac.rejected == 1

    def test_wait_and_fairness_stats(self):
        ac = AdmissionController(num_gpus=1, policy="mfi")
        assert ac.submit(1, "7g.80gb", tenant="a") is not None
        assert ac.submit(2, "7g.80gb", tenant="b", patience=8) is None
        ac.tick(2)
        ac.release(1)
        assert [p.workload_id for p in ac.drain_dispatched()] == [2]
        s = ac.stats()
        assert s["queue_depth"] == 0.0
        assert s["wait_p99"] >= 1.9  # workload 2 waited two ticks (p99 interpolates)
        assert 0.0 < s["fairness"] <= 1.0


def _replay_stream_through_scheduler(policy, spec, stream):
    """Drive a raw SpecScheduler + ClusterState over an arrival/termination
    stream, mirroring what AdmissionController should decide."""
    from repro.core.schedulers import make_scheduler

    cluster = mig.ClusterState(spec=spec)
    scheduler = make_scheduler(policy, "blocked")
    decisions = {}
    for kind, wid, pid in stream:
        if kind == "end":
            if decisions.get(wid) is not None:
                cluster.release(wid)
            continue
        sel = scheduler.select(cluster, pid)
        if sel is None:
            decisions[wid] = None
            continue
        pending = getattr(scheduler, "pending_migration", None)
        if pending is not None:
            vwid, vgpu, vanchor = pending
            cluster.migrate(vwid, vgpu, vanchor)
        cluster.allocate(wid, pid, *sel)
        decisions[wid] = sel
    return decisions, cluster


class TestServingSimulatorParity:
    """Satellite: same-stream serving-vs-scheduler decision parity."""

    MIXED = mig.ClusterSpec(((mig.A100_80GB, 2), (mig.A100_40GB, 2)))

    def _stream(self, seed, n=80, horizon=10):
        rng = np.random.default_rng(seed)
        stream, live = [], []
        for wid in range(n):
            for _ in range(rng.integers(0, 3)):
                if live and rng.random() < 0.5:
                    stream.append(("end", live.pop(0), -1))
            stream.append(("arr", wid, int(rng.integers(0, mig.NUM_PROFILES))))
            live.append(wid)
        for wid in live:
            stream.append(("end", wid, -1))
        return stream

    @pytest.mark.parametrize("policy", ["mfi", "bf-bi", "mfi-defrag"])
    @pytest.mark.parametrize("fleet", ["homog", "mixed"])
    def test_admission_matches_scheduler(self, policy, fleet):
        spec = (
            mig.ClusterSpec.homogeneous(mig.A100_80GB, 4)
            if fleet == "homog"
            else self.MIXED
        )
        stream = self._stream(seed=7)
        ref, ref_cluster = _replay_stream_through_scheduler(policy, spec, stream)

        ac = AdmissionController(policy=policy, cluster_spec=spec)
        got = {}
        for kind, wid, pid in stream:
            if kind == "end":
                if got.get(wid) is not None:
                    ac.release(wid)
                continue
            p = ac.admit(wid, mig.PROFILE_NAMES[pid])
            got[wid] = None if p is None else (p.gpu, p.anchor)
        assert got == ref
        # identical end-state occupancy (migrations included)
        np.testing.assert_array_equal(
            ac.cluster.occupancy_matrix(), ref_cluster.occupancy_matrix()
        )


@pytest.mark.slow
class TestServingEngine:
    @pytest.fixture(scope="class")
    def setup(self):
        cfg = SMOKES["llama3.2-1b"]
        params = model.init_params(cfg, jax.random.PRNGKey(0))
        return cfg, params

    def test_serves_requests_end_to_end(self, setup):
        cfg, params = setup
        rng = np.random.default_rng(0)
        reqs = [
            Request(i, rng.integers(0, cfg.vocab, 16).astype(np.int32), 4, "1g.10gb")
            for i in range(6)
        ]
        eng = ServingEngine(cfg, params, num_slots=3, max_len=32, num_gpus=2)
        stats = eng.run(reqs)
        assert all(r.finished for r in reqs)
        served = [r for r in reqs if r.admitted]
        assert len(served) == 6  # 2 GPUs × 7 slots >> 6 × 1g.10gb
        assert all(len(r.output) == 4 for r in served)
        assert stats["acceptance_rate"] == 1.0
        # all slices released at the end
        assert eng.admission.cluster.used_mem_slices == 0

    def test_rejects_oversubscription(self, setup):
        cfg, params = setup
        rng = np.random.default_rng(1)
        reqs = [
            Request(i, rng.integers(0, cfg.vocab, 16).astype(np.int32), 2, "7g.80gb")
            for i in range(4)
        ]
        eng = ServingEngine(cfg, params, num_slots=2, max_len=32, num_gpus=1, policy="mfi")
        eng.run(reqs)
        admitted = sum(r.admitted for r in reqs)
        rejected = sum(r.rejected for r in reqs)
        # 1 GPU serves one 7g at a time; waves release between admissions
        assert admitted >= 1 and admitted + rejected == 4

    def test_zero_token_request_finishes_clean(self, setup):
        """max_new_tokens == 0 must finish with output == [] and release
        its slices — not linger half-served."""
        cfg, params = setup
        rng = np.random.default_rng(3)
        reqs = [
            Request(0, rng.integers(0, cfg.vocab, 16).astype(np.int32), 0),
            Request(1, rng.integers(0, cfg.vocab, 16).astype(np.int32), 3),
        ]
        eng = ServingEngine(cfg, params, num_slots=2, max_len=32, num_gpus=1)
        eng.run(reqs)
        assert reqs[0].finished and reqs[0].output == []
        assert reqs[1].finished and len(reqs[1].output) == 3
        assert eng.admission.cluster.used_mem_slices == 0

    def test_rejected_requests_get_empty_output(self, setup):
        cfg, params = setup
        rng = np.random.default_rng(4)
        reqs = [
            Request(i, rng.integers(0, cfg.vocab, 16).astype(np.int32), 2, "7g.80gb")
            for i in range(3)
        ]
        # one wave slot, one GPU: later requests reject inside the wave fill
        eng = ServingEngine(cfg, params, num_slots=2, max_len=32, num_gpus=1)
        eng.run(reqs)
        for r in reqs:
            assert r.finished
            assert isinstance(r.output, list)  # never None in terminal state
            if r.rejected:
                assert r.output == []

    def test_patient_requests_queue_across_waves(self, setup):
        """With patience, an over-capacity request waits for a release and
        serves in a later wave instead of dropping."""
        cfg, params = setup
        rng = np.random.default_rng(5)
        reqs = [
            Request(
                i,
                rng.integers(0, cfg.vocab, 16).astype(np.int32),
                2,
                "7g.80gb",
                patience=8,
            )
            for i in range(3)
        ]
        eng = ServingEngine(cfg, params, num_slots=3, max_len=32, num_gpus=1)
        stats = eng.run(reqs)
        # one GPU serves one 7g at a time, but patience lets all three land
        assert all(r.admitted and r.finished for r in reqs)
        assert all(len(r.output) == 2 for r in reqs)
        assert stats["acceptance_rate"] == 1.0
        assert stats["wait_p99"] > 0.0
        assert eng.admission.cluster.used_mem_slices == 0

    def test_deterministic_outputs(self, setup):
        cfg, params = setup
        rng = np.random.default_rng(2)
        prompt = rng.integers(0, cfg.vocab, 16).astype(np.int32)

        outs = []
        for _ in range(2):
            req = Request(0, prompt.copy(), 4, "1g.10gb")
            eng = ServingEngine(cfg, params, num_slots=1, max_len=32, num_gpus=1)
            eng.run([req])
            outs.append(tuple(req.output))
        assert outs[0] == outs[1]
