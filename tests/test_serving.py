"""Serving engine + admission control integration tests."""

import numpy as np
import pytest

import jax

from repro.configs import SMOKES
from repro.core import mig
from repro.models import model
from repro.serving import AdmissionController, Request, ServingEngine
from repro.serving.admission import profile_for_model


class TestAdmission:
    def test_admit_release_cycle(self):
        ac = AdmissionController(num_gpus=2, policy="mfi")
        p = ac.admit(1, "3g.40gb")
        assert p is not None
        assert ac.cluster.used_mem_slices == 4
        ac.release(1)
        assert ac.cluster.used_mem_slices == 0

    def test_rejection_when_full(self):
        ac = AdmissionController(num_gpus=1, policy="mfi")
        assert ac.admit(1, "7g.80gb") is not None
        assert ac.admit(2, "1g.10gb") is None
        assert ac.rejected == 1

    def test_policy_selectable(self):
        for policy in ("ff", "rr", "bf-bi", "wf-bi", "mfi"):
            ac = AdmissionController(num_gpus=2, policy=policy)
            assert ac.admit(1, "1g.10gb") is not None

    def test_profile_for_model(self):
        assert profile_for_model(int(5e9)) == "1g.10gb"
        assert profile_for_model(int(15e9)) == "1g.20gb"
        assert profile_for_model(int(15e9), compute_heavy=True) == "2g.20gb"
        assert profile_for_model(int(70e9)) == "7g.80gb"

    def test_stats(self):
        ac = AdmissionController(num_gpus=2)
        ac.admit(1, "1g.10gb")
        s = ac.stats()
        assert s["accepted"] == 1 and s["active_gpus"] == 1

    def test_defrag_policy_applies_migration(self):
        """mfi-defrag admission migrates the blocking victim (and keeps its
        placement record current) instead of double-booking."""
        ac = AdmissionController(num_gpus=2, policy="mfi-defrag")
        assert ac.admit(1, "1g.10gb") is not None
        # misplace the blocker exactly like the scheduler-unit scenario
        ac.release(1)
        ac.cluster.allocate(1, mig.PROFILE_NAMES.index("1g.10gb"), 0, 1)
        from repro.serving.admission import Placement

        ac.placements[1] = Placement(1, "1g.10gb", 0, 1)
        assert ac.admit(2, "4g.40gb") is not None
        assert ac.admit(3, "2g.20gb") is not None
        p = ac.admit(4, "4g.40gb")  # only feasible via migrating workload 1
        assert p is not None
        moved = ac.placements[1]
        assert (moved.gpu, moved.anchor) != (0, 1)
        # occupancy stays consistent with the placement table
        for g in ac.cluster.gpus:
            expect = np.zeros(mig.NUM_MEM_SLICES, np.int32)
            for a in g.allocations.values():
                expect[a.anchor : a.anchor + mig.PROFILES[a.profile_id].mem] = 1
            np.testing.assert_array_equal(g.occupancy, expect)


@pytest.mark.slow
class TestServingEngine:
    @pytest.fixture(scope="class")
    def setup(self):
        cfg = SMOKES["llama3.2-1b"]
        params = model.init_params(cfg, jax.random.PRNGKey(0))
        return cfg, params

    def test_serves_requests_end_to_end(self, setup):
        cfg, params = setup
        rng = np.random.default_rng(0)
        reqs = [
            Request(i, rng.integers(0, cfg.vocab, 16).astype(np.int32), 4, "1g.10gb")
            for i in range(6)
        ]
        eng = ServingEngine(cfg, params, num_slots=3, max_len=32, num_gpus=2)
        stats = eng.run(reqs)
        assert all(r.finished for r in reqs)
        served = [r for r in reqs if r.admitted]
        assert len(served) == 6  # 2 GPUs × 7 slots >> 6 × 1g.10gb
        assert all(len(r.output) == 4 for r in served)
        assert stats["acceptance_rate"] == 1.0
        # all slices released at the end
        assert eng.admission.cluster.used_mem_slices == 0

    def test_rejects_oversubscription(self, setup):
        cfg, params = setup
        rng = np.random.default_rng(1)
        reqs = [
            Request(i, rng.integers(0, cfg.vocab, 16).astype(np.int32), 2, "7g.80gb")
            for i in range(4)
        ]
        eng = ServingEngine(cfg, params, num_slots=2, max_len=32, num_gpus=1, policy="mfi")
        eng.run(reqs)
        admitted = sum(r.admitted for r in reqs)
        rejected = sum(r.rejected for r in reqs)
        # 1 GPU serves one 7g at a time; waves release between admissions
        assert admitted >= 1 and admitted + rejected == 4

    def test_deterministic_outputs(self, setup):
        cfg, params = setup
        rng = np.random.default_rng(2)
        prompt = rng.integers(0, cfg.vocab, 16).astype(np.int32)

        outs = []
        for _ in range(2):
            req = Request(0, prompt.copy(), 4, "1g.10gb")
            eng = ServingEngine(cfg, params, num_slots=1, max_len=32, num_gpus=1)
            eng.run([req])
            outs.append(tuple(req.output))
        assert outs[0] == outs[1]
