"""Per-architecture smoke tests: reduced variant of the same family runs one
forward/train step on CPU; output shapes + no NaNs (assignment requirement)."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import ASSIGNED, SMOKES
from repro.models import model
from repro.optim import adamw_init, adamw_update

B, S = 2, 64


def _batch(cfg, rng):
    k1, k2, k3 = jax.random.split(rng, 3)
    toks = jax.random.randint(k1, (B, S), 0, cfg.vocab)
    batch = {"tokens": toks, "labels": toks}
    if cfg.frontend == "vision":
        batch["patches"] = jax.random.normal(k2, (B, cfg.num_patches, cfg.d_model), jnp.float32)
    if cfg.encdec:
        batch["frames"] = jax.random.normal(k3, (B, S, cfg.d_model), jnp.float32)
    return batch


@pytest.mark.slow
@pytest.mark.parametrize("arch", ASSIGNED)
def test_smoke_forward_and_train_step(arch):
    cfg = SMOKES[arch]
    assert cfg.n_layers <= 2 and cfg.d_model <= 512 and cfg.n_experts <= 4

    rng = jax.random.PRNGKey(0)
    params = model.init_params(cfg, rng)
    batch = _batch(cfg, jax.random.PRNGKey(1))

    # forward: loss finite
    loss = model.loss_fn(params, batch, cfg)
    assert np.isfinite(float(loss)), arch

    # one full train step (grad + AdamW) — params update, all finite
    opt = adamw_init(params, cfg.opt_dtype)

    @jax.jit
    def step(p, o, b):
        l, g = jax.value_and_grad(lambda pp: model.loss_fn(pp, b, cfg))(p)
        p2, o2 = adamw_update(p, g, o, lr=1e-3)
        return l, p2, o2

    l, params2, opt2 = step(params, opt, batch)
    assert np.isfinite(float(l)), arch
    for leaf in jax.tree.leaves(params2):
        assert np.isfinite(np.asarray(leaf, np.float32)).all(), arch
    # parameters actually moved
    moved = any(
        not np.allclose(np.asarray(a, np.float32), np.asarray(b2, np.float32))
        for a, b2 in zip(jax.tree.leaves(params), jax.tree.leaves(params2))
    )
    assert moved, arch


@pytest.mark.parametrize("arch", ASSIGNED)
def test_smoke_prefill_decode_shapes(arch):
    cfg = SMOKES[arch]
    params = model.init_params(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg, jax.random.PRNGKey(1))
    batch.pop("labels")
    logits, cache = model.prefill(params, batch, cfg)
    assert logits.shape == (B, cfg.vocab), arch
    assert np.isfinite(np.asarray(logits, np.float32)).all(), arch

    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    pos = S + (cfg.num_patches if cfg.frontend == "vision" else 0)
    if cfg.encdec:
        pos = S
    plen = pos
    cache = model.pad_cache(cache, plen, plen + 8)
    logits2, cache2 = model.decode_step(params, cache, tok, jnp.int32(pos), cfg)
    assert logits2.shape == (B, cfg.vocab), arch
    assert np.isfinite(np.asarray(logits2, np.float32)).all(), arch
