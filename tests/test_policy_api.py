"""Unified policy API tests: PolicySpec, registry, facade, and the
register-once-run-everywhere guarantee.

The tentpole property under test: a policy is *one* declarative
:class:`repro.core.policy.PolicySpec`, and both engines compile it — the
host interpreter (:mod:`repro.core.schedulers`) and the batched lowering
(:mod:`repro.sim.batched`) cannot drift because they consume the same
description.  ``assert_cross_engine_parity`` is the generic harness: any
spec (built-in or freshly registered) must agree single-step on random
occupancies AND decision-for-decision over a presampled event stream.
"""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro import api
from repro.core import mig
from repro.core.policy import (
    KEY_VOCABULARY,
    PolicySpec,
    get_policy,
    list_policies,
    policy_engines,
    register_policy,
    resolve,
    unregister_policy,
)
from repro.core.schedulers import MFIDefrag, SpecScheduler, make_scheduler
from repro.sim import SimConfig
from repro.sim import batched, replay

MIXED = mig.ClusterSpec(((mig.A100_80GB, 3), (mig.A100_40GB, 3)))


def _random_cluster(rng, spec):
    cl = mig.ClusterState(spec=spec)
    density = rng.random()
    wid = 0
    for g in range(cl.num_gpus):
        for pid in rng.permutation(mig.NUM_PROFILES):
            if rng.random() < density:
                anchors = cl.gpus[g].feasible_anchors(int(pid))
                if anchors:
                    cl.allocate(wid, int(pid), g, int(rng.choice(anchors)))
                    wid += 1
    return cl


def _host_reference(policy, cluster, pid):
    """Host decision + migration on one cluster state (unbounded budget)."""
    from repro.core.policy import resolve as _resolve

    pspec = _resolve(policy)
    if pspec.defrag:
        sched = MFIDefrag(spec=pspec, max_candidates=None)
    else:
        sched = make_scheduler(policy)
    sel = sched.select(cluster, pid)
    return sel, getattr(sched, "pending_migration", None)


def assert_cross_engine_parity(policy, trials=40, seed=123):
    """Generic parity harness: host compilation vs batched lowering.

    1. single-step: decisions agree on random occupancies (homogeneous and
       mixed specs, including rejects and — for defrag specs — the chosen
       migration victim and target);
    2. same-stream: driving the host scheduler over the batched engine's
       own presampled event stream reproduces the device decision trace
       element-for-element, and the trace passes the replay invariants.

    Works for any batched-capable policy name or ad-hoc spec — this is what
    "a newly registered policy gets parity coverage for free" means.
    """
    from repro.core.policy import resolve as _resolve

    is_defrag = _resolve(policy).defrag
    rng = np.random.default_rng(seed)
    for spec in (mig.ClusterSpec.homogeneous(mig.A100_80GB, 4), MIXED):
        for _ in range(trials):
            cl = _random_cluster(rng, spec)
            occ = cl.occupancy_matrix()
            pid = int(rng.integers(0, mig.NUM_PROFILES))
            workloads = [
                (g.gpu_id, a.profile_id, a.anchor)
                for g in cl.gpus
                for a in g.allocations.values()
            ]
            ref, ref_mig = _host_reference(policy, cl, pid)
            d = batched.policy_select_full(
                jnp.asarray(occ), jnp.int32(pid), policy, spec=spec,
                workloads=workloads,
            )
            got = (int(d.gpu), int(d.anchor)) if bool(d.ok) else None
            assert got == ref, f"{policy}: pid={pid} host={ref} batched={got}\n{occ}"
            assert bool(d.mig) == (ref_mig is not None)
    cfg = SimConfig(cluster_spec=MIXED, offered_load=0.9, seed=seed)
    events, meta, rr, rc = batched.presample_arrivals(cfg, runs=2)
    _, trace = jax.device_get(
        batched._simulate(
            jax.tree.map(jnp.asarray, events),
            policy=policy,
            metric=cfg.metric,
            num_gpus=cfg.num_gpus,
            ring_rows=rr,
            ring_cols=rc,
            use_kernel=False,
            midx=jnp.asarray(MIXED.model_index),
            tables=batched.spec_tables(MIXED),
        )
    )
    kwargs = {"max_candidates": None} if is_defrag else {}
    ok_ref, gpu_ref, _ = replay.host_decisions(
        events, meta, policy, cfg.num_gpus, spec=MIXED, **kwargs
    )
    ok_dev = np.asarray(trace.ok)
    np.testing.assert_array_equal(ok_dev, ok_ref)
    np.testing.assert_array_equal(np.asarray(trace.gpu)[ok_dev], gpu_ref[ok_ref])
    replay.replay(events, meta, trace, cfg.num_gpus, spec=MIXED)


class TestPolicySpec:
    def test_built_ins_registered_with_engine_support(self):
        assert set(list_policies()) >= {
            "mfi", "ff", "bf-bi", "wf-bi", "rr", "mfi-defrag",
        }
        # every built-in — the defrag variant included — runs on both engines
        for name in ("mfi", "ff", "bf-bi", "wf-bi", "rr", "mfi-defrag"):
            assert policy_engines(name) == ("python", "batched")
        assert "mfi-defrag" in list_policies(engine="batched")

    def test_engines_field_opt_out(self):
        """A spec may opt out of an engine; resolve() raises the unified
        mismatch error for it."""
        host_only = PolicySpec(
            name="test-host-only", keys=("gpu", "anchor"), engines=("python",)
        )
        assert host_only.supports("python") and not host_only.supports("batched")
        with pytest.raises(ValueError, match="not supported by the 'batched'"):
            resolve(host_only, engine="batched")
        with pytest.raises(ValueError, match="unknown engine"):
            PolicySpec(name="bad", keys=("gpu",), engines=("quantum",))
        with pytest.raises(ValueError, match="at least one engine"):
            PolicySpec(name="bad", keys=("gpu",), engines=())

    def test_defrag_rejects_rr_distance(self):
        with pytest.raises(ValueError, match="defrag is incompatible"):
            PolicySpec(name="bad", keys=("rr-distance", "anchor"), defrag=True)

    def test_derived_structure(self):
        assert get_policy("mfi").requires_delta_f
        assert not get_policy("ff").requires_delta_f
        assert get_policy("rr").stateful_cursor
        assert not get_policy("bf-bi").stateful_cursor

    def test_validation(self):
        with pytest.raises(ValueError, match="unknown scoring key"):
            PolicySpec(name="bad", keys=("banana",))
        with pytest.raises(ValueError, match="at least one scoring key"):
            PolicySpec(name="bad", keys=())
        with pytest.raises(ValueError, match="unknown feasibility"):
            PolicySpec(name="bad", keys=("gpu",), feasibility="psychic")
        # every vocabulary key is accepted, plain and negated
        for key in KEY_VOCABULARY:
            PolicySpec(name="ok", keys=(key,))
            PolicySpec(name="ok", keys=(f"-{key}",))

    def test_register_duplicate_and_unregister(self):
        spec = PolicySpec(name="tmp-policy", keys=("gpu", "anchor"))
        register_policy(spec)
        try:
            with pytest.raises(ValueError, match="already registered"):
                register_policy(spec)
            register_policy(spec, overwrite=True)  # explicit replace is fine
            assert "tmp-policy" in list_policies()
        finally:
            unregister_policy("tmp-policy")
        assert "tmp-policy" not in list_policies()


class TestUnifiedErrors:
    """One validation path: every entry point raises the same message."""

    def test_unknown_policy_same_message_everywhere(self):
        entry_points = (
            lambda: make_scheduler("nope"),
            lambda: api.make_policy("nope"),
            lambda: api.simulate("nope", num_gpus=2, runs=1),
            lambda: batched.run_batched("nope", SimConfig(num_gpus=2), runs=1),
            lambda: batched.policy_select(
                jnp.zeros((2, 8), jnp.int32), jnp.int32(0), "nope"
            ),
        )
        messages = set()
        for call in entry_points:
            with pytest.raises(ValueError) as exc:
                call()
            messages.add(str(exc.value))
        assert len(messages) == 1
        (msg,) = messages
        # helpful: names every registered policy with its engine support
        assert "unknown policy 'nope'" in msg
        for name in list_policies():
            assert name in msg
        assert "(python+batched)" in msg

    def test_engine_mismatch_names_supported_engines(self):
        host_only = PolicySpec(
            name="test-host-only", keys=("gpu", "anchor"), engines=("python",)
        )
        register_policy(host_only)
        try:
            for call in (
                lambda: batched.run_batched(
                    "test-host-only", SimConfig(num_gpus=2), runs=1
                ),
                lambda: api.simulate(
                    "test-host-only", engine="batched", num_gpus=2, runs=1
                ),
            ):
                with pytest.raises(ValueError, match=r"supports: python") as exc:
                    call()
                assert (
                    "'test-host-only' is not supported by the 'batched' engine"
                    in str(exc.value)
                )
        finally:
            unregister_policy("test-host-only")

    def test_unknown_engine(self):
        with pytest.raises(ValueError, match="unknown engine"):
            resolve("mfi", engine="quantum")
        with pytest.raises(ValueError, match="unknown engine"):
            list_policies(engine="quantum")


class TestRequestKeys:
    """The tenant/priority/wait-age vocabulary: request-scoped keys order
    the admission queue and are placement no-ops within one request."""

    def test_queue_order_default_and_spec_derived(self):
        from repro.core.policy import DEFAULT_QUEUE_ORDER, queue_order

        assert queue_order(get_policy("mfi")) == DEFAULT_QUEUE_ORDER
        spec = PolicySpec(
            name="test-q", keys=("tenant", "-wait-age", "gpu", "anchor")
        )
        assert queue_order(spec) == ("tenant", "-wait-age")

    def test_request_keys_in_vocabulary(self):
        for k in ("tenant", "priority", "wait-age"):
            assert k in KEY_VOCABULARY

    def test_mfi_queued_registered_both_engines(self):
        assert "mfi-queued" in list_policies(engine="batched")
        assert policy_engines("mfi-queued") == ("python", "batched")

    def test_request_keys_never_change_placement(self):
        """Within one request, request-scoped keys are constant — mfi-queued
        must place identically to mfi on any occupancy."""
        rng = np.random.default_rng(17)
        mfi = make_scheduler("mfi")
        mfi_q = make_scheduler("mfi-queued")
        for _ in range(20):
            cl = _random_cluster(rng, mig.ClusterSpec.homogeneous(mig.A100_80GB, 4))
            pid = int(rng.integers(0, mig.NUM_PROFILES))
            assert mfi.select(cl, pid) == mfi_q.select(cl, pid)


class TestCompilers:
    def test_make_scheduler_compiles_specs_and_names(self):
        assert isinstance(make_scheduler("ff"), SpecScheduler)
        assert isinstance(make_scheduler("mfi-defrag"), MFIDefrag)
        ad_hoc = PolicySpec(name="inline", keys=("free-slices", "gpu", "anchor"))
        sched = make_scheduler(ad_hoc)  # unregistered specs work too
        assert sched.select(mig.ClusterState(2), 3) is not None

    def test_stateful_cursor_reset(self):
        sched = make_scheduler("rr")
        cl = mig.ClusterState(3)
        assert sched.select(cl, 5) == (0, 0)
        cl.allocate(1, 5, 0, 0)
        assert sched._next == 1
        sched.reset()
        assert sched._next == 0

    def test_model_group_key_steers_mixed_fleet(self):
        """The `model-group` key orders device generations: -model-group
        prefers the later model group (the A100-40s here) when feasible."""
        prefer_new = PolicySpec(
            name="prefer-new", keys=("-model-group", "gpu", "anchor")
        )
        cl = mig.ClusterState(spec=MIXED)
        sel = make_scheduler(prefer_new).select(cl, 5)  # 10 GiB demand
        assert sel == (3, 0)  # first A100-40GB, not GPU 0
        # the batched lowering agrees
        g, a, ok = batched.policy_select(
            jnp.asarray(cl.occupancy_matrix()), jnp.int32(5), prefer_new, spec=MIXED
        )
        assert bool(ok) and (int(g), int(a)) == sel
        # but an 80 GiB demand must still land on an A100-80GB
        sel80 = make_scheduler(prefer_new).select(cl, 0)
        assert sel80 is not None and sel80[0] < 3


class TestRegisterOnceRunEverywhere:
    """Satellite #1's payoff: registering a policy is all it takes."""

    CUSTOM = PolicySpec(
        name="test-pack-left",
        keys=("free-slices", "-gpu", "-anchor"),
        description="best-fit from the highest GPU id down (test-only)",
    )

    def test_custom_policy_gets_parity_coverage_for_free(self):
        register_policy(self.CUSTOM)
        try:
            assert "test-pack-left" in list_policies(engine="batched")
            assert_cross_engine_parity("test-pack-left", trials=25)
        finally:
            unregister_policy("test-pack-left")

    def test_anchor_key_compares_values_across_models(self):
        """Regression: an `anchor` key NOT preceded by a GPU-unique key
        compares anchors across GPUs of different models; the batched
        lowering must score real anchor VALUES (per-model index<->value
        mappings differ), exactly like the host interpreter."""
        anchor_first = PolicySpec(name="test-anchor-first", keys=("anchor", "gpu"))
        spec = mig.ClusterSpec(((mig.A100_80GB, 1), (mig.A100_40GB, 1)))
        # pid 3 (2g.20gb demand): anchors (0,2,4) on A100-80, (0,4) on A100-40
        # — anchor 4 is index 2 on the A100-80 but index 1 on the A100-40
        occ = np.array(
            [[1, 1, 1, 1, 0, 0, 1, 0], [1, 1, 1, 1, 0, 0, 0, 0]], np.int32
        )
        cl = mig.ClusterState(spec=spec)
        cl.gpus[0].occupancy[:] = occ[0]
        cl.gpus[1].occupancy[:] = occ[1]
        ref = make_scheduler(anchor_first).select(cl, 3)
        assert ref == (0, 4)  # min anchor value 4, gpu tie-break
        g, a, ok = batched.policy_select(
            jnp.asarray(occ), jnp.int32(3), anchor_first, spec=spec
        )
        assert bool(ok) and (int(g), int(a)) == ref
        # and the full generic harness passes for the anchor-primary spec
        assert_cross_engine_parity(anchor_first, trials=20)

    def test_custom_policy_runs_through_both_facade_engines(self):
        register_policy(self.CUSTOM)
        try:
            cfg = SimConfig(num_gpus=3, offered_load=0.8, seed=2)
            rp = api.simulate("test-pack-left", cfg=cfg, engine="python", runs=2)
            rb = api.simulate("test-pack-left", cfg=cfg, engine="batched", runs=2)
            assert 0.0 < rp["acceptance_rate"] <= 1.0
            assert 0.0 < rb["acceptance_rate"] <= 1.0
            assert set(rp) == set(rb)
        finally:
            unregister_policy("test-pack-left")

    @pytest.mark.parametrize("name", list_policies(engine="batched"))
    def test_built_in_specs_pass_the_generic_harness(self, name):
        assert_cross_engine_parity(name, trials=12, seed=7)


class TestFacade:
    def test_simulate_kwargs_build_config(self):
        r = api.simulate("ff", num_gpus=2, offered_load=0.7, runs=2)
        assert 0.0 < r["acceptance_rate"] <= 1.0

    def test_simulate_rejects_cfg_plus_kwargs(self):
        with pytest.raises(ValueError, match="not both"):
            api.simulate("ff", cfg=SimConfig(num_gpus=2), num_gpus=4)

    def test_engine_results_statistically_close(self):
        cfg = SimConfig(num_gpus=4, offered_load=0.85, seed=0)
        rp = api.simulate("mfi", cfg=cfg, engine="python", runs=6)
        rb = api.simulate("mfi", cfg=cfg, engine="batched", runs=6)
        assert abs(rp["acceptance_rate"] - rb["acceptance_rate"]) < 0.15
