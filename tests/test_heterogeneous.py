"""Heterogeneous-cluster substrate tests: DeviceModel / ClusterSpec parity.

Three layers of guarantees:

* **device models are well-formed** — each model's placement table stays in
  bounds and maps every demand class either to legal windows or to an
  explicit no-realization entry;
* **bit-for-bit homogeneity** — the explicit one-model A100-80GB spec
  reproduces the legacy (spec-free) results exactly, for the Python loop,
  the batched engine, and the single-decision paths;
* **mixed-fleet parity** — on an A100-80GB/A100-40GB spec the Python and
  batched engines agree decision-for-decision on the *same* presampled
  event stream (hence on acceptance counts per seed), and the batched
  trajectory passes the replay invariants against per-model tables.
"""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import fragmentation, mig, schedulers
from repro.core.policy import list_policies
from repro.sim import SimConfig, run_many, run_simulation
from repro.sim import batched, replay
from repro.core.schedulers import make_scheduler

MIXED = mig.ClusterSpec(((mig.A100_80GB, 3), (mig.A100_40GB, 3)))

#: four distinct models (both A100 SKUs, both H100 SKUs) — the registry's
#: stacked-table path at K=4, matching the benchmarks' `--cluster mixed`
FOUR_MODEL = mig.ClusterSpec(
    (
        (mig.A100_80GB, 2),
        (mig.A100_40GB, 2),
        (mig.H100_96GB, 2),
        (mig.H100_80GB, 2),
    )
)

#: registry-driven: every batched-capable policy gets parity coverage here
BATCHED_POLICIES = list_policies(engine="batched")


def _sim(policy, cfg, spec, runs):
    events, meta, rr, rc = batched.presample_arrivals(cfg, runs=runs)
    final, trace = jax.device_get(
        batched._simulate(
            jax.tree.map(jnp.asarray, events),
            policy=policy,
            metric=cfg.metric,
            num_gpus=cfg.num_gpus,
            ring_rows=rr,
            ring_cols=rc,
            use_kernel=False,
            midx=jnp.asarray(spec.model_index),
            tables=batched.spec_tables(spec),
        )
    )
    return events, meta, trace, final


class TestDeviceModels:
    def test_registry_and_parse(self):
        spec = mig.ClusterSpec.parse("a100-80:2,a100-40:1,h100-96:1")
        assert spec.num_gpus == 4
        assert [m.name for m in spec.models] == [
            "a100-80gb", "a100-40gb", "h100-96gb",
        ]
        np.testing.assert_array_equal(spec.model_index, [0, 0, 1, 2])
        with pytest.raises(ValueError, match="unknown device model"):
            mig.ClusterSpec.parse("v100:4")

    def test_tables_in_bounds(self):
        for model in (mig.A100_80GB, mig.A100_40GB, mig.H100_96GB, mig.H100_80GB):
            for prof in model.profiles:
                for a in prof.anchors:
                    assert a + prof.mem <= model.num_mem_slices
            masks = model.placement_masks
            np.testing.assert_array_equal(masks.sum(axis=1), model.placement_mem)

    def test_a100_80_is_canonical(self):
        assert mig.A100_80GB.profiles == mig.PROFILES
        np.testing.assert_array_equal(
            mig.A100_80GB.placement_masks, mig.PLACEMENT_MASKS
        )
        assert mig.A100_80GB.num_placements == mig.NUM_PLACEMENTS

    def test_a100_40_realizations(self):
        m = mig.A100_40GB
        assert not m.placeable(0)  # 80 GiB demand cannot fit a 40 GiB GPU
        # 40 GiB demands need the whole GPU; 20 GiB a half; 10 GiB a quarter
        assert [p.mem for p in m.profiles] == [7, 7, 7, 4, 4, 2]
        assert m.num_placements == 9

    def test_unplaceable_class_rejected_everywhere(self):
        cl = mig.ClusterState(spec=mig.ClusterSpec.homogeneous(mig.A100_40GB, 3))
        for name in schedulers.SCHEDULERS:
            assert make_scheduler(name).select(cl, 0) is None

    def test_cross_model_allocation_tracks_model_table(self):
        cl = mig.ClusterState(spec=MIXED)
        # class 4 (1g.20gb demand): 2 slices on A100-80, 4 slices on A100-40
        cl.allocate(1, 4, 0, 0)
        cl.allocate(2, 4, 3, 0)
        assert cl.gpus[0].used_mem_slices == 2
        assert cl.gpus[3].used_mem_slices == 4
        with pytest.raises(ValueError, match="illegal"):
            cl.allocate(3, 4, 3, 2)  # anchor 2 is legal on A100-80 only


class TestHomogeneousBitForBit:
    """The one-model spec must reproduce the legacy results exactly."""

    def test_python_engine(self):
        cfg_a = SimConfig(num_gpus=5, offered_load=0.85, seed=7)
        cfg_b = SimConfig(
            cluster_spec=mig.ClusterSpec.homogeneous(mig.A100_80GB, 5),
            offered_load=0.85, seed=7,
        )
        for policy in ("mfi", "rr"):
            ra = run_simulation(make_scheduler(policy), cfg_a)
            rb = run_simulation(make_scheduler(policy), cfg_b)
            assert ra.acceptance_rate == rb.acceptance_rate
            assert ra.frag_severity == rb.frag_severity
            assert ra.utilization == rb.utilization

    def test_batched_engine(self):
        cfg_a = SimConfig(num_gpus=5, offered_load=0.85, seed=7)
        cfg_b = SimConfig(
            cluster_spec=mig.ClusterSpec.homogeneous(mig.A100_80GB, 5),
            offered_load=0.85, seed=7,
        )
        for policy in ("mfi", "rr"):
            ra = batched.run_batched(policy, cfg_a, runs=4)
            rb = batched.run_batched(policy, cfg_b, runs=4)
            for k in ra:
                np.testing.assert_array_equal(np.asarray(ra[k]), np.asarray(rb[k]))

    def test_single_decisions(self):
        rng = np.random.default_rng(3)
        spec = mig.ClusterSpec.homogeneous(mig.A100_80GB, 4)
        for _ in range(25):
            occ = (rng.random((4, 8)) < 0.4).astype(np.int32)
            pid = int(rng.integers(0, mig.NUM_PROFILES))
            for policy in BATCHED_POLICIES:
                legacy = batched.policy_select(jnp.asarray(occ), jnp.int32(pid), policy)
                spec_d = batched.policy_select(
                    jnp.asarray(occ), jnp.int32(pid), policy, spec=spec
                )
                assert tuple(map(int, legacy)) == tuple(map(int, spec_d))


class TestMixedParity:
    """Python vs batched on a mixed two-model spec."""

    def test_single_step_decisions_match(self):
        from repro.core.policy import resolve
        from repro.core.schedulers import MFIDefrag

        rng = np.random.default_rng(11)
        checked = 0
        for _ in range(60):
            cl = mig.ClusterState(spec=MIXED)
            wid = 0
            for g in range(cl.num_gpus):
                for pid in rng.permutation(mig.NUM_PROFILES):
                    if rng.random() < 0.5:
                        anchors = cl.gpus[g].feasible_anchors(int(pid))
                        if anchors:
                            cl.allocate(wid, int(pid), g, int(rng.choice(anchors)))
                            wid += 1
            occ = cl.occupancy_matrix()
            pid = int(rng.integers(0, mig.NUM_PROFILES))
            workloads = [
                (g.gpu_id, a.profile_id, a.anchor)
                for g in cl.gpus
                for a in g.allocations.values()
            ]
            for name in BATCHED_POLICIES:
                pspec = resolve(name)
                sched = (
                    MFIDefrag(spec=pspec, max_candidates=None)
                    if pspec.defrag
                    else make_scheduler(name)
                )
                ref = sched.select(cl, pid)
                g, a, ok = batched.policy_select(
                    jnp.asarray(occ), jnp.int32(pid), name, spec=MIXED,
                    workloads=workloads,
                )
                got = (int(g), int(a)) if bool(ok) else None
                assert got == ref, f"{name}: pid={pid} python={ref} batched={got}"
                checked += 1
        assert checked >= 50 * len(BATCHED_POLICIES)

    @pytest.mark.parametrize("policy", BATCHED_POLICIES)
    def test_same_stream_acceptance_counts_match(self, policy):
        """Exact per-seed agreement: the Python schedulers driven over the
        batched engine's own event stream accept the same arrivals."""
        for seed in (0, 1):
            cfg = SimConfig(cluster_spec=MIXED, offered_load=0.9, seed=seed)
            events, meta, trace, _ = _sim(policy, cfg, MIXED, runs=2)
            ok_ref, gpu_ref, anc_ref = replay.host_decisions(
                events, meta, policy, cfg.num_gpus, spec=MIXED
            )
            ok_dev = np.asarray(trace.ok)
            np.testing.assert_array_equal(ok_dev, ok_ref)
            assert ok_dev.sum() == ok_ref.sum()  # acceptance counts per seed
            # accepted placements land on the same GPU
            np.testing.assert_array_equal(
                np.asarray(trace.gpu)[ok_dev], gpu_ref[ok_ref]
            )

    @pytest.mark.parametrize("policy", BATCHED_POLICIES)
    def test_replay_invariants_on_mixed_spec(self, policy):
        cfg = SimConfig(cluster_spec=MIXED, offered_load=1.1, seed=5)
        events, meta, trace, final = _sim(policy, cfg, MIXED, runs=2)
        occ = replay.replay(events, meta, trace, cfg.num_gpus, spec=MIXED)
        # device window-count state equals the reconstruction per model
        tables = jax.device_get(batched.spec_tables(MIXED))
        w = tables.W[MIXED.model_index]  # (M, N, S)
        expect = np.einsum("rms,mns->rmn", occ.astype(np.float32), w)
        np.testing.assert_allclose(final.base, expect)
        _, drained = replay.drain_all(events, meta, trace, cfg.num_gpus, spec=MIXED)
        np.testing.assert_array_equal(drained, 0)

    @pytest.mark.slow
    def test_aggregate_parity_monte_carlo(self):
        cfg = SimConfig(
            cluster_spec=mig.ClusterSpec(
                ((mig.A100_80GB, 4), (mig.A100_40GB, 4))
            ),
            offered_load=0.85,
            seed=0,
        )
        rb = batched.run_batched("mfi", cfg, runs=24)
        rp = run_many("mfi", cfg, runs=24)
        assert abs(rb["acceptance_rate"] - rp["acceptance_rate"]) < 0.06
        assert abs(rb["utilization"] - rp["utilization"]) < 0.08


class TestMixedBehaviour:
    def test_big_class_rejected_once_a100_80s_full(self):
        cl = mig.ClusterState(spec=MIXED)
        sched = make_scheduler("mfi")
        for wid in range(3):
            sel = sched.select(cl, 0)  # 80 GiB demand
            assert sel is not None and sel[0] < 3  # only A100-80GB GPUs
            cl.allocate(wid, 0, *sel)
        assert sched.select(cl, 0) is None  # A100-40s can never take it
        assert sched.select(cl, 5) is not None  # small demand still fits

    def test_spec_fragmentation_scores_use_own_tables(self):
        occ = np.zeros((6, 8), np.int32)
        occ[:, 0] = 1  # one occupied slice everywhere
        scores = fragmentation.spec_fragmentation_scores(occ, MIXED)
        # same bitmap, different placement tables -> different scores
        assert scores[0] == scores[1] == scores[2]
        assert scores[3] == scores[4] == scores[5]
        assert scores[0] != scores[3]

    def test_serving_admission_on_mixed_spec(self):
        from repro.serving import AdmissionController

        ac = AdmissionController(policy="mfi", cluster_spec=MIXED)
        p = ac.admit(1, "7g.80gb")
        assert p is not None and p.gpu < 3
        p2 = ac.admit(2, "1g.10gb")
        assert p2 is not None
        s = ac.stats()
        assert s["accepted"] == 2
        ac.release(1)
        ac.release(2)
        assert ac.cluster.used_mem_slices == 0

    def test_h100_spec_runs_end_to_end(self):
        cfg = SimConfig(
            cluster_spec=mig.ClusterSpec.homogeneous(mig.H100_96GB, 4),
            offered_load=0.8,
            seed=2,
        )
        rb = batched.run_batched("mfi", cfg, runs=2)
        rp = run_many("mfi", cfg, runs=2)
        assert 0.0 < rb["acceptance_rate"] <= 1.0
        assert 0.0 < rp["acceptance_rate"] <= 1.0


class TestFourModelSpec:
    """H100-80GB + the four-model `--cluster mixed` scenario (K=4 tables)."""

    def test_h100_80_registry_and_geometry(self):
        spec = mig.ClusterSpec.parse("a100-80:30,a100-40:30,h100-96:20,h100-80:20")
        assert spec.num_gpus == 100
        assert [m.name for m in spec.models] == [
            "a100-80gb", "a100-40gb", "h100-96gb", "h100-80gb",
        ]
        # same canonical placement geometry as the paper's device, distinct SKU
        assert mig.H100_80GB.profiles == mig.PROFILES
        assert mig.H100_80GB != mig.A100_80GB
        np.testing.assert_array_equal(
            mig.H100_80GB.placement_masks, mig.A100_80GB.placement_masks
        )

    @pytest.mark.parametrize("policy", BATCHED_POLICIES)
    def test_same_stream_parity_and_invariants(self, policy):
        """Every registered batched policy agrees with its host compilation
        decision-for-decision on the four-model fleet, and the trajectory
        passes the replay invariants against per-model tables."""
        cfg = SimConfig(cluster_spec=FOUR_MODEL, offered_load=0.9, seed=4)
        events, meta, trace, _ = _sim(policy, cfg, FOUR_MODEL, runs=2)
        ok_ref, gpu_ref, _ = replay.host_decisions(
            events, meta, policy, cfg.num_gpus, spec=FOUR_MODEL
        )
        ok_dev = np.asarray(trace.ok)
        np.testing.assert_array_equal(ok_dev, ok_ref)
        np.testing.assert_array_equal(np.asarray(trace.gpu)[ok_dev], gpu_ref[ok_ref])
        replay.replay(events, meta, trace, cfg.num_gpus, spec=FOUR_MODEL)
        _, drained = replay.drain_all(
            events, meta, trace, cfg.num_gpus, spec=FOUR_MODEL
        )
        np.testing.assert_array_equal(drained, 0)
