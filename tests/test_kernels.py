"""Per-kernel allclose tests: Pallas (interpret=True) vs pure-jnp oracles.

Shape/dtype sweeps as required: every kernel is compared against its
``ref.py`` oracle over a grid of shapes and dtypes.  Hypothesis property
tests on the scheduler kernels live in ``test_hypothesis_properties.py``
(skip-guarded) so this module collects without the optional dev dependency.
"""

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import cluster as jcluster
from repro.core import fragmentation as frag_np
from repro.core import mig, schedulers
from repro.kernels.fragscore import ops as frag_ops
from repro.kernels.fragscore.ref import fragscore_ref
from repro.kernels.decode_attention.decode_attention import decode_attention
from repro.kernels.decode_attention.ref import decode_attention_ref


class TestFragscoreKernel:
    @pytest.mark.parametrize("m", [1, 7, 100, 513, 2048])
    @pytest.mark.parametrize("metric", ["blocked", "partial"])
    def test_matches_ref_random(self, m, metric):
        rng = np.random.default_rng(m)
        occ = (rng.random((m, 8)) < 0.4).astype(np.int32)
        got = np.asarray(frag_ops.fragmentation_scores(jnp.asarray(occ), metric))
        ref = np.asarray(fragscore_ref(jnp.asarray(occ), metric))
        np.testing.assert_allclose(got, ref)

    @pytest.mark.parametrize("dtype", [np.int32, np.float32, np.int8])
    def test_dtypes(self, dtype):
        rng = np.random.default_rng(0)
        occ = (rng.random((64, 8)) < 0.5).astype(dtype)
        got = np.asarray(frag_ops.fragmentation_scores(jnp.asarray(occ)))
        ref = frag_np.fragmentation_scores(occ.astype(np.int32))
        np.testing.assert_allclose(got, ref)

    def test_matches_numpy_reference_exhaustive(self):
        """All 256 possible occupancy bitmaps."""
        occ = np.array([[int(b) for b in f"{i:08b}"] for i in range(256)], np.int32)
        for metric in ("blocked", "partial"):
            got = np.asarray(frag_ops.fragmentation_scores(jnp.asarray(occ), metric))
            ref = frag_np.fragmentation_scores(occ, metric)
            np.testing.assert_allclose(got, ref)


class TestMFIDeltaKernel:
    @pytest.mark.parametrize("pid", range(mig.NUM_PROFILES))
    def test_matches_numpy_candidates(self, pid):
        rng = np.random.default_rng(pid)
        occ = (rng.random((257, 8)) < 0.35).astype(np.int32)
        delta = np.asarray(frag_ops.mfi_delta_f(jnp.asarray(occ), jnp.int32(pid)))
        gpus, anchors, deltas = schedulers.mfi_candidates(occ, pid)
        anchor_list = list(np.asarray(jcluster.PROFILE_ANCHORS)[pid])
        n_feasible = 0
        for g, a, d in zip(gpus, anchors, deltas):
            col = anchor_list.index(a)
            np.testing.assert_allclose(delta[g, col], d, rtol=1e-6)
            n_feasible += 1
        assert (delta < 1e29).sum() == n_feasible

    def test_select_agrees_with_reference_scheduler(self):
        rng = np.random.default_rng(42)
        occ = (rng.random((128, 8)) < 0.45).astype(np.int32)
        for pid in range(6):
            g, a, acc = frag_ops.mfi_select(jnp.asarray(occ), jnp.int32(pid))
            d = jcluster.mfi_select(jnp.asarray(occ), jnp.int32(pid))
            assert bool(acc) == bool(d.accepted)
            if bool(acc):
                assert (int(g), int(a)) == (int(d.gpu), int(d.anchor))


class TestDecodeAttentionKernel:
    SHAPES = [
        # (batch, q_heads, kv_heads, head_dim, kv_len, blk_s)
        (2, 8, 2, 64, 300, 128),    # GQA, ragged tail block
        (1, 8, 1, 128, 1024, 512),  # MQA (paligemma-style)
        (3, 10, 5, 64, 77, 512),    # block larger than sequence
        (2, 4, 4, 256, 513, 256),   # MHA, gemma3 head_dim
        (1, 12, 4, 128, 2048, 512), # starcoder2-style ratio
    ]

    @pytest.mark.parametrize("shape", SHAPES)
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_matches_ref(self, shape, dtype):
        b, h, kh, d, s, blk = shape
        rng = np.random.default_rng(0)
        q = jnp.asarray(rng.standard_normal((b, h, d)), dtype)
        k = jnp.asarray(rng.standard_normal((b, s, kh, d)), dtype)
        v = jnp.asarray(rng.standard_normal((b, s, kh, d)), dtype)
        length = jnp.asarray(rng.integers(1, s + 1, size=b), jnp.int32)
        got = decode_attention(q, k, v, length, blk_s=blk)
        ref = decode_attention_ref(q, k, v, length=length)
        tol = 2e-5 if dtype == jnp.float32 else 2.5e-2
        np.testing.assert_allclose(
            np.asarray(got, np.float32), np.asarray(ref, np.float32), atol=tol, rtol=tol
        )

    def test_full_length_default(self):
        rng = np.random.default_rng(1)
        q = jnp.asarray(rng.standard_normal((2, 4, 64)), jnp.float32)
        k = jnp.asarray(rng.standard_normal((2, 256, 2, 64)), jnp.float32)
        v = jnp.asarray(rng.standard_normal((2, 256, 2, 64)), jnp.float32)
        length = jnp.full((2,), 256, jnp.int32)
        got = decode_attention(q, k, v, length)
        ref = decode_attention_ref(q, k, v)
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref), atol=2e-5, rtol=2e-5)

    def test_custom_scale(self):
        rng = np.random.default_rng(2)
        q = jnp.asarray(rng.standard_normal((1, 4, 64)), jnp.float32)
        k = jnp.asarray(rng.standard_normal((1, 128, 2, 64)), jnp.float32)
        v = jnp.asarray(rng.standard_normal((1, 128, 2, 64)), jnp.float32)
        length = jnp.full((1,), 128, jnp.int32)
        got = decode_attention(q, k, v, length, scale=0.1)
        ref = decode_attention_ref(q, k, v, scale=0.1, length=length)
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref), atol=2e-5, rtol=2e-5)

    def test_length_one(self):
        """Degenerate cache with a single valid entry -> output == v[0]."""
        rng = np.random.default_rng(3)
        q = jnp.asarray(rng.standard_normal((1, 2, 64)), jnp.float32)
        k = jnp.asarray(rng.standard_normal((1, 128, 2, 64)), jnp.float32)
        v = jnp.asarray(rng.standard_normal((1, 128, 2, 64)), jnp.float32)
        length = jnp.asarray([1], jnp.int32)
        got = decode_attention(q, k, v, length)
        np.testing.assert_allclose(
            np.asarray(got)[0], np.asarray(v)[0, 0], atol=1e-6, rtol=1e-6
        )
