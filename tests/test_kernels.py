"""Per-kernel allclose tests: Pallas (interpret=True) vs pure-jnp oracles.

Shape/dtype sweeps as required: every kernel is compared against its
``ref.py`` oracle over a grid of shapes and dtypes.  Hypothesis property
tests on the scheduler kernels live in ``test_hypothesis_properties.py``
(skip-guarded) so this module collects without the optional dev dependency.
"""

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import cluster as jcluster
from repro.core import fragmentation as frag_np
from repro.core import mig, schedulers
from repro.core.policy import resolve
from repro.kernels.fragscore import fragscore as frag_k
from repro.kernels.fragscore import ops as frag_ops
from repro.kernels.fragscore.ref import (
    delta_from_base_ref,
    fragscore_ref,
    select_from_base_ref,
)
from repro.sim import batched
from repro.kernels.decode_attention.decode_attention import decode_attention
from repro.kernels.decode_attention.ref import decode_attention_ref

#: every registered device model once (the registry aliases short names)
DEVICE_MODELS = sorted(set(mig.DEVICE_MODELS.values()), key=lambda m: m.name)


class TestFragscoreKernel:
    @pytest.mark.parametrize("m", [1, 7, 100, 513, 2048])
    @pytest.mark.parametrize("metric", ["blocked", "partial"])
    def test_matches_ref_random(self, m, metric):
        rng = np.random.default_rng(m)
        occ = (rng.random((m, 8)) < 0.4).astype(np.int32)
        got = np.asarray(frag_ops.fragmentation_scores(jnp.asarray(occ), metric))
        ref = np.asarray(fragscore_ref(jnp.asarray(occ), metric))
        np.testing.assert_allclose(got, ref)

    @pytest.mark.parametrize("dtype", [np.int32, np.float32, np.int8])
    def test_dtypes(self, dtype):
        rng = np.random.default_rng(0)
        occ = (rng.random((64, 8)) < 0.5).astype(dtype)
        got = np.asarray(frag_ops.fragmentation_scores(jnp.asarray(occ)))
        ref = frag_np.fragmentation_scores(occ.astype(np.int32))
        np.testing.assert_allclose(got, ref)

    def test_matches_numpy_reference_exhaustive(self):
        """All 256 possible occupancy bitmaps."""
        occ = np.array([[int(b) for b in f"{i:08b}"] for i in range(256)], np.int32)
        for metric in ("blocked", "partial"):
            got = np.asarray(frag_ops.fragmentation_scores(jnp.asarray(occ), metric))
            ref = frag_np.fragmentation_scores(occ, metric)
            np.testing.assert_allclose(got, ref)


class TestMFIDeltaKernel:
    @pytest.mark.parametrize("pid", range(mig.NUM_PROFILES))
    def test_matches_numpy_candidates(self, pid):
        rng = np.random.default_rng(pid)
        occ = (rng.random((257, 8)) < 0.35).astype(np.int32)
        delta = np.asarray(frag_ops.mfi_delta_f(jnp.asarray(occ), jnp.int32(pid)))
        gpus, anchors, deltas = schedulers.mfi_candidates(occ, pid)
        anchor_list = list(np.asarray(jcluster.PROFILE_ANCHORS)[pid])
        n_feasible = 0
        for g, a, d in zip(gpus, anchors, deltas):
            col = anchor_list.index(a)
            np.testing.assert_allclose(delta[g, col], d, rtol=1e-6)
            n_feasible += 1
        assert (delta < 1e29).sum() == n_feasible

    def test_select_agrees_with_reference_scheduler(self):
        rng = np.random.default_rng(42)
        occ = (rng.random((128, 8)) < 0.45).astype(np.int32)
        for pid in range(6):
            g, a, acc = frag_ops.mfi_select(jnp.asarray(occ), jnp.int32(pid))
            d = jcluster.mfi_select(jnp.asarray(occ), jnp.int32(pid))
            assert bool(acc) == bool(d.accepted)
            if bool(acc):
                assert (int(g), int(a)) == (int(d.gpu), int(d.anchor))

    def test_unified_entry_point_kernel_flag(self):
        """cluster.mfi_select is the single seam: use_kernel=True routes the
        same decision through the fused Pallas kernel (the ops.py alias
        delegates here)."""
        rng = np.random.default_rng(7)
        occ = jnp.asarray((rng.random((64, 8)) < 0.5).astype(np.int32))
        for pid in range(mig.NUM_PROFILES):
            d_jnp = jcluster.mfi_select(occ, jnp.int32(pid))
            d_k = jcluster.mfi_select(occ, jnp.int32(pid), use_kernel=True)
            assert bool(d_jnp.accepted) == bool(d_k.accepted)
            if bool(d_jnp.accepted):
                assert (int(d_jnp.gpu), int(d_jnp.anchor)) == (
                    int(d_k.gpu), int(d_k.anchor)
                )
                np.testing.assert_array_equal(d_jnp.delta_f, d_k.delta_f)


def _model_tables(model):
    """(w, v) placement table + per-profile (A, S) anchor masks of a model."""
    w = model.placement_masks.astype(np.float32)
    v = model.placement_mem.astype(np.float32)
    masks = np.zeros((mig.NUM_PROFILES, model.max_anchors, model.num_mem_slices),
                     np.float32)
    for pid, prof in enumerate(model.profiles):
        for j, a in enumerate(prof.anchors):
            masks[pid, j, a:a + prof.mem] = 1
    return w, v, masks


class TestPerModelKernelParity:
    """Kernel-vs-ref parity on every registered DeviceModel — the padded
    non-8-slice H200-141GB (S = 12) included."""

    @pytest.mark.parametrize("model", DEVICE_MODELS, ids=lambda m: m.name)
    @pytest.mark.parametrize("metric", ["blocked", "partial"])
    def test_fragscore_matches_ref(self, model, metric):
        rng = np.random.default_rng(len(model.name))
        s = model.num_mem_slices
        occ = (rng.random((73, s)) < 0.4).astype(np.int32)
        w, v, _ = _model_tables(model)
        got = np.asarray(
            frag_k.fragscore(
                jnp.asarray(occ), jnp.asarray(w), jnp.asarray(v),
                metric=metric, interpret=True,
            )
        )
        want = np.asarray(fragscore_ref(jnp.asarray(occ), metric, w, v))
        np.testing.assert_array_equal(got, want)

    @pytest.mark.parametrize("model", DEVICE_MODELS, ids=lambda m: m.name)
    @pytest.mark.parametrize("metric", ["blocked", "partial"])
    def test_delta_from_base_matches_ref(self, model, metric):
        """The fused ΔF kernel on the model's own window-count state: every
        demand class, raw (unmasked) ΔF values bit-for-bit."""
        rng = np.random.default_rng(1 + len(model.name))
        s = model.num_mem_slices
        occ = (rng.random((41, s)) < 0.35).astype(np.int32)
        w, v, pmasks = _model_tables(model)
        base = occ.astype(np.float32) @ w.T
        free = s - occ.sum(axis=1)
        f = np.asarray(fragscore_ref(jnp.asarray(occ), metric, w, v))
        for pid in range(mig.NUM_PROFILES):
            mw = pmasks[pid] @ w.T  # (A, N)
            mem = float(model.profiles[pid].mem)
            got = np.asarray(
                frag_k.delta_from_base(
                    jnp.asarray(base), jnp.asarray(free), jnp.asarray(v),
                    jnp.asarray(mw), jnp.asarray((mw > 0).astype(np.float32)),
                    mem, jnp.asarray(f), metric=metric, interpret=True,
                )
            )
            want = np.asarray(
                delta_from_base_ref(
                    jnp.asarray(base), jnp.asarray(free), v, mw, mem,
                    jnp.asarray(f), metric,
                )
            )
            np.testing.assert_array_equal(got, want)

    def test_ops_wrapper_matches_engine_lowering(self):
        """The A100 convenience wrapper (`ops.delta_from_base_f`) agrees
        with the batched engine's pure-jnp `_delta_from_base` on the same
        window-count state."""
        from repro.sim import batched

        model = mig.A100_80GB
        spec = mig.ClusterSpec.homogeneous(model, 6)
        tables = batched.spec_tables(spec)
        midx = jnp.asarray(spec.model_index)
        rng = np.random.default_rng(13)
        occ = (rng.random((6, 8)) < 0.4).astype(np.int32)
        base = jnp.einsum(
            "ms,mns->mn", jnp.asarray(occ, jnp.float32), tables.W[midx]
        )
        free = tables.slices[midx] - occ.sum(axis=1).astype(np.int32)
        vg = tables.V[midx]
        f = batched._frag_from_base(base, free, "blocked", vg)
        for pid in range(mig.NUM_PROFILES):
            got = np.asarray(frag_ops.delta_from_base_f(base, free, pid, f))
            want = np.asarray(
                batched._delta_from_base(
                    base, free, "blocked", vg,
                    tables.maskwin[midx, pid], tables.maskpos[midx, pid],
                    tables.profile_mem[midx, pid], f,
                )
            )
            np.testing.assert_array_equal(got, want)

    def test_delta_from_base_padded_tables(self):
        """The batched engine hands the kernel *padded* per-spec tables
        (common N/A across models, zero-padded windows); padded rows and
        anchors must not perturb the scores of the real ones."""
        from repro.sim import batched

        spec = mig.ClusterSpec(((mig.A100_80GB, 2), (mig.H200_141GB, 2)))
        tables = batched.spec_tables(spec)
        rng = np.random.default_rng(3)
        for k, model in enumerate(spec.models):
            s = model.num_mem_slices
            occ = np.zeros((5, spec.num_mem_slices), np.int32)
            occ[:, :s] = (rng.random((5, s)) < 0.4).astype(np.int32)
            w_pad = np.asarray(tables.W[k])  # (N_pad, S_pad) zero-padded
            v_pad = np.asarray(tables.V[k])
            base = occ.astype(np.float32) @ w_pad.T
            free = s - occ.sum(axis=1)
            f = np.asarray(fragscore_ref(jnp.asarray(occ[:, :s]), "blocked",
                                         *_model_tables(model)[:2]))
            for pid in range(mig.NUM_PROFILES):
                got = np.asarray(
                    frag_k.delta_from_base(
                        jnp.asarray(base), jnp.asarray(free),
                        jnp.asarray(v_pad),
                        tables.maskwin[k, pid], tables.maskpos[k, pid],
                        float(model.profiles[pid].mem), jnp.asarray(f),
                        metric="blocked", interpret=True,
                    )
                )
                want = np.asarray(
                    delta_from_base_ref(
                        jnp.asarray(base), jnp.asarray(free), v_pad,
                        np.asarray(tables.maskwin[k, pid]),
                        float(model.profiles[pid].mem), jnp.asarray(f),
                        "blocked",
                    )
                )
                np.testing.assert_array_equal(got, want)


# ---------------------------------------------------------------------------
# Fused select / migrate kernels: ΔF + in-kernel lexicographic argmin
# ---------------------------------------------------------------------------


def _random_state(spec, tables, seed, fill=0.4):
    """Randomized occupancy -> engine-layout ``(base, free, f)``."""
    rng = np.random.default_rng(seed)
    midx = np.asarray(spec.model_index)
    occ = np.zeros((spec.num_gpus, spec.num_mem_slices), np.int32)
    for g in range(spec.num_gpus):
        s = spec.models[midx[g]].num_mem_slices
        occ[g, :s] = (rng.random(s) < fill).astype(np.int32)
    base = jnp.einsum(
        "ms,mns->mn", jnp.asarray(occ, jnp.float32), tables.W[midx]
    )
    free = jnp.asarray(tables.slices[midx] - occ.sum(axis=1), jnp.int32)
    f = batched._frag_from_base(base, free, "blocked", tables.V[midx])
    return base, free, f


class TestFusedSelectParity:
    """Fused select (ΔF + in-kernel lex argmin) vs the masked-refinement
    oracle — every registered DeviceModel (padded H200-141GB included),
    randomized occupancy, interpret mode."""

    @pytest.mark.parametrize("model", DEVICE_MODELS, ids=lambda m: m.name)
    @pytest.mark.parametrize("policy", ["mfi", "bf-bi", "wf-bi"])
    def test_homogeneous_matches_oracle(self, model, policy):
        spec = mig.ClusterSpec.homogeneous(model, 9)
        tables = batched.spec_tables(spec)
        pspec = resolve(policy, engine="batched")
        keys = batched._effective_keys(pspec)
        select_fn = batched.make_select_fn(spec, pspec, interpret=True)
        arange_n = jnp.arange(int(tables.V.shape[-1]))
        gidx = jnp.arange(spec.num_gpus)
        for seed, fill in ((0, 0.0), (1, 0.45), (2, 0.9)):
            base, free, f = _random_state(
                spec, tables, seed + len(model.name), fill
            )
            for pid in range(mig.NUM_PROFILES):
                got = select_fn(base, free, f, pid)
                rowsel = (
                    tables.profile_rows[0, pid][None, :] == arange_n[:, None]
                )
                want = select_from_base_ref(
                    base, free, f, gidx, tables.V[0],
                    tables.maskwin[0, pid], tables.profile_mem[0, pid],
                    rowsel, tables.profile_valid[0, pid],
                    tables.profile_anchors[0, pid], keys,
                )
                assert tuple(int(x) for x in got) == tuple(
                    int(x) for x in want
                ), (model.name, policy, seed, pid)

    @pytest.mark.parametrize("metric", ["blocked", "partial"])
    def test_mixed_fleet_matches_jnp_lowering(self, metric):
        """Per-model dispatch + cross-group merge vs `_lower_select` on a
        three-model fleet (A100-80/H200-141/A100-40)."""
        spec = mig.ClusterSpec(
            ((mig.A100_80GB, 2), (mig.H200_141GB, 2), (mig.A100_40GB, 2))
        )
        tables = batched.spec_tables(spec)
        midx = jnp.asarray(spec.model_index)
        vg = tables.V[midx]
        for policy in ("mfi", "bf-bi"):
            pspec = resolve(policy, engine="batched")
            select_fn = batched.make_select_fn(
                spec, pspec, metric=metric, interpret=True
            )
            for seed in range(3):
                base, free, f = _random_state(spec, tables, 10 + seed, 0.5)
                if metric == "partial":
                    f = batched._frag_from_base(base, free, metric, vg)
                for pid in range(mig.NUM_PROFILES):
                    got = select_fn(base, free, f, pid)
                    want = batched._select(
                        pspec, base, free, f, metric, tables, midx, vg,
                        pid, cursor=jnp.int32(0),
                    )
                    assert tuple(int(x) for x in got) == tuple(
                        int(x) for x in want
                    ), (policy, seed, pid)

    def test_multi_tile_merge(self):
        """m > BLK_M: per-tile winner rows merge across tiles by
        ``(keys…, gpu, col)`` without perturbing the total order."""
        spec = mig.ClusterSpec.homogeneous(mig.A100_80GB, 516)
        tables = batched.spec_tables(spec)
        pspec = resolve("mfi", engine="batched")
        keys = batched._effective_keys(pspec)
        select_fn = batched.make_select_fn(spec, pspec, interpret=True)
        base, free, f = _random_state(spec, tables, 21, 0.6)
        arange_n = jnp.arange(int(tables.V.shape[-1]))
        pid = 3
        rowsel = tables.profile_rows[0, pid][None, :] == arange_n[:, None]
        got = select_fn(base, free, f, pid)
        want = select_from_base_ref(
            base, free, f, jnp.arange(516), tables.V[0],
            tables.maskwin[0, pid], tables.profile_mem[0, pid], rowsel,
            tables.profile_valid[0, pid], tables.profile_anchors[0, pid],
            keys,
        )
        assert tuple(int(x) for x in got) == tuple(int(x) for x in want)

    def test_request_scoped_keys_drop_out(self):
        """mfi-queued's tenant/priority/wait-age keys are request-scoped:
        the fused lowering drops them and must select exactly like mfi."""
        pspec_q = resolve("mfi-queued", engine="batched")
        assert batched._effective_keys(pspec_q) == batched._effective_keys(
            resolve("mfi", engine="batched")
        )
        spec = mig.ClusterSpec.homogeneous(mig.A100_80GB, 6)
        tables = batched.spec_tables(spec)
        fn_q = batched.make_select_fn(spec, pspec_q, interpret=True)
        fn_m = batched.make_select_fn(
            spec, resolve("mfi", engine="batched"), interpret=True
        )
        base, free, f = _random_state(spec, tables, 5, 0.5)
        for pid in range(mig.NUM_PROFILES):
            gq = fn_q(base, free, f, pid)
            gm = fn_m(base, free, f, pid)
            assert tuple(int(x) for x in gq) == tuple(int(x) for x in gm)


class TestFusedMigrateParity:
    """`migrate_refine`'s two passes vs the select oracle — the per-class
    top-2 equals the oracle's best (then best-with-winner-row-excluded) and
    the per-victim patched-row pass equals a one-row oracle call."""

    def _setup(self, model, seed, fill):
        spec = mig.ClusterSpec.homogeneous(model, 7)
        tables = batched.spec_tables(spec)
        pspec = resolve("mfi-defrag", engine="batched")
        keys = batched._effective_keys(pspec)
        fn = batched.make_migrate_fn(spec, pspec, interpret=True)
        base, free, f = _random_state(spec, tables, seed, fill)
        rng = np.random.default_rng(seed + 99)
        c = 5
        rg = jnp.asarray(rng.integers(0, spec.num_gpus, size=c), jnp.int32)
        rp = jnp.asarray(rng.integers(0, mig.NUM_PROFILES, size=c), jnp.int32)
        kc = jnp.zeros((c,), jnp.int32)
        vspec = mig.ClusterSpec.homogeneous(model, c)
        base2, free2, f2 = _random_state(vspec, tables, seed + 7, fill)
        return (spec, tables, keys, fn, base, free, f,
                (base2, free2, f2, rg, rp, kc))

    @pytest.mark.parametrize("model", DEVICE_MODELS, ids=lambda m: m.name)
    @pytest.mark.parametrize("seed,fill", [(0, 0.0), (1, 0.5), (2, 0.95)])
    def test_matches_oracle(self, model, seed, fill):
        (spec, tables, keys, fn, base, free, f,
         (base2, free2, f2, rg, rp, kc)) = self._setup(model, seed, fill)
        g1, ok1, a1, k1, g2, ok2, a2, k2, ap, okp, kp = fn(
            base, free, f, base2, free2, f2, rg, rp, kc
        )
        arange_n = jnp.arange(int(tables.V.shape[-1]))
        gidx = jnp.arange(spec.num_gpus)
        for p in range(mig.NUM_PROFILES):
            rowsel = tables.profile_rows[0, p][None, :] == arange_n[:, None]
            args = (
                tables.V[0], tables.maskwin[0, p], tables.profile_mem[0, p],
                rowsel, tables.profile_valid[0, p],
                tables.profile_anchors[0, p], keys,
            )
            w1 = select_from_base_ref(base, free, f, gidx, *args)
            assert (int(g1[p]), int(a1[p]), bool(ok1[p])) == (
                int(w1[0]), int(w1[1]), bool(w1[2])
            ), (model.name, p)
            # runner-up: best with the winner's row forced infeasible
            # (rows are independent, so patching row g1 is exact exclusion)
            b2 = base.at[w1[0]].set(1.0) if bool(w1[2]) else base
            w2 = select_from_base_ref(b2, free, f, gidx, *args)
            assert (int(g2[p]), int(a2[p]), bool(ok2[p])) == (
                int(w2[0]), int(w2[1]), bool(w2[2])
            ), (model.name, p)

        for c in range(int(rg.shape[0])):
            p = int(rp[c])
            rowsel = tables.profile_rows[0, p][None, :] == arange_n[:, None]
            wv = select_from_base_ref(
                base2[c][None], free2[c][None], f2[c][None], rg[c][None],
                tables.V[0], tables.maskwin[0, p], tables.profile_mem[0, p],
                rowsel, tables.profile_valid[0, p],
                tables.profile_anchors[0, p], keys,
            )
            assert (int(ap[c]), bool(okp[c])) == (int(wv[1]), bool(wv[2])), (
                model.name, c
            )

    def test_all_infeasible_class(self):
        """A fully packed fleet: every class all-infeasible in both passes,
        `(0, 0, False)` rows all the way through."""
        (_, _, _, fn, base, free, f,
         (base2, free2, f2, rg, rp, kc)) = self._setup(mig.A100_80GB, 3, 1.0)
        g1, ok1, a1, _, g2, ok2, a2, _, ap, okp, _ = fn(
            base, free, f, base2, free2, f2, rg, rp, kc
        )
        assert not np.asarray(ok1).any() and not np.asarray(ok2).any()
        assert not np.asarray(okp).any()
        np.testing.assert_array_equal(np.asarray(g1), 0)
        np.testing.assert_array_equal(np.asarray(g2), 0)
        np.testing.assert_array_equal(np.asarray(ap), 0)


class TestDecodeAttentionKernel:
    SHAPES = [
        # (batch, q_heads, kv_heads, head_dim, kv_len, blk_s)
        (2, 8, 2, 64, 300, 128),    # GQA, ragged tail block
        (1, 8, 1, 128, 1024, 512),  # MQA (paligemma-style)
        (3, 10, 5, 64, 77, 512),    # block larger than sequence
        (2, 4, 4, 256, 513, 256),   # MHA, gemma3 head_dim
        (1, 12, 4, 128, 2048, 512), # starcoder2-style ratio
    ]

    @pytest.mark.parametrize("shape", SHAPES)
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_matches_ref(self, shape, dtype):
        b, h, kh, d, s, blk = shape
        rng = np.random.default_rng(0)
        q = jnp.asarray(rng.standard_normal((b, h, d)), dtype)
        k = jnp.asarray(rng.standard_normal((b, s, kh, d)), dtype)
        v = jnp.asarray(rng.standard_normal((b, s, kh, d)), dtype)
        length = jnp.asarray(rng.integers(1, s + 1, size=b), jnp.int32)
        got = decode_attention(q, k, v, length, blk_s=blk)
        ref = decode_attention_ref(q, k, v, length=length)
        tol = 2e-5 if dtype == jnp.float32 else 2.5e-2
        np.testing.assert_allclose(
            np.asarray(got, np.float32), np.asarray(ref, np.float32), atol=tol, rtol=tol
        )

    def test_full_length_default(self):
        rng = np.random.default_rng(1)
        q = jnp.asarray(rng.standard_normal((2, 4, 64)), jnp.float32)
        k = jnp.asarray(rng.standard_normal((2, 256, 2, 64)), jnp.float32)
        v = jnp.asarray(rng.standard_normal((2, 256, 2, 64)), jnp.float32)
        length = jnp.full((2,), 256, jnp.int32)
        got = decode_attention(q, k, v, length)
        ref = decode_attention_ref(q, k, v)
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref), atol=2e-5, rtol=2e-5)

    def test_custom_scale(self):
        rng = np.random.default_rng(2)
        q = jnp.asarray(rng.standard_normal((1, 4, 64)), jnp.float32)
        k = jnp.asarray(rng.standard_normal((1, 128, 2, 64)), jnp.float32)
        v = jnp.asarray(rng.standard_normal((1, 128, 2, 64)), jnp.float32)
        length = jnp.full((1,), 128, jnp.int32)
        got = decode_attention(q, k, v, length, scale=0.1)
        ref = decode_attention_ref(q, k, v, scale=0.1, length=length)
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref), atol=2e-5, rtol=2e-5)

    def test_length_one(self):
        """Degenerate cache with a single valid entry -> output == v[0]."""
        rng = np.random.default_rng(3)
        q = jnp.asarray(rng.standard_normal((1, 2, 64)), jnp.float32)
        k = jnp.asarray(rng.standard_normal((1, 128, 2, 64)), jnp.float32)
        v = jnp.asarray(rng.standard_normal((1, 128, 2, 64)), jnp.float32)
        length = jnp.asarray([1], jnp.int32)
        got = decode_attention(q, k, v, length)
        np.testing.assert_allclose(
            np.asarray(got)[0], np.asarray(v)[0, 0], atol=1e-6, rtol=1e-6
        )
