"""Tests for the Monte-Carlo simulator and workload distributions."""

import numpy as np
import pytest

from repro.core import mig
from repro.sim import SimConfig, distributions, run_simulation, run_many
from repro.core.schedulers import make_scheduler


class TestDistributions:
    def test_all_sum_to_one(self):
        for name, p in distributions.DISTRIBUTIONS.items():
            assert abs(p.sum() - 1.0) < 1e-9, name
            assert len(p) == mig.NUM_PROFILES

    def test_table_ii_values(self):
        d = distributions.DISTRIBUTIONS["skew-small"]
        np.testing.assert_allclose(d, [0.05, 0.10, 0.10, 0.20, 0.25, 0.30])
        d = distributions.DISTRIBUTIONS["bimodal"]
        np.testing.assert_allclose(d, [0.30, 0.15, 0.05, 0.05, 0.15, 0.30])

    def test_sampling_matches_distribution(self):
        rng = np.random.default_rng(0)
        s = distributions.sample_profiles("skew-small", 20000, rng)
        freq = np.bincount(s, minlength=6) / 20000
        np.testing.assert_allclose(freq, distributions.DISTRIBUTIONS["skew-small"], atol=0.02)

    def test_unknown_name_raises(self):
        with pytest.raises(ValueError):
            distributions.sample_profiles("nope", 1, np.random.default_rng(0))


class TestSimulator:
    def test_steady_runs_and_is_deterministic(self):
        cfg = SimConfig(num_gpus=10, offered_load=0.7, seed=3)
        r1 = run_simulation(make_scheduler("mfi"), cfg)
        r2 = run_simulation(make_scheduler("mfi"), cfg)
        assert r1.acceptance_rate == r2.acceptance_rate
        assert 0.0 < r1.acceptance_rate <= 1.0
        assert 0.0 <= r1.utilization <= 1.0
        assert 0 <= r1.active_gpus <= 10

    def test_cumulative_traces(self):
        cfg = SimConfig(num_gpus=10, protocol="cumulative", max_demand=1.0, seed=3)
        r = run_simulation(make_scheduler("ff"), cfg)
        assert r.traces is not None
        assert len(r.traces["acceptance_rate"]) == len(cfg.demand_grid)
        # acceptance is a ratio in [0, 1] and monotone demand grid
        assert ((r.traces["acceptance_rate"] >= 0) & (r.traces["acceptance_rate"] <= 1)).all()

    def test_conservation(self):
        """accepted + rejected == arrived (by profile)."""
        cfg = SimConfig(num_gpus=8, offered_load=1.2, seed=5)
        r = run_simulation(make_scheduler("rr"), cfg)
        arrived = r.arrivals_by_profile.sum()
        assert arrived > 0
        assert r.allocated_workloads + r.rejects_by_profile.sum() == arrived

    @pytest.mark.slow
    def test_mfi_beats_spreading_baselines_under_load(self):
        """Core paper claim, small-scale: MFI acceptance >= RR and WF-BI."""
        cfg = SimConfig(num_gpus=16, offered_load=0.9, seed=11)
        mfi = np.mean([run_simulation(make_scheduler("mfi"), cfg, seed=11 + k).acceptance_rate for k in range(3)])
        rr = np.mean([run_simulation(make_scheduler("rr"), cfg, seed=11 + k).acceptance_rate for k in range(3)])
        wf = np.mean([run_simulation(make_scheduler("wf-bi"), cfg, seed=11 + k).acceptance_rate for k in range(3)])
        assert mfi >= rr
        assert mfi >= wf

    def test_run_many_aggregates(self):
        cfg = SimConfig(num_gpus=8, offered_load=0.8, seed=0)
        out = run_many("ff", cfg, runs=2)
        for k in ("acceptance_rate", "allocated_workloads", "utilization", "frag_severity"):
            assert k in out


class TestQueuedProtocol:
    def test_steady_queued_runs_with_wait_metrics(self):
        cfg = SimConfig(
            num_gpus=8, offered_load=1.2, seed=5, protocol="steady-queued"
        )
        r = run_simulation(make_scheduler("mfi"), cfg)
        assert 0.0 < r.acceptance_rate <= 1.0
        assert r.wait_p50 is not None and r.wait_p99 is not None
        assert 0.0 <= r.wait_p50 <= r.wait_p99 <= cfg.wait_patience
        assert 0.0 < r.fairness <= 1.0
        # conservation holds with the queue in the loop
        arrived = r.arrivals_by_profile.sum()
        assert r.allocated_workloads + r.rejects_by_profile.sum() == arrived

    def test_queue_lifts_acceptance_under_load(self):
        """Waiting instead of dropping can only help acceptance."""
        accs = {}
        for proto in ("steady", "steady-queued"):
            cfg = SimConfig(
                num_gpus=8, offered_load=1.3, seed=9, protocol=proto
            )
            accs[proto] = np.mean(
                [
                    run_simulation(make_scheduler("mfi"), cfg, seed=9 + k).acceptance_rate
                    for k in range(3)
                ]
            )
        assert accs["steady-queued"] >= accs["steady"]

    def test_run_many_queued_keys(self):
        cfg = SimConfig(
            num_gpus=8, offered_load=1.1, seed=1, protocol="steady-queued"
        )
        out = run_many("mfi-queued", cfg, runs=2)
        for k in ("wait_p50", "wait_p99", "fairness", "queue_admits"):
            assert k in out
        assert 0.0 < out["fairness"] <= 1.0
