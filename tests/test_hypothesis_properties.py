"""Hypothesis property tests for the fragmentation metric, the MFI kernels
and the jitted cluster scheduler.

Module-level skip-guarded: ``hypothesis`` is an optional dev dependency
(``requirements-dev.txt`` / the ``dev`` extra) — tier-1 collects cleanly
without it, and these properties run wherever it is installed (CI installs
it).  The deterministic (exhaustive / fixed-seed) variants of these checks
live in the corresponding always-on test modules.
"""

import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings
from hypothesis import strategies as st

import jax.numpy as jnp

from repro.core import cluster as jcluster
from repro.core import fragmentation, mig, schedulers
from repro.core.policy import list_policies
from repro.core.schedulers import make_scheduler
from repro.kernels.fragscore import ops as frag_ops


def _occ(*slices):
    x = np.zeros(mig.NUM_MEM_SLICES, dtype=np.int32)
    for s in slices:
        x[s] = 1
    return x


class TestFragmentationProperties:
    @given(st.lists(st.integers(0, 7), min_size=0, max_size=8))
    @settings(max_examples=200, deadline=None)
    def test_jnp_matches_numpy(self, slices):
        occ = _occ(*slices)[None, :]
        for metric in fragmentation.METRIC_VARIANTS:
            ref = fragmentation.fragmentation_scores(occ, metric)
            got = np.asarray(jcluster.frag_scores(jnp.asarray(occ), metric))
            np.testing.assert_allclose(got, ref)

    @given(st.lists(st.integers(0, 7), min_size=0, max_size=6))
    @settings(max_examples=100, deadline=None)
    def test_nonnegative_and_bounded(self, slices):
        occ = _occ(*slices)
        for metric in fragmentation.METRIC_VARIANTS:
            f = fragmentation.fragmentation_score(occ, metric)
            assert 0 <= f <= mig.PLACEMENT_MEM.sum()


class TestMFIDeltaKernelProperties:
    @given(st.integers(0, 255), st.integers(0, 5))
    @settings(max_examples=80, deadline=None)
    def test_single_gpu_property(self, bitmap, pid):
        occ = np.array([[int(b) for b in f"{bitmap:08b}"]], np.int32)
        delta = np.asarray(frag_ops.mfi_delta_f(jnp.asarray(occ), jnp.int32(pid)))[0]
        prof = mig.PROFILES[pid]
        for j, anchor in enumerate(prof.anchors):
            window_free = occ[0, anchor : anchor + prof.mem].sum() == 0
            if window_free:
                expect = fragmentation.delta_f(occ[0], pid, anchor)
                np.testing.assert_allclose(delta[j], expect, rtol=1e-6)
            else:
                assert delta[j] > 1e29


class TestPolicyFeasibilityProperties:
    """Registry-wide invariant: for EVERY registered policy (defrag
    included) driven over a random demand stream on a mixed fleet, a
    selected placement is always feasible — a legal anchor of the chosen
    GPU's own model table, never a double-booked slice, and never the
    80 GiB class on an A100-40GB (which has no realization for it)."""

    MIXED = mig.ClusterSpec(
        ((mig.A100_80GB, 2), (mig.A100_40GB, 2), (mig.H100_96GB, 1))
    )

    @given(
        policy=st.sampled_from(list_policies()),
        stream=st.lists(
            st.tuples(
                st.integers(0, mig.NUM_PROFILES - 1),  # demand class
                st.booleans(),  # release the oldest alive workload first?
            ),
            min_size=1,
            max_size=40,
        ),
    )
    @settings(max_examples=60, deadline=None)
    def test_selected_placement_always_feasible(self, policy, stream):
        cluster = mig.ClusterState(spec=self.MIXED)
        sched = make_scheduler(policy)
        alive = []
        for step, (pid, release_first) in enumerate(stream):
            if release_first and alive:
                cluster.release(alive.pop(0))
            sel = sched.select(cluster, pid)
            if sel is None:
                continue
            g, a = sel
            model = cluster.spec.model_of(g)
            # never places a class on a model with no realization for it
            # (e.g. the 80 GiB class on an A100-40GB)
            assert model.placeable(pid), (policy, pid, model.name)
            assert a in model.profiles[pid].anchors, (policy, pid, g, a)
            # defrag policies may require their migration to commit first
            mig_req = getattr(sched, "pending_migration", None)
            if mig_req is not None:
                vwid, vg, va = mig_req
                vpid = next(
                    gg.allocations[vwid].profile_id
                    for gg in cluster.gpus
                    if vwid in gg.allocations
                )
                assert cluster.spec.model_of(vg).placeable(vpid)
                cluster.release(vwid)
                cluster.allocate(vwid, vpid, vg, va)  # raises if infeasible
            # never double-books: the window is fully free at commit time
            prof = model.profiles[pid]
            assert not cluster.gpus[g].occupancy[a : a + prof.mem].any(), (
                policy, pid, g, a,
            )
            wid = 1000 + step
            cluster.allocate(wid, pid, g, a)  # raises if illegal
            alive.append(wid)


class TestJaxSchedulerProperties:
    @given(
        st.lists(
            st.tuples(st.integers(0, 5), st.integers(0, 5)), min_size=0, max_size=24
        ),
        st.integers(0, 5),
    )
    @settings(max_examples=60, deadline=None)
    def test_mfi_select_parity(self, placements, req_pid):
        cl = mig.ClusterState(6)
        wid = 0
        for pid, gpu in placements:
            anchors = cl.gpus[gpu].feasible_anchors(pid)
            if anchors:
                cl.allocate(wid, pid, gpu, anchors[0])
                wid += 1
        occ = cl.occupancy_matrix()
        d = jcluster.mfi_select(jnp.asarray(occ), jnp.int32(req_pid))
        gpus, anchors, deltas = schedulers.mfi_candidates(occ, req_pid)
        if len(gpus) == 0:
            assert not bool(d.accepted)
        else:
            assert bool(d.accepted)
            k = np.lexsort((anchors, gpus, deltas))[0]
            assert (int(d.gpu), int(d.anchor)) == (int(gpus[k]), int(anchors[k]))
            np.testing.assert_allclose(float(d.delta_f), deltas[k], rtol=1e-6)
