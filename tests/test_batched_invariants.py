"""Hypothesis property tests for the batched engine's scheduling invariants.

Module-level skip-guarded (``hypothesis`` is an optional dev dependency —
see ``requirements-dev.txt``); the deterministic fixed-seed variants of
these checks always run in ``test_batched_sim.py``.

Invariants (checked by the host replay in :mod:`repro.sim.replay` against
the device decision trace):

* a scan-step trajectory never double-books a memory slice;
* accepted placements only use legal Table-I anchors;
* ``release`` after expiry restores the exact pre-allocation occupancy;
* a defrag **migration never double-books or strands a workload**: the
  victim is a uniquely identified running workload, its evacuated window
  was fully occupied, its landing window is legal and fully free, and it
  still drains exactly from its new placement (``drain_all`` ends empty).
"""

import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings
from hypothesis import strategies as st

import jax
import jax.numpy as jnp

from repro.core import mig
from repro.sim import SimConfig
from repro.sim import batched, replay


def _run_trace(policy, seed, load, runs=2, num_gpus=3):
    cfg = SimConfig(num_gpus=num_gpus, offered_load=load, seed=seed)
    events, meta, rr, rc = batched.presample_arrivals(cfg, runs=runs)
    final, trace = jax.device_get(
        batched._simulate(
            jax.tree.map(jnp.asarray, events),
            policy=policy,
            metric=cfg.metric,
            num_gpus=cfg.num_gpus,
            ring_rows=rr,
            ring_cols=rc,
            use_kernel=False,
        )
    )
    return events, meta, trace, final, cfg


class TestTrajectoryInvariants:
    @given(
        st.sampled_from(batched.POLICIES),
        st.integers(0, 2**16),
        st.sampled_from([0.6, 0.9, 1.2]),
    )
    @settings(max_examples=8, deadline=None)
    def test_no_double_booking_and_legal_anchors(self, policy, seed, load):
        events, meta, trace, final, cfg = _run_trace(policy, seed, load)
        # replay raises AssertionError on any double-booked slice or
        # illegal anchor, and on any release that does not free a
        # fully-occupied window
        occ = replay.replay(events, meta, trace, cfg.num_gpus)
        w = np.asarray(mig.PLACEMENT_MASKS, np.float32)
        np.testing.assert_allclose(final.base, occ.astype(np.float32) @ w.T)

    @given(st.sampled_from(batched.POLICIES), st.integers(0, 2**16))
    @settings(max_examples=6, deadline=None)
    def test_release_restores_exact_occupancy(self, policy, seed):
        events, meta, trace, final, cfg = _run_trace(policy, seed, 0.9)
        _, drained = replay.drain_all(events, meta, trace, cfg.num_gpus)
        np.testing.assert_array_equal(drained, 0)

    @given(st.integers(0, 2**16), st.sampled_from([1.0, 1.2, 1.5]))
    @settings(max_examples=8, deadline=None)
    def test_migration_never_double_books_or_strands(self, seed, load):
        """Defrag trajectories: the replay validates every migration (unique
        victim, fully-occupied evacuated window, legal + free landing
        window) and `drain_all` proves migrated workloads still expire
        exactly from their new placements — nothing is stranded."""
        events, meta, trace, final, cfg = _run_trace("mfi-defrag", seed, load)
        occ = replay.replay(events, meta, trace, cfg.num_gpus)
        w = np.asarray(mig.PLACEMENT_MASKS, np.float32)
        np.testing.assert_allclose(final.base, occ.astype(np.float32) @ w.T)
        _, drained = replay.drain_all(events, meta, trace, cfg.num_gpus)
        np.testing.assert_array_equal(drained, 0)


class TestSingleDecisionProperties:
    @given(
        st.sampled_from(batched.POLICIES),
        st.lists(st.integers(0, 255), min_size=1, max_size=6),
        st.integers(0, mig.NUM_PROFILES - 1),
    )
    @settings(max_examples=120, deadline=None)
    def test_accepted_placement_is_legal_and_free(self, policy, bitmaps, pid):
        occ = np.array(
            [[int(b) for b in f"{bm:08b}"] for bm in bitmaps], np.int32
        )
        g, a, ok = batched.policy_select(jnp.asarray(occ), jnp.int32(pid), policy)
        if not bool(ok):
            return
        g, a = int(g), int(a)
        prof = mig.PROFILES[pid]
        assert a in prof.anchors  # Table-I legality
        assert (occ[g, a : a + prof.mem] == 0).all()  # no double-booking
        # commit + release roundtrip restores exact occupancy
        after = occ.copy()
        after[g, a : a + prof.mem] = 1
        after[g, a : a + prof.mem] = 0
        np.testing.assert_array_equal(after, occ)
