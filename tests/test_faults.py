"""Fault-injection and recovery subsystem.

The ``steady-faulted`` protocol overlays an exponential per-GPU
fail/recover process (:class:`repro.core.mig.FaultModel`) on the queued
engine: a failing GPU is masked from feasibility, its running leases are
evicted in one pass and re-queued with a retry budget and exponential
backoff, and recovery restores the GPU to placement.  These tests pin

  * construction-time validation everywhere a bad knob can enter
    (FaultModel, SimConfig, api.simulate, AdmissionController.submit);
  * byte-identity of every pre-existing event stream when faults are off
    (fault draws happen strictly after all other rng draws);
  * per-event parity of the batched device traces against an independent
    host reference (:func:`repro.sim.replay.faulted_host_decisions`) on
    homogeneous and mixed fleets, plus pinned golden SHA-256 hashes;
  * the serving-layer fail/recover/backoff loop and its fault stats;
  * crash-safe checkpoints: payload digests verified on load, and a
    SIGKILLed chunked run resuming bit-for-bit from its last checkpoint.
"""

import dataclasses
import hashlib
import json
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro import api
from repro.core import mig
from repro.checkpoint import ckpt
from repro.serving.admission import AdmissionController
from repro.sim import SimConfig, batched, replay
from repro.sim.simulator import run_many

from test_engine_core import MIXED

#: the fault process every golden/parity test below runs under — hot enough
#: (MTBF 60 slots on a ~200-slot horizon) that evictions, re-queues and
#: recoveries are all actually exercised
FM = mig.FaultModel(mtbf=60.0, mttr=10.0)


def _sim_faulted(policy, cfg, spec=None, runs=3, fault_model=FM):
    events, meta, rr, rc = batched.presample_arrivals(
        cfg, runs=runs, queued=True, fault_model=fault_model
    )
    kw = {}
    if spec is not None:
        kw = dict(
            midx=jnp.asarray(spec.model_index), tables=batched.spec_tables(spec)
        )
    proto = dataclasses.replace(
        batched.resolve_protocol("steady-faulted"),
        fault_retries=fault_model.max_retries,
        fault_backoff=fault_model.backoff_base,
    )
    final, trace = jax.device_get(
        batched._simulate(
            jax.tree.map(
                lambda x: jnp.asarray(x) if x is not None else None, events
            ),
            policy=policy,
            metric=cfg.metric,
            num_gpus=cfg.num_gpus,
            ring_rows=rr,
            ring_cols=rc,
            use_kernel=False,
            protocol=proto,
            wait_slots=cfg.wait_capacity,
            wait_patience=cfg.wait_patience,
            **kw,
        )
    )
    return events, meta, trace, final


#: (tag -> configuration) for the faulted golden hashes and parity tests
FAULTED_GOLDEN = {
    "homog": (lambda: SimConfig(num_gpus=5, offered_load=1.2, seed=7), None, "mfi"),
    "mixed": (
        lambda: SimConfig(cluster_spec=MIXED, offered_load=1.1, seed=9),
        MIXED,
        "mfi-queued",
    ),
}

#: decision-trace hashes of the faulted protocol at introduction — eviction,
#: backoff re-queue and recovery must stay bit-for-bit reproducible
GOLDEN_FAULTED_TRACE_HASHES = {
    "homog": "abb15f38d863b0c6ce819b7bb452235f163bf35e876e944c1df4c51e4deaad97",
    "mixed": "1bf958443af4abdbe75e50c4ac1e026875e84b3bbddd2658800f8b7f9079f7fe",
}


def _faulted_hash(trace):
    h = hashlib.sha256()
    for a in (
        trace.ok, trace.gpu, trace.aidx, trace.parked, trace.wadm_eidx,
        trace.wadm_gpu, trace.wadm_aidx, trace.evicted, trace.evict_lost,
        trace.evict_esum, trace.free_sum, trace.active, trace.frag,
    ):
        h.update(np.ascontiguousarray(np.asarray(a)).tobytes())
    return h.hexdigest()


# ---------------------------------------------------------------------------
# Construction-time validation
# ---------------------------------------------------------------------------


class TestFaultModelValidation:
    def test_defaults_and_backoff_schedule(self):
        fm = mig.FaultModel()
        assert fm.rates_for("A100-80GB") == (fm.mtbf, fm.mttr)
        assert [fm.backoff(k) for k in (1, 2, 3)] == [2, 4, 8]

    def test_per_model_override(self):
        fm = mig.FaultModel(per_model=(("H100-96GB", (50.0, 5.0)),))
        assert fm.rates_for("H100-96GB") == (50.0, 5.0)
        assert fm.rates_for("A100-80GB") == (fm.mtbf, fm.mttr)

    @pytest.mark.parametrize(
        "kw,match",
        [
            (dict(mtbf=0.0), "MTBF"),
            (dict(mtbf=float("inf")), "MTBF"),
            (dict(mttr=-1.0), "MTTR"),
            (dict(mttr=float("nan")), "MTTR"),
            (dict(per_model=(("A100-80GB", (0.0, 5.0)),)), "A100-80GB"),
            (dict(per_model=(("A100-80GB", (5.0, -2.0)),)), "A100-80GB"),
            (dict(max_retries=-1), "max_retries"),
            (dict(backoff_base=0), "backoff_base"),
        ],
    )
    def test_rejects_bad_knobs(self, kw, match):
        with pytest.raises(ValueError, match=match):
            mig.FaultModel(**kw)

    @pytest.mark.parametrize(
        "kw,match",
        [
            (dict(wait_patience=-1), "wait_patience"),
            (dict(wait_capacity=-2), "wait_capacity"),
            (dict(num_priorities=0), "num_priorities"),
            (dict(num_tenants=0), "num_tenants"),
        ],
    )
    def test_simconfig_rejects_bad_knobs(self, kw, match):
        with pytest.raises(ValueError, match=match):
            SimConfig(num_gpus=3, **kw)

    @pytest.mark.parametrize("chunk_size", [0, -5])
    def test_api_rejects_nonpositive_chunk_size(self, chunk_size):
        with pytest.raises(ValueError, match="chunk_size"):
            api.simulate(
                "mfi", engine="batched", runs=1, num_gpus=3,
                offered_load=1.0, seed=1, chunk_size=chunk_size,
            )

    def test_faultmodel_reexported_from_api(self):
        assert api.FaultModel is mig.FaultModel

    def test_faulted_protocol_requires_fault_model(self):
        cfg = SimConfig(num_gpus=3, offered_load=1.0, seed=1,
                        protocol="steady-faulted")
        with pytest.raises(ValueError, match="fault_model"):
            batched.run_batched("mfi", cfg, runs=2)
        with pytest.raises(ValueError, match="fault_model"):
            run_many("mfi", cfg, runs=1)


# ---------------------------------------------------------------------------
# Stream byte-identity: fault draws ride strictly after every other draw
# ---------------------------------------------------------------------------


class TestFaultStreams:
    def test_queued_stream_unchanged_by_fault_draws(self):
        """With a fault model the shared lanes must stay byte-identical to
        the plain queued stream — every pre-existing golden stays valid."""
        cfg = SimConfig(num_gpus=5, offered_load=1.2, seed=7)
        ev_q, meta_q, rr_q, rc_q = batched.presample_arrivals(
            cfg, runs=3, queued=True
        )
        ev_f, meta_f, rr_f, rc_f = batched.presample_arrivals(
            cfg, runs=3, queued=True, fault_model=FM
        )
        assert (rr_q, rc_q) == (rr_f, rc_f)
        for name in type(ev_q)._fields:
            if name in ("fail", "recover"):
                continue
            a, b = getattr(ev_q, name), getattr(ev_f, name)
            assert (a is None) == (b is None), name
            if a is not None:
                np.testing.assert_array_equal(a, b, err_msg=name)
        assert ev_q.fail is None and ev_q.recover is None
        assert ev_f.fail.any() and ev_f.recover.any()

    def test_fault_lanes_alternate_per_gpu(self):
        """Per GPU the fail/recover marks strictly alternate starting with
        a failure, and never share a slot."""
        spec = mig.ClusterSpec(((mig.A100_80GB, 4),))
        rng = np.random.default_rng(0)
        fail, recover = batched.presample_fault_slots(spec, FM, 2, 400, rng)
        assert not (fail & recover).any()
        for r in range(2):
            for g in range(4):
                marks = [
                    (t, "f" if fail[r, t, g] else "r")
                    for t in range(400)
                    if fail[r, t, g] or recover[r, t, g]
                ]
                assert marks, "fault process drew no events in 400 slots"
                kinds = [k for _, k in marks]
                assert kinds[0] == "f"
                assert all(a != b for a, b in zip(kinds, kinds[1:]))

    def test_fault_lanes_live_on_first_event_of_slot(self):
        cfg = SimConfig(num_gpus=5, offered_load=1.2, seed=7)
        ev, *_ = batched.presample_arrivals(
            cfg, runs=3, queued=True, fault_model=FM
        )
        marked = ev.fail.any(axis=-1) | ev.recover.any(axis=-1)
        e, r = np.nonzero(marked)
        # a marked event is the first of its slot: its predecessor (if any)
        # sits in an earlier slot
        inner = e > 0
        assert (ev.slot[e[inner] - 1, r[inner]] < ev.slot[e[inner], r[inner]]).all()


# ---------------------------------------------------------------------------
# Batched engine: protocol, goldens, and device<->host parity
# ---------------------------------------------------------------------------


class TestFaultedEngine:
    def test_protocol_registered(self):
        proto = batched.resolve_protocol("steady-faulted")
        assert proto.faulted and proto.queued
        assert not batched.resolve_protocol("steady-queued").faulted

    @pytest.mark.parametrize("tag", sorted(GOLDEN_FAULTED_TRACE_HASHES))
    def test_faulted_decision_traces_hash_identically(self, tag):
        cfg_fn, spec, policy = FAULTED_GOLDEN[tag]
        _, _, trace, _ = _sim_faulted(policy, cfg_fn(), spec)
        assert np.asarray(trace.evicted).sum() > 0, "no evictions exercised"
        assert _faulted_hash(trace) == GOLDEN_FAULTED_TRACE_HASHES[tag]

    @pytest.mark.parametrize("tag", sorted(FAULTED_GOLDEN))
    def test_device_trace_matches_host_reference(self, tag):
        """Every per-event decision — admissions, parks, wait-ring
        admissions, evictions, capacity losses and the evicted-id checksum
        — must match an independent host replay of the same stream."""
        cfg_fn, spec, policy = FAULTED_GOLDEN[tag]
        cfg = cfg_fn()
        events, meta, trace, _ = _sim_faulted(policy, cfg, spec)
        ref = replay.faulted_host_decisions(
            events, meta, policy, cfg.num_gpus, metric=cfg.metric, spec=spec,
            capacity=cfg.wait_capacity, patience=cfg.wait_patience,
            max_retries=FM.max_retries, backoff_base=FM.backoff_base,
        )
        assert ref.evicted.sum() > 0, "no evictions exercised"
        for name in (
            "ok", "parked", "wadm_eidx", "evicted", "evict_lost", "evict_esum"
        ):
            np.testing.assert_array_equal(
                np.asarray(getattr(trace, name)), getattr(ref, name),
                err_msg=name,
            )
        ok = ref.ok
        np.testing.assert_array_equal(np.asarray(trace.gpu)[ok], ref.gpu[ok])
        adm = ref.wadm_eidx >= 0
        assert adm.sum() > 0, "no wait-ring admissions exercised"
        np.testing.assert_array_equal(
            np.asarray(trace.wadm_gpu)[adm], ref.wadm_gpu[adm]
        )
        # device records anchor *indices*; the host reference records anchor
        # values — compare through the spec's placement tables
        cs = spec if spec is not None else mig.ClusterSpec(
            ((mig.A100_80GB, cfg.num_gpus),)
        )
        gpu = np.asarray(trace.gpu)
        aidx = np.asarray(trace.aidx)
        for e, r in np.argwhere(ok):
            m = cs.model_of(int(gpu[e, r]))
            anchor = m.profiles[int(events.pid[e, r])].anchors[int(aidx[e, r])]
            assert anchor == ref.anchor[e, r], (e, r)

    def test_run_batched_reports_fault_stats(self):
        cfg = SimConfig(
            num_gpus=5, offered_load=1.2, seed=7,
            protocol="steady-faulted", fault_model=FM,
        )
        out = batched.run_batched("mfi", cfg, runs=2)
        for key in (
            "goodput", "evictions", "evictions_lost", "recovered_fraction",
            "ttr_p50", "ttr_p99",
        ):
            assert key in out, key
        assert out["evictions"] > 0
        assert 0.0 <= out["goodput"] <= 1.0
        assert 0.0 <= out["recovered_fraction"] <= 1.0
        # completing everything that was admitted is impossible under this
        # fault rate, so goodput sits strictly below the acceptance rate
        assert out["goodput"] < out["acceptance_rate"]

    def test_fault_free_model_matches_queued_protocol(self):
        """An (effectively) fault-free model must reproduce the queued
        protocol's decisions exactly — the fault stages are inert no-ops."""
        cfg = SimConfig(num_gpus=5, offered_load=1.2, seed=7)
        calm = mig.FaultModel(mtbf=1e6, mttr=1.0)
        _, _, faulted, _ = _sim_faulted("mfi", cfg, fault_model=calm)
        assert np.asarray(faulted.evicted).sum() == 0
        ev, meta, rr, rc = batched.presample_arrivals(cfg, runs=3, queued=True)
        _, queued = jax.device_get(
            batched._simulate(
                jax.tree.map(
                    lambda x: jnp.asarray(x) if x is not None else None, ev
                ),
                policy="mfi", metric=cfg.metric, num_gpus=cfg.num_gpus,
                ring_rows=rr, ring_cols=rc, use_kernel=False,
                protocol="steady-queued", wait_slots=cfg.wait_capacity,
                wait_patience=cfg.wait_patience,
            )
        )
        for name in ("ok", "gpu", "aidx", "parked", "wadm_eidx", "wadm_gpu"):
            np.testing.assert_array_equal(
                np.asarray(getattr(faulted, name)),
                np.asarray(getattr(queued, name)),
                err_msg=name,
            )


# ---------------------------------------------------------------------------
# Host cluster + python runner
# ---------------------------------------------------------------------------


class TestHostFaults:
    def test_cluster_fail_recover_roundtrip(self):
        p3g = mig.PROFILE_NAMES.index("3g.40gb")
        p2g = mig.PROFILE_NAMES.index("2g.20gb")
        cl = mig.ClusterState(2)
        cl.allocate(1, p3g, 0, 0)
        cl.allocate(2, p2g, 0, 4)
        evicted = cl.fail_gpu(0)
        assert evicted == [1, 2]
        assert not cl.gpus[0].up
        assert cl.up_mask().tolist() == [False, True]
        assert cl.gpu_of(1) is None and cl.gpu_of(2) is None
        assert cl.gpus[0].feasible_anchors(p2g) == []
        with pytest.raises(ValueError, match="already down"):
            cl.fail_gpu(0)
        cl.recover_gpu(0)
        assert cl.gpus[0].up
        # fully free again: the 7g profile fits
        assert cl.gpus[0].feasible_anchors(0) == [0]
        with pytest.raises(ValueError, match="already up"):
            cl.recover_gpu(0)

    def test_run_many_faulted_keys_and_ranges(self):
        cfg = SimConfig(
            num_gpus=5, offered_load=1.2, seed=7,
            protocol="steady-faulted", fault_model=FM,
        )
        out = run_many("mfi", cfg, runs=3)
        for key in (
            "goodput", "evictions", "recovered_fraction", "ttr_p50", "ttr_p99"
        ):
            assert key in out, key
        assert out["evictions"] > 0
        assert 0.0 <= out["goodput"] <= 1.0
        assert 0.0 <= out["recovered_fraction"] <= 1.0


# ---------------------------------------------------------------------------
# Serving layer: AdmissionController fail/recover/backoff
# ---------------------------------------------------------------------------


class TestServingFaults:
    def test_fail_evicts_and_requeues_with_backoff(self):
        ac = AdmissionController(2, policy="mfi", queue_capacity=4)
        p1 = ac.submit(1, "3g.40gb", patience=8)
        p2 = ac.submit(2, "3g.40gb", patience=8)
        assert p1.gpu == p2.gpu == 0  # MFI packs both onto GPU 0
        evicted = ac.fail_gpu(0)
        assert evicted == [1, 2]
        assert not ac.placements and ac.queue_depth == 2
        assert ac.drain_dispatched() == []  # backoff: not eligible yet
        ac.tick()
        ac.tick()  # backoff_base=2 ticks -> eligible, GPU 1 takes both
        redone = {p.workload_id: p.gpu for p in ac.drain_dispatched()}
        assert redone == {1: 1, 2: 1}
        st = ac.stats()
        assert st["evictions"] == 2 and st["evict_lost"] == 0
        assert st["recovered_fraction"] == 1.0
        assert st["ttr_p50"] == 2.0  # both re-admitted two ticks after failure

    def test_recovery_readmits_when_only_the_failed_gpu_has_room(self):
        ac = AdmissionController(2, policy="mfi", queue_capacity=4)
        ac.submit(1, "7g.80gb", patience=8)
        ac.submit(2, "7g.80gb", patience=8)
        g2 = ac.placements[2].gpu
        assert ac.fail_gpu(g2) == [2]
        ac.tick()
        ac.tick()
        assert ac.drain_dispatched() == []  # ready, but no capacity anywhere
        ac.recover_gpu(g2)  # restores the only GPU with room
        redone = {p.workload_id: p.gpu for p in ac.drain_dispatched()}
        assert redone == {2: g2}
        assert ac.stats()["recovered_fraction"] == 1.0

    def test_readmission_does_not_double_count_acceptance(self):
        ac = AdmissionController(2, policy="mfi", queue_capacity=4)
        ac.submit(1, "1g.10gb", patience=8)
        accepted_before = ac.accepted
        ac.fail_gpu(0)
        ac.tick()
        ac.tick()
        assert [p.workload_id for p in ac.drain_dispatched()] == [1]
        assert ac.accepted == accepted_before

    def test_zero_retry_budget_is_final_loss(self):
        ac = AdmissionController(1, policy="mfi", max_retries=0)
        ac.submit(1, "1g.10gb")
        ac.fail_gpu(0)
        assert ac.queue_depth == 0
        assert ac.drain_expired() == [1]
        st = ac.stats()
        assert st["evict_lost"] == 1
        assert st["recovered_fraction"] == 0.0

    def test_full_queue_eviction_is_final_loss(self):
        ac = AdmissionController(1, policy="mfi", queue_capacity=0)
        ac.submit(1, "1g.10gb")
        ac.fail_gpu(0)
        assert ac.drain_expired() == [1]
        assert ac.stats()["evict_lost"] == 1

    def test_retry_budget_exhausts_after_max_retries(self):
        """With nothing freeing capacity, an evicted workload re-arms
        through its budget and then drops."""
        ac = AdmissionController(1, policy="mfi", queue_capacity=4,
                                 max_retries=2)
        ac.submit(1, "7g.80gb")
        ac.fail_gpu(0)  # GPU stays down -> no readmission possible
        for _ in range(64):
            ac.tick()
            if not ac.queue_depth:
                break
        assert ac.queue_depth == 0
        assert ac.drain_expired() == [1]
        assert ac.stats()["evict_lost"] == 1

    def test_goodput_counts_completions_over_terminal_outcomes(self):
        ac = AdmissionController(2, policy="mfi", max_retries=0)
        ac.submit(1, "1g.10gb")
        ac.submit(2, "1g.10gb")
        ac.release(1)
        ac.fail_gpu(ac.placements[2].gpu)
        ac.drain_expired()
        assert ac.stats()["goodput"] == 0.5  # one completed, one lost


# ---------------------------------------------------------------------------
# Crash-safe checkpoints
# ---------------------------------------------------------------------------


class TestCheckpointIntegrity:
    def _tree(self):
        return {"a": np.arange(12, dtype=np.int32).reshape(3, 4),
                "b": np.linspace(0.0, 1.0, 5, dtype=np.float32)}

    def test_sidecar_records_payload_digest(self, tmp_path):
        tree = self._tree()
        ckpt.save_checkpoint(tmp_path / "c", tree, step=3)
        side = json.loads((tmp_path / "c.json").read_text())
        digest = hashlib.sha256((tmp_path / "c.npz").read_bytes()).hexdigest()
        assert side["sha256"] == digest
        restored, step = ckpt.load_checkpoint(tmp_path / "c", tree)
        assert step == 3
        np.testing.assert_array_equal(restored["a"], tree["a"])

    def test_corrupted_payload_is_rejected(self, tmp_path):
        tree = self._tree()
        ckpt.save_checkpoint(tmp_path / "c", tree, step=1)
        payload = tmp_path / "c.npz"
        raw = bytearray(payload.read_bytes())
        raw[len(raw) // 2] ^= 0xFF
        payload.write_bytes(bytes(raw))
        with pytest.raises(ValueError, match="mismatch"):
            ckpt.load_checkpoint(tmp_path / "c", tree)

    def test_missing_sidecar_means_interrupted_save(self, tmp_path):
        tree = self._tree()
        ckpt.save_checkpoint(tmp_path / "c", tree, step=1)
        (tmp_path / "c.json").unlink()
        with pytest.raises(FileNotFoundError, match="sidecar"):
            ckpt.load_checkpoint(tmp_path / "c", tree)

    def test_no_partial_payload_left_behind(self, tmp_path):
        """The payload is staged to a temp name and renamed into place, so
        the directory only ever holds complete payloads."""
        ckpt.save_checkpoint(tmp_path / "c", self._tree(), step=1)
        names = sorted(p.name for p in tmp_path.iterdir())
        assert names == ["c.json", "c.npz"]


class TestCrashResume:
    @pytest.mark.slow
    def test_sigkilled_run_resumes_from_last_checkpoint(self, tmp_path):
        """SIGKILL the chunked scan mid-stream (right after its second
        checkpoint lands); resuming from the surviving checkpoint must
        reproduce the pinned queued golden bit-for-bit."""
        from test_engine_core import GOLDEN_QUEUED_TRACE_HASHES, _sim_queued
        from test_chunked_stream import _queued_hash

        path = tmp_path / "carry"
        code = textwrap.dedent(
            f"""
            import os, signal, sys
            sys.path.insert(0, "src")
            from repro.sim import SimConfig, batched

            cfg = SimConfig(num_gpus=5, offered_load=1.2, seed=7)
            events, meta, rr, rc = batched.presample_arrivals(
                cfg, runs=3, queued=True
            )
            orig = batched.save_stream_checkpoint
            calls = [0]
            def killing_save(path, state, events_done, metadata=None):
                orig(path, state, events_done, metadata=metadata)
                calls[0] += 1
                if calls[0] == 2:
                    os.kill(os.getpid(), signal.SIGKILL)
            batched.save_stream_checkpoint = killing_save
            batched.simulate_chunked(
                events, chunk_size=13, ring_rows=rr, ring_cols=rc,
                policy="mfi", metric=cfg.metric, num_gpus=cfg.num_gpus,
                use_kernel=False, protocol="steady-queued",
                wait_slots=cfg.wait_capacity,
                wait_patience=cfg.wait_patience,
                checkpoint_path={str(path)!r}, checkpoint_every=1,
            )
            print("UNREACHABLE")
            """
        )
        repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
        r = subprocess.run(
            [sys.executable, "-c", code], capture_output=True, text=True,
            timeout=600, cwd=repo,
        )
        assert r.returncode == -9, (r.returncode, r.stderr[-2000:])
        assert "UNREACHABLE" not in r.stdout

        cfg = SimConfig(num_gpus=5, offered_load=1.2, seed=7)
        events, meta, rr, rc = batched.presample_arrivals(
            cfg, runs=3, queued=True
        )
        statics = dict(
            policy="mfi", metric=cfg.metric, num_gpus=cfg.num_gpus,
            use_kernel=False, protocol="steady-queued",
            wait_slots=cfg.wait_capacity, wait_patience=cfg.wait_patience,
        )
        template = batched.init_carry(3, ring_rows=rr, ring_cols=rc, **statics)
        state, done = batched.load_stream_checkpoint(path, template)
        assert done == 26  # second checkpoint: two chunks of 13 events
        _, tail = batched.simulate_chunked(
            events, chunk_size=13, ring_rows=rr, ring_cols=rc,
            carry=state, start=done, **statics,
        )
        _, _, mono, _ = _sim_queued("mfi", cfg)
        head = jax.tree.map(
            lambda x: None if x is None else np.asarray(x)[:done], mono,
            is_leaf=lambda x: x is None,
        )
        spliced = batched._concat_traces(
            [head, jax.device_get(tail)], np.concatenate
        )
        assert _queued_hash(spliced) == GOLDEN_QUEUED_TRACE_HASHES["homog"]

    def test_resume_rejects_corrupted_checkpoint(self, tmp_path):
        cfg = SimConfig(num_gpus=3, offered_load=1.0, seed=1)
        events, meta, rr, rc = batched.presample_arrivals(cfg, runs=2)
        statics = dict(
            policy="mfi", metric=cfg.metric, num_gpus=cfg.num_gpus,
            use_kernel=False, protocol="steady",
        )
        state = batched.init_carry(2, ring_rows=rr, ring_cols=rc, **statics)
        batched.save_stream_checkpoint(tmp_path / "c", state, 0)
        payload = tmp_path / "c.npz"
        raw = bytearray(payload.read_bytes())
        raw[len(raw) // 2] ^= 0xFF
        payload.write_bytes(bytes(raw))
        template = batched.init_carry(2, ring_rows=rr, ring_cols=rc, **statics)
        with pytest.raises(ValueError, match="mismatch"):
            batched.load_stream_checkpoint(tmp_path / "c", template)
