"""Sharding rules + launcher tests.

The multi-device lowering test runs in a SUBPROCESS so the 8-device
XLA_FLAGS override never pollutes the main test process (smoke tests must
see exactly 1 device, per the dry-run contract).
"""

import json
import subprocess
import sys
import textwrap

import pytest

from jax.sharding import PartitionSpec as P

from repro import sharding
from repro.configs import ARCHS, ASSIGNED, LONG_CONTEXT_OK
from repro.launch.shapes import SHAPES, batch_specs, cache_specs
from repro.launch import hlo_analysis
from repro.launch.dryrun import runnable
from repro.models import model


class TestRules:
    def test_constraint_noop_outside_rules(self):
        import jax.numpy as jnp

        x = jnp.ones((4, 4))
        y = sharding.constraint(x, "batch", "ff")
        assert (y == x).all()

    def test_resolve(self):
        with sharding.use_rules({"batch": ("pod", "data"), "ff": "model"}):
            assert sharding.resolve(("batch", None, "ff")) == P(("pod", "data"), None, "model")

    def test_default_rules_head_divisibility(self):
        r = sharding.default_rules(n_heads=32, n_kv_heads=8, model_axis=16)
        assert r["heads"] is None or r["heads"] == "model"
        # 32 % 16 == 0 -> heads sharded; kv 8 % 16 != 0 -> head_dim path
        assert r["heads"] == "model"
        assert r["kv_heads"] is None and r["kv_head_dim"] == "model"

    def test_param_specs_resolve_for_all_archs(self):
        for arch in ASSIGNED:
            cfg = ARCHS[arch]
            with sharding.use_rules(sharding.default_rules(
                n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads)):
                specs = model.param_specs(cfg)
            import jax
            assert all(isinstance(s, P) for s in jax.tree.leaves(
                specs, is_leaf=lambda x: isinstance(x, P)))


class TestShapes:
    def test_four_shapes(self):
        assert set(SHAPES) == {"train_4k", "prefill_32k", "decode_32k", "long_500k"}
        assert SHAPES["long_500k"].seq_len == 524_288

    def test_batch_specs_per_family(self):
        for arch in ("qwen3-14b", "paligemma-3b", "whisper-large-v3"):
            cfg = ARCHS[arch]
            b = batch_specs(cfg, SHAPES["train_4k"], with_labels=True)
            assert "tokens" in b and "labels" in b
            total = b["tokens"].shape[1]
            if cfg.frontend == "vision":
                total += cfg.num_patches
                assert "patches" in b
            if cfg.encdec:
                total += b["frames"].shape[1]
            assert total == 4096  # seq budget preserved

    def test_cache_specs_no_allocation(self):
        import jax

        cfg = ARCHS["gemma3-12b"]
        c = cache_specs(cfg, SHAPES["decode_32k"])
        for leaf in jax.tree.leaves(c):
            assert isinstance(leaf, jax.ShapeDtypeStruct)

    def test_long500k_applicability(self):
        assert not runnable("qwen3-14b", "long_500k")
        assert runnable("mamba2-2.7b", "long_500k")
        assert runnable("gemma3-12b", "long_500k")
        assert runnable("hymba-1.5b", "long_500k")
        for a in ASSIGNED:
            assert runnable(a, "train_4k")


class TestHLOAnalysis:
    def test_shape_bytes(self):
        assert hlo_analysis._shape_bytes("f32[2,3]{1,0}") == 24
        assert hlo_analysis._shape_bytes("bf16[128]") == 256
        assert hlo_analysis._shape_bytes("(f32[2], s32[4])") == 24

    def test_trip_weighted_scan_flops(self):
        """End-to-end: compile a scanned matmul on 8 host devices in a
        subprocess, assert our analysis multiplies by the trip count."""
        code = textwrap.dedent("""
            import os
            os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
            import sys, json
            sys.path.insert(0, "src")
            import jax, jax.numpy as jnp
            from jax.sharding import NamedSharding, PartitionSpec as P
            from repro.launch.hlo_analysis import analyze
            mesh = jax.make_mesh((2, 4), ("data", "model"))
            def f(w, x):
                def body(c, wi):
                    return jnp.tanh(c @ wi), None
                y, _ = jax.lax.scan(body, x, w)
                return y.sum()
            wspec = jax.ShapeDtypeStruct((6, 256, 256), jnp.float32)
            xspec = jax.ShapeDtypeStruct((64, 256), jnp.float32)
            shardings = (NamedSharding(mesh, P(None, "data", "model")),
                         NamedSharding(mesh, P("data", None)))
            comp = jax.jit(f, in_shardings=shardings).lower(wspec, xspec).compile()
            a = analyze(comp.as_text())
            print(json.dumps({"flops": a.flops, "coll": a.collective_bytes}))
        """)
        out = subprocess.run(
            [sys.executable, "-c", code], capture_output=True, text=True, timeout=300
        )
        assert out.returncode == 0, out.stderr[-2000:]
        res = json.loads(out.stdout.strip().splitlines()[-1])
        ideal = 2 * 6 * 64 * 256 * 256 / 8  # per device
        assert res["flops"] == pytest.approx(ideal, rel=0.05)
        assert res["coll"] > 0
