"""Chunked streaming scan driver: bit-exact parity with the monolithic path.

The chunked driver (:func:`repro.sim.batched.simulate_chunked`) must be a
pure performance/memory restructuring — every pinned golden SHA-256 trace
hash and aggregate reproduces *exactly* through it for any chunk size,
including chunk size 1, a divisor of the stream length, and a non-divisor
forcing a ragged final chunk.  The carry holds all cross-event state, so
leases expiring exactly at a chunk boundary and queued wait-admissions
whose arrival and admission land in different chunks must come out
identical; checkpoint/resume through :mod:`repro.checkpoint.ckpt` must
rejoin the monolithic stream bit-for-bit.
"""

import hashlib
import json
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.api import simulate
from repro.sim import SimConfig
from repro.sim import batched, replay

from test_engine_core import (
    GOLDEN_AGGREGATES,
    GOLDEN_CONFIGS,
    GOLDEN_QUEUED_TRACE_HASHES,
    GOLDEN_TRACE_HASHES,
    MIXED,
    _sim,
    _sim_queued,
)

#: (tag -> monolithic golden configuration) for the steady trace hashes
STEADY_GOLDEN = {
    "homog": (lambda: SimConfig(num_gpus=5, offered_load=1.1, seed=7), None, "mfi"),
    "mixed": (
        lambda: SimConfig(cluster_spec=MIXED, offered_load=1.0, seed=9),
        MIXED,
        "mfi",
    ),
}

#: (tag -> monolithic golden configuration) for the queued trace hashes
QUEUED_GOLDEN = {
    "homog": (lambda: SimConfig(num_gpus=5, offered_load=1.2, seed=7), None, "mfi"),
    "mixed": (
        lambda: SimConfig(cluster_spec=MIXED, offered_load=1.1, seed=9),
        MIXED,
        "mfi-queued",
    ),
}


def _statics(cfg, policy, spec=None, protocol="steady"):
    kw = dict(
        policy=policy, metric=cfg.metric, num_gpus=cfg.num_gpus,
        use_kernel=False, protocol=protocol,
    )
    if protocol == "steady-queued":
        kw.update(wait_slots=cfg.wait_capacity, wait_patience=cfg.wait_patience)
    if spec is not None:
        kw.update(
            midx=jnp.asarray(spec.model_index), tables=batched.spec_tables(spec)
        )
    return kw


def _presample(cfg, runs, protocol):
    if protocol == "cumulative":
        return batched.presample_cumulative(cfg, runs=runs)
    return batched.presample_arrivals(
        cfg, runs=runs, queued=(protocol == "steady-queued")
    )


def _chunked(policy, cfg, chunk_size, spec=None, runs=3, protocol="steady", **kw):
    events, meta, rr, rc = _presample(cfg, runs, protocol)
    state, trace = batched.simulate_chunked(
        events, chunk_size=chunk_size, ring_rows=rr, ring_cols=rc,
        **_statics(cfg, policy, spec, protocol), **kw,
    )
    return events, meta, jax.device_get(trace), jax.device_get(state)


def _chunk_sizes(e_max):
    """(1, a divisor of the stream length, a non-divisor → ragged last chunk)."""
    div = next((d for d in range(2, e_max) if e_max % d == 0), e_max)
    ragged = next(c for c in range(max(2, e_max // 3), e_max) if e_max % c)
    return 1, div, ragged


def _steady_hash(trace):
    h = hashlib.sha256()
    for a in (
        trace.ok, trace.gpu, trace.aidx, trace.free_sum, trace.active,
        trace.frag,
    ):
        h.update(np.ascontiguousarray(np.asarray(a)).tobytes())
    return h.hexdigest()


def _queued_hash(trace):
    h = hashlib.sha256()
    for a in (
        trace.ok, trace.gpu, trace.aidx, trace.parked, trace.wadm_eidx,
        trace.wadm_gpu, trace.wadm_aidx, trace.free_sum, trace.active,
        trace.frag,
    ):
        h.update(np.ascontiguousarray(np.asarray(a)).tobytes())
    return h.hexdigest()


def _assert_traces_equal(a, b):
    for name in type(a)._fields:
        fa, fb = getattr(a, name), getattr(b, name)
        assert (fa is None) == (fb is None), name
        if fa is not None:
            np.testing.assert_array_equal(
                np.asarray(fa), np.asarray(fb), err_msg=name
            )


# ---------------------------------------------------------------------------
# Golden parity: every pinned hash/aggregate through the chunked path
# ---------------------------------------------------------------------------


class TestChunkedGoldenParity:
    @pytest.mark.parametrize("tag", sorted(STEADY_GOLDEN))
    def test_steady_trace_hashes_all_chunk_sizes(self, tag):
        cfg_fn, spec, policy = STEADY_GOLDEN[tag]
        e_max = _presample(cfg_fn(), 3, "steady")[0].pid.shape[0]
        for cs in _chunk_sizes(e_max):
            _, _, trace, _ = _chunked(policy, cfg_fn(), cs, spec)
            assert _steady_hash(trace) == GOLDEN_TRACE_HASHES[tag], (
                f"{tag}: chunk_size={cs} drifted from the monolithic golden"
            )

    @pytest.mark.parametrize("tag", sorted(QUEUED_GOLDEN))
    def test_queued_trace_hashes_all_chunk_sizes(self, tag):
        cfg_fn, spec, policy = QUEUED_GOLDEN[tag]
        e_max = _presample(cfg_fn(), 3, "steady-queued")[0].pid.shape[0]
        for cs in _chunk_sizes(e_max):
            _, _, trace, _ = _chunked(
                policy, cfg_fn(), cs, spec, protocol="steady-queued"
            )
            assert _queued_hash(trace) == GOLDEN_QUEUED_TRACE_HASHES[tag], (
                f"{tag}: chunk_size={cs} drifted from the queued golden"
            )

    @pytest.mark.parametrize("tag,policy", sorted(GOLDEN_AGGREGATES))
    def test_golden_aggregates_through_chunked_run_batched(self, tag, policy):
        r = batched.run_batched(
            policy, GOLDEN_CONFIGS[tag](), runs=4, chunk_size=23
        )
        for key, want in GOLDEN_AGGREGATES[(tag, policy)].items():
            assert r[key] == want, f"{tag}/{policy}/{key}: {r[key]!r} != {want!r}"

    def test_cumulative_chunked_matches_monolithic(self):
        cfg = SimConfig(num_gpus=4, offered_load=1.0, seed=3)
        _, _, mono, final = _sim("mfi", cfg, runs=2, protocol="cumulative")
        _, _, trace, state = _chunked(
            "mfi", cfg, 17, runs=2, protocol="cumulative"
        )
        _assert_traces_equal(trace, mono)
        for fa, fb in zip(jax.tree.leaves(state), jax.tree.leaves(final)):
            np.testing.assert_array_equal(np.asarray(fa), np.asarray(fb))

    def test_defrag_chunked_matches_monolithic(self):
        cfg = SimConfig(num_gpus=5, offered_load=1.1, seed=7)
        _, _, mono, _ = _sim("mfi-defrag", cfg, runs=2)
        _, _, trace, _ = _chunked("mfi-defrag", cfg, 11, runs=2)
        _assert_traces_equal(trace, mono)

    def test_stream_false_keeps_device_trace_identical(self):
        cfg = SimConfig(num_gpus=5, offered_load=1.1, seed=7)
        _, _, streamed, _ = _chunked("mfi", cfg, 13)
        _, _, resident, _ = _chunked("mfi", cfg, 13, stream=False)
        _assert_traces_equal(streamed, resident)


# ---------------------------------------------------------------------------
# Chunk-boundary semantics: state that must survive the cut
# ---------------------------------------------------------------------------


class TestChunkBoundarySemantics:
    def test_lease_expiring_exactly_at_boundary(self):
        """Cut the stream exactly where a lease expires: the expiry ring
        rides the carry, so the drain on the boundary event must behave as
        if the scan never stopped."""
        cfg = SimConfig(num_gpus=5, offered_load=1.1, seed=7)
        _, _, mono, _ = _sim("mfi", cfg, runs=3)
        active = np.asarray(mono.active)[:, 0]
        drops = np.nonzero(np.diff(active) < 0)[0] + 1  # expiry fired here
        assert drops.size, "stream exercised no expiries"
        boundary = int(drops[drops > 1][0])
        _, _, trace, _ = _chunked("mfi", cfg, boundary)
        _assert_traces_equal(trace, mono)
        assert _steady_hash(trace) == GOLDEN_TRACE_HASHES["homog"]

    def test_wait_admission_spanning_chunks(self):
        """A request parked in chunk k and admitted from the wait ring in a
        later chunk: the ring (pids, deadlines, priorities) crosses the
        boundary inside the carry."""
        cfg = SimConfig(num_gpus=5, offered_load=1.2, seed=7)
        _, _, mono, _ = _sim_queued("mfi", cfg)
        wadm = np.asarray(mono.wadm_eidx)
        adm_evt, adm_run = np.nonzero(wadm >= 0)
        assert adm_evt.size, "stream exercised no wait admissions"
        arrivals = wadm[adm_evt, adm_run]
        span = adm_evt > arrivals  # parked strictly before the admitting event
        assert span.any(), "no admission separable from its arrival"
        e, a = int(adm_evt[span][0]), int(arrivals[span][0])
        boundary = a + 1  # arrival lands in chunk 0, admission in a later one
        assert boundary <= e
        _, _, trace, _ = _chunked(
            "mfi", cfg, boundary, protocol="steady-queued"
        )
        _assert_traces_equal(trace, mono)
        assert _queued_hash(trace) == GOLDEN_QUEUED_TRACE_HASHES["homog"]


# ---------------------------------------------------------------------------
# Checkpoint / resume
# ---------------------------------------------------------------------------


class TestCheckpointResume:
    def test_resume_reproduces_queued_golden_bit_for_bit(self, tmp_path):
        """Checkpoint mid-run, restore into a fresh template, resume the
        tail, splice onto the monolithic head: the pinned golden hash must
        come out unchanged."""
        cfg = SimConfig(num_gpus=5, offered_load=1.2, seed=7)
        events, meta, rr, rc = _presample(cfg, 3, "steady-queued")
        e_max = events.pid.shape[0]
        statics = _statics(cfg, "mfi", protocol="steady-queued")
        path = tmp_path / "carry"
        cs = 13
        batched.simulate_chunked(
            events, chunk_size=cs, ring_rows=rr, ring_cols=rc,
            checkpoint_path=path, checkpoint_every=3, **statics,
        )
        template = batched.init_carry(3, ring_rows=rr, ring_cols=rc, **statics)
        state, done = batched.load_stream_checkpoint(path, template)
        assert 0 < done < e_max, "checkpoint did not land mid-stream"
        assert done % (3 * cs) == 0
        _, tail = batched.simulate_chunked(
            events, chunk_size=cs, ring_rows=rr, ring_cols=rc,
            carry=state, start=done, **statics,
        )
        _, _, mono, _ = _sim_queued("mfi", cfg)
        head = jax.tree.map(
            lambda x: None if x is None else np.asarray(x)[:done], mono,
            is_leaf=lambda x: x is None,
        )
        spliced = batched._concat_traces([head, jax.device_get(tail)],
                                         np.concatenate)
        assert _queued_hash(spliced) == GOLDEN_QUEUED_TRACE_HASHES["homog"]

    def test_checkpoint_metadata_records_events_done(self, tmp_path):
        cfg = SimConfig(num_gpus=5, offered_load=1.1, seed=7)
        events, meta, rr, rc = _presample(cfg, 2, "steady")
        statics = _statics(cfg, "mfi")
        state, _ = batched.simulate_chunked(
            events, chunk_size=events.pid.shape[0], ring_rows=rr,
            ring_cols=rc, **statics,
        )
        batched.save_stream_checkpoint(
            tmp_path / "c", state, 42, metadata={"seed": cfg.seed}
        )
        side = json.loads((tmp_path / "c.json").read_text())
        assert side["step"] == 42
        assert side["kind"] == "replica-carry"  # merged into the sidecar
        assert side["seed"] == cfg.seed

    def test_restore_rejects_mismatched_template(self, tmp_path):
        """A carry from one configuration must not restore into another:
        the flat-npz validation catches structure/shape drift loudly."""
        cfg = SimConfig(num_gpus=5, offered_load=1.2, seed=7)
        events, meta, rr, rc = _presample(cfg, 3, "steady-queued")
        statics = _statics(cfg, "mfi", protocol="steady-queued")
        state = batched.init_carry(3, ring_rows=rr, ring_cols=rc, **statics)
        batched.save_stream_checkpoint(tmp_path / "c", state, 0)
        wrong = batched.init_carry(
            3, ring_rows=rr, ring_cols=rc,
            **_statics(cfg, "mfi", protocol="steady"),
        )
        with pytest.raises(ValueError, match="mismatch"):
            batched.load_stream_checkpoint(tmp_path / "c", wrong)


# ---------------------------------------------------------------------------
# Replay validation over chunked traces
# ---------------------------------------------------------------------------


class TestChunkedReplayValidation:
    def test_steady_chunked_trace_passes_replay(self):
        cfg = SimConfig(num_gpus=5, offered_load=1.1, seed=7)
        events, meta, trace, _ = _chunked("mfi", cfg, 49)
        replay.replay(events, meta, trace, cfg.num_gpus)

    def test_queued_chunked_trace_passes_replay_and_drains(self):
        cfg = SimConfig(num_gpus=5, offered_load=1.2, seed=7)
        events, meta, trace, _ = _chunked(
            "mfi", cfg, 31, protocol="steady-queued"
        )
        replay.replay(events, meta, trace, cfg.num_gpus)
        _, drained = replay.drain_all(events, meta, trace, cfg.num_gpus)
        assert (drained == 0).all()


# ---------------------------------------------------------------------------
# Error paths
# ---------------------------------------------------------------------------


class TestChunkedErrorPaths:
    def _stream(self):
        cfg = SimConfig(num_gpus=3, offered_load=1.0, seed=1)
        events, meta, rr, rc = _presample(cfg, 2, "steady")
        return cfg, events, rr, rc

    def test_rejects_nonpositive_chunk_size(self):
        cfg, events, rr, rc = self._stream()
        with pytest.raises(ValueError, match="chunk_size"):
            batched.simulate_chunked(
                events, chunk_size=0, ring_rows=rr, ring_cols=rc,
                **_statics(cfg, "mfi"),
            )

    def test_rejects_start_outside_stream(self):
        cfg, events, rr, rc = self._stream()
        e_max = events.pid.shape[0]
        for start in (-1, e_max):
            with pytest.raises(ValueError, match="start"):
                batched.simulate_chunked(
                    events, chunk_size=8, ring_rows=rr, ring_cols=rc,
                    start=start, **_statics(cfg, "mfi"),
                )

    def test_rejects_carry_ring_geometry_mismatch(self):
        cfg, events, rr, rc = self._stream()
        statics = _statics(cfg, "mfi")
        bad = batched.init_carry(2, ring_rows=rr + 1, ring_cols=rc, **statics)
        with pytest.raises(ValueError, match="ring geometry"):
            batched.simulate_chunked(
                events, chunk_size=8, ring_rows=rr, ring_cols=rc,
                carry=bad, **statics,
            )

    def test_run_batched_rejects_stream_knobs_without_chunk_size(self):
        cfg = SimConfig(num_gpus=3, offered_load=1.0, seed=1)
        with pytest.raises(ValueError, match="chunk_size"):
            batched.run_batched("mfi", cfg, runs=2, stream=True)
        with pytest.raises(ValueError, match="chunk_size"):
            batched.run_batched("mfi", cfg, runs=2, stats={})

    def test_api_python_engine_rejects_chunk_size(self):
        with pytest.raises(ValueError, match="batched"):
            simulate(
                "mfi", engine="python", runs=1, num_gpus=3,
                offered_load=1.0, seed=1, chunk_size=8,
            )


# ---------------------------------------------------------------------------
# shard_events no-copy fix (multi-device, subprocess)
# ---------------------------------------------------------------------------


class TestShardEventsNoCopy:
    @pytest.mark.slow
    def test_resharding_already_placed_events_is_a_no_op(self):
        """``shard_events`` on a stream already committed to the replica
        mesh must return the *same* buffers, not re-run ``device_put``."""
        code = textwrap.dedent(
            """
            import os
            os.environ["XLA_FLAGS"] = (
                os.environ.get("XLA_FLAGS", "")
                + " --xla_force_host_platform_device_count=8"
            )
            import json
            import sys
            sys.path.insert(0, "src")
            import jax
            from repro.sim import SimConfig, batched

            cfg = SimConfig(num_gpus=4, offered_load=1.0, seed=0)
            events, *_ = batched.presample_arrivals(cfg, runs=8)
            ev1 = batched.shard_events(events, 8, shard=True)
            ev2 = batched.shard_events(ev1, 8, shard=True)
            l1 = [x for x in jax.tree.leaves(ev1)]
            l2 = [x for x in jax.tree.leaves(ev2)]
            # the chunked driver composes with the replica mesh: every
            # staged chunk is placed on it, results stay bitwise identical
            r_chunked = batched.run_batched(
                "mfi", cfg, runs=8, shard=True, chunk_size=19
            )
            r_plain = batched.run_batched("mfi", cfg, runs=8, shard=False)
            keys = ("acceptance_rate", "utilization", "frag_severity")
            print(json.dumps({
                "same_buffers": all(a is b for a, b in zip(l1, l2)),
                "committed": all(x.committed for x in l1),
                "num_leaves": len(l1),
                "chunked_sharded": {k: r_chunked[k] for k in keys},
                "plain": {k: r_plain[k] for k in keys},
            }))
            """
        )
        repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
        r = subprocess.run(
            [sys.executable, "-c", code], capture_output=True, text=True,
            timeout=600, cwd=repo,
        )
        assert r.returncode == 0, r.stderr[-2000:]
        out = json.loads(r.stdout.strip().splitlines()[-1])
        assert out["committed"], "sharded events not committed to the mesh"
        assert out["same_buffers"], (
            "shard_events re-ran device_put on already-placed events"
        )
        assert out["num_leaves"] > 0
        assert out["chunked_sharded"] == out["plain"]
