"""Tests for the fragmentation metric (Algorithm 1) incl. the paper's worked example.

Hypothesis property tests live in ``test_hypothesis_properties.py`` (skip-
guarded) so this module collects without the optional dev dependency.
"""

import numpy as np
import pytest

from repro.core import cluster as jcluster
from repro.core import fragmentation, mig

import jax.numpy as jnp

PID = {name: i for i, name in enumerate(mig.PROFILE_NAMES)}


def _occ(*slices):
    x = np.zeros(mig.NUM_MEM_SLICES, dtype=np.int32)
    for s in slices:
        x[s] = 1
    return x


class TestPaperWorkedExample:
    """Fig. 3a: GPU2 = {2g.20gb@0, 1g.10gb@5} -> F=16; GPU1 = {2g.20gb@2} -> F=8.

    The paper's stated arithmetic (16 = 2+2+8+4 over profiles 1g.20gb, 2g.20gb,
    3g.40gb, 4g.40gb) is reproduced by the "partial" variant (DESIGN.md §1.1).
    """

    def test_gpu2_partial(self):
        g = mig.GPUState()
        g.allocate(1, PID["2g.20gb"], 0)
        g.allocate(2, PID["1g.10gb"], 5)
        assert fragmentation.fragmentation_score(g, "partial") == 16.0

    def test_gpu1_partial(self):
        g = mig.GPUState()
        g.allocate(1, PID["2g.20gb"], 2)
        assert fragmentation.fragmentation_score(g, "partial") == 8.0

    def test_gpu2_more_fragmented_than_gpu1_both_variants(self):
        g2 = mig.GPUState()
        g2.allocate(1, PID["2g.20gb"], 0)
        g2.allocate(2, PID["1g.10gb"], 5)
        g1 = mig.GPUState()
        g1.allocate(1, PID["2g.20gb"], 2)
        for metric in fragmentation.METRIC_VARIANTS:
            assert fragmentation.fragmentation_score(
                g2, metric
            ) > fragmentation.fragmentation_score(g1, metric)


class TestFragmentationProperties:
    def test_empty_gpu_zero(self):
        for metric in fragmentation.METRIC_VARIANTS:
            assert fragmentation.fragmentation_score(_occ(), metric) == 0.0

    def test_full_gpu_zero(self):
        occ = np.ones(8, dtype=np.int32)
        for metric in fragmentation.METRIC_VARIANTS:
            assert fragmentation.fragmentation_score(occ, metric) == 0.0

    def test_misplaced_1g_blocks_4g(self):
        """Paper: 1g.10gb at index 1 prevents 4g.40gb -> positive score."""
        occ = _occ(1)
        for metric in fragmentation.METRIC_VARIANTS:
            assert fragmentation.fragmentation_score(occ, metric) > 0

    def test_blocked_geq_partial(self):
        """Every partial window is also blocked."""
        rng = np.random.default_rng(0)
        occ = (rng.random((256, 8)) < 0.4).astype(np.int32)
        b = fragmentation.fragmentation_scores(occ, "blocked")
        p = fragmentation.fragmentation_scores(occ, "partial")
        assert (b >= p).all()

    def test_eligibility_gate(self):
        """Profiles larger than the free-slice count don't contribute."""
        # 7 of 8 slices used -> only 1g.10gb eligible; its windows are size-1
        # (never partial), and all occupied -> blocked counts 7.
        occ = _occ(0, 1, 2, 3, 4, 5, 6)
        assert fragmentation.fragmentation_score(occ, "partial") == 0.0
        assert fragmentation.fragmentation_score(occ, "blocked") == 7.0

    def test_empty_gpu_defence_term(self):
        """One occupied slice keeps 7g eligible (mem=7 <= ΔS=7): the broken
        7g window is the empty-GPU defence (DESIGN.md §1.2)."""
        occ = _occ(6)
        s = fragmentation.fragmentation_score(occ, "blocked")
        assert s >= 7.0

    def test_jnp_matches_numpy_exhaustive(self):
        """All 256 bitmaps: the jitted scorer equals the numpy reference."""
        occ = np.array([[int(b) for b in f"{i:08b}"] for i in range(256)], np.int32)
        for metric in fragmentation.METRIC_VARIANTS:
            ref = fragmentation.fragmentation_scores(occ, metric)
            got = np.asarray(jcluster.frag_scores(jnp.asarray(occ), metric))
            np.testing.assert_allclose(got, ref)

    def test_nonnegative_and_bounded_exhaustive(self):
        occ = np.array([[int(b) for b in f"{i:08b}"] for i in range(256)], np.int32)
        for metric in fragmentation.METRIC_VARIANTS:
            f = fragmentation.fragmentation_scores(occ, metric)
            assert (f >= 0).all() and (f <= mig.PLACEMENT_MEM.sum()).all()


class TestDeltaF:
    def test_delta_matches_difference(self):
        occ = _occ(0, 1)
        d = fragmentation.delta_f(occ, PID["2g.20gb"], 2, "blocked")
        before = fragmentation.fragmentation_score(occ, "blocked")
        occ2 = _occ(0, 1, 2, 3)
        after = fragmentation.fragmentation_score(occ2, "blocked")
        assert d == after - before

    def test_infeasible_raises(self):
        occ = _occ(0)
        with pytest.raises(ValueError):
            fragmentation.delta_f(occ, PID["4g.40gb"], 0)
