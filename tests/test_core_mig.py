"""Unit tests for the MIG hardware model (paper Table I semantics)."""

import numpy as np
import pytest

from repro.core import mig


PID = {name: i for i, name in enumerate(mig.PROFILE_NAMES)}


class TestProfiles:
    def test_table_i(self):
        spec = {
            "7g.80gb": (7, 7, (0,)),
            "4g.40gb": (4, 4, (0,)),
            "3g.40gb": (3, 4, (0, 4)),
            "2g.20gb": (2, 2, (0, 2, 4)),
            "1g.20gb": (1, 2, (0, 2, 4, 6)),
            "1g.10gb": (1, 1, (0, 1, 2, 3, 4, 5, 6)),
        }
        for name, (comp, mem, anchors) in spec.items():
            p = mig.PROFILE_BY_NAME[name]
            assert p.compute == comp
            assert p.mem == mem
            assert p.anchors == anchors

    def test_placement_table_has_18_rows(self):
        assert mig.NUM_PLACEMENTS == 18
        assert mig.PLACEMENT_MASKS.shape == (18, 8)
        # each mask is a contiguous run of `mem` ones
        for r in range(18):
            mask = mig.PLACEMENT_MASKS[r]
            mem = mig.PLACEMENT_MEM[r]
            anchor = mig.PLACEMENT_ANCHOR[r]
            assert mask.sum() == mem
            assert (mask[anchor : anchor + mem] == 1).all()

    def test_windows_stay_in_bounds(self):
        for p in mig.PROFILES:
            for a in p.anchors:
                assert a + p.mem <= mig.NUM_MEM_SLICES


class TestGPUState:
    def test_allocate_release_roundtrip(self):
        g = mig.GPUState()
        g.allocate(1, PID["3g.40gb"], 4)
        assert g.occupancy.tolist() == [0, 0, 0, 0, 1, 1, 1, 1]
        assert g.free_slices == 4
        assert g.used_compute_slices == 3
        g.release(1)
        assert g.occupancy.sum() == 0

    def test_illegal_anchor_rejected(self):
        g = mig.GPUState()
        with pytest.raises(ValueError, match="illegal"):
            g.allocate(1, PID["4g.40gb"], 4)  # 4g only anchors at 0

    def test_overlap_rejected(self):
        g = mig.GPUState()
        g.allocate(1, PID["2g.20gb"], 2)
        with pytest.raises(ValueError, match="overlaps"):
            g.allocate(2, PID["4g.40gb"], 0)

    def test_two_3g_coexist(self):
        """Real-MIG property: two 3g.40gb instances fit one GPU."""
        g = mig.GPUState()
        g.allocate(1, PID["3g.40gb"], 0)
        g.allocate(2, PID["3g.40gb"], 4)
        assert g.free_slices == 0

    def test_seven_1g_saturate_compute(self):
        g = mig.GPUState()
        for i in range(7):
            g.allocate(i, PID["1g.10gb"], i)
        assert g.used_compute_slices == 7
        assert g.feasible_anchors(PID["1g.10gb"]) == []

    def test_7g_excludes_everything(self):
        g = mig.GPUState()
        g.allocate(1, PID["7g.80gb"], 0)
        for name, pid in PID.items():
            assert not g.can_fit(pid), name

    def test_4g_plus_3g_fit(self):
        g = mig.GPUState()
        g.allocate(1, PID["4g.40gb"], 0)
        assert g.feasible_anchors(PID["3g.40gb"]) == [4]
        g.allocate(2, PID["3g.40gb"], 4)
        assert g.free_slices == 0


class TestClusterState:
    def test_metrics(self):
        cl = mig.ClusterState(4)
        cl.allocate(1, PID["2g.20gb"], 0, 0)
        cl.allocate(2, PID["1g.10gb"], 2, 3)
        assert cl.active_gpus == 2
        assert cl.used_mem_slices == 3
        assert cl.used_compute_slices == 3
        cl.release(1)
        assert cl.active_gpus == 1
        assert cl.gpu_of(1) is None
        assert cl.gpu_of(2) == 2

    def test_occupancy_matrix(self):
        cl = mig.ClusterState(2)
        cl.allocate(1, PID["1g.20gb"], 1, 6)
        occ = cl.occupancy_matrix()
        assert occ.shape == (2, 8)
        assert occ[0].sum() == 0
        assert occ[1].tolist() == [0, 0, 0, 0, 0, 0, 1, 1]
