"""Parity tests: the batched JAX engine vs the Python reference simulator.

Two layers of guarantees (see ``docs/SIMULATOR.md``):

* **exact** — single-step placement decisions of every batched-capable
  registered policy match their host-compiled ``Scheduler.select``
  counterparts on arbitrary occupancy matrices (including full-cluster
  rejects);
* **statistical** — whole-run aggregates agree within Monte-Carlo
  tolerance (the engines consume their RNG streams differently).

Parametrization is **registry-driven** (``list_policies(engine="batched")``
— both compilers consume the same ``PolicySpec``), so registering a new
policy extends this coverage automatically; see ``test_policy_api.py`` for
the in-test custom-registration demonstration.

Plus deterministic trajectory-invariant checks via the host replay
(:mod:`repro.sim.replay`); the hypothesis-driven variants live in
``test_batched_invariants.py``.
"""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import mig, schedulers
from repro.core.policy import list_policies
from repro.core.schedulers import make_scheduler
from repro.sim import SimConfig, run_many
from repro.sim import batched, replay

PID = {name: i for i, name in enumerate(mig.PROFILE_NAMES)}

#: every registered batched-capable policy, compiled for the host engine
#: through the same registry the batched lowering reads
BATCHED_POLICIES = list_policies(engine="batched")


def _random_cluster(rng, m):
    """A cluster with random legal allocations (possibly empty or full)."""
    cl = mig.ClusterState(m)
    density = rng.random() * 1.2
    wid = 0
    for g in range(m):
        for pid in rng.permutation(mig.NUM_PROFILES):
            if rng.random() < density:
                anchors = cl.gpus[g].feasible_anchors(int(pid))
                if anchors:
                    cl.allocate(wid, int(pid), g, int(rng.choice(anchors)))
                    wid += 1
    return cl


class TestSingleStepParity:
    """(b) decisions match Scheduler.select exactly, incl. rejects."""

    @pytest.mark.slow
    def test_randomized_decisions_match_python(self):
        from repro.core.policy import resolve
        from repro.core.schedulers import MFIDefrag

        rng = np.random.default_rng(7)
        checked = 0
        for _ in range(220):
            m = int(rng.integers(1, 12))
            cl = _random_cluster(rng, m)
            occ = cl.occupancy_matrix()
            pid = int(rng.integers(0, mig.NUM_PROFILES))
            workloads = [
                (g.gpu_id, a.profile_id, a.anchor)
                for g in cl.gpus
                for a in g.allocations.values()
            ]
            for name in BATCHED_POLICIES:
                pspec = resolve(name)
                sched = (
                    MFIDefrag(spec=pspec, max_candidates=None)
                    if pspec.defrag
                    else make_scheduler(name)
                )
                ref = sched.select(cl, pid)
                g, a, ok = batched.policy_select(
                    jnp.asarray(occ), jnp.int32(pid), name, workloads=workloads
                )
                got = (int(g), int(a)) if bool(ok) else None
                assert got == ref, (
                    f"{name}: pid={pid} python={ref} batched={got}\n{occ}"
                )
                checked += 1
        assert checked >= 200 * len(BATCHED_POLICIES)

    @pytest.mark.parametrize("policy", BATCHED_POLICIES)
    def test_full_cluster_rejects(self, policy):
        occ = jnp.ones((3, mig.NUM_MEM_SLICES), jnp.int32)
        for pid in range(mig.NUM_PROFILES):
            g, a, ok = batched.policy_select(occ, jnp.int32(pid), policy)
            assert not bool(ok) and int(g) == -1 and int(a) == -1

    @pytest.mark.parametrize("policy", BATCHED_POLICIES)
    def test_empty_cluster_accepts_everything(self, policy):
        occ = jnp.zeros((3, mig.NUM_MEM_SLICES), jnp.int32)
        for pid in range(mig.NUM_PROFILES):
            cl = mig.ClusterState(3)
            ref = make_scheduler(policy).select(cl, pid)
            g, a, ok = batched.policy_select(occ, jnp.int32(pid), policy)
            assert bool(ok) and (int(g), int(a)) == ref

    def test_partial_metric_decisions_match_python(self):
        rng = np.random.default_rng(11)
        for _ in range(40):
            cl = _random_cluster(rng, int(rng.integers(1, 8)))
            occ = cl.occupancy_matrix()
            pid = int(rng.integers(0, mig.NUM_PROFILES))
            ref = make_scheduler("mfi", metric="partial").select(cl, pid)
            g, a, ok = batched.policy_select(
                jnp.asarray(occ), jnp.int32(pid), "mfi", metric="partial"
            )
            got = (int(g), int(a)) if bool(ok) else None
            assert got == ref


class TestAggregateParity:
    """(a) whole-run aggregates agree within Monte-Carlo tolerance."""

    RUNS = 24

    @pytest.mark.slow
    @pytest.mark.parametrize("policy", BATCHED_POLICIES)
    def test_acceptance_rate_m8(self, policy):
        cfg = SimConfig(num_gpus=8, offered_load=0.85, seed=0)
        rb = batched.run_batched(policy, cfg, runs=self.RUNS)
        rp = run_many(policy, cfg, runs=self.RUNS)
        # per-run acceptance std at M=8 is ~0.05 -> 3 sigma of the
        # difference of two 24-run means is ~0.06
        assert abs(rb["acceptance_rate"] - rp["acceptance_rate"]) < 0.06, (
            f"{policy}: batched={rb['acceptance_rate']:.4f} "
            f"python={rp['acceptance_rate']:.4f}"
        )
        assert abs(rb["utilization"] - rp["utilization"]) < 0.08
        assert abs(rb["active_gpus"] - rp["active_gpus"]) < 1.0

    def test_aggregate_keys_match_run_many(self):
        cfg = SimConfig(num_gpus=4, offered_load=0.7, seed=1)
        rb = batched.run_batched("mfi", cfg, runs=2)
        rp = run_many("mfi", cfg, runs=2)
        assert set(rb) == set(rp)
        assert rb["arrivals_by_profile"].shape == (mig.NUM_PROFILES,)
        total = rb["arrivals_by_profile"].sum()
        accepted_plus_rejected = (
            rb["allocated_workloads"] + rb["rejects_by_profile"].sum()
        )
        np.testing.assert_allclose(total, accepted_plus_rejected)


class TestTrajectoryInvariants:
    """Deterministic replay checks; hypothesis variants in
    test_batched_invariants.py."""

    @pytest.mark.parametrize("policy", BATCHED_POLICIES)
    def test_replay_validates_and_matches_final_state(self, policy):
        cfg = SimConfig(num_gpus=4, offered_load=1.1, seed=3)
        events, meta, rr, rc = batched.presample_arrivals(cfg, runs=3)
        final, trace = jax.device_get(
            batched._simulate(
                jax.tree.map(jnp.asarray, events),
                policy=policy,
                metric=cfg.metric,
                num_gpus=cfg.num_gpus,
                ring_rows=rr,
                ring_cols=rc,
                use_kernel=False,
            )
        )
        # replay asserts: legal anchors, no double-booking, exact releases
        occ = replay.replay(events, meta, trace, cfg.num_gpus)
        # device state must equal the independently reconstructed occupancy
        w = np.asarray(mig.PLACEMENT_MASKS, np.float32)
        np.testing.assert_allclose(final.base, occ.astype(np.float32) @ w.T)
        np.testing.assert_array_equal(
            final.free, mig.NUM_MEM_SLICES - occ.sum(axis=-1)
        )

    def test_drain_all_restores_empty_cluster(self):
        cfg = SimConfig(num_gpus=4, offered_load=0.9, seed=5)
        events, meta, rr, rc = batched.presample_arrivals(cfg, runs=2)
        _, trace = jax.device_get(
            batched._simulate(
                jax.tree.map(jnp.asarray, events),
                policy="mfi",
                metric=cfg.metric,
                num_gpus=cfg.num_gpus,
                ring_rows=rr,
                ring_cols=rc,
                use_kernel=False,
            )
        )
        _, drained = replay.drain_all(events, meta, trace, cfg.num_gpus)
        np.testing.assert_array_equal(drained, 0)


class TestAPI:
    def test_unknown_policy_raises(self):
        from repro.core.policy import PolicySpec

        # registry's single validation path: unknown names list every
        # registered policy with its engine support...
        with pytest.raises(ValueError, match=r"unknown policy 'nope'.*mfi \(python\+batched\)"):
            batched.run_batched("nope", SimConfig(num_gpus=2), runs=1)
        # ...and engine-restricted specs name the engines that do support them
        host_only = PolicySpec(
            name="host-only", keys=("gpu", "anchor"), engines=("python",)
        )
        with pytest.raises(
            ValueError,
            match=r"'host-only' is not supported by the 'batched' engine",
        ):
            batched.run_batched(host_only, SimConfig(num_gpus=2), runs=1)

    def test_rr_cursor_advances_like_python(self):
        """RR is stateful: the cursor carried through consecutive decisions
        must track the Python scheduler's ``_next`` exactly."""
        cl = mig.ClusterState(3)
        rr = schedulers.RoundRobin()
        cursor = 0
        for step in range(5):
            ref = rr.select(cl, PID["1g.10gb"])
            occ = jnp.asarray(cl.occupancy_matrix())
            g, a, ok = batched.policy_select(
                occ, jnp.int32(PID["1g.10gb"]), "rr", cursor=cursor
            )
            got = (int(g), int(a)) if bool(ok) else None
            assert got == ref
            if ref is not None:
                cl.allocate(100 + step, PID["1g.10gb"], *ref)
                cursor = (ref[0] + 1) % cl.num_gpus
            assert cursor == rr._next

    def test_unknown_protocol_raises(self):
        cfg = SimConfig(num_gpus=2, protocol="bursty")
        with pytest.raises(ValueError, match="unknown protocol"):
            batched.run_batched("mfi", cfg, runs=1)

    def test_deterministic_given_seed(self):
        cfg = SimConfig(num_gpus=4, offered_load=0.8, seed=9)
        r1 = batched.run_batched("ff", cfg, runs=2)
        r2 = batched.run_batched("ff", cfg, runs=2)
        assert r1["acceptance_rate"] == r2["acceptance_rate"]
        assert r1["frag_severity"] == r2["frag_severity"]
