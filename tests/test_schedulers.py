"""Tests for MFI (Algorithm 2) and the baseline schedulers.

Hypothesis property tests live in ``test_hypothesis_properties.py`` (skip-
guarded) so this module collects without the optional dev dependency.
"""

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import cluster as jcluster
from repro.core import fragmentation, mig, schedulers

PID = {name: i for i, name in enumerate(mig.PROFILE_NAMES)}


def _cluster_with(allocs, n=4):
    cl = mig.ClusterState(n)
    for wid, (pid, gpu, anchor) in enumerate(allocs):
        cl.allocate(1000 + wid, pid, gpu, anchor)
    return cl


class TestBaselines:
    def test_ff_picks_first_gpu_first_index(self):
        cl = _cluster_with([(PID["7g.80gb"], 0, 0)])
        sel = schedulers.FirstFit().select(cl, PID["1g.10gb"])
        assert sel == (1, 0)

    def test_rr_advances(self):
        cl = mig.ClusterState(3)
        rr = schedulers.RoundRobin()
        assert rr.select(cl, PID["1g.10gb"]) == (0, 0)
        cl.allocate(1, PID["1g.10gb"], 0, 0)
        assert rr.select(cl, PID["1g.10gb"]) == (1, 0)
        cl.allocate(2, PID["1g.10gb"], 1, 0)
        assert rr.select(cl, PID["1g.10gb"]) == (2, 0)

    def test_bf_picks_tightest_gpu_best_index(self):
        # GPU0 empty; GPU1 has 4 slices used -> BF should pick GPU1, and the
        # best-index policy places 1g.10gb at the highest feasible anchor.
        cl = _cluster_with([(PID["4g.40gb"], 1, 0)])
        sel = schedulers.BestFitBestIndex().select(cl, PID["1g.10gb"])
        assert sel == (1, 6)

    def test_wf_picks_emptiest_gpu(self):
        cl = _cluster_with([(PID["4g.40gb"], 1, 0)])
        sel = schedulers.WorstFitBestIndex().select(cl, PID["1g.10gb"])
        assert sel == (0, 6)

    def test_best_index_reserves_index0_for_4g(self):
        """Paper §VI: 1g.10gb goes to index 6 rather than 0."""
        cl = mig.ClusterState(1)
        sel = schedulers.BestFitBestIndex().select(cl, PID["1g.10gb"])
        assert sel == (0, 6)

    def test_reject_when_full(self):
        cl = _cluster_with([(PID["7g.80gb"], g, 0) for g in range(4)])
        for name in schedulers.SCHEDULERS:
            s = schedulers.make_scheduler(name)
            assert s.select(cl, PID["1g.10gb"]) is None


class TestMFI:
    def test_accepts_when_feasible(self):
        cl = mig.ClusterState(2)
        sel = schedulers.MFI().select(cl, PID["3g.40gb"])
        assert sel is not None
        gpu, anchor = sel
        assert anchor in mig.PROFILES[PID["3g.40gb"]].anchors

    def test_selection_minimizes_delta_f(self):
        cl = _cluster_with([(PID["2g.20gb"], 0, 0), (PID["1g.10gb"], 1, 3)])
        mfi = schedulers.MFI()
        sel = mfi.select(cl, PID["2g.20gb"])
        occ = cl.occupancy_matrix()
        gpus, anchors, deltas = schedulers.mfi_candidates(occ, PID["2g.20gb"], mfi.metric)
        best = deltas.min()
        # the chosen placement attains the minimum ΔF
        chosen = [d for g, a, d in zip(gpus, anchors, deltas) if (g, a) == sel]
        assert chosen and chosen[0] == best

    def test_mfi_fills_holes_before_opening_empty_gpus(self):
        # GPU0 has {0..3} occupied; a 3g.40gb fits the {4..7} hole exactly.
        cl = _cluster_with([(PID["4g.40gb"], 0, 0)])
        sel = schedulers.MFI().select(cl, PID["3g.40gb"])
        assert sel == (0, 4)

    def test_mfi_commit_matches_dry_run(self):
        """Committing the selected placement yields exactly F + ΔF."""
        cl = _cluster_with([(PID["1g.10gb"], 0, 2), (PID["2g.20gb"], 1, 4)])
        mfi = schedulers.MFI()
        occ = cl.occupancy_matrix()
        before = fragmentation.fragmentation_scores(occ, mfi.metric).sum()
        gpus, anchors, deltas = schedulers.mfi_candidates(occ, PID["1g.20gb"], mfi.metric)
        k = np.lexsort((anchors, gpus, deltas))[0]
        cl.allocate(77, PID["1g.20gb"], int(gpus[k]), int(anchors[k]))
        after = fragmentation.fragmentation_scores(cl.occupancy_matrix(), mfi.metric).sum()
        np.testing.assert_allclose(after - before, deltas[k])


class TestJaxParity:
    """The jitted cluster scheduler must agree with the numpy reference."""

    def test_mfi_select_parity_randomized(self):
        rng = np.random.default_rng(0)
        for _ in range(40):
            cl = mig.ClusterState(6)
            wid = 0
            for _ in range(int(rng.integers(0, 24))):
                pid, gpu = int(rng.integers(0, 6)), int(rng.integers(0, 6))
                anchors = cl.gpus[gpu].feasible_anchors(pid)
                if anchors:
                    cl.allocate(wid, pid, gpu, anchors[0])
                    wid += 1
            occ = cl.occupancy_matrix()
            req_pid = int(rng.integers(0, 6))
            d = jcluster.mfi_select(jnp.asarray(occ), jnp.int32(req_pid))
            gpus, anchors, deltas = schedulers.mfi_candidates(occ, req_pid)
            if len(gpus) == 0:
                assert not bool(d.accepted)
            else:
                assert bool(d.accepted)
                k = np.lexsort((anchors, gpus, deltas))[0]
                assert (int(d.gpu), int(d.anchor)) == (int(gpus[k]), int(anchors[k]))
                np.testing.assert_allclose(float(d.delta_f), deltas[k], rtol=1e-6)

    def test_allocate_release_roundtrip(self):
        occ = jnp.zeros((3, 8), dtype=jnp.int32)
        occ1, d = jcluster.mfi_allocate(occ, jnp.int32(PID["3g.40gb"]))
        assert bool(d.accepted)
        occ2 = jcluster.release(occ1, d.gpu, jnp.int32(PID["3g.40gb"]), d.anchor)
        assert bool((occ2 == occ).all())

    def test_rejected_allocate_is_noop(self):
        occ = jnp.ones((2, 8), dtype=jnp.int32)
        occ1, d = jcluster.mfi_allocate(occ, jnp.int32(PID["1g.10gb"]))
        assert not bool(d.accepted)
        assert bool((occ1 == occ).all())


class TestMFIDefrag:
    """Beyond-paper extension: single-migration defragmentation."""

    def test_migration_enables_acceptance(self):
        from repro.core.schedulers import MFIDefrag

        # GPU0: 1g.10gb at slice 1 blocks 4g.40gb@0; GPU1 full except slice 6.
        cl = mig.ClusterState(2)
        cl.allocate(1, PID["1g.10gb"], 0, 1)
        cl.allocate(2, PID["4g.40gb"], 1, 0)
        cl.allocate(3, PID["2g.20gb"], 1, 4)
        # request 4g.40gb: plain MFI must reject (GPU0 blocked at {0..3}, GPU1 full)
        assert schedulers.MFI().select(cl, PID["4g.40gb"]) is None
        d = MFIDefrag()
        sel = d.select(cl, PID["4g.40gb"])
        assert sel is not None
        assert d.pending_migration is not None
        vwid, vg, va = d.pending_migration
        assert vwid == 1  # the misplaced 1g.10gb moves
        # applying the migration then the request must be legal
        cl.release(vwid)
        cl.allocate(vwid, PID["1g.10gb"], vg, va)
        cl.allocate(9, PID["4g.40gb"], *sel)

    def test_no_migration_when_feasible(self):
        from repro.core.schedulers import MFIDefrag

        cl = mig.ClusterState(2)
        d = MFIDefrag()
        sel = d.select(cl, PID["2g.20gb"])
        assert sel is not None and d.pending_migration is None

    def test_rejects_when_truly_full(self):
        from repro.core.schedulers import MFIDefrag

        cl = _cluster_with([(PID["7g.80gb"], g, 0) for g in range(2)], n=2)
        assert MFIDefrag().select(cl, PID["1g.10gb"]) is None

    def test_candidate_budget_caps_total_work(self):
        """Regression: the budget must cap work across ALL GPUs, not per GPU.

        Before the fix ``tried >= max_candidates`` only broke the inner
        per-GPU loop, so a 32-GPU cluster with one allocation per GPU
        evaluated 32 candidates under a budget of 2.
        """
        from repro.core import schedulers as sched_mod
        from repro.core.schedulers import MFIDefrag

        cl = mig.ClusterState(32)
        # one 7g per GPU: every request must go through the migration search
        for g in range(32):
            cl.allocate(g, PID["7g.80gb"], g, 0)

        d = MFIDefrag(max_candidates=2)
        calls = {"n": 0}
        orig = sched_mod.MFI.select

        def counting_select(self, cluster, profile_id):
            calls["n"] += 1
            return orig(self, cluster, profile_id)

        sched_mod.MFI.select = counting_select
        try:
            d.select(cl, PID["1g.10gb"])
        finally:
            sched_mod.MFI.select = orig
        # 1 initial attempt + at most 2 selects per budgeted candidate
        # (request dry-run + victim re-placement); before the fix this was
        # 1 + 2 * 32 selects
        assert calls["n"] <= 1 + 2 * d.max_candidates

    def test_budget_still_finds_migration_within_budget(self):
        from repro.core.schedulers import MFIDefrag

        cl = mig.ClusterState(2)
        cl.allocate(1, PID["1g.10gb"], 0, 1)
        cl.allocate(2, PID["4g.40gb"], 1, 0)
        cl.allocate(3, PID["2g.20gb"], 1, 4)
        d = MFIDefrag(max_candidates=1)  # first candidate IS the victim
        sel = d.select(cl, PID["4g.40gb"])
        assert sel is not None and d.pending_migration is not None

    def test_pending_migration_commit_semantics(self):
        """Applying pending_migration then the selection must be legal and
        leave the cluster state consistent (occupancy == allocations)."""
        from repro.core.schedulers import MFIDefrag

        cl = mig.ClusterState(2)
        cl.allocate(1, PID["1g.10gb"], 0, 1)
        cl.allocate(2, PID["4g.40gb"], 1, 0)
        cl.allocate(3, PID["2g.20gb"], 1, 4)
        d = MFIDefrag()
        sel = d.select(cl, PID["4g.40gb"])
        assert sel is not None
        vwid, vg, va = d.pending_migration
        vpid = None
        for g in cl.gpus:
            if vwid in g.allocations:
                vpid = g.allocations[vwid].profile_id
        cl.release(vwid)
        cl.allocate(vwid, vpid, vg, va)  # raises if illegal
        cl.allocate(99, PID["4g.40gb"], *sel)  # raises if illegal
        # occupancy bitmap consistent with the allocation table
        for g in cl.gpus:
            expect = np.zeros(mig.NUM_MEM_SLICES, np.int32)
            for a in g.allocations.values():
                expect[a.anchor : a.anchor + mig.PROFILES[a.profile_id].mem] = 1
            np.testing.assert_array_equal(g.occupancy, expect)

    def test_select_rollback_on_rejection(self):
        """A rejected defrag search must not mutate the cluster and must
        clear any stale pending_migration from a previous call."""
        from repro.core.schedulers import MFIDefrag

        # feasible-migration cluster first -> sets pending_migration
        cl = mig.ClusterState(2)
        cl.allocate(1, PID["1g.10gb"], 0, 1)
        cl.allocate(2, PID["4g.40gb"], 1, 0)
        cl.allocate(3, PID["2g.20gb"], 1, 4)
        d = MFIDefrag()
        assert d.select(cl, PID["4g.40gb"]) is not None
        assert d.pending_migration is not None

        # now a truly-full cluster: reject, rollback, stale state cleared
        full = _cluster_with([(PID["7g.80gb"], g, 0) for g in range(2)], n=2)
        before = full.occupancy_matrix().copy()
        assert d.select(full, PID["4g.40gb"]) is None
        assert d.pending_migration is None
        np.testing.assert_array_equal(full.occupancy_matrix(), before)
