"""Tests for MFI (Algorithm 2) and the baseline schedulers."""

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

import jax.numpy as jnp

from repro.core import cluster as jcluster
from repro.core import fragmentation, mig, schedulers

PID = {name: i for i, name in enumerate(mig.PROFILE_NAMES)}


def _cluster_with(allocs, n=4):
    cl = mig.ClusterState(n)
    for wid, (pid, gpu, anchor) in enumerate(allocs):
        cl.allocate(1000 + wid, pid, gpu, anchor)
    return cl


class TestBaselines:
    def test_ff_picks_first_gpu_first_index(self):
        cl = _cluster_with([(PID["7g.80gb"], 0, 0)])
        sel = schedulers.FirstFit().select(cl, PID["1g.10gb"])
        assert sel == (1, 0)

    def test_rr_advances(self):
        cl = mig.ClusterState(3)
        rr = schedulers.RoundRobin()
        assert rr.select(cl, PID["1g.10gb"]) == (0, 0)
        cl.allocate(1, PID["1g.10gb"], 0, 0)
        assert rr.select(cl, PID["1g.10gb"]) == (1, 0)
        cl.allocate(2, PID["1g.10gb"], 1, 0)
        assert rr.select(cl, PID["1g.10gb"]) == (2, 0)

    def test_bf_picks_tightest_gpu_best_index(self):
        # GPU0 empty; GPU1 has 4 slices used -> BF should pick GPU1, and the
        # best-index policy places 1g.10gb at the highest feasible anchor.
        cl = _cluster_with([(PID["4g.40gb"], 1, 0)])
        sel = schedulers.BestFitBestIndex().select(cl, PID["1g.10gb"])
        assert sel == (1, 6)

    def test_wf_picks_emptiest_gpu(self):
        cl = _cluster_with([(PID["4g.40gb"], 1, 0)])
        sel = schedulers.WorstFitBestIndex().select(cl, PID["1g.10gb"])
        assert sel == (0, 6)

    def test_best_index_reserves_index0_for_4g(self):
        """Paper §VI: 1g.10gb goes to index 6 rather than 0."""
        cl = mig.ClusterState(1)
        sel = schedulers.BestFitBestIndex().select(cl, PID["1g.10gb"])
        assert sel == (0, 6)

    def test_reject_when_full(self):
        cl = _cluster_with([(PID["7g.80gb"], g, 0) for g in range(4)])
        for name in schedulers.SCHEDULERS:
            s = schedulers.make_scheduler(name)
            assert s.select(cl, PID["1g.10gb"]) is None


class TestMFI:
    def test_accepts_when_feasible(self):
        cl = mig.ClusterState(2)
        sel = schedulers.MFI().select(cl, PID["3g.40gb"])
        assert sel is not None
        gpu, anchor = sel
        assert anchor in mig.PROFILES[PID["3g.40gb"]].anchors

    def test_selection_minimizes_delta_f(self):
        cl = _cluster_with([(PID["2g.20gb"], 0, 0), (PID["1g.10gb"], 1, 3)])
        mfi = schedulers.MFI()
        sel = mfi.select(cl, PID["2g.20gb"])
        occ = cl.occupancy_matrix()
        gpus, anchors, deltas = schedulers.mfi_candidates(occ, PID["2g.20gb"], mfi.metric)
        best = deltas.min()
        # the chosen placement attains the minimum ΔF
        chosen = [d for g, a, d in zip(gpus, anchors, deltas) if (g, a) == sel]
        assert chosen and chosen[0] == best

    def test_mfi_fills_holes_before_opening_empty_gpus(self):
        # GPU0 has {0..3} occupied; a 3g.40gb fits the {4..7} hole exactly.
        cl = _cluster_with([(PID["4g.40gb"], 0, 0)])
        sel = schedulers.MFI().select(cl, PID["3g.40gb"])
        assert sel == (0, 4)

    def test_mfi_commit_matches_dry_run(self):
        """Committing the selected placement yields exactly F + ΔF."""
        cl = _cluster_with([(PID["1g.10gb"], 0, 2), (PID["2g.20gb"], 1, 4)])
        mfi = schedulers.MFI()
        occ = cl.occupancy_matrix()
        before = fragmentation.fragmentation_scores(occ, mfi.metric).sum()
        gpus, anchors, deltas = schedulers.mfi_candidates(occ, PID["1g.20gb"], mfi.metric)
        k = np.lexsort((anchors, gpus, deltas))[0]
        cl.allocate(77, PID["1g.20gb"], int(gpus[k]), int(anchors[k]))
        after = fragmentation.fragmentation_scores(cl.occupancy_matrix(), mfi.metric).sum()
        np.testing.assert_allclose(after - before, deltas[k])


class TestJaxParity:
    """The jitted cluster scheduler must agree with the numpy reference."""

    @given(
        st.lists(
            st.tuples(st.integers(0, 5), st.integers(0, 5)), min_size=0, max_size=24
        ),
        st.integers(0, 5),
    )
    @settings(max_examples=60, deadline=None)
    def test_mfi_select_parity(self, placements, req_pid):
        cl = mig.ClusterState(6)
        wid = 0
        for pid, gpu in placements:
            anchors = cl.gpus[gpu].feasible_anchors(pid)
            if anchors:
                cl.allocate(wid, pid, gpu, anchors[0])
                wid += 1
        occ = cl.occupancy_matrix()
        d = jcluster.mfi_select(jnp.asarray(occ), jnp.int32(req_pid))
        gpus, anchors, deltas = schedulers.mfi_candidates(occ, req_pid)
        if len(gpus) == 0:
            assert not bool(d.accepted)
        else:
            assert bool(d.accepted)
            k = np.lexsort((anchors, gpus, deltas))[0]
            assert (int(d.gpu), int(d.anchor)) == (int(gpus[k]), int(anchors[k]))
            np.testing.assert_allclose(float(d.delta_f), deltas[k], rtol=1e-6)

    def test_allocate_release_roundtrip(self):
        occ = jnp.zeros((3, 8), dtype=jnp.int32)
        occ1, d = jcluster.mfi_allocate(occ, jnp.int32(PID["3g.40gb"]))
        assert bool(d.accepted)
        occ2 = jcluster.release(occ1, d.gpu, jnp.int32(PID["3g.40gb"]), d.anchor)
        assert bool((occ2 == occ).all())

    def test_rejected_allocate_is_noop(self):
        occ = jnp.ones((2, 8), dtype=jnp.int32)
        occ1, d = jcluster.mfi_allocate(occ, jnp.int32(PID["1g.10gb"]))
        assert not bool(d.accepted)
        assert bool((occ1 == occ).all())


class TestMFIDefrag:
    """Beyond-paper extension: single-migration defragmentation."""

    def test_migration_enables_acceptance(self):
        from repro.core.schedulers import MFIDefrag

        # GPU0: 1g.10gb at slice 1 blocks 4g.40gb@0; GPU1 full except slice 6.
        cl = mig.ClusterState(2)
        cl.allocate(1, PID["1g.10gb"], 0, 1)
        cl.allocate(2, PID["4g.40gb"], 1, 0)
        cl.allocate(3, PID["2g.20gb"], 1, 4)
        # request 4g.40gb: plain MFI must reject (GPU0 blocked at {0..3}, GPU1 full)
        assert schedulers.MFI().select(cl, PID["4g.40gb"]) is None
        d = MFIDefrag()
        sel = d.select(cl, PID["4g.40gb"])
        assert sel is not None
        assert d.pending_migration is not None
        vwid, vg, va = d.pending_migration
        assert vwid == 1  # the misplaced 1g.10gb moves
        # applying the migration then the request must be legal
        cl.release(vwid)
        cl.allocate(vwid, PID["1g.10gb"], vg, va)
        cl.allocate(9, PID["4g.40gb"], *sel)

    def test_no_migration_when_feasible(self):
        from repro.core.schedulers import MFIDefrag

        cl = mig.ClusterState(2)
        d = MFIDefrag()
        sel = d.select(cl, PID["2g.20gb"])
        assert sel is not None and d.pending_migration is None

    def test_rejects_when_truly_full(self):
        from repro.core.schedulers import MFIDefrag

        cl = _cluster_with([(PID["7g.80gb"], g, 0) for g in range(2)], n=2)
        assert MFIDefrag().select(cl, PID["1g.10gb"]) is None
