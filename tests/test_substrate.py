"""Optimizer, data pipeline, checkpoint, schedule tests."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.checkpoint import load_checkpoint, save_checkpoint
from repro.data import SyntheticLM, make_batch_iterator
from repro.models.config import ModelConfig
from repro.optim import adamw_init, adamw_update, cosine_schedule


class TestAdamW:
    def test_reduces_quadratic(self):
        params = {"w": jnp.array([3.0, -2.0, 1.0])}
        opt = adamw_init(params)

        def loss(p):
            return jnp.sum(p["w"] ** 2)

        for _ in range(200):
            g = jax.grad(loss)(params)
            params, opt = adamw_update(params, g, opt, lr=5e-2, weight_decay=0.0)
        assert float(loss(params)) < 1e-2

    def test_moment_dtype(self):
        params = {"w": jnp.ones((4, 4), jnp.bfloat16)}
        opt = adamw_init(params, "bfloat16")
        assert opt["m"]["w"].dtype == jnp.bfloat16

    def test_grad_clip(self):
        params = {"w": jnp.zeros(3)}
        opt = adamw_init(params)
        g = {"w": jnp.array([1e6, 0.0, 0.0])}
        p2, _ = adamw_update(params, g, opt, lr=1.0, weight_decay=0.0, grad_clip=1.0)
        # clipped update magnitude bounded by lr × O(1)
        assert np.abs(np.asarray(p2["w"])).max() < 10.0

    def test_big_leaf_chunked_path(self):
        # exercises the lax.map branch (leading dim > 1, size > 2^26)
        params = {"w": jnp.ones((4, 1024, 16384 + 1), jnp.float32)}
        opt = adamw_init(params)
        g = {"w": jnp.ones_like(params["w"]) * 0.1}
        p2, o2 = adamw_update(params, g, opt, lr=1e-2)
        assert p2["w"].shape == params["w"].shape
        assert float(o2["step"]) == 1


class TestSchedule:
    def test_warmup_and_decay(self):
        lr0 = cosine_schedule(jnp.int32(0), peak_lr=1.0, warmup=10, total=100)
        lr_peak = cosine_schedule(jnp.int32(10), peak_lr=1.0, warmup=10, total=100)
        lr_end = cosine_schedule(jnp.int32(100), peak_lr=1.0, warmup=10, total=100)
        assert float(lr0) == 0.0
        assert abs(float(lr_peak) - 1.0) < 1e-5
        assert float(lr_end) == pytest.approx(0.1, abs=1e-5)


class TestData:
    def test_markov_structure_learnable(self):
        gen = SyntheticLM(vocab=64, seed=0, branching=2)
        toks = gen.sample(4, 100, np.random.default_rng(0))
        # successors constrained: each (prev -> next) pair must be in table
        for b in range(4):
            for t in range(1, 100):
                assert toks[b, t] in gen.succ[toks[b, t - 1]]

    def test_iterator_shapes_all_modalities(self):
        for cfg in (
            ModelConfig(name="d", family="dense", n_layers=2, d_model=32, n_heads=2,
                        n_kv_heads=2, d_ff=64, vocab=100),
            ModelConfig(name="v", family="vlm", n_layers=2, d_model=32, n_heads=2,
                        n_kv_heads=1, d_ff=64, vocab=100, frontend="vision", num_patches=4),
            ModelConfig(name="a", family="encdec", n_layers=2, d_model=32, n_heads=2,
                        n_kv_heads=2, d_ff=64, vocab=100, encdec=True, n_enc_layers=2,
                        pos="learned"),
        ):
            b = next(make_batch_iterator(cfg, 2, 16))
            assert b["tokens"].shape == (2, 16)
            assert b["labels"].shape == (2, 16)
            if cfg.frontend == "vision":
                assert b["patches"].shape == (2, 4, 32)
            if cfg.encdec:
                assert b["frames"].shape == (2, 16, 32)

    def test_determinism(self):
        a = next(make_batch_iterator(
            ModelConfig(name="d", family="dense", n_layers=2, d_model=32, n_heads=2,
                        n_kv_heads=2, d_ff=64, vocab=100), 2, 8, seed=7))
        b = next(make_batch_iterator(
            ModelConfig(name="d", family="dense", n_layers=2, d_model=32, n_heads=2,
                        n_kv_heads=2, d_ff=64, vocab=100), 2, 8, seed=7))
        np.testing.assert_array_equal(a["tokens"], b["tokens"])


class TestCheckpoint:
    def test_roundtrip(self, tmp_path):
        tree = {"a": {"w": jnp.arange(6.0).reshape(2, 3)}, "b": jnp.ones(4, jnp.bfloat16)}
        save_checkpoint(tmp_path / "ck.npz", tree, step=42)
        restored, step = load_checkpoint(tmp_path / "ck.npz", tree)
        assert step == 42
        np.testing.assert_array_equal(np.asarray(restored["a"]["w"]), np.asarray(tree["a"]["w"]))
        assert restored["b"].dtype == jnp.bfloat16

    def test_structure_mismatch_fails(self, tmp_path):
        tree = {"a": jnp.ones(3)}
        save_checkpoint(tmp_path / "ck.npz", tree)
        with pytest.raises(ValueError, match="mismatch"):
            load_checkpoint(tmp_path / "ck.npz", {"a": jnp.ones(3), "c": jnp.ones(2)})

    def test_shape_mismatch_fails(self, tmp_path):
        tree = {"a": jnp.ones(3)}
        save_checkpoint(tmp_path / "ck.npz", tree)
        with pytest.raises(ValueError, match="shape"):
            load_checkpoint(tmp_path / "ck.npz", {"a": jnp.ones(4)})
