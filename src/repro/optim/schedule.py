"""Learning-rate schedules."""

from __future__ import annotations

import jax.numpy as jnp


def cosine_schedule(step, *, peak_lr: float, warmup: int, total: int, min_frac: float = 0.1):
    stepf = step.astype(jnp.float32) if hasattr(step, "astype") else jnp.float32(step)
    warm = peak_lr * stepf / max(warmup, 1)
    t = jnp.clip((stepf - warmup) / max(total - warmup, 1), 0.0, 1.0)
    cos = peak_lr * (min_frac + (1 - min_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t)))
    return jnp.where(stepf < warmup, warm, cos)
