"""AdamW in pure JAX (no optax dependency).

Moments are stored in ``moment_dtype`` — float32 normally, bfloat16 for
very large models (grok-1) where optimizer state dominates HBM
(DESIGN.md §4).  Moments follow the parameter sharding.
"""

from __future__ import annotations

from typing import Any, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp


def adamw_init(params, moment_dtype: str = "float32") -> Dict[str, Any]:
    dt = jnp.dtype(moment_dtype)
    zeros = lambda p: jnp.zeros(p.shape, dt)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def adamw_update(
    params,
    grads,
    state,
    *,
    lr,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.1,
    grad_clip: float = 1.0,
) -> Tuple[Any, Dict[str, Any]]:
    step = state["step"] + 1

    # global-norm clip; layer-stacked leaves reduce via lax.map so the f32
    # squares never materialise for a whole (L, ...) stack at once
    def leaf_sq(g):
        if g.ndim >= 2 and g.shape[0] > 1 and g.size > (1 << 26):
            return jnp.sum(jax.lax.map(
                lambda t: jnp.sum(jnp.square(t.astype(jnp.float32))), g))
        return jnp.sum(jnp.square(g.astype(jnp.float32)))

    gnorm = jnp.sqrt(sum(leaf_sq(g) for g in jax.tree.leaves(grads)))
    scale = jnp.minimum(1.0, grad_clip / jnp.maximum(gnorm, 1e-9))

    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        gf = g.astype(jnp.float32) * scale
        m_new = b1 * m.astype(jnp.float32) + (1 - b1) * gf
        v_new = b2 * v.astype(jnp.float32) + (1 - b2) * gf * gf
        update = (m_new / bc1) / (jnp.sqrt(v_new / bc2) + eps)
        update = update + weight_decay * p.astype(jnp.float32)
        p_new = p.astype(jnp.float32) - lr * update
        return p_new.astype(p.dtype), m_new.astype(m.dtype), v_new.astype(v.dtype)

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state["m"])
    flat_v = jax.tree.leaves(state["v"])

    def upd_leaf(p, g, m, v):
        # layer-stacked leaves update via lax.map so the transient f32 copies
        # cover one layer at a time, not the whole (L, ...) stack
        if p.ndim >= 2 and p.shape[0] > 1 and p.size > (1 << 26):
            return tuple(
                jax.lax.map(lambda t: upd(*t), (p, g, m, v))
            )
        return upd(p, g, m, v)

    out = [upd_leaf(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree.unflatten(tdef, [o[0] for o in out])
    new_m = jax.tree.unflatten(tdef, [o[1] for o in out])
    new_v = jax.tree.unflatten(tdef, [o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v, "step": step}
