"""Architecture config: whisper-large-v3 [arXiv:2212.04356]."""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-large-v3", family="encdec",
    n_layers=32, d_model=1280, n_heads=20, n_kv_heads=20, head_dim=64,
    d_ff=5120, vocab=51866,
    encdec=True, n_enc_layers=32, pos="learned", mlp="gelu",
    frontend="audio",
)

SMOKE = ModelConfig(
    name="whisper-smoke", family="encdec",
    n_layers=2, d_model=256, n_heads=4, n_kv_heads=4, head_dim=64,
    d_ff=512, vocab=512, encdec=True, n_enc_layers=2, pos="learned",
    mlp="gelu", frontend="audio", dtype="float32",
)
