"""Architecture config: llama3.2-1b [hf:meta-llama/Llama-3.2-1B]."""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llama3.2-1b", family="dense",
    n_layers=16, d_model=2048, n_heads=32, n_kv_heads=8, head_dim=64,
    d_ff=8192, vocab=128256,
    mlp="swiglu", rope_theta=500_000.0,
)

# Beyond-paper variant enabling long_500k on a dense family: sliding-window
# attention (1:1 local:global would still be quadratic at the globals, so the
# variant is fully local).  Reported separately in EXPERIMENTS.md.
CONFIG_SW = ModelConfig(
    name="llama3.2-1b-sw", family="dense",
    n_layers=16, d_model=2048, n_heads=32, n_kv_heads=8, head_dim=64,
    d_ff=8192, vocab=128256,
    mlp="swiglu", rope_theta=500_000.0,
    local_global=(15, 1), window=4096,
)

SMOKE = ModelConfig(
    name="llama-smoke", family="dense",
    n_layers=2, d_model=256, n_heads=4, n_kv_heads=2, head_dim=64,
    d_ff=512, vocab=512, mlp="swiglu", dtype="float32",
)
