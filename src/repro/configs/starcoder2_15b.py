"""Architecture config: starcoder2-15b [arXiv:2402.19173]."""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-15b", family="dense",
    n_layers=40, d_model=6144, n_heads=48, n_kv_heads=4, head_dim=128,
    d_ff=24576, vocab=49152,
    mlp="gelu", rope_theta=100_000.0,
    grad_accum=4
)

SMOKE = ModelConfig(
    name="starcoder2-smoke", family="dense",
    n_layers=2, d_model=256, n_heads=4, n_kv_heads=2, head_dim=64,
    d_ff=512, vocab=512, mlp="gelu", dtype="float32",
)
