"""Architecture config: qwen3-14b [hf:Qwen/Qwen3-8B family]."""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-14b", family="dense",
    n_layers=40, d_model=5120, n_heads=40, n_kv_heads=8, head_dim=128,
    d_ff=17408, vocab=151936,
    qk_norm=True, mlp="swiglu", rope_theta=1_000_000.0,
    grad_accum=4
)

SMOKE = ModelConfig(
    name="qwen3-smoke", family="dense",
    n_layers=2, d_model=256, n_heads=4, n_kv_heads=2, head_dim=64,
    d_ff=512, vocab=512, qk_norm=True, mlp="swiglu", dtype="float32",
)
