"""Architecture config: paligemma-3b [arXiv:2407.07726]."""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="paligemma-3b", family="vlm",
    n_layers=18, d_model=2048, n_heads=8, n_kv_heads=1, head_dim=256,
    d_ff=16384, vocab=257216,
    mlp="geglu", frontend="vision", num_patches=256,
)

SMOKE = ModelConfig(
    name="paligemma-smoke", family="vlm",
    n_layers=2, d_model=256, n_heads=4, n_kv_heads=1, head_dim=64,
    d_ff=512, vocab=512, mlp="geglu", frontend="vision", num_patches=16,
    dtype="float32",
)
