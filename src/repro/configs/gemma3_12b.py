"""Architecture config: gemma3-12b [hf:google/gemma-3 family]."""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-12b", family="dense",
    n_layers=48, d_model=3840, n_heads=16, n_kv_heads=8, head_dim=256,
    d_ff=15360, vocab=262144,
    mlp="geglu", post_norm=True,
    local_global=(5, 1), window=1024, rope_theta=1_000_000.0,
    grad_accum=4
)

SMOKE = ModelConfig(
    name="gemma3-smoke", family="dense",
    n_layers=2, d_model=256, n_heads=4, n_kv_heads=2, head_dim=64,
    d_ff=512, vocab=512, mlp="geglu", post_norm=True,
    local_global=(1, 1), window=32, dtype="float32",
)
