"""Architecture config: grok-1-314b [hf:xai-org/grok-1]."""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="grok-1-314b", family="moe",
    n_layers=64, d_model=6144, n_heads=48, n_kv_heads=8, head_dim=128,
    d_ff=32768, vocab=131072,
    n_experts=8, topk=2, mlp="swiglu",
    opt_dtype="bfloat16",  # optimizer state dominates HBM at 314B params,
    grad_accum=8
)

SMOKE = ModelConfig(
    name="grok-smoke", family="moe",
    n_layers=2, d_model=256, n_heads=4, n_kv_heads=2, head_dim=64,
    d_ff=512, vocab=512, n_experts=4, topk=2, mlp="swiglu", dtype="float32",
)
