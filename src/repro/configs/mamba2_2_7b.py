"""Architecture config: mamba2-2.7b [arXiv:2405.21060]."""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-2.7b", family="ssm",
    n_layers=64, d_model=2560, n_heads=1, n_kv_heads=1,
    d_ff=0, vocab=50280,
    ssm_state=128, ssm_headdim=64, ssm_expand=2, conv_width=4, ssm_chunk=256,
    pos="none",
    grad_accum=2
)

SMOKE = ModelConfig(
    name="mamba2-smoke", family="ssm",
    n_layers=2, d_model=256, n_heads=1, n_kv_heads=1,
    d_ff=0, vocab=512, ssm_state=32, ssm_headdim=32, ssm_chunk=32,
    pos="none", dtype="float32",
)
