"""Architecture config: hymba-1.5b [arXiv:2411.13676]."""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="hymba-1.5b", family="hybrid",
    n_layers=32, d_model=1600, n_heads=25, n_kv_heads=5, head_dim=64,
    d_ff=5504, vocab=32001,
    ssm_state=16, ssm_headdim=64, ssm_expand=2, ssm_chunk=256,
    local_global=(15, 1), window=1024, mlp="swiglu",
)

SMOKE = ModelConfig(
    name="hymba-smoke", family="hybrid",
    n_layers=2, d_model=256, n_heads=4, n_kv_heads=2, head_dim=64,
    d_ff=512, vocab=512, ssm_state=16, ssm_headdim=32, ssm_chunk=32,
    local_global=(1, 1), window=32, mlp="swiglu", dtype="float32",
)
