"""Architecture config: granite-moe-3b-a800m [hf:ibm-granite/granite-3.0 family]."""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="granite-moe-3b-a800m", family="moe",
    n_layers=32, d_model=1536, n_heads=24, n_kv_heads=8, head_dim=64,
    d_ff=512, vocab=49155,
    n_experts=40, topk=8, mlp="swiglu",
)

SMOKE = ModelConfig(
    name="granite-smoke", family="moe",
    n_layers=2, d_model=256, n_heads=4, n_kv_heads=2, head_dim=64,
    d_ff=128, vocab=512, n_experts=4, topk=2, mlp="swiglu", dtype="float32",
)
