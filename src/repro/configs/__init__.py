"""Architecture registry: 10 assigned architectures (+ variants)."""

from repro.configs import (
    gemma3_12b,
    granite_moe_3b_a800m,
    grok_1_314b,
    hymba_1_5b,
    llama3_2_1b,
    mamba2_2_7b,
    paligemma_3b,
    qwen3_14b,
    starcoder2_15b,
    whisper_large_v3,
)

ARCHS = {
    "qwen3-14b": qwen3_14b.CONFIG,
    "paligemma-3b": paligemma_3b.CONFIG,
    "grok-1-314b": grok_1_314b.CONFIG,
    "llama3.2-1b": llama3_2_1b.CONFIG,
    "llama3.2-1b-sw": llama3_2_1b.CONFIG_SW,  # beyond-paper sliding-window variant
    "whisper-large-v3": whisper_large_v3.CONFIG,
    "mamba2-2.7b": mamba2_2_7b.CONFIG,
    "gemma3-12b": gemma3_12b.CONFIG,
    "starcoder2-15b": starcoder2_15b.CONFIG,
    "hymba-1.5b": hymba_1_5b.CONFIG,
    "granite-moe-3b-a800m": granite_moe_3b_a800m.CONFIG,
}

# the 10 officially assigned ids (excludes local variants)
ASSIGNED = [
    "qwen3-14b", "paligemma-3b", "grok-1-314b", "llama3.2-1b",
    "whisper-large-v3", "mamba2-2.7b", "gemma3-12b", "starcoder2-15b",
    "hymba-1.5b", "granite-moe-3b-a800m",
]

SMOKES = {
    "qwen3-14b": qwen3_14b.SMOKE,
    "paligemma-3b": paligemma_3b.SMOKE,
    "grok-1-314b": grok_1_314b.SMOKE,
    "llama3.2-1b": llama3_2_1b.SMOKE,
    "llama3.2-1b-sw": llama3_2_1b.SMOKE,
    "whisper-large-v3": whisper_large_v3.SMOKE,
    "mamba2-2.7b": mamba2_2_7b.SMOKE,
    "gemma3-12b": gemma3_12b.SMOKE,
    "starcoder2-15b": starcoder2_15b.SMOKE,
    "hymba-1.5b": hymba_1_5b.SMOKE,
    "granite-moe-3b-a800m": granite_moe_3b_a800m.SMOKE,
}

# archs with sub-quadratic attention, eligible for the long_500k shape
# (DESIGN.md: pure full-attention archs skip long_500k)
LONG_CONTEXT_OK = {"mamba2-2.7b", "hymba-1.5b", "gemma3-12b", "llama3.2-1b-sw"}


def get_config(arch: str):
    try:
        return ARCHS[arch]
    except KeyError:
        raise ValueError(f"unknown arch {arch!r}; options: {sorted(ARCHS)}")
