"""Logical-axis sharding rules (MaxText-style), resolved against the mesh.

Models annotate parameters and activations with *logical* axis names
("batch", "ff", "heads", ...).  The launcher installs a rule set mapping
logical names to mesh axes; outside a rule context every constraint is a
no-op, so the same model code runs single-device (tests, examples) and
multi-pod (dry-run, train/serve).
"""

from __future__ import annotations

import contextlib
import threading
from typing import Dict, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import PartitionSpec as P

MeshAxes = Union[None, str, Tuple[str, ...]]

_state = threading.local()


def _rules() -> Optional[Dict[str, MeshAxes]]:
    return getattr(_state, "rules", None)


@contextlib.contextmanager
def use_rules(rules: Dict[str, MeshAxes]):
    prev = _rules()
    _state.rules = rules
    try:
        yield
    finally:
        _state.rules = prev


def active_rule(name: str):
    """Value of a rule in the active rule set (None outside a context)."""
    rules = _rules()
    return rules.get(name) if rules else None


def resolve(logical_axes: Sequence[Optional[str]]) -> P:
    """Logical axes -> PartitionSpec under the active rules."""
    rules = _rules() or {}
    return P(*[rules.get(a) if a is not None else None for a in logical_axes])


def constraint(x: jax.Array, *logical_axes: Optional[str]) -> jax.Array:
    """with_sharding_constraint when rules are active; identity otherwise."""
    if _rules() is None:
        return x
    return jax.lax.with_sharding_constraint(x, resolve(logical_axes))


# ---------------------------------------------------------------------------
# Default rule sets (see DESIGN.md §4)
# ---------------------------------------------------------------------------


def default_rules(
    *,
    multi_pod: bool = False,
    n_heads: int = 0,
    n_kv_heads: int = 0,
    model_axis: int = 16,
    batch_shardable: bool = True,
    shard_kv_seq: bool = False,
    fsdp: bool = True,
) -> Dict[str, MeshAxes]:
    """Standard rules: batch->data(+pod), ff/vocab->model, FSDP d_model->data.

    Head axes go to "model" only when divisible; otherwise head_dim (always a
    multiple of 64 here) takes the model axis.
    """
    batch = (("pod", "data") if multi_pod else ("data",)) if batch_shardable else None
    heads_div = n_heads > 0 and n_heads % model_axis == 0
    kv_div = n_kv_heads > 0 and n_kv_heads % model_axis == 0
    return {
        "batch": batch,
        "seq": None,
        "kv_seq": "data" if shard_kv_seq else None,
        "vocab": "model",
        "ff": "model",
        "dmodel": "data" if fsdp else None,  # FSDP weight shard (gathered per layer)
        "dmodel_act": None,                  # activations keep d_model replicated
        "heads": "model" if heads_div else None,
        "head_dim": None if heads_div else "model",
        "kv_heads": "model" if kv_div else None,
        "kv_head_dim": None if kv_div else "model",
        "experts": None,       # experts replicated; TP inside experts via "ff"
        "ssm_inner": "model",  # SSD inner channels (head-aligned column shard)
        "ssm_heads": "model",
        "ssm_state": None,
        "conv": None,
    }
