"""Batched serving engine over a MIG-scheduled cluster.

The engine closes the paper's loop end-to-end: tenant requests arrive with a
MIG profile demand; :class:`AdmissionController` (MFI or a baseline policy)
places or rejects them on the simulated A100 fleet; admitted requests run
REAL jitted model steps — a shared batched prefill followed by token-by-token
decode with a common KV cache — and completion releases the MIG slices,
reproducing the arrival/termination churn of paper Fig. 1 in a live serving
loop.

Batching model: requests are served in waves of up to ``num_slots`` (one
shared position counter per wave, prompts padded to the wave's max length
via BOS-left-padding is avoided by requiring equal prompt lengths from the
driver — see examples/serve_cluster.py).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import model
from repro.models.config import ModelConfig
from repro.serving.admission import AdmissionController


@dataclasses.dataclass
class Request:
    request_id: int
    prompt: np.ndarray                 # (S,) int32 — equal S within a wave
    max_new_tokens: int
    profile: str = "1g.10gb"           # MIG demand of the tenant workload
    tenant: str = "default"
    priority: int = 0                  # 0 = most urgent
    patience: int = 0                  # waves it may queue before final reject
    output: Optional[List[int]] = None
    admitted: bool = False
    rejected: bool = False
    finished: bool = False


class ServingEngine:
    def __init__(
        self,
        cfg: ModelConfig,
        params,
        *,
        num_slots: int = 4,
        max_len: int = 256,
        num_gpus: int = 4,
        policy: str = "mfi",
    ):
        self.cfg = cfg
        self.params = params
        self.num_slots = num_slots
        self.max_len = max_len
        self.admission = AdmissionController(num_gpus, policy=policy)
        self._decode = jax.jit(
            lambda p, c, t, pos: model.decode_step(p, c, t, pos, cfg)
        )
        self._prefill = jax.jit(lambda p, b: model.prefill(p, b, cfg))

    def fail_gpu(self, gpu_id: int) -> List[int]:
        """Inject a GPU failure: evicted workloads re-queue in the admission
        controller with backoff and re-admit (onto surviving GPUs, or the
        failed one after :meth:`recover_gpu`) as capacity allows.  Returns
        the evicted workload ids."""
        return self.admission.fail_gpu(gpu_id)

    def recover_gpu(self, gpu_id: int) -> None:
        """Bring a previously failed GPU back into placement."""
        self.admission.recover_gpu(gpu_id)

    def _release(self, req: Request) -> None:
        # an evicted request's slices are already gone — finishing its
        # service then is not an error, just nothing left to release
        if req.request_id in self.admission.placements:
            self.admission.release(req.request_id)

    def _serve_wave(self, wave: List[Request]) -> None:
        """Prefill + decode one wave of admitted requests together."""
        n = len(wave)
        plen = len(wave[0].prompt)
        assert all(len(r.prompt) == plen for r in wave), "wave prompts must align"
        prompts = jnp.asarray(np.stack([r.prompt for r in wave]), jnp.int32)

        logits, cache = self._prefill(self.params, {"tokens": prompts})
        cache = model.pad_cache(cache, plen, self.max_len)
        tokens = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        for r in wave:
            r.output = []

        alive = list(range(n))
        for i in list(alive):  # zero-token requests finish at prefill
            if wave[i].max_new_tokens <= 0:
                wave[i].finished = True
                self._release(wave[i])
                alive.remove(i)
        if not alive:
            return
        max_new = max(wave[i].max_new_tokens for i in alive)
        for step in range(min(max_new, self.max_len - plen - 1)):
            for i in list(alive):
                wave[i].output.append(int(tokens[i]))
                if len(wave[i].output) >= wave[i].max_new_tokens:
                    wave[i].finished = True
                    self._release(wave[i])
                    alive.remove(i)
            if not alive:
                break
            logits, cache = self._decode(
                self.params, cache, tokens, jnp.int32(plen + step)
            )
            tokens = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        for i in alive:  # hit max_len
            wave[i].finished = True
            self._release(wave[i])

    def run(self, requests: List[Request]) -> Dict:
        """Serve the request list in admission-controlled waves.

        Each request submits with its ``(tenant, priority, patience)``;
        the MIG scheduler admits it, parks it in the controller's waiting
        queue (``patience > 0``), or finally rejects it.  Releases at wave
        completion re-drive admission, so parked requests join later waves
        in queue order; the controller clock ticks once per iteration and
        expires entries past their patience.  Every terminal request ends
        with ``output`` as a list (``[]`` when rejected or expired) and
        ``finished=True``.
        """
        pending = list(requests)
        by_id = {r.request_id: r for r in pending}
        ready: List[Request] = []  # admitted, awaiting a wave slot
        waves = 0
        while pending or ready or self.admission.queue_depth:
            while pending and len(ready) < self.num_slots:
                req = pending.pop(0)
                placement = self.admission.submit(
                    req.request_id,
                    req.profile,
                    tenant=req.tenant,
                    priority=req.priority,
                    patience=req.patience,
                )
                if placement is not None:
                    req.admitted = True
                    ready.append(req)
                elif not self.admission.in_queue(req.request_id):
                    req.rejected = True
                    req.finished = True
                    req.output = []
            wave = ready[: self.num_slots]
            ready = ready[len(wave):]
            if wave:
                # wave boundary: waiting requests age one tick BEFORE the
                # wave's releases re-drive admission, so their recorded
                # wait counts the wave they sat out
                self.admission.tick()
                self._serve_wave(wave)  # releases re-drive queue admission
                waves += 1
            elif not pending and not ready:
                # no running work will ever free capacity — flush the queue
                self.admission.flush_queue()
            else:
                self.admission.tick()
            for placement in self.admission.drain_dispatched():
                req = by_id.get(placement.workload_id)
                if req is None or req.finished:  # e.g. a re-admitted eviction
                    continue
                req.admitted = True
                ready.append(req)
            for wid in self.admission.drain_expired():
                req = by_id.get(wid)
                if req is None or req.finished:
                    continue
                req.rejected = True
                req.finished = True
                req.output = []
        return {"waves": waves, **self.admission.stats()}
