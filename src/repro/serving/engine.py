"""Batched serving engine over a MIG-scheduled cluster.

The engine closes the paper's loop end-to-end: tenant requests arrive with a
MIG profile demand; :class:`AdmissionController` (MFI or a baseline policy)
places or rejects them on the simulated A100 fleet; admitted requests run
REAL jitted model steps — a shared batched prefill followed by token-by-token
decode with a common KV cache — and completion releases the MIG slices,
reproducing the arrival/termination churn of paper Fig. 1 in a live serving
loop.

Batching model: requests are served in waves of up to ``num_slots`` (one
shared position counter per wave, prompts padded to the wave's max length
via BOS-left-padding is avoided by requiring equal prompt lengths from the
driver — see examples/serve_cluster.py).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import model
from repro.models.config import ModelConfig
from repro.serving.admission import AdmissionController


@dataclasses.dataclass
class Request:
    request_id: int
    prompt: np.ndarray                 # (S,) int32 — equal S within a wave
    max_new_tokens: int
    profile: str = "1g.10gb"           # MIG demand of the tenant workload
    output: Optional[List[int]] = None
    admitted: bool = False
    rejected: bool = False
    finished: bool = False


class ServingEngine:
    def __init__(
        self,
        cfg: ModelConfig,
        params,
        *,
        num_slots: int = 4,
        max_len: int = 256,
        num_gpus: int = 4,
        policy: str = "mfi",
    ):
        self.cfg = cfg
        self.params = params
        self.num_slots = num_slots
        self.max_len = max_len
        self.admission = AdmissionController(num_gpus, policy=policy)
        self._decode = jax.jit(
            lambda p, c, t, pos: model.decode_step(p, c, t, pos, cfg)
        )
        self._prefill = jax.jit(lambda p, b: model.prefill(p, b, cfg))

    def _serve_wave(self, wave: List[Request]) -> None:
        """Prefill + decode one wave of admitted requests together."""
        n = len(wave)
        plen = len(wave[0].prompt)
        assert all(len(r.prompt) == plen for r in wave), "wave prompts must align"
        prompts = jnp.asarray(np.stack([r.prompt for r in wave]), jnp.int32)

        logits, cache = self._prefill(self.params, {"tokens": prompts})
        cache = model.pad_cache(cache, plen, self.max_len)
        tokens = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        for r in wave:
            r.output = []

        max_new = max(r.max_new_tokens for r in wave)
        alive = list(range(n))
        for step in range(min(max_new, self.max_len - plen - 1)):
            for i in list(alive):
                wave[i].output.append(int(tokens[i]))
                if len(wave[i].output) >= wave[i].max_new_tokens:
                    wave[i].finished = True
                    self.admission.release(wave[i].request_id)
                    alive.remove(i)
            if not alive:
                break
            logits, cache = self._decode(
                self.params, cache, tokens, jnp.int32(plen + step)
            )
            tokens = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        for i in alive:  # hit max_len
            wave[i].finished = True
            self.admission.release(wave[i].request_id)

    def run(self, requests: List[Request]) -> Dict:
        """Serve a FIFO queue: admit up to num_slots via the MIG scheduler,
        serve the wave, release, repeat.  Rejected requests drop (paper
        semantics: no retry)."""
        queue = list(requests)
        waves = 0
        while queue:
            wave: List[Request] = []
            while queue and len(wave) < self.num_slots:
                req = queue.pop(0)
                placement = self.admission.admit(req.request_id, req.profile)
                if placement is None:
                    req.rejected = True
                    req.finished = True
                    continue
                req.admitted = True
                wave.append(req)
            if wave:
                self._serve_wave(wave)
                waves += 1
        return {"waves": waves, **self.admission.stats()}
