"""Multi-tenant GPU-as-a-Service serving: MFI admission + batched decode."""

from repro.serving.engine import ServingEngine, Request  # noqa: F401
from repro.serving.admission import AdmissionController  # noqa: F401
