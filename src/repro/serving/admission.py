"""Admission control: the paper's scheduler as the serving control plane.

Each incoming serving workload declares a MIG profile demand (derived from
its model's memory footprint); the controller consults a scheduling policy
(MFI by default, any paper baseline selectable) against the simulated MIG
cluster, commits accepted placements and releases them on completion —
reproducing the arrival/termination churn of paper Fig. 1 inside a real
serving loop.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

from repro.core import mig
from repro.core.policy import PolicyLike
from repro.core.schedulers import Scheduler, make_scheduler

# model HBM footprint (GiB) -> smallest sufficient MIG profile
_PROFILE_BY_GIB = [
    (10, "1g.10gb"),
    (20, "1g.20gb"),  # picked when compute demand is low; else 2g.20gb
    (40, "3g.40gb"),
    (80, "7g.80gb"),
]


def profile_for_model(param_bytes: int, kv_bytes: int = 0, compute_heavy: bool = False) -> str:
    """Map a model's memory footprint to the smallest fitting MIG profile."""
    gib = (param_bytes + kv_bytes) / 2**30 * 1.2  # + activation headroom
    if gib <= 10:
        return "1g.10gb"
    if gib <= 20:
        return "2g.20gb" if compute_heavy else "1g.20gb"
    if gib <= 40:
        return "4g.40gb" if compute_heavy else "3g.40gb"
    return "7g.80gb"


@dataclasses.dataclass
class Placement:
    workload_id: int
    profile: str
    gpu: int
    anchor: int


class AdmissionController:
    """Places serving workloads on the MIG cluster via a scheduling policy.

    ``policy`` is any registered policy name or an ad-hoc
    :class:`~repro.core.policy.PolicySpec` — compiled for the host engine
    through the policy registry, so custom registered policies drive
    admission exactly like the built-ins.  ``cluster_spec`` selects a
    (possibly mixed) fleet; the default is the paper's homogeneous
    A100-80GB cluster of ``num_gpus`` GPUs.  Workloads keep declaring
    canonical profile names — each GPU's device model realizes the demand
    with its own placement table (an 80 GiB demand is simply infeasible on
    every A100-40GB, for example).
    """

    def __init__(
        self,
        num_gpus: Optional[int] = None,
        policy: PolicyLike = "mfi",
        metric: str = "blocked",
        cluster_spec: Optional[mig.ClusterSpec] = None,
    ):
        self.cluster = mig.ClusterState(num_gpus, spec=cluster_spec)
        self.scheduler: Scheduler = make_scheduler(policy, metric)
        self.placements: Dict[int, Placement] = {}
        self.accepted = 0
        self.rejected = 0

    def admit(self, workload_id: int, profile: str) -> Optional[Placement]:
        pid = mig.PROFILE_NAMES.index(profile)
        sel = self.scheduler.select(self.cluster, pid)
        if sel is None:
            self.rejected += 1
            return None
        pending = getattr(self.scheduler, "pending_migration", None)
        if pending is not None:  # defrag policies: move the victim first
            vwid, vgpu, vanchor = pending
            self.cluster.migrate(vwid, vgpu, vanchor)
            old = self.placements[vwid]
            self.placements[vwid] = Placement(vwid, old.profile, vgpu, vanchor)
        gpu, anchor = sel
        self.cluster.allocate(workload_id, pid, gpu, anchor)
        placement = Placement(workload_id, profile, gpu, anchor)
        self.placements[workload_id] = placement
        self.accepted += 1
        return placement

    def release(self, workload_id: int) -> None:
        self.placements.pop(workload_id)
        self.cluster.release(workload_id)

    @property
    def acceptance_rate(self) -> float:
        total = self.accepted + self.rejected
        return self.accepted / total if total else 1.0

    def stats(self) -> Dict[str, float]:
        from repro.core import fragmentation

        return {
            "accepted": self.accepted,
            "rejected": self.rejected,
            "acceptance_rate": self.acceptance_rate,
            "active_gpus": self.cluster.active_gpus,
            "used_slices": self.cluster.used_mem_slices,
            "frag_severity": fragmentation.cluster_fragmentation(
                self.cluster.occupancy_matrix(),
                self.scheduler.metric,
                spec=self.cluster.spec,
            ),
        }
