"""Admission control: the paper's scheduler as the serving control plane.

Each incoming serving workload declares a MIG profile demand (derived from
its model's memory footprint); the controller consults a scheduling policy
(MFI by default, any paper baseline selectable) against the simulated MIG
cluster, commits accepted placements and releases them on completion —
reproducing the arrival/termination churn of paper Fig. 1 inside a real
serving loop.

Beyond accept-or-drop, the controller is a tenant-aware queued front-end:
requests carry ``(tenant, priority, patience)``, rejected requests park in
a bounded waiting queue ordered by the policy's queue keys
(:func:`repro.core.policy.queue_order` — priority first, oldest wait-age
breaking ties by default), per-tenant concurrency quotas cap how much of
the fleet one tenant can hold, and every release re-drives admission so
parked requests dispatch as capacity frees up.  This mirrors the batched
engine's ``steady-queued`` protocol (:mod:`repro.sim.batched`) on the
serving path.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

from repro.core import mig
from repro.core.policy import (
    DEFAULT_QUEUE_ORDER,
    PolicyLike,
    key_base,
    queue_order,
)
from repro.core.schedulers import Scheduler, make_scheduler


def profile_for_model(param_bytes: int, kv_bytes: int = 0, compute_heavy: bool = False) -> str:
    """Map a model's memory footprint to the smallest fitting MIG profile.

    Raises :class:`ValueError` when the footprint (with activation
    headroom) exceeds the largest MIG profile (80 GiB) — an unplaceable
    demand must fail loudly at submission, not silently degrade into a
    ``7g.80gb`` that can never hold the model.
    """
    gib = (param_bytes + kv_bytes) / 2**30 * 1.2  # + activation headroom
    if gib <= 10:
        return "1g.10gb"
    if gib <= 20:
        return "2g.20gb" if compute_heavy else "1g.20gb"
    if gib <= 40:
        return "4g.40gb" if compute_heavy else "3g.40gb"
    if gib <= 80:
        return "7g.80gb"
    raise ValueError(
        f"model footprint {gib:.1f} GiB (with headroom) exceeds the largest "
        "MIG profile (7g.80gb, 80 GiB); it cannot be served on one slice"
    )


@dataclasses.dataclass
class Placement:
    workload_id: int
    profile: str
    gpu: int
    anchor: int
    tenant: str = "default"
    priority: int = 0
    patience: int = 0  # carried along so an eviction re-queues with it


@dataclasses.dataclass
class QueueEntry:
    """One parked request in the admission waiting queue."""

    workload_id: int
    profile: str
    tenant: str
    priority: int
    patience: int   # max clock ticks it may wait before final rejection
    arrival: int    # controller clock at submission (reset on re-arm)
    seq: int        # submission order — final FIFO tie-break
    tries: int = 0      # eviction re-queue attempts consumed (0 = fresh park)
    ready_at: int = 0   # earliest clock this entry may dispatch (backoff)


class AdmissionController:
    """Places serving workloads on the MIG cluster via a scheduling policy.

    ``policy`` is any registered policy name or an ad-hoc
    :class:`~repro.core.policy.PolicySpec` — compiled for the host engine
    through the policy registry, so custom registered policies drive
    admission exactly like the built-ins.  ``cluster_spec`` selects a
    (possibly mixed) fleet; the default is the paper's homogeneous
    A100-80GB cluster of ``num_gpus`` GPUs.  Workloads keep declaring
    canonical profile names — each GPU's device model realizes the demand
    with its own placement table (an 80 GiB demand is simply infeasible on
    every A100-40GB, for example).

    Queued admission: :meth:`submit` admits, parks (``patience > 0`` and
    queue room) or rejects.  The queue is ordered by the policy's
    request-scoped keys (:func:`~repro.core.policy.queue_order`); each
    :meth:`release` re-drives admission from the queue head until the
    first failure (head-of-line order is part of the contract), and
    :meth:`tick` advances the wait clock, expiring entries past their
    patience.  Dispatches and expiries triggered in the background are
    collected with :meth:`drain_dispatched` / :meth:`drain_expired`.
    ``tenant_quotas`` caps concurrently placed workloads per tenant
    (requests over quota queue or reject without consulting the policy).

    Fault handling: :meth:`fail_gpu` marks a GPU down — its running
    workloads are evicted into the waiting queue with a retry budget
    (``max_retries``) and exponential backoff (``backoff_base`` doubling
    per attempt) — and :meth:`recover_gpu` brings it back (re-driving
    admission).  Evicted entries past their patience re-arm with doubled
    backoff while the retry budget lasts; exhausted ones are final losses,
    surfaced via :meth:`drain_expired` and the ``evict_lost`` stat.
    Fresh parked requests keep the plain patience-expiry semantics.
    """

    def __init__(
        self,
        num_gpus: Optional[int] = None,
        policy: PolicyLike = "mfi",
        metric: str = "blocked",
        cluster_spec: Optional[mig.ClusterSpec] = None,
        queue_capacity: int = 64,
        tenant_quotas: Optional[Dict[str, int]] = None,
        max_retries: int = 2,
        backoff_base: int = 2,
    ):
        if queue_capacity < 0:
            raise ValueError(
                f"queue_capacity must be >= 0, got {queue_capacity}"
            )
        if max_retries < 0:
            raise ValueError(
                f"max_retries must be >= 0 (eviction re-queue budget), "
                f"got {max_retries}"
            )
        if backoff_base < 1:
            raise ValueError(
                f"backoff_base must be >= 1 (ticks before the first retry), "
                f"got {backoff_base}"
            )
        self.cluster = mig.ClusterState(num_gpus, spec=cluster_spec)
        self.scheduler: Scheduler = make_scheduler(policy, metric)
        self.placements: Dict[int, Placement] = {}
        self.queue: List[QueueEntry] = []
        self.queue_capacity = queue_capacity
        self.tenant_quotas = dict(tenant_quotas or {})
        self.max_retries = max_retries
        self.backoff_base = backoff_base
        self.accepted = 0
        self.rejected = 0
        self.completed = 0
        self.evictions = 0
        self.evict_lost = 0
        self.clock = 0
        self._seq = 0
        self._active_by_tenant: Dict[str, int] = {}
        self._tenant_submitted: Dict[str, int] = {}
        self._tenant_accepted: Dict[str, int] = {}
        self._waits: List[int] = []
        self._drained_dispatched: List[Placement] = []
        self._drained_expired: List[int] = []
        self._evicted_at: Dict[int, int] = {}  # wid -> eviction clock
        self._recovered = 0
        self._ttrs: List[int] = []

    # -- queue ordering ------------------------------------------------------

    @property
    def _queue_order(self) -> Tuple[str, ...]:
        spec = getattr(self.scheduler, "spec", None)
        return queue_order(spec) if spec is not None else DEFAULT_QUEUE_ORDER

    def _entry_key(self, entry: QueueEntry):
        key = []
        for k in self._queue_order:
            base = key_base(k)
            if base == "priority":
                v: float = entry.priority
            elif base == "wait-age":
                v = self.clock - entry.arrival
            else:  # tenant — stable hash-free ordering by name
                v = 0.0
            key.append(-v if k.startswith("-") else v)
        key.append(entry.seq)  # FIFO tie-break
        return tuple(key)

    # -- admission -----------------------------------------------------------

    def submit(
        self,
        workload_id: int,
        profile: str,
        tenant: str = "default",
        priority: int = 0,
        patience: int = 0,
    ) -> Optional[Placement]:
        """Admit, park or reject one request.

        Returns the :class:`Placement` on immediate admission, ``None``
        otherwise — distinguish a parked request (later surfacing via
        :meth:`drain_dispatched` or :meth:`drain_expired`) from a final
        reject with :meth:`in_queue`.
        """
        if workload_id in self.placements:
            raise ValueError(
                f"workload {workload_id} is already placed "
                f"({self.placements[workload_id]}); duplicate admission "
                "would orphan its MIG slices"
            )
        if any(e.workload_id == workload_id for e in self.queue):
            raise ValueError(
                f"workload {workload_id} is already waiting in the "
                "admission queue"
            )
        if profile not in mig.PROFILE_NAMES:
            raise ValueError(
                f"unknown MIG profile {profile!r} "
                f"(valid: {', '.join(mig.PROFILE_NAMES)})"
            )
        if priority < 0:
            raise ValueError(
                f"priority must be >= 0 (0 = most urgent), got {priority}"
            )
        if patience < 0:
            raise ValueError(
                f"patience must be >= 0 (clock ticks the request may wait; "
                f"0 = accept-or-drop), got {patience}"
            )
        self._tenant_submitted[tenant] = self._tenant_submitted.get(tenant, 0) + 1
        placement = self._try_dispatch(
            workload_id, profile, tenant, priority, patience
        )
        if placement is not None:
            self._waits.append(0)
            return placement
        if patience > 0 and len(self.queue) < self.queue_capacity:
            self.queue.append(
                QueueEntry(
                    workload_id, profile, tenant, priority,
                    patience, self.clock, self._seq,
                    ready_at=self.clock,
                )
            )
            self._seq += 1
            return None
        self.rejected += 1
        return None

    def admit(self, workload_id: int, profile: str) -> Optional[Placement]:
        """Back-compat accept-or-drop admission (``patience=0``)."""
        return self.submit(workload_id, profile)

    def _try_dispatch(
        self,
        workload_id: int,
        profile: str,
        tenant: str,
        priority: int,
        patience: int = 0,
    ) -> Optional[Placement]:
        quota = self.tenant_quotas.get(tenant)
        if quota is not None and self._active_by_tenant.get(tenant, 0) >= quota:
            return None
        pid = mig.PROFILE_NAMES.index(profile)
        sel = self.scheduler.select(self.cluster, pid)
        if sel is None:
            return None
        pending = getattr(self.scheduler, "pending_migration", None)
        if pending is not None:  # defrag policies: move the victim first
            vwid, vgpu, vanchor = pending
            self.cluster.migrate(vwid, vgpu, vanchor)
            old = self.placements[vwid]
            self.placements[vwid] = dataclasses.replace(
                old, gpu=vgpu, anchor=vanchor
            )
        gpu, anchor = sel
        self.cluster.allocate(workload_id, pid, gpu, anchor)
        placement = Placement(
            workload_id, profile, gpu, anchor, tenant, priority, patience
        )
        self.placements[workload_id] = placement
        evicted_at = self._evicted_at.pop(workload_id, None)
        if evicted_at is not None:  # an eviction re-admitting, not a new accept
            self._recovered += 1
            self._ttrs.append(self.clock - evicted_at)
        else:
            self.accepted += 1
            self._tenant_accepted[tenant] = self._tenant_accepted.get(tenant, 0) + 1
        self._active_by_tenant[tenant] = self._active_by_tenant.get(tenant, 0) + 1
        return placement

    # -- queue progress ------------------------------------------------------

    def _expire_overdue(self) -> None:
        keep: List[QueueEntry] = []
        for e in self.queue:
            if self.clock - e.arrival <= e.patience:
                keep.append(e)
            elif 1 <= e.tries < self.max_retries:
                # overdue eviction with retry budget left: re-arm with
                # doubled backoff instead of expiring
                e.tries += 1
                e.arrival = self.clock
                e.ready_at = self.clock + self._backoff(e.tries)
                keep.append(e)
            else:
                if e.workload_id in self._evicted_at:
                    # an eviction that never re-admitted — a final loss,
                    # but not a (second) admission reject
                    del self._evicted_at[e.workload_id]
                    self.evict_lost += 1
                else:
                    self.rejected += 1
                self._drained_expired.append(e.workload_id)
        self.queue = keep

    def _backoff(self, attempt: int) -> int:
        return self.backoff_base * 2 ** max(0, attempt - 1)

    def _readmit(self) -> None:
        """Dispatch from the queue head until the first failure.

        The head is the queue-order minimum among entries whose backoff
        expired (``ready_at <= clock``); entries still backing off are
        skipped without breaking head-of-line order among the ready."""
        self._expire_overdue()
        while True:
            self.queue.sort(key=self._entry_key)
            ready = [e for e in self.queue if e.ready_at <= self.clock]
            if not ready:
                break
            head = ready[0]
            placement = self._try_dispatch(
                head.workload_id, head.profile, head.tenant, head.priority,
                head.patience,
            )
            if placement is None:
                break  # head-of-line blocking: later entries wait their turn
            self.queue.remove(head)
            self._waits.append(self.clock - head.arrival)
            self._drained_dispatched.append(placement)

    def tick(self, steps: int = 1) -> None:
        """Advance the wait clock, expiring overdue entries and re-driving
        admission (wait-age ordering can change the queue head)."""
        self.clock += steps
        self._readmit()

    def release(self, workload_id: int) -> None:
        if workload_id not in self.placements:
            raise KeyError(
                f"workload {workload_id} has no active placement to release"
            )
        placement = self.placements.pop(workload_id)
        self.cluster.release(workload_id)
        self._active_by_tenant[placement.tenant] -= 1
        self.completed += 1
        self._readmit()

    # -- fault handling ------------------------------------------------------

    def fail_gpu(self, gpu_id: int) -> List[int]:
        """Mark a GPU failed; evict and re-queue its running workloads.

        The GPU is masked out of placement until :meth:`recover_gpu`.
        Each evicted workload re-enters the waiting queue with one retry
        consumed and a ``backoff_base``-tick backoff (its patience floored
        at the backoff so it survives to its first retry); when the retry
        budget is zero or the queue is full it is a final loss, surfaced
        via :meth:`drain_expired`.  Returns the evicted workload ids in
        placement order.
        """
        wids = self.cluster.fail_gpu(gpu_id)
        for wid in wids:
            p = self.placements.pop(wid)
            self._active_by_tenant[p.tenant] -= 1
            self.evictions += 1
            if self.max_retries >= 1 and len(self.queue) < self.queue_capacity:
                self._evicted_at[wid] = self.clock
                self.queue.append(
                    QueueEntry(
                        wid, p.profile, p.tenant, p.priority,
                        patience=max(p.patience, self._backoff(1)),
                        arrival=self.clock, seq=self._seq, tries=1,
                        ready_at=self.clock + self._backoff(1),
                    )
                )
                self._seq += 1
            else:
                self.evict_lost += 1
                self._drained_expired.append(wid)
        return wids

    def recover_gpu(self, gpu_id: int) -> None:
        """Bring a failed GPU back up and re-drive queue admission."""
        self.cluster.recover_gpu(gpu_id)
        self._readmit()

    # -- drain buffers -------------------------------------------------------

    def in_queue(self, workload_id: int) -> bool:
        return any(e.workload_id == workload_id for e in self.queue)

    @property
    def queue_depth(self) -> int:
        return len(self.queue)

    def drain_dispatched(self) -> List[Placement]:
        """Placements dispatched from the queue since the last drain."""
        out, self._drained_dispatched = self._drained_dispatched, []
        return out

    def drain_expired(self) -> List[int]:
        """Workload ids finally rejected (patience exhausted) since the
        last drain."""
        out, self._drained_expired = self._drained_expired, []
        return out

    def flush_queue(self) -> List[int]:
        """Finally reject every waiting entry (e.g. at shutdown, or when no
        running workload remains to ever free capacity)."""
        wids = [e.workload_id for e in self.queue]
        for wid in wids:
            if wid in self._evicted_at:  # flushed eviction: a final loss
                del self._evicted_at[wid]
                self.evict_lost += 1
            else:
                self.rejected += 1
        self._drained_expired.extend(wids)
        self.queue = []
        return wids

    # -- metrics -------------------------------------------------------------

    @property
    def acceptance_rate(self) -> float:
        total = self.accepted + self.rejected
        return self.accepted / total if total else 1.0

    def stats(self) -> Dict[str, float]:
        import numpy as np

        from repro.core import fragmentation
        from repro.sim.simulator import jain_fairness

        waits = np.asarray(self._waits, dtype=np.float64)
        rates = [
            self._tenant_accepted.get(t, 0) / n
            for t, n in self._tenant_submitted.items()
            if n > 0
        ]
        return {
            "accepted": self.accepted,
            "rejected": self.rejected,
            "acceptance_rate": self.acceptance_rate,
            "active_gpus": self.cluster.active_gpus,
            "used_slices": self.cluster.used_mem_slices,
            "frag_severity": fragmentation.cluster_fragmentation(
                self.cluster.occupancy_matrix(),
                self.scheduler.metric,
                spec=self.cluster.spec,
            ),
            "queue_depth": float(len(self.queue)),
            "wait_p50": float(np.percentile(waits, 50)) if waits.size else 0.0,
            "wait_p99": float(np.percentile(waits, 99)) if waits.size else 0.0,
            "fairness": jain_fairness(rates),
            # fault/recovery metrics (all benign defaults when no GPU failed)
            "goodput": (
                self.completed / (self.completed + self.evict_lost)
                if (self.completed + self.evict_lost) else 1.0
            ),
            "evictions": float(self.evictions),
            "evict_lost": float(self.evict_lost),
            "recovered_fraction": (
                self._recovered / self.evictions if self.evictions else 1.0
            ),
            "ttr_p50": (
                float(np.percentile(np.asarray(self._ttrs), 50))
                if self._ttrs else 0.0
            ),
            "ttr_p99": (
                float(np.percentile(np.asarray(self._ttrs), 99))
                if self._ttrs else 0.0
            ),
        }
