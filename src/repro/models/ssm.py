"""Mamba-2 (SSD — state-space duality) block: chunked train/prefill scan and
O(1)-state decode step.  [arXiv:2405.21060]

The chunked algorithm splits the sequence into chunks of Q tokens:
intra-chunk terms are a masked attention-like matmul (runs on the MXU),
inter-chunk terms pass a (H, P, N) state through a `lax.scan` — this is the
TPU-native mapping of the paper's "quadratic mode within chunks, linear
mode across chunks".
"""

from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro import sharding
from repro.models import common
from repro.models.config import ModelConfig

ParamDef = common.ParamDef


def ssm_defs(cfg: ModelConfig) -> Dict[str, ParamDef]:
    """Projections are split per segment (z / x / BC / dt) so the inner dim
    column-shards over the model axis (Megatron-style); the fused layout of
    the reference implementation cannot shard its mixed channels and would
    replicate (B, L, 2·di+2N+H) activations across all model shards."""
    d = cfg.d_model
    di = cfg.ssm_dinner
    n = cfg.ssm_state
    h = cfg.ssm_nheads
    cw = cfg.conv_width
    return {
        "w_z": ParamDef((d, di), ("dmodel", "ssm_inner")),
        "w_x": ParamDef((d, di), ("dmodel", "ssm_inner")),
        "w_bc": ParamDef((d, 2 * n), ("dmodel", None)),   # shared across heads
        "w_dt": ParamDef((d, h), ("dmodel", "ssm_heads")),
        "conv_x": ParamDef((cw, di), (None, "ssm_inner"), scale=1.0),
        "conv_bc": ParamDef((cw, 2 * n), (None, None), scale=1.0),
        "conv_b_x": ParamDef((di,), ("ssm_inner",), init="zeros"),
        "conv_b_bc": ParamDef((2 * n,), (None,), init="zeros"),
        "a_log": ParamDef((h,), ("ssm_heads",), init="zeros", dtype="float32"),
        "d_skip": ParamDef((h,), ("ssm_heads",), init="ones", dtype="float32"),
        "dt_bias": ParamDef((h,), ("ssm_heads",), init="zeros", dtype="float32"),
        "norm": common.rms_norm_def(di),
        "out_proj": ParamDef((di, d), ("ssm_inner", "dmodel")),
    }


def _project(p, x: jax.Array, cfg: ModelConfig):
    """x (..., D) -> (z, xs, B, C, dt) with inner dims model-sharded.

    Weights are gathered over the FSDP shard at the use site (see
    transformer._gathered).
    """
    n = cfg.ssm_state
    g = lambda w, ax: sharding.constraint(w, None, ax)
    z = sharding.constraint(x @ g(p["w_z"], "ssm_inner"), "batch", None, "ssm_inner")
    xs = sharding.constraint(x @ g(p["w_x"], "ssm_inner"), "batch", None, "ssm_inner")
    bc = x @ g(p["w_bc"], None)
    dt = sharding.constraint(x @ g(p["w_dt"], "ssm_heads"), "batch", None, "ssm_heads")
    return z, xs, bc[..., :n], bc[..., n:], dt


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv via explicit shifts. x: (B, L, C), w: (W, C)."""
    cw = w.shape[0]
    out = jnp.zeros_like(x, dtype=jnp.float32)
    for k in range(cw):
        shift = cw - 1 - k
        xk = jnp.pad(x, ((0, 0), (shift, 0), (0, 0)))[:, : x.shape[1]]
        out = out + xk.astype(jnp.float32) * w[k].astype(jnp.float32)
    return (out + b.astype(jnp.float32)).astype(x.dtype)


def ssm_forward(p, x: jax.Array, cfg: ModelConfig, *, return_cache: bool = False):
    """Chunked SSD forward. x: (B, L, D) -> (B, L, D).  L % chunk == 0.

    With ``return_cache=True`` also returns the decode cache: the final SSM
    state and the conv ring tail, so decoding can continue at position L.
    """
    bsz, l, _ = x.shape
    di, n, h, pdim = cfg.ssm_dinner, cfg.ssm_state, cfg.ssm_nheads, cfg.ssm_headdim
    q = min(cfg.ssm_chunk, l)
    assert l % q == 0, (l, q)
    nc = l // q

    z, xs, b_, c_, dt = _project(p, x, cfg)
    conv_x_in = xs
    conv_bc_in = jnp.concatenate([b_, c_], axis=-1)
    xs = common.silu(_causal_conv(conv_x_in, p["conv_x"], p["conv_b_x"]))
    bc = common.silu(_causal_conv(conv_bc_in, p["conv_bc"], p["conv_b_bc"]))
    b_, c_ = bc[..., :n], bc[..., n:]

    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # (B, L, H)
    a = -jnp.exp(p["a_log"])  # (H,)
    da = dt * a  # (B, L, H) negative

    xh = xs.reshape(bsz, l, h, pdim).astype(jnp.float32)
    xh = sharding.constraint(xh, "batch", None, "ssm_heads", None)
    bc = b_.astype(jnp.float32)  # (B, L, N) single group
    cc = c_.astype(jnp.float32)

    # chunk views — heads sharded over the model axis (DESIGN.md §4): the
    # intra-chunk (B, nc, Q, Q, H) decay/score tensors are the SSD memory
    # hot-spot and must not replicate across model shards.
    shard_h = lambda t: sharding.constraint(t, "batch", None, None, "ssm_heads")
    da_c = shard_h(da.reshape(bsz, nc, q, h))
    dt_c = shard_h(dt.reshape(bsz, nc, q, h))
    x_c = sharding.constraint(
        xh.reshape(bsz, nc, q, h, pdim), "batch", None, None, "ssm_heads", None
    )
    b_c = bc.reshape(bsz, nc, q, n)
    c_c = cc.reshape(bsz, nc, q, n)

    cum = jnp.cumsum(da_c, axis=2)  # (B, nc, Q, H) inclusive
    total = cum[:, :, -1:, :]  # (B, nc, 1, H)

    # ---- intra-chunk (quadratic within chunk) ----------------------------
    # scores[i, j] = (C_i · B_j) · exp(cum_i - cum_j) · dt_j  for i >= j
    cb = jnp.einsum("bcin,bcjn->bcij", c_c, b_c)  # (B, nc, Q, Q)
    seg = cum[:, :, :, None, :] - cum[:, :, None, :, :]  # (B, nc, Qi, Qj, H)
    mask = jnp.tril(jnp.ones((q, q), bool))
    # mask the exponent (not the result): exp of masked entries would be inf
    # and poison the backward pass through the where.
    seg = jnp.where(mask[None, None, :, :, None], seg, -jnp.inf)
    decay = jnp.exp(seg)
    scores = cb[..., None] * decay * dt_c[:, :, None, :, :]  # (B,nc,Qi,Qj,H)
    y_intra = jnp.einsum("bcijh,bcjhp->bcihp", scores, x_c)
    y_intra = sharding.constraint(y_intra, "batch", None, None, "ssm_heads", None)

    # ---- chunk states + inter-chunk scan ----------------------------------
    # state contribution of chunk: sum_j exp(total - cum_j)·dt_j·B_j ⊗ x_j
    w_j = jnp.exp(total - cum) * dt_c  # (B, nc, Q, H)
    states = jnp.einsum("bcjh,bcjn,bcjhp->bchnp", w_j, b_c, x_c)  # (B,nc,H,N,P)

    chunk_decay = jnp.exp(total[:, :, 0, :])  # (B, nc, H)

    def scan_body(s_prev, inp):
        st, dec = inp  # (B,H,N,P), (B,H)
        s_new = s_prev * dec[:, :, None, None] + st
        return s_new, s_prev

    s0 = jnp.zeros((bsz, h, n, pdim), jnp.float32)
    s_last, s_prevs = jax.lax.scan(
        scan_body,
        s0,
        (states.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2)),
    )
    s_prevs = s_prevs.transpose(1, 0, 2, 3, 4)  # (B, nc, H, N, P) state BEFORE chunk

    # inter-chunk output: C_i · S_prev · exp(cum_i)
    y_inter = jnp.einsum("bcin,bchnp,bcih->bcihp", c_c, s_prevs, jnp.exp(cum))

    y = (y_intra + y_inter) + p["d_skip"][None, None, :, None] * x_c.reshape(
        bsz, nc, q, h, pdim
    )
    y = y.reshape(bsz, l, di).astype(x.dtype)

    # gated norm + out proj (Mamba-2 block tail)
    y = common.rms_norm(y * common.silu(z), p["norm"])
    out = y @ sharding.constraint(p["out_proj"], "ssm_inner", None)
    if not return_cache:
        return out
    cw = cfg.conv_width
    conv_in = jnp.concatenate([conv_x_in, conv_bc_in], axis=-1)
    conv_tail = conv_in[:, l - (cw - 1) :, :] if l >= cw - 1 else jnp.pad(
        conv_in, ((0, 0), (cw - 1 - l, 0), (0, 0))
    )
    return out, {"state": s_last, "conv": conv_tail}


def ssm_init_cache(cfg: ModelConfig, batch: int, dtype) -> Dict[str, jax.Array]:
    di, n, h, pdim = cfg.ssm_dinner, cfg.ssm_state, cfg.ssm_nheads, cfg.ssm_headdim
    conv_ch = di + 2 * n
    return {
        "state": jnp.zeros((batch, h, n, pdim), jnp.float32),
        "conv": jnp.zeros((batch, cfg.conv_width - 1, conv_ch), dtype),
    }


def ssm_decode_step(p, x: jax.Array, cache, cfg: ModelConfig):
    """Single-token SSD step. x: (B, D) -> (B, D), updated cache."""
    bsz, _ = x.shape
    di, n, h, pdim = cfg.ssm_dinner, cfg.ssm_state, cfg.ssm_nheads, cfg.ssm_headdim

    z, xs, b_, c_, dt = _project(p, x[:, None, :], cfg)
    z = z[:, 0]
    conv_in = jnp.concatenate([xs, b_, c_], axis=-1)[:, 0]  # (B, C)
    conv_w = jnp.concatenate([p["conv_x"], p["conv_bc"]], axis=-1)
    conv_b = jnp.concatenate([p["conv_b_x"], p["conv_b_bc"]], axis=-1)

    # conv ring: history (B, W-1, C) + current token
    hist = jnp.concatenate([cache["conv"], conv_in[:, None, :]], axis=1)  # (B, W, C)
    conv_out = jnp.einsum("bwc,wc->bc", hist.astype(jnp.float32), conv_w.astype(jnp.float32))
    conv_out = common.silu(conv_out + conv_b.astype(jnp.float32)).astype(x.dtype)
    new_conv = hist[:, 1:]

    xs = conv_out[:, :di]
    b_ = conv_out[:, di : di + n].astype(jnp.float32)
    c_ = conv_out[:, di + n :].astype(jnp.float32)
    dt = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + p["dt_bias"])  # (B, H)
    a = -jnp.exp(p["a_log"])
    dec = jnp.exp(dt * a)  # (B, H)

    xh = xs.reshape(bsz, h, pdim).astype(jnp.float32)
    state = cache["state"] * dec[:, :, None, None] + jnp.einsum(
        "bh,bn,bhp->bhnp", dt, b_, xh
    )
    y = jnp.einsum("bn,bhnp->bhp", c_, state) + p["d_skip"][None, :, None] * xh
    y = y.reshape(bsz, di).astype(x.dtype)
    y = common.rms_norm(y * common.silu(z), p["norm"])
    return y @ p["out_proj"], {"state": state, "conv": new_conv}
