"""Model configuration shared by all architecture families."""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # "dense" | "moe" | "ssm" | "hybrid" | "encdec" | "vlm"
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int

    head_dim: Optional[int] = None  # default: d_model // n_heads
    qk_norm: bool = False
    post_norm: bool = False         # gemma3-style post-attn/post-mlp norms
    mlp: str = "swiglu"             # "swiglu" | "geglu" | "gelu"
    pos: str = "rope"               # "rope" | "learned" | "sincos" | "none"
    rope_theta: float = 10_000.0
    tie_embeddings: bool = True

    # local:global attention pattern — layers are grouped as
    # [n_local × sliding-window, n_global × full]; n_layers must be divisible
    # by (n_local + n_global).  None -> all layers full attention.
    local_global: Optional[Tuple[int, int]] = None
    window: int = 1024              # sliding-window size for local layers

    # MoE
    n_experts: int = 0
    topk: int = 0
    capacity_factor: float = 1.25

    # SSM (Mamba-2 SSD)
    ssm_state: int = 0
    ssm_headdim: int = 64
    ssm_expand: int = 2
    conv_width: int = 4
    ssm_chunk: int = 256

    # blockwise-attention tile size (memory/perf knob, see EXPERIMENTS.md §Perf)
    attn_blk: int = 512
    # gradient-accumulation microbatches per train step (memory knob)
    grad_accum: int = 1

    # hybrid (Hymba): parallel attention + SSM heads in every layer
    hybrid: bool = False

    # encoder-decoder (Whisper)
    encdec: bool = False
    n_enc_layers: int = 0

    # modality frontend stub: embeddings provided directly by input_specs()
    frontend: Optional[str] = None  # None | "audio" | "vision"
    num_patches: int = 256          # vision: tokens contributed by the stub

    dtype: str = "bfloat16"
    # AdamW moment dtype ("float32" normally; "bfloat16" for very large models)
    opt_dtype: str = "float32"

    def __post_init__(self):
        if self.head_dim is None:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)
        if self.n_heads % max(self.n_kv_heads, 1):
            raise ValueError("n_heads must be divisible by n_kv_heads")
        if self.local_global is not None:
            g = sum(self.local_global)
            if self.n_layers % g:
                raise ValueError(
                    f"n_layers={self.n_layers} not divisible by group {g}"
                )
        if self.family == "moe" and (self.n_experts <= 0 or self.topk <= 0):
            raise ValueError("moe family needs n_experts/topk")

    # -- derived ------------------------------------------------------------
    @property
    def jax_dtype(self):
        return jnp.dtype(self.dtype)

    @property
    def padded_vocab(self) -> int:
        """Embedding rows padded to a multiple of 256 (Megatron-style) so the
        vocab axis shards evenly over the 16-way model axis; logits beyond
        ``vocab`` are masked in the loss and at decode."""
        return -(-self.vocab // 256) * 256

    @property
    def group_pattern(self) -> Tuple[int, int]:
        """(n_local, n_global) per scan group; (0, 1) means all-global."""
        return self.local_global if self.local_global else (0, 1)

    @property
    def n_groups(self) -> int:
        return self.n_layers // sum(self.group_pattern)

    @property
    def ssm_dinner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_nheads(self) -> int:
        return self.ssm_dinner // self.ssm_headdim

    def param_count(self) -> int:
        """Total parameters (N for roofline 6·N·D)."""
        d, f, v, hd = self.d_model, self.d_ff, self.vocab, self.head_dim
        h, kv = self.n_heads, self.n_kv_heads
        attn = d * hd * (h + 2 * kv) + h * hd * d  # qkv + out
        if self.qk_norm:
            attn += 2 * hd
        gated = self.mlp in ("swiglu", "geglu")
        mlp = d * f * (3 if gated else 2)
        if self.family == "moe":
            mlp = self.n_experts * mlp + d * self.n_experts  # + router
        ssm = 0
        if self.family in ("ssm", "hybrid"):
            di, n, hh = self.ssm_dinner, self.ssm_state, self.ssm_nheads
            # in_proj (z,x,B,C,dt) + conv + out_proj + A/D/dt_bias + gated norm
            ssm = d * (2 * di + 2 * n + hh) + self.conv_width * (di + 2 * n) \
                + di * d + 3 * hh + di
        norms = 2 * d * (2 if self.post_norm else 1)
        if self.family == "ssm":
            per_layer = ssm + norms
        elif self.family == "hybrid":
            per_layer = attn + ssm + mlp + norms + d  # + fusion norms approx
        else:
            per_layer = attn + mlp + norms
        total = self.n_layers * per_layer + v * d + d  # embed + final norm
        if self.encdec:
            enc_layer = attn + mlp + norms
            total += self.n_enc_layers * (enc_layer + attn + d)  # + cross-attn
        if self.frontend == "vision":
            total += d * d  # projector
        return int(total)

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: topk of n_experts)."""
        if self.family != "moe":
            return self.param_count()
        d, f = self.d_model, self.d_ff
        gated = self.mlp in ("swiglu", "geglu")
        expert = d * f * (3 if gated else 2)
        dense_total = self.param_count()
        return int(dense_total - self.n_layers * (self.n_experts - self.topk) * expert)
