"""Whisper-style encoder–decoder (audio family).  [arXiv:2212.04356]

The mel+conv frontend is a STUB (see DESIGN.md §3): the model consumes
precomputed frame embeddings (B, S_enc, D).  Everything downstream — the
bidirectional encoder, the causal decoder with learned positions, and
cross-attention with a precomputed encoder KV cache — is fully implemented.

Shape mapping: the assigned seq_len S is split S_enc = S_dec = S // 2.
"""

from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro import sharding
from repro.models import common, transformer
from repro.models.config import ModelConfig

ParamDef = common.ParamDef


def enc_layer_defs(cfg: ModelConfig) -> Dict[str, Any]:
    d = cfg.d_model
    return {
        "ln1": common.rms_norm_def(d),
        "attn": transformer.attn_defs(cfg),
        "ln2": common.rms_norm_def(d),
        "mlp": transformer.mlp_defs(cfg),
    }


def dec_layer_defs(cfg: ModelConfig) -> Dict[str, Any]:
    d = cfg.d_model
    return {
        "ln1": common.rms_norm_def(d),
        "self_attn": transformer.attn_defs(cfg),
        "ln_x": common.rms_norm_def(d),
        "cross_attn": transformer.attn_defs(cfg),
        "ln2": common.rms_norm_def(d),
        "mlp": transformer.mlp_defs(cfg),
    }


def model_defs(cfg: ModelConfig) -> Dict[str, Any]:
    return {
        "embed": ParamDef((cfg.padded_vocab, cfg.d_model), ("vocab", "dmodel"), scale=1.0),
        "enc_layers": transformer._stack(enc_layer_defs(cfg), cfg.n_enc_layers),
        "dec_layers": transformer._stack(dec_layer_defs(cfg), cfg.n_layers),
        "enc_norm": common.rms_norm_def(cfg.d_model),
        "final_norm": common.rms_norm_def(cfg.d_model),
        "pos_embed": ParamDef((32768, cfg.d_model), (None, "dmodel"), scale=1.0),
    }


def _cross_attention(p, x, enc_k, enc_v, cfg: ModelConfig):
    """Unmasked attention from decoder states onto encoder KV."""
    b, s, _ = x.shape
    hn, hd = cfg.n_heads, cfg.head_dim
    q = (x @ p["wq"]).reshape(b, s, hn, hd)
    o = common.blockwise_attention(q, enc_k, enc_v, causal=False, blk_q=cfg.attn_blk, blk_k=cfg.attn_blk)
    return o.reshape(b, s, -1) @ p["wo"]


def _enc_layer(p, x, cfg: ModelConfig, positions):
    h = common.rms_norm(x, p["ln1"])
    hn, kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    b, s, _ = h.shape
    q = (h @ p["attn"]["wq"]).reshape(b, s, hn, hd)
    k = (h @ p["attn"]["wk"]).reshape(b, s, kv, hd)
    v = (h @ p["attn"]["wv"]).reshape(b, s, kv, hd)
    o = common.blockwise_attention(q, k, v, causal=False, blk_q=cfg.attn_blk, blk_k=cfg.attn_blk)
    x = x + o.reshape(b, s, -1) @ p["attn"]["wo"]
    x = x + transformer.mlp_block(p["mlp"], common.rms_norm(x, p["ln2"]), cfg)
    return x


def encode(params, frames: jax.Array, cfg: ModelConfig, *, train: bool = False):
    """frames: (B, S_enc, D) stub embeddings -> encoder states (B, S_enc, D)."""
    b, s, d = frames.shape
    x = frames.astype(cfg.jax_dtype) + jnp.asarray(
        common.sincos_positions(s, d), cfg.jax_dtype
    )[None]
    x = sharding.constraint(x, "batch", None, "dmodel_act")
    positions = jnp.broadcast_to(jnp.arange(s)[None, :], (b, s))

    def body(xc, lp):
        return _enc_layer(lp, xc, cfg, positions), None

    x, _ = common.remat_scan(body, x, params["enc_layers"], train=train)
    return common.rms_norm(x, params["enc_norm"])


def _dec_layer(p, x, enc_k, enc_v, cfg: ModelConfig, positions):
    """Training/prefill decoder layer. Returns (x, (self_k, self_v))."""
    h = common.rms_norm(x, p["ln1"])
    attn_out, (k, v) = transformer.attention_block(
        p["self_attn"], h, cfg, window=None, positions=positions
    )
    x = x + attn_out
    x = x + _cross_attention(
        p["cross_attn"], common.rms_norm(x, p["ln_x"]), enc_k, enc_v, cfg
    )
    x = x + transformer.mlp_block(p["mlp"], common.rms_norm(x, p["ln2"]), cfg)
    return x, (k, v)


def dec_forward(params, tokens, enc_states, cfg: ModelConfig, *, train: bool = False, return_cache: bool = False):
    """Decoder over full token sequence. Returns (hidden, cache or None)."""
    b, s = tokens.shape
    x = params["embed"][tokens].astype(cfg.jax_dtype) * (cfg.d_model ** 0.5)
    x = x + params["pos_embed"][:s][None].astype(x.dtype)
    positions = jnp.broadcast_to(jnp.arange(s)[None, :], (b, s))

    kv, hd = cfg.n_kv_heads, cfg.head_dim

    def enc_kv(lp):
        bb, se, _ = enc_states.shape
        ek = (enc_states @ lp["cross_attn"]["wk"]).reshape(bb, se, kv, hd)
        ev = (enc_states @ lp["cross_attn"]["wv"]).reshape(bb, se, kv, hd)
        return ek, ev

    def body(xc, lp):
        ek, ev = enc_kv(lp)
        out, c = _dec_layer(lp, xc, ek, ev, cfg, positions)
        return out, c

    x, caches = common.remat_scan(body, x, params["dec_layers"], train=train)
    x = common.rms_norm(x, params["final_norm"])
    if not return_cache:
        return x, None
    # self-attn cache (L, B, S, KV, hd) + cross KV per layer
    def all_enc_kv(lp):
        return enc_kv(lp)

    ek, ev = jax.vmap(all_enc_kv)(params["dec_layers"])
    cache = {
        "self": {"k": caches[0], "v": caches[1]},
        "cross": {"k": ek, "v": ev},
    }
    return x, cache


def init_cache(cfg: ModelConfig, batch: int, dec_len: int, enc_len: int):
    dtype = cfg.jax_dtype
    kv, hd = cfg.n_kv_heads, cfg.head_dim
    L = cfg.n_layers
    z = lambda s: jnp.zeros((L, batch, s, kv, hd), dtype)
    return {
        "self": {"k": z(dec_len), "v": z(dec_len)},
        "cross": {"k": z(enc_len), "v": z(enc_len)},
    }


def decode(params, cache, token: jax.Array, pos, cfg: ModelConfig):
    """One decoder token. Returns (logits (B, V), updated cache)."""
    x = params["embed"][token].astype(cfg.jax_dtype) * (cfg.d_model ** 0.5)
    x = x + params["pos_embed"][pos][None].astype(x.dtype)

    def body(xc, inp):
        lp, sk, sv, ck, cv = inp
        h = common.rms_norm(xc, lp["ln1"])
        attn_out, new_sc = transformer.attention_decode(
            lp["self_attn"], h, {"k": sk, "v": sv}, cfg, window=None, pos=pos
        )
        xc = xc + attn_out
        # cross attention (single query token onto precomputed encoder KV)
        hq = common.rms_norm(xc, lp["ln_x"])
        b = hq.shape[0]
        q = (hq @ lp["cross_attn"]["wq"]).reshape(b, cfg.n_heads, cfg.head_dim)
        enc_len = ck.shape[1]
        kv_pos = jnp.broadcast_to(jnp.arange(enc_len)[None, :], (b, enc_len))
        o = common.decode_gqa_attention(q, ck, cv, kv_pos, jnp.int32(enc_len))
        xc = xc + o.reshape(b, -1) @ lp["cross_attn"]["wo"]
        xc = xc + transformer.mlp_block(
            lp["mlp"], common.rms_norm(xc, lp["ln2"])[:, None, :], cfg
        )[:, 0]
        return xc, new_sc

    x, new_self = jax.lax.scan(
        body,
        x,
        (
            params["dec_layers"],
            cache["self"]["k"],
            cache["self"]["v"],
            cache["cross"]["k"],
            cache["cross"]["v"],
        ),
    )
    x = common.rms_norm(x, params["final_norm"])
    logits = jnp.einsum("bd,vd->bv", x, params["embed"], preferred_element_type=jnp.float32)
    logits = common.mask_padded_logits(logits, cfg.vocab)
    new_cache = {
        "self": {"k": new_self["k"], "v": new_self["v"]},
        "cross": cache["cross"],
    }
    return sharding.constraint(logits, "batch", "vocab"), new_cache
