"""Shared layer library: param builder, norms, RoPE, blockwise attention, MLP.

Conventions:
  * params are plain nested dicts of jnp arrays (bf16 by default);
  * every parameter is declared once via :class:`ParamDef` so the same
    definition yields concrete weights, ShapeDtypeStructs (dry-run) or
    logical sharding axes;
  * activations layout: (batch, seq, ...); attention heads (B, S, H, D);
  * attention never materialises (S, S) logits — the blockwise (flash)
    implementation scans Q and KV tiles (DESIGN.md §4), bounding peak
    memory at (B, blk_q, H, blk_k) per step.
"""

from __future__ import annotations

import dataclasses
import functools
import math
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import sharding

# ---------------------------------------------------------------------------
# Parameter definitions
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ParamDef:
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]  # logical sharding axes, len == ndim
    init: str = "normal"  # "normal" | "zeros" | "ones"
    scale: float = 1.0    # stddev multiplier for "normal" (fan-in applied)
    dtype: Optional[str] = None  # override model dtype (e.g. norms in f32)

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def materialize(tree, mode: str, dtype, rng: Optional[jax.Array] = None):
    """ParamDef tree -> params ("init"), specs ("abstract") or axes ("axes")."""
    leaves, treedef = jax.tree.flatten(
        tree, is_leaf=lambda x: isinstance(x, ParamDef)
    )
    if mode == "init":
        keys = jax.random.split(rng, len(leaves))
    out = []
    for i, d in enumerate(leaves):
        dt = jnp.dtype(d.dtype) if d.dtype else dtype
        if mode == "abstract":
            out.append(jax.ShapeDtypeStruct(d.shape, dt))
        elif mode == "axes":
            out.append(d.axes)
        elif mode == "init":
            if d.init == "zeros":
                out.append(jnp.zeros(d.shape, dt))
            elif d.init == "ones":
                out.append(jnp.ones(d.shape, dt))
            else:
                fan_in = d.shape[0] if len(d.shape) > 1 else d.shape[-1]
                std = d.scale / math.sqrt(max(fan_in, 1))
                out.append(
                    (jax.random.normal(keys[i], d.shape, jnp.float32) * std).astype(dt)
                )
        else:
            raise ValueError(mode)
    return jax.tree.unflatten(treedef, out)


def param_partition_specs(defs_tree):
    """ParamDef tree -> PartitionSpec tree under the active sharding rules."""
    return jax.tree.map(
        lambda d: sharding.resolve(d.axes),
        defs_tree,
        is_leaf=lambda x: isinstance(x, ParamDef),
    )


# ---------------------------------------------------------------------------
# Norms / activations
# ---------------------------------------------------------------------------


def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * (1.0 + scale.astype(jnp.float32))
    return out.astype(x.dtype)


def rms_norm_def(d: int) -> ParamDef:
    return ParamDef((d,), (None,), init="zeros", dtype="float32")


def silu(x):
    return x * jax.nn.sigmoid(x)


# ---------------------------------------------------------------------------
# Positions
# ---------------------------------------------------------------------------


def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Rotary embedding. x: (..., S, H, D) or (..., H, D); positions (..., S)."""
    d = x.shape[-1]
    half = d // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, half)
    # broadcast to head axis: x is (B,S,H,D) -> angles (B,S,1,half)
    while angles.ndim < x.ndim:
        angles = angles[..., None, :]
    sin, cos = jnp.sin(angles), jnp.cos(angles)
    x1, x2 = x[..., :half].astype(jnp.float32), x[..., half:].astype(jnp.float32)
    return jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    ).astype(x.dtype)


def sincos_positions(s: int, d: int) -> np.ndarray:
    """Whisper-style sinusoidal position table (S, D)."""
    half = d // 2
    pos = np.arange(s)[:, None]
    freqs = np.exp(-np.log(10000.0) * np.arange(half) / max(half - 1, 1))
    t = pos * freqs[None, :]
    return np.concatenate([np.sin(t), np.cos(t)], axis=1).astype(np.float32)


# ---------------------------------------------------------------------------
# Blockwise (flash) attention — pure jnp, memory-bounded
# ---------------------------------------------------------------------------


def _tile_logits(qt, kt, scale, qpos, kpos, causal, window):
    """Masked logits for one (Q-tile, KV-tile) pair.

    qt: (B, KV, G, bq, D); kt: (B, KV, bk, D) -> (B, KV, G, bq, bk) f32.
    """
    logits = jnp.einsum(
        "bkgqd,bksd->bkgqs", qt, kt, preferred_element_type=jnp.float32
    ) * scale
    mask = kpos[None, :] >= 0
    if causal:
        mask = mask & (qpos[:, None] >= kpos[None, :])
    if window is not None:
        mask = mask & (kpos[None, :] > qpos[:, None] - window)
    return jnp.where(mask[None, None, None], logits, -jnp.inf)


def _make_flash_qtile(scale, causal, window, blk_k):
    """Factory for a custom-VJP flash attention of ONE query tile vs a tiled
    KV span.  Residuals are only (o, L): the backward pass recomputes tile
    logits, so nested-scan autodiff never stores (bq × bk) probabilities —
    this is what keeps train-time attention memory O(S·D) instead of O(S²).
    """

    def fwd_scan(qt, kts, vts, qstart, kstart):
        b, kv, g, bq, d = qt.shape
        nk = kts.shape[0]
        qpos = qstart + jnp.arange(bq)

        def body(carry, xs):
            j, kt, vt = xs
            kpos = kstart + j * blk_k + jnp.arange(blk_k)
            logits = _tile_logits(qt, kt, scale, qpos, kpos, causal, window)
            m, l, acc = carry
            m_cur = jnp.max(logits, axis=-1, keepdims=True)
            m_new = jnp.maximum(m, m_cur)
            safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
            p = jnp.where(jnp.isfinite(logits), jnp.exp(logits - safe), 0.0)
            alpha = jnp.where(jnp.isfinite(m), jnp.exp(m - safe), 0.0)
            l_new = l * alpha + jnp.sum(p, axis=-1, keepdims=True)
            acc_new = acc * alpha + jnp.einsum(
                "bkgqs,bksd->bkgqd", p.astype(vt.dtype), vt,
                preferred_element_type=jnp.float32,
            )
            return (m_new, l_new, acc_new), None

        init = (
            jnp.full((b, kv, g, bq, 1), -jnp.inf, jnp.float32),
            jnp.zeros((b, kv, g, bq, 1), jnp.float32),
            jnp.zeros((b, kv, g, bq, d), jnp.float32),
        )
        (m, l, acc), _ = jax.lax.scan(body, init, (jnp.arange(nk), kts, vts))
        o = acc / jnp.where(l == 0, 1.0, l)
        lse = jnp.where(l > 0, m + jnp.log(jnp.maximum(l, 1e-38)), -jnp.inf)
        return o, lse  # (B,KV,G,bq,D), (B,KV,G,bq,1)

    @jax.custom_vjp
    def flash(qt, kts, vts, qstart, kstart):
        o, _ = fwd_scan(qt, kts, vts, qstart, kstart)
        return o.astype(qt.dtype)

    def flash_fwd(qt, kts, vts, qstart, kstart):
        o, lse = fwd_scan(qt, kts, vts, qstart, kstart)
        return o.astype(qt.dtype), (qt, kts, vts, qstart, kstart, o, lse)

    def flash_bwd(res, do):
        qt, kts, vts, qstart, kstart, o, lse = res
        b, kv, g, bq, d = qt.shape
        nk = kts.shape[0]
        qpos = qstart + jnp.arange(bq)
        dof = do.astype(jnp.float32)
        dsum = jnp.sum(dof * o, axis=-1, keepdims=True)  # (B,KV,G,bq,1)
        qtf = qt.astype(jnp.float32)

        def body(dq, xs):
            j, kt, vt = xs
            kpos = kstart + j * blk_k + jnp.arange(blk_k)
            logits = _tile_logits(qtf, kt, scale, qpos, kpos, causal, window)
            p = jnp.where(jnp.isfinite(logits), jnp.exp(logits - jnp.where(
                jnp.isfinite(lse), lse, 0.0)), 0.0)  # (B,KV,G,bq,bk)
            dv_j = jnp.einsum("bkgqs,bkgqd->bksd", p, dof,
                              preferred_element_type=jnp.float32)
            dp = jnp.einsum("bkgqd,bksd->bkgqs", dof, vt.astype(jnp.float32),
                            preferred_element_type=jnp.float32)
            ds = p * (dp - dsum) * scale
            dq = dq + jnp.einsum("bkgqs,bksd->bkgqd", ds, kt.astype(jnp.float32),
                                 preferred_element_type=jnp.float32)
            dk_j = jnp.einsum("bkgqs,bkgqd->bksd", ds, qtf,
                              preferred_element_type=jnp.float32)
            return dq, (dk_j, dv_j)

        dq0 = jnp.zeros((b, kv, g, bq, d), jnp.float32)
        dq, (dks, dvs) = jax.lax.scan(body, dq0, (jnp.arange(nk), kts, vts))
        zint = np.zeros((), jax.dtypes.float0)
        return (dq.astype(qt.dtype), dks.astype(kts.dtype), dvs.astype(vts.dtype),
                zint, zint)

    flash.defvjp(flash_fwd, flash_bwd)
    return flash


def blockwise_attention(
    q: jax.Array,  # (B, Sq, H, D)
    k: jax.Array,  # (B, Sk, KV, D)
    v: jax.Array,  # (B, Sk, KV, D)
    *,
    causal: bool = True,
    window: Optional[int] = None,
    scale: Optional[float] = None,
    blk_q: int = 512,
    blk_k: int = 512,
    q_offset: int = 0,
) -> jax.Array:
    """Flash attention with GQA, causal and optional sliding window.

    Memory-optimal: the custom-VJP tile kernel stores only (o, logsumexp);
    backward recomputes tile logits.  The sliding-window path slices only the
    (blk_q + window) KV span each Q tile needs, so local layers cost
    O(S·window) rather than O(S²).
    """
    b, sq, h, d = q.shape
    sk, kv = k.shape[1], k.shape[2]
    g = h // kv
    scale = d ** -0.5 if scale is None else scale
    blk_q = min(blk_q, sq)
    blk_k = min(blk_k, sk)
    assert sq % blk_q == 0 and sk % blk_k == 0, (sq, blk_q, sk, blk_k)
    nq = sq // blk_q

    qg = q.reshape(b, nq, blk_q, kv, g, d).transpose(1, 0, 3, 4, 2, 5)
    # (nq, B, KV, G, bq, D)

    if window is not None and sk > blk_q + window:
        # --- local path: each Q tile sees one (blk_q + window) KV span -----
        span = blk_q + window
        kp = jnp.pad(k, ((0, 0), (window, 0), (0, 0), (0, 0)))
        vp = jnp.pad(v, ((0, 0), (window, 0), (0, 0), (0, 0)))
        flash = _make_flash_qtile(scale, causal, window, span)

        def q_body(carry, qi):
            i, qt = qi
            start = i * blk_q  # padded coords == (i*blk_q - window) + window
            kt = jax.lax.dynamic_slice_in_dim(kp, start, span, axis=1)
            vt = jax.lax.dynamic_slice_in_dim(vp, start, span, axis=1)
            kt = kt.transpose(0, 2, 1, 3)[None]  # (1, B, KV, span, D)
            vt = vt.transpose(0, 2, 1, 3)[None]
            o = flash(qt, kt, vt, q_offset + i * blk_q, i * blk_q - window)
            return carry, o

        _, out = jax.lax.scan(q_body, None, (jnp.arange(nq), qg))
    else:
        # --- global path: flash over all KV tiles --------------------------
        nk = sk // blk_k
        kt_all = k.reshape(b, nk, blk_k, kv, d).transpose(1, 0, 3, 2, 4)
        vt_all = v.reshape(b, nk, blk_k, kv, d).transpose(1, 0, 3, 2, 4)
        flash = _make_flash_qtile(scale, causal, window, blk_k)

        def q_body(carry, qi):
            i, qt = qi
            o = flash(qt, kt_all, vt_all, q_offset + i * blk_q, 0)
            return carry, o

        _, out = jax.lax.scan(q_body, None, (jnp.arange(nq), qg))

    # out: (nq, B, KV, G, bq, D) -> (B, Sq, H, D)
    out = out.transpose(1, 0, 4, 2, 3, 5).reshape(b, sq, h, d)
    return out.astype(q.dtype)


def decode_gqa_attention(
    q: jax.Array,       # (B, H, D) single token
    k_cache: jax.Array,  # (B, S, KV, D)
    v_cache: jax.Array,
    kv_positions: jax.Array,  # (B, S) true token position per slot (-1 invalid)
    pos: jax.Array,      # scalar current position
    *,
    scale: Optional[float] = None,
    window: Optional[int] = None,
) -> jax.Array:
    """Single-token decode attention over a (ring or linear) KV cache."""
    b, h, d = q.shape
    kv = k_cache.shape[2]
    g = h // kv
    scale = d ** -0.5 if scale is None else scale
    qf = q.reshape(b, kv, g, d).astype(jnp.float32)
    logits = jnp.einsum(
        "bkgd,bskd->bkgs", qf, k_cache.astype(jnp.float32),
        preferred_element_type=jnp.float32,
    ) * scale
    valid = (kv_positions >= 0) & (kv_positions <= pos)
    if window is not None:
        valid &= kv_positions > pos - window
    logits = jnp.where(valid[:, None, None, :], logits, -jnp.inf)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum(
        "bkgs,bskd->bkgd", p, v_cache.astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )
    return out.reshape(b, h, d).astype(q.dtype)


# ---------------------------------------------------------------------------
# Loss
# ---------------------------------------------------------------------------


def grad_dtype_barrier(x: jax.Array) -> jax.Array:
    """Identity whose backward casts the cotangent to x's dtype.

    The CE loss computes logits with preferred_element_type=f32, so the
    residual-stream cotangent arrives in f32 and every activation
    all-reduce/all-gather in the backward pass doubles in size (§Perf).
    Placing this barrier between the decoder stack and the loss keeps the
    backward pass in bf16 (f32 still used inside norms/softmax locally).
    """

    @jax.custom_vjp
    def _barrier(y):
        return y

    def _fwd(y):
        return y, None

    def _bwd(_, ct):
        return (ct.astype(x.dtype),)

    _barrier.defvjp(_fwd, _bwd)
    return _barrier(x)


def mask_padded_logits(logits: jax.Array, valid_vocab: int) -> jax.Array:
    """-inf out embedding-padding rows (see ModelConfig.padded_vocab)."""
    v = logits.shape[-1]
    if v == valid_vocab:
        return logits
    mask = jnp.arange(v) < valid_vocab
    return jnp.where(mask, logits, -1e30)


def _sqrt_factor(n: int) -> int:
    """Largest divisor of n that is <= sqrt(n)."""
    best = 1
    f = 1
    while f * f <= n:
        if n % f == 0:
            best = f
        f += 1
    return best


def remat_scan(body, carry, xs, *, train: bool):
    """Scan over the leading axis of ``xs`` with sqrt(N) two-level remat.

    Training a scan over N layers normally checkpoints N copies of the carry
    (activations); splitting into outer×inner scans with ``jax.checkpoint``
    on the outer body bounds live checkpoints at outer + inner ≈ 2·sqrt(N).
    Inference (train=False) runs a plain scan.
    """
    leaves = jax.tree.leaves(xs)
    n = leaves[0].shape[0]
    if not train:
        return jax.lax.scan(body, carry, xs)
    o = _sqrt_factor(n)
    i = n // o
    inner_body = jax.checkpoint(body)  # per-layer: save only the carry
    if o == 1:
        return jax.lax.scan(inner_body, carry, xs)
    xs2 = jax.tree.map(lambda a: a.reshape((o, i) + a.shape[1:]), xs)

    @jax.checkpoint  # per super-group: bounds live checkpoints at o + i
    def outer(c, xo):
        return jax.lax.scan(inner_body, c, xo)

    carry, ys = jax.lax.scan(outer, carry, xs2)
    if ys is not None:
        ys = jax.tree.map(
            lambda a: a.reshape((n,) + a.shape[2:]) if a is not None else a, ys
        )
    return carry, ys


def chunked_ce_loss(
    x: jax.Array,        # (B, S, D) final hidden states
    embed: jax.Array,    # (Vp, D) tied softmax weights (padded vocab)
    labels: jax.Array,   # (B, S) int32, -1 = ignore
    chunk: int = 512,
    valid_vocab: int | None = None,
) -> jax.Array:
    """Cross-entropy with S-chunked logits (never materialises (B,S,V))."""
    b, s, d = x.shape
    chunk = min(chunk, s)
    assert s % chunk == 0
    xc = x.reshape(b, s // chunk, chunk, d).transpose(1, 0, 2, 3)
    lc = labels.reshape(b, s // chunk, chunk).transpose(1, 0, 2)

    @jax.checkpoint  # recompute chunk logits in backward: O(B·chunk·V) -> transient
    def body(carry, xs):
        xt, lt = xs
        logits = jnp.einsum(
            "bsd,vd->bsv", xt, embed, preferred_element_type=jnp.float32
        )
        logits = sharding.constraint(logits, "batch", None, "vocab")
        if valid_vocab is not None:
            logits = mask_padded_logits(logits, valid_vocab)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(
            logits, jnp.maximum(lt, 0)[..., None], axis=-1
        )[..., 0]
        mask = (lt >= 0).astype(jnp.float32)
        loss_sum, n = carry
        return (loss_sum + jnp.sum((lse - gold) * mask), n + jnp.sum(mask)), None

    (loss_sum, n), _ = jax.lax.scan(body, (jnp.float32(0), jnp.float32(0)), (xc, lc))
    return loss_sum / jnp.maximum(n, 1.0)
