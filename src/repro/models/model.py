"""Unified model API over all families.

  * ``param_defs(cfg)`` / ``abstract_params`` / ``init_params`` / ``param_specs``
  * ``loss_fn(params, batch, cfg)``         — next-token CE (modality-aware)
  * ``prefill(params, batch, cfg)``         — returns (last-token logits, cache)
  * ``decode_step(params, cache, token, pos, cfg)``
  * ``init_cache(cfg, batch, seq_len)``     — decode-cache pytree (allocation-free
                                              via jax.eval_shape for the dry-run)
"""

from __future__ import annotations

import functools
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro import sharding
from repro.models import common, encdec, transformer
from repro.models.config import ModelConfig


def param_defs(cfg: ModelConfig):
    if cfg.encdec:
        return encdec.model_defs(cfg)
    return transformer.model_defs(cfg)


def abstract_params(cfg: ModelConfig):
    return common.materialize(param_defs(cfg), "abstract", cfg.jax_dtype)


def init_params(cfg: ModelConfig, rng: jax.Array):
    return common.materialize(param_defs(cfg), "init", cfg.jax_dtype, rng)


def param_specs(cfg: ModelConfig):
    """PartitionSpec tree under the active sharding rules."""
    return common.param_partition_specs(param_defs(cfg))


# ---------------------------------------------------------------------------
# Training loss
# ---------------------------------------------------------------------------


def loss_fn(params, batch: Dict[str, jax.Array], cfg: ModelConfig) -> jax.Array:
    """Mean next-token cross-entropy.  batch keys per family:

      * dense/moe/ssm/hybrid: tokens (B, S), labels (B, S)
      * vlm:   + patches (B, P, D) stub embeddings (labels cover text only)
      * audio: frames (B, S_enc, D) stub embeddings + tokens/labels (B, S_dec)
    """
    if cfg.encdec:
        enc = encdec.encode(params, batch["frames"], cfg, train=True)
        x, _ = encdec.dec_forward(params, batch["tokens"], enc, cfg, train=True)
        if sharding.active_rule("bf16_grad"):
            x = common.grad_dtype_barrier(x)
        return common.chunked_ce_loss(x, params["embed"], batch["labels"], valid_vocab=cfg.vocab)

    x, _ = transformer.forward(params, batch, cfg, train=True)
    if sharding.active_rule("bf16_grad"):
        x = common.grad_dtype_barrier(x)
    labels = batch["labels"]
    if cfg.frontend == "vision":
        # hidden states include the patch prefix; ignore it in the loss
        pad = jnp.full(
            (labels.shape[0], cfg.num_patches), -1, labels.dtype
        )
        labels = jnp.concatenate([pad, labels], axis=1)
    return common.chunked_ce_loss(x, params["embed"], labels, valid_vocab=cfg.vocab)


# ---------------------------------------------------------------------------
# Serving
# ---------------------------------------------------------------------------


def init_cache(cfg: ModelConfig, batch: int, seq_len: int):
    if cfg.encdec:
        half = seq_len // 2
        return encdec.init_cache(cfg, batch, dec_len=half, enc_len=half)
    if cfg.frontend == "vision":
        seq_len = seq_len  # patches are part of seq_len budget already
    return transformer.init_cache(cfg, batch, seq_len)


def pad_cache(cache, prefill_len: int, max_len: int):
    """Grow linear (non-ring) KV caches from prefill_len to max_len slots."""
    def f(x):
        if x.ndim >= 3 and x.shape[-3] == prefill_len:
            pad = [(0, 0)] * x.ndim
            pad[-3] = (0, max_len - prefill_len)
            return jnp.pad(x, pad)
        return x

    return jax.tree.map(f, cache)


def prefill(params, batch: Dict[str, jax.Array], cfg: ModelConfig):
    """Process the full prompt; returns (last-token logits (B, V), cache)."""
    if cfg.encdec:
        enc = encdec.encode(params, batch["frames"], cfg)
        x, cache = encdec.dec_forward(
            params, batch["tokens"], enc, cfg, return_cache=True
        )
    else:
        x, cache = transformer.forward(params, batch, cfg, return_cache=True)
    last = x[:, -1]
    logits = jnp.einsum(
        "bd,vd->bv", last, params["embed"], preferred_element_type=jnp.float32
    )
    logits = common.mask_padded_logits(logits, cfg.vocab)
    return sharding.constraint(logits, "batch", "vocab"), cache


def decode_step(params, cache, token: jax.Array, pos, cfg: ModelConfig):
    """One new token (B,) at position ``pos`` -> (logits (B, V), new cache)."""
    if cfg.encdec:
        return encdec.decode(params, cache, token, pos, cfg)
    return transformer.decode(params, cache, token, pos, cfg)
