"""Decoder-only transformer assembly for all families (dense/moe/ssm/hybrid/vlm).

Layers are stacked into scan *groups* following ``cfg.local_global``:
each group is ``n_local`` sliding-window layers followed by ``n_global``
full-attention layers (gemma3: 5+1; hymba: 15+1; uniform archs: 0+1).
Parameters carry a leading ``n_groups`` axis and the layer stack runs as
``lax.scan`` over groups (with an inner scan over the local stack), keeping
HLO size O(1) in depth; training wraps group bodies in ``jax.checkpoint``.

KV caches mirror the group structure:
  * global layers: linear cache of the full sequence length;
  * local layers: ring cache of ``window`` slots (slot = pos % window);
  * ssm/hybrid: Mamba-2 state + conv ring.
"""

from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro import sharding
from repro.models import common, moe, ssm
from repro.models.config import ModelConfig

ParamDef = common.ParamDef


# ---------------------------------------------------------------------------
# Param definitions
# ---------------------------------------------------------------------------


def attn_defs(cfg: ModelConfig) -> Dict[str, Any]:
    d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    defs = {
        "wq": ParamDef((d, h * hd), ("dmodel", "attn_flat")),
        "wk": ParamDef((d, kv * hd), ("dmodel", "attn_flat")),
        "wv": ParamDef((d, kv * hd), ("dmodel", "attn_flat")),
        "wo": ParamDef((h * hd, d), ("attn_flat", "dmodel")),
    }
    if cfg.qk_norm:
        defs["q_norm"] = common.rms_norm_def(hd)
        defs["k_norm"] = common.rms_norm_def(hd)
    return defs


def mlp_defs(cfg: ModelConfig) -> Dict[str, Any]:
    d, f = cfg.d_model, cfg.d_ff
    defs = {
        "w_up": ParamDef((d, f), ("dmodel", "ff")),
        "w_down": ParamDef((f, d), ("ff", "dmodel")),
    }
    if cfg.mlp in ("swiglu", "geglu"):
        defs["w_gate"] = ParamDef((d, f), ("dmodel", "ff"))
    return defs


def layer_defs(cfg: ModelConfig) -> Dict[str, Any]:
    d = cfg.d_model
    if cfg.family == "ssm":
        return {"ln1": common.rms_norm_def(d), "ssm": ssm.ssm_defs(cfg)}
    defs: Dict[str, Any] = {"ln1": common.rms_norm_def(d), "attn": attn_defs(cfg)}
    if cfg.family == "hybrid":
        defs["ssm"] = ssm.ssm_defs(cfg)
        defs["attn_out_norm"] = common.rms_norm_def(d)
        defs["ssm_out_norm"] = common.rms_norm_def(d)
    defs["ln2"] = common.rms_norm_def(d)
    defs["mlp"] = moe.moe_defs(cfg) if cfg.family == "moe" else mlp_defs(cfg)
    if cfg.post_norm:
        defs["post_ln1"] = common.rms_norm_def(d)
        defs["post_ln2"] = common.rms_norm_def(d)
    return defs


def _stack(defs, n: int):
    return jax.tree.map(
        lambda p: ParamDef((n,) + p.shape, (None,) + p.axes, p.init, p.scale, p.dtype),
        defs,
        is_leaf=lambda x: isinstance(x, ParamDef),
    )


def model_defs(cfg: ModelConfig) -> Dict[str, Any]:
    n_local, n_global = cfg.group_pattern
    group: Dict[str, Any] = {}
    if n_local:
        group["local"] = _stack(layer_defs(cfg), n_local)
    group["global"] = _stack(layer_defs(cfg), n_global)
    defs: Dict[str, Any] = {
        "embed": ParamDef((cfg.padded_vocab, cfg.d_model), ("vocab", "dmodel"), scale=1.0),
        "groups": _stack(group, cfg.n_groups),
        "final_norm": common.rms_norm_def(cfg.d_model),
    }
    if cfg.pos == "learned":
        defs["pos_embed"] = ParamDef((32768, cfg.d_model), (None, "dmodel"), scale=1.0)
    if cfg.frontend == "vision":
        defs["vision_proj"] = ParamDef((cfg.d_model, cfg.d_model), ("dmodel", "dmodel_act"))
    return defs


# ---------------------------------------------------------------------------
# Blocks
# ---------------------------------------------------------------------------


def _gathered(w, *axes):
    """FSDP weight gather: drop the dmodel shard at the use site so GSPMD
    all-gathers the (small) weights once per layer instead of all-reducing
    the (large) partial-sum activations of the contraction."""
    return sharding.constraint(w, *axes)


def _qkv(p, h_in, cfg: ModelConfig, positions):
    b, s, _ = h_in.shape
    hn, kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = sharding.constraint(h_in @ _gathered(p["wq"], None, "attn_flat"), "batch", None, "attn_flat")
    k = sharding.constraint(h_in @ _gathered(p["wk"], None, "attn_flat"), "batch", None, "attn_flat")
    v = sharding.constraint(h_in @ _gathered(p["wv"], None, "attn_flat"), "batch", None, "attn_flat")
    q = q.reshape(b, s, hn, hd)
    k = k.reshape(b, s, kv, hd)
    v = v.reshape(b, s, kv, hd)
    if cfg.qk_norm:
        q = common.rms_norm(q, p["q_norm"])
        k = common.rms_norm(k, p["k_norm"])
    if cfg.pos == "rope":
        q = common.rope(q, positions, cfg.rope_theta)
        k = common.rope(k, positions, cfg.rope_theta)
    return q, k, v


def attention_block(p, x, cfg: ModelConfig, *, window, positions):
    """Full attention sub-block for train/prefill. Returns (out, (k, v)).

    With the ``attn_tp`` rule active (EXPERIMENTS.md §Perf iteration 1) the
    KV heads are replicated across the model axis and expanded to the full
    query-head count, so every attention tile is head-local (Megatron-style
    GQA tensor parallelism, heads padded when H % 16 != 0).  Without it,
    non-divisible head counts make GSPMD shard the head_dim contraction and
    ALL-REDUCE every (bq × bk) logits tile — the dominant collective term of
    the baseline.
    """
    q, k, v = _qkv(p, x, cfg, positions)
    cache_kv = (k, v)
    if sharding.active_rule("attn_tp"):
        g = cfg.n_heads // cfg.n_kv_heads
        if g > 1:
            k = jnp.repeat(k, g, axis=2)
            v = jnp.repeat(v, g, axis=2)
        q = sharding.constraint(q, "batch", None, "heads_tp", None)
        k = sharding.constraint(k, "batch", None, "heads_tp", None)
        v = sharding.constraint(v, "batch", None, "heads_tp", None)
    o = common.blockwise_attention(
        q, k, v, causal=True, window=window, blk_q=cfg.attn_blk, blk_k=cfg.attn_blk
    )
    b, s, _, _ = o.shape
    o = sharding.constraint(o, "batch", None, "heads_tp", None)
    out = o.reshape(b, s, -1) @ _gathered(p["wo"], "attn_flat", None)
    return sharding.constraint(out, "batch", None, "dmodel_act"), cache_kv


def attention_decode(p, x, cache, cfg: ModelConfig, *, window, pos):
    """Single-token attention. x: (B, D). cache: {"k","v"} (B, C, KV, hd)."""
    b, _ = x.shape
    hn, kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = (x @ p["wq"]).reshape(b, 1, hn, hd)
    k_new = (x @ p["wk"]).reshape(b, 1, kv, hd)
    v_new = (x @ p["wv"]).reshape(b, 1, kv, hd)
    if cfg.qk_norm:
        q = common.rms_norm(q, p["q_norm"])
        k_new = common.rms_norm(k_new, p["k_norm"])
    if cfg.pos == "rope":
        pvec = jnp.full((b, 1), pos, jnp.int32)
        q = common.rope(q, pvec, cfg.rope_theta)
        k_new = common.rope(k_new, pvec, cfg.rope_theta)

    c = cache["k"].shape[1]
    slot = pos % c if window is not None else pos  # ring for local layers
    k_c = jax.lax.dynamic_update_slice_in_dim(cache["k"], k_new.astype(cache["k"].dtype), slot, axis=1)
    v_c = jax.lax.dynamic_update_slice_in_dim(cache["v"], v_new.astype(cache["v"].dtype), slot, axis=1)

    idx = jnp.arange(c)
    if window is not None:
        # slot i holds the most recent position t <= pos with t % C == i
        kv_pos = pos - ((pos - idx) % c)
    else:
        kv_pos = idx
    kv_pos = jnp.broadcast_to(kv_pos[None, :], (b, c))

    o = common.decode_gqa_attention(
        q[:, 0], k_c, v_c, kv_pos, pos, window=window
    )
    return o.reshape(b, -1) @ p["wo"], {"k": k_c, "v": v_c}


def mlp_block(p, x, cfg: ModelConfig):
    up = sharding.constraint(x @ _gathered(p["w_up"], None, "ff"), "batch", None, "ff")
    if cfg.mlp == "swiglu":
        h = common.silu(sharding.constraint(x @ _gathered(p["w_gate"], None, "ff"), "batch", None, "ff")) * up
    elif cfg.mlp == "geglu":
        h = jax.nn.gelu(sharding.constraint(x @ _gathered(p["w_gate"], None, "ff"), "batch", None, "ff")) * up
    else:
        h = jax.nn.gelu(up)
    return sharding.constraint(h @ _gathered(p["w_down"], "ff", None), "batch", None, "dmodel_act")


def layer_forward(p, x, cfg: ModelConfig, *, window, positions, want_cache=False):
    """One layer, train/prefill mode. Returns (x, cache_entry).

    The cache entry mirrors the decode-cache structure of :func:`init_cache`
    ({"attn": {"k","v"}} / {"ssm": ...}); attention caches are trimmed to the
    window later by :func:`_prefill_cache_from`.
    """
    if cfg.family == "ssm":
        h_in = common.rms_norm(x, p["ln1"])
        if want_cache:
            y, sc = ssm.ssm_forward(p["ssm"], h_in, cfg, return_cache=True)
            return x + y, {"ssm": sc}
        return x + ssm.ssm_forward(p["ssm"], h_in, cfg), 0

    h_in = common.rms_norm(x, p["ln1"])
    attn_out, (k, v) = attention_block(p["attn"], h_in, cfg, window=window, positions=positions)
    cache = {"attn": {"k": k, "v": v}} if want_cache else 0
    if cfg.family == "hybrid":
        if want_cache:
            ssm_out, sc = ssm.ssm_forward(p["ssm"], h_in, cfg, return_cache=True)
            cache["ssm"] = sc
        else:
            ssm_out = ssm.ssm_forward(p["ssm"], h_in, cfg)
        mixed = 0.5 * (
            common.rms_norm(attn_out, p["attn_out_norm"])
            + common.rms_norm(ssm_out, p["ssm_out_norm"])
        )
    else:
        mixed = attn_out
    if cfg.post_norm:
        mixed = common.rms_norm(mixed, p["post_ln1"])
    x = x + mixed

    h2 = common.rms_norm(x, p["ln2"])
    if cfg.family == "moe":
        m = moe.moe_layer(p["mlp"], h2, cfg)
    else:
        m = mlp_block(p["mlp"], h2, cfg)
    if cfg.post_norm:
        m = common.rms_norm(m, p["post_ln2"])
    return x + m, cache


def layer_decode(p, x, cache, cfg: ModelConfig, *, window, pos):
    """One layer, single-token decode. x: (B, D)."""
    if cfg.family == "ssm":
        y, new = ssm.ssm_decode_step(p["ssm"], common.rms_norm(x, p["ln1"]), cache["ssm"], cfg)
        return x + y, {"ssm": new}

    h_in = common.rms_norm(x, p["ln1"])
    attn_out, new_attn = attention_decode(p["attn"], h_in, cache["attn"], cfg, window=window, pos=pos)
    new_cache = {"attn": new_attn}
    if cfg.family == "hybrid":
        ssm_out, new_ssm = ssm.ssm_decode_step(p["ssm"], h_in, cache["ssm"], cfg)
        new_cache["ssm"] = new_ssm
        mixed = 0.5 * (
            common.rms_norm(attn_out, p["attn_out_norm"])
            + common.rms_norm(ssm_out, p["ssm_out_norm"])
        )
    else:
        mixed = attn_out
    if cfg.post_norm:
        mixed = common.rms_norm(mixed, p["post_ln1"])
    x = x + mixed

    h2 = common.rms_norm(x, p["ln2"])
    if cfg.family == "moe":
        m = moe.moe_layer(p["mlp"], h2[:, None, :], cfg)[:, 0]
    else:
        m = mlp_block(p["mlp"], h2[:, None, :], cfg)[:, 0]
    if cfg.post_norm:
        m = common.rms_norm(m, p["post_ln2"])
    return x + m, new_cache


# ---------------------------------------------------------------------------
# Cache construction
# ---------------------------------------------------------------------------


def _attn_cache_spec(cfg: ModelConfig, batch: int, length: int, dtype):
    kv, hd = cfg.n_kv_heads, cfg.head_dim
    return {
        "k": jnp.zeros((batch, length, kv, hd), dtype),
        "v": jnp.zeros((batch, length, kv, hd), dtype),
    }


def _layer_cache(cfg: ModelConfig, batch: int, *, window, seq_len: int, dtype):
    if cfg.family == "ssm":
        return {"ssm": ssm.ssm_init_cache(cfg, batch, dtype)}
    length = min(window, seq_len) if window is not None else seq_len
    c = {"attn": _attn_cache_spec(cfg, batch, length, dtype)}
    if cfg.family == "hybrid":
        c["ssm"] = ssm.ssm_init_cache(cfg, batch, dtype)
    return c


def init_cache(cfg: ModelConfig, batch: int, seq_len: int):
    """Decode cache pytree matching the scan-group structure."""
    dtype = cfg.jax_dtype
    n_local, n_global = cfg.group_pattern

    def stack(tree, n):
        return jax.tree.map(lambda a: jnp.broadcast_to(a[None], (n,) + a.shape), tree)

    group: Dict[str, Any] = {}
    if n_local:
        group["local"] = stack(
            _layer_cache(cfg, batch, window=cfg.window, seq_len=seq_len, dtype=dtype), n_local
        )
    group["global"] = stack(
        _layer_cache(cfg, batch, window=None, seq_len=seq_len, dtype=dtype), n_global
    )
    return stack(group, cfg.n_groups)


# ---------------------------------------------------------------------------
# Full model: embed -> groups -> norm
# ---------------------------------------------------------------------------


def embed_inputs(params, batch: Dict[str, jax.Array], cfg: ModelConfig):
    """Token (+ modality stub) embedding. Returns (x, positions)."""
    tokens = batch["tokens"]
    x = params["embed"][tokens].astype(cfg.jax_dtype) * (cfg.d_model ** 0.5)
    if cfg.frontend == "vision":
        patches = batch["patches"].astype(cfg.jax_dtype)  # (B, P, D) stub embeds
        px = patches @ params["vision_proj"]
        x = jnp.concatenate([px, x], axis=1)
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s)[None, :], (b, s))
    if cfg.pos == "learned":
        x = x + params["pos_embed"][:s][None].astype(x.dtype)
    return sharding.constraint(x, "batch", None, "dmodel_act"), positions


def forward(params, batch, cfg: ModelConfig, *, train: bool = False, return_cache: bool = False):
    """Run the decoder stack. Returns (hidden (B,S,D), cache or None)."""
    x, positions = embed_inputs(params, batch, cfg)
    n_local, _ = cfg.group_pattern

    def group_body(x, gp):
        caches = {}

        if n_local:
            def local_body(xc, lp):
                out, c = layer_forward(lp, xc, cfg, window=cfg.window,
                                       positions=positions, want_cache=return_cache)
                return out, c

            x, local_c = jax.lax.scan(local_body, x, gp["local"])
            caches["local"] = local_c

        def global_body(xc, lp):
            out, c = layer_forward(lp, xc, cfg, window=None,
                                   positions=positions, want_cache=return_cache)
            return out, c

        x, global_c = jax.lax.scan(global_body, x, gp["global"])
        caches["global"] = global_c
        return x, caches

    x, caches = common.remat_scan(group_body, x, params["groups"], train=train)
    x = common.rms_norm(x, params["final_norm"])

    if not return_cache:
        return x, None
    return x, _prefill_cache_from(caches, cfg)


def _prefill_cache_from(caches, cfg: ModelConfig):
    """Trim attention caches of local layers to the ring window.

    Prefill length S is a multiple of the window, so positions S-W..S-1 land
    on ring slots 0..W-1 in order — a plain tail slice is ring-aligned.
    """

    def trim(group_cache, window):
        if window is None or "attn" not in group_cache:
            return group_cache
        out = dict(group_cache)
        attn = group_cache["attn"]
        length = attn["k"].shape[-3]
        w = min(window, length)

        def ring(x):
            # tail positions L-w..L-1 must land on slots t % w; tail index i
            # holds position L-w+i whose slot is (i + L) % w -> roll by L % w.
            t = x[..., -w:, :, :]
            return jnp.roll(t, shift=length % w, axis=-3)

        out["attn"] = {"k": ring(attn["k"]), "v": ring(attn["v"])}
        return out

    out = {}
    if "local" in caches:
        out["local"] = trim(caches["local"], cfg.window)
    out["global"] = trim(caches["global"], None)
    return out


def decode(params, cache, token: jax.Array, pos, cfg: ModelConfig):
    """One decode step. token: (B,) int32. Returns (logits (B, V), cache)."""
    x = params["embed"][token].astype(cfg.jax_dtype) * (cfg.d_model ** 0.5)
    if cfg.pos == "learned":
        x = x + params["pos_embed"][pos][None].astype(x.dtype)

    n_local, _ = cfg.group_pattern

    def group_body(x, scan_in):
        gp, gc = scan_in
        new_c = {}
        if n_local:
            def local_body(xc, inp):
                lp, lc = inp
                out, c = layer_decode(lp, xc, lc, cfg, window=cfg.window, pos=pos)
                return out, c

            x, nc = jax.lax.scan(local_body, x, (gp["local"], gc["local"]))
            new_c["local"] = nc

        def global_body(xc, inp):
            lp, lc = inp
            out, c = layer_decode(lp, xc, lc, cfg, window=None, pos=pos)
            return out, c

        x, ngc = jax.lax.scan(global_body, x, (gp["global"], gc["global"]))
        new_c["global"] = ngc
        return x, new_c

    x, new_cache = jax.lax.scan(group_body, x, (params["groups"], cache))
    x = common.rms_norm(x, params["final_norm"])
    logits = jnp.einsum(
        "bd,vd->bv", x, params["embed"], preferred_element_type=jnp.float32
    )
    logits = common.mask_padded_logits(logits, cfg.vocab)
    return sharding.constraint(logits, "batch", "vocab"), new_cache
