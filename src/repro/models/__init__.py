"""Multi-architecture JAX model substrate (data plane)."""
from repro.models.config import ModelConfig  # noqa: F401
from repro.models import model  # noqa: F401
