"""Mixture-of-Experts layer: top-k router + capacity-based dispatch.

Dispatch uses sort/gather/scatter (static shapes, no one-hot dispatch
einsums), so compiled HLO FLOPs stay ≈ active-expert FLOPs × capacity
factor — this matters for the roofline analysis (DESIGN.md §4).

Tokens are routed *locally* per data shard (routing is per-token, hence
embarrassingly data-parallel); expert weights are TP-sharded on the ff axis
and FSDP-sharded on d_model, exactly like dense MLP weights.  An
expert-parallel (all-to-all) variant is explored in EXPERIMENTS.md §Perf.
"""

from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro import sharding
from repro.models import common
from repro.models.config import ModelConfig

ParamDef = common.ParamDef


def moe_defs(cfg: ModelConfig) -> Dict[str, ParamDef]:
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    gated = cfg.mlp in ("swiglu", "geglu")
    defs = {
        "router": ParamDef((d, e), ("dmodel", None), dtype="float32"),
        "w_up": ParamDef((e, d, f), (None, "dmodel", "ff")),
        "w_down": ParamDef((e, f, d), (None, "ff", "dmodel")),
    }
    if gated:
        defs["w_gate"] = ParamDef((e, d, f), (None, "dmodel", "ff"))
    return defs


def _expert_ffn_batched(p, x, cfg: ModelConfig):
    """Batched expert MLP. x: (B, E, C, D) -> (B, E, C, D).

    Weights are explicitly gathered over the FSDP (dmodel) shard at the use
    site (see transformer._gathered): contracting against dmodel-sharded
    weights would otherwise all-reduce the large expert activations.
    """
    g = lambda w: sharding.constraint(w, "experts", None, "ff")
    gd = lambda w: sharding.constraint(w, "experts", "ff", None)
    up = jnp.einsum("becd,edf->becf", x, g(p["w_up"]))
    if cfg.mlp == "swiglu":
        gate = jnp.einsum("becd,edf->becf", x, g(p["w_gate"]))
        h = common.silu(gate) * up
    elif cfg.mlp == "geglu":
        gate = jnp.einsum("becd,edf->becf", x, g(p["w_gate"]))
        h = jax.nn.gelu(gate) * up
    else:
        h = jax.nn.gelu(up)
    h = sharding.constraint(h, "batch", "experts", None, "ff")
    return jnp.einsum("becf,efd->becd", h, gd(p["w_down"]))


def moe_layer(p, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    """x: (B, S, D) -> (B, S, D).

    Dispatch is PER BATCH ROW: every op (top-k, sort, gather, scatter,
    batched expert matmul) keeps the leading B axis, so the layer shards
    cleanly over the data axis with zero cross-row communication — flattening
    (B, S) -> T would force a global sort and replicate the dispatch buffers
    across the mesh (catastrophic for a 314B MoE, see EXPERIMENTS.md).
    Per-row capacity = ceil(S·k/E · capacity_factor); overflow tokens drop
    (GShard semantics).
    """
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.topk

    # the f32 router cast must not leak f32 cotangents into the residual
    # stream (doubles every backward collective) — see §Perf/bf16grad
    xr = common.grad_dtype_barrier(x) if sharding.active_rule("bf16_grad") else x
    logits = jnp.einsum(
        "bsd,de->bse", xr.astype(jnp.float32), p["router"].astype(jnp.float32)
    )
    probs = jax.nn.softmax(logits, axis=-1)
    w, idx = jax.lax.top_k(probs, k)  # (B, S, k)
    w = w / jnp.sum(w, axis=-1, keepdims=True)

    # (token-in-row, slot) pairs sorted by expert, per row
    eid = idx.reshape(b, s * k)
    tid = jnp.broadcast_to(jnp.repeat(jnp.arange(s), k)[None], (b, s * k))
    wgt = w.reshape(b, s * k)
    order = jnp.argsort(eid, axis=-1)
    eid_s = jnp.take_along_axis(eid, order, axis=-1)
    tid_s = jnp.take_along_axis(tid, order, axis=-1)
    w_s = jnp.take_along_axis(wgt, order, axis=-1)

    # position of each entry within its expert (per row)
    counts = jnp.sum(
        (idx[..., None] == jnp.arange(e)).reshape(b, s * k, e), axis=1
    )  # (B, E)
    start = jnp.cumsum(counts, axis=-1) - counts  # exclusive prefix (B, E)
    pos = jnp.arange(s * k)[None, :] - jnp.take_along_axis(start, eid_s, axis=-1)

    cap = int(s * k / e * cfg.capacity_factor)
    cap = max(k, -(-cap // 4) * 4) if s > 1 else max(1, k // e + 1)
    keep = pos < cap
    # slot id of each kept (sorted) entry; kept slots are strictly increasing,
    # which lets every data movement below be a batched GATHER — scatters
    # with explicit index arrays defeat GSPMD's batch-dim detection and
    # replicate the dispatch buffers across the mesh.
    slot = jnp.where(keep, eid_s * cap + pos, e * cap)

    # invert: which sorted entry fills slot s_idx (exact-match gather)
    slot_ids = jnp.arange(e * cap)
    entry_of_slot = jax.vmap(lambda sl: jnp.searchsorted(sl, slot_ids))(slot)
    entry_of_slot = jnp.minimum(entry_of_slot, s * k - 1)  # (B, E*cap)
    slot_hit = jnp.take_along_axis(slot, entry_of_slot, axis=-1) == slot_ids[None]

    tok_of_slot = jnp.take_along_axis(tid_s, entry_of_slot, axis=-1)  # (B, E*cap)
    expert_in = jnp.take_along_axis(x, tok_of_slot[..., None], axis=1)
    # NB: zero literal must match dtype — a python 0.0 would promote the
    # whole expert path to f32 and double every collective.
    expert_in = jnp.where(slot_hit[..., None], expert_in, jnp.zeros((), x.dtype))
    expert_in = expert_in.reshape(b, e, cap, d)
    expert_in = sharding.constraint(expert_in, "batch", "experts", None, "dmodel_act")
    expert_out = _expert_ffn_batched(p, expert_in, cfg).reshape(b, e * cap, d)

    # route outputs back: sorted entry -> its slot -> original (token, k) lane
    out_sorted = jnp.take_along_axis(
        expert_out, jnp.minimum(slot, e * cap - 1)[..., None], axis=1
    )  # (B, S*k, D)
    out_sorted = out_sorted * (w_s * keep).astype(x.dtype)[..., None]
    inv_order = jnp.argsort(order, axis=-1)  # sorted position of entry (t*k + j)
    contrib = jnp.take_along_axis(out_sorted, inv_order[..., None], axis=1)
    return jnp.sum(contrib.reshape(b, s, k, d), axis=2)


def moe_layer_ref(p, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    """Dense-dispatch oracle (no capacity drops): loops experts, masks tokens."""
    b, s, d = x.shape
    xf = x.reshape(-1, d)
    logits = xf.astype(jnp.float32) @ p["router"].astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    w, idx = jax.lax.top_k(probs, cfg.topk)
    w = w / jnp.sum(w, axis=-1, keepdims=True)
    y = jnp.zeros_like(xf)
    for e in range(cfg.n_experts):
        pe = {kk: (vv[e] if kk != "router" else vv) for kk, vv in p.items()}
        he = _expert_ffn_batched(
            {kk: vv[None] for kk, vv in pe.items() if kk != "router"},
            xf[None, None],
            cfg,
        )[0, 0]
        weight = jnp.sum(jnp.where(idx == e, w, 0.0), axis=-1)  # (T,)
        y = y + he * weight.astype(xf.dtype)[:, None]
    return y.reshape(b, s, d)
