"""Production mesh construction (TPU v5e pods; host-placeholder in dry-run).

``make_production_mesh`` is a FUNCTION (never module-level state) so that
importing this module never touches jax device state — the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import and only then builds the mesh.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (data=16, model=16) = 256 chips.  Multi-pod adds pod=2."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes)
    )


def make_host_mesh():
    """Degenerate 1×1 mesh over the local device (tests / examples)."""
    return jax.make_mesh(
        (1, 1), ("data", "model"),
        axis_types=(jax.sharding.AxisType.Auto, jax.sharding.AxisType.Auto),
    )


MODEL_AXIS_SIZE = 16
DATA_AXIS_SIZE = 16
POD_AXIS_SIZE = 2
