"""Assigned input shapes and ShapeDtypeStruct input specs (no allocation).

Shape-to-batch mapping per family (DESIGN.md §3):
  * decoder-only: tokens (B, S)
  * vlm: 256 patch embeddings + (S - 256) text tokens  (total budget = S)
  * audio (enc-dec): encoder frames S//2 + decoder tokens S//2
Decode shapes build a serve_step over a KV cache of the full seq_len.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models import model
from repro.models.config import ModelConfig


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES: Dict[str, InputShape] = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}


def batch_specs(cfg: ModelConfig, shape: InputShape, *, with_labels: bool):
    """ShapeDtypeStructs for the data batch of a train/prefill step."""
    b, s = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    dt = cfg.jax_dtype
    if cfg.encdec:
        half = s // 2
        out = {
            "frames": jax.ShapeDtypeStruct((b, half, cfg.d_model), dt),
            "tokens": jax.ShapeDtypeStruct((b, half), i32),
        }
        if with_labels:
            out["labels"] = jax.ShapeDtypeStruct((b, half), i32)
        return out
    if cfg.frontend == "vision":
        text = s - cfg.num_patches
        out = {
            "patches": jax.ShapeDtypeStruct((b, cfg.num_patches, cfg.d_model), dt),
            "tokens": jax.ShapeDtypeStruct((b, text), i32),
        }
        if with_labels:
            out["labels"] = jax.ShapeDtypeStruct((b, text), i32)
        return out
    out = {"tokens": jax.ShapeDtypeStruct((b, s), i32)}
    if with_labels:
        out["labels"] = jax.ShapeDtypeStruct((b, s), i32)
    return out


def cache_specs(cfg: ModelConfig, shape: InputShape):
    """Abstract decode-cache pytree (jax.eval_shape — zero allocation)."""
    return jax.eval_shape(
        lambda: model.init_cache(cfg, shape.global_batch, shape.seq_len)
    )


def decode_specs(cfg: ModelConfig, shape: InputShape):
    b = shape.global_batch
    return {
        "cache": cache_specs(cfg, shape),
        "token": jax.ShapeDtypeStruct((b,), jnp.int32),
        "pos": jax.ShapeDtypeStruct((), jnp.int32),
    }
