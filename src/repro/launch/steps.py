"""Step functions (train / prefill / decode) with sharding specs.

``build_step(cfg, shape, mesh, multi_pod)`` returns (fn, arg_specs,
in_shardings) ready for ``jax.jit(fn, in_shardings=...).lower(*specs)``.
Sharding rules follow DESIGN.md §4: batch -> (pod, data); ff/vocab/attn
projections -> model; FSDP d_model -> data; long_500k (B=1) shards the KV
cache sequence axis over data instead of the batch.
"""

from __future__ import annotations

import functools
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import sharding
from repro.launch import shapes as shapes_lib
from repro.models import model
from repro.models.config import ModelConfig
from repro.optim import adamw_init, adamw_update, cosine_schedule


def rules_for(cfg: ModelConfig, shape, *, multi_pod: bool, overrides=None):
    r = sharding.default_rules(
        multi_pod=multi_pod,
        n_heads=cfg.n_heads,
        n_kv_heads=cfg.n_kv_heads,
        model_axis=16,
        batch_shardable=shape.global_batch >= (32 if multi_pod else 16),
        shard_kv_seq=shape.global_batch == 1,
        # FSDP only makes sense in training (amortises optimizer state);
        # at inference weights stay TP-only, else every decode step would
        # re-gather the FSDP shard (dominates the collective roofline term).
        # Exception: models whose TP-sharded weights alone exceed ~12 GiB per
        # chip (grok-1: 631 GiB bf16 / 16 = 39 GiB) must weight-shard over
        # data at inference as well.
        fsdp=shape.kind == "train" or cfg.param_count() * 2 / 16 > 12e9,
    )
    r["attn_flat"] = "model"  # flattened head*dim projections always divide
    if cfg.ssm_nheads and cfg.ssm_nheads % 16 != 0:
        r["ssm_heads"] = None  # per-head scalars replicate when not divisible
    if cfg.ssm_dinner and (cfg.ssm_dinner % 16 or (cfg.ssm_dinner // 16) % cfg.ssm_headdim):
        r["ssm_inner"] = None  # shard only when shards stay head-aligned
    if overrides:
        r.update(overrides)
    return r


def _batch_sharding(cfg, shape, rules):
    """PartitionSpec tree for the data batch."""
    batch_axes = rules.get("batch")

    def spec(s):
        ndim = len(s.shape)
        return P(batch_axes, *([None] * (ndim - 1)))

    return jax.tree.map(spec, shapes_lib.batch_specs(cfg, shape, with_labels=True))


def _cache_sharding(cfg, shape, rules):
    """PartitionSpec tree for the decode cache, matched by leaf path."""
    abstract = shapes_lib.cache_specs(cfg, shape)
    batch = rules.get("batch")
    kv_seq = rules.get("kv_seq")
    kvh = rules.get("kv_heads")
    kvd = rules.get("kv_head_dim")
    ssmh = rules.get("ssm_heads")

    def leaf_spec(path, leaf):
        keys = [getattr(k, "key", getattr(k, "name", str(k))) for k in path]
        nd = len(leaf.shape)
        if "state" in keys:  # ssm state (..., B, H, N, P)
            return P(*([None] * (nd - 4)), batch, ssmh, None, None)
        if "conv" in keys:  # conv ring (..., B, W, C)
            return P(*([None] * (nd - 3)), batch, None, None)
        # attention k/v: (..., B, C, KV, hd)
        return P(*([None] * (nd - 4)), batch, kv_seq, kvh, kvd)

    return jax.tree_util.tree_map_with_path(leaf_spec, abstract)


def build_step(cfg: ModelConfig, shape, *, multi_pod: bool, rule_overrides=None):
    """Returns (step_fn, example_args (ShapeDtypeStructs), in_shardings)."""
    rules = rules_for(cfg, shape, multi_pod=multi_pod, overrides=rule_overrides)
    with sharding.use_rules(rules):
        pspecs = model.param_specs(cfg)
        pstructs = model.abstract_params(cfg)

        if shape.kind == "train":
            batch_structs = shapes_lib.batch_specs(cfg, shape, with_labels=True)
            batch_shard = _batch_sharding(cfg, shape, rules)
            opt_structs = jax.eval_shape(
                lambda p: adamw_init(p, cfg.opt_dtype), pstructs
            )
            opt_shard = {
                "m": pspecs,
                "v": pspecs,
                "step": P(),
            }

            def train_step(params, opt_state, batch):
                with sharding.use_rules(rules):
                    na = cfg.grad_accum

                    if na == 1:
                        lval, grads = jax.value_and_grad(
                            lambda p: model.loss_fn(p, batch, cfg)
                        )(params)
                    else:
                        # gradient accumulation: scan over microbatches keeps
                        # activation transients at 1/na of the global batch
                        micro = jax.tree.map(
                            lambda a: a.reshape((na, a.shape[0] // na) + a.shape[1:]),
                            batch,
                        )

                        def micro_step(acc, mb):
                            l, g = jax.value_and_grad(
                                lambda p: model.loss_fn(p, mb, cfg)
                            )(params)
                            acc_l, acc_g = acc
                            return (acc_l + l / na,
                                    jax.tree.map(lambda a, b: a + b / na, acc_g, g)), None

                        zero_g = jax.tree.map(jnp.zeros_like, params)
                        (lval, grads), _ = jax.lax.scan(
                            micro_step, (jnp.float32(0.0), zero_g), micro
                        )

                    lr = cosine_schedule(
                        opt_state["step"], peak_lr=3e-4, warmup=2000, total=100_000
                    )
                    new_p, new_o = adamw_update(params, grads, opt_state, lr=lr)
                    return new_p, new_o, {"loss": lval}

            args = (pstructs, opt_structs, batch_structs)
            in_shard = (pspecs, opt_shard, batch_shard)
            out_shard = (pspecs, opt_shard, {"loss": P()})
            return train_step, args, in_shard, out_shard

        if shape.kind == "prefill":
            batch_structs = shapes_lib.batch_specs(cfg, shape, with_labels=False)
            batch_shard = _batch_sharding(cfg, shape, rules)
            batch_shard = {k: batch_shard[k] for k in batch_structs}

            def prefill_step(params, batch):
                with sharding.use_rules(rules):
                    logits, cache = model.prefill(params, batch, cfg)
                    return logits, cache

            args = (pstructs, batch_structs)
            in_shard = (pspecs, batch_shard)
            cache_shard = _cache_sharding(
                cfg,
                shapes_lib.InputShape(shape.name, shape.seq_len, shape.global_batch, "decode"),
                rules,
            )
            out_shard = (P(rules.get("batch"), rules.get("vocab")), cache_shard)
            return prefill_step, args, in_shard, out_shard

        # decode
        dec = shapes_lib.decode_specs(cfg, shape)
        cache_shard = _cache_sharding(cfg, shape, rules)
        tok_shard = P(rules.get("batch"))

        def serve_step(params, cache, token, pos):
            with sharding.use_rules(rules):
                logits, new_cache = model.decode_step(params, cache, token, pos, cfg)
                return logits, new_cache

        args = (pstructs, dec["cache"], dec["token"], dec["pos"])
        in_shard = (pspecs, cache_shard, tok_shard, P())
        out_shard = (P(rules.get("batch"), rules.get("vocab")), cache_shard)
        return serve_step, args, in_shard, out_shard
