"""Roofline-term extraction from compiled HLO text.

XLA's ``compiled.cost_analysis()`` on the CPU backend counts each while-loop
body ONCE, but our models scan over layer groups / attention tiles / SSD
chunks — so every interesting FLOP lives inside a while.  This module
re-derives trip-count-weighted totals by parsing ``compiled.as_text()``:

  * computations are parsed into per-op symbol tables (name -> shape);
  * ``while`` ops are resolved to their condition computation, whose largest
    integer constant is taken as the trip count (scan bounds compile to a
    ``compare(induction, constant(N))``);
  * FLOPs are counted for ``dot``/``convolution`` ops
    (2 × |result| × contraction), weighted by the product of enclosing
    trip counts;
  * collective bytes sum the result sizes of all-reduce / all-gather /
    reduce-scatter / all-to-all / collective-permute, trip-weighted —
    a per-chip ICI traffic proxy (ring algorithms move ≈|result| bytes
    through each chip);
  * HBM bytes are approximated as trip-weighted dot operand+result traffic
    plus entry argument bytes (params/caches read once per step).

All numbers are PER DEVICE (the compiled module is the per-device SPMD
program).  Methodology caveats are documented in EXPERIMENTS.md §Roofline.
"""

from __future__ import annotations

import dataclasses
import math
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_SHAPE_RE = re.compile(r"(pred|s8|u8|s16|u16|bf16|f16|s32|u32|f32|s64|u64|f64|c64|c128)\[([\d,]*)\]")
_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all", "collective-permute")


def _shape_bytes(type_str: str) -> int:
    """Total bytes of all array shapes appearing in an HLO type string."""
    total = 0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _shape_dims(type_str: str) -> Optional[List[int]]:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return None
    dims = m.group(2)
    return [int(d) for d in dims.split(",")] if dims else []


@dataclasses.dataclass
class Computation:
    name: str
    lines: List[str]
    shapes: Dict[str, str]          # %op name -> result type string
    whiles: List[Tuple[str, str]]   # (condition comp, body comp)


def _parse_computations(hlo: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    header_re = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\((.*)\)\s*->.*\{")
    for raw in hlo.splitlines():
        line = raw.rstrip()
        m = header_re.match(line)
        if m and not line.startswith(" "):
            cur = Computation(m.group(1), [], {}, [])
            comps[cur.name] = cur
            # parameters: "name: f32[...]" pairs
            for pm in re.finditer(r"([\w\.\-]+):\s*((?:\([^)]*\)|[\w\[\],{}\s/]+?))(?:,|$)", m.group(2)):
                cur.shapes["%" + pm.group(1)] = pm.group(2)
            continue
        if cur is None:
            continue
        if line.startswith("}"):
            cur = None
            continue
        s = line.strip()
        dm = re.match(r"(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*((?:\([^)]*\)|\S+))\s+([\w\-]+)\(", s)
        if dm:
            cur.shapes["%" + dm.group(1)] = dm.group(2)
        cur.lines.append(s)
        if re.search(r"\bwhile\(", s):
            cm = re.search(r"condition=%?([\w\.\-]+)", s)
            bm = re.search(r"body=%?([\w\.\-]+)", s)
            if cm and bm:
                cur.whiles.append((cm.group(1), bm.group(1)))
    return comps


def _trip_count(comps: Dict[str, Computation], cond_name: str) -> int:
    cond = comps.get(cond_name)
    if cond is None:
        return 1
    best = 1
    for line in cond.lines:
        for c in re.findall(r"constant\((\d+)\)", line):
            best = max(best, int(c))
    return best


def _multipliers(comps: Dict[str, Computation], entry: str) -> Dict[str, int]:
    """Computation -> product of enclosing while trip counts."""
    mult: Dict[str, int] = {entry: 1}
    # call graph: while bodies/conditions, fusions, calls
    stack = [entry]
    seen = set()
    while stack:
        name = stack.pop()
        if name in seen:
            continue
        seen.add(name)
        comp = comps.get(name)
        if comp is None:
            continue
        m = mult.get(name, 1)
        for cond, body in comp.whiles:
            trips = _trip_count(comps, cond)
            for child in (body, cond):
                mult[child] = max(mult.get(child, 0), m * trips)
                stack.append(child)
        # other computation references (fusions, reduces, calls, maps)
        for line in comp.lines:
            for ref in re.findall(r"(?:calls|to_apply|fusion)=%?([\w\.\-]+)", line):
                mult[ref] = max(mult.get(ref, 0), m)
                stack.append(ref)
    return mult


def _split_operands(s: str) -> List[str]:
    """Split an HLO operand list at top-level commas (shape dims contain ',').

    A close paren at depth 0 is the end of the operand list itself — the
    caller's greedy capture may run past it into trailing attributes (e.g.
    paren-containing ``metadata={op_name="jit(f)/..."}``), which must not
    leak into the last operand.
    """
    parts: List[str] = []
    depth = 0
    cur: List[str] = []
    for ch in s:
        if ch in "([{":
            depth += 1
        elif ch in ")]}":
            if depth == 0:
                break  # closing paren of the operand list
            depth -= 1
        if ch == "," and depth == 0:
            parts.append("".join(cur).strip())
            cur = []
        else:
            cur.append(ch)
    if cur:
        parts.append("".join(cur).strip())
    return parts


def _operand_type(comp: Computation, operand: str) -> str:
    """Type string of one operand.

    Compiled modules inline operand types (``f32[32,256]{1,0} %copy.1``);
    unoptimized ones reference bare names (``%copy.1``) resolved via the
    computation's symbol table.
    """
    if _SHAPE_RE.search(operand):
        return operand
    name = operand.split()[-1] if operand else ""
    return comp.shapes.get(name if name.startswith("%") else "%" + name, "")


def _dot_flops(comp: Computation, line: str) -> int:
    dm = re.match(r"(?:ROOT\s+)?%?[\w\.\-]+\s*=\s*(\S+)\s+dot\((.*)\)", line)
    if not dm:
        return 0
    result_dims = _shape_dims(dm.group(1))
    if result_dims is None:
        return 0
    out_elems = math.prod(result_dims) if result_dims else 1
    # contraction size from lhs shape + lhs_contracting_dims
    ops = _split_operands(dm.group(2))
    lhs_dims = _shape_dims(_operand_type(comp, ops[0])) if ops else None
    cm = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", line)
    contract = 1
    if lhs_dims and cm and cm.group(1):
        for d in cm.group(1).split(","):
            di = int(d)
            if di < len(lhs_dims):
                contract *= lhs_dims[di]
    return 2 * out_elems * contract


def _conv_flops(comp: Computation, line: str) -> int:
    dm = re.match(r"(?:ROOT\s+)?%?[\w\.\-]+\s*=\s*(\S+)\s+convolution\((.*)\)", line)
    if not dm:
        return 0
    result_dims = _shape_dims(dm.group(1))
    if result_dims is None:
        return 0
    ops = _split_operands(dm.group(2))
    rhs_dims = (
        _shape_dims(_operand_type(comp, ops[1])) if len(ops) > 1 else None
    ) or [1]
    return 2 * math.prod(result_dims) * math.prod(rhs_dims[:-1])


@dataclasses.dataclass
class HLOAnalysis:
    flops: float                 # trip-weighted dot/conv FLOPs, per device
    collective_bytes: float      # trip-weighted collective result bytes, per device
    dot_bytes: float             # trip-weighted dot operand+result bytes
    argument_bytes: float        # entry argument bytes (params/caches)
    collective_breakdown: Dict[str, float]
    collective_count: int


def analyze(hlo: str) -> HLOAnalysis:
    comps = _parse_computations(hlo)
    entry = None
    for line in hlo.splitlines():
        if line.startswith("ENTRY"):
            m = re.match(r"ENTRY\s+%?([\w\.\-]+)", line)
            if m:
                entry = m.group(1)
    if entry is None:
        raise ValueError("no ENTRY computation found")
    mult = _multipliers(comps, entry)

    flops = 0.0
    coll_bytes = 0.0
    dot_bytes = 0.0
    breakdown: Dict[str, float] = {c: 0.0 for c in _COLLECTIVES}
    count = 0

    for name, comp in comps.items():
        m = mult.get(name, 1)
        for line in comp.lines:
            if " dot(" in line:
                f = _dot_flops(comp, line)
                flops += m * f
                dm = re.match(r"(?:ROOT\s+)?%?[\w\.\-]+\s*=\s*(\S+)\s+dot\((.*)\)", line)
                if dm:
                    b = _shape_bytes(dm.group(1))
                    for o in _split_operands(dm.group(2)):
                        b += _shape_bytes(_operand_type(comp, o))
                    dot_bytes += m * b
            elif " convolution(" in line:
                flops += m * _conv_flops(comp, line)
            else:
                for c in _COLLECTIVES:
                    if f" {c}(" in line or f" {c}-start(" in line:
                        dm = re.match(r"(?:ROOT\s+)?%?[\w\.\-]+\s*=\s*((?:\([^)]*\)|\S+))\s", line)
                        if dm:
                            b = _shape_bytes(dm.group(1))
                            coll_bytes += m * b
                            breakdown[c] += m * b
                            count += 1
                        break

    arg_bytes = 0.0
    ec = comps.get(entry)
    if ec:
        for k, v in ec.shapes.items():
            if re.match(r"%(arg|Arg|param)", k, re.IGNORECASE):
                arg_bytes += _shape_bytes(v)

    return HLOAnalysis(
        flops=flops,
        collective_bytes=coll_bytes,
        dot_bytes=dot_bytes,
        argument_bytes=arg_bytes,
        collective_breakdown={k: v for k, v in breakdown.items() if v},
        collective_count=count,
    )
