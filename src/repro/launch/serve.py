"""Serving launcher: batched requests through the MIG-scheduled engine.

Example (CPU, reduced config):
    PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-1b \
        --requests 24 --gpus 4 --policy mfi
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import SMOKES
from repro.models import model
from repro.serving import Request, ServingEngine
from repro.sim import distributions


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--gpus", type=int, default=4)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--policy", default="mfi")
    ap.add_argument("--distribution", default="uniform")
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = SMOKES[args.arch]
    params = model.init_params(cfg, jax.random.PRNGKey(args.seed))
    rng = np.random.default_rng(args.seed)

    from repro.core import mig

    profiles = distributions.sample_profiles(args.distribution, args.requests, rng)
    requests = [
        Request(
            request_id=i,
            prompt=rng.integers(0, cfg.vocab, args.prompt_len).astype(np.int32),
            max_new_tokens=args.new_tokens,
            profile=mig.PROFILE_NAMES[profiles[i]],
        )
        for i in range(args.requests)
    ]

    engine = ServingEngine(
        cfg, params, num_slots=args.slots,
        max_len=args.prompt_len + args.new_tokens + 1,
        num_gpus=args.gpus, policy=args.policy,
    )
    t0 = time.time()
    stats = engine.run(requests)
    dt = time.time() - t0
    done = sum(r.finished and r.admitted for r in requests)
    toks = sum(len(r.output or []) for r in requests)
    print(f"[serve] policy={args.policy} served={done}/{args.requests} "
          f"tokens={toks} in {dt:.1f}s ({toks/max(dt,1e-9):.1f} tok/s)")
    print(f"[serve] scheduler stats: {stats}")


if __name__ == "__main__":
    main()
