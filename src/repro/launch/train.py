"""Training launcher: real steps on the local device, or production-mesh
lowering via --dryrun (see dryrun.py for the full multi-pod sweep).

Example (CPU, reduced config):
    PYTHONPATH=src python -m repro.launch.train --arch llama3.2-1b --smoke \
        --steps 50 --batch 8 --seq 128
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import save_checkpoint
from repro.configs import ARCHS, SMOKES
from repro.data import make_batch_iterator
from repro.models import model
from repro.optim import adamw_init, adamw_update, cosine_schedule


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true", help="use the reduced config")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--checkpoint", default=None)
    args = ap.parse_args()

    cfg = SMOKES[args.arch] if args.smoke else ARCHS[args.arch]
    print(f"[train] {cfg.name}: {cfg.param_count()/1e6:.1f}M params")

    params = model.init_params(cfg, jax.random.PRNGKey(args.seed))
    opt = adamw_init(params, cfg.opt_dtype)
    data = make_batch_iterator(cfg, args.batch, args.seq, seed=args.seed)

    @jax.jit
    def step(params, opt, batch):
        loss, grads = jax.value_and_grad(lambda p: model.loss_fn(p, batch, cfg))(params)
        lr = cosine_schedule(opt["step"], peak_lr=args.lr, warmup=10, total=args.steps)
        params, opt = adamw_update(params, grads, opt, lr=lr)
        return params, opt, loss

    t0 = time.time()
    losses = []
    for i in range(args.steps):
        batch = {k: jnp.asarray(v) for k, v in next(data).items()}
        params, opt, loss = step(params, opt, batch)
        losses.append(float(loss))
        if i % args.log_every == 0 or i == args.steps - 1:
            print(f"[train] step {i:4d} loss {losses[-1]:.4f} "
                  f"({(time.time()-t0)/(i+1):.2f}s/step)")

    if args.checkpoint:
        save_checkpoint(args.checkpoint, params, step=args.steps)
        print(f"[train] saved checkpoint to {args.checkpoint}")

    first, last = np.mean(losses[:5]), np.mean(losses[-5:])
    print(f"[train] loss {first:.4f} -> {last:.4f} "
          f"({'LEARNING' if last < first - 0.1 else 'flat'})")
    return last < first


if __name__ == "__main__":
    main()
