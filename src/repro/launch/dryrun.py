import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# ^ MUST precede any jax import: jax locks the device count on first init.
# Only the dry-run sees 512 placeholder devices; tests/benches see 1.

import argparse
import json
import subprocess
import sys
import time
from pathlib import Path

import jax

from repro.configs import ARCHS, ASSIGNED, LONG_CONTEXT_OK
from repro.launch import hlo_analysis
from repro.launch.mesh import make_production_mesh
from repro.launch.shapes import SHAPES
from repro.launch.steps import build_step

# TPU v5e hardware constants (per chip)
PEAK_FLOPS = 197e12   # bf16
HBM_BW = 819e9        # bytes/s
ICI_BW = 50e9         # bytes/s per link


def runnable(arch: str, shape_name: str) -> bool:
    if shape_name == "long_500k" and arch not in LONG_CONTEXT_OK:
        return False
    return True


def run_one(arch: str, shape_name: str, multi_pod: bool, out_dir: Path, overrides=None, tag: str = ""):
    cfg = ARCHS[arch]
    shape = SHAPES[shape_name]
    chips = 512 if multi_pod else 256
    mesh = make_production_mesh(multi_pod=multi_pod)

    fn, args, in_shard, out_shard = build_step(
        cfg, shape, multi_pod=multi_pod, rule_overrides=overrides
    )
    donate = {"train": (0, 1), "prefill": (), "decode": (1,)}[shape.kind]

    t0 = time.time()
    with jax.set_mesh(mesh):
        lowered = jax.jit(
            fn, in_shardings=in_shard, out_shardings=out_shard, donate_argnums=donate
        ).lower(*args)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

    ma = compiled.memory_analysis()
    ca = compiled.cost_analysis() or {}
    hlo = hlo_analysis.analyze(compiled.as_text())

    # roofline terms — all quantities are per-chip
    compute_t = hlo.flops / PEAK_FLOPS
    memory_bytes = hlo.dot_bytes + hlo.argument_bytes
    memory_t = memory_bytes / HBM_BW
    collective_t = hlo.collective_bytes / ICI_BW
    terms = {"compute": compute_t, "memory": memory_t, "collective": collective_t}
    bottleneck = max(terms, key=terms.get)

    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode" else 1)
    n_active = cfg.active_param_count()
    model_flops = (6 if shape.kind == "train" else 2) * n_active * tokens
    hlo_flops_global = hlo.flops * chips
    useful_ratio = model_flops / hlo_flops_global if hlo_flops_global else 0.0

    result = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "chips": chips,
        "kind": shape.kind,
        "tag": tag,
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "memory": {
            "args_bytes_per_chip": ma.argument_size_in_bytes,
            "temp_bytes_per_chip": ma.temp_size_in_bytes,
            "output_bytes_per_chip": ma.output_size_in_bytes,
            "total_bytes_per_chip": ma.argument_size_in_bytes + ma.temp_size_in_bytes,
        },
        "xla_cost_analysis": {
            "flops_unscanned": ca.get("flops"),
            "bytes_unscanned": ca.get("bytes accessed"),
        },
        "hlo_analysis": {
            "flops_per_chip": hlo.flops,
            "collective_bytes_per_chip": hlo.collective_bytes,
            "collective_breakdown": hlo.collective_breakdown,
            "collective_op_count": hlo.collective_count,
            "dot_bytes_per_chip": hlo.dot_bytes,
            "argument_bytes_per_chip": hlo.argument_bytes,
        },
        "roofline": {
            "compute_s": compute_t,
            "memory_s": memory_t,
            "collective_s": collective_t,
            "bottleneck": bottleneck,
            "model_flops_global": model_flops,
            "hlo_flops_global": hlo_flops_global,
            "useful_flops_ratio": useful_ratio,
            "params": cfg.param_count(),
            "active_params": n_active,
        },
    }

    out_dir.mkdir(parents=True, exist_ok=True)
    suffix = f"__{tag}" if tag else ""
    path = out_dir / f"{arch}__{shape_name}__{result['mesh']}{suffix}.json"
    path.write_text(json.dumps(result, indent=2))

    print(f"[dryrun] {arch} × {shape_name} × {result['mesh']}{suffix}: "
          f"compile={t_compile:.0f}s "
          f"mem/chip={(result['memory']['total_bytes_per_chip'])/2**30:.2f}GiB "
          f"compute={compute_t*1e3:.2f}ms memory={memory_t*1e3:.2f}ms "
          f"collective={collective_t*1e3:.2f}ms -> {bottleneck} "
          f"useful={useful_ratio:.2f}")
    print(f"  memory_analysis: args={ma.argument_size_in_bytes/2**30:.2f}GiB "
          f"temp={ma.temp_size_in_bytes/2**30:.2f}GiB out={ma.output_size_in_bytes/2**30:.2f}GiB")
    return result


def main():
    ap = argparse.ArgumentParser(description="Multi-pod dry-run: lower+compile every (arch × shape × mesh)")
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true", help="run all combos in subprocesses")
    ap.add_argument("--force", action="store_true", help="re-run existing artifacts")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--tag", default="", help="artifact tag (perf experiments)")
    ap.add_argument("--opt", default=None,
                    help="comma list of perf options: attn_tp,kvseq,ep (see §Perf)")
    args = ap.parse_args()
    out_dir = Path(args.out)

    if args.all:
        failures = []
        for arch in ASSIGNED:
            for shape_name in SHAPES:
                if not runnable(arch, shape_name):
                    print(f"[dryrun] SKIP {arch} × {shape_name} (full attention; see DESIGN.md)")
                    continue
                for mp in (False, True):
                    mesh_name = "2x16x16" if mp else "16x16"
                    art = out_dir / f"{arch}__{shape_name}__{mesh_name}.json"
                    if art.exists() and not args.force:
                        print(f"[dryrun] cached {art.name}")
                        continue
                    cmd = [sys.executable, "-m", "repro.launch.dryrun",
                           "--arch", arch, "--shape", shape_name, "--out", str(out_dir)]
                    if mp:
                        cmd.append("--multi-pod")
                    r = subprocess.run(cmd, env={**os.environ})
                    if r.returncode != 0:
                        failures.append((arch, shape_name, mesh_name))
        if failures:
            print("FAILURES:", failures)
            sys.exit(1)
        print("[dryrun] all combinations lowered + compiled successfully")
        return

    assert args.arch and args.shape, "--arch and --shape required (or --all)"
    if not runnable(args.arch, args.shape):
        print(f"[dryrun] {args.arch} × {args.shape} skipped by design (DESIGN.md §3)")
        return
    overrides = {}
    tag = args.tag
    for opt in (args.opt.split(",") if args.opt else []):
        if opt == "attn_tp":       # §Perf iter: Megatron GQA-TP attention
            overrides.update({"attn_tp": True, "heads_tp": "model"})
        elif opt == "kvseq":       # §Perf iter: sequence-sharded KV decode
            overrides.update({"kv_seq": "model", "kv_heads": None,
                              "kv_head_dim": None, "decode_seq_shard": True})
        elif opt == "bf16grad":    # §Perf iter: bf16 residual-stream cotangents
            overrides.update({"bf16_grad": True})
        elif opt == "nofsdp":
            overrides.update({"dmodel": None})
        else:
            raise SystemExit(f"unknown --opt {opt}")
        tag = f"{tag}+{opt}" if tag else opt
    run_one(args.arch, args.shape, args.multi_pod, out_dir, overrides=overrides or None, tag=tag)


if __name__ == "__main__":
    main()
