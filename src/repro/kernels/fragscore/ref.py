"""Pure-jnp oracle for the fragscore kernel.

Computes F(m) (paper Algorithm 1) for a batch of GPU occupancy bitmaps.
Mirrors :func:`repro.core.cluster.frag_scores` but is kept dependency-light
so the kernel test compares kernel vs. this file alone.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import mig

# Constant tables (host-side numpy, baked into the jaxpr as literals).
W = np.asarray(mig.PLACEMENT_MASKS, dtype=np.float32)        # (18, 8)
V = np.asarray(mig.PLACEMENT_MEM, dtype=np.float32)          # (18,)
NUM_SLICES = mig.NUM_MEM_SLICES


def fragscore_ref(occ: jax.Array, metric: str = "blocked") -> jax.Array:
    """F(m) for every GPU.

    Args:
      occ: (M, 8) int/float occupancy bitmap.
      metric: "blocked" | "partial".

    Returns:
      (M,) float32 fragmentation scores.
    """
    occf = occ.astype(jnp.float32)
    inwin = occf @ W.T  # (M, 18) occupied count per window
    if metric == "blocked":
        counted = inwin > 0
    elif metric == "partial":
        counted = (inwin > 0) & (inwin < V[None, :])
    else:
        raise ValueError(metric)
    free = NUM_SLICES - occf.sum(axis=-1, keepdims=True)
    eligible = V[None, :] <= free
    return jnp.sum(jnp.where(counted & eligible, V[None, :], 0.0), axis=-1)
