"""Pure-jnp oracles for the fragscore kernels.

Computes F(m) (paper Algorithm 1) and the ΔF dry-run table for a batch of
GPUs.  Mirrors :func:`repro.core.cluster.frag_scores` /
:func:`repro.sim.batched._delta_from_base` but is kept dependency-light so
the kernel tests compare kernel vs. this file alone.  Every oracle takes
the placement table as explicit ``(w, v)`` operands (defaulting to the
A100-80GB table), so any registered :class:`~repro.core.mig.DeviceModel` —
including the non-8-slice H200-141GB — can be checked with its own table.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import mig

# Constant tables (host-side numpy, baked into the jaxpr as literals).
W = np.asarray(mig.PLACEMENT_MASKS, dtype=np.float32)        # (18, 8)
V = np.asarray(mig.PLACEMENT_MEM, dtype=np.float32)          # (18,)
NUM_SLICES = mig.NUM_MEM_SLICES


def fragscore_ref(
    occ: jax.Array,
    metric: str = "blocked",
    w: jax.Array = None,
    v: jax.Array = None,
) -> jax.Array:
    """F(m) for every GPU.

    Args:
      occ: (M, S) int/float occupancy bitmap.
      metric: "blocked" | "partial".
      w, v: (N, S) / (N,) placement table (default: A100-80GB).

    Returns:
      (M,) float32 fragmentation scores.
    """
    w = W if w is None else jnp.asarray(w, jnp.float32)
    v = V if v is None else jnp.asarray(v, jnp.float32)
    occf = occ.astype(jnp.float32)
    inwin = occf @ w.T  # (M, N) occupied count per window
    if metric == "blocked":
        counted = inwin > 0
    elif metric == "partial":
        counted = (inwin > 0) & (inwin < v[None, :])
    else:
        raise ValueError(metric)
    free = occf.shape[-1] - occf.sum(axis=-1, keepdims=True)
    eligible = v[None, :] <= free
    return jnp.sum(jnp.where(counted & eligible, v[None, :], 0.0), axis=-1)


def delta_from_base_ref(
    base: jax.Array,
    free: jax.Array,
    v: jax.Array,
    mw: jax.Array,
    mem,
    f_before: jax.Array,
    metric: str = "blocked",
) -> jax.Array:
    """ΔF of every anchor dry-run, from window counts — dense oracle.

    The straightforward (M, A, N) form: window counts after placement are
    ``base + mw`` (feasible windows are disjoint from current occupancy),
    eligibility compares window sizes against the post-allocation free
    count.  The :func:`repro.kernels.fragscore.fragscore.delta_from_base`
    kernel must match this bit-for-bit (integer-valued scores).

    Args:
      base: (M, N) occupied-slice count per placement window.
      free: (M,) free slices per GPU.
      v: (N,) window sizes.
      mw: (A, N) slices each anchor of the request adds per window.
      mem: scalar slice demand of the request.
      f_before: (M,) current F scores.
    """
    v = jnp.asarray(v, jnp.float32)
    ba = base[:, None, :] + jnp.asarray(mw, jnp.float32)[None, :, :]  # (M, A, N)
    if metric == "blocked":
        counted = ba > 0
    elif metric == "partial":
        counted = (ba > 0) & (ba < v[None, None, :])
    else:
        raise ValueError(metric)
    free_after = free.astype(jnp.float32) - jnp.float32(mem)  # (M,)
    eligible = v[None, None, :] <= free_after[:, None, None]
    f_after = jnp.sum(jnp.where(counted & eligible, v[None, None, :], 0.0), axis=-1)
    return f_after - f_before[:, None]


_BIG = jnp.float32(1e9)


def lex_argmin_ref(feasible: jax.Array, vals) -> tuple:
    """Masked lexicographic argmin over an (M, A) candidate table — oracle.

    ``vals`` lists the (M, A)-broadcastable signed key tensors in spec
    order; ties break by the first surviving flat index (ascending
    ``(gpu, col)``), exactly ``repro.sim.batched._lower_select``.  Returns
    ``(gpu, col, ok)``.
    """
    mask = feasible
    for val in vals:
        val = jnp.broadcast_to(val, feasible.shape)
        masked = jnp.where(mask, val, _BIG)
        mask = mask & (masked == masked.min())
    flat = mask.reshape(-1)
    k = jnp.argmax(flat)
    a = feasible.shape[1]
    return k // a, k % a, flat[k]


def select_from_base_ref(
    base, free, f_before, gidx, v, mw, mem, rowsel, valid, anchors,
    keys, metric: str = "blocked",
):
    """Fused-select oracle: ΔF + the policy's masked refinement, merged.

    Builds each effective key's (M, A) tensor from the dense ΔF oracle and
    reduces with :func:`lex_argmin_ref`.  The winner of the
    :func:`~repro.kernels.fragscore.fragscore.select_from_base` tile rows,
    merged by ``(keys…, gpu, col)``, must reproduce this bit-for-bit.
    Returns ``(gpu_value, col, ok)`` — ``gpu_value = gidx[gpu_row]``.
    """
    free_f = free.astype(jnp.float32)
    overlap = base @ jnp.asarray(rowsel, jnp.float32)         # (M, A)
    feas = (overlap == 0) & (jnp.asarray(valid) > 0)[None, :]
    delta = delta_from_base_ref(base, free, v, mw, mem, f_before, metric)
    m, a = feas.shape
    vals = []
    for base_key, sign in keys:
        if base_key == "frag-delta":
            val = delta
        elif base_key == "free-slices":
            val = (free_f - jnp.float32(mem))[:, None]
        elif base_key == "gpu":
            val = jnp.asarray(gidx, jnp.float32)[:, None]
        elif base_key == "anchor":
            val = jnp.broadcast_to(jnp.asarray(anchors, jnp.float32)[None, :], (m, a))
        else:
            raise ValueError(base_key)
        vals.append(-val if sign < 0 else val)
    row, col, ok = lex_argmin_ref(feas, vals)
    gpu = jnp.where(ok, jnp.asarray(gidx, jnp.int32)[row], 0)
    return gpu, jnp.where(ok, col, 0), ok
