"""Public jit'd wrappers for the fragscore / mfi_delta / delta_from_base
Pallas kernels (A100-80GB table defaults; pass other models' tables to the
kernels in :mod:`repro.kernels.fragscore.fragscore` directly)."""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import cluster as jcluster
from repro.core import mig
from repro.kernels.fragscore import fragscore as _k

_W = np.asarray(mig.PLACEMENT_MASKS, dtype=np.float32)
_V = np.asarray(mig.PLACEMENT_MEM, dtype=np.float32)


def _use_interpret() -> bool:
    return jax.default_backend() != "tpu"


def fragmentation_scores(occ: jax.Array, metric: str = "blocked") -> jax.Array:
    """Kernel-backed F(m) over the cluster: (M, 8) -> (M,) float32."""
    return _k.fragscore(
        occ, jnp.asarray(_W), jnp.asarray(_V), metric=metric, interpret=_use_interpret()
    )


def mfi_delta_f(occ: jax.Array, profile_id, metric: str = "blocked") -> jax.Array:
    """Kernel-backed ΔF table for Algorithm 2: (M, 8) × profile -> (M, A)."""
    masks = jcluster.PROFILE_MASKS[profile_id]  # (A, 8)
    valid = jcluster.PROFILE_VALID[profile_id].astype(jnp.float32)  # (A,)
    return _k.mfi_delta(
        occ,
        jnp.asarray(_W),
        jnp.asarray(_V),
        masks,
        valid,
        metric=metric,
        interpret=_use_interpret(),
    )


def delta_from_base_f(
    base: jax.Array,
    free: jax.Array,
    profile_id,
    f_before: jax.Array,
    metric: str = "blocked",
) -> jax.Array:
    """Kernel-backed engine-hot-path ΔF table from window counts.

    A100-80GB convenience wrapper over
    :func:`repro.kernels.fragscore.fragscore.delta_from_base`; the batched
    engine's per-model dispatch (:func:`repro.sim.batched.make_delta_fn`)
    calls the kernel once per ClusterSpec model group with each group's
    own tables.
    """
    tables = jcluster.tables_for(mig.A100_80GB)
    maskwin = (
        tables.profile_masks[profile_id].astype(jnp.float32) @ jnp.asarray(_W).T
    )  # (A, N)
    return _k.delta_from_base(
        base,
        free,
        jnp.asarray(_V),
        maskwin,
        (maskwin > 0).astype(jnp.float32),
        jnp.asarray(mig.PROFILE_MEM)[profile_id],
        f_before,
        metric=metric,
        interpret=_use_interpret(),
    )


def mfi_select(occ: jax.Array, profile_id, metric: str = "blocked") -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Kernel-backed Algorithm 2 — thin alias for the unified entry point
    :func:`repro.core.cluster.mfi_select` with ``use_kernel=True``.

    Returns the legacy ``(gpu, anchor, accepted)`` tuple.
    """
    d = jcluster.mfi_select(occ, profile_id, metric, use_kernel=True)
    return d.gpu, d.anchor, d.accepted
