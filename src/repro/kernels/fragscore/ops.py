"""Public jit'd wrappers for the fragscore / mfi_delta Pallas kernels."""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import cluster as jcluster
from repro.core import mig
from repro.kernels.fragscore import fragscore as _k

_W = np.asarray(mig.PLACEMENT_MASKS, dtype=np.float32)
_V = np.asarray(mig.PLACEMENT_MEM, dtype=np.float32)


def _use_interpret() -> bool:
    return jax.default_backend() != "tpu"


def fragmentation_scores(occ: jax.Array, metric: str = "blocked") -> jax.Array:
    """Kernel-backed F(m) over the cluster: (M, 8) -> (M,) float32."""
    return _k.fragscore(
        occ, jnp.asarray(_W), jnp.asarray(_V), metric=metric, interpret=_use_interpret()
    )


def mfi_delta_f(occ: jax.Array, profile_id, metric: str = "blocked") -> jax.Array:
    """Kernel-backed ΔF table for Algorithm 2: (M, 8) × profile -> (M, A)."""
    masks = jcluster.PROFILE_MASKS[profile_id]  # (A, 8)
    valid = jcluster.PROFILE_VALID[profile_id].astype(jnp.float32)  # (A,)
    return _k.mfi_delta(
        occ,
        jnp.asarray(_W),
        jnp.asarray(_V),
        masks,
        valid,
        metric=metric,
        interpret=_use_interpret(),
    )


def mfi_select(occ: jax.Array, profile_id, metric: str = "blocked") -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Kernel-backed Algorithm 2: returns (gpu, anchor, accepted)."""
    delta = mfi_delta_f(occ, profile_id, metric)  # (M, A)
    flat = delta.reshape(-1)
    k = jnp.argmin(flat)
    accepted = flat[k] < 1e29
    a = delta.shape[1]
    gpu = jnp.where(accepted, k // a, -1).astype(jnp.int32)
    anchor = jnp.where(
        accepted, jcluster.PROFILE_ANCHORS[profile_id][k % a], -1
    ).astype(jnp.int32)
    return gpu, anchor, accepted
