"""Pallas TPU kernels for batched MIG fragmentation scoring (paper Alg. 1/2).

TPU adaptation (DESIGN.md §5): the per-GPU python loop becomes bitmask
algebra — an (BLK_M, S) occupancy slab in VMEM against the constant
placement-window matrix Wᵀ (S, N), one small matmul per block plus VPU
predicates.  Cloud-scale schedulers score 10⁴–10⁶ GPUs per decision batch;
the M axis is tiled in BLK_M-row slabs.

Weights/constants are passed as operands (broadcast BlockSpec) so the same
compiled kernel serves any placement table: each :class:`DeviceModel`
(including the non-8-slice H200-141GB, ``S = 12``) supplies its own
``(N, S)`` window matrix — shapes are static per model, so a mixed fleet
dispatches one compiled kernel per model group.

Five kernels:

* :func:`fragscore` — F(m) from raw ``(M, S)`` occupancy bitmaps (Alg. 1);
* :func:`mfi_delta` — feasibility-masked ΔF over all (GPU, anchor)
  dry-runs from raw occupancy (Alg. 2's inner loop);
* :func:`delta_from_base` — the engine-hot-path form of the ΔF table: it
  consumes the *window-count state* ``base = occ @ Wᵀ`` (+ free counts and
  pre-scores) that :class:`repro.sim.batched.EngineCore` maintains
  incrementally, fusing eligibility, the occupied/cross split and the
  final subtraction into one launch — no occupancy materialization, no
  per-anchor hypothetical matmuls.  Mirrors
  :func:`repro.sim.batched._delta_from_base` bit-for-bit (all scores are
  integer-valued, hence exact in float32);
* :func:`select_from_base` — the *fused select*: ΔF **and** the masked
  lexicographic argmin of the policy's scoring keys in one launch; only
  per-tile winner rows ``(keys…, gpu, anchor-column, ok)`` leave VMEM, the
  ``(M, A)`` score table never round-trips through HBM;
* :func:`migrate_refine` — the *fused migrate-search* refinements: the
  per-class ``(P, M, A)`` untouched-row refinement reduced to best +
  runner-up per class (``_lex_top2``) as grid pass 0, and the per-victim
  ``O(C·A)`` patched-row refinement as grid pass 1 — one launch for both
  (the second grid dimension selects the pass).

The fused kernels take the policy's ordered keys as a static
``((base, sign), …)`` tuple.  Every key value is integer-valued (ΔF
included), hence exact in float32: equality-based masked refinement and
cross-tile lexicographic merges reproduce the pure-jnp total order
bit-for-bit.  See ``docs/KERNELS.md`` for the packing scheme.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NUM_SLICES = 8  # canonical A100-style geometry (kernels accept any S)
BLK_M = 512  # GPUs per VMEM slab (512×8 f32 = 16 KiB)


def _score_block(occ, w, v, metric: str):
    """Score a (blk, S) occupancy slab.  occ f32, w (N, S) f32, v (N,) f32."""
    num_slices = occ.shape[-1]
    inwin = jnp.dot(occ, w.T, preferred_element_type=jnp.float32)  # (blk, N)
    if metric == "blocked":
        counted = inwin > 0
    else:  # partial
        counted = (inwin > 0) & (inwin < v[None, :])
    free = num_slices - jnp.sum(occ, axis=-1, keepdims=True)  # (blk, 1)
    eligible = v[None, :] <= free
    return jnp.sum(jnp.where(counted & eligible, v[None, :], 0.0), axis=-1)


def _fragscore_kernel(occ_ref, w_ref, v_ref, out_ref, *, metric: str):
    occ = occ_ref[...].astype(jnp.float32)  # (BLK_M, S)
    out_ref[...] = _score_block(occ, w_ref[...], v_ref[...], metric)[:, None]


@functools.partial(jax.jit, static_argnames=("metric", "interpret"))
def fragscore(
    occ: jax.Array,
    w: jax.Array,
    v: jax.Array,
    *,
    metric: str = "blocked",
    interpret: bool = True,
) -> jax.Array:
    """F(m) for every GPU.

    Args:
      occ: (M, S) occupancy bitmap (any int/float dtype, any slice count S).
      w: (N, S) placement-window masks of the device model.
      v: (N,) memory-slice weights.
      metric: "blocked" | "partial".
      interpret: run in interpret mode (CPU validation); False on real TPU.

    Returns:
      (M,) float32.
    """
    m, s = occ.shape
    m_pad = -(-m // BLK_M) * BLK_M
    occ_p = jnp.zeros((m_pad, s), occ.dtype).at[:m].set(occ)

    out = pl.pallas_call(
        functools.partial(_fragscore_kernel, metric=metric),
        grid=(m_pad // BLK_M,),
        in_specs=[
            pl.BlockSpec((BLK_M, s), lambda i: (i, 0)),
            pl.BlockSpec((w.shape[0], s), lambda i: (0, 0)),
            pl.BlockSpec((v.shape[0],), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((BLK_M, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m_pad, 1), jnp.float32),
        interpret=interpret,
    )(occ_p.astype(jnp.float32), w.astype(jnp.float32), v.astype(jnp.float32))
    return out[:m, 0]


def _mfi_delta_kernel(occ_ref, w_ref, v_ref, pm_ref, pv_ref, out_ref, *, metric: str, max_anchors: int):
    """ΔF of placing the requested profile at each anchor, +inf if infeasible."""
    occ = occ_ref[...].astype(jnp.float32)  # (BLK_M, S)
    w = w_ref[...]
    v = v_ref[...]
    f_before = _score_block(occ, w, v, metric)  # (BLK_M,)
    big = jnp.float32(1e30)
    for a in range(max_anchors):  # unrolled: A <= 12
        mask = pm_ref[a, :]  # (S,)
        valid = pv_ref[a]  # scalar 0/1
        overlap = jnp.sum(occ * mask[None, :], axis=-1)  # (BLK_M,)
        feasible = (overlap == 0) & (valid > 0)
        hypo = jnp.minimum(occ + mask[None, :], 1.0)
        delta = _score_block(hypo, w, v, metric) - f_before
        out_ref[:, a] = jnp.where(feasible, delta, big)


@functools.partial(jax.jit, static_argnames=("metric", "interpret"))
def mfi_delta(
    occ: jax.Array,
    w: jax.Array,
    v: jax.Array,
    profile_masks: jax.Array,
    profile_valid: jax.Array,
    *,
    metric: str = "blocked",
    interpret: bool = True,
) -> jax.Array:
    """Fused Algorithm-2 inner loop: ΔF over all (GPU, anchor) dry-runs.

    Args:
      occ: (M, S) occupancy.
      w, v: placement table as in :func:`fragscore`.
      profile_masks: (A, S) window masks of the *requested* profile's anchors
        (padded rows are zero).
      profile_valid: (A,) 1.0 for real anchors, 0.0 for padding.

    Returns:
      (M, A) float32 ΔF, +1e30 where the placement is infeasible.
    """
    m, s = occ.shape
    a = profile_masks.shape[0]
    m_pad = -(-m // BLK_M) * BLK_M
    occ_p = jnp.zeros((m_pad, s), occ.dtype).at[:m].set(occ)

    out = pl.pallas_call(
        functools.partial(_mfi_delta_kernel, metric=metric, max_anchors=a),
        grid=(m_pad // BLK_M,),
        in_specs=[
            pl.BlockSpec((BLK_M, s), lambda i: (i, 0)),
            pl.BlockSpec((w.shape[0], s), lambda i: (0, 0)),
            pl.BlockSpec((v.shape[0],), lambda i: (0,)),
            pl.BlockSpec((a, s), lambda i: (0, 0)),
            pl.BlockSpec((a,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((BLK_M, a), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m_pad, a), jnp.float32),
        interpret=interpret,
    )(
        occ_p.astype(jnp.float32),
        w.astype(jnp.float32),
        v.astype(jnp.float32),
        profile_masks.astype(jnp.float32),
        profile_valid.astype(jnp.float32),
    )
    return out[:m]


def _delta_block(base, free, f_before, v, mw, mp, mem, metric: str):
    """ΔF tile from window counts — the shared fused-ΔF math.

    Window counts after a feasible placement are ``base + mw`` (the anchor
    window is disjoint from current occupancy), so for the "blocked" metric
    the counted-predicate decomposes as ``(base > 0) | (mw > 0)`` and the
    whole (blk, A) tile is one (blk, N) × (N, A) matmul on the MXU plus
    VPU predicates; "partial" takes the dense (blk, A, N) elementwise
    path (A ≤ 12, N ≤ 31 — a few hundred KiB of VMEM).
    """
    free_after = free - mem                  # (blk,) — same for every anchor
    elig = v[None, :] <= free_after[:, None]  # (blk, N)
    if metric == "partial":
        ba = base[:, None, :] + mw[None, :, :]  # (blk, A, N)
        counted = (ba > 0) & (ba < v[None, None, :])
        f_after = jnp.sum(
            jnp.where(counted & elig[:, None, :], v[None, None, :], 0.0), axis=-1
        )
    else:  # blocked: counted_after = (base > 0) | (mw > 0)
        cb = base > 0                        # (blk, N)
        s_occ = jnp.sum(jnp.where(cb & elig, v[None, :], 0.0), axis=-1)  # (blk,)
        cross = jnp.dot(                     # (blk, A)
            jnp.where(~cb & elig, v[None, :], 0.0),
            mp.T,
            preferred_element_type=jnp.float32,
        )
        f_after = s_occ[:, None] + cross
    return f_after - f_before[:, None]


def _delta_rows(base, free, f_before, v, mw, mp, mem, metric: str):
    """Row-wise ΔF: every row is an independent GPU with its *own* window
    sizes ``v (blk, N)``, per-row anchor tables ``mw/mp (blk, A, N)`` and
    per-row slice demand ``mem (blk,)`` — the per-victim patched-row form.
    """
    free_after = free - mem                  # (blk,)
    elig = v <= free_after[:, None]          # (blk, N)
    if metric == "partial":
        ba = base[:, None, :] + mw           # (blk, A, N)
        counted = (ba > 0) & (ba < v[:, None, :])
        f_after = jnp.sum(
            jnp.where(counted & elig[:, None, :], v[:, None, :], 0.0), axis=-1
        )
    else:
        cb = base > 0                        # (blk, N)
        s_occ = jnp.sum(jnp.where(cb & elig, v, 0.0), axis=-1)  # (blk,)
        cross = jnp.sum(                     # (blk, A)
            jnp.where(~cb & elig, v, 0.0)[:, None, :] * mp, axis=-1
        )
        f_after = s_occ[:, None] + cross
    return f_after - f_before[:, None]


def _delta_from_base_kernel(
    base_ref, free_ref, f_ref, v_ref, mw_ref, mp_ref, mem_ref, out_ref,
    *, metric: str,
):
    """Fused ΔF dry-run table from the incremental window-count state."""
    out_ref[...] = _delta_block(
        base_ref[...], free_ref[...][:, 0], f_ref[...][:, 0], v_ref[...],
        mw_ref[...], mp_ref[...], mem_ref[0], metric,
    )


@functools.partial(jax.jit, static_argnames=("metric", "interpret"))
def delta_from_base(
    base: jax.Array,
    free: jax.Array,
    v: jax.Array,
    mw: jax.Array,
    mp: jax.Array,
    mem: jax.Array,
    f_before: jax.Array,
    *,
    metric: str = "blocked",
    interpret: bool = True,
) -> jax.Array:
    """ΔF of every anchor dry-run of one request, from window counts.

    The Pallas form of :func:`repro.sim.batched._delta_from_base` for one
    model group (all GPUs share the placement table ``v``); the batched
    engine dispatches one call per :class:`~repro.core.mig.ClusterSpec`
    model group.  Output is the *raw* ΔF (no feasibility masking) —
    exactly what the engine's masked-refinement select consumes.

    Args:
      base: (M, N) float32 — occupied-slice count per placement window.
      free: (M,) — free memory slices per GPU.
      v: (N,) float32 — placement-window sizes (0 where padded).
      mw: (A, N) float32 — slices the request's anchors add per window.
      mp: (A, N) float32 — ``mw > 0`` indicator.
      mem: scalar — the request's slice demand on this model.
      f_before: (M,) float32 — current F(m) scores.
      metric: "blocked" | "partial".
      interpret: run in interpret mode (CPU validation); False on real TPU.

    Returns:
      (M, A) float32 ΔF table.
    """
    m, n = base.shape
    a = mw.shape[0]
    m_pad = -(-m // BLK_M) * BLK_M
    base_p = jnp.zeros((m_pad, n), jnp.float32).at[:m].set(base)
    free_p = jnp.zeros((m_pad, 1), jnp.float32).at[:m, 0].set(
        free.astype(jnp.float32)
    )
    f_p = jnp.zeros((m_pad, 1), jnp.float32).at[:m, 0].set(f_before)

    out = pl.pallas_call(
        functools.partial(_delta_from_base_kernel, metric=metric),
        grid=(m_pad // BLK_M,),
        in_specs=[
            pl.BlockSpec((BLK_M, n), lambda i: (i, 0)),
            pl.BlockSpec((BLK_M, 1), lambda i: (i, 0)),
            pl.BlockSpec((BLK_M, 1), lambda i: (i, 0)),
            pl.BlockSpec((n,), lambda i: (0,)),
            pl.BlockSpec((a, n), lambda i: (0, 0)),
            pl.BlockSpec((a, n), lambda i: (0, 0)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((BLK_M, a), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m_pad, a), jnp.float32),
        interpret=interpret,
    )(
        base_p,
        free_p,
        f_p,
        v.astype(jnp.float32),
        mw.astype(jnp.float32),
        mp.astype(jnp.float32),
        jnp.reshape(mem, (1,)).astype(jnp.float32),
        )
    return out[:m]


# ---------------------------------------------------------------------------
# Fused select / migrate-search kernels: ΔF + lexicographic argmin in-kernel
# ---------------------------------------------------------------------------

#: the refinement sentinel — MUST equal ``repro.sim.batched._BIG`` so the
#: in-kernel masked refinements and the host-side cross-tile merges compare
#: against the same value the pure-jnp lowering uses.  Kept a Python float:
#: a module-level jax array would be captured as a constant by pallas kernels.
BIG = 1e9


def _blk_rows(m: int) -> int:
    """Adaptive row-tile: whole problem when it fits, BLK_M slabs beyond.

    Fleets are usually far smaller than BLK_M; padding 16 rows to 512 would
    make every fused launch 32× wider than the work.  TPU f32 tiles are
    (8, 128), so round up to a multiple of 8.
    """
    return min(BLK_M, -(-m // 8) * 8)


def _key_tile(base_key, sign, delta, free, mem, gid, anchors, shape):
    """One effective scoring key as a (blk, A) tile (direction applied).

    ``anchors`` broadcasts along rows when it is a shared (A,) vector (the
    per-class form) and is taken as-is when per-row (blk, A) (the
    per-victim form); ``gid``/``free``/``mem`` are (blk,) / scalar-or-(blk,).
    Request-scoped keys never reach the kernels — they are constant over
    one request's candidates and are dropped from the effective key tuple
    by the dispatch builders.
    """
    if base_key == "frag-delta":
        val = delta
    elif base_key == "free-slices":
        val = jnp.broadcast_to((free - mem)[:, None], shape)
    elif base_key == "gpu":
        val = jnp.broadcast_to(gid[:, None], shape)
    elif base_key == "anchor":
        a2 = anchors if anchors.ndim == 2 else anchors[None, :]
        val = jnp.broadcast_to(a2, shape)
    else:  # pragma: no cover — guarded by PolicySpec.argmin_fusable
        raise ValueError(f"key {base_key!r} is not argmin-fusable")
    return -val if sign < 0 else val


def _refine_cols(feas, vals):
    """Masked per-row refinement along the anchor axis (``_refine_rows``'s
    total order): returns ``(okr (blk, 1), wincol (blk, 1) int32, keyr)``
    where ``keyr`` lists each key's winner-column value (blk, 1).

    Winner extraction is *unmasked* at the first surviving column
    (``argmax``-of-mask semantics, column 0 for all-infeasible rows) so the
    values match the jnp lowering's ``take_along_axis`` bit-for-bit even on
    rows no feasible anchor survives.
    """
    blk, a = feas.shape
    mask = feas
    for val in vals:
        mval = jnp.where(mask, val, BIG)
        mask = mask & (mval == jnp.min(mval, axis=-1, keepdims=True))
    okr = jnp.any(mask, axis=-1, keepdims=True)            # (blk, 1)
    cid = jax.lax.broadcasted_iota(jnp.int32, (blk, a), 1)
    wincol = jnp.min(jnp.where(mask, cid, a), axis=-1, keepdims=True)
    wincol = jnp.where(okr, wincol, 0)                     # (blk, 1)
    w = cid == wincol
    keyr = [
        jnp.sum(jnp.where(w, val, 0.0), axis=-1, keepdims=True) for val in vals
    ]
    return okr, wincol, keyr


def _tile_top2(okr, wincol, keyr, gid2):
    """Cross-row lexicographic top-2 of per-row winners inside one tile.

    Rows ascend in global GPU id, so the in-tile row order *is* the
    ``_lex_top2`` ascending-row tie-break.  Returns two candidate rows
    ``[keys…, gpu, col, ok]`` (keys masked to BIG, gpu/col zeroed when not
    ok) ready for the host-side cross-tile merge by ``(keys…, gpu)``.
    """
    blk = okr.shape[0]
    rid = jax.lax.broadcasted_iota(jnp.int32, (blk, 1), 0)

    def best(rmask):
        for kv in keyr:
            mval = jnp.where(rmask, kv, BIG)
            rmask = rmask & (mval == jnp.min(mval))
        winrow = jnp.min(jnp.where(rmask, rid, blk))
        w = rmask & (rid == winrow)
        ok = jnp.any(rmask)
        okf = ok.astype(jnp.float32)
        pick = lambda t: jnp.sum(jnp.where(w, t, 0.0))  # noqa: E731
        row = [jnp.where(ok, pick(kv), BIG) for kv in keyr]
        row += [
            pick(gid2) * okf,
            pick(wincol.astype(jnp.float32)) * okf,
            okf,
        ]
        return winrow, row

    r1, row1 = best(okr)
    _, row2 = best(okr & (rid != r1))
    return row1 + row2


def _select_from_base_kernel(
    base_ref, free_ref, f_ref, gidx_ref, live_ref,
    v_ref, mw_ref, mp_ref, mem_ref, rowsel_ref, valid_ref, anchors_ref,
    out_ref, *, metric: str, keys,
):
    """Fused select: ΔF + masked lexicographic argmin, one winner row out."""
    base = base_ref[...]                      # (blk, N)
    free = free_ref[...][:, 0]                # (blk,)
    f = f_ref[...][:, 0]
    gid = gidx_ref[...][:, 0]
    live = live_ref[...][:, 0] > 0
    mem = mem_ref[0]
    blk = base.shape[0]
    a = valid_ref.shape[0]

    # feasibility: the request's anchor windows hold zero occupied slices —
    # a one-hot gather ``base @ rowsel`` on the MXU (exact: single terms)
    overlap = jnp.dot(base, rowsel_ref[...], preferred_element_type=jnp.float32)
    feas = (overlap == 0) & (valid_ref[...][None, :] > 0) & live[:, None]

    delta = None
    if any(b == "frag-delta" for b, _ in keys):
        delta = _delta_block(base, free, f, v_ref[...], mw_ref[...],
                             mp_ref[...], mem, metric)
    vals = [
        _key_tile(b, s, delta, free, mem, gid, anchors_ref[...], (blk, a))
        for b, s in keys
    ]

    # tile-global masked refinement — ``_lower_select``'s total order
    mask = feas
    for val in vals:
        mval = jnp.where(mask, val, BIG)
        mask = mask & (mval == jnp.min(mval))
    rid = jax.lax.broadcasted_iota(jnp.int32, (blk, a), 0)
    cid = jax.lax.broadcasted_iota(jnp.int32, (blk, a), 1)
    flat = rid * a + cid                      # rows ascend in global gpu id
    win = mask & (flat == jnp.min(jnp.where(mask, flat, blk * a)))
    ok = jnp.any(mask)
    okf = ok.astype(jnp.float32)
    pick = lambda t: jnp.sum(jnp.where(win, t, 0.0))  # noqa: E731
    row = [jnp.where(ok, pick(val), BIG) for val in vals]
    row += [
        pick(jnp.broadcast_to(gid[:, None], (blk, a))) * okf,
        pick(cid.astype(jnp.float32)) * okf,
        okf,
    ]
    out_ref[...] = jnp.stack(row)[None, :]


@functools.partial(jax.jit, static_argnames=("keys", "metric", "interpret"))
def select_from_base(
    base: jax.Array,
    free: jax.Array,
    f_before: jax.Array,
    gidx: jax.Array,
    v: jax.Array,
    mw: jax.Array,
    mp: jax.Array,
    mem: jax.Array,
    rowsel: jax.Array,
    valid: jax.Array,
    anchors: jax.Array,
    *,
    keys,
    metric: str = "blocked",
    interpret: bool = True,
) -> jax.Array:
    """Fused select over one model group: per-tile winner rows.

    Evaluates the ΔF table *and* reduces it through the policy's masked
    lexicographic refinement in one launch — the ``(M, A)`` score table
    never leaves VMEM.  Only ``T = ceil(M / blk)`` winner rows
    ``[signed key values…, gpu, anchor-column, ok]`` (keys BIG / gpu,col 0
    when the tile has no feasible candidate) reach HBM; the caller merges
    tiles (and model groups) by ``(keys…, gpu, col)`` — exactly
    ``_lower_select``'s total order, since rows ascend in global GPU id and
    every key value is integer-valued (exact in float32).

    Args:
      base: (M, N) window counts of this group's GPUs.
      free: (M,) free slices; f_before: (M,) current F(m).
      gidx: (M,) *global* GPU ids of the group's rows (ascending).
      v/mw/mp/mem: the group's placement table and the request class's
        anchor tables, as in :func:`delta_from_base`.
      rowsel: (N, A) one-hot of ``profile_rows`` — feasibility gather.
      valid: (A,) anchor validity (1.0 real / 0.0 padded).
      anchors: (A,) anchor *values* (``profile_anchors``).
      keys: static ``((base_key, sign), …)`` effective scoring keys.

    Returns:
      (T, L + 3) float32 winner rows, ``L = len(keys)``.
    """
    m, n = base.shape
    a = mw.shape[0]
    blk = _blk_rows(m)
    m_pad = -(-m // blk) * blk
    t = m_pad // blk
    base_p = jnp.zeros((m_pad, n), jnp.float32).at[:m].set(base)
    col = lambda x: jnp.zeros((m_pad, 1), jnp.float32).at[:m, 0].set(  # noqa: E731
        x.astype(jnp.float32)
    )
    live_p = jnp.zeros((m_pad, 1), jnp.float32).at[:m, 0].set(1.0)
    l = len(keys)

    return pl.pallas_call(
        functools.partial(_select_from_base_kernel, metric=metric, keys=keys),
        grid=(t,),
        in_specs=[
            pl.BlockSpec((blk, n), lambda i: (i, 0)),
            pl.BlockSpec((blk, 1), lambda i: (i, 0)),
            pl.BlockSpec((blk, 1), lambda i: (i, 0)),
            pl.BlockSpec((blk, 1), lambda i: (i, 0)),
            pl.BlockSpec((blk, 1), lambda i: (i, 0)),
            pl.BlockSpec((n,), lambda i: (0,)),
            pl.BlockSpec((a, n), lambda i: (0, 0)),
            pl.BlockSpec((a, n), lambda i: (0, 0)),
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((n, a), lambda i: (0, 0)),
            pl.BlockSpec((a,), lambda i: (0,)),
            pl.BlockSpec((a,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((1, l + 3), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((t, l + 3), jnp.float32),
        interpret=interpret,
    )(
        base_p,
        col(free),
        col(f_before),
        col(gidx),
        live_p,
        v.astype(jnp.float32),
        mw.astype(jnp.float32),
        mp.astype(jnp.float32),
        jnp.reshape(mem, (1,)).astype(jnp.float32),
        rowsel.astype(jnp.float32),
        valid.astype(jnp.float32),
        anchors.astype(jnp.float32),
    )


def _class_pass_impl(
    base_ref, free_ref, f_ref, gidx_ref, live_ref, v_ref,
    mw_all_ref, mp_all_ref, mem_all_ref, rowsel_all_ref, valid_all_ref,
    anchors_all_ref, out0_ref, *, metric: str, keys,
):
    """Pass 0: per-class untouched-row refinement + in-tile top-2.

    The demand-class loop is unrolled (P = 6); each class emits two
    candidate rows ``[keys…, gpu, col, ok]`` — the tile's best and
    runner-up per ``_lex_top2``'s order — into a (1, P, 2·(L+3)) block.
    """
    base = base_ref[...]                      # (blk, N)
    free = free_ref[...][:, 0]
    f = f_ref[...][:, 0]
    gid = gidx_ref[...][:, 0]
    live = live_ref[...][:, 0] > 0
    v = v_ref[...]
    blk = base.shape[0]
    p_, a = valid_all_ref.shape
    gid2 = gid[:, None]
    need_delta = any(b == "frag-delta" for b, _ in keys)
    rows = []
    for p in range(p_):
        mem = mem_all_ref[p]
        overlap = jnp.dot(
            base, rowsel_all_ref[p], preferred_element_type=jnp.float32
        )
        feas = (overlap == 0) & (valid_all_ref[p][None, :] > 0) & live[:, None]
        delta = None
        if need_delta:
            delta = _delta_block(
                base, free, f, v, mw_all_ref[p], mp_all_ref[p], mem, metric
            )
        vals = [
            _key_tile(b, s, delta, free, mem, gid, anchors_all_ref[p], (blk, a))
            for b, s in keys
        ]
        okr, wincol, keyr = _refine_cols(feas, vals)
        rows.append(jnp.stack(_tile_top2(okr, wincol, keyr, gid2)))
    out0_ref[...] = jnp.stack(rows)[None]


def _victim_pass_impl(
    base2_ref, free2_ref, f2_ref, vgid_ref, vv_ref, vmw_ref, vmp_ref,
    vmem_ref, vrowsel_ref, vvalid_ref, vanchors_ref, out1_ref,
    *, metric: str, keys,
):
    """Pass 1: per-victim patched-row refinement.

    Every row is an independent victim with its *own* model tables (mixed
    fleets gather per victim) — the row-wise ΔF form.  Emits
    ``[keys…, col, ok]`` per victim; column 0 (unmasked values) when no
    anchor survives, matching the jnp path's argmax-of-mask semantics.
    """
    base2 = base2_ref[...]                    # (blk, N)
    free2 = free2_ref[...][:, 0]
    f2 = f2_ref[...][:, 0]
    vgid = vgid_ref[...][:, 0]
    vmem = vmem_ref[...][:, 0]
    blk = base2.shape[0]
    a = vvalid_ref.shape[-1]
    overlap = jnp.sum(base2[:, :, None] * vrowsel_ref[...], axis=1)  # (blk, A)
    feas = (overlap == 0) & (vvalid_ref[...] > 0)
    delta = None
    if any(b == "frag-delta" for b, _ in keys):
        delta = _delta_rows(
            base2, free2, f2, vv_ref[...], vmw_ref[...], vmp_ref[...],
            vmem, metric,
        )
    vals = [
        _key_tile(b, s, delta, free2, vmem, vgid, vanchors_ref[...], (blk, a))
        for b, s in keys
    ]
    okr, wincol, keyr = _refine_cols(feas, vals)
    out1_ref[...] = jnp.concatenate(
        keyr + [wincol.astype(jnp.float32), okr.astype(jnp.float32)], axis=1
    )


def _migrate_class_kernel(*refs, metric: str, keys):
    _class_pass_impl(*refs, metric=metric, keys=keys)


def _migrate_refine_kernel(passid_ref, *refs, metric: str, keys):
    """Both migrate refinements in one launch; the second grid dimension
    selects the pass.  The pass id arrives as a (1, 1) operand indexed by
    the grid (never ``pl.program_id`` — vmap over replicas prepends a batch
    grid dimension and would shift the axis numbering)."""
    pid = passid_ref[0, 0]
    class_in, victim_in = refs[:12], refs[12:23]
    out0_ref, out1_ref = refs[23], refs[24]

    @pl.when(pid == 0.0)
    def _():
        _class_pass_impl(*class_in, out0_ref, metric=metric, keys=keys)

    @pl.when(pid == 1.0)
    def _():
        _victim_pass_impl(*victim_in, out1_ref, metric=metric, keys=keys)


def _pad_rows(x, m, m_pad):
    shp = (m_pad,) + x.shape[1:]
    return jnp.zeros(shp, jnp.float32).at[:m].set(x.astype(jnp.float32))


@functools.partial(jax.jit, static_argnames=("keys", "metric", "interpret"))
def migrate_refine(
    base: jax.Array,
    free: jax.Array,
    f_before: jax.Array,
    gidx: jax.Array,
    v: jax.Array,
    mw_all: jax.Array,
    mp_all: jax.Array,
    mem_all: jax.Array,
    rowsel_all: jax.Array,
    valid_all: jax.Array,
    anchors_all: jax.Array,
    victims=None,
    *,
    keys,
    metric: str = "blocked",
    interpret: bool = True,
):
    """Fused migrate-search refinements over one model group.

    Pass 0 (tiled over the group's ``M`` GPUs) runs the per-class
    ``(P, M, A)`` untouched-row refinement — ΔF, feasibility, per-row
    anchor refinement, and the cross-row best/runner-up reduction — and
    emits two candidate rows ``[keys…, gpu, col, ok]`` per class per tile
    (keys BIG when not ok).  With ``victims`` (the per-victim gathered
    tables — mixed fleets gather per row, so one call covers every victim
    regardless of model), the per-victim ``O(C·A)`` patched-row refinement
    is fused as grid pass 1 of the *same* launch: grid ``(T, 2)``, the
    second dimension selecting the pass, input index maps clamped to each
    pass's own tile range (revisits rewrite identical content).

    Args:
      base/free/f_before/gidx: the group's window-count state + global ids.
      v: (N,) group placement-window sizes.
      mw_all/mp_all: (P, A, N) per-class anchor tables; mem_all: (P,).
      rowsel_all: (P, N, A) one-hot feasibility gathers; valid_all /
        anchors_all: (P, A).
      victims: optional tuple ``(base2, free2, f2, vgid, vv, vmw, vmp,
        vmem, vrowsel, vvalid, vanchors)`` of per-victim (C, …) tables.
      keys: static ``((base_key, sign), …)`` effective scoring keys.

    Returns:
      ``(out0, out1)`` — out0 (T0, P, 2·(L+3)) candidate pairs, out1
      (C, L+2) per-victim ``[keys…, col, ok]`` rows (``None`` without
      ``victims``).
    """
    m, n = base.shape
    p_, a, _ = mw_all.shape
    l = len(keys)
    w0 = 2 * (l + 3)
    blk0 = _blk_rows(m)
    m_pad = -(-m // blk0) * blk0
    t0 = m_pad // blk0

    col = lambda x: _pad_rows(x.reshape(-1, 1), m, m_pad)  # noqa: E731
    class_ops = (
        _pad_rows(base, m, m_pad),
        col(free),
        col(f_before),
        col(gidx),
        _pad_rows(jnp.ones((m, 1)), m, m_pad),
        v.astype(jnp.float32),
        mw_all.astype(jnp.float32),
        mp_all.astype(jnp.float32),
        mem_all.astype(jnp.float32),
        rowsel_all.astype(jnp.float32),
        valid_all.astype(jnp.float32),
        anchors_all.astype(jnp.float32),
    )

    if victims is None:
        class_specs = [
            pl.BlockSpec((blk0, n), lambda i: (i, 0)),
            pl.BlockSpec((blk0, 1), lambda i: (i, 0)),
            pl.BlockSpec((blk0, 1), lambda i: (i, 0)),
            pl.BlockSpec((blk0, 1), lambda i: (i, 0)),
            pl.BlockSpec((blk0, 1), lambda i: (i, 0)),
            pl.BlockSpec((n,), lambda i: (0,)),
            pl.BlockSpec((p_, a, n), lambda i: (0, 0, 0)),
            pl.BlockSpec((p_, a, n), lambda i: (0, 0, 0)),
            pl.BlockSpec((p_,), lambda i: (0,)),
            pl.BlockSpec((p_, n, a), lambda i: (0, 0, 0)),
            pl.BlockSpec((p_, a), lambda i: (0, 0)),
            pl.BlockSpec((p_, a), lambda i: (0, 0)),
        ]
        out0 = pl.pallas_call(
            functools.partial(_migrate_class_kernel, metric=metric, keys=keys),
            grid=(t0,),
            in_specs=class_specs,
            out_specs=pl.BlockSpec((1, p_, w0), lambda i: (i, 0, 0)),
            out_shape=jax.ShapeDtypeStruct((t0, p_, w0), jnp.float32),
            interpret=interpret,
        )(*class_ops)
        return out0, None

    (base2, free2, f2, vgid, vv, vmw, vmp, vmem, vrowsel, vvalid,
     vanchors) = victims
    c = base2.shape[0]
    blk1 = _blk_rows(c)
    c_pad = -(-c // blk1) * blk1
    t1 = c_pad // blk1
    t = max(t0, t1)

    colv = lambda x: _pad_rows(x.reshape(-1, 1), c, c_pad)  # noqa: E731
    victim_ops = (
        _pad_rows(base2, c, c_pad),
        colv(free2),
        colv(f2),
        colv(vgid),
        _pad_rows(vv, c, c_pad),
        _pad_rows(vmw, c, c_pad),
        _pad_rows(vmp, c, c_pad),
        colv(vmem),
        _pad_rows(vrowsel, c, c_pad),
        _pad_rows(vvalid, c, c_pad),  # zero-padded validity masks pad victims
        _pad_rows(vanchors, c, c_pad),
    )

    i0 = lambda i, j: (jnp.minimum(i, t0 - 1), 0)  # noqa: E731
    i1 = lambda i, j: (jnp.minimum(i, t1 - 1), 0)  # noqa: E731
    in_specs = [
        pl.BlockSpec((1, 1), lambda i, j: (j, 0)),  # pass id
        # -- pass 0 operands (clamped to the class tiles) -------------------
        pl.BlockSpec((blk0, n), i0),
        pl.BlockSpec((blk0, 1), i0),
        pl.BlockSpec((blk0, 1), i0),
        pl.BlockSpec((blk0, 1), i0),
        pl.BlockSpec((blk0, 1), i0),
        pl.BlockSpec((n,), lambda i, j: (0,)),
        pl.BlockSpec((p_, a, n), lambda i, j: (0, 0, 0)),
        pl.BlockSpec((p_, a, n), lambda i, j: (0, 0, 0)),
        pl.BlockSpec((p_,), lambda i, j: (0,)),
        pl.BlockSpec((p_, n, a), lambda i, j: (0, 0, 0)),
        pl.BlockSpec((p_, a), lambda i, j: (0, 0)),
        pl.BlockSpec((p_, a), lambda i, j: (0, 0)),
        # -- pass 1 operands (clamped to the victim tiles) ------------------
        pl.BlockSpec((blk1, n), i1),
        pl.BlockSpec((blk1, 1), i1),
        pl.BlockSpec((blk1, 1), i1),
        pl.BlockSpec((blk1, 1), i1),
        pl.BlockSpec((blk1, n), i1),
        pl.BlockSpec((blk1, a, n), lambda i, j: (jnp.minimum(i, t1 - 1), 0, 0)),
        pl.BlockSpec((blk1, a, n), lambda i, j: (jnp.minimum(i, t1 - 1), 0, 0)),
        pl.BlockSpec((blk1, 1), i1),
        pl.BlockSpec((blk1, n, a), lambda i, j: (jnp.minimum(i, t1 - 1), 0, 0)),
        pl.BlockSpec((blk1, a), i1),
        pl.BlockSpec((blk1, a), i1),
    ]
    passid = jnp.arange(2, dtype=jnp.float32).reshape(2, 1)
    out0, out1 = pl.pallas_call(
        functools.partial(_migrate_refine_kernel, metric=metric, keys=keys),
        grid=(t, 2),
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((1, p_, w0), lambda i, j: (jnp.minimum(i, t0 - 1), 0, 0)),
            pl.BlockSpec((blk1, l + 2), i1),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((t0, p_, w0), jnp.float32),
            jax.ShapeDtypeStruct((c_pad, l + 2), jnp.float32),
        ],
        interpret=interpret,
    )(passid, *class_ops, *victim_ops)
    return out0, out1[:c]
