"""Pallas TPU kernels for batched MIG fragmentation scoring (paper Alg. 1/2).

TPU adaptation (DESIGN.md §5): the per-GPU python loop becomes bitmask
algebra — an (BLK_M, S) occupancy slab in VMEM against the constant
placement-window matrix Wᵀ (S, N), one small matmul per block plus VPU
predicates.  Cloud-scale schedulers score 10⁴–10⁶ GPUs per decision batch;
the M axis is tiled in BLK_M-row slabs.

Weights/constants are passed as operands (broadcast BlockSpec) so the same
compiled kernel serves any placement table: each :class:`DeviceModel`
(including the non-8-slice H200-141GB, ``S = 12``) supplies its own
``(N, S)`` window matrix — shapes are static per model, so a mixed fleet
dispatches one compiled kernel per model group.

Three kernels:

* :func:`fragscore` — F(m) from raw ``(M, S)`` occupancy bitmaps (Alg. 1);
* :func:`mfi_delta` — feasibility-masked ΔF over all (GPU, anchor)
  dry-runs from raw occupancy (Alg. 2's inner loop);
* :func:`delta_from_base` — the engine-hot-path form of the ΔF table: it
  consumes the *window-count state* ``base = occ @ Wᵀ`` (+ free counts and
  pre-scores) that :class:`repro.sim.batched.EngineCore` maintains
  incrementally, fusing eligibility, the occupied/cross split and the
  final subtraction into one launch — no occupancy materialization, no
  per-anchor hypothetical matmuls.  Mirrors
  :func:`repro.sim.batched._delta_from_base` bit-for-bit (all scores are
  integer-valued, hence exact in float32).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NUM_SLICES = 8  # canonical A100-style geometry (kernels accept any S)
BLK_M = 512  # GPUs per VMEM slab (512×8 f32 = 16 KiB)


def _score_block(occ, w, v, metric: str):
    """Score a (blk, S) occupancy slab.  occ f32, w (N, S) f32, v (N,) f32."""
    num_slices = occ.shape[-1]
    inwin = jnp.dot(occ, w.T, preferred_element_type=jnp.float32)  # (blk, N)
    if metric == "blocked":
        counted = inwin > 0
    else:  # partial
        counted = (inwin > 0) & (inwin < v[None, :])
    free = num_slices - jnp.sum(occ, axis=-1, keepdims=True)  # (blk, 1)
    eligible = v[None, :] <= free
    return jnp.sum(jnp.where(counted & eligible, v[None, :], 0.0), axis=-1)


def _fragscore_kernel(occ_ref, w_ref, v_ref, out_ref, *, metric: str):
    occ = occ_ref[...].astype(jnp.float32)  # (BLK_M, S)
    out_ref[...] = _score_block(occ, w_ref[...], v_ref[...], metric)[:, None]


@functools.partial(jax.jit, static_argnames=("metric", "interpret"))
def fragscore(
    occ: jax.Array,
    w: jax.Array,
    v: jax.Array,
    *,
    metric: str = "blocked",
    interpret: bool = True,
) -> jax.Array:
    """F(m) for every GPU.

    Args:
      occ: (M, S) occupancy bitmap (any int/float dtype, any slice count S).
      w: (N, S) placement-window masks of the device model.
      v: (N,) memory-slice weights.
      metric: "blocked" | "partial".
      interpret: run in interpret mode (CPU validation); False on real TPU.

    Returns:
      (M,) float32.
    """
    m, s = occ.shape
    m_pad = -(-m // BLK_M) * BLK_M
    occ_p = jnp.zeros((m_pad, s), occ.dtype).at[:m].set(occ)

    out = pl.pallas_call(
        functools.partial(_fragscore_kernel, metric=metric),
        grid=(m_pad // BLK_M,),
        in_specs=[
            pl.BlockSpec((BLK_M, s), lambda i: (i, 0)),
            pl.BlockSpec((w.shape[0], s), lambda i: (0, 0)),
            pl.BlockSpec((v.shape[0],), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((BLK_M, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m_pad, 1), jnp.float32),
        interpret=interpret,
    )(occ_p.astype(jnp.float32), w.astype(jnp.float32), v.astype(jnp.float32))
    return out[:m, 0]


def _mfi_delta_kernel(occ_ref, w_ref, v_ref, pm_ref, pv_ref, out_ref, *, metric: str, max_anchors: int):
    """ΔF of placing the requested profile at each anchor, +inf if infeasible."""
    occ = occ_ref[...].astype(jnp.float32)  # (BLK_M, S)
    w = w_ref[...]
    v = v_ref[...]
    f_before = _score_block(occ, w, v, metric)  # (BLK_M,)
    big = jnp.float32(1e30)
    for a in range(max_anchors):  # unrolled: A <= 12
        mask = pm_ref[a, :]  # (S,)
        valid = pv_ref[a]  # scalar 0/1
        overlap = jnp.sum(occ * mask[None, :], axis=-1)  # (BLK_M,)
        feasible = (overlap == 0) & (valid > 0)
        hypo = jnp.minimum(occ + mask[None, :], 1.0)
        delta = _score_block(hypo, w, v, metric) - f_before
        out_ref[:, a] = jnp.where(feasible, delta, big)


@functools.partial(jax.jit, static_argnames=("metric", "interpret"))
def mfi_delta(
    occ: jax.Array,
    w: jax.Array,
    v: jax.Array,
    profile_masks: jax.Array,
    profile_valid: jax.Array,
    *,
    metric: str = "blocked",
    interpret: bool = True,
) -> jax.Array:
    """Fused Algorithm-2 inner loop: ΔF over all (GPU, anchor) dry-runs.

    Args:
      occ: (M, S) occupancy.
      w, v: placement table as in :func:`fragscore`.
      profile_masks: (A, S) window masks of the *requested* profile's anchors
        (padded rows are zero).
      profile_valid: (A,) 1.0 for real anchors, 0.0 for padding.

    Returns:
      (M, A) float32 ΔF, +1e30 where the placement is infeasible.
    """
    m, s = occ.shape
    a = profile_masks.shape[0]
    m_pad = -(-m // BLK_M) * BLK_M
    occ_p = jnp.zeros((m_pad, s), occ.dtype).at[:m].set(occ)

    out = pl.pallas_call(
        functools.partial(_mfi_delta_kernel, metric=metric, max_anchors=a),
        grid=(m_pad // BLK_M,),
        in_specs=[
            pl.BlockSpec((BLK_M, s), lambda i: (i, 0)),
            pl.BlockSpec((w.shape[0], s), lambda i: (0, 0)),
            pl.BlockSpec((v.shape[0],), lambda i: (0,)),
            pl.BlockSpec((a, s), lambda i: (0, 0)),
            pl.BlockSpec((a,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((BLK_M, a), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m_pad, a), jnp.float32),
        interpret=interpret,
    )(
        occ_p.astype(jnp.float32),
        w.astype(jnp.float32),
        v.astype(jnp.float32),
        profile_masks.astype(jnp.float32),
        profile_valid.astype(jnp.float32),
    )
    return out[:m]


def _delta_from_base_kernel(
    base_ref, free_ref, f_ref, v_ref, mw_ref, mp_ref, mem_ref, out_ref,
    *, metric: str,
):
    """Fused ΔF dry-run table from the incremental window-count state.

    Window counts after a feasible placement are ``base + mw`` (the anchor
    window is disjoint from current occupancy), so for the "blocked" metric
    the counted-predicate decomposes as ``(base > 0) | (mw > 0)`` and the
    whole (BLK_M, A) tile is one (BLK_M, N) × (N, A) matmul on the MXU plus
    VPU predicates; "partial" takes the dense (BLK_M, A, N) elementwise
    path (A ≤ 12, N ≤ 31 — a few hundred KiB of VMEM).
    """
    base = base_ref[...]                     # (BLK_M, N) f32
    free = free_ref[...][:, 0]               # (BLK_M,) f32
    f_before = f_ref[...][:, 0]              # (BLK_M,) f32
    v = v_ref[...]                           # (N,) f32
    mw = mw_ref[...]                         # (A, N) f32
    mp = mp_ref[...]                         # (A, N) f32
    mem = mem_ref[0]                         # scalar f32 — request slice demand
    free_after = free - mem                  # (BLK_M,) — same for every anchor
    elig = v[None, :] <= free_after[:, None]  # (BLK_M, N)
    if metric == "partial":
        ba = base[:, None, :] + mw[None, :, :]  # (BLK_M, A, N)
        counted = (ba > 0) & (ba < v[None, None, :])
        f_after = jnp.sum(
            jnp.where(counted & elig[:, None, :], v[None, None, :], 0.0), axis=-1
        )
    else:  # blocked: counted_after = (base > 0) | (mw > 0)
        cb = base > 0                        # (BLK_M, N)
        s_occ = jnp.sum(jnp.where(cb & elig, v[None, :], 0.0), axis=-1)  # (BLK_M,)
        cross = jnp.dot(                     # (BLK_M, A)
            jnp.where(~cb & elig, v[None, :], 0.0),
            mp.T,
            preferred_element_type=jnp.float32,
        )
        f_after = s_occ[:, None] + cross
    out_ref[...] = f_after - f_before[:, None]


@functools.partial(jax.jit, static_argnames=("metric", "interpret"))
def delta_from_base(
    base: jax.Array,
    free: jax.Array,
    v: jax.Array,
    mw: jax.Array,
    mp: jax.Array,
    mem: jax.Array,
    f_before: jax.Array,
    *,
    metric: str = "blocked",
    interpret: bool = True,
) -> jax.Array:
    """ΔF of every anchor dry-run of one request, from window counts.

    The Pallas form of :func:`repro.sim.batched._delta_from_base` for one
    model group (all GPUs share the placement table ``v``); the batched
    engine dispatches one call per :class:`~repro.core.mig.ClusterSpec`
    model group.  Output is the *raw* ΔF (no feasibility masking) —
    exactly what the engine's masked-refinement select consumes.

    Args:
      base: (M, N) float32 — occupied-slice count per placement window.
      free: (M,) — free memory slices per GPU.
      v: (N,) float32 — placement-window sizes (0 where padded).
      mw: (A, N) float32 — slices the request's anchors add per window.
      mp: (A, N) float32 — ``mw > 0`` indicator.
      mem: scalar — the request's slice demand on this model.
      f_before: (M,) float32 — current F(m) scores.
      metric: "blocked" | "partial".
      interpret: run in interpret mode (CPU validation); False on real TPU.

    Returns:
      (M, A) float32 ΔF table.
    """
    m, n = base.shape
    a = mw.shape[0]
    m_pad = -(-m // BLK_M) * BLK_M
    base_p = jnp.zeros((m_pad, n), jnp.float32).at[:m].set(base)
    free_p = jnp.zeros((m_pad, 1), jnp.float32).at[:m, 0].set(
        free.astype(jnp.float32)
    )
    f_p = jnp.zeros((m_pad, 1), jnp.float32).at[:m, 0].set(f_before)

    out = pl.pallas_call(
        functools.partial(_delta_from_base_kernel, metric=metric),
        grid=(m_pad // BLK_M,),
        in_specs=[
            pl.BlockSpec((BLK_M, n), lambda i: (i, 0)),
            pl.BlockSpec((BLK_M, 1), lambda i: (i, 0)),
            pl.BlockSpec((BLK_M, 1), lambda i: (i, 0)),
            pl.BlockSpec((n,), lambda i: (0,)),
            pl.BlockSpec((a, n), lambda i: (0, 0)),
            pl.BlockSpec((a, n), lambda i: (0, 0)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((BLK_M, a), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m_pad, a), jnp.float32),
        interpret=interpret,
    )(
        base_p,
        free_p,
        f_p,
        v.astype(jnp.float32),
        mw.astype(jnp.float32),
        mp.astype(jnp.float32),
        jnp.reshape(mem, (1,)).astype(jnp.float32),
        )
    return out[:m]
