"""Pallas TPU kernel for batched MIG fragmentation scoring (paper Alg. 1).

TPU adaptation (DESIGN.md §5): the per-GPU python loop becomes bitmask
algebra — an (BLK_M, 8) occupancy slab in VMEM against the constant
placement-window matrix Wᵀ (8, 18), one small matmul per block plus VPU
predicates.  Cloud-scale schedulers score 10⁴–10⁶ GPUs per decision batch;
the M axis is tiled in BLK_M-row slabs.

Weights/constants are passed as operands (broadcast BlockSpec) so the same
compiled kernel serves any placement table (e.g. other GPU models).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NUM_SLICES = 8
BLK_M = 512  # GPUs per VMEM slab (512×8 f32 = 16 KiB)


def _score_block(occ, w, v, metric: str):
    """Score a (blk, 8) occupancy slab.  occ f32, w (18,8) f32, v (18,) f32."""
    inwin = jnp.dot(occ, w.T, preferred_element_type=jnp.float32)  # (blk, 18)
    if metric == "blocked":
        counted = inwin > 0
    else:  # partial
        counted = (inwin > 0) & (inwin < v[None, :])
    free = NUM_SLICES - jnp.sum(occ, axis=-1, keepdims=True)  # (blk, 1)
    eligible = v[None, :] <= free
    return jnp.sum(jnp.where(counted & eligible, v[None, :], 0.0), axis=-1)


def _fragscore_kernel(occ_ref, w_ref, v_ref, out_ref, *, metric: str):
    occ = occ_ref[...].astype(jnp.float32)  # (BLK_M, 8)
    out_ref[...] = _score_block(occ, w_ref[...], v_ref[...], metric)[:, None]


@functools.partial(jax.jit, static_argnames=("metric", "interpret"))
def fragscore(
    occ: jax.Array,
    w: jax.Array,
    v: jax.Array,
    *,
    metric: str = "blocked",
    interpret: bool = True,
) -> jax.Array:
    """F(m) for every GPU.

    Args:
      occ: (M, 8) occupancy bitmap (any int/float dtype).
      w: (18, 8) placement-window masks.
      v: (18,) memory-slice weights.
      metric: "blocked" | "partial".
      interpret: run in interpret mode (CPU validation); False on real TPU.

    Returns:
      (M,) float32.
    """
    m = occ.shape[0]
    m_pad = -(-m // BLK_M) * BLK_M
    occ_p = jnp.zeros((m_pad, NUM_SLICES), occ.dtype).at[:m].set(occ)

    out = pl.pallas_call(
        functools.partial(_fragscore_kernel, metric=metric),
        grid=(m_pad // BLK_M,),
        in_specs=[
            pl.BlockSpec((BLK_M, NUM_SLICES), lambda i: (i, 0)),
            pl.BlockSpec((w.shape[0], NUM_SLICES), lambda i: (0, 0)),
            pl.BlockSpec((v.shape[0],), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((BLK_M, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m_pad, 1), jnp.float32),
        interpret=interpret,
    )(occ_p.astype(jnp.float32), w.astype(jnp.float32), v.astype(jnp.float32))
    return out[:m, 0]


def _mfi_delta_kernel(occ_ref, w_ref, v_ref, pm_ref, pv_ref, out_ref, *, metric: str, max_anchors: int):
    """ΔF of placing the requested profile at each anchor, +inf if infeasible."""
    occ = occ_ref[...].astype(jnp.float32)  # (BLK_M, 8)
    w = w_ref[...]
    v = v_ref[...]
    f_before = _score_block(occ, w, v, metric)  # (BLK_M,)
    big = jnp.float32(1e30)
    for a in range(max_anchors):  # unrolled: A <= 7
        mask = pm_ref[a, :]  # (8,)
        valid = pv_ref[a]  # scalar 0/1
        overlap = jnp.sum(occ * mask[None, :], axis=-1)  # (BLK_M,)
        feasible = (overlap == 0) & (valid > 0)
        hypo = jnp.minimum(occ + mask[None, :], 1.0)
        delta = _score_block(hypo, w, v, metric) - f_before
        out_ref[:, a] = jnp.where(feasible, delta, big)


@functools.partial(jax.jit, static_argnames=("metric", "interpret"))
def mfi_delta(
    occ: jax.Array,
    w: jax.Array,
    v: jax.Array,
    profile_masks: jax.Array,
    profile_valid: jax.Array,
    *,
    metric: str = "blocked",
    interpret: bool = True,
) -> jax.Array:
    """Fused Algorithm-2 inner loop: ΔF over all (GPU, anchor) dry-runs.

    Args:
      occ: (M, 8) occupancy.
      w, v: placement table as in :func:`fragscore`.
      profile_masks: (A, 8) window masks of the *requested* profile's anchors
        (padded rows are zero).
      profile_valid: (A,) 1.0 for real anchors, 0.0 for padding.

    Returns:
      (M, A) float32 ΔF, +1e30 where the placement is infeasible.
    """
    m = occ.shape[0]
    a = profile_masks.shape[0]
    m_pad = -(-m // BLK_M) * BLK_M
    occ_p = jnp.zeros((m_pad, NUM_SLICES), occ.dtype).at[:m].set(occ)

    out = pl.pallas_call(
        functools.partial(_mfi_delta_kernel, metric=metric, max_anchors=a),
        grid=(m_pad // BLK_M,),
        in_specs=[
            pl.BlockSpec((BLK_M, NUM_SLICES), lambda i: (i, 0)),
            pl.BlockSpec((w.shape[0], NUM_SLICES), lambda i: (0, 0)),
            pl.BlockSpec((v.shape[0],), lambda i: (0,)),
            pl.BlockSpec((a, NUM_SLICES), lambda i: (0, 0)),
            pl.BlockSpec((a,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((BLK_M, a), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m_pad, a), jnp.float32),
        interpret=interpret,
    )(
        occ_p.astype(jnp.float32),
        w.astype(jnp.float32),
        v.astype(jnp.float32),
        profile_masks.astype(jnp.float32),
        profile_valid.astype(jnp.float32),
    )
    return out[:m]
