"""Pallas TPU kernels (validated on CPU with interpret=True).

* ``fragscore``       -- batched fragmentation scoring (paper Algorithm 1)
* ``fragscore.mfi_delta`` -- fused MFI dry-run delta-F table (paper Algorithm 2)
* ``decode_attention`` -- GQA flash-decode over a KV cache (serving hot path)

Each kernel ships ``ops.py`` (jit'd public wrapper) and ``ref.py``
(pure-jnp oracle); tests sweep shapes/dtypes against the oracle.
"""
