"""Pure-jnp oracle for GQA flash-decode attention."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def decode_attention_ref(
    q: jax.Array,  # (B, H, D)
    k: jax.Array,  # (B, S, K, D)
    v: jax.Array,  # (B, S, K, D)
    *,
    scale: float | None = None,
    length: jax.Array | None = None,  # (B,) valid KV length per batch row
) -> jax.Array:
    """Single-token decode attention with a GQA KV cache.

    Returns (B, H, D) in the dtype of q.
    """
    b, h, d = q.shape
    s, kheads = k.shape[1], k.shape[2]
    assert h % kheads == 0
    g = h // kheads
    if scale is None:
        scale = d ** -0.5

    qf = q.astype(jnp.float32).reshape(b, kheads, g, d)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)

    # logits: (B, K, G, S)
    logits = jnp.einsum("bkgd,bskd->bkgs", qf, kf) * scale
    if length is not None:
        mask = jnp.arange(s)[None, :] < length[:, None]  # (B, S)
        logits = jnp.where(mask[:, None, None, :], logits, -jnp.inf)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgs,bskd->bkgd", p, vf)
    return out.reshape(b, h, d).astype(q.dtype)
