"""Public wrapper for the flash-decode kernel with CPU fallback selection."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.decode_attention.decode_attention import decode_attention as _kernel
from repro.kernels.decode_attention.ref import decode_attention_ref


def _use_interpret() -> bool:
    return jax.default_backend() != "tpu"


def gqa_decode_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    length: jax.Array | None = None,
    *,
    scale: float | None = None,
    blk_s: int = 512,
    use_kernel: bool = True,
) -> jax.Array:
    """GQA decode attention: (B,H,D) × (B,S,K,D) KV cache -> (B,H,D).

    ``use_kernel=False`` falls back to the pure-jnp reference (used inside
    jitted model code where interpret-mode pallas would be slow on CPU).
    """
    if length is None:
        length = jnp.full((q.shape[0],), k.shape[1], jnp.int32)
    if not use_kernel:
        return decode_attention_ref(q, k, v, scale=scale, length=length)
    return _kernel(q, k, v, length, scale=scale, blk_s=blk_s, interpret=_use_interpret())
