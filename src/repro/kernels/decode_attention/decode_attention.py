"""Pallas TPU flash-decode kernel: one new token attending to a GQA KV cache.

Blocking (TPU-native, DESIGN.md §5/6):
  * grid = (B, K, S/BLK_S): batch × kv-head × sequence blocks; the sequence
    axis is the innermost (sequential) grid dimension, so the online-softmax
    accumulators live in VMEM scratch across S-blocks.
  * per step the kernel holds a (G, D) query tile (the kv-head's query
    group), a (BLK_S, D) key tile and a (BLK_S, D) value tile in VMEM —
    BLK_S×D is lane-aligned (D ∈ {64..256} multiples of 64, BLK_S multiple
    of 128).
  * accumulators: running max m (G, 1), normaliser l (G, 1), weighted sum
    acc (G, D), all f32; output written on the last S-block.

Numerics follow the standard flash recurrence; masking of padded KV entries
uses a per-batch ``length`` operand.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLK_S = 512


def _decode_kernel(len_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *, scale, blk_s):
    sb = pl.program_id(2)
    nsb = pl.num_programs(2)

    @pl.when(sb == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, -jnp.inf)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)  # (G, D)
    k = k_ref[0, :, 0, :].astype(jnp.float32)  # (BLK_S, D)
    v = v_ref[0, :, 0, :].astype(jnp.float32)  # (BLK_S, D)

    logits = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale  # (G, BLK_S)

    # mask out entries beyond the valid KV length of this batch row
    length = len_ref[0]
    pos = sb * blk_s + jax.lax.broadcasted_iota(jnp.int32, logits.shape, 1)
    logits = jnp.where(pos < length, logits, -jnp.inf)

    m_prev = m_ref[...]  # (G, 1)
    m_cur = jnp.max(logits, axis=-1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    # guard against all -inf blocks (fully masked): exp(-inf - -inf) -> nan
    safe_m = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
    p = jnp.exp(logits - safe_m)  # (G, BLK_S)
    p = jnp.where(jnp.isfinite(logits), p, 0.0)
    alpha = jnp.where(jnp.isfinite(m_prev), jnp.exp(m_prev - safe_m), 0.0)  # (G, 1)

    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
        p, v, preferred_element_type=jnp.float32
    )
    m_ref[...] = m_new

    @pl.when(sb == nsb - 1)
    def _finalize():
        l = l_ref[...]
        o_ref[0, 0] = (acc_ref[...] / jnp.where(l == 0, 1.0, l)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("scale", "blk_s", "interpret"))
def decode_attention(
    q: jax.Array,  # (B, H, D)
    k: jax.Array,  # (B, S, K, D)
    v: jax.Array,  # (B, S, K, D)
    length: jax.Array,  # (B,) int32 valid KV length
    *,
    scale: float | None = None,
    blk_s: int = DEFAULT_BLK_S,
    interpret: bool = True,
) -> jax.Array:
    b, h, d = q.shape
    s, kheads = k.shape[1], k.shape[2]
    assert h % kheads == 0, (h, kheads)
    g = h // kheads
    if scale is None:
        scale = float(d) ** -0.5

    blk_s = min(blk_s, s)
    s_pad = -(-s // blk_s) * blk_s
    if s_pad != s:
        pad = [(0, 0), (0, s_pad - s), (0, 0), (0, 0)]
        k = jnp.pad(k, pad)
        v = jnp.pad(v, pad)

    qg = q.reshape(b, kheads, g, d)

    out = pl.pallas_call(
        functools.partial(_decode_kernel, scale=scale, blk_s=blk_s),
        grid=(b, kheads, s_pad // blk_s),
        in_specs=[
            pl.BlockSpec((1,), lambda bi, ki, si: (bi,)),
            pl.BlockSpec((1, 1, g, d), lambda bi, ki, si: (bi, ki, 0, 0)),
            pl.BlockSpec((1, blk_s, 1, d), lambda bi, ki, si: (bi, si, ki, 0)),
            pl.BlockSpec((1, blk_s, 1, d), lambda bi, ki, si: (bi, si, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, g, d), lambda bi, ki, si: (bi, ki, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, kheads, g, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, d), jnp.float32),
        ],
        interpret=interpret,
    )(length.astype(jnp.int32), qg, k, v)
    return out.reshape(b, h, d)
