"""Algorithm 1 — the MIG fragmentation score.

Two variants are provided (see DESIGN.md §1.1):

* ``"blocked"`` (default — Algorithm 1 exactly as written): a placement
  window contributes when any of its slices is occupied
  (``sum_{i in window} x_{m,i} > 0``).  Together with Table I's literal
  slice counts (7g.80gb -> 7) this reproduces the paper's *relative results*
  (MFI best on acceptance/allocated/fragmentation).
* ``"partial"``: a window contributes only when it contains at least one
  occupied AND at least one free slice — i.e. its free slices are wasted by
  co-occupancy.  This is the only reading that reproduces the paper's worked
  example arithmetic (F(GPU2)=16=2+2+8+4, F(GPU1)=8), but it empirically
  *underperforms* the blocked variant as an MFI driver (see EXPERIMENTS.md
  §Paper/MetricVariants).

Both variants only consider profiles that could still fit by raw free-slice
count (``mem(p) <= free_slices``) — the paper's eligibility condition
``r_w(p) <= ΔS_m`` — and weight each counted window by the profile's
memory-slice count ``r^mem``.
"""

from __future__ import annotations

from typing import Union

import numpy as np

from repro.core import mig

METRIC_VARIANTS = ("blocked", "partial")


def _validate_metric(metric: str) -> None:
    if metric not in METRIC_VARIANTS:
        raise ValueError(f"metric must be one of {METRIC_VARIANTS}, got {metric!r}")


def fragmentation_score(
    occupancy: Union[np.ndarray, "mig.GPUState"],
    metric: str = "blocked",
) -> float:
    """Fragmentation score F(m) of a single GPU (Algorithm 1)."""
    if isinstance(occupancy, mig.GPUState):
        occupancy = occupancy.occupancy
    return float(
        fragmentation_scores(occupancy[None, :].astype(np.int32), metric)[0]
    )


def fragmentation_scores(occupancy: np.ndarray, metric: str = "blocked") -> np.ndarray:
    """Vectorized F(m) over a cluster occupancy matrix.

    Args:
      occupancy: (M, 8) 0/1 int array.
      metric: "blocked" (Algorithm-1-literal, default) or "partial" (worked-example).

    Returns:
      (M,) float64 fragmentation scores.
    """
    _validate_metric(metric)
    occ = np.asarray(occupancy, dtype=np.int32)
    if occ.ndim != 2 or occ.shape[1] != mig.NUM_MEM_SLICES:
        raise ValueError(f"occupancy must be (M, {mig.NUM_MEM_SLICES}), got {occ.shape}")

    # occupied-slice count inside each placement window: (M, NUM_PLACEMENTS)
    occ_in_window = occ @ mig.PLACEMENT_MASKS.T
    window_size = mig.PLACEMENT_MEM[None, :]

    if metric == "partial":
        counted = (occ_in_window > 0) & (occ_in_window < window_size)
    else:  # blocked
        counted = occ_in_window > 0

    # eligibility: profile must still fit by raw free-slice count
    free = mig.NUM_MEM_SLICES - occ.sum(axis=1, keepdims=True)  # (M, 1)
    eligible = mig.PLACEMENT_MEM[None, :] <= free  # (M, NUM_PLACEMENTS)

    weights = mig.PLACEMENT_MEM[None, :].astype(np.float64)
    return ((counted & eligible) * weights).sum(axis=1)


def cluster_fragmentation(occupancy: np.ndarray, metric: str = "blocked") -> float:
    """Average fragmentation score across the cluster (paper's severity metric)."""
    return float(fragmentation_scores(occupancy, metric).mean())


def delta_f(
    occupancy: np.ndarray,
    profile_id: int,
    anchor: int,
    metric: str = "blocked",
) -> float:
    """ΔF of hypothetically placing ``profile_id``@``anchor`` on one GPU.

    Args:
      occupancy: (8,) occupancy of a single GPU; the placement must be feasible.
    """
    occ = np.asarray(occupancy, dtype=np.int32)
    prof = mig.PROFILES[profile_id]
    if anchor not in prof.anchors:
        raise ValueError(f"anchor {anchor} illegal for {prof.name}")
    window = occ[anchor : anchor + prof.mem]
    if window.any():
        raise ValueError("infeasible dry-run placement")
    before = fragmentation_score(occ, metric)
    hypo = occ.copy()
    hypo[anchor : anchor + prof.mem] = 1
    after = fragmentation_score(hypo, metric)
    return after - before
