"""Algorithm 1 — the MIG fragmentation score.

Two variants are provided (see DESIGN.md §1.1):

* ``"blocked"`` (default — Algorithm 1 exactly as written): a placement
  window contributes when any of its slices is occupied
  (``sum_{i in window} x_{m,i} > 0``).  Together with Table I's literal
  slice counts (7g.80gb -> 7) this reproduces the paper's *relative results*
  (MFI best on acceptance/allocated/fragmentation).
* ``"partial"``: a window contributes only when it contains at least one
  occupied AND at least one free slice — i.e. its free slices are wasted by
  co-occupancy.  This is the only reading that reproduces the paper's worked
  example arithmetic (F(GPU2)=16=2+2+8+4, F(GPU1)=8), but it empirically
  *underperforms* the blocked variant as an MFI driver (see EXPERIMENTS.md
  §Paper/MetricVariants).

Both variants only consider profiles that could still fit by raw free-slice
count (``mem(p) <= free_slices``) — the paper's eligibility condition
``r_w(p) <= ΔS_m`` — and weight each counted window by the profile's
memory-slice count ``r^mem``.
"""

from __future__ import annotations

from typing import Optional, Union

import numpy as np

from repro.core import mig

METRIC_VARIANTS = ("blocked", "partial")


def _validate_metric(metric: str) -> None:
    if metric not in METRIC_VARIANTS:
        raise ValueError(f"metric must be one of {METRIC_VARIANTS}, got {metric!r}")


def fragmentation_score(
    occupancy: Union[np.ndarray, "mig.GPUState"],
    metric: str = "blocked",
    model: Optional["mig.DeviceModel"] = None,
) -> float:
    """Fragmentation score F(m) of a single GPU (Algorithm 1)."""
    if isinstance(occupancy, mig.GPUState):
        model = occupancy.model if model is None else model
        occupancy = occupancy.occupancy
    return float(
        fragmentation_scores(occupancy[None, :].astype(np.int32), metric, model)[0]
    )


def fragmentation_scores(
    occupancy: np.ndarray,
    metric: str = "blocked",
    model: Optional["mig.DeviceModel"] = None,
) -> np.ndarray:
    """Vectorized F(m) over the occupancy matrix of same-model GPUs.

    Args:
      occupancy: (M, S) 0/1 int array, S = the model's memory-slice count.
      metric: "blocked" (Algorithm-1-literal, default) or "partial" (worked-example).
      model: device model whose placement table scores the windows
        (default: the paper's A100-80GB).

    Returns:
      (M,) float64 fragmentation scores.
    """
    _validate_metric(metric)
    if model is None:
        model = mig.A100_80GB
    occ = np.asarray(occupancy, dtype=np.int32)
    if occ.ndim != 2 or occ.shape[1] != model.num_mem_slices:
        raise ValueError(
            f"occupancy must be (M, {model.num_mem_slices}), got {occ.shape}"
        )

    # occupied-slice count inside each placement window: (M, NUM_PLACEMENTS)
    occ_in_window = occ @ model.placement_masks.T
    window_size = model.placement_mem[None, :]

    if metric == "partial":
        counted = (occ_in_window > 0) & (occ_in_window < window_size)
    else:  # blocked
        counted = occ_in_window > 0

    # eligibility: profile must still fit by raw free-slice count
    free = model.num_mem_slices - occ.sum(axis=1, keepdims=True)  # (M, 1)
    eligible = window_size <= free  # (M, NUM_PLACEMENTS)

    weights = window_size.astype(np.float64)
    return ((counted & eligible) * weights).sum(axis=1)


def spec_fragmentation_scores(
    occupancy: np.ndarray,
    spec: "mig.ClusterSpec",
    metric: str = "blocked",
) -> np.ndarray:
    """F(m) per GPU of a (possibly mixed) cluster, each against its own model.

    Args:
      occupancy: (spec.num_gpus, spec.num_mem_slices) bitmap — narrower
        models read their leading columns (the rest are zero-padding).
    """
    occ = np.asarray(occupancy, dtype=np.int32)
    out = np.zeros(spec.num_gpus, dtype=np.float64)
    for model, rows in spec.model_groups():
        out[rows] = fragmentation_scores(
            occ[rows][:, : model.num_mem_slices], metric, model
        )
    return out


def cluster_fragmentation(
    occupancy: np.ndarray,
    metric: str = "blocked",
    spec: Optional["mig.ClusterSpec"] = None,
) -> float:
    """Average fragmentation score across the cluster (paper's severity metric)."""
    if spec is None:
        return float(fragmentation_scores(occupancy, metric).mean())
    return float(spec_fragmentation_scores(occupancy, spec, metric).mean())


def delta_f(
    occupancy: np.ndarray,
    profile_id: int,
    anchor: int,
    metric: str = "blocked",
    model: Optional["mig.DeviceModel"] = None,
) -> float:
    """ΔF of hypothetically placing ``profile_id``@``anchor`` on one GPU.

    Args:
      occupancy: (S,) occupancy of a single GPU; the placement must be feasible.
    """
    if model is None:
        model = mig.A100_80GB
    occ = np.asarray(occupancy, dtype=np.int32)
    prof = model.profiles[profile_id]
    if anchor not in prof.anchors:
        raise ValueError(f"anchor {anchor} illegal for {prof.name}")
    window = occ[anchor : anchor + prof.mem]
    if window.any():
        raise ValueError("infeasible dry-run placement")
    before = fragmentation_score(occ, metric, model)
    hypo = occ.copy()
    hypo[anchor : anchor + prof.mem] = 1
    after = fragmentation_score(hypo, metric, model)
    return after - before
