"""MIG hardware model: profiles, placement indexes, GPU and cluster state.

Models an A100-80GB-style GPU as 8 memory slices (the unit of occupancy) and
7 SM slices (tracked for the utilization metric).  Placement legality follows
NVIDIA's placement-index table (paper Table I): a profile anchored at memory
slice ``i`` occupies the contiguous memory-slice window ``[i, i + mem - 1]``.

The module is pure-python/numpy (the reference control plane); the vectorized
JAX cluster lives in :mod:`repro.core.cluster` and the Pallas kernels in
:mod:`repro.kernels`.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

NUM_MEM_SLICES = 8
NUM_SM_SLICES = 7


@dataclasses.dataclass(frozen=True)
class MIGProfile:
    """A MIG profile (e.g. ``2g.20gb``): compute + memory slice demand."""

    name: str
    compute: int  # SM slices (utilization accounting)
    mem: int      # memory slices (occupancy unit)
    anchors: Tuple[int, ...]  # legal placement start indexes (Table I)

    @property
    def num_placements(self) -> int:
        return len(self.anchors)


# Paper Table I (A100-80GB).  7g.80gb has slice count 7 exactly as the paper
# prints: its window is {0..6}; memory slice 7 is unreachable by any other
# profile once 7g is placed (no legal anchor covers it), so mem=7 is
# behaviourally equivalent for allocation while keeping 7g *eligible* in the
# fragmentation score of a GPU with exactly one occupied slice -- this is the
# empty-GPU defence term (see DESIGN.md §1.2 and EXPERIMENTS.md).
PROFILES: Tuple[MIGProfile, ...] = (
    MIGProfile("7g.80gb", compute=7, mem=7, anchors=(0,)),
    MIGProfile("4g.40gb", compute=4, mem=4, anchors=(0,)),
    MIGProfile("3g.40gb", compute=3, mem=4, anchors=(0, 4)),
    MIGProfile("2g.20gb", compute=2, mem=2, anchors=(0, 2, 4)),
    MIGProfile("1g.20gb", compute=1, mem=2, anchors=(0, 2, 4, 6)),
    MIGProfile("1g.10gb", compute=1, mem=1, anchors=(0, 1, 2, 3, 4, 5, 6)),
)

PROFILE_BY_NAME: Dict[str, MIGProfile] = {p.name: p for p in PROFILES}
PROFILE_NAMES: Tuple[str, ...] = tuple(p.name for p in PROFILES)
NUM_PROFILES = len(PROFILES)

# ---------------------------------------------------------------------------
# Flattened placement table: every legal (profile, anchor) pair is one row.
# ---------------------------------------------------------------------------


def _build_placements():
    rows = []
    for pid, prof in enumerate(PROFILES):
        for anchor in prof.anchors:
            mask = np.zeros(NUM_MEM_SLICES, dtype=np.int32)
            mask[anchor : anchor + prof.mem] = 1
            rows.append((pid, anchor, mask))
    pids = np.array([r[0] for r in rows], dtype=np.int32)
    anchors = np.array([r[1] for r in rows], dtype=np.int32)
    masks = np.stack([r[2] for r in rows])  # (NUM_PLACEMENTS, 8)
    return pids, anchors, masks


PLACEMENT_PROFILE_ID, PLACEMENT_ANCHOR, PLACEMENT_MASKS = _build_placements()
NUM_PLACEMENTS = PLACEMENT_MASKS.shape[0]  # 18 for the A100 table
PLACEMENT_MEM = np.array(
    [PROFILES[pid].mem for pid in PLACEMENT_PROFILE_ID], dtype=np.int32
)
PROFILE_MEM = np.array([p.mem for p in PROFILES], dtype=np.int32)
PROFILE_COMPUTE = np.array([p.compute for p in PROFILES], dtype=np.int32)

# slice-offset ranges of each profile inside the flattened placement table
_PROFILE_PLACEMENT_SLICES: List[slice] = []
_off = 0
for _p in PROFILES:
    _PROFILE_PLACEMENT_SLICES.append(slice(_off, _off + _p.num_placements))
    _off += _p.num_placements


def profile_placement_rows(pid: int) -> slice:
    """Rows of the placement table belonging to profile ``pid``."""
    return _PROFILE_PLACEMENT_SLICES[pid]


# ---------------------------------------------------------------------------
# GPU state
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class Allocation:
    """A committed placement of a workload on a GPU."""

    workload_id: int
    profile_id: int
    anchor: int


class GPUState:
    """Occupancy state of one MIG-capable GPU."""

    def __init__(self, gpu_id: int = 0):
        self.gpu_id = gpu_id
        self.occupancy = np.zeros(NUM_MEM_SLICES, dtype=np.int32)
        self.allocations: Dict[int, Allocation] = {}

    # -- queries ------------------------------------------------------------
    @property
    def free_slices(self) -> int:
        return int(NUM_MEM_SLICES - self.occupancy.sum())

    @property
    def used_mem_slices(self) -> int:
        return int(self.occupancy.sum())

    @property
    def used_compute_slices(self) -> int:
        return int(
            sum(PROFILES[a.profile_id].compute for a in self.allocations.values())
        )

    @property
    def is_active(self) -> bool:
        return bool(self.allocations)

    def feasible_anchors(self, profile_id: int) -> List[int]:
        """Anchors where ``profile_id`` can be placed right now."""
        prof = PROFILES[profile_id]
        out = []
        for anchor in prof.anchors:
            if not self.occupancy[anchor : anchor + prof.mem].any():
                out.append(anchor)
        return out

    def can_fit(self, profile_id: int) -> bool:
        return bool(self.feasible_anchors(profile_id))

    # -- mutation -----------------------------------------------------------
    def allocate(self, workload_id: int, profile_id: int, anchor: int) -> None:
        prof = PROFILES[profile_id]
        window = self.occupancy[anchor : anchor + prof.mem]
        if anchor not in prof.anchors:
            raise ValueError(
                f"anchor {anchor} illegal for profile {prof.name} "
                f"(legal: {prof.anchors})"
            )
        if window.any():
            raise ValueError(
                f"profile {prof.name}@{anchor} overlaps occupied slices on "
                f"GPU {self.gpu_id}"
            )
        window[:] = 1
        self.allocations[workload_id] = Allocation(workload_id, profile_id, anchor)

    def release(self, workload_id: int) -> None:
        alloc = self.allocations.pop(workload_id)
        prof = PROFILES[alloc.profile_id]
        self.occupancy[alloc.anchor : alloc.anchor + prof.mem] = 0


class ClusterState:
    """A homogeneous MIG GPU cluster."""

    def __init__(self, num_gpus: int):
        self.gpus = [GPUState(i) for i in range(num_gpus)]
        self._placement_of: Dict[int, int] = {}  # workload_id -> gpu_id

    def __len__(self) -> int:
        return len(self.gpus)

    @property
    def num_gpus(self) -> int:
        return len(self.gpus)

    def occupancy_matrix(self) -> np.ndarray:
        """(M, 8) int32 occupancy bitmap of the whole cluster."""
        return np.stack([g.occupancy for g in self.gpus])

    def allocate(self, workload_id: int, profile_id: int, gpu_id: int, anchor: int):
        self.gpus[gpu_id].allocate(workload_id, profile_id, anchor)
        self._placement_of[workload_id] = gpu_id

    def release(self, workload_id: int) -> None:
        gpu_id = self._placement_of.pop(workload_id)
        self.gpus[gpu_id].release(workload_id)

    def gpu_of(self, workload_id: int) -> Optional[int]:
        return self._placement_of.get(workload_id)

    # -- metrics ------------------------------------------------------------
    @property
    def active_gpus(self) -> int:
        return sum(g.is_active for g in self.gpus)

    @property
    def used_mem_slices(self) -> int:
        return sum(g.used_mem_slices for g in self.gpus)

    @property
    def used_compute_slices(self) -> int:
        return sum(g.used_compute_slices for g in self.gpus)

    @property
    def total_mem_slices(self) -> int:
        return NUM_MEM_SLICES * self.num_gpus
