"""MIG hardware model: profiles, placement indexes, GPU and cluster state.

Requests arrive as one of the paper's six Table-I demand classes (named
after their A100-80GB realization, e.g. ``2g.20gb`` = 2 SM slices + 20 GiB).
A :class:`DeviceModel` describes how each class is realized on one GPU
generation: its own placement table (legal anchor windows per class), its
slice-memory size, and possibly *no* realization at all (an 80 GiB demand
cannot fit an A100-40GB).  Placement legality follows NVIDIA's
placement-index tables: a profile anchored at memory slice ``i`` occupies
the contiguous memory-slice window ``[i, i + mem - 1]``.

A :class:`ClusterSpec` is an ordered list of ``(model, count)`` pairs; the
paper's homogeneous A100 fleet is the trivial one-model spec and is the
default everywhere, so all module-level table aliases (``PLACEMENT_MASKS``,
``PROFILE_MEM``, ...) remain the A100-80GB tables.

The module is pure-python/numpy (the reference control plane); the vectorized
JAX cluster lives in :mod:`repro.core.cluster` and the Pallas kernels in
:mod:`repro.kernels`.
"""

from __future__ import annotations

import dataclasses
import functools
import math
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

NUM_MEM_SLICES = 8
NUM_SM_SLICES = 7


@dataclasses.dataclass(frozen=True)
class MIGProfile:
    """A MIG profile (e.g. ``2g.20gb``): compute + memory slice demand.

    ``anchors`` may be empty: the demand class has no realization on the
    device model carrying this entry (e.g. 80 GiB on an A100-40GB) and is
    rejected there by construction.
    """

    name: str
    compute: int  # SM slices (utilization accounting)
    mem: int      # memory slices (occupancy unit)
    anchors: Tuple[int, ...]  # legal placement start indexes (Table I)

    @property
    def num_placements(self) -> int:
        return len(self.anchors)


# Paper Table I (A100-80GB).  7g.80gb has slice count 7 exactly as the paper
# prints: its window is {0..6}; memory slice 7 is unreachable by any other
# profile once 7g is placed (no legal anchor covers it), so mem=7 is
# behaviourally equivalent for allocation while keeping 7g *eligible* in the
# fragmentation score of a GPU with exactly one occupied slice -- this is the
# empty-GPU defence term (see DESIGN.md §1.2 and EXPERIMENTS.md).
PROFILES: Tuple[MIGProfile, ...] = (
    MIGProfile("7g.80gb", compute=7, mem=7, anchors=(0,)),
    MIGProfile("4g.40gb", compute=4, mem=4, anchors=(0,)),
    MIGProfile("3g.40gb", compute=3, mem=4, anchors=(0, 4)),
    MIGProfile("2g.20gb", compute=2, mem=2, anchors=(0, 2, 4)),
    MIGProfile("1g.20gb", compute=1, mem=2, anchors=(0, 2, 4, 6)),
    MIGProfile("1g.10gb", compute=1, mem=1, anchors=(0, 1, 2, 3, 4, 5, 6)),
)

PROFILE_BY_NAME: Dict[str, MIGProfile] = {p.name: p for p in PROFILES}
PROFILE_NAMES: Tuple[str, ...] = tuple(p.name for p in PROFILES)
NUM_PROFILES = len(PROFILES)

# ---------------------------------------------------------------------------
# Device models: per-generation placement tables for the same demand classes.
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class DeviceModel:
    """One GPU generation/SKU: how each demand class lands on its slices.

    ``profiles[pid]`` is the local realization of canonical demand class
    ``pid`` (indexed exactly like :data:`PROFILES`); an entry with empty
    ``anchors`` means the class cannot be placed on this model.  The derived
    flattened placement table (every legal (class, anchor) pair is one row)
    is cached per instance; instances are frozen/hashable so they double as
    cache and jit keys.
    """

    name: str
    slice_gib: int  # memory per slice (GiB) — documentation/capacity planning
    profiles: Tuple[MIGProfile, ...]
    num_mem_slices: int = NUM_MEM_SLICES
    num_sm_slices: int = NUM_SM_SLICES

    def __post_init__(self):
        if len(self.profiles) != len(PROFILES):
            raise ValueError(
                f"{self.name}: need one realization per demand class "
                f"({len(PROFILES)}), got {len(self.profiles)}"
            )
        for p in self.profiles:
            for a in p.anchors:
                if a + p.mem > self.num_mem_slices:
                    raise ValueError(f"{self.name}/{p.name}@{a} out of bounds")

    # -- flattened placement table (one row per legal (class, anchor)) ------
    @functools.cached_property
    def _placements(self) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        rows = []
        for pid, prof in enumerate(self.profiles):
            for anchor in prof.anchors:
                mask = np.zeros(self.num_mem_slices, dtype=np.int32)
                mask[anchor : anchor + prof.mem] = 1
                rows.append((pid, anchor, mask))
        pids = np.array([r[0] for r in rows], dtype=np.int32)
        anchors = np.array([r[1] for r in rows], dtype=np.int32)
        masks = (
            np.stack([r[2] for r in rows])
            if rows
            else np.zeros((0, self.num_mem_slices), dtype=np.int32)
        )
        return pids, anchors, masks

    @property
    def placement_profile_id(self) -> np.ndarray:
        return self._placements[0]

    @property
    def placement_anchor(self) -> np.ndarray:
        return self._placements[1]

    @property
    def placement_masks(self) -> np.ndarray:
        return self._placements[2]

    @functools.cached_property
    def placement_mem(self) -> np.ndarray:
        return np.array(
            [self.profiles[pid].mem for pid in self.placement_profile_id],
            dtype=np.int32,
        )

    @property
    def num_placements(self) -> int:
        return self.placement_masks.shape[0]

    @functools.cached_property
    def max_anchors(self) -> int:
        return max(1, max(p.num_placements for p in self.profiles))

    @functools.cached_property
    def profile_mem(self) -> np.ndarray:
        return np.array([p.mem for p in self.profiles], dtype=np.int32)

    @functools.cached_property
    def profile_compute(self) -> np.ndarray:
        return np.array([p.compute for p in self.profiles], dtype=np.int32)

    @functools.cached_property
    def _profile_placement_slices(self) -> Tuple[slice, ...]:
        out, off = [], 0
        for p in self.profiles:
            out.append(slice(off, off + p.num_placements))
            off += p.num_placements
        return tuple(out)

    def profile_placement_rows(self, pid: int) -> slice:
        """Rows of this model's placement table belonging to class ``pid``."""
        return self._profile_placement_slices[pid]

    def placeable(self, pid: int) -> bool:
        return bool(self.profiles[pid].anchors)


#: The paper's device (canonical classes ARE their realizations).
A100_80GB = DeviceModel(name="a100-80gb", slice_gib=10, profiles=PROFILES)

#: A100-40GB: 8 × 5 GiB slices.  The same demand classes need twice the
#: slices (NVIDIA table: 1g.5gb / 2g.10gb / 3g.20gb / 4g.20gb / 7g.40gb),
#: so 20 GiB demands occupy a half-GPU window, 40 GiB demands the full GPU,
#: and the 80 GiB class has no realization at all.
A100_40GB = DeviceModel(
    name="a100-40gb",
    slice_gib=5,
    profiles=(
        MIGProfile("n/a.80gb", compute=7, mem=7, anchors=()),   # cannot fit
        MIGProfile("7g.40gb", compute=7, mem=7, anchors=(0,)),
        MIGProfile("7g.40gb", compute=7, mem=7, anchors=(0,)),
        MIGProfile("3g.20gb", compute=3, mem=4, anchors=(0, 4)),
        MIGProfile("3g.20gb", compute=3, mem=4, anchors=(0, 4)),
        MIGProfile("2g.10gb", compute=2, mem=2, anchors=(0, 2, 4)),
    ),
)

#: H100-96GB: 8 × 12 GiB slices — A100 placement geometry, roomier slices.
H100_96GB = DeviceModel(
    name="h100-96gb",
    slice_gib=12,
    profiles=(
        MIGProfile("7g.96gb", compute=7, mem=7, anchors=(0,)),
        MIGProfile("4g.48gb", compute=4, mem=4, anchors=(0,)),
        MIGProfile("3g.48gb", compute=3, mem=4, anchors=(0, 4)),
        MIGProfile("2g.24gb", compute=2, mem=2, anchors=(0, 2, 4)),
        MIGProfile("1g.24gb", compute=1, mem=2, anchors=(0, 2, 4, 6)),
        MIGProfile("1g.12gb", compute=1, mem=1, anchors=(0, 1, 2, 3, 4, 5, 6)),
    ),
)

#: H100-80GB: 8 × 10 GiB slices.  NVIDIA's H100-80GB placement-index table
#: matches the A100-80GB one for the six canonical demand classes, so the
#: canonical classes are their own realizations — same geometry as the
#: paper's device, distinct SKU (cost/power-aware policies can tell them
#: apart via the ``model-group`` scoring key).
H100_80GB = DeviceModel(name="h100-80gb", slice_gib=10, profiles=PROFILES)

#: H200-141GB (stylized): **12** × 12 GiB memory slices (144 ≈ the 141 GiB
#: marketing capacity) — the only non-8-slice geometry in the registry, so
#: mixed fleets carrying it exercise the padded-width paths everywhere
#: (occupancy bitmaps, stacked `SpecTables`, per-model fragmentation).
#: Placement windows follow the NVIDIA power-of-two alignment style on the
#: wider grid: full-GPU-minus-trailing for 7g, quarter-aligned for 4g/3g,
#: even anchors for the 2-slice classes, every slice for 1g.
H200_141GB = DeviceModel(
    name="h200-141gb",
    slice_gib=12,
    num_mem_slices=12,
    profiles=(
        MIGProfile("7g.84gb", compute=7, mem=7, anchors=(0,)),
        MIGProfile("4g.48gb", compute=4, mem=4, anchors=(0, 4, 8)),
        MIGProfile("3g.48gb", compute=3, mem=4, anchors=(0, 4, 8)),
        MIGProfile("2g.24gb", compute=2, mem=2, anchors=(0, 2, 4, 6, 8, 10)),
        MIGProfile("1g.24gb", compute=1, mem=2, anchors=(0, 2, 4, 6, 8, 10)),
        MIGProfile("1g.12gb", compute=1, mem=1, anchors=tuple(range(12))),
    ),
)

DEVICE_MODELS: Dict[str, DeviceModel] = {
    "a100-80": A100_80GB,
    "a100-80gb": A100_80GB,
    "a100-40": A100_40GB,
    "a100-40gb": A100_40GB,
    "h100-96": H100_96GB,
    "h100-96gb": H100_96GB,
    "h100-80": H100_80GB,
    "h100-80gb": H100_80GB,
    "h200-141": H200_141GB,
    "h200-141gb": H200_141GB,
}


@dataclasses.dataclass(frozen=True)
class ClusterSpec:
    """An ordered mixed fleet: ``((model, count), ...)``.

    GPU ids are assigned contiguously in entry order; the paper's setup is
    the one-model spec ``ClusterSpec.homogeneous(A100_80GB, M)``.
    """

    entries: Tuple[Tuple[DeviceModel, int], ...]

    def __post_init__(self):
        if not self.entries:
            raise ValueError("ClusterSpec needs at least one (model, count)")
        for model, count in self.entries:
            if count <= 0:
                raise ValueError(f"{model.name}: count must be positive")

    @classmethod
    def homogeneous(cls, model: DeviceModel, num_gpus: int) -> "ClusterSpec":
        return cls(entries=((model, num_gpus),))

    @classmethod
    def parse(cls, text: str) -> "ClusterSpec":
        """``"a100-80:50,a100-40:50"`` -> ClusterSpec (see DEVICE_MODELS)."""
        entries = []
        for part in text.split(","):
            name, _, count = part.strip().partition(":")
            if name not in DEVICE_MODELS:
                raise ValueError(
                    f"unknown device model {name!r}; options "
                    f"{sorted(set(DEVICE_MODELS))}"
                )
            entries.append((DEVICE_MODELS[name], int(count) if count else 1))
        return cls(entries=tuple(entries))

    @functools.cached_property
    def num_gpus(self) -> int:
        return sum(count for _, count in self.entries)

    @functools.cached_property
    def models(self) -> Tuple[DeviceModel, ...]:
        """Distinct models in first-appearance order."""
        seen: List[DeviceModel] = []
        for model, _ in self.entries:
            if model not in seen:
                seen.append(model)
        return tuple(seen)

    @functools.cached_property
    def model_index(self) -> np.ndarray:
        """(num_gpus,) int32 — index into :attr:`models` per GPU."""
        idx = {m: k for k, m in enumerate(self.models)}
        return np.concatenate(
            [np.full(count, idx[model], np.int32) for model, count in self.entries]
        )

    def model_of(self, gpu_id: int) -> DeviceModel:
        return self.models[self.model_index[gpu_id]]

    @property
    def is_homogeneous(self) -> bool:
        return len(self.models) == 1

    @functools.cached_property
    def num_mem_slices(self) -> int:
        """Common occupancy-bitmap width (max slice count over models)."""
        return max(m.num_mem_slices for m in self.models)

    @functools.cached_property
    def total_mem_slices(self) -> int:
        return sum(m.num_mem_slices * count for m, count in self.entries)

    def model_groups(self) -> List[Tuple[DeviceModel, np.ndarray]]:
        """Per distinct model: (model, int array of its GPU ids)."""
        return [
            (m, np.flatnonzero(self.model_index == k))
            for k, m in enumerate(self.models)
        ]


# ---------------------------------------------------------------------------
# Fault model
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class FaultModel:
    """Exponential GPU failure/recovery process in slot-time units.

    Each GPU alternates up/down phases: up-phase lengths are drawn from
    ``Exp(mtbf)`` and down-phases from ``Exp(mttr)``, with per-
    :class:`DeviceModel` overrides keyed by model name.  The descriptor is
    frozen/hashable so it can ride in jit-static configuration, and all
    draws happen at presample time *after* the arrival/tenant draws — a
    disabled fault model therefore leaves every existing event stream
    byte-identical.

    ``max_retries``/``backoff_base`` govern what happens to evicted (and
    patience-overdue) workloads: attempt ``k`` waits ``backoff_base *
    2**(k-1)`` slots before becoming eligible again, and a workload is
    finally rejected only after ``max_retries`` re-queues (or when its
    lease expires in the queue).
    """

    mtbf: float = 500.0
    mttr: float = 20.0
    per_model: Tuple[Tuple[str, Tuple[float, float]], ...] = ()
    max_retries: int = 2
    backoff_base: int = 2

    def __post_init__(self):
        for label, mtbf, mttr in (("", self.mtbf, self.mttr),) + tuple(
            (f" for model {name!r}", pair[0], pair[1]) for name, pair in self.per_model
        ):
            if not (math.isfinite(mtbf) and mtbf > 0):
                raise ValueError(
                    f"FaultModel MTBF{label} must be a positive finite number "
                    f"of slots, got {mtbf!r}"
                )
            if not (math.isfinite(mttr) and mttr > 0):
                raise ValueError(
                    f"FaultModel MTTR{label} must be a positive finite number "
                    f"of slots, got {mttr!r}"
                )
        if self.max_retries < 0:
            raise ValueError(
                f"FaultModel max_retries must be >= 0, got {self.max_retries}"
            )
        if self.backoff_base < 1:
            raise ValueError(
                f"FaultModel backoff_base must be >= 1, got {self.backoff_base}"
            )

    def rates_for(self, model_name: str) -> Tuple[float, float]:
        """(mtbf, mttr) for a device model, honouring per-model overrides."""
        for name, pair in self.per_model:
            if name == model_name:
                return (float(pair[0]), float(pair[1]))
        return (self.mtbf, self.mttr)

    def backoff(self, attempt: int) -> int:
        """Slots to wait before re-queue attempt ``attempt`` (1-based)."""
        return self.backoff_base * 2 ** max(0, attempt - 1)


# ---------------------------------------------------------------------------
# Flattened A100-80GB placement table (module-level aliases, back-compat).
# ---------------------------------------------------------------------------

PLACEMENT_PROFILE_ID = A100_80GB.placement_profile_id
PLACEMENT_ANCHOR = A100_80GB.placement_anchor
PLACEMENT_MASKS = A100_80GB.placement_masks
NUM_PLACEMENTS = A100_80GB.num_placements  # 18 for the A100 table
PLACEMENT_MEM = A100_80GB.placement_mem
PROFILE_MEM = A100_80GB.profile_mem
PROFILE_COMPUTE = A100_80GB.profile_compute


def profile_placement_rows(pid: int) -> slice:
    """Rows of the A100-80GB placement table belonging to profile ``pid``."""
    return A100_80GB.profile_placement_rows(pid)


# ---------------------------------------------------------------------------
# GPU state
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class Allocation:
    """A committed placement of a workload on a GPU."""

    workload_id: int
    profile_id: int
    anchor: int


class GPUState:
    """Occupancy state of one MIG-capable GPU of a given device model."""

    def __init__(self, gpu_id: int = 0, model: DeviceModel = A100_80GB):
        self.gpu_id = gpu_id
        self.model = model
        self.up = True  # a down GPU accepts no placements until recovered
        self.occupancy = np.zeros(model.num_mem_slices, dtype=np.int32)
        self.allocations: Dict[int, Allocation] = {}

    # -- queries ------------------------------------------------------------
    @property
    def free_slices(self) -> int:
        return int(self.model.num_mem_slices - self.occupancy.sum())

    @property
    def used_mem_slices(self) -> int:
        return int(self.occupancy.sum())

    @property
    def used_compute_slices(self) -> int:
        return int(
            sum(
                self.model.profiles[a.profile_id].compute
                for a in self.allocations.values()
            )
        )

    @property
    def is_active(self) -> bool:
        return bool(self.allocations)

    def feasible_anchors(self, profile_id: int) -> List[int]:
        """Anchors where ``profile_id`` can be placed right now."""
        if not self.up:
            return []  # single choke point: down GPUs are infeasible everywhere
        prof = self.model.profiles[profile_id]
        out = []
        for anchor in prof.anchors:
            if not self.occupancy[anchor : anchor + prof.mem].any():
                out.append(anchor)
        return out

    def can_fit(self, profile_id: int) -> bool:
        return bool(self.feasible_anchors(profile_id))

    # -- mutation -----------------------------------------------------------
    def allocate(self, workload_id: int, profile_id: int, anchor: int) -> None:
        prof = self.model.profiles[profile_id]
        window = self.occupancy[anchor : anchor + prof.mem]
        if anchor not in prof.anchors:
            raise ValueError(
                f"anchor {anchor} illegal for profile {prof.name} "
                f"on {self.model.name} (legal: {prof.anchors})"
            )
        if window.any():
            raise ValueError(
                f"profile {prof.name}@{anchor} overlaps occupied slices on "
                f"GPU {self.gpu_id}"
            )
        window[:] = 1
        self.allocations[workload_id] = Allocation(workload_id, profile_id, anchor)

    def release(self, workload_id: int) -> None:
        alloc = self.allocations.pop(workload_id)
        prof = self.model.profiles[alloc.profile_id]
        self.occupancy[alloc.anchor : alloc.anchor + prof.mem] = 0


class ClusterState:
    """A MIG GPU cluster — homogeneous by default, mixed via ``spec``."""

    def __init__(self, num_gpus: Optional[int] = None, spec: Optional[ClusterSpec] = None):
        if spec is None:
            if num_gpus is None:
                raise ValueError("need num_gpus or spec")
            spec = ClusterSpec.homogeneous(A100_80GB, num_gpus)
        elif num_gpus is not None and num_gpus != spec.num_gpus:
            raise ValueError(
                f"num_gpus={num_gpus} contradicts spec ({spec.num_gpus} GPUs)"
            )
        self.spec = spec
        self.gpus = [
            GPUState(i, spec.model_of(i)) for i in range(spec.num_gpus)
        ]
        self._placement_of: Dict[int, int] = {}  # workload_id -> gpu_id

    def __len__(self) -> int:
        return len(self.gpus)

    @property
    def num_gpus(self) -> int:
        return len(self.gpus)

    def occupancy_matrix(self) -> np.ndarray:
        """(M, S) int32 occupancy bitmap, S = ``spec.num_mem_slices``.

        GPUs of models with fewer slices are zero-padded on the right (their
        extra columns can never be occupied).
        """
        s = self.spec.num_mem_slices
        out = np.zeros((self.num_gpus, s), dtype=np.int32)
        for i, g in enumerate(self.gpus):
            out[i, : g.occupancy.shape[0]] = g.occupancy
        return out

    def allocate(self, workload_id: int, profile_id: int, gpu_id: int, anchor: int):
        if workload_id in self._placement_of:
            raise ValueError(
                f"workload {workload_id} is already placed on GPU "
                f"{self._placement_of[workload_id]}; release it before "
                "re-allocating (a duplicate allocate would orphan its slices)"
            )
        self.gpus[gpu_id].allocate(workload_id, profile_id, anchor)
        self._placement_of[workload_id] = gpu_id

    def release(self, workload_id: int) -> None:
        if workload_id not in self._placement_of:
            raise KeyError(
                f"workload {workload_id} is not placed on this cluster"
            )
        gpu_id = self._placement_of.pop(workload_id)
        self.gpus[gpu_id].release(workload_id)

    def migrate(self, workload_id: int, gpu_id: int, anchor: int) -> Tuple[int, int, int]:
        """Move a running workload to a new placement (same class, same id).

        The single primitive behind every defrag ``pending_migration``
        apply (simulator protocols, serving admission, host replay).
        Returns the old ``(gpu, anchor, profile_id)``; raises like
        :meth:`allocate` if the target is illegal or occupied.
        """
        old_gpu = self._placement_of[workload_id]
        alloc = self.gpus[old_gpu].allocations[workload_id]
        old = (old_gpu, alloc.anchor, alloc.profile_id)
        self.release(workload_id)
        self.allocate(workload_id, alloc.profile_id, gpu_id, anchor)
        return old

    def gpu_of(self, workload_id: int) -> Optional[int]:
        return self._placement_of.get(workload_id)

    # -- faults -------------------------------------------------------------
    def up_mask(self) -> np.ndarray:
        """(M,) bool — True for GPUs currently accepting placements."""
        return np.array([g.up for g in self.gpus], dtype=bool)

    def fail_gpu(self, gpu_id: int) -> List[int]:
        """Take a GPU down, evicting every live allocation on it.

        Returns the evicted workload ids (insertion order).  The slices are
        released, so a down GPU reads as empty in every occupancy metric;
        :meth:`GPUState.feasible_anchors` keeps it out of placement until
        :meth:`recover_gpu`.
        """
        gpu = self.gpus[gpu_id]
        if not gpu.up:
            raise ValueError(f"GPU {gpu_id} is already down")
        evicted = list(gpu.allocations)
        for wid in evicted:
            self.release(wid)
        gpu.up = False
        return evicted

    def recover_gpu(self, gpu_id: int) -> None:
        """Bring a failed GPU back into the placement tables (empty)."""
        gpu = self.gpus[gpu_id]
        if gpu.up:
            raise ValueError(f"GPU {gpu_id} is already up")
        gpu.up = True

    # -- metrics ------------------------------------------------------------
    @property
    def active_gpus(self) -> int:
        return sum(g.is_active for g in self.gpus)

    @property
    def used_mem_slices(self) -> int:
        return sum(g.used_mem_slices for g in self.gpus)

    @property
    def used_compute_slices(self) -> int:
        return sum(g.used_compute_slices for g in self.gpus)

    @property
    def total_mem_slices(self) -> int:
        return self.spec.total_mem_slices
