"""Host-engine policy compiler: `PolicySpec` -> `Scheduler`.

The policies themselves (MFI — paper Algorithm 2 — and the four baselines)
are *declared* once in :mod:`repro.core.policy` as lexicographic
:class:`~repro.core.policy.PolicySpec` key lists; this module interprets a
spec against a :class:`repro.core.mig.ClusterState`.  The batched engine
(:mod:`repro.sim.batched`) lowers the same specs to vectorized selection
inside its scan step, so the two engines cannot drift by construction.

All schedulers implement ``select(cluster, profile_id) -> (gpu_id, anchor)``
or ``None`` (reject).  They never mutate the cluster; the caller commits.

Anchor-selection policies (paper §VI) map onto the key vocabulary:
  * MIG-agnostic (FF, RR): "first available index" — the ascending
    ``anchor`` key.
  * MIG-aware "Best Index" (BF-BI, WF-BI), after [Turkkan et al. 2024]:
    prefer indexes that do not restrict profiles with fewer placement
    options — e.g. 1g.10gb goes to index 6 rather than 0, reserving the
    {0..3} window for 4g.40gb.  This is the descending ``-anchor`` key,
    which reproduces the paper's example preference.
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import numpy as np

from repro.core import fragmentation, mig
from repro.core.policy import (
    REQUEST_KEYS,
    PolicyLike,
    PolicySpec,
    key_base,
    resolve,
)

Placement = Tuple[int, int]  # (gpu_id, anchor)


class Scheduler:
    """Base class. Subclasses implement ``select``."""

    name: str = "base"

    def __init__(self, metric: str = "blocked"):
        self.metric = metric

    def select(self, cluster: mig.ClusterState, profile_id: int) -> Optional[Placement]:
        raise NotImplementedError

    def reset(self) -> None:  # for stateful schedulers (RR)
        pass


class SpecScheduler(Scheduler):
    """Interprets a :class:`PolicySpec` on the host cluster state.

    Candidates are every feasible ``(gpu, anchor)`` dry-run of the request
    (the spec's feasibility filter); the winner minimizes the spec's key
    tuple lexicographically, with ascending ``(gpu, anchor)`` as the
    implicit final tie-break — exactly the order the batched lowering's
    first-flat-index argmin produces.
    """

    def __init__(self, spec: PolicySpec, metric: str = "blocked"):
        super().__init__(metric)
        self.spec = spec
        self.name = spec.name
        self._next = 0  # rotation cursor (used by the "rr-distance" key)

    def reset(self) -> None:
        self._next = 0

    # -- candidate enumeration ----------------------------------------------
    def _candidates(self, cluster: mig.ClusterState, profile_id: int):
        """Feasible dry-runs as ``(gpu_ids, anchors, deltas)`` arrays.

        ΔF is computed only when the spec's keys ask for it; the loop is
        vectorized per model group exactly like the Pallas-kernel oracle
        (:func:`mfi_candidates`).
        """
        if self.spec.requires_delta_f:
            occ = cluster.occupancy_matrix()
            gpu_ids, anchors, deltas = [], [], []
            for model, rows in cluster.spec.model_groups():
                # down GPUs look empty in the occupancy matrix (their slices
                # were released on failure), so they must be masked out here
                # — the other enumeration paths go through feasible_anchors
                rows = rows[[cluster.gpus[g].up for g in rows]]
                if not len(rows):
                    continue
                g, a, d = mfi_candidates(
                    occ[rows][:, : model.num_mem_slices],
                    profile_id,
                    self.metric,
                    model,
                )
                gpu_ids.append(rows[g])  # local -> global GPU ids
                anchors.append(a)
                deltas.append(d)
            if gpu_ids:
                gpu_ids = np.concatenate(gpu_ids)
                anchors = np.concatenate(anchors)
                deltas = np.concatenate(deltas)
            else:
                gpu_ids = np.empty(0, dtype=np.int64)
                anchors = np.empty(0, dtype=np.int64)
                deltas = np.empty(0)
        else:
            pairs = [
                (g.gpu_id, a)
                for g in cluster.gpus
                for a in g.feasible_anchors(profile_id)
            ]
            gpu_ids = np.array([p[0] for p in pairs], dtype=np.int64)
            anchors = np.array([p[1] for p in pairs], dtype=np.int64)
            deltas = np.zeros(len(pairs))
        return gpu_ids, anchors, deltas

    def _key_column(self, key, cluster, profile_id, gpus, anchors, deltas):
        base = key_base(key)
        if base == "frag-delta":
            col = deltas
        elif base == "free-slices":
            col = np.array(
                [
                    cluster.gpus[g].free_slices
                    - cluster.gpus[g].model.profiles[profile_id].mem
                    for g in gpus
                ],
                dtype=np.float64,
            )
        elif base == "gpu":
            col = gpus.astype(np.float64)
        elif base == "anchor":
            col = anchors.astype(np.float64)
        elif base == "rr-distance":
            col = ((gpus - self._next) % cluster.num_gpus).astype(np.float64)
        elif base == "model-group":
            col = cluster.spec.model_index[gpus].astype(np.float64)
        elif base in REQUEST_KEYS:
            # request-scoped keys (tenant / priority / wait-age) are
            # constant over the candidates of one request — a zero column
            # never changes the lexsort outcome.  Their semantics live in
            # the cross-request queue order (policy.queue_order).
            col = np.zeros(len(gpus), dtype=np.float64)
        else:  # unreachable: PolicySpec validates the vocabulary
            raise ValueError(f"unknown scoring key {key!r}")
        return -col if key.startswith("-") else col

    def _pick(self, cluster, profile_id, gpus, anchors, deltas) -> Placement:
        cols = [
            self._key_column(k, cluster, profile_id, gpus, anchors, deltas)
            for k in self.spec.keys
        ]
        # np.lexsort: last key is primary; (gpu, anchor) is the implicit
        # least-significant tie-break shared with the batched lowering
        k = int(np.lexsort((anchors, gpus) + tuple(reversed(cols)))[0])
        return (int(gpus[k]), int(anchors[k]))

    def select(self, cluster, profile_id):
        spec = self.spec
        sel: Optional[Placement] = None
        if not spec.requires_delta_f and key_base(spec.keys[0]) in ("gpu", "rr-distance"):
            # gpu-major primary key: the winner lives on the first GPU (in
            # scan order) with any feasible anchor — short-circuit like the
            # classic First-Fit / Round-Robin loops did
            m = cluster.num_gpus
            start = self._next if key_base(spec.keys[0]) == "rr-distance" else 0
            order = range(m) if not spec.keys[0].startswith("-") else range(m - 1, -1, -1)
            for i in order:
                g = (start + i) % m
                feas = cluster.gpus[g].feasible_anchors(profile_id)
                if feas:
                    gp = np.full(len(feas), g, dtype=np.int64)
                    an = np.asarray(feas, dtype=np.int64)
                    sel = self._pick(cluster, profile_id, gp, an, np.zeros(len(feas)))
                    break
        else:
            gpus, anchors, deltas = self._candidates(cluster, profile_id)
            if len(gpus):
                sel = self._pick(cluster, profile_id, gpus, anchors, deltas)
        if sel is not None and spec.stateful_cursor:
            self._next = (sel[0] + 1) % cluster.num_gpus
        return sel


def mfi_candidates(
    occupancy: np.ndarray,
    profile_id: int,
    metric: str = "blocked",
    model: Optional[mig.DeviceModel] = None,
) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Vectorized MFI inner loop (numpy reference for the Pallas kernel).

    Returns (gpu_ids, anchors, delta_f) arrays over all *feasible* dry-run
    placements of ``profile_id`` across same-model GPUs (default A100-80GB;
    mixed clusters call this once per model group).
    """
    if model is None:
        model = mig.A100_80GB
    occ = np.asarray(occupancy, dtype=np.int32)
    m = occ.shape[0]
    rows = model.profile_placement_rows(profile_id)
    masks = model.placement_masks[rows]  # (A, S)
    anchors = model.placement_anchor[rows]  # (A,)
    a = masks.shape[0]

    # feasibility: window fully free (classes with no realization have A=0)
    overlap = occ @ masks.T  # (M, A)
    feasible = overlap == 0

    if not feasible.any():
        empty = np.empty(0, dtype=np.int64)
        return empty, empty, np.empty(0)

    f_before = fragmentation.fragmentation_scores(occ, metric, model)  # (M,)
    # hypothetical occupancy for every (gpu, anchor): (M, A, S)
    hypo = np.minimum(occ[:, None, :] + masks[None, :, :], 1)
    f_after = fragmentation.fragmentation_scores(
        hypo.reshape(m * a, model.num_mem_slices), metric, model
    ).reshape(m, a)
    delta = f_after - f_before[:, None]

    gpu_idx, anchor_idx = np.nonzero(feasible)
    return gpu_idx, anchors[anchor_idx], delta[gpu_idx, anchor_idx]


class MFIDefrag(SpecScheduler):
    """BEYOND-PAPER extension: MFI + opportunistic single-migration defrag.

    The paper excludes rescheduling ("we are going to consider rescheduling
    in a future work").  This variant keeps the no-disruption spirit almost
    intact: only when a request would be REJECTED does it search for ONE
    running workload whose migration (to a spec-chosen new placement) makes
    the request feasible, choosing the migration that minimises the final
    cluster fragmentation sum.  The caller performs the migration via the
    ``pending_migration`` attribute ((workload_id, gpu, anchor) or None).

    The search is **canonical**: victims are enumerated in ascending
    ``(gpu, anchor)`` order and the first strict minimum of the total-F
    objective wins, i.e. the chosen migration is the lexicographic minimum
    of ``(total F after, victim gpu, victim anchor)``.  The batched
    engine's migrate stage (:mod:`repro.sim.batched`) computes exactly this
    total order with masked tensor ops.  The search is unbounded by
    default — matching the batched engine, which is always exhaustive (it
    is vectorized, a budget would save no work) — so the two engines
    express the same policy at any scale; pass ``max_candidates`` to cap
    host-side work on large clusters at the cost of that parity.
    """

    def __init__(
        self,
        metric: str = "blocked",
        max_candidates: Optional[int] = None,
        spec: Optional[PolicySpec] = None,
    ):
        super().__init__(spec if spec is not None else resolve("mfi-defrag"), metric)
        self.max_candidates = max_candidates
        self.pending_migration = None
        self.migrations = 0

    def select(self, cluster, profile_id):
        self.pending_migration = None
        sel = super().select(cluster, profile_id)
        if sel is not None:
            return sel

        # rejected: try single-workload migration
        budget = (
            self.max_candidates
            if self.max_candidates is not None
            else float("inf")
        )
        best = None  # (total_F, victim_id, victim_new, request_placement)
        tried = 0
        for gpu in cluster.gpus:
            if tried >= budget:
                break  # candidate budget caps TOTAL work, not per-GPU work
            # canonical victim order: ascending anchor within the GPU scan
            # (the migration objective's tie-break — see class docstring)
            victims = sorted(
                gpu.allocations.items(), key=lambda kv: kv[1].anchor
            )
            for wid, alloc in victims:
                if tried >= budget:
                    break
                tried += 1
                prof = gpu.model.profiles[alloc.profile_id]
                # hypothetically remove the victim
                gpu.occupancy[alloc.anchor : alloc.anchor + prof.mem] = 0
                req_sel = super().select(cluster, profile_id)
                if req_sel is not None:
                    rg, ra = req_sel
                    rp = cluster.gpus[rg].model.profiles[profile_id]
                    cluster.gpus[rg].occupancy[ra : ra + rp.mem] = 1
                    new_sel = super().select(cluster, alloc.profile_id)
                    if new_sel is not None:
                        ng, na = new_sel
                        nprof = cluster.gpus[ng].model.profiles[alloc.profile_id]
                        occ = cluster.occupancy_matrix().copy()
                        occ[ng, na : na + nprof.mem] = 1
                        total = fragmentation.spec_fragmentation_scores(
                            occ, cluster.spec, self.metric
                        ).sum()
                        cand = (total, wid, (ng, na), req_sel)
                        if best is None or cand[0] < best[0]:
                            best = cand
                    cluster.gpus[rg].occupancy[ra : ra + rp.mem] = 0
                # restore victim
                gpu.occupancy[alloc.anchor : alloc.anchor + prof.mem] = 1
        if best is None:
            return None
        _, wid, new_place, req_sel = best
        self.pending_migration = (wid, *new_place)
        self.migrations += 1
        return req_sel


def compile_policy(spec: PolicySpec, metric: str = "blocked") -> Scheduler:
    """Host-engine compiler: spec -> ready-to-run ``Scheduler``.

    Registry-compiled defrag schedulers run the UNBOUNDED canonical search
    so both engines express the same policy at any scale (the batched
    migrate stage is always exhaustive); construct
    ``MFIDefrag(max_candidates=...)`` directly to opt into the work cap.
    """
    if spec.defrag:
        return MFIDefrag(metric=metric, spec=spec, max_candidates=None)
    return SpecScheduler(spec, metric=metric)


def make_scheduler(policy: PolicyLike, metric: str = "blocked") -> Scheduler:
    """Compile a registered policy name (or an ad-hoc spec) for the host
    engine.  Unknown names raise through the registry's single validation
    path (:func:`repro.core.policy.resolve`)."""
    return compile_policy(resolve(policy, engine="python"), metric=metric)


# ---------------------------------------------------------------------------
# Backward-compatible class aliases — thin spec bindings, no select loops.
# ---------------------------------------------------------------------------


def _spec_alias(policy_name: str, doc: str) -> type:
    class _Alias(SpecScheduler):
        name = policy_name

        def __init__(self, metric: str = "blocked"):
            super().__init__(resolve(policy_name), metric)

    _Alias.__name__ = _Alias.__qualname__ = policy_name.replace("-", "_").upper()
    _Alias.__doc__ = doc
    return _Alias


MFI = _spec_alias("mfi", "Minimum Fragmentation Increment (paper Algorithm 2).")
FirstFit = _spec_alias("ff", "MIG-agnostic: first GPU with room, first index.")
RoundRobin = _spec_alias("rr", "MIG-agnostic: rotate over GPUs, first index.")
BestFitBestIndex = _spec_alias(
    "bf-bi", "MIG-aware bin packing: minimize post-allocation free slices."
)
WorstFitBestIndex = _spec_alias(
    "wf-bi", "MIG-aware load balancing: maximize post-allocation free slices."
)

#: registered host-engine policies (name -> compiling callable); kept for
#: backward compatibility — `repro.core.policy.list_policies()` is the API.
SCHEDULERS: Dict[str, type] = {
    "ff": FirstFit,
    "rr": RoundRobin,
    "bf-bi": BestFitBestIndex,
    "wf-bi": WorstFitBestIndex,
    "mfi": MFI,
    "mfi-defrag": MFIDefrag,
}
