"""Scheduling policies: MFI (Algorithm 2) and the paper's four baselines.

All schedulers implement ``select(cluster, profile_id) -> (gpu_id, anchor)``
or ``None`` (reject).  They never mutate the cluster; the caller commits.

Anchor-selection policies (paper §VI):
  * MIG-agnostic (FF, RR): "first available index" — ascending anchors.
  * MIG-aware "Best Index" (BF-BI, WF-BI), after [Turkkan et al. 2024]:
    prefer indexes that do not restrict profiles with fewer placement
    options — e.g. 1g.10gb goes to index 6 rather than 0, reserving the
    {0..3} window for 4g.40gb.  Implemented as descending anchor order,
    which reproduces the paper's example preference.
"""

from __future__ import annotations

from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core import fragmentation, mig

Placement = Tuple[int, int]  # (gpu_id, anchor)


class Scheduler:
    """Base class. Subclasses implement ``select``."""

    name: str = "base"

    def __init__(self, metric: str = "blocked"):
        self.metric = metric

    def select(self, cluster: mig.ClusterState, profile_id: int) -> Optional[Placement]:
        raise NotImplementedError

    def reset(self) -> None:  # for stateful schedulers (RR)
        pass


def _first_anchor(gpu: mig.GPUState, profile_id: int, best_index: bool) -> Optional[int]:
    anchors = gpu.feasible_anchors(profile_id)
    if not anchors:
        return None
    return max(anchors) if best_index else min(anchors)


class FirstFit(Scheduler):
    """MIG-agnostic: first GPU with enough resources, first available index."""

    name = "ff"

    def select(self, cluster, profile_id):
        for gpu in cluster.gpus:
            anchor = _first_anchor(gpu, profile_id, best_index=False)
            if anchor is not None:
                return (gpu.gpu_id, anchor)
        return None


class RoundRobin(Scheduler):
    """MIG-agnostic: sequentially distribute over GPUs, first available index."""

    name = "rr"

    def __init__(self, metric: str = "blocked"):
        super().__init__(metric)
        self._next = 0

    def reset(self):
        self._next = 0

    def select(self, cluster, profile_id):
        n = cluster.num_gpus
        for k in range(n):
            gpu = cluster.gpus[(self._next + k) % n]
            anchor = _first_anchor(gpu, profile_id, best_index=False)
            if anchor is not None:
                self._next = (gpu.gpu_id + 1) % n
                return (gpu.gpu_id, anchor)
        return None


class BestFitBestIndex(Scheduler):
    """MIG-aware bin packing: GPU minimizing post-allocation free slices."""

    name = "bf-bi"

    def select(self, cluster, profile_id):
        best: Optional[Tuple[int, int, int]] = None  # (free_after, gpu_id, anchor)
        for gpu in cluster.gpus:
            anchor = _first_anchor(gpu, profile_id, best_index=True)
            if anchor is None:
                continue
            mem = gpu.model.profiles[profile_id].mem
            key = (gpu.free_slices - mem, gpu.gpu_id)
            if best is None or key < best[:2]:
                best = (key[0], key[1], anchor)
        return None if best is None else (best[1], best[2])


class WorstFitBestIndex(Scheduler):
    """MIG-aware load balancing: GPU maximizing post-allocation free slices."""

    name = "wf-bi"

    def select(self, cluster, profile_id):
        best: Optional[Tuple[int, int, int]] = None  # (-free_after, gpu_id, anchor)
        for gpu in cluster.gpus:
            anchor = _first_anchor(gpu, profile_id, best_index=True)
            if anchor is None:
                continue
            mem = gpu.model.profiles[profile_id].mem
            key = (-(gpu.free_slices - mem), gpu.gpu_id)
            if best is None or key < best[:2]:
                best = (key[0], key[1], anchor)
        return None if best is None else (best[1], best[2])


class MFI(Scheduler):
    """Minimum Fragmentation Increment (paper Algorithm 2).

    Greedy: dry-run the requested profile at every feasible (GPU, anchor)
    and commit the placement with the minimum fragmentation-score increment
    ΔF = F⁽ⁱ⁾(m) − F(m).  Ties broken by (gpu_id, anchor) for determinism.
    """

    name = "mfi"

    def select(self, cluster, profile_id):
        occ = cluster.occupancy_matrix()  # (M, S)
        gpu_ids, anchors, deltas = [], [], []
        for model, rows in cluster.spec.model_groups():
            g, a, d = mfi_candidates(
                occ[rows][:, : model.num_mem_slices], profile_id, self.metric, model
            )
            gpu_ids.append(rows[g])  # local -> global GPU ids
            anchors.append(a)
            deltas.append(d)
        gpu_ids = np.concatenate(gpu_ids)
        if len(gpu_ids) == 0:
            return None
        anchors = np.concatenate(anchors)
        deltas = np.concatenate(deltas)
        k = int(np.lexsort((anchors, gpu_ids, deltas))[0])
        return (int(gpu_ids[k]), int(anchors[k]))


def mfi_candidates(
    occupancy: np.ndarray,
    profile_id: int,
    metric: str = "blocked",
    model: Optional[mig.DeviceModel] = None,
) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Vectorized MFI inner loop (numpy reference for the Pallas kernel).

    Returns (gpu_ids, anchors, delta_f) arrays over all *feasible* dry-run
    placements of ``profile_id`` across same-model GPUs (default A100-80GB;
    mixed clusters call this once per model group).
    """
    if model is None:
        model = mig.A100_80GB
    occ = np.asarray(occupancy, dtype=np.int32)
    m = occ.shape[0]
    rows = model.profile_placement_rows(profile_id)
    masks = model.placement_masks[rows]  # (A, S)
    anchors = model.placement_anchor[rows]  # (A,)
    a = masks.shape[0]

    # feasibility: window fully free (classes with no realization have A=0)
    overlap = occ @ masks.T  # (M, A)
    feasible = overlap == 0

    if not feasible.any():
        empty = np.empty(0, dtype=np.int64)
        return empty, empty, np.empty(0)

    f_before = fragmentation.fragmentation_scores(occ, metric, model)  # (M,)
    # hypothetical occupancy for every (gpu, anchor): (M, A, S)
    hypo = np.minimum(occ[:, None, :] + masks[None, :, :], 1)
    f_after = fragmentation.fragmentation_scores(
        hypo.reshape(m * a, model.num_mem_slices), metric, model
    ).reshape(m, a)
    delta = f_after - f_before[:, None]

    gpu_idx, anchor_idx = np.nonzero(feasible)
    return gpu_idx, anchors[anchor_idx], delta[gpu_idx, anchor_idx]


class MFIDefrag(MFI):
    """BEYOND-PAPER extension: MFI + opportunistic single-migration defrag.

    The paper excludes rescheduling ("we are going to consider rescheduling
    in a future work").  This variant keeps the no-disruption spirit almost
    intact: only when a request would be REJECTED does it search for ONE
    running workload whose migration (to an MFI-chosen new placement) makes
    the request feasible, choosing the migration that minimises the final
    cluster fragmentation sum.  The caller performs the migration via the
    ``pending_migration`` attribute ((workload_id, gpu, anchor) or None).
    """

    name = "mfi-defrag"

    def __init__(self, metric: str = "blocked", max_candidates: int = 64):
        super().__init__(metric)
        self.max_candidates = max_candidates
        self.pending_migration = None
        self.migrations = 0

    def select(self, cluster, profile_id):
        self.pending_migration = None
        sel = super().select(cluster, profile_id)
        if sel is not None:
            return sel

        # rejected: try single-workload migration
        best = None  # (total_F, victim_id, victim_new, request_placement)
        tried = 0
        for gpu in cluster.gpus:
            if tried >= self.max_candidates:
                break  # candidate budget caps TOTAL work, not per-GPU work
            for wid, alloc in list(gpu.allocations.items()):
                if tried >= self.max_candidates:
                    break
                tried += 1
                prof = gpu.model.profiles[alloc.profile_id]
                # hypothetically remove the victim
                gpu.occupancy[alloc.anchor : alloc.anchor + prof.mem] = 0
                req_sel = super().select(cluster, profile_id)
                if req_sel is not None:
                    rg, ra = req_sel
                    rp = cluster.gpus[rg].model.profiles[profile_id]
                    cluster.gpus[rg].occupancy[ra : ra + rp.mem] = 1
                    new_sel = super().select(cluster, alloc.profile_id)
                    if new_sel is not None:
                        ng, na = new_sel
                        nprof = cluster.gpus[ng].model.profiles[alloc.profile_id]
                        occ = cluster.occupancy_matrix().copy()
                        occ[ng, na : na + nprof.mem] = 1
                        total = fragmentation.spec_fragmentation_scores(
                            occ, cluster.spec, self.metric
                        ).sum()
                        cand = (total, wid, (ng, na), req_sel)
                        if best is None or cand[0] < best[0]:
                            best = cand
                    cluster.gpus[rg].occupancy[ra : ra + rp.mem] = 0
                # restore victim
                gpu.occupancy[alloc.anchor : alloc.anchor + prof.mem] = 1
        if best is None:
            return None
        _, wid, new_place, req_sel = best
        self.pending_migration = (wid, *new_place)
        self.migrations += 1
        return req_sel


SCHEDULERS: Dict[str, type] = {
    "ff": FirstFit,
    "rr": RoundRobin,
    "bf-bi": BestFitBestIndex,
    "wf-bi": WorstFitBestIndex,
    "mfi": MFI,
    "mfi-defrag": MFIDefrag,
}


def make_scheduler(name: str, metric: str = "blocked") -> Scheduler:
    try:
        cls = SCHEDULERS[name]
    except KeyError:
        raise ValueError(f"unknown scheduler {name!r}; options: {sorted(SCHEDULERS)}")
    return cls(metric=metric)
