"""Declarative policy layer: one :class:`PolicySpec`, two engine compilers.

Every scheduling policy in this repo used to exist twice — as a Python
``Scheduler`` subclass in :mod:`repro.core.schedulers` and again as a
hand-vectorized selector in :mod:`repro.sim.batched` — with parity
maintained by hand.  This module replaces both with a single *frozen,
registrable description* of a policy:

* a **feasibility filter** (today always "window-free": an anchor is a
  candidate iff its placement window is fully free and the demand class has
  a realization on the GPU's device model);
* an optional **ΔF requirement** (``"frag-delta"`` among the keys — the
  fragmentation-increment table of paper Algorithm 2 is computed only for
  policies that ask for it);
* an ordered list of **lexicographic scoring keys** drawn from a small
  vocabulary (:data:`KEY_VOCABULARY`), each optionally prefixed with ``-``
  to flip the tie-break direction.  The candidate minimizing the key tuple
  wins; any remaining tie is broken by ascending ``(gpu, anchor)``.

Both engines *compile* the same spec:

* the host engine (:func:`repro.core.schedulers.compile_policy`) interprets
  it into a ``Scheduler`` operating on a ``ClusterState``;
* the batched engine (:mod:`repro.sim.batched`) lowers it to a vectorized
  masked-refinement argmin inside the ``lax.scan`` event step.

Because both consume the identical description, the two implementations
cannot drift by construction — a newly registered policy is immediately
available to ``make_scheduler`` / ``run_many`` / ``run_batched`` /
``simulate`` and inherits the cross-engine parity test coverage for free
(``tests/test_policy_api.py``).

Key vocabulary
    ==============  =========================================================
    ``frag-delta``  ΔF of the dry-run placement (fragmentation increment,
                    paper Alg. 2); requests the ΔF table from the engine
    ``free-slices`` post-allocation free memory slices of the GPU
                    (ascending = best-fit packing, ``-free-slices`` =
                    worst-fit load balancing); per-model slice demand on
                    mixed fleets
    ``gpu``         GPU index (ascending = first-fit scan order)
    ``anchor``      placement-anchor index (ascending = first available
                    index; ``-anchor`` = the MIG-aware "Best Index" rule)
    ``rr-distance`` rotation distance ``(gpu - cursor) mod M`` from the
                    round-robin cursor; marks the policy *stateful* (the
                    cursor advances past each accepted GPU)
    ``model-group`` index of the GPU's device model in the spec's model
                    list (mixed fleets: steer demand across generations)
    ``tenant``      request-scoped: id of the submitting tenant (constant
                    across candidates — orders competing *requests*, not
                    placements; see :data:`REQUEST_KEYS`)
    ``priority``    request-scoped: the request's declared priority class
                    (ascending: 0 admits first)
    ``wait-age``    request-scoped: slots the request has waited since
                    arrival (``-wait-age`` = oldest first)
    ==============  =========================================================

The six shipped policies (``mfi``, ``ff``, ``bf-bi``, ``wf-bi``, ``rr``,
``mfi-defrag``) are registered here as specs; ``mfi-defrag`` additionally
sets ``defrag=True`` (an opportunistic single-migration search on reject).
Both engines implement the migration step — the host scheduler as a
candidate search (:class:`repro.core.schedulers.MFIDefrag`), the batched
engine as a masked migrate stage compiled into its scan body
(:mod:`repro.sim.batched`) — so a defrag spec runs everywhere.  A spec may
still *opt out* of an engine via the ``engines`` field; :func:`resolve`
remains the single validation path both engines raise through.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple, Union

#: engines a policy may be compiled to
ENGINES: Tuple[str, ...] = ("python", "batched")

#: legal scoring-key bases (each may be prefixed with ``-`` to flip order)
KEY_VOCABULARY: Tuple[str, ...] = (
    "frag-delta",
    "free-slices",
    "gpu",
    "anchor",
    "rr-distance",
    "model-group",
    "tenant",
    "priority",
    "wait-age",
)

#: request-scoped scoring keys: their value is a property of the REQUEST
#: being placed (the submitting tenant, its declared priority, how long the
#: request has waited), not of the candidate ``(gpu, anchor)``.  Within one
#: request's placement argmin they are constant across every candidate, so
#: both engines compile them to constant columns — adding them to a spec
#: never changes which placement wins.  Their effect is *cross-request*:
#: wherever several requests compete for the next admission slot (the
#: serving front-end's wait queue, the batched engine's wait ring under the
#: ``steady-queued`` protocol), the request-scoped keys of the spec order
#: the competitors (see :func:`queue_order`).
REQUEST_KEYS: Tuple[str, ...] = ("tenant", "priority", "wait-age")

#: queue ordering used when a spec names no request-scoped keys: lowest
#: priority value first (0 = most urgent), then oldest wait first
#: (descending wait-age), then arrival order.
DEFAULT_QUEUE_ORDER: Tuple[str, ...] = ("priority", "-wait-age")

#: feasibility filters (currently the single built-in rule)
FEASIBILITY_FILTERS: Tuple[str, ...] = ("window-free",)

#: legal ``PolicySpec.kernel_lowering`` declarations (see the field docs):
#: ``True`` = everything available, ``"fused"`` = require the fused
#: argmin kernels, ``"delta"`` = ΔF table only, ``False`` = no kernels.
KERNEL_LOWERINGS: Tuple[object, ...] = (True, False, "delta", "fused")

#: key bases the fused select/migrate Pallas kernels can pack into their
#: in-kernel lexicographic encoding.  ``rr-distance`` (stateful cursor) and
#: ``model-group`` stay jnp-only; request-scoped keys are constant within
#: one request's candidates, so the kernels simply drop them.
FUSABLE_KEYS: Tuple[str, ...] = (
    "frag-delta", "free-slices", "gpu", "anchor",
) + REQUEST_KEYS


def key_base(key: str) -> str:
    """Strip the optional ``-`` direction prefix off a scoring key."""
    return key[1:] if key.startswith("-") else key


def queue_order(spec: "PolicySpec") -> Tuple[str, ...]:
    """The cross-request admission ordering a spec implies.

    Returns the spec's request-scoped keys (:data:`REQUEST_KEYS` bases, in
    spec order, direction prefixes preserved), or
    :data:`DEFAULT_QUEUE_ORDER` when the spec names none.  Queued admission
    paths — the serving front-end's wait queue and the batched engine's
    ``steady-queued`` wait ring — admit the waiting request minimizing this
    key tuple (ties broken by arrival order).
    """
    keys = tuple(k for k in spec.keys if key_base(k) in REQUEST_KEYS)
    return keys if keys else DEFAULT_QUEUE_ORDER


@dataclasses.dataclass(frozen=True)
class PolicySpec:
    """A frozen, registrable description of a placement policy.

    A policy is: filter the feasible ``(gpu, anchor)`` dry-runs of the
    request, score each with the ordered ``keys``, and commit the candidate
    with the lexicographically smallest key tuple (remaining ties broken by
    ascending ``(gpu, anchor)``).  Instances are hashable, so a spec doubles
    as a jit static argument in the batched engine.

    Attributes:
      name: registry name (also the CLI / ``SimConfig`` policy string).
      keys: ordered lexicographic scoring keys; bases must come from
        :data:`KEY_VOCABULARY`, a ``-`` prefix flips the direction.
      feasibility: candidate filter; ``"window-free"`` keeps anchors whose
        placement window has zero occupied slices (and drops demand classes
        with no realization on the GPU's model).
      defrag: on reject, search for ONE running workload whose migration
        makes the request feasible (the beyond-paper ``mfi-defrag``
        behaviour).  Both engines implement it: the host scheduler as the
        canonical ``(total F, victim gpu, victim anchor)`` candidate search,
        the batched engine as a migrate stage compiled into its scan body
        (the expiry ring doubles as the allocation table).  Incompatible
        with the ``rr-distance`` key (the inner dry-run selections of the
        search would advance the rotation cursor ambiguously).
      engines: engines this spec may be compiled to (default: all).  A
        spec can opt out of an engine, e.g. a host-side-only experiment;
        :func:`resolve` raises through the same message everywhere.
      kernel_lowering: how far the batched engine may lower this spec's
        scoring into the Pallas kernels (``use_kernel=True``).  One of
        :data:`KERNEL_LOWERINGS`:

        * ``True`` (default) — everything available: the fused per-model
          select/migrate kernels with in-kernel lexicographic argmin when
          the spec's keys are fusable (:attr:`argmin_fusable`), the
          ``delta_from_base`` ΔF dispatch otherwise, plus the
          occupancy-based ``fragscore`` rescore on homogeneous fleets;
        * ``"fused"`` — like ``True`` but *declares* argmin-fusability:
          constructing the spec raises unless every key is packable
          (:data:`FUSABLE_KEYS`), so a defrag spec that says ``"fused"``
          is guaranteed to compose with the fused migrate-search kernel;
        * ``"delta"`` — ΔF-table lowering only; the argmin (select and the
          migrate stage's refinements) stays pure jnp.  For specs whose
          custom key semantics must not enter the packed-key reduction;
        * ``False`` — no kernels at all; ``run_batched(use_kernel=True)``
          raises.

        All lowerings are bit-for-bit with the pure-jnp reference
        (integer-valued scores, exact in float32).
      description: one-line human summary (shown by ``list_policies``
        consumers and docs).
    """

    name: str
    keys: Tuple[str, ...]
    feasibility: str = "window-free"
    defrag: bool = False
    engines: Tuple[str, ...] = ENGINES
    kernel_lowering: Union[bool, str] = True
    description: str = ""

    def __post_init__(self):
        if not self.name:
            raise ValueError("PolicySpec needs a non-empty name")
        if not isinstance(self.keys, tuple):
            object.__setattr__(self, "keys", tuple(self.keys))
        if not self.keys:
            raise ValueError(f"policy {self.name!r}: needs at least one scoring key")
        for key in self.keys:
            if key_base(key) not in KEY_VOCABULARY:
                raise ValueError(
                    f"policy {self.name!r}: unknown scoring key {key!r}; "
                    f"vocabulary: {KEY_VOCABULARY} (optionally '-'-prefixed)"
                )
        if self.feasibility not in FEASIBILITY_FILTERS:
            raise ValueError(
                f"policy {self.name!r}: unknown feasibility filter "
                f"{self.feasibility!r}; options: {FEASIBILITY_FILTERS}"
            )
        if not isinstance(self.engines, tuple):
            object.__setattr__(self, "engines", tuple(self.engines))
        if not self.engines:
            raise ValueError(f"policy {self.name!r}: needs at least one engine")
        for engine in self.engines:
            if engine not in ENGINES:
                raise ValueError(
                    f"policy {self.name!r}: unknown engine {engine!r}; "
                    f"options: {ENGINES}"
                )
        if self.defrag and self.stateful_cursor:
            raise ValueError(
                f"policy {self.name!r}: defrag is incompatible with the "
                "'rr-distance' key (the migration search's inner dry-run "
                "selections would advance the rotation cursor ambiguously)"
            )
        if self.kernel_lowering not in KERNEL_LOWERINGS:
            raise ValueError(
                f"policy {self.name!r}: unknown kernel_lowering "
                f"{self.kernel_lowering!r}; options: {KERNEL_LOWERINGS}"
            )
        if self.kernel_lowering == "fused" and not self.argmin_fusable:
            bad = tuple(k for k in self.keys if key_base(k) not in FUSABLE_KEYS)
            raise ValueError(
                f"policy {self.name!r}: kernel_lowering='fused' declares "
                "argmin-fusability, but the spec is not fusable "
                f"({'keys ' + repr(bad) + ' cannot be packed' if bad else 'no frag-delta key — nothing to fuse'}; "
                f"fusable bases: {FUSABLE_KEYS})"
            )

    # -- derived structure ---------------------------------------------------
    @property
    def requires_delta_f(self) -> bool:
        """Whether any key consumes the ΔF (fragmentation-increment) table."""
        return any(key_base(k) == "frag-delta" for k in self.keys)

    @property
    def stateful_cursor(self) -> bool:
        """Whether the policy carries a round-robin rotation cursor."""
        return any(key_base(k) == "rr-distance" for k in self.keys)

    @property
    def argmin_fusable(self) -> bool:
        """Whether the spec's key list can be packed into the fused
        select/migrate Pallas kernels' in-kernel lexicographic argmin:
        every key base must be in :data:`FUSABLE_KEYS`.  ΔF-free specs
        (bf-bi/wf-bi/ff) qualify too — the kernel simply skips the ΔF
        tile and reduces the remaining keys in-register."""
        return all(key_base(k) in FUSABLE_KEYS for k in self.keys)

    @property
    def fused_argmin(self) -> bool:
        """Whether ``use_kernel=True`` routes this spec through the fused
        select/migrate kernels (declared via :attr:`kernel_lowering` and
        structurally :attr:`argmin_fusable`)."""
        return self.kernel_lowering in (True, "fused") and self.argmin_fusable

    def supports(self, engine: str) -> bool:
        return engine in self.engines


#: anything the public entry points accept where a policy is expected
PolicyLike = Union[str, PolicySpec]


# ---------------------------------------------------------------------------
# Registry — the single source of truth for both engines
# ---------------------------------------------------------------------------

_REGISTRY: Dict[str, PolicySpec] = {}


def register_policy(spec: PolicySpec, overwrite: bool = False) -> PolicySpec:
    """Register ``spec`` under ``spec.name``; returns the spec.

    Registered policies are immediately usable by both engines and every
    entry point (``make_scheduler``, ``run_many``, ``run_batched``,
    ``simulate``) and picked up by the registry-parametrized parity tests.
    """
    if not isinstance(spec, PolicySpec):
        raise TypeError(f"register_policy expects a PolicySpec, got {type(spec)}")
    if spec.name in _REGISTRY and not overwrite:
        raise ValueError(
            f"policy {spec.name!r} is already registered; "
            "pass overwrite=True to replace it"
        )
    _REGISTRY[spec.name] = spec
    return spec


def unregister_policy(name: str) -> None:
    """Remove a registered policy (built-ins included — use with care)."""
    _REGISTRY.pop(name, None)


def get_policy(name: str) -> PolicySpec:
    """Look up a registered spec by name (the validating path is
    :func:`resolve`)."""
    return resolve(name)


def list_policies(engine: Optional[str] = None) -> Tuple[str, ...]:
    """Sorted names of registered policies, optionally engine-filtered."""
    if engine is not None and engine not in ENGINES:
        raise ValueError(f"unknown engine {engine!r}; options: {ENGINES}")
    return tuple(
        sorted(
            name
            for name, spec in _REGISTRY.items()
            if engine is None or spec.supports(engine)
        )
    )


def policy_engines(name: str) -> Tuple[str, ...]:
    """Engines supporting a registered policy."""
    return resolve(name).engines


def _catalog() -> str:
    return ", ".join(
        f"{name} ({'+'.join(_REGISTRY[name].engines)})"
        for name in sorted(_REGISTRY)
    )


def resolve(policy: PolicyLike, engine: Optional[str] = None) -> PolicySpec:
    """The one validation path: name-or-spec -> :class:`PolicySpec`.

    Raises ``ValueError`` with a message naming every registered policy and
    which engines support each — both on an unknown name and on a policy /
    engine mismatch.  All entry points (``make_scheduler``, ``run_many``,
    ``run_batched``, ``policy_select``, ``simulate``) route through here, so
    the errors are consistent everywhere.
    """
    if engine is not None and engine not in ENGINES:
        raise ValueError(f"unknown engine {engine!r}; options: {ENGINES}")
    if isinstance(policy, PolicySpec):
        spec = policy  # ad-hoc (possibly unregistered) specs are welcome
    else:
        spec = _REGISTRY.get(policy)
        if spec is None:
            raise ValueError(
                f"unknown policy {policy!r}; registered policies: {_catalog()}"
            )
    if engine is not None and not spec.supports(engine):
        raise ValueError(
            f"policy {spec.name!r} is not supported by the {engine!r} engine "
            f"(supports: {'+'.join(spec.engines)}); policies supporting "
            f"{engine!r}: {', '.join(list_policies(engine))}"
        )
    return spec


# ---------------------------------------------------------------------------
# Built-in policies — the paper's MFI, its four baselines, and the
# beyond-paper defrag variant, each as one declarative spec.
# ---------------------------------------------------------------------------

MFI_SPEC = register_policy(
    PolicySpec(
        name="mfi",
        keys=("frag-delta", "gpu", "anchor"),
        description=(
            "Minimum Fragmentation Increment (paper Alg. 2): argmin ΔF over "
            "all feasible dry-runs, ties by (gpu, anchor)"
        ),
    )
)

FF_SPEC = register_policy(
    PolicySpec(
        name="ff",
        keys=("gpu", "anchor"),
        description="First-Fit: first GPU with room, first available index",
    )
)

RR_SPEC = register_policy(
    PolicySpec(
        name="rr",
        keys=("rr-distance", "anchor"),
        description=(
            "Round-Robin: first feasible GPU in cursor rotation, first "
            "available index; the cursor advances past each accepted GPU"
        ),
    )
)

BF_BI_SPEC = register_policy(
    PolicySpec(
        name="bf-bi",
        keys=("free-slices", "gpu", "-anchor"),
        description=(
            "Best-Fit Best-Index: fewest post-allocation free slices, ties "
            "by GPU id; highest feasible anchor (Best Index)"
        ),
    )
)

WF_BI_SPEC = register_policy(
    PolicySpec(
        name="wf-bi",
        keys=("-free-slices", "gpu", "-anchor"),
        description=(
            "Worst-Fit Best-Index: most post-allocation free slices, ties "
            "by GPU id; highest feasible anchor (Best Index)"
        ),
    )
)

MFI_DEFRAG_SPEC = register_policy(
    PolicySpec(
        name="mfi-defrag",
        keys=("frag-delta", "gpu", "anchor"),
        defrag=True,
        description=(
            "BEYOND-PAPER: MFI plus an opportunistic single-migration "
            "defrag search on reject (both engines)"
        ),
    )
)

MFI_QUEUED_SPEC = register_policy(
    PolicySpec(
        name="mfi-queued",
        keys=("priority", "-wait-age", "frag-delta", "gpu", "anchor"),
        description=(
            "BEYOND-PAPER: MFI placement with an explicit queue order — "
            "priority class first, then oldest wait (placement-identical "
            "to mfi; the request-scoped keys order waiting requests under "
            "queued admission)"
        ),
    )
)
