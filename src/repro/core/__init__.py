"""Paper core: MIG model, fragmentation metric (Alg. 1), MFI scheduler (Alg. 2)."""

from repro.core.mig import (  # noqa: F401
    A100_40GB,
    A100_80GB,
    DEVICE_MODELS,
    H100_96GB,
    NUM_MEM_SLICES,
    NUM_PROFILES,
    NUM_SM_SLICES,
    PROFILE_BY_NAME,
    PROFILE_NAMES,
    PROFILES,
    ClusterSpec,
    ClusterState,
    DeviceModel,
    GPUState,
    MIGProfile,
)
from repro.core.fragmentation import (  # noqa: F401
    cluster_fragmentation,
    delta_f,
    fragmentation_score,
    fragmentation_scores,
    spec_fragmentation_scores,
)
from repro.core.schedulers import (  # noqa: F401
    MFI,
    SCHEDULERS,
    BestFitBestIndex,
    FirstFit,
    RoundRobin,
    Scheduler,
    WorstFitBestIndex,
    make_scheduler,
    mfi_candidates,
)
