"""Paper core: MIG model, fragmentation metric (Alg. 1), MFI scheduler (Alg. 2)."""

from repro.core.mig import (  # noqa: F401
    A100_40GB,
    A100_80GB,
    DEVICE_MODELS,
    H100_80GB,
    H100_96GB,
    NUM_MEM_SLICES,
    NUM_PROFILES,
    NUM_SM_SLICES,
    PROFILE_BY_NAME,
    PROFILE_NAMES,
    PROFILES,
    ClusterSpec,
    ClusterState,
    DeviceModel,
    GPUState,
    MIGProfile,
)
from repro.core.fragmentation import (  # noqa: F401
    cluster_fragmentation,
    delta_f,
    fragmentation_score,
    fragmentation_scores,
    spec_fragmentation_scores,
)
from repro.core.policy import (  # noqa: F401
    KEY_VOCABULARY,
    PolicySpec,
    get_policy,
    list_policies,
    policy_engines,
    register_policy,
    unregister_policy,
)
from repro.core.schedulers import (  # noqa: F401
    MFI,
    SCHEDULERS,
    BestFitBestIndex,
    FirstFit,
    MFIDefrag,
    RoundRobin,
    Scheduler,
    SpecScheduler,
    WorstFitBestIndex,
    compile_policy,
    make_scheduler,
    mfi_candidates,
)
