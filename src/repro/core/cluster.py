"""Vectorized, jittable cluster scheduling in JAX.

The paper's Algorithms 1/2 are per-GPU python loops.  On TPU we recast them
as batched bitmask algebra (DESIGN.md §5): cluster occupancy ``X (M, 8)``
against the constant placement-window matrix ``Wᵀ (8, 18)``, partial-window
predicate and weighted reduction — one fused launch per scheduling decision.

Everything here is pure ``jnp`` and jit-compatible with a *traced* profile
id, which lets the serving engine batch scheduling decisions.  The Pallas
kernels in :mod:`repro.kernels.fragscore` / :mod:`repro.kernels.mfi_select`
implement the same math with explicit VMEM tiling; this module doubles as
their oracle at cluster scale.
"""

from __future__ import annotations

import functools
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import mig

MAX_ANCHORS = max(p.num_placements for p in mig.PROFILES)  # 7


class DeviceTables(NamedTuple):
    """One device model's placement tables as jnp constants.

    Shapes (N = flattened placements, A = padded anchor count, S = slices):
      ``placement_masks (N, S)`` / ``placement_mem (N,)`` — flattened table;
      ``profile_masks (P, A, S)`` / ``profile_anchors (P, A)`` /
      ``profile_valid (P, A)`` — per-class padded anchor views.
    """

    placement_masks: jax.Array
    placement_mem: jax.Array
    profile_masks: jax.Array
    profile_anchors: jax.Array
    profile_valid: jax.Array

    @property
    def num_mem_slices(self) -> int:
        return self.placement_masks.shape[1]


def _np_profile_tables(
    model: mig.DeviceModel, max_anchors: int = None
) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Per-profile padded anchor tables of one device model.

    Returns:
      masks:   (P, A_max, S) int32 — placement window bitmask (0 where padded)
      anchors: (P, A_max)    int32 — anchor index (-1 where padded)
      valid:   (P, A_max)    bool  — anchor validity
    """
    P = mig.NUM_PROFILES
    A = max_anchors if max_anchors is not None else model.max_anchors
    masks = np.zeros((P, A, model.num_mem_slices), dtype=np.int32)
    anchors = np.full((P, A), -1, dtype=np.int32)
    valid = np.zeros((P, A), dtype=bool)
    for pid, prof in enumerate(model.profiles):
        for j, a in enumerate(prof.anchors):
            masks[pid, j, a : a + prof.mem] = 1
            anchors[pid, j] = a
            valid[pid, j] = True
    return masks, anchors, valid


@functools.lru_cache(maxsize=None)
def tables_for(model: mig.DeviceModel, max_anchors: int = None) -> DeviceTables:
    """Build (and cache) the jnp placement tables of a device model."""
    masks, anchors, valid = _np_profile_tables(model, max_anchors)
    return DeviceTables(
        placement_masks=jnp.asarray(model.placement_masks, dtype=jnp.float32),
        placement_mem=jnp.asarray(model.placement_mem, dtype=jnp.float32),
        profile_masks=jnp.asarray(masks),
        profile_anchors=jnp.asarray(anchors),
        profile_valid=jnp.asarray(valid),
    )


_PROFILE_MASKS_NP, _PROFILE_ANCHORS_NP, _PROFILE_VALID_NP = _np_profile_tables(
    mig.A100_80GB
)

# Constant A100-80GB tables (host numpy; closed over by jitted fns as
# literals) — the defaults whenever no ``tables`` argument is passed.
PLACEMENT_MASKS = jnp.asarray(mig.PLACEMENT_MASKS, dtype=jnp.float32)  # (18, 8)
PLACEMENT_MEM = jnp.asarray(mig.PLACEMENT_MEM, dtype=jnp.float32)  # (18,)
PROFILE_MASKS = jnp.asarray(_PROFILE_MASKS_NP)  # (P, 7, 8)
PROFILE_ANCHORS = jnp.asarray(_PROFILE_ANCHORS_NP)  # (P, 7)
PROFILE_VALID = jnp.asarray(_PROFILE_VALID_NP)  # (P, 7)
PROFILE_MEM = jnp.asarray(mig.PROFILE_MEM)  # (P,)

_DEFAULT_TABLES = DeviceTables(
    placement_masks=PLACEMENT_MASKS,
    placement_mem=PLACEMENT_MEM,
    profile_masks=PROFILE_MASKS,
    profile_anchors=PROFILE_ANCHORS,
    profile_valid=PROFILE_VALID,
)


def frag_scores(
    occ: jax.Array, metric: str = "blocked", tables: DeviceTables = None
) -> jax.Array:
    """F(m) for every same-model GPU.  occ: (M, S) int — returns (M,) float32."""
    t = _DEFAULT_TABLES if tables is None else tables
    occf = occ.astype(jnp.float32)
    occ_in_window = occf @ t.placement_masks.T  # (M, N)
    size = t.placement_mem[None, :]
    if metric == "blocked":
        counted = occ_in_window > 0
    elif metric == "partial":
        counted = (occ_in_window > 0) & (occ_in_window < size)
    else:
        raise ValueError(f"unknown metric {metric!r}")
    free = t.num_mem_slices - occf.sum(axis=1, keepdims=True)  # (M, 1)
    eligible = size <= free
    return jnp.sum(jnp.where(counted & eligible, size, 0.0), axis=1)


class MFIDecision(NamedTuple):
    gpu: jax.Array      # int32, -1 when rejected
    anchor: jax.Array   # int32, -1 when rejected
    accepted: jax.Array  # bool
    delta_f: jax.Array  # float32 ΔF of the chosen placement (0 when rejected)


def placement_feasibility(
    occ: jax.Array, profile_id: jax.Array, tables: DeviceTables = None,
    gpu_ok: jax.Array = None,
) -> jax.Array:
    """(M, A) bool — anchors of ``profile_id`` whose window is fully free.

    Columns follow ``tables.profile_anchors[profile_id]`` (ascending anchor
    order); padded anchor columns are always infeasible.  ``gpu_ok`` is an
    optional (M,) bool availability mask (False rows — e.g. failed GPUs —
    are infeasible regardless of occupancy).
    """
    t = _DEFAULT_TABLES if tables is None else tables
    masks = t.profile_masks[profile_id]  # (A, S) int32
    valid = t.profile_valid[profile_id]  # (A,)
    occf = occ.astype(jnp.float32)
    overlap = occf @ masks.T.astype(jnp.float32)  # (M, A)
    feasible = (overlap == 0) & valid[None, :]
    if gpu_ok is not None:
        feasible = feasible & gpu_ok[:, None]
    return feasible


def placement_delta_f(
    occ: jax.Array,
    profile_id: jax.Array,
    metric: str = "blocked",
    frag_fn=None,
    tables: DeviceTables = None,
) -> jax.Array:
    """(M, A) float32 — ΔF of every dry-run placement of ``profile_id``.

    ``frag_fn`` maps an (N, S) occupancy to (N,) scores; defaults to the
    pure-jnp :func:`frag_scores` (the Pallas ``fragscore`` kernel is a
    drop-in — see :mod:`repro.kernels.fragscore.ops`).
    """
    t = _DEFAULT_TABLES if tables is None else tables
    if frag_fn is None:
        frag_fn = functools.partial(frag_scores, metric=metric, tables=tables)
    masks = t.profile_masks[profile_id]  # (A, S) int32
    f_before = frag_fn(occ)  # (M,)
    hypo = jnp.minimum(occ[:, None, :] + masks[None, :, :], 1)  # (M, A, S)
    f_after = frag_fn(hypo.reshape(-1, t.num_mem_slices)).reshape(
        occ.shape[0], -1
    )  # (M, A)
    return f_after - f_before[:, None]


@functools.partial(jax.jit, static_argnames=("metric", "use_kernel", "interpret"))
def mfi_select(
    occ: jax.Array,
    profile_id: jax.Array,
    metric: str = "blocked",
    tables: DeviceTables = None,
    use_kernel: bool = False,
    interpret: bool = None,
) -> MFIDecision:
    """Algorithm 2's argmin over all feasible (GPU, anchor) dry-runs.

    The single entry point for both lowerings: the pure-jnp dense dry-run
    (default) and the fused Pallas ``mfi_delta`` kernel (``use_kernel=True``
    — feasibility + ΔF in one launch; ``interpret`` defaults to interpret
    mode off-TPU).  Both produce the identical decision: scores are
    integer-valued, the argmin's first-occurrence tie-break is shared.

    Args:
      occ: (M, S) int32 occupancy of same-model GPUs (``tables`` selects the
        model; default A100-80GB).
      profile_id: scalar int32 (traced — one jit serves all profiles).
    """
    t = _DEFAULT_TABLES if tables is None else tables
    anchors = t.profile_anchors[profile_id]  # (A,)
    if use_kernel:
        from repro.kernels.fragscore import fragscore as _k

        interp = jax.default_backend() != "tpu" if interpret is None else interpret
        big = jnp.float32(1e30)  # the kernel's own infeasibility sentinel
        scored = _k.mfi_delta(
            occ,
            t.placement_masks,
            t.placement_mem,
            t.profile_masks[profile_id],
            t.profile_valid[profile_id].astype(jnp.float32),
            metric=metric,
            interpret=interp,
        )
    else:
        feasible = placement_feasibility(occ, profile_id, tables)
        delta = placement_delta_f(occ, profile_id, metric, tables=tables)
        big = jnp.float32(1e9)
        scored = jnp.where(feasible, delta, big)
    flat = scored.reshape(-1)
    k = jnp.argmin(flat)  # first occurrence == (gpu, anchor) lexicographic tie-break
    accepted = flat[k] < big
    gpu = jnp.where(accepted, k // scored.shape[1], -1).astype(jnp.int32)
    aidx = k % scored.shape[1]
    anchor = jnp.where(accepted, anchors[aidx], -1).astype(jnp.int32)
    return MFIDecision(gpu, anchor, accepted, jnp.where(accepted, flat[k], 0.0))


@functools.partial(jax.jit, static_argnames=("metric",))
def mfi_allocate(
    occ: jax.Array,
    profile_id: jax.Array,
    metric: str = "blocked",
    tables: DeviceTables = None,
) -> Tuple[jax.Array, MFIDecision]:
    """Select AND commit: returns (new_occ, decision).  Pure/jittable."""
    t = _DEFAULT_TABLES if tables is None else tables
    d = mfi_select(occ, profile_id, metric, tables)
    masks = t.profile_masks[profile_id]  # (A, S)
    aidx = jnp.argmax(t.profile_anchors[profile_id] == d.anchor)
    mask = masks[aidx] * d.accepted.astype(jnp.int32)  # zero mask when rejected
    row = jnp.where(d.accepted, d.gpu, 0)
    new_occ = occ.at[row].set(jnp.minimum(occ[row] + mask, 1))
    return new_occ, d


@jax.jit
def release(
    occ: jax.Array,
    gpu: jax.Array,
    profile_id: jax.Array,
    anchor: jax.Array,
    tables: DeviceTables = None,
) -> jax.Array:
    """Free a previously committed placement (jittable)."""
    t = _DEFAULT_TABLES if tables is None else tables
    aidx = jnp.argmax(t.profile_anchors[profile_id] == anchor)
    mask = t.profile_masks[profile_id][aidx]
    return occ.at[gpu].set(jnp.maximum(occ[gpu] - mask, 0))
