"""Flat-npz checkpointing for arbitrary param/opt pytrees.

Leaves are stored under '/'-joined key paths; restore validates structure
against a template pytree, so a checkpoint from a different architecture
or stale config fails loudly instead of silently mis-loading.
"""

from __future__ import annotations

import json
from pathlib import Path
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

_BF16 = jnp.bfloat16


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(
            str(getattr(p, "key", getattr(p, "idx", p))) for p in path
        )
        arr = np.asarray(leaf)
        if arr.dtype == _BF16:  # npz has no bf16: store upcast, restore downcast
            arr = arr.astype(np.float32)
        flat[key] = arr
    return flat


def save_checkpoint(path, tree, step: int = 0, metadata: Dict[str, Any] | None = None):
    path = Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    flat = _flatten(tree)
    np.savez(path, **flat)
    meta = {"step": step, "keys": sorted(flat), **(metadata or {})}
    path.with_suffix(".json").write_text(json.dumps(meta))


def load_checkpoint(path, template) -> Tuple[Any, int]:
    """Restore into the structure of ``template``; returns (tree, step)."""
    path = Path(path)
    data = np.load(path if path.suffix == ".npz" else path.with_suffix(".npz"))
    meta = json.loads(path.with_suffix(".json").read_text())
    flat_t = _flatten(template)
    missing = set(flat_t) - set(data.files)
    extra = set(data.files) - set(flat_t)
    if missing or extra:
        raise ValueError(f"checkpoint mismatch: missing={sorted(missing)[:5]} extra={sorted(extra)[:5]}")
    leaves_with_path = jax.tree_util.tree_flatten_with_path(template)
    restored = []
    for path_keys, leaf in leaves_with_path[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path_keys)
        arr = data[key]
        if arr.shape != leaf.shape:
            raise ValueError(f"shape mismatch at {key}: {arr.shape} vs {leaf.shape}")
        restored.append(jnp.asarray(arr).astype(leaf.dtype))
    return jax.tree_util.tree_unflatten(leaves_with_path[1], restored), meta["step"]
