"""Flat-npz checkpointing for arbitrary param/opt pytrees.

Leaves are stored under '/'-joined key paths; restore validates structure
against a template pytree, so a checkpoint from a different architecture
or stale config fails loudly instead of silently mis-loading.

Saves are crash-safe: the payload is written to a temp file and moved into
place with ``os.replace``, then the metadata sidecar (which records a
SHA-256 of the payload) is committed the same way.  A missing sidecar
therefore means the save never completed; a digest mismatch means the
payload was corrupted or overwritten after the sidecar was committed.
Both are surfaced as descriptive errors on load.
"""

from __future__ import annotations

import hashlib
import json
import os
from pathlib import Path
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

_BF16 = jnp.bfloat16


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(
            str(getattr(p, "key", getattr(p, "idx", p))) for p in path
        )
        arr = np.asarray(leaf)
        if arr.dtype == _BF16:  # npz has no bf16: store upcast, restore downcast
            arr = arr.astype(np.float32)
        flat[key] = arr
    return flat


def _payload_path(path: Path) -> Path:
    # np.savez appends .npz when the name does not already end with it;
    # mirror that so save and load agree on the final payload location.
    return path if path.suffix == ".npz" else Path(str(path) + ".npz")


def _sha256_file(path: Path) -> str:
    digest = hashlib.sha256()
    with open(path, "rb") as fh:
        for block in iter(lambda: fh.read(1 << 20), b""):
            digest.update(block)
    return digest.hexdigest()


def save_checkpoint(path, tree, step: int = 0, metadata: Dict[str, Any] | None = None):
    path = Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    flat = _flatten(tree)
    payload = _payload_path(path)
    # The temp name keeps the .npz suffix so np.savez does not append another.
    tmp = payload.with_name(payload.name + ".tmp.npz")
    np.savez(tmp, **flat)
    digest = _sha256_file(tmp)
    os.replace(tmp, payload)  # atomic: readers see old payload or new, never partial
    meta = {"step": step, "keys": sorted(flat), "sha256": digest, **(metadata or {})}
    sidecar = path.with_suffix(".json")
    meta_tmp = sidecar.with_name(sidecar.name + ".tmp")
    meta_tmp.write_text(json.dumps(meta))
    os.replace(meta_tmp, sidecar)  # sidecar lands last: it is the commit marker


def load_checkpoint(path, template) -> Tuple[Any, int]:
    """Restore into the structure of ``template``; returns (tree, step)."""
    path = Path(path)
    payload = _payload_path(path)
    sidecar = path.with_suffix(".json")
    if not sidecar.exists():
        raise FileNotFoundError(
            f"checkpoint sidecar {sidecar} is missing; the sidecar is written "
            f"last, so an absent one means the save was interrupted before it "
            f"committed — discard {payload} and fall back to an older checkpoint"
        )
    meta = json.loads(sidecar.read_text())
    recorded = meta.get("sha256")
    if recorded is not None:  # sidecars from before the digest existed load as-is
        actual = _sha256_file(payload)
        if actual != recorded:
            raise ValueError(
                f"checkpoint payload mismatch for {payload}: sha256 {actual} != "
                f"recorded {recorded}; the payload is corrupt or was overwritten "
                f"after the sidecar was committed"
            )
    data = np.load(payload)
    flat_t = _flatten(template)
    missing = set(flat_t) - set(data.files)
    extra = set(data.files) - set(flat_t)
    if missing or extra:
        raise ValueError(f"checkpoint mismatch: missing={sorted(missing)[:5]} extra={sorted(extra)[:5]}")
    leaves_with_path = jax.tree_util.tree_flatten_with_path(template)
    restored = []
    for path_keys, leaf in leaves_with_path[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path_keys)
        arr = data[key]
        if arr.shape != leaf.shape:
            raise ValueError(f"shape mismatch at {key}: {arr.shape} vs {leaf.shape}")
        restored.append(jnp.asarray(arr).astype(leaf.dtype))
    return jax.tree_util.tree_unflatten(leaves_with_path[1], restored), meta["step"]
