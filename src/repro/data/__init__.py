"""Deterministic synthetic data pipeline."""

from repro.data.synthetic import SyntheticLM, make_batch_iterator  # noqa: F401
