"""Synthetic token stream with learnable structure.

A pure-numpy, seeded generator producing (tokens, labels) batches whose
next-token distribution is a genuinely learnable order-2 Markov chain —
training loss decreasing below the unigram entropy demonstrates real
learning in the e2e example, not just graph execution.  Modality stubs
(vision patches / audio frames) are generated as seeded gaussians of the
correct post-frontend shape.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import numpy as np

from repro.models.config import ModelConfig


@dataclasses.dataclass
class SyntheticLM:
    vocab: int
    seed: int = 0
    branching: int = 4  # successors per (prev, cur) state

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        # order-2 transition table: (V, B) successor ids + logits
        self.succ = rng.integers(0, self.vocab, size=(self.vocab, self.branching))
        self.probs = rng.dirichlet(np.ones(self.branching), size=self.vocab)

    def sample(self, batch: int, seq: int, rng: np.random.Generator) -> np.ndarray:
        toks = np.empty((batch, seq), dtype=np.int32)
        toks[:, 0] = rng.integers(0, self.vocab, size=batch)
        for t in range(1, seq):
            prev = toks[:, t - 1]
            choice = np.array(
                [rng.choice(self.branching, p=self.probs[p]) for p in prev]
            )
            toks[:, t] = self.succ[prev, choice]
        return toks


def make_batch_iterator(
    cfg: ModelConfig,
    batch: int,
    seq: int,
    *,
    seed: int = 0,
) -> Iterator[Dict[str, np.ndarray]]:
    """Yields model-ready batches for cfg's family, forever."""
    gen = SyntheticLM(cfg.vocab, seed=seed)
    rng = np.random.default_rng(seed + 1)
    while True:
        toks = gen.sample(batch, seq + 1, rng)
        out: Dict[str, np.ndarray] = {
            "tokens": toks[:, :-1],
            "labels": toks[:, 1:],
        }
        if cfg.frontend == "vision":
            out["patches"] = rng.standard_normal(
                (batch, cfg.num_patches, cfg.d_model)
            ).astype(np.float32)
        if cfg.encdec:
            out["frames"] = rng.standard_normal((batch, seq, cfg.d_model)).astype(
                np.float32
            )
        yield out
