"""Batched on-device Monte-Carlo simulation engine (staged scan pipeline).

The Python reference in :mod:`repro.sim.simulator` runs replicas one at a
time through a ``ClusterState``/``heapq`` event loop; at the paper's scale
(500 replicas per point, §VI) a load sweep takes hours.  This module runs
**R replicas × T slots as one** ``lax.scan`` **over a vmapped replica axis**
so the whole Monte-Carlo average is a single XLA program.

Staged pipeline (the :class:`EngineCore`)
    The scan body is composed from small *stages* —
    ``arrival → select → migrate → commit → expire → measure`` — and two
    static descriptors decide which stages are compiled in:

    * the :class:`Protocol` descriptor (``steady`` | ``cumulative``)
      selects the *measure* semantics: slot-boundary sampling for the
      steady protocol (paper §VI), post-commit sampling on the cumulative
      demand grid for the paper-literal cumulative protocol;
    * the :class:`~repro.core.policy.PolicySpec` selects the decision
      stages: the select lowering (:func:`_lower_select`, or — under
      ``use_kernel`` for argmin-fusable specs — the fused Pallas
      :func:`~repro.kernels.fragscore.fragscore.select_from_base` launch
      via :func:`make_select_fn`), the optional *migrate* stage
      (``spec.defrag`` — the beyond-paper ``mfi-defrag``
      single-migration search, see below; fused counterpart
      :func:`make_migrate_fn`), and the rotation-cursor update.  See
      ``docs/KERNELS.md`` for the kernel dispatch rules.

    Because the descriptors are static jit arguments, a configuration
    compiles exactly the stages it needs: the steady/non-defrag pipeline
    emits the same computation as the original monolithic event step
    (pre-refactor traces reproduce bit-for-bit).

Event stream
    Arrivals are pre-sampled on host (Poisson counts, profile ids and
    durations per slot for the steady protocol; one arrival per slot for
    the cumulative protocol) and flattened into one *event stream* per
    replica: one event per arrival, plus one synthetic heartbeat event for
    every empty slot so consecutive events never skip a slot.  Streams are
    padded to the longest replica (``pid = -1`` lanes are no-ops), and
    everything slot-dependent (release ring row, metric-sample flags,
    measurement window membership) is precomputed host-side, so the device
    step is pure tensor algebra with no clock arithmetic.

Heterogeneous fleets
    A :class:`repro.core.mig.ClusterSpec` (``SimConfig.cluster_spec``) may
    mix device models.  All per-model placement tables are stacked into one
    :class:`SpecTables` pytree — ``(K, N, ...)`` arrays padded to a common
    placement count ``N`` and anchor count ``A`` — and a static ``(M,)``
    model-index array ``midx`` gathers each GPU's tables inside the scan
    step.  The MFI ΔF table becomes a per-model gather plus one batched
    matmul (``einsum('mn,man->ma')``), so the scan stays fully jittable;
    the paper's homogeneous setup is the trivial ``K = 1`` spec and
    reproduces the previous engine bit-for-bit.  Non-8-slice geometries
    (e.g. the stylized H200-141GB) ride the same padded-width path.

Replica state (fixed-capacity struct-of-arrays pytree)
    * ``occ (M, S) int32`` — cluster occupancy bitmap (materialized only
      when the Pallas-kernel scoring path needs it; otherwise ``base``
      carries the full information);
    * ``base (M, N) float32`` — occupied-slice count per placement window
      of each GPU's own model, ``occ @ W[midx]ᵀ``.  Window counts are
      *linear* in occupancy, so ``base`` is maintained incrementally (row
      add on commit, row subtract on release) and every fragmentation
      quantity — F(m), the full MFI ΔF table, feasibility — derives from
      it without per-arrival matmuls over hypothetical occupancies;
    * ``free (M,) int32`` / ``f (M,) float32`` — free-slice counts and
      per-GPU fragmentation scores, recomputed only for rows a drain or
      commit touched;
    * ``rr () int32`` — RoundRobin cursor (next GPU to try first); carried
      through the scan so RR is an ordinary batched policy;
    * an expiry ring buffer ``ring_gpu (K+2, E) int32`` /
      ``ring_mask (K+2, E, S) int32`` keyed by end slot modulo
      ``K = T + 1``: row ``e % K`` holds the (gpu, placement-window) rows
      of workloads expiring at slot ``e``.  Durations are drawn from
      ``[1, T]``, so an end slot is strictly less than one ring revolution
      ahead and each row is drained (masked scatter-subtract) exactly when
      the clock reaches it, before it can be re-targeted.  Within-row
      columns are assigned on host (arrival rank among same-end-slot
      arrivals), so inserts never collide; row ``K + 1`` is a write-only
      trash row for padding lanes.  For defrag specs two parallel planes
      ``ring_pid`` / ``ring_aidx`` additionally record each running
      workload's demand class and anchor index — **the ring doubles as the
      allocation table** the migration search needs.

Migrate stage (batched ``mfi-defrag``)
    When ``spec.defrag`` and the arrival was rejected, the stage evaluates
    every running workload (= live ring entry) as a migration victim with
    masked tensor ops: hypothetically evacuate it, re-select the request on
    the freed GPU (the only GPU where it can have become feasible), then
    re-place the victim anywhere via the spec's own key list, scoring each
    candidate by the total cluster fragmentation after both moves.  The
    winner is the lexicographic minimum of ``(total F, victim gpu, victim
    anchor)`` — exactly the canonical order the host search
    (:class:`repro.core.schedulers.MFIDefrag`) enumerates — so the two
    engines agree single-step whenever the host's candidate budget does
    not bind (the batched search is always exhaustive: it is vectorized,
    a budget would save no work).  All scores are integer-valued, hence
    exact in float32.

Replica sharding
    The replica axis is embarrassingly parallel: :func:`run_batched`
    splits it across all visible devices via ``jax.sharding``
    (``NamedSharding`` over a 1-D ``replicas`` mesh) whenever more than
    one device is available and ``runs`` divides evenly — results are
    bitwise identical to the single-device run (no cross-replica
    arithmetic happens on device).  Single-device setups are unchanged.

Chunked streaming driver (``chunk_size``)
    By default the whole ``(E_max, R)`` event stream ships to device and
    the whole trace comes back — one program, fastest when it fits.
    :func:`simulate_chunked` (``run_batched(..., chunk_size=c)``) instead
    streams the scan: the carry stays device-resident and is **donated**
    into each chunk (:func:`_scan_chunk`), the host ``device_put``\\ s
    chunk ``k+1`` while chunk ``k`` computes (double-buffered), and each
    chunk's trace is fetched back and concatenated host-side — device
    memory is bounded by ``c``, not ``E_max``.  The carry holds every
    cross-event datum, so chunking is bit-for-bit the monolithic scan at
    any chunk size (golden hashes enforced in
    ``tests/test_chunked_stream.py``), and the carry checkpoints/restores
    through :mod:`repro.checkpoint.ckpt` for bit-exact resume
    (:func:`save_stream_checkpoint` / :func:`load_stream_checkpoint` /
    :func:`init_carry`).

Policies are **compiled from declarative**
:class:`repro.core.policy.PolicySpec` **registry entries** — the same specs
the host engine interprets (:mod:`repro.core.schedulers`), so the two
engines cannot drift by construction.  :func:`_lower_select` lowers a
spec's ordered lexicographic key list to a masked refinement over the
``(M, A)`` feasibility tensor (each key narrows the candidate mask to its
minimizers; the first surviving flat index supplies the implicit
``(gpu, anchor)`` tie-break), with the ΔF table computed only for specs
whose keys ask for it.  The spec itself is the static jit argument, so any
newly registered batched-capable policy runs without touching this module.
Acceptance, utilization, active-GPU and fragmentation-severity metrics
accumulate inside the scan; :func:`run_batched` returns the same aggregate
dict as :func:`repro.sim.simulator.run_many` — demand-grid traces included
for the cumulative protocol.

Parity guarantees vs the Python reference (``tests/test_batched_sim.py``,
``tests/test_heterogeneous.py``, ``tests/test_engine_core.py``):

* single-step decisions of every batched-capable registered policy match
  their host-compiled ``Scheduler.select`` counterparts *exactly*
  (including rejects, tie-breaks and defrag migrations — every scoring-key
  value is integer-valued, hence exact in float32), on homogeneous and
  mixed specs;
* whole-run acceptance rates agree within Monte-Carlo tolerance on the
  steady protocol (the two engines consume their RNG streams differently);
  driving the Python schedulers over the *same* presampled event stream
  matches decision-for-decision (:func:`repro.sim.replay.host_decisions`);
* cumulative-protocol runs consume the *identical* per-replica RNG streams
  as ``run_many`` (seed ``cfg.seed + r * 9973``), so the demand-grid
  traces match the Python simulator to float tolerance on the same stream.

On TPU, per-GPU fragmentation rescoring (the rows each drain/commit
touches, which feed both MFI and the severity metric) routes through the
Pallas ``fragscore`` kernel (``interpret=False``) — homogeneous specs only
(the kernel bakes in one placement table); on CPU and on mixed fleets the
``base``-derived pure-jnp scoring is used.
"""

from __future__ import annotations

import dataclasses
import functools
import time
from typing import Dict, NamedTuple, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import cluster as jcluster
from repro.core import mig
from repro.core.policy import (
    REQUEST_KEYS,
    PolicyLike,
    PolicySpec,
    key_base,
    list_policies,
    queue_order,
    resolve,
)
from repro.sim import distributions
from repro.sim.simulator import (
    SAMPLE_EVERY,
    SimConfig,
    jain_fairness,
    request_probs,
    steady_params,
)

#: batched-capable registered policies at import time (back-compat alias;
#: `repro.core.policy.list_policies(engine="batched")` is the live view)
POLICIES = list_policies(engine="batched")

_BIG = jnp.float32(1e9)


# ---------------------------------------------------------------------------
# Protocol descriptors — static configuration of the measure stage
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Protocol:
    """Static load-protocol descriptor compiled into the scan body.

    ``boundary_metrics`` samples utilization / active-GPU / fragmentation
    at slot boundaries *before* the drain (the steady protocol's
    time-averaged metrics, reduced host-side against the ``sample``
    flags); ``post_metrics`` samples them *after* the commit of every
    event (the cumulative protocol's demand-grid traces); ``queued``
    compiles the wait-ring stages into the step (the ``steady-queued``
    protocol: rejected arrivals park in a fixed-capacity wait ring with a
    patience budget and re-enter selection ahead of later arrivals — see
    :meth:`EngineCore._stage_wait`).  ``faulted`` (implies ``queued``)
    additionally compiles the fault stage: presampled GPU fail/recover
    lanes mask GPUs out of feasibility, evict their live expiry-ring
    entries into the wait ring, and patience overruns re-arm with
    exponential backoff instead of dropping — up to ``fault_retries``
    re-queues of ``fault_backoff * 2**(k-1)`` slots each (see
    :meth:`EngineCore._stage_fault` and ``docs/FAULTS.md``).  Instances
    are frozen/hashable so a protocol doubles as a jit static argument.
    """

    name: str
    boundary_metrics: bool
    post_metrics: bool
    queued: bool = False
    faulted: bool = False
    fault_retries: int = 2
    fault_backoff: int = 2


PROTOCOLS: Dict[str, Protocol] = {
    "steady": Protocol("steady", boundary_metrics=True, post_metrics=False),
    "cumulative": Protocol("cumulative", boundary_metrics=False, post_metrics=True),
    "steady-queued": Protocol(
        "steady-queued", boundary_metrics=True, post_metrics=False, queued=True
    ),
    "steady-faulted": Protocol(
        "steady-faulted", boundary_metrics=True, post_metrics=False,
        queued=True, faulted=True,
    ),
}


def resolve_protocol(protocol: Union[str, Protocol]) -> Protocol:
    """Name-or-descriptor -> :class:`Protocol` (single validation path)."""
    if isinstance(protocol, Protocol):
        return protocol
    if protocol not in PROTOCOLS:
        raise ValueError(
            f"unknown protocol {protocol!r}; options: {tuple(sorted(PROTOCOLS))}"
        )
    return PROTOCOLS[protocol]


# ---------------------------------------------------------------------------
# Stacked per-model placement tables
# ---------------------------------------------------------------------------


class SpecTables(NamedTuple):
    """Per-model placement tables of a ClusterSpec, stacked and padded.

    Axis glossary: ``K`` distinct models, ``N`` common (padded) placement
    count, ``A`` common (padded) anchor count, ``P`` demand classes,
    ``S`` memory slices.  Padded placement rows have all-zero windows and
    ``V = 0`` so they never count toward any score; padded anchor columns
    are marked invalid in ``profile_valid``.
    """

    W: jax.Array               # (K, N, S) float32 — placement windows
    V: jax.Array               # (K, N) float32 — window sizes (0 where padded)
    slices: jax.Array          # (K,) int32 — memory slices per model
    profile_rows: jax.Array    # (K, P, A) int32 — row into W/V per anchor
    profile_masks: jax.Array   # (K, P, A, S) int32 — anchor window bitmask
    profile_anchors: jax.Array  # (K, P, A) int32 — anchor index (-1 pad)
    profile_valid: jax.Array   # (K, P, A) bool — anchor validity
    profile_mem: jax.Array     # (K, P) float32 — slice demand per class
    maskwin: jax.Array         # (K, P, A, N) float32 — slices each anchor adds per window
    maskpos: jax.Array         # (K, P, A, N) float32 — (maskwin > 0)


@functools.lru_cache(maxsize=None)
def spec_tables(spec: mig.ClusterSpec) -> SpecTables:
    """Build (and cache) the stacked device tables of a cluster spec."""
    models = spec.models
    K = len(models)
    P = mig.NUM_PROFILES
    N = max(m.num_placements for m in models)
    A = max(m.max_anchors for m in models)
    S = spec.num_mem_slices

    W = np.zeros((K, N, S), np.float32)
    V = np.zeros((K, N), np.float32)
    slices = np.array([m.num_mem_slices for m in models], np.int32)
    rows_t = np.zeros((K, P, A), np.int32)
    masks_t = np.zeros((K, P, A, S), np.int32)
    anchors_t = np.full((K, P, A), -1, np.int32)
    valid_t = np.zeros((K, P, A), bool)
    mem_t = np.zeros((K, P), np.float32)
    for k, m in enumerate(models):
        n = m.num_placements
        W[k, :n, : m.num_mem_slices] = m.placement_masks
        V[k, :n] = m.placement_mem
        pm, pa, pv = jcluster._np_profile_tables(m, max_anchors=A)
        masks_t[k, :, :, : m.num_mem_slices] = pm
        anchors_t[k] = pa
        valid_t[k] = pv
        mem_t[k] = m.profile_mem
        for pid in range(P):
            s = m.profile_placement_rows(pid)
            rows_t[k, pid, : s.stop - s.start] = np.arange(s.start, s.stop)
    # occupied-slice count each profile anchor adds to every placement window
    maskwin = np.einsum("kpas,kns->kpan", masks_t.astype(np.float32), W)
    # the cache may be populated from inside a jit trace (e.g. `_simulate`
    # building its default tables): force concrete device arrays so no
    # tracer ever escapes into the cache
    with jax.ensure_compile_time_eval():
        return SpecTables(
            W=jnp.asarray(W),
            V=jnp.asarray(V),
            slices=jnp.asarray(slices),
            profile_rows=jnp.asarray(rows_t),
            profile_masks=jnp.asarray(masks_t),
            profile_anchors=jnp.asarray(anchors_t),
            profile_valid=jnp.asarray(valid_t),
            profile_mem=jnp.asarray(mem_t),
            maskwin=jnp.asarray(maskwin),
            maskpos=jnp.asarray((maskwin > 0).astype(np.float32)),
        )


def _default_spec(num_gpus: int) -> mig.ClusterSpec:
    return mig.ClusterSpec.homogeneous(mig.A100_80GB, num_gpus)


# ---------------------------------------------------------------------------
# Fragmentation scoring from the window-count state
# ---------------------------------------------------------------------------


def _frag_from_base(base: jax.Array, free: jax.Array, metric: str, v: jax.Array) -> jax.Array:
    """F(m) per GPU from window counts ``base (M, N)`` and per-GPU window
    sizes ``v (M, N)`` (= ``V[midx]``): (M,) float32."""
    if metric == "partial":
        counted = (base > 0) & (base < v)
    else:  # blocked
        counted = base > 0
    eligible = v <= free[..., None].astype(jnp.float32)
    return jnp.sum(jnp.where(counted & eligible, v, 0.0), axis=-1)


def _delta_from_base(
    base: jax.Array,
    free: jax.Array,
    metric: str,
    v: jax.Array,
    mw: jax.Array,
    mp: jax.Array,
    mem_g: jax.Array,
    f_before: jax.Array,
) -> jax.Array:
    """ΔF of every anchor dry-run of the request: (M, A) float32.

    ``v (M, N)``, ``mw/mp (M, A, N)`` and ``mem_g (M,)`` are the per-GPU
    gathers ``V[midx]``, ``maskwin/maskpos[midx, pid]`` and
    ``profile_mem[midx, pid]``.  Window counts after placement are
    ``base + mw`` (exact for feasible placements — the window is disjoint
    from current occupancy), so for the "blocked" metric the
    counted-predicate decomposes as ``(base > 0) | (mw > 0)`` and the whole
    (M, A) table reduces to one batched (M, N) × (M, N, A) matmul;
    "partial" needs the dense (M, A, N) elementwise form.  All scores are
    integer-valued — exact in float32.
    """
    freef = free.astype(jnp.float32)
    free_after = freef - mem_g  # (M,) — same for every anchor
    elig = v <= free_after[:, None]  # (M, N)
    if metric == "partial":
        ba = base[:, None, :] + mw  # (M, A, N)
        counted = (ba > 0) & (ba < v[:, None, :])
        f_after = jnp.sum(
            jnp.where(counted & elig[:, None, :], v[:, None, :], 0.0), axis=-1
        )
    else:  # blocked: counted_after = (base > 0) | (mw > 0)
        cb = base > 0  # (M, N)
        s_occ = jnp.sum(jnp.where(cb & elig, v, 0.0), axis=-1)  # (M,)
        cross = jnp.einsum("mn,man->ma", jnp.where(~cb & elig, v, 0.0), mp)  # (M, A)
        f_after = s_occ[:, None] + cross
    return f_after - f_before[:, None]


def _delta_from_base_all(
    base: jax.Array,
    free: jax.Array,
    metric: str,
    v: jax.Array,
    mw_all: jax.Array,
    mp_all: jax.Array,
    mem_all: jax.Array,
    f_before: jax.Array,
) -> jax.Array:
    """ΔF of every anchor dry-run of EVERY demand class: (P, M, A) float32.

    The class-batched form of :func:`_delta_from_base` — ``mw_all/mp_all
    (P, M, A, N)`` and ``mem_all (P, M)`` carry a leading class axis and the
    whole table is one batched einsum over it (no per-class Python loop).
    Bitwise identical to stacking the per-class calls: every contraction
    sums the same integer-valued float32 terms.
    """
    freef = free.astype(jnp.float32)
    free_after = freef[None, :] - mem_all           # (P, M)
    elig = v[None] <= free_after[..., None]         # (P, M, N)
    if metric == "partial":
        ba = base[None, :, None, :] + mw_all        # (P, M, A, N)
        counted = (ba > 0) & (ba < v[None, :, None, :])
        f_after = jnp.sum(
            jnp.where(counted & elig[:, :, None, :], v[None, :, None, :], 0.0),
            axis=-1,
        )
    else:  # blocked: counted_after = (base > 0) | (mw > 0)
        cb = base > 0                               # (M, N)
        s_occ = jnp.sum(jnp.where(cb[None] & elig, v[None], 0.0), axis=-1)  # (P, M)
        cross = jnp.einsum(
            "pmn,pman->pma", jnp.where(~cb[None] & elig, v[None], 0.0), mp_all
        )  # (P, M, A)
        f_after = s_occ[..., None] + cross
    return f_after - f_before[None, :, None]


def make_frag_fn(
    metric: str = "blocked",
    use_kernel: bool = False,
    model: mig.DeviceModel = mig.A100_80GB,
    interpret: Optional[bool] = None,
):
    """(N, S) occupancy -> (N,) F scores; Pallas kernel when ``use_kernel``
    (``interpret`` defaults to interpret mode off-TPU)."""
    if use_kernel:
        from repro.kernels.fragscore import fragscore as _k

        w = jnp.asarray(model.placement_masks, dtype=jnp.float32)
        v = jnp.asarray(model.placement_mem, dtype=jnp.float32)
        interp = (jax.default_backend() != "tpu") if interpret is None else interpret
        return lambda occ: _k.fragscore(occ, w, v, metric=metric, interpret=interp)
    tables = jcluster.tables_for(model)
    return functools.partial(jcluster.frag_scores, metric=metric, tables=tables)


def make_delta_fn(
    spec: mig.ClusterSpec,
    metric: str = "blocked",
    interpret: Optional[bool] = None,
):
    """Fused Pallas ΔF dispatch: ``(base, free, f, pid) -> (M, A)``.

    Lowers the engine's dry-run ΔF table to the
    :func:`repro.kernels.fragscore.fragscore.delta_from_base` kernel with
    **per-model dispatch**: one launch per distinct
    :class:`~repro.core.mig.DeviceModel` of ``spec`` (the group's GPU ids
    are static, so each launch sees one placement table with static
    shapes), scattered back into the padded ``(M, A)`` layout the
    masked-refinement select consumes.  This is how ``use_kernel`` works on
    *mixed* fleets — the occupancy-based ``fragscore`` kernel still
    requires a homogeneous spec (it bakes in one table), but the ΔF path
    only needs per-group window counts.  ``interpret`` defaults to
    interpret mode off-TPU (CPU validation).
    """
    from repro.kernels.fragscore import fragscore as _k

    tables = spec_tables(spec)
    groups = spec.model_groups()  # static (model, numpy GPU-id array) pairs
    a = int(tables.profile_rows.shape[-1])
    interp = (jax.default_backend() != "tpu") if interpret is None else interpret

    def delta_fn(base, free, f, pid):
        out = jnp.zeros((base.shape[0], a), jnp.float32)
        for k, (_, rows) in enumerate(groups):
            ridx = jnp.asarray(rows)
            d = _k.delta_from_base(
                base[ridx],
                free[ridx],
                tables.V[k],
                tables.maskwin[k, pid],
                tables.maskpos[k, pid],
                tables.profile_mem[k, pid],
                f[ridx],
                metric=metric,
                interpret=interp,
            )
            out = out.at[ridx].set(d)
        return out

    return delta_fn


def _effective_keys(pspec: PolicySpec):
    """Static ``((base, sign), …)`` kernel encoding of a spec's keys.

    Request-scoped keys (:data:`~repro.core.policy.REQUEST_KEYS` bases) are
    constant over one request's candidate table — they never narrow the
    refinement and never vary a winner-key comparison — so the fused
    kernels drop them (``PolicySpec.argmin_fusable`` guarantees everything
    else packs).
    """
    return tuple(
        (key_base(k), -1.0 if k.startswith("-") else 1.0)
        for k in pspec.keys
        if key_base(k) not in REQUEST_KEYS
    )


def _lex_pick_rows(cand: jax.Array, l: int):
    """Merge fused-select winner rows ``(ΣT, L+3)`` to ``(gpu, col, ok)``.

    Rows are ``[signed keys…, gpu, col, ok]`` per tile (keys BIG when not
    ok); the lexicographic refinement over ``(keys…, gpu, col)`` reproduces
    :func:`_lower_select`'s total order — within a tile the kernel already
    resolved ties by ascending ``(gpu, col)``, and across tiles/groups the
    explicit gpu/col columns do.  All-infeasible events resolve to
    ``(0, 0, False)``, exactly like the jnp lowering.
    """
    ok = cand[:, l + 2] > 0
    mask = ok
    for i in range(l + 2):
        masked = jnp.where(mask, cand[:, i], _BIG)
        mask = mask & (masked == masked.min())
    j = jnp.argmax(mask)
    any_ok = ok.any()
    gpu = jnp.where(any_ok, cand[j, l], 0.0).astype(jnp.int32)
    col = jnp.where(any_ok, cand[j, l + 1], 0.0).astype(jnp.int32)
    return gpu, col, any_ok


def make_select_fn(
    spec: mig.ClusterSpec,
    pspec: PolicySpec,
    metric: str = "blocked",
    interpret: Optional[bool] = None,
):
    """Fused Pallas select dispatch: ``(base, free, f, pid) -> (gpu, aidx, ok)``.

    Lowers the whole select stage — ΔF table *and* the masked lexicographic
    argmin — to :func:`repro.kernels.fragscore.fragscore.select_from_base`
    with per-model dispatch over ``spec.model_groups()`` (one launch per
    distinct :class:`~repro.core.mig.DeviceModel`, padded H200-141GB
    included).  Each launch returns only per-tile winner rows; the
    ``(M, A)`` score table never round-trips through HBM.  Requires
    ``pspec.argmin_fusable`` (every key base packable in-kernel).
    """
    from repro.kernels.fragscore import fragscore as _k

    tables = spec_tables(spec)
    groups = spec.model_groups()
    keys = _effective_keys(pspec)
    l = len(keys)
    interp = (jax.default_backend() != "tpu") if interpret is None else interpret
    arange_n = jnp.arange(int(tables.V.shape[-1]), dtype=jnp.int32)

    def select_fn(base, free, f, pid):
        cand = []
        for k, (_, rows) in enumerate(groups):
            ridx = jnp.asarray(rows)
            rowsel = tables.profile_rows[k, pid][None, :] == arange_n[:, None]
            cand.append(
                _k.select_from_base(
                    base[ridx],
                    free[ridx],
                    f[ridx],
                    jnp.asarray(rows, dtype=jnp.float32),
                    tables.V[k],
                    tables.maskwin[k, pid],
                    tables.maskpos[k, pid],
                    tables.profile_mem[k, pid],
                    rowsel,
                    tables.profile_valid[k, pid],
                    tables.profile_anchors[k, pid],
                    keys=keys,
                    metric=metric,
                    interpret=interp,
                )
            )
        return _lex_pick_rows(jnp.concatenate(cand, axis=0), l)

    return select_fn


def _merge_top2(cand: jax.Array, l: int):
    """Merge fused migrate candidate pairs ``(P, Q, L+3)`` to per-class
    best + runner-up.

    The cross-tile form of :func:`_lex_top2`: candidates compare by
    ``(keys…, gpu)`` (the kernel resolved in-tile row ties by ascending
    gpu, and gpu values are globally unique across tiles/groups), and the
    runner-up excludes the best row's *gpu* — guarded on ``ok1`` so an
    all-infeasible class keeps the jnp path's ``(0, False)`` shape.
    Returns ``(g1, ok1, a1, k1, g2, ok2, a2, k2)``.
    """
    ok = cand[..., l + 2] > 0                  # (P, Q)
    gpu = cand[..., l]                         # (P, Q) float gpu values
    pa = jnp.arange(cand.shape[0])

    def best(mask):
        for i in range(l):
            masked = jnp.where(mask, cand[..., i], _BIG)
            mask = mask & (masked == masked.min(axis=-1, keepdims=True))
        masked = jnp.where(mask, gpu, _BIG)
        mask = mask & (masked == masked.min(axis=-1, keepdims=True))
        j = jnp.argmax(mask, axis=-1)          # (P,)
        okb = mask.any(axis=-1)
        g = jnp.where(okb, gpu[pa, j], 0.0).astype(jnp.int32)
        aw = jnp.where(okb, cand[pa, j, l + 1], 0.0).astype(jnp.int32)
        return g, okb, aw, cand[pa, j, :l]

    g1, ok1, a1, k1 = best(ok)
    excl = ok & (~ok1[:, None] | (gpu != g1.astype(jnp.float32)[:, None]))
    g2, ok2, a2, k2 = best(excl)
    return g1, ok1, a1, k1, g2, ok2, a2, k2


def make_migrate_fn(
    spec: mig.ClusterSpec,
    pspec: PolicySpec,
    metric: str = "blocked",
    interpret: Optional[bool] = None,
):
    """Fused Pallas migrate-search dispatch for defrag specs.

    Returns ``migrate_fn(base, free, f, base2, free2, f2, rg, rp, kc)``
    producing the per-class top-2 untouched rows *and* the per-victim
    patched-row refinements that :func:`_migrate_search` consumes —
    ``(g1, ok1, a1, k1, g2, ok2, a2, k2, ap, okp, kp)``.  One
    :func:`repro.kernels.fragscore.fragscore.migrate_refine` launch per
    model group; the per-victim pass rides as grid pass 1 of the first
    group's launch (victims gather their own tables per row, so one pass
    covers every victim on any fleet).
    """
    from repro.kernels.fragscore import fragscore as _k

    tables = spec_tables(spec)
    groups = spec.model_groups()
    keys = _effective_keys(pspec)
    l = len(keys)
    p_ = int(tables.profile_rows.shape[1])
    interp = (jax.default_backend() != "tpu") if interpret is None else interpret
    arange_n = jnp.arange(int(tables.V.shape[-1]), dtype=jnp.int32)
    # (K, P, N, A) one-hot feasibility gathers — static per spec
    rowsel_all = (
        tables.profile_rows[:, :, None, :] == arange_n[None, None, :, None]
    ).astype(jnp.float32)

    def migrate_fn(base, free, f, base2, free2, f2, rg, rp, kc):
        vrowsel = (
            tables.profile_rows[kc, rp][:, None, :] == arange_n[None, :, None]
        ).astype(jnp.float32)                  # (C, N, A)
        victims = (
            base2, free2, f2, rg.astype(jnp.float32),
            tables.V[kc],
            tables.maskwin[kc, rp], tables.maskpos[kc, rp],
            tables.profile_mem[kc, rp], vrowsel,
            tables.profile_valid[kc, rp], tables.profile_anchors[kc, rp],
        )
        cands, out1 = [], None
        for k, (_, rows) in enumerate(groups):
            ridx = jnp.asarray(rows)
            o0, o1 = _k.migrate_refine(
                base[ridx],
                free[ridx],
                f[ridx],
                jnp.asarray(rows, dtype=jnp.float32),
                tables.V[k],
                tables.maskwin[k],
                tables.maskpos[k],
                tables.profile_mem[k],
                rowsel_all[k],
                tables.profile_valid[k],
                tables.profile_anchors[k],
                victims if k == 0 else None,
                keys=keys,
                metric=metric,
                interpret=interp,
            )
            if o1 is not None:
                out1 = o1
            t0 = o0.shape[0]                   # (T0, P, 2·(L+3)) → (P, 2·T0, L+3)
            cands.append(
                jnp.transpose(o0.reshape(t0, p_, 2, l + 3), (1, 0, 2, 3))
                .reshape(p_, 2 * t0, l + 3)
            )
        merged = _merge_top2(jnp.concatenate(cands, axis=1), l)
        ap = out1[:, l].astype(jnp.int32)
        okp = out1[:, l + 1] > 0
        return merged + (ap, okp, out1[:, :l])

    return migrate_fn


# ---------------------------------------------------------------------------
# PolicySpec lowering: lexicographic keys -> masked refinement argmin
# ---------------------------------------------------------------------------


def _key_tensor(base_key, feasible, free, mem_g, delta, anchors_g, cursor, midx):
    """One scoring key as an (M, A)-broadcastable float32 tensor.

    All key values are integer-valued (ΔF included — see
    :func:`_delta_from_base`), hence exact in float32: the refinement's
    equality comparisons are exact and the lowering matches the host
    interpreter bit-for-bit.
    """
    m, a = feasible.shape
    if base_key == "frag-delta":
        return delta  # (M, A)
    if base_key == "free-slices":
        return (free.astype(jnp.float32) - mem_g)[:, None]  # (M, 1)
    if base_key == "gpu":
        return jnp.arange(m, dtype=jnp.float32)[:, None]
    if base_key == "anchor":
        # real anchor VALUES (``profile_anchors[midx, pid]``), not padded
        # column indexes: on mixed fleets the index<->value mapping differs
        # per model, and the host interpreter compares values — padded
        # (-1) columns are masked infeasible so they never win
        return anchors_g.astype(jnp.float32)  # (M, A)
    if base_key == "rr-distance":
        prio = jnp.mod(jnp.arange(m, dtype=jnp.int32) - cursor, m)
        return prio.astype(jnp.float32)[:, None]
    if base_key == "model-group":
        return midx.astype(jnp.float32)[:, None]
    if base_key in ("tenant", "priority", "wait-age"):
        # request-scoped keys are constant over one request's candidates —
        # a zero tensor never changes the refinement.  Their semantics are
        # cross-request (the wait ring's queue order, policy.queue_order).
        return jnp.zeros((1, 1), jnp.float32)
    raise ValueError(f"unknown scoring key {base_key!r}")  # unreachable


def _lower_select(spec, feasible, free, mem_g, delta, anchors_g, cursor, midx):
    """Compile a spec's key list against the (M, A) feasibility tensor.

    Each key narrows the candidate mask to its minimizers (``-`` prefix
    negates); the first surviving flat index supplies the implicit
    ascending ``(gpu, anchor)`` tie-break — the same total order the host
    interpreter's lexsort produces.  Returns ``(gpu, aidx, ok)``.
    """
    mask = feasible
    for key in spec.keys:
        val = _key_tensor(
            key_base(key), feasible, free, mem_g, delta, anchors_g, cursor, midx
        )
        if key.startswith("-"):
            val = -val
        masked = jnp.where(mask, val, _BIG)
        mask = mask & (masked == masked.min())
    flat = mask.reshape(-1)
    k = jnp.argmax(flat)
    a = feasible.shape[1]
    return k // a, k % a, flat[k]


def _feasibility(base: jax.Array, rows: jax.Array, valid: jax.Array) -> jax.Array:
    """(M, A) bool — anchors whose window has zero occupied slices.

    ``rows (M, A)`` / ``valid (M, A)`` are the per-GPU gathers
    ``profile_rows[midx, pid]`` / ``profile_valid[midx, pid]``.
    """
    overlap = jnp.take_along_axis(base, rows, axis=1)  # (M, A)
    return (overlap == 0) & valid


def _select(spec, base, free, f, metric, tables, midx, vg, pid, cursor,
            delta_fn=None, select_fn=None, gpu_ok=None):
    """Shared decision path: returns (gpu, aidx, ok) for one request.

    ``delta_fn`` (from :func:`make_delta_fn`) routes the ΔF table through
    the fused Pallas kernel; ``select_fn`` (from :func:`make_select_fn`)
    goes further and runs the whole stage — ΔF *and* the masked
    lexicographic argmin — in fused per-model launches; ``None`` uses the
    pure-jnp lowering.  ``gpu_ok`` is an optional (M,) bool availability
    mask (faulted protocols: down GPUs are infeasible regardless of
    occupancy); ``None`` compiles the mask out entirely.
    """
    if select_fn is not None:
        return select_fn(base, free, f, pid)
    rows = tables.profile_rows[midx, pid]  # (M, A)
    valid = tables.profile_valid[midx, pid]  # (M, A)
    mem_g = tables.profile_mem[midx, pid]  # (M,)
    anchors_g = tables.profile_anchors[midx, pid]  # (M, A), -1 where padded
    feasible = _feasibility(base, rows, valid)
    if gpu_ok is not None:
        feasible = feasible & gpu_ok[:, None]
    if spec.requires_delta_f:  # ΔF table only for specs whose keys use it
        if delta_fn is not None:
            delta = delta_fn(base, free, f, pid)
        else:
            delta = _delta_from_base(
                base, free, metric, vg,
                tables.maskwin[midx, pid], tables.maskpos[midx, pid], mem_g, f,
            )
    else:
        delta = None
    return _lower_select(spec, feasible, free, mem_g, delta, anchors_g, cursor, midx)


# ---------------------------------------------------------------------------
# Row-wise / grid-wise refinement variants (the migrate stage's selections)
# ---------------------------------------------------------------------------


def _key_rows(base_key, free, mem_g, delta, anchors_g, cursor, gidx, kidx, num_gpus):
    """One scoring key as a (C, A)-broadcastable tensor for *per-row*
    selection: row ``c`` is an independent single-GPU candidate whose GPU
    index is ``gidx[c]`` and model index ``kidx[c]``."""
    if base_key == "frag-delta":
        return delta  # (C, A)
    if base_key == "free-slices":
        return (free.astype(jnp.float32) - mem_g)[:, None]  # (C, 1)
    if base_key == "gpu":
        return gidx.astype(jnp.float32)[:, None]
    if base_key == "anchor":
        return anchors_g.astype(jnp.float32)  # (C, A)
    if base_key == "rr-distance":
        prio = jnp.mod(gidx.astype(jnp.int32) - cursor, num_gpus)
        return prio.astype(jnp.float32)[:, None]
    if base_key == "model-group":
        return kidx.astype(jnp.float32)[:, None]
    if base_key in ("tenant", "priority", "wait-age"):
        return jnp.zeros((1, 1), jnp.float32)  # request-scoped: constant per request
    raise ValueError(f"unknown scoring key {base_key!r}")  # unreachable


def _refine_rows(spec, feasible, free, mem_g, delta, anchors_g, cursor, gidx,
                 kidx, num_gpus, return_keys=False):
    """Per-row spec selection: one independent argmin along the anchor axis
    of every row of ``feasible (C, A)``.  Returns ``(aidx (C,), ok (C,))``.

    Equivalent to the host interpreter's full select when each row's
    feasible set is confined to its own GPU (GPU-keyed scores are constant
    per row, so only anchor-varying keys act; the implicit ascending-anchor
    tie-break is the first surviving column).

    With ``return_keys`` additionally returns the winner's key values
    ``(C, L)`` (direction prefix applied) — the row's representative in a
    cross-row lexicographic comparison: the grid-wide lex-min equals the
    lex-min over per-row winners compared by ``(keys…, gpu)``, which is
    what the factored migrate search exploits.
    """
    mask = feasible
    vals = []
    for key in spec.keys:
        val = _key_rows(
            key_base(key), free, mem_g, delta, anchors_g, cursor, gidx, kidx,
            num_gpus,
        )
        if key.startswith("-"):
            val = -val
        if return_keys:
            vals.append(jnp.broadcast_to(val, feasible.shape))
        masked = jnp.where(mask, val, _BIG)
        mask = mask & (masked == masked.min(axis=-1, keepdims=True))
    aidx = jnp.argmax(mask, axis=-1)
    ok = mask.any(axis=-1)
    if not return_keys:
        return aidx, ok
    keys = jnp.stack(
        [jnp.take_along_axis(v, aidx[:, None], axis=1)[:, 0] for v in vals],
        axis=-1,
    )  # (C, L)
    return aidx, ok, keys


def _key_grid(base_key, free, mem_g, delta, anchors_g, cursor, midx):
    """One scoring key as a (C, M, A)-broadcastable tensor for *batched
    whole-cluster* selection (one independent (gpu, anchor) argmin per
    leading candidate row): ``free/mem_g (C, M)``, ``delta/anchors_g
    (C, M, A)``."""
    m = free.shape[-1]
    if base_key == "frag-delta":
        return delta
    if base_key == "free-slices":
        return (free.astype(jnp.float32) - mem_g)[..., None]  # (C, M, 1)
    if base_key == "gpu":
        return jnp.arange(m, dtype=jnp.float32)[None, :, None]
    if base_key == "anchor":
        return anchors_g.astype(jnp.float32)
    if base_key == "rr-distance":  # pragma: no cover — defrag+rr is rejected
        prio = jnp.mod(jnp.arange(m, dtype=jnp.int32) - cursor, m)
        return prio.astype(jnp.float32)[None, :, None]
    if base_key == "model-group":
        return midx.astype(jnp.float32)[None, :, None]
    if base_key in ("tenant", "priority", "wait-age"):
        return jnp.zeros((1, 1, 1), jnp.float32)  # request-scoped: constant per request
    raise ValueError(f"unknown scoring key {base_key!r}")  # unreachable


def _refine_grid(spec, feasible, free, mem_g, delta, anchors_g, cursor, midx):
    """Batched whole-cluster spec selection: an independent ``(gpu, anchor)``
    argmin over the trailing (M, A) axes of every leading row of
    ``feasible (C, M, A)``.  Returns ``(gpu (C,), aidx (C,), ok (C,))`` —
    the same total order :func:`_lower_select` produces, per row.
    """
    mask = feasible
    for key in spec.keys:
        val = _key_grid(
            key_base(key), free, mem_g, delta, anchors_g, cursor, midx
        )
        if key.startswith("-"):
            val = -val
        masked = jnp.where(mask, val, _BIG)
        mask = mask & (masked == masked.min(axis=(-2, -1), keepdims=True))
    a = feasible.shape[-1]
    flat = mask.reshape(mask.shape[:-2] + (-1,))
    k = jnp.argmax(flat, axis=-1)
    return k // a, k % a, flat.any(axis=-1)


# ---------------------------------------------------------------------------
# Migrate stage: the batched single-migration defrag search
# ---------------------------------------------------------------------------


class MigrationResult(NamedTuple):
    """Chosen migration of one event (all entries masked by ``mig``)."""

    mig: jax.Array            # () bool — a migration was committed
    gpu: jax.Array            # () int32 — request GPU (= victim's old GPU)
    aidx: jax.Array           # () int32 — request anchor index
    vic_row: jax.Array        # () int32 — victim's ring row
    vic_col: jax.Array        # () int32 — victim's ring column
    vic_gpu: jax.Array        # () int32 — victim's old GPU
    vic_anchor: jax.Array     # () int32 — victim's old anchor value
    vic_pid: jax.Array        # () int32 — victim's demand class
    new_gpu: jax.Array        # () int32 — victim's new GPU
    new_aidx: jax.Array       # () int32 — victim's new anchor index
    new_anchor: jax.Array     # () int32 — victim's new anchor value
    old_mask: jax.Array       # (S,) int32 — victim's old window bitmask
    old_mwin: jax.Array       # (N,) float32 — window counts the old mask held
    new_mask: jax.Array       # (S,) int32 — victim's new window bitmask
    new_mwin: jax.Array       # (N,) float32 — window counts the new mask adds


def _migrate_search_dense(
    spec: PolicySpec,
    metric: str,
    tables: SpecTables,
    midx: jax.Array,
    vg: jax.Array,
    base: jax.Array,
    free: jax.Array,
    f: jax.Array,
    ring_gpu: jax.Array,
    ring_mask: jax.Array,
    ring_pid: jax.Array,
    ring_aidx: jax.Array,
    pid_c: jax.Array,
    cursor: jax.Array,
    want: jax.Array,
) -> MigrationResult:
    """Reference dense form of the single-migration search.

    Materializes the full victim × cluster ``(C, M, A)`` re-placement grid
    (``C`` = every ring slot, dead ones included) and lex-refines it per
    victim — the semantics :func:`_migrate_search` factors into
    ``O(P·M·A + C_live·A)`` work.  Kept as the oracle for the
    factored-vs-dense equivalence test; not used on the engine hot path.
    """
    num_gpus = midx.shape[0]
    rows, cols = ring_gpu.shape
    c = rows * cols
    rg = ring_gpu.reshape(c)                       # (C,) victim gpu
    rm = ring_mask.reshape(c, ring_mask.shape[-1])  # (C, S) victim window
    rp = ring_pid.reshape(c)                       # (C,) victim class
    ra = ring_aidx.reshape(c)                      # (C,) victim anchor index
    present = rm.sum(axis=1) > 0                   # live entries only
    kc = midx[rg]                                  # (C,) victim model index
    vgc = vg[rg]                                   # (C, N) window sizes

    # -- evacuate the victim from its own GPU -------------------------------
    mwin_vic = tables.maskwin[kc, rp, ra]          # (C, N)
    mem_vic = rm.sum(axis=1)                       # (C,) int32
    base_v = base[rg] - mwin_vic                   # (C, N)
    free_v = free[rg] + mem_vic                    # (C,)
    f_v = _frag_from_base(base_v, free_v, metric, vgc)  # (C,)

    # -- re-select the request on the freed GPU -----------------------------
    rows_req = tables.profile_rows[kc, pid_c]      # (C, A)
    valid_req = tables.profile_valid[kc, pid_c]    # (C, A)
    mem_req = tables.profile_mem[kc, pid_c]        # (C,) float32
    anchors_req = tables.profile_anchors[kc, pid_c]  # (C, A)
    overlap_req = jnp.take_along_axis(base_v, rows_req, axis=1)
    feas_req = (overlap_req == 0) & valid_req
    if spec.requires_delta_f:
        delta_req = _delta_from_base(
            base_v, free_v, metric, vgc,
            tables.maskwin[kc, pid_c], tables.maskpos[kc, pid_c],
            mem_req, f_v,
        )
    else:
        delta_req = None
    aidx_req, ok_req = _refine_rows(
        spec, feas_req, free_v, mem_req, delta_req, anchors_req, cursor,
        rg, kc, num_gpus,
    )

    # -- place the request, then re-place the victim anywhere ---------------
    take = lambda t, i: jnp.take_along_axis(  # noqa: E731 — (C, A, ...) @ (C,)
        t, i[:, None, None] if t.ndim == 3 else i[:, None], axis=1
    )[:, 0]
    mask_req = take(tables.profile_masks[kc, pid_c], aidx_req)   # (C, S)
    mwin_req = take(tables.maskwin[kc, pid_c], aidx_req)         # (C, N)
    base2 = base_v + mwin_req                                    # (C, N)
    free2 = free_v - mask_req.sum(axis=1)                        # (C,)
    f2 = _frag_from_base(base2, free2, metric, vgc)              # (C,)

    # whole-cluster tables for the victim's class, with the victim's own
    # GPU row patched to the post-evacuation/post-request state
    rows_all = jnp.transpose(tables.profile_rows[midx], (1, 0, 2))      # (P, M, A)
    valid_all = jnp.transpose(tables.profile_valid[midx], (1, 0, 2))    # (P, M, A)
    anchors_all = jnp.transpose(tables.profile_anchors[midx], (1, 0, 2))
    mem_all = jnp.transpose(tables.profile_mem[midx], (1, 0))           # (P, M)
    overlap_all = jnp.take_along_axis(base[None], rows_all, axis=2)     # (P, M, A)
    feas_all = (overlap_all == 0) & valid_all

    rows_vic = tables.profile_rows[kc, rp]         # (C, A)
    valid_vic = tables.profile_valid[kc, rp]       # (C, A)
    overlap_patch = jnp.take_along_axis(base2, rows_vic, axis=1)
    feas_patch = (overlap_patch == 0) & valid_vic  # (C, A)
    onehot = jnp.arange(num_gpus)[None, :] == rg[:, None]  # (C, M)
    feas_grid = jnp.where(onehot[:, :, None], feas_patch[:, None, :], feas_all[rp])
    free_grid = jnp.where(onehot, free2[:, None], free[None, :])        # (C, M)
    mem_grid = mem_all[rp]                                              # (C, M)
    anchors_grid = anchors_all[rp]                                      # (C, M, A)
    if spec.requires_delta_f:
        mw_all = jnp.transpose(tables.maskwin[midx], (1, 0, 2, 3))      # (P, M, A, N)
        mp_all = jnp.transpose(tables.maskpos[midx], (1, 0, 2, 3))
        delta_all = jnp.stack(  # ΔF per class on the untouched cluster
            [
                _delta_from_base(
                    base, free, metric, vg, mw_all[p], mp_all[p],
                    mem_all[p], f,
                )
                for p in range(mig.NUM_PROFILES)
            ]
        )  # (P, M, A)
        delta_patch = _delta_from_base(
            base2, free2, metric, vgc,
            tables.maskwin[kc, rp], tables.maskpos[kc, rp],
            tables.profile_mem[kc, rp], f2,
        )  # (C, A)
        delta_grid = jnp.where(
            onehot[:, :, None], delta_patch[:, None, :], delta_all[rp]
        )
    else:
        delta_grid = None
    new_gpu, new_aidx, ok_vic = _refine_grid(
        spec, feas_grid, free_grid, mem_grid, delta_grid, anchors_grid,
        cursor, midx,
    )

    # -- score: total cluster fragmentation after both moves ----------------
    kv = midx[new_gpu]                                           # (C,)
    idx3 = (kv, rp, new_aidx)
    mask_new = tables.profile_masks[idx3]                        # (C, S)
    mwin_new = tables.maskwin[idx3]                              # (C, N)
    same = new_gpu == rg
    base_gv = jnp.where(same[:, None], base2, base[new_gpu])     # (C, N)
    free_gv = jnp.where(same, free2, free[new_gpu])              # (C,)
    f_gv_before = _frag_from_base(base_gv, free_gv, metric, vg[new_gpu])
    f_gv_after = _frag_from_base(
        base_gv + mwin_new, free_gv - mask_new.sum(axis=1), metric, vg[new_gpu]
    )
    total = f.sum() - f[rg] + f2 + f_gv_after - f_gv_before      # (C,)

    # -- canonical choice: lex-min (total F, victim gpu, victim anchor) -----
    vic_anchor = tables.profile_anchors[kc, rp, ra]              # (C,)
    cmask = present & ok_req & ok_vic & want
    for val in (total, rg.astype(jnp.float32), vic_anchor.astype(jnp.float32)):
        masked = jnp.where(cmask, val, _BIG)
        cmask = cmask & (masked == masked.min())
    j = jnp.argmax(cmask)
    return MigrationResult(
        mig=cmask[j],
        gpu=rg[j],
        aidx=aidx_req[j].astype(jnp.int32),
        vic_row=(j // cols).astype(jnp.int32),
        vic_col=(j % cols).astype(jnp.int32),
        vic_gpu=rg[j],
        vic_anchor=vic_anchor[j],
        vic_pid=rp[j],
        new_gpu=new_gpu[j].astype(jnp.int32),
        new_aidx=new_aidx[j].astype(jnp.int32),
        new_anchor=tables.profile_anchors[kv[j], rp[j], new_aidx[j]],
        old_mask=rm[j],
        old_mwin=mwin_vic[j],
        new_mask=mask_new[j],
        new_mwin=mwin_new[j],
    )


def _lex_top2(keys: jax.Array, ok: jax.Array):
    """Two lexicographically smallest valid columns per leading row.

    ``keys (B, M, L)`` are ordered key vectors (direction already applied),
    ``ok (B, M)`` their validity; remaining ties break by ascending column
    index — duplicate best keys therefore resolve to the two lowest tied
    columns, in order.  The runner-up excludes the winner's column only
    when a winner exists (``ok1``-guarded): an all-infeasible row keeps
    the full (vacuously empty) mask instead of arbitrarily excluding
    column 0, so ``g2`` carries the same ``argmax``-of-empty-mask value
    (0) as ``g1`` rather than depending on the winner's placeholder.  A
    single-valid-column row yields ``ok2 = False``.  Returns
    ``(g1, ok1, g2, ok2)``, each ``(B,)``.
    """
    def best(mask):
        for l in range(keys.shape[-1]):
            masked = jnp.where(mask, keys[..., l], _BIG)
            mask = mask & (masked == masked.min(axis=-1, keepdims=True))
        return jnp.argmax(mask, axis=-1), mask.any(axis=-1)

    g1, ok1 = best(ok)
    m = keys.shape[1]
    excl = ~ok1[:, None] | (jnp.arange(m)[None, :] != g1[:, None])
    g2, ok2 = best(ok & excl)
    return g1, ok1, g2, ok2


def _migrate_search(
    spec: PolicySpec,
    metric: str,
    tables: SpecTables,
    midx: jax.Array,
    vg: jax.Array,
    base: jax.Array,
    free: jax.Array,
    f: jax.Array,
    ring_gpu: jax.Array,
    ring_mask: jax.Array,
    ring_pid: jax.Array,
    ring_aidx: jax.Array,
    pid_c: jax.Array,
    cursor: jax.Array,
    want: jax.Array,
    delta_fn=None,
    migrate_fn=None,
) -> MigrationResult:
    """Factored masked single-migration search over live ring entries.

    For every candidate victim (a running workload): evacuate it, re-select
    the request on the victim's GPU (the only GPU where feasibility can
    have appeared — the arrival was just rejected everywhere), re-place the
    victim anywhere via the spec's keys, and score the candidate by the
    total cluster fragmentation after both moves.  The winner minimizes
    ``(total F, victim gpu, victim anchor)`` — the host search's canonical
    order.  ``want`` gates the whole stage (scalar bool).

    Unlike :func:`_migrate_search_dense` (the reference oracle), the victim
    re-placement never materializes a ``(C, M, A)`` grid.  Evacuating a
    victim perturbs exactly one GPU row, so the re-placement candidates
    split into the *patched* row (the victim's own GPU after evacuation +
    request placement) and ``M - 1`` *untouched* rows shared by every
    victim of the same demand class:

    * once per event, a per-class ``(P, M, A)`` row refinement over the
      untouched cluster reduces each GPU row to its winning anchor + key
      vector, and :func:`_lex_top2` keeps the best and runner-up row per
      class (the runner-up covers victims whose own GPU is the best row) —
      ``O(P·M·A)``, the per-class table today's ``delta_all`` already paid
      for and then re-broadcast;
    * per victim, only its patched row is refined (``O(C_live·A)``) and
      lex-compared against the class's surviving untouched row (the grid
      lex-min equals the min over row winners compared by ``(keys…,
      gpu)``, anchors having been resolved within each row).

    Dead ring slots are compacted away first: the number of *live* entries
    is bounded by the cluster's total slice count (every running workload
    occupies at least one slice), a static budget ``C_live = min(C, M·S)``
    that a stable argsort of the ``present`` mask fills with live entries
    in ring order.  Decisions are bit-for-bit those of the dense search:
    every key value is integer-valued, hence exact in float32, and the
    winner is unique (two live workloads can never share a (gpu, anchor)).
    """
    num_gpus = midx.shape[0]
    rows, cols = ring_gpu.shape
    c_total = rows * cols
    s = ring_mask.shape[-1]
    rg = ring_gpu.reshape(c_total)                 # (C,) victim gpu
    rm = ring_mask.reshape(c_total, s)             # (C, S) victim window
    rp = ring_pid.reshape(c_total)                 # (C,) victim class
    ra = ring_aidx.reshape(c_total)                # (C,) victim anchor index
    present = rm.sum(axis=1) > 0                   # live entries only

    # -- live-candidate compaction: dead ring slots cost nothing ------------
    c_live = min(c_total, num_gpus * s)
    if c_live < c_total:
        live = jnp.argsort(~present)[:c_live]      # stable: live first, ring order
        rg, rm, rp, ra = rg[live], rm[live], rp[live], ra[live]
        present = present[live]
    else:
        live = jnp.arange(c_total, dtype=jnp.int32)
    kc = midx[rg]                                  # (C,) victim model index
    vgc = vg[rg]                                   # (C, N) window sizes

    # -- evacuate the victim from its own GPU -------------------------------
    mwin_vic = tables.maskwin[kc, rp, ra]          # (C, N)
    mem_vic = rm.sum(axis=1)                       # (C,) int32
    base_v = base[rg] - mwin_vic                   # (C, N)
    free_v = free[rg] + mem_vic                    # (C,)
    f_v = _frag_from_base(base_v, free_v, metric, vgc)  # (C,)

    # -- re-select the request on the freed GPU -----------------------------
    rows_req = tables.profile_rows[kc, pid_c]      # (C, A)
    valid_req = tables.profile_valid[kc, pid_c]    # (C, A)
    mem_req = tables.profile_mem[kc, pid_c]        # (C,) float32
    anchors_req = tables.profile_anchors[kc, pid_c]  # (C, A)
    overlap_req = jnp.take_along_axis(base_v, rows_req, axis=1)
    feas_req = (overlap_req == 0) & valid_req
    if spec.requires_delta_f:
        delta_req = _delta_from_base(
            base_v, free_v, metric, vgc,
            tables.maskwin[kc, pid_c], tables.maskpos[kc, pid_c],
            mem_req, f_v,
        )
    else:
        delta_req = None
    aidx_req, ok_req = _refine_rows(
        spec, feas_req, free_v, mem_req, delta_req, anchors_req, cursor,
        rg, kc, num_gpus,
    )

    # -- place the request on the freed GPU ---------------------------------
    take = lambda t, i: jnp.take_along_axis(  # noqa: E731 — (C, A, ...) @ (C,)
        t, i[:, None, None] if t.ndim == 3 else i[:, None], axis=1
    )[:, 0]
    mask_req = take(tables.profile_masks[kc, pid_c], aidx_req)   # (C, S)
    mwin_req = take(tables.maskwin[kc, pid_c], aidx_req)         # (C, N)
    base2 = base_v + mwin_req                                    # (C, N)
    free2 = free_v - mask_req.sum(axis=1)                        # (C,)
    f2 = _frag_from_base(base2, free2, metric, vgc)              # (C,)

    # -- per-class row winners on the untouched cluster (once per event) ----
    # + per-victim patched-row refinement.  The fused ``migrate_fn`` (from
    # :func:`make_migrate_fn`) runs both in per-model Pallas launches —
    # the per-victim pass riding as grid pass 1 of the first — and returns
    # only the reduced rows; the jnp path below materializes the
    # ``(P, M, A)`` tables and reduces them with :func:`_refine_rows` +
    # :func:`_lex_top2`.
    p_ = mig.NUM_PROFILES
    a_ = tables.profile_rows.shape[-1]
    if migrate_fn is not None:
        (g1, ok1, aw1, kw1, g2, ok2, aw2, kw2, ap, okp, kp) = migrate_fn(
            base, free, f, base2, free2, f2, rg, rp, kc
        )
    else:
        rows_all = jnp.transpose(tables.profile_rows[midx], (1, 0, 2))      # (P, M, A)
        valid_all = jnp.transpose(tables.profile_valid[midx], (1, 0, 2))
        anchors_all = jnp.transpose(tables.profile_anchors[midx], (1, 0, 2))
        mem_all = jnp.transpose(tables.profile_mem[midx], (1, 0))           # (P, M)
        overlap_all = jnp.take_along_axis(base[None], rows_all, axis=2)     # (P, M, A)
        feas_all = (overlap_all == 0) & valid_all
        if spec.requires_delta_f:
            if delta_fn is not None:  # fused Pallas ΔF, one launch per class
                delta_all = jnp.stack([delta_fn(base, free, f, p) for p in range(p_)])
            else:
                mw_all = jnp.transpose(tables.maskwin[midx], (1, 0, 2, 3))  # (P, M, A, N)
                mp_all = jnp.transpose(tables.maskpos[midx], (1, 0, 2, 3))
                delta_all = _delta_from_base_all(
                    base, free, metric, vg, mw_all, mp_all, mem_all, f
                )  # (P, M, A)
        else:
            delta_all = None
        aw, okw, kw = _refine_rows(
            spec,
            feas_all.reshape(p_ * num_gpus, a_),
            jnp.tile(free, p_),
            mem_all.reshape(p_ * num_gpus),
            None if delta_all is None else delta_all.reshape(p_ * num_gpus, a_),
            anchors_all.reshape(p_ * num_gpus, a_),
            cursor,
            jnp.tile(jnp.arange(num_gpus, dtype=jnp.int32), p_),
            jnp.tile(midx, p_),
            num_gpus,
            return_keys=True,
        )
        l_ = kw.shape[-1]
        aw = aw.reshape(p_, num_gpus)
        okw = okw.reshape(p_, num_gpus)
        kw = kw.reshape(p_, num_gpus, l_)
        g1, ok1, g2, ok2 = _lex_top2(kw, okw)      # best + runner-up per class
        pa = jnp.arange(p_)
        kw1, aw1 = kw[pa, g1], aw[pa, g1]          # (P, L), (P,)
        kw2, aw2 = kw[pa, g2], aw[pa, g2]

        # -- per victim: refine its patched row -----------------------------
        rows_vic = tables.profile_rows[kc, rp]     # (C, A)
        valid_vic = tables.profile_valid[kc, rp]   # (C, A)
        mem_vic_c = tables.profile_mem[kc, rp]     # (C,) float32
        anchors_vic = tables.profile_anchors[kc, rp]  # (C, A)
        overlap_patch = jnp.take_along_axis(base2, rows_vic, axis=1)
        feas_patch = (overlap_patch == 0) & valid_vic  # (C, A)
        if spec.requires_delta_f:
            delta_patch = _delta_from_base(
                base2, free2, metric, vgc,
                tables.maskwin[kc, rp], tables.maskpos[kc, rp],
                mem_vic_c, f2,
            )  # (C, A)
        else:
            delta_patch = None
        ap, okp, kp = _refine_rows(
            spec, feas_patch, free2, mem_vic_c, delta_patch, anchors_vic,
            cursor, rg, kc, num_gpus, return_keys=True,
        )

    # -- per victim: best untouched row (excluding its own GPU) -------------
    l_ = kw1.shape[-1]
    use2 = g1[rp] == rg                            # own GPU was the best row
    gu = jnp.where(use2, g2[rp], g1[rp])
    oku = jnp.where(use2, ok2[rp], ok1[rp])
    au = jnp.where(use2, aw2[rp], aw1[rp])
    ku = jnp.where(use2[:, None], kw2[rp], kw1[rp])  # (C, L)

    # -- lex-merge the two row winners: (keys…, gpu) ------------------------
    ku_e = jnp.where(oku[:, None], ku, _BIG)
    kp_e = jnp.where(okp[:, None], kp, _BIG)
    lt = jnp.zeros(ku.shape[0], bool)
    eq = jnp.ones(ku.shape[0], bool)
    for l in range(l_):
        lt = lt | (eq & (ku_e[:, l] < kp_e[:, l]))
        eq = eq & (ku_e[:, l] == kp_e[:, l])
    pick_u = oku & (lt | (eq & (gu < rg)))
    new_gpu = jnp.where(pick_u, gu, rg)
    new_aidx = jnp.where(pick_u, au, ap)
    ok_vic = oku | okp

    # -- score: total cluster fragmentation after both moves ----------------
    kv = midx[new_gpu]                                           # (C,)
    idx3 = (kv, rp, new_aidx)
    mask_new = tables.profile_masks[idx3]                        # (C, S)
    mwin_new = tables.maskwin[idx3]                              # (C, N)
    same = new_gpu == rg
    base_gv = jnp.where(same[:, None], base2, base[new_gpu])     # (C, N)
    free_gv = jnp.where(same, free2, free[new_gpu])              # (C,)
    f_gv_before = _frag_from_base(base_gv, free_gv, metric, vg[new_gpu])
    f_gv_after = _frag_from_base(
        base_gv + mwin_new, free_gv - mask_new.sum(axis=1), metric, vg[new_gpu]
    )
    total = f.sum() - f[rg] + f2 + f_gv_after - f_gv_before      # (C,)

    # -- canonical choice: lex-min (total F, victim gpu, victim anchor) -----
    vic_anchor = tables.profile_anchors[kc, rp, ra]              # (C,)
    cmask = present & ok_req & ok_vic & want
    for val in (total, rg.astype(jnp.float32), vic_anchor.astype(jnp.float32)):
        masked = jnp.where(cmask, val, _BIG)
        cmask = cmask & (masked == masked.min())
    j = jnp.argmax(cmask)
    orig = live[j]                                 # winner's original ring slot
    return MigrationResult(
        mig=cmask[j],
        gpu=rg[j],
        aidx=aidx_req[j].astype(jnp.int32),
        vic_row=(orig // cols).astype(jnp.int32),
        vic_col=(orig % cols).astype(jnp.int32),
        vic_gpu=rg[j],
        vic_anchor=vic_anchor[j],
        vic_pid=rp[j],
        new_gpu=new_gpu[j].astype(jnp.int32),
        new_aidx=new_aidx[j].astype(jnp.int32),
        new_anchor=tables.profile_anchors[kv[j], rp[j], new_aidx[j]],
        old_mask=rm[j],
        old_mwin=mwin_vic[j],
        new_mask=mask_new[j],
        new_mwin=mwin_new[j],
    )


# ---------------------------------------------------------------------------
# Single-decision entry point
# ---------------------------------------------------------------------------


class PolicyDecision(NamedTuple):
    """One placement decision, migration included (``-1`` where n/a)."""

    gpu: jax.Array
    anchor: jax.Array
    ok: jax.Array
    mig: jax.Array
    vic_gpu: jax.Array
    vic_anchor: jax.Array
    new_gpu: jax.Array
    new_anchor: jax.Array


def policy_select_full(
    occ: jax.Array,
    profile_id: jax.Array,
    policy: PolicyLike,
    metric: str = "blocked",
    spec: Optional[mig.ClusterSpec] = None,
    cursor: int = 0,
    workloads: Optional[Sequence[Tuple[int, int, int]]] = None,
) -> PolicyDecision:
    """One placement decision on a raw occupancy, defrag search included.

    ``workloads`` lists the running workloads as ``(gpu, profile_id,
    anchor)`` triples — the allocation table a defrag spec's migration
    search needs (victims).  It is optional (and ignored) for non-defrag
    specs; a defrag spec with no workloads simply has no migration
    candidates.  Matches the host compilation
    (:class:`repro.core.schedulers.MFIDefrag` with an unbounded candidate
    budget) exactly, migration choice included.
    """
    pspec = resolve(policy, engine="batched")
    spec = spec if spec is not None else _default_spec(int(occ.shape[0]))
    tables = spec_tables(spec)
    midx = jnp.asarray(spec.model_index)
    occf = occ.astype(jnp.float32)
    base = jnp.einsum("ms,mns->mn", occf, tables.W[midx])  # (M, N)
    free = tables.slices[midx] - occ.sum(axis=1).astype(jnp.int32)
    vg = tables.V[midx]
    f = _frag_from_base(base, free, metric, vg)
    cur = jnp.int32(cursor)
    gpu, aidx, ok = _select(
        pspec, base, free, f, metric, tables, midx, vg, profile_id, cur
    )
    neg1 = jnp.int32(-1)
    mig_out = (jnp.asarray(False), neg1, neg1, neg1, neg1)
    if pspec.defrag:
        wl = list(workloads) if workloads else []
        cols = max(1, len(wl))
        ring_gpu = np.zeros((1, cols), np.int32)
        ring_mask = np.zeros((1, cols, int(tables.W.shape[2])), np.int32)
        ring_pid = np.zeros((1, cols), np.int32)
        ring_aidx = np.zeros((1, cols), np.int32)
        for i, (g, p, anchor) in enumerate(wl):
            model = spec.model_of(int(g))
            j = model.profiles[int(p)].anchors.index(int(anchor))
            m = model.profiles[int(p)].mem
            ring_gpu[0, i] = g
            ring_mask[0, i, anchor : anchor + m] = 1
            ring_pid[0, i] = p
            ring_aidx[0, i] = j
        res = _migrate_search(
            pspec, metric, tables, midx, vg, base, free, f,
            jnp.asarray(ring_gpu), jnp.asarray(ring_mask),
            jnp.asarray(ring_pid), jnp.asarray(ring_aidx),
            profile_id, cur, want=~ok,
        )
        gpu = jnp.where(res.mig, res.gpu, gpu)
        aidx = jnp.where(res.mig, res.aidx, aidx)
        ok = ok | res.mig
        mig_out = (
            res.mig,
            jnp.where(res.mig, res.vic_gpu, neg1),
            jnp.where(res.mig, res.vic_anchor, neg1),
            jnp.where(res.mig, res.new_gpu, neg1),
            jnp.where(res.mig, res.new_anchor, neg1),
        )
    anchor = jnp.where(ok, tables.profile_anchors[midx[gpu], profile_id, aidx], -1)
    return PolicyDecision(
        gpu=jnp.where(ok, gpu, -1).astype(jnp.int32),
        anchor=anchor.astype(jnp.int32),
        ok=ok,
        mig=mig_out[0],
        vic_gpu=mig_out[1],
        vic_anchor=mig_out[2],
        new_gpu=mig_out[3],
        new_anchor=mig_out[4],
    )


def policy_select(
    occ: jax.Array,
    profile_id: jax.Array,
    policy: PolicyLike,
    metric: str = "blocked",
    spec: Optional[mig.ClusterSpec] = None,
    cursor: int = 0,
    workloads: Optional[Sequence[Tuple[int, int, int]]] = None,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """One placement decision on a raw occupancy: ``(gpu, anchor, accepted)``.

    Lowers ``policy`` (a registered name or an ad-hoc
    :class:`~repro.core.policy.PolicySpec`) exactly like the scan step (via
    the derived ``base``/``free`` state) and matches the corresponding host
    ``Scheduler.select`` — including rejects — for every batched-capable
    registered policy.  ``spec`` defaults to a homogeneous A100-80GB fleet
    of ``occ.shape[0]`` GPUs; ``cursor`` is the rotation start of stateful
    policies (``SpecScheduler._next``); ``workloads`` supplies the running
    allocations a defrag spec's migration search considers (see
    :func:`policy_select_full`, which also reports the chosen migration).
    """
    d = policy_select_full(
        occ, profile_id, policy, metric=metric, spec=spec, cursor=cursor,
        workloads=workloads,
    )
    return d.gpu, d.anchor, d.ok


# ---------------------------------------------------------------------------
# Scan state and the staged event step
# ---------------------------------------------------------------------------


class ReplicaState(NamedTuple):
    occ: jax.Array        # (M, S) int32 — None when occupancy isn't tracked
    base: jax.Array       # (M, N) float32 — occ @ W[midx]ᵀ, kept incrementally
    free: jax.Array       # (M,) int32
    f: jax.Array          # (M,) float32 — per-GPU F score, kept incrementally
    rr: jax.Array         # () int32 — RoundRobin cursor
    ring_gpu: jax.Array   # (K+2, E) int32 — expiry ring, keyed end_slot % K
    ring_mask: jax.Array  # (K+2, E, S) int32
    ring_pid: jax.Array   # (K+2, E) int32 — defrag specs only, else None
    ring_aidx: jax.Array  # (K+2, E) int32 — defrag specs only, else None
    # wait ring (queued protocols only, else None): parked rejected
    # arrivals, -1 pid marks a free slot.  Each entry keeps its original
    # host-assigned expiry-ring coordinates and absolute end slot — a
    # wait-admit commits with them unchanged (admission is only legal while
    # ``end > t``, so the row is still < one ring revolution ahead and the
    # column stays collision-free).
    wait_pid: jax.Array = None   # (Q,) int32 — demand class, -1 = free slot
    wait_arr: jax.Array = None   # (Q,) int32 — arrival slot
    wait_end: jax.Array = None   # (Q,) int32 — absolute lease deadline
    wait_row: jax.Array = None   # (Q,) int32 — original expiry-ring row
    wait_col: jax.Array = None   # (Q,) int32 — original expiry-ring column
    wait_prio: jax.Array = None  # (Q,) int32 — priority class
    wait_ten: jax.Array = None   # (Q,) int32 — tenant id
    wait_eidx: jax.Array = None  # (Q,) int32 — original event index
    ev: jax.Array = None         # () int32 — running event index (queued only)
    # faulted protocols only (else None): GPU availability, the extra ring
    # planes that make every live entry fully re-queueable on eviction, and
    # the wait ring's retry/backoff bookkeeping.  Appended after ``ev`` so
    # non-faulted pytrees (checkpoints included) are structurally unchanged.
    up: jax.Array = None         # (M,) bool — GPU accepting placements
    ring_end: jax.Array = None   # (K+2, E) int32 — entry's absolute lease deadline
    ring_eidx: jax.Array = None  # (K+2, E) int32 — entry's original event index
    ring_prio: jax.Array = None  # (K+2, E) int32 — entry's priority class
    ring_ten: jax.Array = None   # (K+2, E) int32 — entry's tenant id
    wait_try: jax.Array = None   # (Q,) int32 — re-queue attempts so far
    wait_rdy: jax.Array = None   # (Q,) int32 — earliest admission slot (backoff)


class EventStream(NamedTuple):
    """Host-precomputed per-event scan inputs, each ``(E_max, R)``."""

    pid: np.ndarray        # profile id, -1 for heartbeat/padding lanes
    exp_row: np.ndarray    # ring row (end_slot % K; trash row for padding)
    exp_col: np.ndarray    # ring column (host-assigned, collision-free)
    drain_row: np.ndarray  # ring row to drain when new_slot
    new_slot: np.ndarray   # first event of its slot (drain + maybe sample)
    sample: np.ndarray     # sample metrics of the just-finished slot
    measuring: np.ndarray  # arrival inside the measurement window
    # queued protocols only (None otherwise; shipped to device):
    slot: np.ndarray = None    # int32 — event slot (the wait stage's clock)
    end: np.ndarray = None     # int32 — absolute end slot of the arrival
    prio: np.ndarray = None    # int32 — priority class of the arrival
    tenant: np.ndarray = None  # int32 — tenant id of the arrival
    wlive: np.ndarray = None   # bool — real event (not padding/sentinel)
    # faulted protocols only (None otherwise; shipped to device).  Lanes are
    # (E_max, R, M) and set on the *first* event of each slot only, so the
    # fault stage applies each slot's fail/recover set exactly once.
    fail: np.ndarray = None     # bool — GPU m fails at this slot
    recover: np.ndarray = None  # bool — GPU m recovers at this slot


class EventMeta(NamedTuple):
    """Host-only per-event annotations (never shipped to device), ``(E_max, R)``.

    Used by :mod:`repro.sim.replay` to reconstruct and validate occupancy
    trajectories from a decision trace.
    """

    slot: np.ndarray  # arrival/heartbeat slot (total_slots for padding)
    end: np.ndarray   # absolute end slot of the arrival (0 for non-arrivals)


class EventTrace(NamedTuple):
    """Per-event scan outputs, each ``(E_max, R)``; counters and metric sums
    are reduced host-side against the host-known flags of the stream.

    Fields past ``aidx`` are compiled in per configuration and ``None``
    otherwise: the slot-boundary metrics for protocols with
    ``boundary_metrics`` (steady), the ``post_*`` metrics for protocols
    with ``post_metrics`` (cumulative), the ``mig_*`` fields for defrag
    specs (the victim's old placement and its new one).
    """

    ok: jax.Array        # arrival accepted
    gpu: jax.Array       # chosen GPU (undefined when not accepted)
    aidx: jax.Array      # chosen anchor index (undefined when not accepted)
    free_sum: jax.Array = None  # Σ free slices at slot boundary (pre-drain)
    active: jax.Array = None    # active-GPU count at slot boundary (pre-drain)
    frag: jax.Array = None      # cluster-mean F at slot boundary (pre-drain)
    post_free: jax.Array = None    # Σ free slices after the commit
    post_active: jax.Array = None  # active-GPU count after the commit
    post_frag: jax.Array = None    # cluster-mean F after the commit
    mig: jax.Array = None          # a migration was committed at this event
    mig_from_gpu: jax.Array = None     # victim's old GPU (-1 when no mig)
    mig_from_anchor: jax.Array = None  # victim's old anchor value
    mig_to_gpu: jax.Array = None       # victim's new GPU
    mig_to_anchor: jax.Array = None    # victim's new anchor value
    # queued protocols only: the wait-ring stage's outputs at this event
    parked: jax.Array = None       # rejected arrival entered the wait ring
    wadm_eidx: jax.Array = None    # original event index of the wait-admit (-1 none)
    wadm_gpu: jax.Array = None     # wait-admit's chosen GPU (-1 none)
    wadm_aidx: jax.Array = None    # wait-admit's chosen anchor index (-1 none)
    # faulted protocols only: the fault stage's eviction accounting
    evicted: jax.Array = None      # int32 — live entries evicted by failures
    evict_lost: jax.Array = None   # int32 — evictions dropped (ring full / no budget)
    evict_esum: jax.Array = None   # int32 — Σ original event indexes of evictions


def _init_state(
    tables: SpecTables,
    midx: jax.Array,
    ring_rows: int,
    ring_cols: int,
    track_occ: bool,
    track_alloc: bool,
    wait_slots: int = 0,
    faulted: bool = False,
) -> ReplicaState:
    num_gpus = midx.shape[0]
    s = tables.W.shape[2]
    n = tables.W.shape[1]
    q = wait_slots
    zq = jnp.zeros((q,), jnp.int32) if q else None
    # faulted protocols track every live entry's full identity on the ring
    # (demand class via the defrag planes + the deadline/priority/tenant/
    # event-index planes below) so an eviction can re-queue it losslessly
    track_alloc = track_alloc or faulted
    zr = (lambda: jnp.zeros((ring_rows, ring_cols), jnp.int32)) if faulted else (lambda: None)
    return ReplicaState(
        occ=jnp.zeros((num_gpus, s), jnp.int32) if track_occ else None,
        base=jnp.zeros((num_gpus, n), jnp.float32),
        free=tables.slices[midx].astype(jnp.int32),
        f=jnp.zeros((num_gpus,), jnp.float32),
        rr=jnp.int32(0),
        ring_gpu=jnp.zeros((ring_rows, ring_cols), jnp.int32),
        ring_mask=jnp.zeros((ring_rows, ring_cols, s), jnp.int32),
        ring_pid=jnp.zeros((ring_rows, ring_cols), jnp.int32) if track_alloc else None,
        ring_aidx=jnp.zeros((ring_rows, ring_cols), jnp.int32) if track_alloc else None,
        wait_pid=jnp.full((q,), -1, jnp.int32) if q else None,
        wait_arr=zq,
        wait_end=zq,
        wait_row=zq,
        wait_col=zq,
        wait_prio=zq,
        wait_ten=zq,
        wait_eidx=zq,
        ev=jnp.int32(0) if q else None,
        up=jnp.ones((num_gpus,), bool) if faulted else None,
        ring_end=zr(),
        ring_eidx=zr(),
        ring_prio=zr(),
        ring_ten=zr(),
        wait_try=zq if faulted else None,
        wait_rdy=zq if faulted else None,
    )


@dataclasses.dataclass(frozen=True)
class EngineCore:
    """The staged scan body: one event step, composed from stages.

    Static configuration (``spec``, ``protocol``, ``metric``) selects which
    stages are compiled in; the array members (stacked tables, model-index
    gather, per-GPU window sizes) are closed over as constants.  Stage
    order within one event is the semantic order of the simulators:
    *measure* the just-finished slot (steady), *expire* this slot's ring
    row, decode the *arrival*, *select*, *migrate* (defrag specs, on
    reject), *commit*, and *measure* the post-commit state (cumulative).
    """

    spec: PolicySpec
    protocol: Protocol
    metric: str
    tables: SpecTables
    midx: jax.Array
    vg: jax.Array
    frag_fn: Optional[object] = None
    delta_fn: Optional[object] = None
    select_fn: Optional[object] = None
    migrate_fn: Optional[object] = None
    wait_patience: int = 0  # queued protocols: max slots a request may wait

    # -- stages --------------------------------------------------------------
    def _stage_boundary_measure(self, st: ReplicaState):
        """Slot-boundary metrics (state == end of slot t-1); reduced
        host-side against the ``sample`` flags of the stream."""
        frag = st.f.mean()
        free_sum = st.free.sum()
        active = (st.free < self.tables.slices[self.midx]).sum()
        return frag, free_sum, active

    def _stage_expire(self, st: ReplicaState, drain_row, new_slot):
        """Drain this slot's expiry-ring row (first event of the slot only)."""
        ns = new_slot.astype(jnp.int32)
        rel_gpu = st.ring_gpu[drain_row]  # (E,)
        rel_mask = st.ring_mask[drain_row] * ns  # (E, S)
        occ = None if st.occ is None else st.occ.at[rel_gpu].add(-rel_mask)
        rel_win = jnp.einsum(
            "es,ens->en", rel_mask.astype(jnp.float32), self.tables.W[self.midx[rel_gpu]]
        )  # (E, N) — window counts each release frees, per its GPU's model
        base = st.base.at[rel_gpu].add(-rel_win)
        free = st.free.at[rel_gpu].add(rel_mask.sum(axis=1))
        # rescore exactly the touched rows — through the Pallas kernel when it
        # is routed in (occ is materialized then), else from the window counts
        f = st.f.at[rel_gpu].set(
            self.frag_fn(occ[rel_gpu])
            if self.frag_fn is not None
            else _frag_from_base(
                base[rel_gpu], free[rel_gpu], self.metric, self.vg[rel_gpu]
            )
        )
        ring_mask = st.ring_mask.at[drain_row].set(st.ring_mask[drain_row] * (1 - ns))
        return st._replace(
            occ=occ, base=base, free=free, f=f, ring_mask=ring_mask
        )

    def _btable(self):
        """Static backoff lookup: ``btable[k]`` is the wait before becoming
        eligible again after re-queue attempt ``k`` (1-based; exponential
        ``fault_backoff * 2**(k-1)``, clamped at the retry budget)."""
        b, r = self.protocol.fault_backoff, self.protocol.fault_retries
        return jnp.asarray(
            [b * 2 ** max(0, k - 1) for k in range(r + 2)], jnp.int32
        )

    def _stage_fault(self, st: ReplicaState, fail_v, rec_v, t):
        """Faulted protocols: apply this slot's GPU fail/recover lanes.

        Runs after the expire drain (a lease ending the very slot its GPU
        dies still completes) and before the wait stage (evictions are
        eligible for re-admission only after their backoff).  A failing GPU
        is cleared wholesale — every live allocation is a ring entry, so
        zeroing its occupancy/base/free/f equals subtracting each eviction
        one by one (a down GPU reads empty and inactive in every metric,
        ``F = 0`` exactly like the initial state) — and masked out of
        feasibility via ``up`` until its recover lane.  Evicted entries are
        re-queued into the wait ring in flat ``(row, col)`` ring order,
        filling free slots in ascending index order; whatever exceeds the
        free capacity (or everything, when the retry budget is zero) is a
        final loss, counted in the trace.  Returns
        ``(st, evicted, evict_lost, evict_esum)``.
        """
        up = (st.up | rec_v) & ~fail_v  # presampling alternates fail/recover
        rows, cols = st.ring_gpu.shape
        live = st.ring_mask.sum(axis=-1) > 0          # (K+2, E)
        evict = fail_v[st.ring_gpu] & live            # stale slots: live=False
        fi = fail_v.astype(jnp.int32)
        occ = None if st.occ is None else st.occ * (1 - fi)[:, None]
        base = jnp.where(fail_v[:, None], 0.0, st.base)
        free = jnp.where(
            fail_v, self.tables.slices[self.midx].astype(jnp.int32), st.free
        )
        f = jnp.where(fail_v, 0.0, st.f)
        ring_mask = st.ring_mask * (1 - evict.astype(jnp.int32))[:, :, None]
        st = st._replace(
            up=up, occ=occ, base=base, free=free, f=f, ring_mask=ring_mask
        )

        ev_flat = evict.reshape(-1)                   # flat (row, col) order
        n_ev = ev_flat.sum().astype(jnp.int32)
        esum = (st.ring_eidx.reshape(-1) * ev_flat.astype(jnp.int32)).sum()
        if self.protocol.fault_retries < 1:
            return st, n_ev, n_ev, esum  # no retry budget: immediate losses

        q = st.wait_pid.shape[0]
        c = ev_flat.shape[0]
        rank = jnp.cumsum(ev_flat.astype(jnp.int32)) - 1
        freeslot = st.wait_pid < 0
        nfree = freeslot.sum()
        slot_order = jnp.argsort(~freeslot)  # stable: free slots, ascending
        can = ev_flat & (rank < nfree)
        # rank-based scatter: eviction #k lands in the k-th free wait slot;
        # overflow targets index q and is dropped (a final loss)
        tgt = jnp.where(can, slot_order[jnp.clip(rank, 0, q - 1)], q)
        idx = jnp.arange(c, dtype=jnp.int32)
        tcol = jnp.broadcast_to(t, (c,)).astype(jnp.int32)

        def put(arr, v):
            return arr.at[tgt].set(v, mode="drop")

        st = st._replace(
            wait_pid=put(st.wait_pid, st.ring_pid.reshape(-1)),
            wait_arr=put(st.wait_arr, tcol),
            wait_end=put(st.wait_end, st.ring_end.reshape(-1)),
            wait_row=put(st.wait_row, idx // cols),
            wait_col=put(st.wait_col, idx % cols),
            wait_prio=put(st.wait_prio, st.ring_prio.reshape(-1)),
            wait_ten=put(st.wait_ten, st.ring_ten.reshape(-1)),
            wait_eidx=put(st.wait_eidx, st.ring_eidx.reshape(-1)),
            wait_try=put(st.wait_try, jnp.ones((c,), jnp.int32)),
            wait_rdy=put(st.wait_rdy, tcol + self._btable()[1]),
        )
        lost = n_ev - can.sum().astype(jnp.int32)
        return st, n_ev, lost, esum

    def _stage_select(self, st: ReplicaState, pid_c, valid):
        """Place (or reject) the arrival; ``pid == -1`` lanes are no-ops."""
        gpu, aidx, ok = _select(
            self.spec, st.base, st.free, st.f, self.metric, self.tables,
            self.midx, self.vg, pid_c, st.rr, delta_fn=self.delta_fn,
            select_fn=self.select_fn, gpu_ok=st.up,
        )
        return gpu, aidx, ok & valid

    def _stage_migrate(self, st: ReplicaState, pid_c, valid, gpu, aidx, ok):
        """Defrag search on reject; commits the victim's move in place."""
        res = _migrate_search(
            self.spec, self.metric, self.tables, self.midx, self.vg,
            st.base, st.free, st.f,
            st.ring_gpu, st.ring_mask, st.ring_pid, st.ring_aidx,
            pid_c, st.rr, want=valid & ~ok, delta_fn=self.delta_fn,
            migrate_fn=self.migrate_fn,
        )
        mi = res.mig.astype(jnp.int32)
        mf = res.mig.astype(jnp.float32)
        base = st.base.at[res.vic_gpu].add(-res.old_mwin * mf)
        base = base.at[res.new_gpu].add(res.new_mwin * mf)
        free = st.free.at[res.vic_gpu].add(res.old_mask.sum() * mi)
        free = free.at[res.new_gpu].add(-res.new_mask.sum() * mi)
        occ = st.occ
        if occ is not None:
            occ = occ.at[res.vic_gpu].add(-res.old_mask * mi)
            occ = occ.at[res.new_gpu].add(res.new_mask * mi)
        rc = (res.vic_row, res.vic_col)
        ring_mask = st.ring_mask.at[rc].add((res.new_mask - res.old_mask) * mi)
        ring_gpu = st.ring_gpu.at[rc].set(
            jnp.where(res.mig, res.new_gpu, st.ring_gpu[rc])
        )
        ring_aidx = st.ring_aidx.at[rc].set(
            jnp.where(res.mig, res.new_aidx, st.ring_aidx[rc])
        )
        st = st._replace(
            occ=occ, base=base, free=free,
            ring_gpu=ring_gpu, ring_mask=ring_mask, ring_aidx=ring_aidx,
        )
        gpu = jnp.where(res.mig, res.gpu, gpu)
        aidx = jnp.where(res.mig, res.aidx, aidx)
        ok = ok | res.mig
        return st, gpu, aidx, ok, res

    def _stage_commit(
        self, st: ReplicaState, pid_c, gpu, aidx, ok, exp_row, exp_col,
        mig_res: Optional[MigrationResult], meta=None,
    ):
        """Commit the accepted placement: occupancy/window/free updates, the
        expiry-ring insert, the rescore of touched rows, the cursor.

        ``meta`` (faulted protocols: ``(end, prio, ten, eidx)``) writes the
        entry's identity into the extra ring planes so a later eviction can
        re-queue it losslessly; ``None`` compiles those writes out."""
        tables, midx, vg = self.tables, self.midx, self.vg
        oki = ok.astype(jnp.int32)
        gpu_c = jnp.where(ok, gpu, 0).astype(jnp.int32)
        kg = midx[gpu_c]  # chosen GPU's model index
        mask = tables.profile_masks[kg, pid_c, aidx] * oki  # (S,)
        mwin = tables.maskwin[kg, pid_c, aidx] * oki.astype(jnp.float32)  # (N,)
        occ = None if st.occ is None else st.occ.at[gpu_c].add(mask)
        base = st.base.at[gpu_c].add(mwin)
        free = st.free.at[gpu_c].add(-mask.sum())
        f = st.f.at[gpu_c].set(
            self.frag_fn(occ[gpu_c][None])[0]
            if self.frag_fn is not None
            else _frag_from_base(
                base[gpu_c][None], free[gpu_c][None], self.metric, vg[gpu_c][None]
            )[0]
        )
        if mig_res is not None:
            # rescore the victim's landing GPU too (its old GPU is gpu_c)
            g2 = jnp.where(mig_res.mig, mig_res.new_gpu, gpu_c)
            f = f.at[g2].set(
                self.frag_fn(occ[g2][None])[0]
                if self.frag_fn is not None
                else _frag_from_base(
                    base[g2][None], free[g2][None], self.metric, vg[g2][None]
                )[0]
            )
        rr = st.rr
        if self.spec.stateful_cursor:  # advance the cursor past the chosen GPU
            rr = jnp.where(ok, (gpu_c + 1) % midx.shape[0], rr).astype(jnp.int32)
        ring_gpu = st.ring_gpu.at[exp_row, exp_col].set(
            jnp.where(ok, gpu_c, st.ring_gpu[exp_row, exp_col])
        )
        ring_mask = st.ring_mask.at[exp_row, exp_col].add(mask)
        ring_pid, ring_aidx = st.ring_pid, st.ring_aidx
        if ring_pid is not None:
            ring_pid = ring_pid.at[exp_row, exp_col].set(
                jnp.where(ok, pid_c, ring_pid[exp_row, exp_col])
            )
            ring_aidx = ring_aidx.at[exp_row, exp_col].set(
                jnp.where(ok, aidx.astype(jnp.int32), ring_aidx[exp_row, exp_col])
            )
        ring_end, ring_eidx = st.ring_end, st.ring_eidx
        ring_prio, ring_ten = st.ring_prio, st.ring_ten
        if meta is not None and ring_end is not None:
            end_m, prio_m, ten_m, eidx_m = meta

            def put_meta(plane, v):
                return plane.at[exp_row, exp_col].set(
                    jnp.where(ok, v.astype(jnp.int32), plane[exp_row, exp_col])
                )

            ring_end = put_meta(ring_end, end_m)
            ring_prio = put_meta(ring_prio, prio_m)
            ring_ten = put_meta(ring_ten, ten_m)
            ring_eidx = put_meta(ring_eidx, eidx_m)
        return st._replace(
            occ=occ, base=base, free=free, f=f, rr=rr,
            ring_gpu=ring_gpu, ring_mask=ring_mask,
            ring_pid=ring_pid, ring_aidx=ring_aidx,
            ring_end=ring_end, ring_eidx=ring_eidx,
            ring_prio=ring_prio, ring_ten=ring_ten,
        )

    def _stage_wait(self, st: ReplicaState, t, wlive):
        """Queued protocols: prune the wait ring, then try to admit its head.

        Entries whose lease deadline passed (``end <= t``) or whose wait
        exceeded the patience budget are dropped — final rejects (they
        simply never appear as a wait-admit in the trace).  Among the
        survivors the *head* is the lexicographic minimum of the spec's
        queue order (:func:`repro.core.policy.queue_order`; the original
        event index breaks ties FIFO).  The head re-enters the spec's
        placement selection; on acceptance it commits with its original
        host-assigned ring coordinates (its absolute end slot is
        unchanged, so the expiry row is still less than one ring
        revolution ahead and the column is collision-free).  One admission
        attempt per event — waiting requests drain across the stream's
        events (heartbeats included), always ahead of the concurrent
        arrival.  ``wlive`` gates the stage to real events (padding and
        sentinel lanes have no host-side clock).
        """
        present = st.wait_pid >= 0
        age = t - st.wait_arr
        if self.protocol.faulted:
            # SLA-aware retry: a patience overrun re-arms with exponential
            # backoff while the retry budget and the lease allow it, and
            # becomes a final drop only past the budget.  Entries inside
            # their backoff window (``wait_rdy > t``) are skipped as head.
            overdue = wlive & present & (age > self.wait_patience)
            rearm = (
                overdue
                & (st.wait_try < self.protocol.fault_retries)
                & (st.wait_end > t)
            )
            drop = wlive & present & ((st.wait_end <= t) | (overdue & ~rearm))
            keep = present & ~drop
            try_new = jnp.where(rearm, st.wait_try + 1, st.wait_try)
            btable = self._btable()
            st = st._replace(
                wait_arr=jnp.where(rearm, t, st.wait_arr),
                wait_try=try_new,
                wait_rdy=jnp.where(
                    rearm,
                    t + btable[jnp.clip(try_new, 0, btable.shape[0] - 1)],
                    st.wait_rdy,
                ),
            )
            mask = keep & wlive & (st.wait_rdy <= t)
        else:
            drop = wlive & ((st.wait_end <= t) | (age > self.wait_patience))
            keep = present & ~drop
            mask = keep & wlive
        for key in queue_order(self.spec):
            base_k = key_base(key)
            if base_k == "priority":
                val = st.wait_prio.astype(jnp.float32)
            elif base_k == "wait-age":
                val = age.astype(jnp.float32)
            else:  # tenant
                val = st.wait_ten.astype(jnp.float32)
            if key.startswith("-"):
                val = -val
            masked = jnp.where(mask, val, _BIG)
            mask = mask & (masked == masked.min())
        fifo = jnp.where(mask, st.wait_eidx, jnp.int32(2**31 - 1))
        j = jnp.argmin(fifo)
        head = mask.any()

        pid_w = jnp.maximum(st.wait_pid[j], 0)
        gpu, aidx, sel_ok = _select(
            self.spec, st.base, st.free, st.f, self.metric, self.tables,
            self.midx, self.vg, pid_w, st.rr, delta_fn=self.delta_fn,
            select_fn=self.select_fn, gpu_ok=st.up,
        )
        ok_w = sel_ok & head
        meta_w = (
            (st.wait_end[j], st.wait_prio[j], st.wait_ten[j], st.wait_eidx[j])
            if self.protocol.faulted else None
        )
        st = self._stage_commit(
            st, pid_w, gpu, aidx, ok_w, st.wait_row[j], st.wait_col[j], None,
            meta=meta_w,
        )
        wait_pid = jnp.where(keep, st.wait_pid, jnp.int32(-1))
        wait_pid = wait_pid.at[j].set(jnp.where(ok_w, jnp.int32(-1), wait_pid[j]))
        st = st._replace(wait_pid=wait_pid)
        eidx = jnp.where(ok_w, st.wait_eidx[j], jnp.int32(-1))
        return st, eidx, gpu.astype(jnp.int32), aidx.astype(jnp.int32), ok_w

    def _stage_park(
        self, st: ReplicaState, pid_c, can, t, end, prio, ten, exp_row, exp_col
    ):
        """Insert a rejected arrival into the first free wait-ring slot
        (``can`` already folds in validity, rejection and free capacity)."""
        freeslot = st.wait_pid < 0
        j = jnp.argmax(freeslot)

        def put(arr, v):
            return arr.at[j].set(jnp.where(can, v, arr[j]))

        st = st._replace(
            wait_pid=put(st.wait_pid, pid_c),
            wait_arr=put(st.wait_arr, t),
            wait_end=put(st.wait_end, end),
            wait_row=put(st.wait_row, exp_row),
            wait_col=put(st.wait_col, exp_col),
            wait_prio=put(st.wait_prio, prio),
            wait_ten=put(st.wait_ten, ten),
            wait_eidx=put(st.wait_eidx, st.ev),
        )
        if self.protocol.faulted:  # fresh parks: no retries used, no backoff
            st = st._replace(
                wait_try=put(st.wait_try, jnp.int32(0)),
                wait_rdy=put(st.wait_rdy, t),
            )
        return st

    def _stage_post_measure(self, st: ReplicaState):
        """Post-commit metrics (the cumulative protocol samples every event)."""
        return st.f.mean(), st.free.sum(), (st.free < self.tables.slices[self.midx]).sum()

    # -- the composed step ---------------------------------------------------
    def step(self, st: ReplicaState, x):
        if self.protocol.faulted:
            (pid, exp_row, exp_col, drain_row, new_slot,
             t, end, prio, ten, wlive, fail_v, rec_v) = x
        elif self.protocol.queued:
            (pid, exp_row, exp_col, drain_row, new_slot,
             t, end, prio, ten, wlive) = x
        else:
            pid, exp_row, exp_col, drain_row, new_slot = x

        frag = free_sum = active = None
        if self.protocol.boundary_metrics:
            frag, free_sum, active = self._stage_boundary_measure(st)

        st = self._stage_expire(st, drain_row, new_slot)

        evicted = evict_lost = evict_esum = None
        if self.protocol.faulted:  # after expire: same-slot completions win
            st, evicted, evict_lost, evict_esum = self._stage_fault(
                st, fail_v, rec_v, t
            )

        wadm_eidx = wadm_gpu = wadm_aidx = parked = None
        if self.protocol.queued:  # waiting requests admit ahead of the arrival
            st, wadm_eidx, wadm_gpu, wadm_aidx, ok_w = self._stage_wait(st, t, wlive)
            wadm_gpu = jnp.where(ok_w, wadm_gpu, -1)
            wadm_aidx = jnp.where(ok_w, wadm_aidx, -1)

        valid = pid >= 0
        pid_c = jnp.maximum(pid, 0)
        gpu, aidx, ok = self._stage_select(st, pid_c, valid)

        mig_res = None
        if self.spec.defrag:
            st, gpu, aidx, ok, mig_res = self._stage_migrate(
                st, pid_c, valid, gpu, aidx, ok
            )

        meta = (end, prio, ten, st.ev) if self.protocol.faulted else None
        st = self._stage_commit(
            st, pid_c, gpu, aidx, ok, exp_row, exp_col, mig_res, meta=meta
        )

        if self.protocol.queued:
            parked = valid & ~ok & wlive & (st.wait_pid < 0).any()
            st = self._stage_park(
                st, pid_c, parked, t, end, prio, ten, exp_row, exp_col
            )
            st = st._replace(ev=st.ev + 1)

        post_frag = post_free = post_active = None
        if self.protocol.post_metrics:
            post_frag, post_free, post_active = self._stage_post_measure(st)

        neg1 = jnp.int32(-1)
        trace = EventTrace(
            ok=ok,
            gpu=jnp.where(ok, gpu, 0).astype(jnp.int32),
            aidx=aidx.astype(jnp.int32),
            free_sum=free_sum,
            active=active,
            frag=frag,
            post_free=post_free,
            post_active=post_active,
            post_frag=post_frag,
            mig=None if mig_res is None else mig_res.mig,
            mig_from_gpu=None if mig_res is None else jnp.where(
                mig_res.mig, mig_res.vic_gpu, neg1
            ),
            mig_from_anchor=None if mig_res is None else jnp.where(
                mig_res.mig, mig_res.vic_anchor, neg1
            ),
            mig_to_gpu=None if mig_res is None else jnp.where(
                mig_res.mig, mig_res.new_gpu, neg1
            ),
            mig_to_anchor=None if mig_res is None else jnp.where(
                mig_res.mig, mig_res.new_anchor, neg1
            ),
            parked=parked,
            wadm_eidx=wadm_eidx,
            wadm_gpu=wadm_gpu,
            wadm_aidx=wadm_aidx,
            evicted=evicted,
            evict_lost=evict_lost,
            evict_esum=evict_esum,
        )
        return st, trace


def _build_core(
    *,
    policy: PolicyLike,
    metric: str,
    num_gpus: int,
    use_kernel: bool,
    kernel_spec: Optional[mig.ClusterSpec] = None,
    protocol: Union[str, Protocol] = "steady",
    wait_slots: int = 0,
    wait_patience: int = 0,
    midx: Optional[jax.Array] = None,
    tables: Optional[SpecTables] = None,
) -> Tuple[EngineCore, SpecTables, jax.Array]:
    """Validate one engine configuration and build its staged core.

    The single construction path shared by the monolithic :func:`_simulate`,
    the chunked :func:`_scan_chunk` and :func:`init_carry` — every entry
    point applies the same policy/protocol validation and compiles the same
    stages, so the chunked and monolithic drivers cannot drift.  Returns
    ``(core, tables, midx)`` with the homogeneous defaults filled in.
    """
    pspec = resolve(policy, engine="batched")
    proto = resolve_protocol(protocol)
    if proto.queued:
        if pspec.defrag:
            raise ValueError(
                f"policy {pspec.name!r}: defrag specs are not supported under "
                "the queued protocol (the migrate stage's victim table does "
                "not cover parked requests)"
            )
        if wait_slots <= 0:
            raise ValueError(
                f"protocol {proto.name!r} needs wait_slots > 0 "
                "(SimConfig.wait_capacity)"
            )
    if tables is None:  # homogeneous A100-80GB default
        cspec = _default_spec(num_gpus)
        tables = spec_tables(cspec)
        midx = jnp.asarray(cspec.model_index)
    frag_fn = delta_fn = select_fn = migrate_fn = None
    if use_kernel:
        # Pallas dispatch rules (`kernel_spec` is the static ClusterSpec):
        # the occupancy-based `fragscore` rescore kernel needs one placement
        # table, so it compiles in on homogeneous specs only (mixed fleets
        # keep the base-derived rescoring); the fused `delta_from_base` ΔF
        # kernel dispatches per model group and serves any fleet, for specs
        # whose keys consume ΔF; specs that additionally declare
        # argmin-fusability (`PolicySpec.fused_argmin`) lower the whole
        # select stage — and, for defrag specs, both migrate refinements —
        # to the fused `select_from_base` / `migrate_refine` kernels (the
        # `(M, A)` score table stays in VMEM).  `kernel_lowering="delta"`
        # keeps only the ΔF kernel.
        kspec = kernel_spec if kernel_spec is not None else _default_spec(num_gpus)
        if kspec.is_homogeneous:
            frag_fn = make_frag_fn(metric, True, kspec.models[0])
        if pspec.requires_delta_f:
            delta_fn = make_delta_fn(kspec, metric)
        # the fused select kernel cannot see the faulted protocol's up-mask,
        # so faulted runs keep the jnp lowering (frag/ΔF kernels still apply)
        if pspec.fused_argmin and not proto.faulted:
            select_fn = make_select_fn(kspec, pspec, metric)
            if pspec.defrag:
                migrate_fn = make_migrate_fn(kspec, pspec, metric)
    vg = tables.V[midx]  # (M, N) per-GPU window sizes, gathered once
    core = EngineCore(
        spec=pspec, protocol=proto, metric=metric, tables=tables,
        midx=midx, vg=vg, frag_fn=frag_fn, delta_fn=delta_fn,
        select_fn=select_fn, migrate_fn=migrate_fn,
        wait_patience=wait_patience,
    )
    return core, tables, midx


def _broadcast_init(
    core: EngineCore, runs: int, ring_rows: int, ring_cols: int, wait_slots: int
) -> ReplicaState:
    """The ``(runs,)``-vmapped initial carry for ``core``'s configuration."""
    return jax.tree.map(
        lambda x: jnp.broadcast_to(x, (runs,) + x.shape),
        _init_state(
            core.tables, core.midx, ring_rows, ring_cols,
            track_occ=core.frag_fn is not None, track_alloc=core.spec.defrag,
            wait_slots=wait_slots if core.protocol.queued else 0,
            faulted=core.protocol.faulted,
        ),
    )


def _scan_xs(events: EventStream, proto: Protocol):
    """The scanned input tuple: every device-shipped stream field.

    ``sample``/``measuring`` are host-side reduction flags — never shipped
    to the scan.
    """
    xs = (events.pid, events.exp_row, events.exp_col, events.drain_row, events.new_slot)
    if proto.queued:  # the wait stage's clock + per-arrival queue attributes
        xs = xs + (events.slot, events.end, events.prio, events.tenant, events.wlive)
    if proto.faulted:  # per-slot GPU fail/recover lanes, (E, R, M)
        xs = xs + (events.fail, events.recover)
    return xs


@functools.partial(
    jax.jit,
    static_argnames=(
        "policy", "metric", "num_gpus", "ring_rows", "ring_cols",
        "use_kernel", "kernel_spec", "protocol", "wait_slots", "wait_patience",
    ),
)
def _simulate(
    events: EventStream,  # each field (E_max, R) — events are the scanned axis
    *,
    policy: PolicyLike,  # registered name or (hashable, static) PolicySpec
    metric: str,
    num_gpus: int,
    ring_rows: int,
    ring_cols: int,
    use_kernel: bool,
    kernel_spec: Optional[mig.ClusterSpec] = None,
    protocol: Union[str, Protocol] = "steady",
    wait_slots: int = 0,
    wait_patience: int = 0,
    midx: Optional[jax.Array] = None,
    tables: Optional[SpecTables] = None,
) -> Tuple[ReplicaState, EventTrace]:
    runs = events.pid.shape[1]
    core, tables, midx = _build_core(
        policy=policy, metric=metric, num_gpus=num_gpus,
        use_kernel=use_kernel, kernel_spec=kernel_spec, protocol=protocol,
        wait_slots=wait_slots, wait_patience=wait_patience,
        midx=midx, tables=tables,
    )
    step = jax.vmap(core.step, in_axes=(0, 0))
    init = _broadcast_init(core, runs, ring_rows, ring_cols, wait_slots)
    return jax.lax.scan(
        lambda st, x: step(st, x), init, _scan_xs(events, core.protocol)
    )


# ---------------------------------------------------------------------------
# Host-side arrival pre-sampling + public entry point
# ---------------------------------------------------------------------------


def _rank_within_groups(keys: np.ndarray) -> np.ndarray:
    """Rank of each element within its equal-key group (first-occurrence order)."""
    order = np.argsort(keys, kind="stable")
    ks = keys[order]
    starts = np.r_[0, np.flatnonzero(np.diff(ks)) + 1]
    lengths = np.diff(np.r_[starts, len(ks)])
    ranks_sorted = np.arange(len(ks)) - np.repeat(starts, lengths)
    ranks = np.empty(len(ks), dtype=np.int64)
    ranks[order] = ranks_sorted
    return ranks


def _ring_columns(
    is_arrival: np.ndarray, end: np.ndarray, span: int
) -> Tuple[np.ndarray, int]:
    """Collision-free ring columns: rank among same-(replica, end) arrivals.

    ``span`` must exceed every end slot so the per-replica key blocks never
    overlap.  Returns ``(exp_col, ring_cols)``.
    """
    runs, e_max = is_arrival.shape
    exp_col = np.zeros((runs, e_max), dtype=np.int32)
    flat = np.flatnonzero(is_arrival)  # C-order == per-replica arrival order
    keys = (np.repeat(np.arange(runs), e_max)[flat].astype(np.int64) * span
            + end.ravel()[flat])
    ranks = _rank_within_groups(keys)
    exp_col.ravel()[flat] = ranks
    ring_cols = max(1, int(ranks.max()) + 1 if len(ranks) else 1)
    return exp_col, ring_cols


def presample_fault_slots(
    spec: mig.ClusterSpec,
    fault_model: "mig.FaultModel",
    runs: int,
    total_slots: int,
    rng: np.random.Generator,
) -> Tuple[np.ndarray, np.ndarray]:
    """Draw per-GPU alternating fail/recover slot tables.

    Returns ``(fail, recover)`` as ``(runs, total_slots, M)`` bools.  Each
    GPU alternates ``Exp(mtbf)`` up-phases and ``Exp(mttr)`` down-phases
    (per-model rates via :meth:`FaultModel.rates_for`); phase lengths are
    ceiled to at least one slot, so fail and recover marks strictly
    alternate and never share a slot.  Draw order is fixed (replica-major,
    then GPU, then alternating phases) so a seeded rng reproduces the
    tables exactly.
    """
    m = spec.num_gpus
    rates = [fault_model.rates_for(spec.model_of(g).name) for g in range(m)]
    fail = np.zeros((runs, total_slots, m), dtype=bool)
    recover = np.zeros((runs, total_slots, m), dtype=bool)
    for r in range(runs):
        for g in range(m):
            mtbf, mttr = rates[g]
            t = 0.0
            while True:
                t += max(1.0, np.ceil(rng.exponential(mtbf)))
                if t >= total_slots:
                    break
                fail[r, int(t), g] = True
                t += max(1.0, np.ceil(rng.exponential(mttr)))
                if t >= total_slots:
                    break
                recover[r, int(t), g] = True
    return fail, recover


def presample_arrivals(
    cfg: SimConfig, runs: int, seed=None, queued: bool = False,
    fault_model: "mig.FaultModel" = None,
) -> Tuple[EventStream, EventMeta, int, int]:
    """Build per-replica steady-protocol event streams on host.

    Returns ``(events, meta, ring_rows, ring_cols)``.  One event per
    Poisson arrival plus one heartbeat per empty slot (so consecutive
    events never skip a slot), plus a trailing sentinel that samples the
    final slot; streams are right-padded to the longest replica with no-op
    lanes.

    ``queued`` additionally populates the stream's queued-protocol fields
    (slot clock, absolute end slots, per-arrival tenant/priority draws and
    the live-event mask).  The tenant/priority draws happen strictly
    *after* the shared arrival sampling, so the arrival process — and
    every non-queued field — is byte-identical with ``queued=False``
    (golden steady traces are unaffected).  ``fault_model`` (faulted
    protocols; implies ``queued``) additionally draws per-GPU fail/recover
    lanes — strictly after every other draw, preserving the same
    byte-identity guarantee — and attaches each slot's lane set to the
    first event of that slot.
    """
    rng = np.random.default_rng(cfg.seed if seed is None else seed)
    probs = request_probs(cfg)
    T, warm, meas, rate = steady_params(cfg)
    total_slots = warm + meas
    ring_k = T + 1  # end slots live in (t, t + T] — one ring revolution

    counts = rng.poisson(rate, size=(runs, total_slots))
    ev_per_slot = np.maximum(counts, 1)  # heartbeat for empty slots
    n_events = ev_per_slot.sum(axis=1)  # (R,)
    e_max = int(n_events.max()) + 1  # +1 trailing sentinel

    pid = np.full((runs, e_max), -1, dtype=np.int32)
    slot = np.full((runs, e_max), total_slots, dtype=np.int32)
    new_slot = np.zeros((runs, e_max), dtype=bool)
    end = np.zeros((runs, e_max), dtype=np.int64)  # absolute end slot

    for r in range(runs):
        n = n_events[r]
        slots_r = np.repeat(np.arange(total_slots), ev_per_slot[r])
        within = np.arange(n) - np.repeat(
            np.cumsum(ev_per_slot[r]) - ev_per_slot[r], ev_per_slot[r]
        )
        is_arr = within < counts[r, slots_r]
        na = int(is_arr.sum())
        pid[r, :n][is_arr] = distributions.sample_profile_probs(probs, na, rng)
        slot[r, :n] = slots_r
        new_slot[r, :n] = within == 0
        end[r, :n][is_arr] = slots_r[is_arr] + rng.integers(1, T + 1, size=na)
        new_slot[r, n] = True  # sentinel: drains/samples the final slot

    is_arrival = pid >= 0
    exp_col, ring_cols = _ring_columns(is_arrival, end, total_slots + T + 1)

    exp_row = np.where(is_arrival, end % ring_k, ring_k + 1).astype(np.int32)
    drain_row = (slot % ring_k).astype(np.int32)
    prev = slot - 1
    sample = (
        new_slot & (prev >= warm) & ((prev - warm) % SAMPLE_EVERY == 0)
    )
    measuring = is_arrival & (slot >= warm)

    prio = tenant = wlive = None
    if queued:  # drawn after the shared stream: arrival sampling unchanged
        tenant = np.zeros((runs, e_max), dtype=np.int32)
        prio = np.zeros((runs, e_max), dtype=np.int32)
        for r in range(runs):
            sel = is_arrival[r]
            na = int(sel.sum())
            tenant[r, sel] = rng.integers(0, max(1, cfg.num_tenants), size=na)
            prio[r, sel] = rng.integers(0, max(1, cfg.num_priorities), size=na)
        wlive = slot < total_slots  # padding/sentinel lanes have no clock
        tenant, prio, wlive = tenant.T, prio.T, wlive.T

    fail = recover = None
    if fault_model is not None:  # drawn strictly after every other draw
        spec = cfg.spec()
        fail_s, rec_s = presample_fault_slots(
            spec, fault_model, runs, total_slots, rng
        )
        m = spec.num_gpus
        fail = np.zeros((runs, e_max, m), dtype=bool)
        recover = np.zeros((runs, e_max, m), dtype=bool)
        first = new_slot & (slot < total_slots)  # sentinel/padding carry none
        rr_idx, ee_idx = np.nonzero(first)
        fail[rr_idx, ee_idx] = fail_s[rr_idx, slot[rr_idx, ee_idx]]
        recover[rr_idx, ee_idx] = rec_s[rr_idx, slot[rr_idx, ee_idx]]
        fail = np.ascontiguousarray(fail.transpose(1, 0, 2))
        recover = np.ascontiguousarray(recover.transpose(1, 0, 2))

    events = EventStream(
        pid=pid.T,
        exp_row=exp_row.T,
        exp_col=exp_col.T,
        drain_row=drain_row.T,
        new_slot=new_slot.T,
        sample=sample.T,
        measuring=measuring.T,
        slot=slot.T.astype(np.int32) if queued else None,
        end=end.T.astype(np.int32) if queued else None,
        prio=prio,
        tenant=tenant,
        wlive=wlive,
        fail=fail,
        recover=recover,
    )
    meta = EventMeta(slot=slot.T, end=end.T)
    return events, meta, ring_k + 2, ring_cols


def presample_cumulative(
    cfg: SimConfig, runs: int, seed=None
) -> Tuple[EventStream, EventMeta, int, int]:
    """Build per-replica cumulative-protocol event streams on host.

    One arrival per slot (the paper-literal protocol — no heartbeats, no
    padding), durations ``U[1, T]``.  Replica ``r`` consumes the *same*
    RNG stream as the Python simulator's run ``r`` (seed
    ``cfg.seed + r * 9973``, profiles then durations), so
    :func:`run_batched` and :func:`repro.sim.simulator.run_many` simulate
    identical arrival processes per seed — the cross-engine cumulative
    parity is same-stream, not just statistical.
    """
    base_seed = cfg.seed if seed is None else seed
    spec = cfg.spec()
    cap = spec.total_mem_slices
    probs = request_probs(cfg)
    mean_mem = distributions.mean_mem_from_probs(probs)
    T = int(np.ceil(cap / mean_mem))
    n = int(np.ceil(cfg.max_demand * cap / mean_mem)) + 20
    ring_k = T + 1

    pid = np.zeros((runs, n), dtype=np.int32)
    end = np.zeros((runs, n), dtype=np.int64)
    for r in range(runs):
        rng = np.random.default_rng(base_seed + r * 9973)
        pid[r] = distributions.sample_profile_probs(probs, n, rng)
        end[r] = np.arange(n) + rng.integers(1, T + 1, size=n)

    slot = np.tile(np.arange(n, dtype=np.int32), (runs, 1))
    new_slot = np.ones((runs, n), dtype=bool)
    exp_col, ring_cols = _ring_columns(np.ones_like(pid, bool), end, n + T + 1)
    exp_row = (end % ring_k).astype(np.int32)
    drain_row = (slot % ring_k).astype(np.int32)

    events = EventStream(
        pid=pid.T,
        exp_row=exp_row.T,
        exp_col=exp_col.T,
        drain_row=drain_row.T,
        new_slot=new_slot.T,
        sample=np.zeros((n, runs), dtype=bool),
        measuring=np.ones((n, runs), dtype=bool),
    )
    meta = EventMeta(slot=slot.T, end=end.T)
    return events, meta, ring_k + 2, ring_cols


def _replica_sharding(runs: int, shard: Optional[bool] = None):
    """The replica-axis ``NamedSharding`` for ``(E, R)`` inputs, or ``None``.

    ``shard=None`` (auto) shards when more than one device is visible and
    ``runs`` divides evenly; ``True`` requires it (raises otherwise);
    ``False`` disables.  Factored out of :func:`shard_events` so the
    chunked driver can place every staged chunk on the same mesh.
    """
    if shard is False:
        return None
    devices = jax.devices()
    if len(devices) <= 1:
        if shard:
            raise ValueError(
                "replica sharding requested but only one device is visible"
            )
        return None
    if runs % len(devices) != 0:
        if shard:
            raise ValueError(
                f"runs={runs} does not divide across {len(devices)} devices"
            )
        return None
    mesh = jax.make_mesh((len(devices),), ("replicas",))
    return jax.sharding.NamedSharding(
        mesh, jax.sharding.PartitionSpec(None, "replicas")
    )


def shard_events(events, runs: int, shard: Optional[bool] = None):
    """Split the replica axis of a device event stream across devices.

    Replicas are embarrassingly parallel (no cross-replica arithmetic on
    device), so placing the ``(E_max, R)`` inputs on a 1-D ``replicas``
    mesh lets XLA partition the whole scan — bitwise-identical results,
    R/D replicas of work per device.  ``shard=None`` (auto) shards when
    more than one device is visible and ``runs`` divides evenly; ``True``
    requires it (raises otherwise); ``False`` disables.

    Leaves already committed to an equivalent sharding are returned as-is
    (no transfer), so repeated ``run_batched`` calls over the same placed
    stream never re-copy the full event pytree host→device.
    """
    sharding = _replica_sharding(runs, shard)
    if sharding is None:
        return events

    def put(x):
        if (
            isinstance(x, jax.Array)
            and getattr(x, "committed", False)
            and x.sharding.is_equivalent_to(sharding, x.ndim)
        ):
            return x  # already placed — skip the device_put
        return jax.device_put(x, sharding)

    return jax.tree.map(put, events)


# ---------------------------------------------------------------------------
# Chunked streaming driver — double-buffered host→device feed, donated carry
# ---------------------------------------------------------------------------


def init_carry(
    runs: int,
    *,
    policy: PolicyLike,
    metric: str,
    num_gpus: int,
    ring_rows: int,
    ring_cols: int,
    use_kernel: bool = False,
    kernel_spec: Optional[mig.ClusterSpec] = None,
    protocol: Union[str, Protocol] = "steady",
    wait_slots: int = 0,
    wait_patience: int = 0,
    midx: Optional[jax.Array] = None,
    tables: Optional[SpecTables] = None,
) -> ReplicaState:
    """The initial ``(runs,)``-vmapped chunk carry for one configuration.

    This is the *same* initial state :func:`_simulate` builds internally —
    chunking the scan at any boundary is bit-exact because the carry holds
    every cross-event datum (occupancy planes, expiry/wait rings, cursor,
    event counter).  Also the checkpoint *template*: build it from the
    identical static configuration to restore a saved carry via
    :func:`load_stream_checkpoint`.

    Delegates to a jitted builder so repeated chunked runs of one
    configuration pay the table/broadcast construction once at compile
    time; every call returns fresh buffers (safe to donate into the
    first chunk).
    """
    return _init_carry_jit(
        midx, tables, runs=runs, ring_rows=ring_rows, ring_cols=ring_cols,
        policy=policy, metric=metric, num_gpus=num_gpus,
        use_kernel=use_kernel, kernel_spec=kernel_spec, protocol=protocol,
        wait_slots=wait_slots, wait_patience=wait_patience,
    )


@functools.partial(
    jax.jit,
    static_argnames=(
        "runs", "ring_rows", "ring_cols", "policy", "metric", "num_gpus",
        "use_kernel", "kernel_spec", "protocol", "wait_slots",
        "wait_patience",
    ),
)
def _init_carry_jit(
    midx, tables, *, runs, ring_rows, ring_cols, policy, metric, num_gpus,
    use_kernel, kernel_spec, protocol, wait_slots, wait_patience,
) -> ReplicaState:
    core, _, _ = _build_core(
        policy=policy, metric=metric, num_gpus=num_gpus,
        use_kernel=use_kernel, kernel_spec=kernel_spec, protocol=protocol,
        wait_slots=wait_slots, wait_patience=wait_patience,
        midx=midx, tables=tables,
    )
    return _broadcast_init(core, runs, ring_rows, ring_cols, wait_slots)


@functools.partial(
    jax.jit,
    donate_argnums=(0,),
    static_argnames=(
        "policy", "metric", "num_gpus", "use_kernel", "kernel_spec",
        "protocol", "wait_slots", "wait_patience",
    ),
)
def _scan_chunk(
    state: ReplicaState,  # donated: each chunk-step reuses its buffers in place
    events: EventStream,  # one chunk, each field (chunk, R)
    *,
    policy: PolicyLike,
    metric: str,
    num_gpus: int,
    use_kernel: bool,
    kernel_spec: Optional[mig.ClusterSpec] = None,
    protocol: Union[str, Protocol] = "steady",
    wait_slots: int = 0,
    wait_patience: int = 0,
    midx: Optional[jax.Array] = None,
    tables: Optional[SpecTables] = None,
) -> Tuple[ReplicaState, EventTrace]:
    """Scan one event chunk from an explicit carry (the chunked step).

    Identical scan body to :func:`_simulate` (same :func:`_build_core`
    path, same vmapped :meth:`EngineCore.step`), with the carry passed in
    instead of built internally and its input buffers **donated** — XLA
    writes the updated carry back into the chunk's input storage, so the
    resident state footprint stays one carry regardless of chunk count.
    """
    core, _, _ = _build_core(
        policy=policy, metric=metric, num_gpus=num_gpus,
        use_kernel=use_kernel, kernel_spec=kernel_spec, protocol=protocol,
        wait_slots=wait_slots, wait_patience=wait_patience,
        midx=midx, tables=tables,
    )
    step = jax.vmap(core.step, in_axes=(0, 0))
    return jax.lax.scan(
        lambda st, x: step(st, x), state, _scan_xs(events, core.protocol)
    )


def save_stream_checkpoint(path, state: ReplicaState, events_done: int,
                           metadata: Optional[dict] = None) -> None:
    """Persist a chunked-scan carry (flat npz via :mod:`repro.checkpoint`).

    ``events_done`` — how many events of the stream the carry has consumed —
    is stored as the checkpoint step; resume by presampling the same
    ``(cfg, runs, seed)`` stream and calling :func:`simulate_chunked` with
    ``carry=state, start=events_done``.
    """
    from repro.checkpoint import ckpt

    host = jax.device_get(state)  # copy out before the next chunk donates it
    ckpt.save_checkpoint(
        path, host, step=int(events_done),
        metadata={"kind": "replica-carry", **(metadata or {})},
    )


def load_stream_checkpoint(path, template: ReplicaState) -> Tuple[ReplicaState, int]:
    """Restore a carry saved by :func:`save_stream_checkpoint`.

    ``template`` must come from :func:`init_carry` with the *identical*
    static configuration (the flat-npz restore validates structure and
    shapes, so a carry from a different policy/protocol/ring geometry
    fails loudly).  Returns ``(state, events_done)``.
    """
    from repro.checkpoint import ckpt

    return ckpt.load_checkpoint(path, template)


def _concat_traces(traces, concat):
    """Concatenate per-chunk :class:`EventTrace` pytrees along the event
    axis; fields compiled out (``None``) stay ``None``."""
    if len(traces) == 1:
        return traces[0]
    return EventTrace(*[
        None if getattr(traces[0], name) is None
        else concat([getattr(t, name) for t in traces], axis=0)
        for name in EventTrace._fields
    ])


def simulate_chunked(
    events: EventStream,  # host-resident stream, each field (E_max, R)
    *,
    chunk_size: int,
    policy: PolicyLike,
    metric: str,
    num_gpus: int,
    ring_rows: int,
    ring_cols: int,
    use_kernel: bool = False,
    kernel_spec: Optional[mig.ClusterSpec] = None,
    protocol: Union[str, Protocol] = "steady",
    wait_slots: int = 0,
    wait_patience: int = 0,
    midx: Optional[jax.Array] = None,
    tables: Optional[SpecTables] = None,
    stream: bool = True,
    carry: Optional[ReplicaState] = None,
    start: int = 0,
    shard: Optional[bool] = None,
    checkpoint_path=None,
    checkpoint_every: int = 0,
    stats: Optional[dict] = None,
) -> Tuple[ReplicaState, EventTrace]:
    """Drive the event scan in chunks with a double-buffered device feed.

    Bit-for-bit equal to :func:`_simulate` on the same stream for *any*
    ``chunk_size`` (the carry holds every cross-event datum, and both paths
    compile the same :meth:`EngineCore.step`), but device memory holds only
    one carry plus two staged chunks instead of the full ``(E_max, R)``
    event tensor and ``(E_max, R)`` trace:

    * the carry lives on device across chunks and is **donated** into each
      :func:`_scan_chunk` call (in-place buffer reuse);
    * chunk ``k+1`` is ``device_put`` while chunk ``k``'s compute is in
      flight (dispatch is asynchronous), so host→device transfer overlaps
      compute — the overlapped fraction is reported via ``stats``;
    * with ``stream=True`` (default) each chunk's decision trace is fetched
      back and concatenated host-side, so full traces never accumulate on
      device; ``stream=False`` keeps them on device (explicit opt-in).

    ``carry``/``start`` resume a run mid-stream (see
    :func:`load_stream_checkpoint`); a passed-in carry is *consumed* (its
    buffers are donated to the first chunk).  ``checkpoint_path`` +
    ``checkpoint_every`` (in chunks) persist the carry periodically through
    :mod:`repro.checkpoint.ckpt`.  ``shard`` places every staged chunk on
    the replica-axis mesh (see :func:`_replica_sharding`).  ``stats``, when
    given, is filled with chunk/transfer telemetry, including
    ``h2d_overlap_frac`` — the fraction of host→device bytes staged while a
    chunk compute was in flight (all puts except the first prefetch).
    """
    if chunk_size <= 0:
        raise ValueError(f"chunk_size must be positive, got {chunk_size}")
    e_max, runs = events.pid.shape
    if not 0 <= start < e_max:
        raise ValueError(f"start={start} outside the event stream [0, {e_max})")
    sharding = _replica_sharding(runs, shard)
    statics = dict(
        policy=policy, metric=metric, num_gpus=num_gpus,
        use_kernel=use_kernel, kernel_spec=kernel_spec, protocol=protocol,
        wait_slots=wait_slots, wait_patience=wait_patience,
        midx=midx, tables=tables,
    )
    state = carry if carry is not None else init_carry(
        runs, ring_rows=ring_rows, ring_cols=ring_cols, **statics
    )
    if state.ring_gpu.shape[-2:] != (ring_rows, ring_cols):
        raise ValueError(
            f"carry ring geometry {state.ring_gpu.shape[-2:]} does not match "
            f"this stream's ({ring_rows}, {ring_cols}) — resumed with a carry "
            "from a different presample?"
        )
    host = jax.tree.map(np.asarray, events)  # host slicing source
    bounds = list(range(start, e_max, chunk_size)) + [e_max]
    n_chunks = len(bounds) - 1
    h2d_s = h2d_overlap_s = d2h_s = 0.0
    h2d_bytes = h2d_overlap_bytes = 0

    def put(lo, hi):
        ch = jax.tree.map(lambda x: x[lo:hi], host)
        nbytes = sum(x.nbytes for x in jax.tree.leaves(ch))
        t0 = time.perf_counter()
        # one batched transfer for the whole chunk pytree (a single
        # Sharding broadcasts across leaves), not one dispatch per field
        dev = (
            jax.device_put(ch, sharding) if sharding is not None
            else jax.device_put(ch)
        )
        return dev, time.perf_counter() - t0, nbytes

    buf, dt, nb = put(bounds[0], bounds[1])  # prefetch chunk 0 (not overlapped)
    h2d_s += dt
    h2d_bytes += nb
    state, tr = _scan_chunk(state, buf, **statics)  # async dispatch
    traces = []
    for k in range(n_chunks):
        # chunk k's scan is already in flight; ``state`` is its output carry
        if checkpoint_path and checkpoint_every and (k + 1) % checkpoint_every == 0:
            # copy the post-chunk-k carry out *before* the next dispatch
            # donates its buffers (a deliberate pipeline bubble)
            save_stream_checkpoint(checkpoint_path, state, bounds[k + 1])
        if k + 1 < n_chunks:
            # stage chunk k+1 and dispatch its scan before blocking on
            # chunk k's trace, so the d2h fetch below overlaps compute
            buf, dt, nb = put(bounds[k + 1], bounds[k + 2])
            h2d_s += dt
            h2d_bytes += nb
            h2d_overlap_s += dt
            h2d_overlap_bytes += nb
            state, tr_next = _scan_chunk(state, buf, **statics)
        if stream:
            t0 = time.perf_counter()
            traces.append(jax.device_get(tr))  # joins chunk k's compute
            d2h_s += time.perf_counter() - t0
        else:
            traces.append(tr)
        if k + 1 < n_chunks:
            tr = tr_next
    if stats is not None:
        stats.update(
            chunks=n_chunks,
            chunk_size=chunk_size,
            events=e_max - start,
            h2d_seconds=h2d_s,
            h2d_overlapped_seconds=h2d_overlap_s,
            h2d_bytes=h2d_bytes,
            h2d_overlapped_bytes=h2d_overlap_bytes,
            h2d_overlap_frac=(
                h2d_overlap_bytes / h2d_bytes if h2d_bytes else 0.0
            ),
            d2h_seconds=d2h_s,
        )
    concat = np.concatenate if stream else jnp.concatenate
    return state, _concat_traces(traces, concat)


def run_batched(
    policy: PolicyLike,
    cfg: SimConfig,
    runs: int = 64,
    use_kernel: bool | None = None,
    shard: Optional[bool] = None,
    chunk_size: Optional[int] = None,
    stream: Optional[bool] = None,
    stats: Optional[dict] = None,
) -> Dict[str, float]:
    """Average ``runs`` replicas in one device program.

    Drop-in for :func:`repro.sim.simulator.run_many` on both protocols
    (same aggregate keys; the cumulative protocol additionally returns the
    demand-grid ``traces``); ``policy`` is any batched-capable registered
    policy name or an ad-hoc :class:`~repro.core.policy.PolicySpec`
    (validated through the registry's single path, like every other entry
    point) — defrag specs included (the migrate stage is compiled into the
    scan).  ``use_kernel`` routes scoring through the Pallas kernels
    (default: only on TPU): the fused ``delta_from_base`` ΔF kernel with
    per-model dispatch on any fleet (for specs whose keys consume ΔF), plus
    the occupancy-based ``fragscore`` rescore kernel on homogeneous specs
    (it bakes in one model's placement table).  A spec may opt out via
    ``PolicySpec.kernel_lowering=False`` (requesting ``use_kernel=True``
    for such a spec raises).  ``shard`` splits the replica axis across
    visible devices (see :func:`shard_events`; default: auto).

    ``chunk_size`` routes the run through the chunked streaming driver
    (:func:`simulate_chunked`): device memory holds one carry plus two
    staged event chunks instead of the full ``(E_max, R)`` tensors —
    bit-identical results for any chunk size.  ``stream`` (chunked only;
    default ``True``) fetches each chunk's trace back as it completes so
    traces never accumulate on device; ``stats`` (chunked only) receives
    transfer/overlap telemetry.  ``chunk_size=None`` (default) keeps
    today's single-chunk monolithic scan.
    """
    policy = resolve(policy, engine="batched")
    proto = resolve_protocol(cfg.protocol)
    spec = cfg.spec()
    if use_kernel is None:
        use_kernel = bool(
            jax.default_backend() == "tpu" and policy.kernel_lowering
        )
    if use_kernel and not policy.kernel_lowering:
        raise ValueError(
            f"policy {policy.name!r} opts out of Pallas kernel lowering "
            "(PolicySpec.kernel_lowering=False); run with use_kernel=False"
        )
    if chunk_size is not None and chunk_size <= 0:
        raise ValueError(f"chunk_size must be positive, got {chunk_size}")
    if chunk_size is None and (stream is not None or stats is not None):
        raise ValueError(
            "stream/stats are chunked-driver knobs; pass chunk_size as well"
        )
    if proto.faulted:
        if cfg.fault_model is None:
            raise ValueError(
                f"protocol {proto.name!r} needs SimConfig.fault_model "
                "(a repro.core.mig.FaultModel describing MTBF/MTTR)"
            )
        # retry/backoff ride in the (static, hashable) protocol descriptor
        proto = dataclasses.replace(
            proto,
            fault_retries=cfg.fault_model.max_retries,
            fault_backoff=cfg.fault_model.backoff_base,
        )

    if proto.name == "cumulative":
        events, _, ring_rows, ring_cols = presample_cumulative(cfg, runs)
    else:
        events, _, ring_rows, ring_cols = presample_arrivals(
            cfg, runs, queued=proto.queued,
            fault_model=cfg.fault_model if proto.faulted else None,
        )
    common = dict(
        policy=policy,
        metric=cfg.metric,
        num_gpus=cfg.num_gpus,
        ring_rows=ring_rows,
        ring_cols=ring_cols,
        use_kernel=use_kernel,
        kernel_spec=spec if use_kernel else None,
        protocol=proto,
        wait_slots=cfg.wait_capacity if proto.queued else 0,
        wait_patience=cfg.wait_patience if proto.queued else 0,
        midx=jnp.asarray(spec.model_index),
        tables=spec_tables(spec),
    )
    if chunk_size is not None:
        _, trace = simulate_chunked(
            events,
            chunk_size=chunk_size,
            stream=True if stream is None else stream,
            shard=shard,
            stats=stats,
            **common,
        )
        trace = jax.device_get(trace)  # no-op for already-streamed traces
    else:
        events_dev = shard_events(jax.tree.map(jnp.asarray, events), runs, shard)
        _, trace = jax.device_get(_simulate(events_dev, **common))
    if proto.name == "cumulative":
        return _aggregate_cumulative(events, trace, spec, runs, cfg)
    if proto.faulted:
        return _aggregate_faulted(events, trace, spec, runs)
    if proto.queued:
        return _aggregate_queued(events, trace, spec, runs)
    return aggregate(events, trace, spec, runs)


def aggregate(
    events: EventStream, trace: EventTrace, spec, runs: int
) -> Dict[str, float]:
    """Reduce per-event steady traces against host-known flags to
    ``run_many`` keys.

    ``spec`` is the ClusterSpec (or an int GPU count, back-compat).
    """
    if isinstance(spec, int):
        spec = _default_spec(spec)
    cap = float(spec.total_mem_slices)
    ok = np.asarray(trace.ok)
    meas = events.measuring
    samp = events.sample

    arrived = np.maximum(meas.sum(axis=0), 1)  # (R,)
    accepted = (ok & meas).sum(axis=0)
    nsamp = np.maximum(samp.sum(axis=0), 1)
    util = ((cap - trace.free_sum) / cap * samp).sum(axis=0) / nsamp
    active = (trace.active * samp).sum(axis=0) / nsamp
    frag = (trace.frag * samp).sum(axis=0) / nsamp
    arrivals_p = np.stack(
        [((events.pid == p) & meas).sum() for p in range(mig.NUM_PROFILES)]
    )
    rejects_p = np.stack(
        [((events.pid == p) & meas & ~ok).sum() for p in range(mig.NUM_PROFILES)]
    )
    return {
        "acceptance_rate": float((accepted / arrived).mean()),
        "allocated_workloads": float(accepted.mean()),
        "active_gpus": float(active.mean()),
        "utilization": float(util.mean()),
        "frag_severity": float(frag.mean()),
        "rejects_by_profile": rejects_p / runs,
        "arrivals_by_profile": arrivals_p / runs,
    }


def _aggregate_queued(
    events: EventStream, trace: EventTrace, spec, runs: int
) -> Dict[str, float]:
    """Reduce queued-protocol traces: acceptance folds in wait-admits, plus
    p50/p99 wait and Jain per-tenant fairness.

    The device trace records each wait-admit's *original* event index
    (``wadm_eidx``), so late acceptances and their waits reconstruct
    host-side: arrival ``e`` was ultimately accepted iff it was accepted
    in place (``ok``) or some later event admitted it from the wait ring;
    its wait is the slot distance between the two events (0 when
    immediate).  Acceptance/fairness attribute to the original arrival's
    measurement-window membership, exactly like the host simulator
    (:func:`repro.sim.simulator._run_steady_queued`).
    """
    if isinstance(spec, int):
        spec = _default_spec(spec)
    cap = float(spec.total_mem_slices)
    ok = np.asarray(trace.ok)
    wadm = np.asarray(trace.wadm_eidx)   # (E, R)
    slot = np.asarray(events.slot)
    tenant = np.asarray(events.tenant)
    meas = events.measuring
    samp = events.sample

    late_ok = np.zeros_like(ok)
    wait = np.zeros(ok.shape, np.float64)
    for r in range(runs):
        adm = np.flatnonzero(wadm[:, r] >= 0)
        orig = wadm[adm, r]
        late_ok[orig, r] = True
        wait[orig, r] = slot[adm, r] - slot[orig, r]
    acc_all = ok | late_ok

    arrived = np.maximum(meas.sum(axis=0), 1)  # (R,)
    accepted = (acc_all & meas).sum(axis=0)
    nsamp = np.maximum(samp.sum(axis=0), 1)
    util = ((cap - trace.free_sum) / cap * samp).sum(axis=0) / nsamp
    active = (trace.active * samp).sum(axis=0) / nsamp
    frag = (trace.frag * samp).sum(axis=0) / nsamp

    p50 = np.zeros(runs)
    p99 = np.zeros(runs)
    fair = np.zeros(runs)
    for r in range(runs):
        w = wait[:, r][acc_all[:, r] & meas[:, r]]
        p50[r] = np.percentile(w, 50) if len(w) else 0.0
        p99[r] = np.percentile(w, 99) if len(w) else 0.0
        tm = meas[:, r]
        rates = [
            (acc_all[:, r] & tm & (tenant[:, r] == tn)).sum()
            / (tm & (tenant[:, r] == tn)).sum()
            for tn in np.unique(tenant[:, r][tm])
        ]
        fair[r] = jain_fairness(rates)

    arrivals_p = np.stack(
        [((events.pid == p) & meas).sum() for p in range(mig.NUM_PROFILES)]
    )
    rejects_p = np.stack(
        [((events.pid == p) & meas & ~acc_all).sum() for p in range(mig.NUM_PROFILES)]
    )
    return {
        "acceptance_rate": float((accepted / arrived).mean()),
        "allocated_workloads": float(accepted.mean()),
        "active_gpus": float(active.mean()),
        "utilization": float(util.mean()),
        "frag_severity": float(frag.mean()),
        "rejects_by_profile": rejects_p / runs,
        "arrivals_by_profile": arrivals_p / runs,
        "wait_p50": float(p50.mean()),
        "wait_p99": float(p99.mean()),
        "fairness": float(fair.mean()),
        "queue_admits": float((late_ok & meas).sum(axis=0).mean()),
    }


def _aggregate_faulted(
    events: EventStream, trace: EventTrace, spec, runs: int
) -> Dict[str, float]:
    """Reduce faulted-protocol traces: the queued keys plus failure stats.

    The extra keys come from a host-side walk of the decision trace against
    the stream's fail lanes, reconstructing each workload's lifecycle
    (admit → maybe evict → maybe re-admit → complete):

    * ``goodput`` — fraction of measured arrivals whose lease *completed*
      (reached its end slot, or was still running at the horizon); an
      admitted-then-evicted-never-re-admitted workload counts against it;
    * ``evictions`` / ``evictions_lost`` — mean per-replica eviction count
      and the subset dropped outright (wait ring full or zero retry budget);
    * ``recovered_fraction`` — evictions later re-admitted / evictions
      (1.0 when nothing was evicted);
    * ``ttr_p50`` / ``ttr_p99`` — per-replica percentiles of the
      time-to-recovery (slots between eviction and re-admission), averaged.
    """
    if isinstance(spec, int):
        spec = _default_spec(spec)
    out = _aggregate_queued(events, trace, spec, runs)

    slot = np.asarray(events.slot)
    end = np.asarray(events.end)
    fail = np.asarray(events.fail)      # (E, R, M)
    wlive = np.asarray(events.wlive)
    new_slot = np.asarray(events.new_slot)
    meas = np.asarray(events.measuring)
    ok = np.asarray(trace.ok)
    gpu_tr = np.asarray(trace.gpu)
    wadm = np.asarray(trace.wadm_eidx)
    wgpu = np.asarray(trace.wadm_gpu)
    e_max = ok.shape[0]

    goodput = np.zeros(runs)
    recovered = np.zeros(runs)
    ttr_p50 = np.zeros(runs)
    ttr_p99 = np.zeros(runs)
    for r in range(runs):
        alive = {}    # original event index -> (gpu, end slot)
        done = set()  # leases that ran to completion
        pending = {}  # eviction awaiting re-admission -> eviction slot
        n_evict = 0
        n_recovered = 0
        ttrs = []
        for e in range(e_max):
            if not wlive[e, r]:
                continue
            t = slot[e, r]
            if new_slot[e, r]:
                # expire before faults — the device order: a lease ending
                # the very slot its GPU dies still completes
                for k in [k for k, (_, kend) in alive.items() if kend <= t]:
                    del alive[k]
                    done.add(k)
                downs = set(np.flatnonzero(fail[e, r]).tolist())
                if downs:
                    for k in [k for k, (g, _) in alive.items() if g in downs]:
                        del alive[k]
                        pending[k] = t
                        n_evict += 1
            a = int(wadm[e, r])
            if a >= 0:
                alive[a] = (int(wgpu[e, r]), int(end[a, r]))
                if a in pending:
                    n_recovered += 1
                    ttrs.append(t - pending.pop(a))
            if ok[e, r]:
                alive[e] = (int(gpu_tr[e, r]), int(end[e, r]))
        done.update(alive)  # still running at the horizon: never disrupted
        m = meas[:, r]
        goodput[r] = sum(1 for k in done if m[k]) / max(1, int(m.sum()))
        recovered[r] = (n_recovered / n_evict) if n_evict else 1.0
        ttr_p50[r] = np.percentile(ttrs, 50) if ttrs else 0.0
        ttr_p99[r] = np.percentile(ttrs, 99) if ttrs else 0.0

    out.update(
        goodput=float(goodput.mean()),
        evictions=float(np.asarray(trace.evicted).sum(axis=0).mean()),
        evictions_lost=float(np.asarray(trace.evict_lost).sum(axis=0).mean()),
        recovered_fraction=float(recovered.mean()),
        ttr_p50=float(ttr_p50.mean()),
        ttr_p99=float(ttr_p99.mean()),
    )
    return out


def _aggregate_cumulative(
    events: EventStream, trace: EventTrace, spec, runs: int, cfg: SimConfig
) -> Dict[str, float]:
    """Reduce per-event cumulative traces to ``run_many`` keys + demand-grid
    traces, replicating the Python simulator's grid-crossing and early-stop
    semantics exactly (both are host-computable from the presampled pids).
    """
    cap = float(spec.total_mem_slices)
    pid = np.asarray(events.pid)           # (E, R)
    ok = np.asarray(trace.ok)
    post_free = np.asarray(trace.post_free)
    post_active = np.asarray(trace.post_active)
    post_frag = np.asarray(trace.post_frag)
    e_max, _ = pid.shape

    frac = np.cumsum(mig.PROFILE_MEM[pid], axis=0) / cap  # (E, R)
    acc_cum = np.cumsum(ok, axis=0)                       # (E, R)
    arr_cum = np.arange(1, e_max + 1)[:, None]            # (E, 1)
    util = (cap - post_free) / cap

    grid = np.asarray(cfg.demand_grid, dtype=np.float64)
    G = len(grid)
    keys = (
        "acceptance_rate", "allocated_workloads", "active_gpus",
        "utilization", "frag_severity",
    )
    per_event = {
        "acceptance_rate": acc_cum / arr_cum,
        "allocated_workloads": acc_cum.astype(np.float64),
        "active_gpus": post_active.astype(np.float64),
        "utilization": util,
        "frag_severity": post_frag.astype(np.float64),
    }
    traces = {k: np.zeros((G, runs)) for k in keys}
    for i in range(G):
        crossed = frac >= grid[i]             # (E, R)
        hit = crossed.any(axis=0)             # (R,)
        idx = np.argmax(crossed, axis=0)      # first crossing event (per replica)
        for k in keys:
            v = per_event[k][idx, np.arange(runs)]
            if i > 0:  # tail-fill: an uncrossed point repeats the last recorded
                v = np.where(hit, v, traces[k][i - 1])
            else:
                v = np.where(hit, v, 0.0)
            traces[k][i] = v

    # early stop: the Python loop breaks once demand reached max_demand AND
    # every grid point was recorded — both depend only on the pid stream
    stop_at = max(float(cfg.max_demand), float(grid[-1]) if G else 0.0)
    stopped = frac >= stop_at
    stop = np.where(stopped.any(axis=0), np.argmax(stopped, axis=0), e_max - 1)
    ridx = np.arange(runs)
    processed = np.arange(e_max)[:, None] <= stop[None, :]  # (E, R)

    arrivals_p = np.stack(
        [((pid == p) & processed).sum() for p in range(mig.NUM_PROFILES)]
    )
    rejects_p = np.stack(
        [((pid == p) & processed & ~ok).sum() for p in range(mig.NUM_PROFILES)]
    )
    return {
        "acceptance_rate": float(per_event["acceptance_rate"][stop, ridx].mean()),
        "allocated_workloads": float(acc_cum[stop, ridx].mean()),
        "active_gpus": float(post_active[stop, ridx].mean()),
        "utilization": float(util[stop, ridx].mean()),
        "frag_severity": float(post_frag[stop, ridx].mean()),
        "rejects_by_profile": rejects_p / runs,
        "arrivals_by_profile": arrivals_p / runs,
        "traces": {k: v.mean(axis=1) for k, v in traces.items()},
        "demand_grid": grid,
    }
