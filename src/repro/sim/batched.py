"""Batched on-device Monte-Carlo simulation engine (steady protocol).

The Python reference in :mod:`repro.sim.simulator` runs replicas one at a
time through a ``ClusterState``/``heapq`` event loop; at the paper's scale
(500 replicas per point, §VI) a load sweep takes hours.  This module runs
**R replicas × T slots as one** ``lax.scan`` **over a vmapped replica axis**
so the whole Monte-Carlo average is a single XLA program.

Event stream
    Arrivals are pre-sampled on host (Poisson counts, profile ids and
    durations per slot) and flattened into one *event stream* per replica:
    one event per arrival, plus one synthetic heartbeat event for every
    empty slot so consecutive events never skip a slot.  Streams are padded
    to the longest replica (``pid = -1`` lanes are no-ops), and everything
    slot-dependent (release ring row, metric-sample flags, measurement
    window membership) is precomputed host-side, so the device step is pure
    tensor algebra with no clock arithmetic.

Heterogeneous fleets
    A :class:`repro.core.mig.ClusterSpec` (``SimConfig.cluster_spec``) may
    mix device models.  All per-model placement tables are stacked into one
    :class:`SpecTables` pytree — ``(K, N, ...)`` arrays padded to a common
    placement count ``N`` and anchor count ``A`` — and a static ``(M,)``
    model-index array ``midx`` gathers each GPU's tables inside the scan
    step.  The MFI ΔF table becomes a per-model gather plus one batched
    matmul (``einsum('mn,man->ma')``), so the scan stays fully jittable;
    the paper's homogeneous setup is the trivial ``K = 1`` spec and
    reproduces the previous engine bit-for-bit.

Replica state (fixed-capacity struct-of-arrays pytree)
    * ``occ (M, S) int32`` — cluster occupancy bitmap (materialized only
      when the Pallas-kernel scoring path needs it; otherwise ``base``
      carries the full information);
    * ``base (M, N) float32`` — occupied-slice count per placement window
      of each GPU's own model, ``occ @ W[midx]ᵀ``.  Window counts are
      *linear* in occupancy, so ``base`` is maintained incrementally (row
      add on commit, row subtract on release) and every fragmentation
      quantity — F(m), the full MFI ΔF table, feasibility — derives from
      it without per-arrival matmuls over hypothetical occupancies;
    * ``free (M,) int32`` / ``f (M,) float32`` — free-slice counts and
      per-GPU fragmentation scores, recomputed only for rows a drain or
      commit touched;
    * ``rr () int32`` — RoundRobin cursor (next GPU to try first); carried
      through the scan so RR is an ordinary batched policy;
    * an expiry ring buffer ``ring_gpu (K+2, E) int32`` /
      ``ring_mask (K+2, E, S) int32`` keyed by end slot modulo
      ``K = T + 1``: row ``e % K`` holds the (gpu, placement-window) rows
      of workloads expiring at slot ``e``.  Durations are drawn from
      ``[1, T]``, so an end slot is strictly less than one ring revolution
      ahead and each row is drained (masked scatter-subtract) exactly when
      the clock reaches it, before it can be re-targeted.  Within-row
      columns are assigned on host (arrival rank among same-end-slot
      arrivals), so inserts never collide; row ``K + 1`` is a write-only
      trash row for padding lanes.

Policies are **compiled from declarative**
:class:`repro.core.policy.PolicySpec` **registry entries** — the same specs
the host engine interprets (:mod:`repro.core.schedulers`), so the two
engines cannot drift by construction.  :func:`_lower_select` lowers a
spec's ordered lexicographic key list to a masked refinement over the
``(M, A)`` feasibility tensor (each key narrows the candidate mask to its
minimizers; the first surviving flat index supplies the implicit
``(gpu, anchor)`` tie-break), with the ΔF table computed only for specs
whose keys ask for it.  The spec itself is the static jit argument, so any
newly registered batched-capable policy runs without touching this module.
Acceptance, utilization, active-GPU and fragmentation-severity metrics
accumulate inside the scan; :func:`run_batched` returns the same aggregate
dict as :func:`repro.sim.simulator.run_many`.

Parity guarantees vs the Python reference (``tests/test_batched_sim.py``,
``tests/test_heterogeneous.py``):

* single-step decisions of every batched-capable registered policy match
  their host-compiled ``Scheduler.select`` counterparts *exactly*
  (including rejects and tie-breaks — every scoring-key value is
  integer-valued, hence exact in float32), on homogeneous and mixed specs;
* whole-run acceptance rates agree within Monte-Carlo tolerance (the two
  engines consume their RNG streams differently, so trajectories are
  statistically — not bitwise — identical); driving the Python schedulers
  over the *same* presampled event stream matches decision-for-decision
  (:func:`repro.sim.replay.host_decisions`).

On TPU, per-GPU fragmentation rescoring (the rows each drain/commit
touches, which feed both MFI and the severity metric) routes through the
Pallas ``fragscore`` kernel (``interpret=False``) — homogeneous specs only
(the kernel bakes in one placement table); on CPU and on mixed fleets the
``base``-derived pure-jnp scoring is used.
"""

from __future__ import annotations

import functools
from typing import Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import cluster as jcluster
from repro.core import mig
from repro.core.policy import (
    PolicyLike,
    PolicySpec,
    key_base,
    list_policies,
    resolve,
)
from repro.sim import distributions
from repro.sim.simulator import SAMPLE_EVERY, SimConfig, steady_params

#: batched-capable registered policies at import time (back-compat alias;
#: `repro.core.policy.list_policies(engine="batched")` is the live view)
POLICIES = list_policies(engine="batched")

_BIG = jnp.float32(1e9)


# ---------------------------------------------------------------------------
# Stacked per-model placement tables
# ---------------------------------------------------------------------------


class SpecTables(NamedTuple):
    """Per-model placement tables of a ClusterSpec, stacked and padded.

    Axis glossary: ``K`` distinct models, ``N`` common (padded) placement
    count, ``A`` common (padded) anchor count, ``P`` demand classes,
    ``S`` memory slices.  Padded placement rows have all-zero windows and
    ``V = 0`` so they never count toward any score; padded anchor columns
    are marked invalid in ``profile_valid``.
    """

    W: jax.Array               # (K, N, S) float32 — placement windows
    V: jax.Array               # (K, N) float32 — window sizes (0 where padded)
    slices: jax.Array          # (K,) int32 — memory slices per model
    profile_rows: jax.Array    # (K, P, A) int32 — row into W/V per anchor
    profile_masks: jax.Array   # (K, P, A, S) int32 — anchor window bitmask
    profile_anchors: jax.Array  # (K, P, A) int32 — anchor index (-1 pad)
    profile_valid: jax.Array   # (K, P, A) bool — anchor validity
    profile_mem: jax.Array     # (K, P) float32 — slice demand per class
    maskwin: jax.Array         # (K, P, A, N) float32 — slices each anchor adds per window
    maskpos: jax.Array         # (K, P, A, N) float32 — (maskwin > 0)


@functools.lru_cache(maxsize=None)
def spec_tables(spec: mig.ClusterSpec) -> SpecTables:
    """Build (and cache) the stacked device tables of a cluster spec."""
    models = spec.models
    K = len(models)
    P = mig.NUM_PROFILES
    N = max(m.num_placements for m in models)
    A = max(m.max_anchors for m in models)
    S = spec.num_mem_slices

    W = np.zeros((K, N, S), np.float32)
    V = np.zeros((K, N), np.float32)
    slices = np.array([m.num_mem_slices for m in models], np.int32)
    rows_t = np.zeros((K, P, A), np.int32)
    masks_t = np.zeros((K, P, A, S), np.int32)
    anchors_t = np.full((K, P, A), -1, np.int32)
    valid_t = np.zeros((K, P, A), bool)
    mem_t = np.zeros((K, P), np.float32)
    for k, m in enumerate(models):
        n = m.num_placements
        W[k, :n, : m.num_mem_slices] = m.placement_masks
        V[k, :n] = m.placement_mem
        pm, pa, pv = jcluster._np_profile_tables(m, max_anchors=A)
        masks_t[k, :, :, : m.num_mem_slices] = pm
        anchors_t[k] = pa
        valid_t[k] = pv
        mem_t[k] = m.profile_mem
        for pid in range(P):
            s = m.profile_placement_rows(pid)
            rows_t[k, pid, : s.stop - s.start] = np.arange(s.start, s.stop)
    # occupied-slice count each profile anchor adds to every placement window
    maskwin = np.einsum("kpas,kns->kpan", masks_t.astype(np.float32), W)
    return SpecTables(
        W=jnp.asarray(W),
        V=jnp.asarray(V),
        slices=jnp.asarray(slices),
        profile_rows=jnp.asarray(rows_t),
        profile_masks=jnp.asarray(masks_t),
        profile_anchors=jnp.asarray(anchors_t),
        profile_valid=jnp.asarray(valid_t),
        profile_mem=jnp.asarray(mem_t),
        maskwin=jnp.asarray(maskwin),
        maskpos=jnp.asarray((maskwin > 0).astype(np.float32)),
    )


def _default_spec(num_gpus: int) -> mig.ClusterSpec:
    return mig.ClusterSpec.homogeneous(mig.A100_80GB, num_gpus)


# ---------------------------------------------------------------------------
# Fragmentation scoring from the window-count state
# ---------------------------------------------------------------------------


def _frag_from_base(base: jax.Array, free: jax.Array, metric: str, v: jax.Array) -> jax.Array:
    """F(m) per GPU from window counts ``base (M, N)`` and per-GPU window
    sizes ``v (M, N)`` (= ``V[midx]``): (M,) float32."""
    if metric == "partial":
        counted = (base > 0) & (base < v)
    else:  # blocked
        counted = base > 0
    eligible = v <= free[..., None].astype(jnp.float32)
    return jnp.sum(jnp.where(counted & eligible, v, 0.0), axis=-1)


def _delta_from_base(
    base: jax.Array,
    free: jax.Array,
    metric: str,
    v: jax.Array,
    mw: jax.Array,
    mp: jax.Array,
    mem_g: jax.Array,
    f_before: jax.Array,
) -> jax.Array:
    """ΔF of every anchor dry-run of the request: (M, A) float32.

    ``v (M, N)``, ``mw/mp (M, A, N)`` and ``mem_g (M,)`` are the per-GPU
    gathers ``V[midx]``, ``maskwin/maskpos[midx, pid]`` and
    ``profile_mem[midx, pid]``.  Window counts after placement are
    ``base + mw`` (exact for feasible placements — the window is disjoint
    from current occupancy), so for the "blocked" metric the
    counted-predicate decomposes as ``(base > 0) | (mw > 0)`` and the whole
    (M, A) table reduces to one batched (M, N) × (M, N, A) matmul;
    "partial" needs the dense (M, A, N) elementwise form.  All scores are
    integer-valued — exact in float32.
    """
    freef = free.astype(jnp.float32)
    free_after = freef - mem_g  # (M,) — same for every anchor
    elig = v <= free_after[:, None]  # (M, N)
    if metric == "partial":
        ba = base[:, None, :] + mw  # (M, A, N)
        counted = (ba > 0) & (ba < v[:, None, :])
        f_after = jnp.sum(
            jnp.where(counted & elig[:, None, :], v[:, None, :], 0.0), axis=-1
        )
    else:  # blocked: counted_after = (base > 0) | (mw > 0)
        cb = base > 0  # (M, N)
        s_occ = jnp.sum(jnp.where(cb & elig, v, 0.0), axis=-1)  # (M,)
        cross = jnp.einsum("mn,man->ma", jnp.where(~cb & elig, v, 0.0), mp)  # (M, A)
        f_after = s_occ[:, None] + cross
    return f_after - f_before[:, None]


def make_frag_fn(
    metric: str = "blocked",
    use_kernel: bool = False,
    model: mig.DeviceModel = mig.A100_80GB,
):
    """(N, S) occupancy -> (N,) F scores; Pallas kernel when ``use_kernel``."""
    if use_kernel:
        from repro.kernels.fragscore import fragscore as _k

        w = jnp.asarray(model.placement_masks, dtype=jnp.float32)
        v = jnp.asarray(model.placement_mem, dtype=jnp.float32)
        return lambda occ: _k.fragscore(occ, w, v, metric=metric, interpret=False)
    tables = jcluster.tables_for(model)
    return functools.partial(jcluster.frag_scores, metric=metric, tables=tables)


# ---------------------------------------------------------------------------
# PolicySpec lowering: lexicographic keys -> masked refinement argmin
# ---------------------------------------------------------------------------


def _key_tensor(base_key, feasible, free, mem_g, delta, anchors_g, cursor, midx):
    """One scoring key as an (M, A)-broadcastable float32 tensor.

    All key values are integer-valued (ΔF included — see
    :func:`_delta_from_base`), hence exact in float32: the refinement's
    equality comparisons are exact and the lowering matches the host
    interpreter bit-for-bit.
    """
    m, a = feasible.shape
    if base_key == "frag-delta":
        return delta  # (M, A)
    if base_key == "free-slices":
        return (free.astype(jnp.float32) - mem_g)[:, None]  # (M, 1)
    if base_key == "gpu":
        return jnp.arange(m, dtype=jnp.float32)[:, None]
    if base_key == "anchor":
        # real anchor VALUES (``profile_anchors[midx, pid]``), not padded
        # column indexes: on mixed fleets the index<->value mapping differs
        # per model, and the host interpreter compares values — padded
        # (-1) columns are masked infeasible so they never win
        return anchors_g.astype(jnp.float32)  # (M, A)
    if base_key == "rr-distance":
        prio = jnp.mod(jnp.arange(m, dtype=jnp.int32) - cursor, m)
        return prio.astype(jnp.float32)[:, None]
    if base_key == "model-group":
        return midx.astype(jnp.float32)[:, None]
    raise ValueError(f"unknown scoring key {base_key!r}")  # unreachable


def _lower_select(spec, feasible, free, mem_g, delta, anchors_g, cursor, midx):
    """Compile a spec's key list against the (M, A) feasibility tensor.

    Each key narrows the candidate mask to its minimizers (``-`` prefix
    negates); the first surviving flat index supplies the implicit
    ascending ``(gpu, anchor)`` tie-break — the same total order the host
    interpreter's lexsort produces.  Returns ``(gpu, aidx, ok)``.
    """
    mask = feasible
    for key in spec.keys:
        val = _key_tensor(
            key_base(key), feasible, free, mem_g, delta, anchors_g, cursor, midx
        )
        if key.startswith("-"):
            val = -val
        masked = jnp.where(mask, val, _BIG)
        mask = mask & (masked == masked.min())
    flat = mask.reshape(-1)
    k = jnp.argmax(flat)
    a = feasible.shape[1]
    return k // a, k % a, flat[k]


def _feasibility(base: jax.Array, rows: jax.Array, valid: jax.Array) -> jax.Array:
    """(M, A) bool — anchors whose window has zero occupied slices.

    ``rows (M, A)`` / ``valid (M, A)`` are the per-GPU gathers
    ``profile_rows[midx, pid]`` / ``profile_valid[midx, pid]``.
    """
    overlap = jnp.take_along_axis(base, rows, axis=1)  # (M, A)
    return (overlap == 0) & valid


def _select(spec, base, free, f, metric, tables, midx, vg, pid, cursor):
    """Shared decision path: returns (gpu, aidx, ok) for one request."""
    rows = tables.profile_rows[midx, pid]  # (M, A)
    valid = tables.profile_valid[midx, pid]  # (M, A)
    mem_g = tables.profile_mem[midx, pid]  # (M,)
    anchors_g = tables.profile_anchors[midx, pid]  # (M, A), -1 where padded
    feasible = _feasibility(base, rows, valid)
    if spec.requires_delta_f:  # ΔF table only for specs whose keys use it
        delta = _delta_from_base(
            base, free, metric, vg,
            tables.maskwin[midx, pid], tables.maskpos[midx, pid], mem_g, f,
        )
    else:
        delta = None
    return _lower_select(spec, feasible, free, mem_g, delta, anchors_g, cursor, midx)


def policy_select(
    occ: jax.Array,
    profile_id: jax.Array,
    policy: PolicyLike,
    metric: str = "blocked",
    spec: Optional[mig.ClusterSpec] = None,
    cursor: int = 0,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """One placement decision on a raw occupancy: ``(gpu, anchor, accepted)``.

    Lowers ``policy`` (a registered name or an ad-hoc
    :class:`~repro.core.policy.PolicySpec`) exactly like the scan step (via
    the derived ``base``/``free`` state) and matches the corresponding host
    ``Scheduler.select`` — including rejects — for every batched-capable
    registered policy.  ``spec`` defaults to a homogeneous A100-80GB fleet
    of ``occ.shape[0]`` GPUs; ``cursor`` is the rotation start of stateful
    policies (``SpecScheduler._next``).
    """
    pspec = resolve(policy, engine="batched")
    spec = spec if spec is not None else _default_spec(int(occ.shape[0]))
    tables = spec_tables(spec)
    midx = jnp.asarray(spec.model_index)
    occf = occ.astype(jnp.float32)
    base = jnp.einsum("ms,mns->mn", occf, tables.W[midx])  # (M, N)
    free = tables.slices[midx] - occ.sum(axis=1).astype(jnp.int32)
    vg = tables.V[midx]
    f = _frag_from_base(base, free, metric, vg)
    gpu, aidx, ok = _select(
        pspec, base, free, f, metric, tables, midx,
        vg, profile_id, jnp.int32(cursor),
    )
    anchor = jnp.where(ok, tables.profile_anchors[midx[gpu], profile_id, aidx], -1)
    return (
        jnp.where(ok, gpu, -1).astype(jnp.int32),
        anchor.astype(jnp.int32),
        ok,
    )


# ---------------------------------------------------------------------------
# Scan state and event step
# ---------------------------------------------------------------------------


class ReplicaState(NamedTuple):
    occ: jax.Array        # (M, S) int32 — None when occupancy isn't tracked
    base: jax.Array       # (M, N) float32 — occ @ W[midx]ᵀ, kept incrementally
    free: jax.Array       # (M,) int32
    f: jax.Array          # (M,) float32 — per-GPU F score, kept incrementally
    rr: jax.Array         # () int32 — RoundRobin cursor
    ring_gpu: jax.Array   # (K+2, E) int32 — expiry ring, keyed end_slot % K
    ring_mask: jax.Array  # (K+2, E, S) int32


class EventStream(NamedTuple):
    """Host-precomputed per-event scan inputs, each ``(E_max, R)``."""

    pid: np.ndarray        # profile id, -1 for heartbeat/padding lanes
    exp_row: np.ndarray    # ring row (end_slot % K; trash row for padding)
    exp_col: np.ndarray    # ring column (host-assigned, collision-free)
    drain_row: np.ndarray  # ring row to drain when new_slot
    new_slot: np.ndarray   # first event of its slot (drain + maybe sample)
    sample: np.ndarray     # sample metrics of the just-finished slot
    measuring: np.ndarray  # arrival inside the measurement window


class EventMeta(NamedTuple):
    """Host-only per-event annotations (never shipped to device), ``(E_max, R)``.

    Used by :mod:`repro.sim.replay` to reconstruct and validate occupancy
    trajectories from a decision trace.
    """

    slot: np.ndarray  # arrival/heartbeat slot (total_slots for padding)
    end: np.ndarray   # absolute end slot of the arrival (0 for non-arrivals)


class EventTrace(NamedTuple):
    """Per-event scan outputs, each ``(E_max, R)``; counters and metric sums
    are reduced host-side against the host-known flags of the stream."""

    ok: jax.Array        # arrival accepted
    gpu: jax.Array       # chosen GPU (undefined when not accepted)
    aidx: jax.Array      # chosen anchor index (undefined when not accepted)
    free_sum: jax.Array  # Σ free slices at slot boundary (pre-drain)
    active: jax.Array    # active-GPU count at slot boundary (pre-drain)
    frag: jax.Array      # cluster-mean F at slot boundary (pre-drain)


def _init_state(
    tables: SpecTables,
    midx: jax.Array,
    ring_rows: int,
    ring_cols: int,
    track_occ: bool,
) -> ReplicaState:
    num_gpus = midx.shape[0]
    s = tables.W.shape[2]
    n = tables.W.shape[1]
    return ReplicaState(
        occ=jnp.zeros((num_gpus, s), jnp.int32) if track_occ else None,
        base=jnp.zeros((num_gpus, n), jnp.float32),
        free=tables.slices[midx].astype(jnp.int32),
        f=jnp.zeros((num_gpus,), jnp.float32),
        rr=jnp.int32(0),
        ring_gpu=jnp.zeros((ring_rows, ring_cols), jnp.int32),
        ring_mask=jnp.zeros((ring_rows, ring_cols, s), jnp.int32),
    )


def _event_step(st: ReplicaState, x, *, spec, metric, frag_fn, tables, midx, vg):
    pid, exp_row, exp_col, drain_row, new_slot = x

    # 1. slot-boundary metrics (state == end of slot t-1); reduced host-side
    frag = st.f.mean()
    free_sum = st.free.sum()
    active = (st.free < tables.slices[midx]).sum()

    # 2. drain this slot's expiry-ring row (first event of the slot only)
    ns = new_slot.astype(jnp.int32)
    rel_gpu = st.ring_gpu[drain_row]  # (E,)
    rel_mask = st.ring_mask[drain_row] * ns  # (E, S)
    occ = None if st.occ is None else st.occ.at[rel_gpu].add(-rel_mask)
    rel_win = jnp.einsum(
        "es,ens->en", rel_mask.astype(jnp.float32), tables.W[midx[rel_gpu]]
    )  # (E, N) — window counts each release frees, per its GPU's model
    base = st.base.at[rel_gpu].add(-rel_win)
    free = st.free.at[rel_gpu].add(rel_mask.sum(axis=1))
    # rescore exactly the touched rows — through the Pallas kernel when it
    # is routed in (occ is materialized then), else from the window counts
    f = st.f.at[rel_gpu].set(
        frag_fn(occ[rel_gpu])
        if frag_fn is not None
        else _frag_from_base(base[rel_gpu], free[rel_gpu], metric, vg[rel_gpu])
    )
    ring_mask = st.ring_mask.at[drain_row].set(st.ring_mask[drain_row] * (1 - ns))

    # 3. place (or reject) the arrival; pid == -1 lanes are no-ops
    valid = pid >= 0
    pid_c = jnp.maximum(pid, 0)
    gpu, aidx, ok = _select(
        spec, base, free, f, metric, tables, midx, vg, pid_c, st.rr
    )
    ok = ok & valid

    oki = ok.astype(jnp.int32)
    gpu_c = jnp.where(ok, gpu, 0).astype(jnp.int32)
    kg = midx[gpu_c]  # chosen GPU's model index
    mask = tables.profile_masks[kg, pid_c, aidx] * oki  # (S,)
    mwin = tables.maskwin[kg, pid_c, aidx] * oki.astype(jnp.float32)  # (N,)
    occ = None if occ is None else occ.at[gpu_c].add(mask)
    base = base.at[gpu_c].add(mwin)
    free = free.at[gpu_c].add(-mask.sum())
    f = f.at[gpu_c].set(
        frag_fn(occ[gpu_c][None])[0]
        if frag_fn is not None
        else _frag_from_base(
            base[gpu_c][None], free[gpu_c][None], metric, vg[gpu_c][None]
        )[0]
    )
    rr = st.rr
    if spec.stateful_cursor:  # advance the cursor past the chosen GPU on accept
        rr = jnp.where(ok, (gpu_c + 1) % midx.shape[0], rr).astype(jnp.int32)
    ring_gpu = st.ring_gpu.at[exp_row, exp_col].set(
        jnp.where(ok, gpu_c, st.ring_gpu[exp_row, exp_col])
    )
    ring_mask = ring_mask.at[exp_row, exp_col].add(mask)

    st = ReplicaState(
        occ=occ, base=base, free=free, f=f, rr=rr,
        ring_gpu=ring_gpu, ring_mask=ring_mask,
    )
    trace = EventTrace(
        ok=ok,
        gpu=gpu_c,
        aidx=aidx.astype(jnp.int32),
        free_sum=free_sum,
        active=active,
        frag=frag,
    )
    return st, trace


@functools.partial(
    jax.jit,
    static_argnames=(
        "policy", "metric", "num_gpus", "ring_rows", "ring_cols",
        "use_kernel", "kernel_model",
    ),
)
def _simulate(
    events: EventStream,  # each field (E_max, R) — events are the scanned axis
    *,
    policy: PolicyLike,  # registered name or (hashable, static) PolicySpec
    metric: str,
    num_gpus: int,
    ring_rows: int,
    ring_cols: int,
    use_kernel: bool,
    kernel_model: Optional[mig.DeviceModel] = None,
    midx: Optional[jax.Array] = None,
    tables: Optional[SpecTables] = None,
) -> Tuple[ReplicaState, EventTrace]:
    runs = events.pid.shape[1]
    pspec = resolve(policy, engine="batched")
    if tables is None:  # homogeneous A100-80GB default
        cspec = _default_spec(num_gpus)
        tables = spec_tables(cspec)
        midx = jnp.asarray(cspec.model_index)
    frag_fn = (
        make_frag_fn(metric, True, kernel_model or mig.A100_80GB)
        if use_kernel
        else None
    )
    vg = tables.V[midx]  # (M, N) per-GPU window sizes, gathered once
    step = jax.vmap(
        functools.partial(
            _event_step, spec=pspec, metric=metric, frag_fn=frag_fn,
            tables=tables, midx=midx, vg=vg,
        ),
        in_axes=(0, 0),
    )
    init = jax.tree.map(
        lambda x: jnp.broadcast_to(x, (runs,) + x.shape),
        _init_state(tables, midx, ring_rows, ring_cols, track_occ=use_kernel),
    )
    # sample/measuring are host-side reduction flags — never shipped to the scan
    xs = (events.pid, events.exp_row, events.exp_col, events.drain_row, events.new_slot)
    return jax.lax.scan(lambda st, x: step(st, x), init, xs)


# ---------------------------------------------------------------------------
# Host-side arrival pre-sampling + public entry point
# ---------------------------------------------------------------------------


def _rank_within_groups(keys: np.ndarray) -> np.ndarray:
    """Rank of each element within its equal-key group (first-occurrence order)."""
    order = np.argsort(keys, kind="stable")
    ks = keys[order]
    starts = np.r_[0, np.flatnonzero(np.diff(ks)) + 1]
    lengths = np.diff(np.r_[starts, len(ks)])
    ranks_sorted = np.arange(len(ks)) - np.repeat(starts, lengths)
    ranks = np.empty(len(ks), dtype=np.int64)
    ranks[order] = ranks_sorted
    return ranks


def presample_arrivals(
    cfg: SimConfig, runs: int, seed=None
) -> Tuple[EventStream, EventMeta, int, int]:
    """Build per-replica event streams on host.

    Returns ``(events, meta, ring_rows, ring_cols)``.  One event per
    Poisson arrival plus one heartbeat per empty slot (so consecutive
    events never skip a slot), plus a trailing sentinel that samples the
    final slot; streams are right-padded to the longest replica with no-op
    lanes.
    """
    rng = np.random.default_rng(cfg.seed if seed is None else seed)
    T, warm, meas, rate = steady_params(cfg)
    total_slots = warm + meas
    ring_k = T + 1  # end slots live in (t, t + T] — one ring revolution

    counts = rng.poisson(rate, size=(runs, total_slots))
    ev_per_slot = np.maximum(counts, 1)  # heartbeat for empty slots
    n_events = ev_per_slot.sum(axis=1)  # (R,)
    e_max = int(n_events.max()) + 1  # +1 trailing sentinel

    pid = np.full((runs, e_max), -1, dtype=np.int32)
    slot = np.full((runs, e_max), total_slots, dtype=np.int32)
    new_slot = np.zeros((runs, e_max), dtype=bool)
    end = np.zeros((runs, e_max), dtype=np.int64)  # absolute end slot

    for r in range(runs):
        n = n_events[r]
        slots_r = np.repeat(np.arange(total_slots), ev_per_slot[r])
        within = np.arange(n) - np.repeat(
            np.cumsum(ev_per_slot[r]) - ev_per_slot[r], ev_per_slot[r]
        )
        is_arr = within < counts[r, slots_r]
        na = int(is_arr.sum())
        pid[r, :n][is_arr] = distributions.sample_profiles(
            cfg.distribution, na, rng
        )
        slot[r, :n] = slots_r
        new_slot[r, :n] = within == 0
        end[r, :n][is_arr] = slots_r[is_arr] + rng.integers(1, T + 1, size=na)
        new_slot[r, n] = True  # sentinel: drains/samples the final slot

    is_arrival = pid >= 0
    # collision-free ring columns: rank among same-(replica, end-slot) arrivals
    exp_col = np.zeros((runs, e_max), dtype=np.int32)
    flat = np.flatnonzero(is_arrival)  # C-order == per-replica arrival order
    keys = (np.repeat(np.arange(runs), e_max)[flat].astype(np.int64)
            * (total_slots + T + 1) + end.ravel()[flat])
    ranks = _rank_within_groups(keys)
    exp_col.ravel()[flat] = ranks
    ring_cols = max(1, int(ranks.max()) + 1 if len(ranks) else 1)

    exp_row = np.where(is_arrival, end % ring_k, ring_k + 1).astype(np.int32)
    drain_row = (slot % ring_k).astype(np.int32)
    prev = slot - 1
    sample = (
        new_slot & (prev >= warm) & ((prev - warm) % SAMPLE_EVERY == 0)
    )
    measuring = is_arrival & (slot >= warm)

    events = EventStream(
        pid=pid.T,
        exp_row=exp_row.T,
        exp_col=exp_col.T,
        drain_row=drain_row.T,
        new_slot=new_slot.T,
        sample=sample.T,
        measuring=measuring.T,
    )
    meta = EventMeta(slot=slot.T, end=end.T)
    return events, meta, ring_k + 2, ring_cols


def run_batched(
    policy: PolicyLike,
    cfg: SimConfig,
    runs: int = 64,
    use_kernel: bool | None = None,
) -> Dict[str, float]:
    """Average ``runs`` replicas in one device program.

    Drop-in for :func:`repro.sim.simulator.run_many` on the steady protocol
    (same aggregate keys); ``policy`` is any batched-capable registered
    policy name or an ad-hoc :class:`~repro.core.policy.PolicySpec`
    (validated through the registry's single path, like every other entry
    point).  ``use_kernel`` routes fragmentation-severity sampling through
    the Pallas ``fragscore`` kernel (default: only on TPU; homogeneous
    specs only — the kernel bakes in one model's placement table).
    """
    policy = resolve(policy, engine="batched")
    if cfg.protocol != "steady":
        raise ValueError("run_batched implements the steady protocol only")
    spec = cfg.spec()
    if use_kernel is None:
        use_kernel = jax.default_backend() == "tpu" and spec.is_homogeneous
    if use_kernel and not spec.is_homogeneous:
        raise ValueError(
            "use_kernel requires a homogeneous ClusterSpec (the Pallas "
            "fragscore kernel bakes in a single placement table)"
        )

    events, _, ring_rows, ring_cols = presample_arrivals(cfg, runs)
    _, trace = jax.device_get(
        _simulate(
            jax.tree.map(jnp.asarray, events),
            policy=policy,
            metric=cfg.metric,
            num_gpus=cfg.num_gpus,
            ring_rows=ring_rows,
            ring_cols=ring_cols,
            use_kernel=use_kernel,
            kernel_model=spec.models[0] if use_kernel else None,
            midx=jnp.asarray(spec.model_index),
            tables=spec_tables(spec),
        )
    )
    return aggregate(events, trace, spec, runs)


def aggregate(
    events: EventStream, trace: EventTrace, spec, runs: int
) -> Dict[str, float]:
    """Reduce per-event traces against host-known flags to ``run_many`` keys.

    ``spec`` is the ClusterSpec (or an int GPU count, back-compat).
    """
    if isinstance(spec, int):
        spec = _default_spec(spec)
    cap = float(spec.total_mem_slices)
    ok = np.asarray(trace.ok)
    meas = events.measuring
    samp = events.sample

    arrived = np.maximum(meas.sum(axis=0), 1)  # (R,)
    accepted = (ok & meas).sum(axis=0)
    nsamp = np.maximum(samp.sum(axis=0), 1)
    util = ((cap - trace.free_sum) / cap * samp).sum(axis=0) / nsamp
    active = (trace.active * samp).sum(axis=0) / nsamp
    frag = (trace.frag * samp).sum(axis=0) / nsamp
    arrivals_p = np.stack(
        [((events.pid == p) & meas).sum() for p in range(mig.NUM_PROFILES)]
    )
    rejects_p = np.stack(
        [((events.pid == p) & meas & ~ok).sum() for p in range(mig.NUM_PROFILES)]
    )
    return {
        "acceptance_rate": float((accepted / arrived).mean()),
        "allocated_workloads": float(accepted.mean()),
        "active_gpus": float(active.mean()),
        "utilization": float(util.mean()),
        "frag_severity": float(frag.mean()),
        "rejects_by_profile": rejects_p / runs,
        "arrivals_by_profile": arrivals_p / runs,
    }
