"""Batched on-device Monte-Carlo simulation engine (steady protocol).

The Python reference in :mod:`repro.sim.simulator` runs replicas one at a
time through a ``ClusterState``/``heapq`` event loop; at the paper's scale
(500 replicas per point, §VI) a load sweep takes hours.  This module runs
**R replicas × T slots as one** ``lax.scan`` **over a vmapped replica axis**
so the whole Monte-Carlo average is a single XLA program.

Event stream
    Arrivals are pre-sampled on host (Poisson counts, profile ids and
    durations per slot) and flattened into one *event stream* per replica:
    one event per arrival, plus one synthetic heartbeat event for every
    empty slot so consecutive events never skip a slot.  Streams are padded
    to the longest replica (``pid = -1`` lanes are no-ops), and everything
    slot-dependent (release ring row, metric-sample flags, measurement
    window membership) is precomputed host-side, so the device step is pure
    tensor algebra with no clock arithmetic.

Replica state (fixed-capacity struct-of-arrays pytree)
    * ``occ (M, 8) int32`` — cluster occupancy bitmap (materialized only
      when the Pallas-kernel scoring path needs it; otherwise ``base``
      carries the full information);
    * ``base (M, 18) float32`` — occupied-slice count per placement window,
      ``occ @ Wᵀ``.  Window counts are *linear* in occupancy, so ``base``
      is maintained incrementally (row add on commit, row subtract on
      release) and every fragmentation quantity — F(m), the full MFI ΔF
      table, feasibility — derives from it without per-arrival matmuls
      over hypothetical occupancies;
    * ``free (M,) int32`` / ``f (M,) float32`` — free-slice counts and
      per-GPU fragmentation scores, recomputed only for rows a drain or
      commit touched;
    * an expiry ring buffer ``ring_gpu (K+2, E) int32`` /
      ``ring_mask (K+2, E, 8) int32`` keyed by end slot modulo
      ``K = T + 1``: row ``e % K`` holds the (gpu, placement-window) rows
      of workloads expiring at slot ``e``.  Durations are drawn from
      ``[1, T]``, so an end slot is strictly less than one ring revolution
      ahead and each row is drained (masked scatter-subtract) exactly when
      the clock reaches it, before it can be re-targeted.  Within-row
      columns are assigned on host (arrival rank among same-end-slot
      arrivals), so inserts never collide; row ``K + 1`` is a write-only
      trash row for padding lanes.

Policies — **MFI, FF, BF-BI and WF-BI as pure-``jnp`` selection rules**
over the same feasibility/ΔF tensors :func:`repro.core.cluster.mfi_select`
computes (MFI: argmin ΔF with (gpu, anchor) tie-break; FF: first feasible;
BF-BI/WF-BI: argmin/argmax post-allocation free slices with best-index
anchors), selected by a static ``policy`` argument.  Acceptance,
utilization, active-GPU and fragmentation-severity metrics accumulate
inside the scan; :func:`run_batched` returns the same aggregate dict as
:func:`repro.sim.simulator.run_many`.

Parity guarantees vs the Python reference (``tests/test_batched_sim.py``):

* single-step decisions of all four policies match their
  ``Scheduler.select`` counterparts *exactly* (including rejects and
  tie-breaks — every score involved is integer-valued, hence exact in
  float32);
* whole-run acceptance rates agree within Monte-Carlo tolerance (the two
  engines consume their RNG streams differently, so trajectories are
  statistically — not bitwise — identical).

On TPU, per-GPU fragmentation rescoring (the rows each drain/commit
touches, which feed both MFI and the severity metric) routes through the
Pallas ``fragscore`` kernel (``interpret=False``); on CPU the
``base``-derived pure-jnp scoring is used.
"""

from __future__ import annotations

import functools
from typing import Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import cluster as jcluster
from repro.core import mig
from repro.sim import distributions
from repro.sim.simulator import SAMPLE_EVERY, SimConfig, steady_params

POLICIES = ("mfi", "ff", "bf-bi", "wf-bi")

_BIG = jnp.float32(1e9)

# Constant tables.  W (18, 8) placement windows, V (18,) window sizes;
# per-profile padded anchor views of the flattened placement table.
_W = jnp.asarray(mig.PLACEMENT_MASKS, dtype=jnp.float32)  # (18, 8)
_V = jnp.asarray(mig.PLACEMENT_MEM, dtype=jnp.float32)  # (18,)


def _np_profile_rows() -> np.ndarray:
    """(P, A_max) int32 — placement-table row of each profile anchor (0-padded)."""
    rows = np.zeros((mig.NUM_PROFILES, jcluster.MAX_ANCHORS), dtype=np.int32)
    for pid in range(mig.NUM_PROFILES):
        s = mig.profile_placement_rows(pid)
        n = s.stop - s.start
        rows[pid, :n] = np.arange(s.start, s.stop)
    return rows


_PROFILE_ROWS = jnp.asarray(_np_profile_rows())  # (P, A_max)
# occupied-slice count each profile anchor adds to every placement window
_MASKWIN = jnp.asarray(
    jcluster._PROFILE_MASKS_NP.astype(np.float32)
    @ np.asarray(mig.PLACEMENT_MASKS, dtype=np.float32).T
)  # (P, A_max, 18)
_MASKPOS = (_MASKWIN > 0).astype(jnp.float32)  # (P, A_max, 18)


# ---------------------------------------------------------------------------
# Fragmentation scoring from the window-count state
# ---------------------------------------------------------------------------


def _frag_from_base(base: jax.Array, free: jax.Array, metric: str) -> jax.Array:
    """F(m) for every GPU from window counts ``base (M, 18)``: (M,) float32."""
    if metric == "partial":
        counted = (base > 0) & (base < _V[None, :])
    else:  # blocked
        counted = base > 0
    eligible = _V[None, :] <= free[:, None].astype(jnp.float32)
    return jnp.sum(jnp.where(counted & eligible, _V[None, :], 0.0), axis=-1)


def _delta_from_base(
    base: jax.Array,
    free: jax.Array,
    pid: jax.Array,
    metric: str,
    f_before: jax.Array = None,
) -> jax.Array:
    """ΔF of every anchor dry-run of ``pid``: (M, A) float32.

    Window counts after placement are ``base + MASKWIN[pid, a]`` (exact for
    feasible placements — the window is disjoint from current occupancy),
    so for the "blocked" metric the counted-predicate decomposes as
    ``(base > 0) | (maskwin > 0)`` and the whole (M, A) table reduces to
    one (M, 18) × (18, A) matmul; "partial" needs the dense (M, A, 18)
    elementwise form.  All scores are integer-valued — exact in float32.
    """
    v = _V[None, :]
    freef = free.astype(jnp.float32)
    if f_before is None:
        f_before = _frag_from_base(base, free, metric)  # (M,)
    free_after = freef - jcluster.PROFILE_MEM[pid]  # (M,) — same for every anchor
    elig = v <= free_after[:, None]  # (M, 18)
    if metric == "partial":
        ba = base[:, None, :] + _MASKWIN[pid][None, :, :]  # (M, A, 18)
        counted = (ba > 0) & (ba < v[None, :, :])
        f_after = jnp.sum(
            jnp.where(counted & elig[:, None, :], _V[None, None, :], 0.0), axis=-1
        )
    else:  # blocked: counted_after = (base > 0) | (maskwin > 0)
        cb = base > 0  # (M, 18)
        s_occ = jnp.sum(jnp.where(cb & elig, v, 0.0), axis=-1)  # (M,)
        cross = jnp.where(~cb & elig, v, 0.0) @ _MASKPOS[pid].T  # (M, A)
        f_after = s_occ[:, None] + cross
    return f_after - f_before[:, None]


def make_frag_fn(metric: str = "blocked", use_kernel: bool = False):
    """(N, 8) occupancy -> (N,) F scores; Pallas kernel when ``use_kernel``."""
    if use_kernel:
        from repro.kernels.fragscore import fragscore as _k

        return lambda occ: _k.fragscore(occ, _W, _V, metric=metric, interpret=False)
    return functools.partial(jcluster.frag_scores, metric=metric)


# ---------------------------------------------------------------------------
# Policies as pure-jnp selection rules over the feasibility/ΔF tensors
# ---------------------------------------------------------------------------


def _select_mfi(base, free, f, feasible, pid, metric):
    """Argmin ΔF over all feasible (GPU, anchor); ties (gpu, anchor) lex."""
    delta = _delta_from_base(base, free, pid, metric, f_before=f)
    flat = jnp.where(feasible, delta, _BIG).reshape(-1)
    k = jnp.argmin(flat)
    a = feasible.shape[1]
    return k // a, k % a, flat[k] < _BIG


def _select_ff(base, free, f, feasible, pid, metric):
    """First feasible (GPU, anchor) in ascending (gpu, anchor) order."""
    flat = feasible.reshape(-1)
    k = jnp.argmax(flat)
    a = feasible.shape[1]
    return k // a, k % a, flat[k]


def _best_anchor(feasible_row):
    """Highest feasible anchor index (the Best-Index rule)."""
    a = feasible_row.shape[0]
    return a - 1 - jnp.argmax(feasible_row[::-1])


def _select_bf(base, free, f, feasible, pid, metric):
    """Fewest post-allocation free slices, ties by gpu id; best index."""
    any_feas = feasible.any(axis=1)
    g = jnp.argmin(jnp.where(any_feas, free.astype(jnp.float32), _BIG))
    return g, _best_anchor(feasible[g]), any_feas.any()


def _select_wf(base, free, f, feasible, pid, metric):
    """Most post-allocation free slices, ties by gpu id; best index."""
    any_feas = feasible.any(axis=1)
    g = jnp.argmin(jnp.where(any_feas, -free.astype(jnp.float32), _BIG))
    return g, _best_anchor(feasible[g]), any_feas.any()


_SELECT = {"mfi": _select_mfi, "ff": _select_ff, "bf-bi": _select_bf, "wf-bi": _select_wf}


def _feasibility(base: jax.Array, pid: jax.Array) -> jax.Array:
    """(M, A) bool — anchors of ``pid`` whose window has zero occupied slices."""
    overlap = jnp.take(base, _PROFILE_ROWS[pid], axis=1)  # (M, A)
    return (overlap == 0) & jcluster.PROFILE_VALID[pid][None, :]


def policy_select(
    occ: jax.Array,
    profile_id: jax.Array,
    policy: str,
    metric: str = "blocked",
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """One placement decision on a raw occupancy: ``(gpu, anchor, accepted)``.

    Runs the same selection rule as the scan step (via the derived
    ``base``/``free`` state) and exactly matches the corresponding Python
    ``Scheduler.select`` — including rejects — for all :data:`POLICIES`.
    """
    occf = occ.astype(jnp.float32)
    base = occf @ _W.T  # (M, 18)
    free = (mig.NUM_MEM_SLICES - occ.sum(axis=1)).astype(jnp.int32)
    f = _frag_from_base(base, free, metric)
    feasible = _feasibility(base, profile_id)
    gpu, aidx, ok = _SELECT[policy](base, free, f, feasible, profile_id, metric)
    anchor = jnp.where(ok, jcluster.PROFILE_ANCHORS[profile_id][aidx], -1)
    return (
        jnp.where(ok, gpu, -1).astype(jnp.int32),
        anchor.astype(jnp.int32),
        ok,
    )


# ---------------------------------------------------------------------------
# Scan state and event step
# ---------------------------------------------------------------------------


class ReplicaState(NamedTuple):
    occ: jax.Array        # (M, 8) int32 — None when occupancy isn't tracked
    base: jax.Array       # (M, 18) float32 — occ @ Wᵀ, kept incrementally
    free: jax.Array       # (M,) int32
    f: jax.Array          # (M,) float32 — per-GPU F score, kept incrementally
    ring_gpu: jax.Array   # (K+2, E) int32 — expiry ring, keyed end_slot % K
    ring_mask: jax.Array  # (K+2, E, 8) int32


class EventStream(NamedTuple):
    """Host-precomputed per-event scan inputs, each ``(E_max, R)``."""

    pid: np.ndarray        # profile id, -1 for heartbeat/padding lanes
    exp_row: np.ndarray    # ring row (end_slot % K; trash row for padding)
    exp_col: np.ndarray    # ring column (host-assigned, collision-free)
    drain_row: np.ndarray  # ring row to drain when new_slot
    new_slot: np.ndarray   # first event of its slot (drain + maybe sample)
    sample: np.ndarray     # sample metrics of the just-finished slot
    measuring: np.ndarray  # arrival inside the measurement window


class EventMeta(NamedTuple):
    """Host-only per-event annotations (never shipped to device), ``(E_max, R)``.

    Used by :mod:`repro.sim.replay` to reconstruct and validate occupancy
    trajectories from a decision trace.
    """

    slot: np.ndarray  # arrival/heartbeat slot (total_slots for padding)
    end: np.ndarray   # absolute end slot of the arrival (0 for non-arrivals)


class EventTrace(NamedTuple):
    """Per-event scan outputs, each ``(E_max, R)``; counters and metric sums
    are reduced host-side against the host-known flags of the stream."""

    ok: jax.Array        # arrival accepted
    gpu: jax.Array       # chosen GPU (undefined when not accepted)
    aidx: jax.Array      # chosen anchor index (undefined when not accepted)
    free_sum: jax.Array  # Σ free slices at slot boundary (pre-drain)
    active: jax.Array    # active-GPU count at slot boundary (pre-drain)
    frag: jax.Array      # cluster-mean F at slot boundary (pre-drain)


def _init_state(
    num_gpus: int, ring_rows: int, ring_cols: int, track_occ: bool
) -> ReplicaState:
    return ReplicaState(
        occ=(
            jnp.zeros((num_gpus, mig.NUM_MEM_SLICES), jnp.int32)
            if track_occ
            else None
        ),
        base=jnp.zeros((num_gpus, mig.NUM_PLACEMENTS), jnp.float32),
        free=jnp.full((num_gpus,), mig.NUM_MEM_SLICES, jnp.int32),
        f=jnp.zeros((num_gpus,), jnp.float32),
        ring_gpu=jnp.zeros((ring_rows, ring_cols), jnp.int32),
        ring_mask=jnp.zeros(
            (ring_rows, ring_cols, mig.NUM_MEM_SLICES), jnp.int32
        ),
    )


def _event_step(st: ReplicaState, x, *, policy, metric, frag_fn):
    pid, exp_row, exp_col, drain_row, new_slot = x

    # 1. slot-boundary metrics (state == end of slot t-1); reduced host-side
    frag = st.f.mean()
    free_sum = st.free.sum()
    active = (st.free < mig.NUM_MEM_SLICES).sum()

    # 2. drain this slot's expiry-ring row (first event of the slot only)
    ns = new_slot.astype(jnp.int32)
    rel_gpu = st.ring_gpu[drain_row]  # (E,)
    rel_mask = st.ring_mask[drain_row] * ns  # (E, 8)
    occ = None if st.occ is None else st.occ.at[rel_gpu].add(-rel_mask)
    base = st.base.at[rel_gpu].add(-(rel_mask.astype(jnp.float32) @ _W.T))
    free = st.free.at[rel_gpu].add(rel_mask.sum(axis=1))
    # rescore exactly the touched rows — through the Pallas kernel when it
    # is routed in (occ is materialized then), else from the window counts
    f = st.f.at[rel_gpu].set(
        frag_fn(occ[rel_gpu])
        if frag_fn is not None
        else _frag_from_base(base[rel_gpu], free[rel_gpu], metric)
    )
    ring_mask = st.ring_mask.at[drain_row].set(st.ring_mask[drain_row] * (1 - ns))

    # 3. place (or reject) the arrival; pid == -1 lanes are no-ops
    valid = pid >= 0
    pid_c = jnp.maximum(pid, 0)
    feasible = _feasibility(base, pid_c)
    gpu, aidx, ok = _SELECT[policy](base, free, f, feasible, pid_c, metric)
    ok = ok & valid

    oki = ok.astype(jnp.int32)
    mask = jcluster.PROFILE_MASKS[pid_c, aidx] * oki  # (8,)
    mwin = _MASKWIN[pid_c, aidx] * oki  # (18,)
    gpu_c = jnp.where(ok, gpu, 0).astype(jnp.int32)
    occ = None if occ is None else occ.at[gpu_c].add(mask)
    base = base.at[gpu_c].add(mwin)
    free = free.at[gpu_c].add(-mask.sum())
    f = f.at[gpu_c].set(
        frag_fn(occ[gpu_c][None])[0]
        if frag_fn is not None
        else _frag_from_base(base[gpu_c][None], free[gpu_c][None], metric)[0]
    )
    ring_gpu = st.ring_gpu.at[exp_row, exp_col].set(
        jnp.where(ok, gpu_c, st.ring_gpu[exp_row, exp_col])
    )
    ring_mask = ring_mask.at[exp_row, exp_col].add(mask)

    st = ReplicaState(
        occ=occ, base=base, free=free, f=f, ring_gpu=ring_gpu, ring_mask=ring_mask
    )
    trace = EventTrace(
        ok=ok,
        gpu=gpu_c,
        aidx=aidx.astype(jnp.int32),
        free_sum=free_sum,
        active=active,
        frag=frag,
    )
    return st, trace


@functools.partial(
    jax.jit,
    static_argnames=(
        "policy", "metric", "num_gpus", "ring_rows", "ring_cols", "use_kernel"
    ),
)
def _simulate(
    events: EventStream,  # each field (E_max, R) — events are the scanned axis
    *,
    policy: str,
    metric: str,
    num_gpus: int,
    ring_rows: int,
    ring_cols: int,
    use_kernel: bool,
) -> Tuple[ReplicaState, EventTrace]:
    runs = events.pid.shape[1]
    frag_fn = make_frag_fn(metric, True) if use_kernel else None
    step = jax.vmap(
        functools.partial(_event_step, policy=policy, metric=metric, frag_fn=frag_fn),
        in_axes=(0, 0),
    )
    init = jax.tree.map(
        lambda x: jnp.broadcast_to(x, (runs,) + x.shape),
        _init_state(num_gpus, ring_rows, ring_cols, track_occ=use_kernel),
    )
    # sample/measuring are host-side reduction flags — never shipped to the scan
    xs = (events.pid, events.exp_row, events.exp_col, events.drain_row, events.new_slot)
    return jax.lax.scan(lambda st, x: step(st, x), init, xs)


# ---------------------------------------------------------------------------
# Host-side arrival pre-sampling + public entry point
# ---------------------------------------------------------------------------


def _rank_within_groups(keys: np.ndarray) -> np.ndarray:
    """Rank of each element within its equal-key group (first-occurrence order)."""
    order = np.argsort(keys, kind="stable")
    ks = keys[order]
    starts = np.r_[0, np.flatnonzero(np.diff(ks)) + 1]
    lengths = np.diff(np.r_[starts, len(ks)])
    ranks_sorted = np.arange(len(ks)) - np.repeat(starts, lengths)
    ranks = np.empty(len(ks), dtype=np.int64)
    ranks[order] = ranks_sorted
    return ranks


def presample_arrivals(
    cfg: SimConfig, runs: int, seed=None
) -> Tuple[EventStream, EventMeta, int, int]:
    """Build per-replica event streams on host.

    Returns ``(events, meta, ring_rows, ring_cols)``.  One event per
    Poisson arrival plus one heartbeat per empty slot (so consecutive
    events never skip a slot), plus a trailing sentinel that samples the
    final slot; streams are right-padded to the longest replica with no-op
    lanes.
    """
    rng = np.random.default_rng(cfg.seed if seed is None else seed)
    T, warm, meas, rate = steady_params(cfg)
    total_slots = warm + meas
    ring_k = T + 1  # end slots live in (t, t + T] — one ring revolution

    counts = rng.poisson(rate, size=(runs, total_slots))
    ev_per_slot = np.maximum(counts, 1)  # heartbeat for empty slots
    n_events = ev_per_slot.sum(axis=1)  # (R,)
    e_max = int(n_events.max()) + 1  # +1 trailing sentinel

    pid = np.full((runs, e_max), -1, dtype=np.int32)
    slot = np.full((runs, e_max), total_slots, dtype=np.int32)
    new_slot = np.zeros((runs, e_max), dtype=bool)
    end = np.zeros((runs, e_max), dtype=np.int64)  # absolute end slot

    for r in range(runs):
        n = n_events[r]
        slots_r = np.repeat(np.arange(total_slots), ev_per_slot[r])
        within = np.arange(n) - np.repeat(
            np.cumsum(ev_per_slot[r]) - ev_per_slot[r], ev_per_slot[r]
        )
        is_arr = within < counts[r, slots_r]
        na = int(is_arr.sum())
        pid[r, :n][is_arr] = distributions.sample_profiles(
            cfg.distribution, na, rng
        )
        slot[r, :n] = slots_r
        new_slot[r, :n] = within == 0
        end[r, :n][is_arr] = slots_r[is_arr] + rng.integers(1, T + 1, size=na)
        new_slot[r, n] = True  # sentinel: drains/samples the final slot

    is_arrival = pid >= 0
    # collision-free ring columns: rank among same-(replica, end-slot) arrivals
    exp_col = np.zeros((runs, e_max), dtype=np.int32)
    flat = np.flatnonzero(is_arrival)  # C-order == per-replica arrival order
    keys = (np.repeat(np.arange(runs), e_max)[flat].astype(np.int64)
            * (total_slots + T + 1) + end.ravel()[flat])
    ranks = _rank_within_groups(keys)
    exp_col.ravel()[flat] = ranks
    ring_cols = max(1, int(ranks.max()) + 1 if len(ranks) else 1)

    exp_row = np.where(is_arrival, end % ring_k, ring_k + 1).astype(np.int32)
    drain_row = (slot % ring_k).astype(np.int32)
    prev = slot - 1
    sample = (
        new_slot & (prev >= warm) & ((prev - warm) % SAMPLE_EVERY == 0)
    )
    measuring = is_arrival & (slot >= warm)

    events = EventStream(
        pid=pid.T,
        exp_row=exp_row.T,
        exp_col=exp_col.T,
        drain_row=drain_row.T,
        new_slot=new_slot.T,
        sample=sample.T,
        measuring=measuring.T,
    )
    meta = EventMeta(slot=slot.T, end=end.T)
    return events, meta, ring_k + 2, ring_cols


def run_batched(
    policy: str,
    cfg: SimConfig,
    runs: int = 64,
    use_kernel: bool | None = None,
) -> Dict[str, float]:
    """Average ``runs`` replicas in one device program.

    Drop-in for :func:`repro.sim.simulator.run_many` on the steady protocol
    (same aggregate keys); ``policy`` must be one of :data:`POLICIES`.
    ``use_kernel`` routes fragmentation-severity sampling through the
    Pallas ``fragscore`` kernel (default: only on TPU).
    """
    if policy not in POLICIES:
        raise ValueError(f"unknown batched policy {policy!r}; options {POLICIES}")
    if cfg.protocol != "steady":
        raise ValueError("run_batched implements the steady protocol only")
    if use_kernel is None:
        use_kernel = jax.default_backend() == "tpu"

    events, _, ring_rows, ring_cols = presample_arrivals(cfg, runs)
    _, trace = jax.device_get(
        _simulate(
            jax.tree.map(jnp.asarray, events),
            policy=policy,
            metric=cfg.metric,
            num_gpus=cfg.num_gpus,
            ring_rows=ring_rows,
            ring_cols=ring_cols,
            use_kernel=use_kernel,
        )
    )
    return aggregate(events, trace, cfg.num_gpus, runs)


def aggregate(
    events: EventStream, trace: EventTrace, num_gpus: int, runs: int
) -> Dict[str, float]:
    """Reduce per-event traces against host-known flags to ``run_many`` keys."""
    cap = float(num_gpus * mig.NUM_MEM_SLICES)
    ok = np.asarray(trace.ok)
    meas = events.measuring
    samp = events.sample

    arrived = np.maximum(meas.sum(axis=0), 1)  # (R,)
    accepted = (ok & meas).sum(axis=0)
    nsamp = np.maximum(samp.sum(axis=0), 1)
    util = ((cap - trace.free_sum) / cap * samp).sum(axis=0) / nsamp
    active = (trace.active * samp).sum(axis=0) / nsamp
    frag = (trace.frag * samp).sum(axis=0) / nsamp
    arrivals_p = np.stack(
        [((events.pid == p) & meas).sum() for p in range(mig.NUM_PROFILES)]
    )
    rejects_p = np.stack(
        [((events.pid == p) & meas & ~ok).sum() for p in range(mig.NUM_PROFILES)]
    )
    return {
        "acceptance_rate": float((accepted / arrived).mean()),
        "allocated_workloads": float(accepted.mean()),
        "active_gpus": float(active.mean()),
        "utilization": float(util.mean()),
        "frag_severity": float(frag.mean()),
        "rejects_by_profile": rejects_p / runs,
        "arrivals_by_profile": arrivals_p / runs,
    }
