"""MIG-profile request distributions (paper Table II).

Beyond the paper's fleet-wide mixes, a heterogeneous fleet may carry a
**per-device-model demand-class mix** (``SimConfig.model_distributions``):
each model group contributes arrivals in proportion to its slice-capacity
share, with its own Table-II mix — e.g. H100s attracting the big classes
while A100-40s see small ones.  The effective fleet-wide distribution is
the capacity-weighted mixture (:func:`resolve_probs`); requests remain
schedulable anywhere (the mix is a demand model, not a routing rule), so
both engines consume the same probabilities and stay same-stream
comparable.
"""

from __future__ import annotations

from typing import Dict, Mapping, Optional

import numpy as np

from repro.core import mig

# Probability per profile, ordered as mig.PROFILE_NAMES =
# (7g.80gb, 4g.40gb, 3g.40gb, 2g.20gb, 1g.20gb, 1g.10gb)
DISTRIBUTIONS: Dict[str, np.ndarray] = {
    "uniform": np.array([1 / 6] * 6),
    "skew-small": np.array([0.05, 0.10, 0.10, 0.20, 0.25, 0.30]),
    "skew-big": np.array([0.30, 0.25, 0.20, 0.10, 0.10, 0.05]),
    "bimodal": np.array([0.30, 0.15, 0.05, 0.05, 0.15, 0.30]),
}

for _name, _p in DISTRIBUTIONS.items():
    assert abs(_p.sum() - 1.0) < 1e-9, _name


def _named(name: str) -> np.ndarray:
    try:
        return DISTRIBUTIONS[name]
    except KeyError:
        raise ValueError(
            f"unknown distribution {name!r}; options {sorted(DISTRIBUTIONS)}"
        )


def sample_profiles(name: str, n: int, rng: np.random.Generator) -> np.ndarray:
    """Sample ``n`` profile ids from the named distribution."""
    return rng.choice(mig.NUM_PROFILES, size=n, p=_named(name))


def sample_profile_probs(
    probs: np.ndarray, n: int, rng: np.random.Generator
) -> np.ndarray:
    """Sample ``n`` profile ids from an explicit probability vector.

    Identical RNG consumption to :func:`sample_profiles` for the same
    probabilities — callers switching between named and resolved mixes
    stay same-stream.
    """
    return rng.choice(mig.NUM_PROFILES, size=n, p=probs)


def resolve_probs(
    name: str,
    spec: Optional["mig.ClusterSpec"] = None,
    model_distributions: Optional[Mapping[str, str]] = None,
) -> np.ndarray:
    """Effective fleet-wide demand-class probabilities.

    Without ``model_distributions`` this is exactly the named Table-II mix
    (the same array object — RNG streams are unchanged).  With it, each
    model group of ``spec`` contributes in proportion to its slice-capacity
    share, drawing from its own named mix (models not listed keep the
    fleet-wide default ``name``).  Keys may be canonical model names
    (``"a100-80gb"``) or registry aliases (``"a100-80"``).
    """
    if not model_distributions:
        return _named(name)
    if spec is None:
        raise ValueError("model_distributions needs a ClusterSpec")
    by_model: Dict[str, str] = {}
    for key, dist in model_distributions.items():
        if key in mig.DEVICE_MODELS:
            by_model[mig.DEVICE_MODELS[key].name] = dist
        else:
            raise ValueError(
                f"unknown device model {key!r} in model_distributions; "
                f"options {sorted(set(mig.DEVICE_MODELS))}"
            )
        _named(dist)  # validate the distribution name early
    fleet_models = {m.name for m in spec.models}
    unknown = set(by_model) - fleet_models
    if unknown:
        raise ValueError(
            f"model_distributions names models not in the fleet: "
            f"{sorted(unknown)} (fleet: {sorted(fleet_models)})"
        )
    total = float(spec.total_mem_slices)
    probs = np.zeros(mig.NUM_PROFILES, dtype=np.float64)
    for model, rows in spec.model_groups():
        weight = len(rows) * model.num_mem_slices / total
        probs += weight * _named(by_model.get(model.name, name))
    return probs / probs.sum()  # guard float drift; weights already sum to 1


def mean_mem_from_probs(probs: np.ndarray) -> float:
    """Expected memory-slice demand per request under the probabilities."""
    return float(np.asarray(probs) @ mig.PROFILE_MEM)


def mean_mem_demand(name: str) -> float:
    """Expected memory-slice demand per request under the distribution."""
    return mean_mem_from_probs(_named(name))
