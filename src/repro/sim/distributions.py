"""MIG-profile request distributions (paper Table II)."""

from __future__ import annotations

from typing import Dict

import numpy as np

from repro.core import mig

# Probability per profile, ordered as mig.PROFILE_NAMES =
# (7g.80gb, 4g.40gb, 3g.40gb, 2g.20gb, 1g.20gb, 1g.10gb)
DISTRIBUTIONS: Dict[str, np.ndarray] = {
    "uniform": np.array([1 / 6] * 6),
    "skew-small": np.array([0.05, 0.10, 0.10, 0.20, 0.25, 0.30]),
    "skew-big": np.array([0.30, 0.25, 0.20, 0.10, 0.10, 0.05]),
    "bimodal": np.array([0.30, 0.15, 0.05, 0.05, 0.15, 0.30]),
}

for _name, _p in DISTRIBUTIONS.items():
    assert abs(_p.sum() - 1.0) < 1e-9, _name


def sample_profiles(name: str, n: int, rng: np.random.Generator) -> np.ndarray:
    """Sample ``n`` profile ids from the named distribution."""
    try:
        p = DISTRIBUTIONS[name]
    except KeyError:
        raise ValueError(f"unknown distribution {name!r}; options {sorted(DISTRIBUTIONS)}")
    return rng.choice(mig.NUM_PROFILES, size=n, p=p)


def mean_mem_demand(name: str) -> float:
    """Expected memory-slice demand per request under the distribution."""
    return float(DISTRIBUTIONS[name] @ mig.PROFILE_MEM)
