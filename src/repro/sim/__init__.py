"""Monte-Carlo online evaluation of MIG scheduling (paper §VI)."""

from repro.sim.distributions import DISTRIBUTIONS, sample_profiles  # noqa: F401
from repro.sim.simulator import SimConfig, SimResult, run_simulation, run_many  # noqa: F401
