"""Monte-Carlo online evaluation of MIG scheduling (paper §VI).

Two engines simulate the same load model (see ``docs/SIMULATOR.md``):

* :mod:`repro.sim.simulator` — the Python/`heapq` reference, one replica at
  a time;
* :mod:`repro.sim.batched` — the batched JAX engine: R replicas × T slots
  as one staged ``lax.scan`` over a vmapped (and optionally
  device-sharded) replica axis, ≥10× replica throughput on CPU and the
  engine every large scenario sweep should use.

Both engines run every registered policy (``mfi-defrag``'s migration
search included — the batched engine compiles it as a *migrate* stage)
and both load protocols (``steady`` | ``cumulative``), and both accept a
heterogeneous ``SimConfig.cluster_spec``
(:class:`repro.core.mig.ClusterSpec`) with optional per-model demand
mixes (``SimConfig.model_distributions``); the default is the paper's
homogeneous A100-80GB fleet with the fleet-wide Table-II mix.
"""

from repro.sim.distributions import DISTRIBUTIONS, sample_profiles  # noqa: F401
from repro.sim.simulator import (  # noqa: F401
    SimConfig,
    SimResult,
    request_probs,
    run_simulation,
    run_many,
)
from repro.sim.batched import POLICIES as BATCHED_POLICIES  # noqa: F401
from repro.sim.batched import (  # noqa: F401
    PROTOCOLS,
    policy_select,
    policy_select_full,
    run_batched,
)
from repro.core.policy import PolicySpec, list_policies, register_policy  # noqa: F401
