"""Monte-Carlo online evaluation of MIG scheduling (paper §VI).

Two engines simulate the same load model (see ``docs/SIMULATOR.md``):

* :mod:`repro.sim.simulator` — the Python/`heapq` reference, one replica at
  a time (both ``steady`` and ``cumulative`` protocols);
* :mod:`repro.sim.batched` — the batched JAX engine: R replicas × T slots
  as one ``lax.scan`` over a vmapped replica axis (``steady`` only,
  policies MFI/FF/BF-BI/WF-BI/RR), ≥10× replica throughput on CPU and the
  engine every large scenario sweep should use.

Both engines accept a heterogeneous ``SimConfig.cluster_spec``
(:class:`repro.core.mig.ClusterSpec`); the default is the paper's
homogeneous A100-80GB fleet.
"""

from repro.sim.distributions import DISTRIBUTIONS, sample_profiles  # noqa: F401
from repro.sim.simulator import SimConfig, SimResult, run_simulation, run_many  # noqa: F401
from repro.sim.batched import POLICIES as BATCHED_POLICIES  # noqa: F401
from repro.sim.batched import policy_select, run_batched  # noqa: F401
from repro.core.policy import PolicySpec, list_policies, register_policy  # noqa: F401
