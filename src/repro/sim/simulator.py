"""Online Monte-Carlo scheduling simulation (paper §VI).

Two load protocols are provided (see EXPERIMENTS.md §Paper/LoadModel for the
calibration analysis):

* ``"steady"`` (default): the "GPU demand" axis is the **offered load** — the
  steady-state concurrent slice demand as a fraction of cluster capacity.
  Workloads arrive as a Poisson process with rate
  ``λ_f = f·capacity / (E[duration]·E[mem])`` per slot, durations are sampled
  ``U[1, T]`` slots (``T = capacity/E[mem]``, the paper's saturation horizon),
  the simulation warms up for ``3T`` slots and measures over ``2T`` slots.
  This is the only reading of the paper's protocol under which fragmentation
  "naturally increases over time" at a fixed demand level and under which the
  baselines differentiate at 85% demand as the paper's figures show.

* ``"cumulative"`` (paper-literal text): one arrival per slot, durations
  ``U[1, T]``; the demand axis is cumulative arrived demand / capacity.
  Under this protocol concurrent occupancy provably cannot exceed ~50% of
  capacity at 100% demand, so every packing scheduler accepts ~everything —
  we keep it for reference.

* ``"steady-queued"`` (beyond-paper): the steady protocol with a waiting
  queue instead of accept-or-drop.  Rejected requests park in a
  fixed-capacity queue with a patience budget and re-enter selection ahead
  of new arrivals, ordered by the policy's queue order
  (:func:`repro.core.policy.queue_order` — priority class, then wait age,
  by default).  Each request keeps a *lease deadline*: its end slot is
  fixed at arrival, so a queued request is only admissible while the
  deadline has not passed — the same duration semantics the batched
  engine's wait-ring stage uses (:mod:`repro.sim.batched`).  Adds p50/p99
  wait and Jain per-tenant fairness to the reported metrics.

* ``"steady-faulted"`` (beyond-paper): the queued protocol under GPU
  failures.  Each GPU alternates exponential up/down phases
  (:class:`repro.core.mig.FaultModel` — per-model MTBF/MTTR); a failure
  evicts the GPU's running workloads into the wait queue with a retry
  budget and exponential backoff, and masks the GPU out of placement
  until it recovers.  Adds goodput, eviction counts, recovered fraction
  and time-to-recovery percentiles — see docs/FAULTS.md.

Metrics (paper §VI): acceptance rate, allocated workloads, active GPUs,
resource utilization (allocated slices), fragmentation severity (mean F);
the queued protocol adds wait percentiles and per-tenant fairness, the
faulted protocol the failure metrics above.
"""

from __future__ import annotations

import dataclasses
import heapq
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import fragmentation, mig
from repro.core.policy import DEFAULT_QUEUE_ORDER, PolicyLike, key_base, queue_order
from repro.core.schedulers import Scheduler, make_scheduler
from repro.sim import distributions


@dataclasses.dataclass
class SimConfig:
    num_gpus: int = 100
    distribution: str = "uniform"
    protocol: str = "steady"  # "steady" | "cumulative" | "steady-queued" | "steady-faulted"
    metric: str = "blocked"   # fragmentation variant (MFI driver + severity metric)
    seed: int = 0
    # heterogeneous fleets: a ClusterSpec overrides num_gpus (the paper's
    # homogeneous A100-80GB setup is the default one-model spec)
    cluster_spec: Optional[mig.ClusterSpec] = None
    # optional per-device-model demand-class mix (model name -> Table-II
    # distribution name); models not listed keep ``distribution``.  The
    # effective fleet-wide mix is the capacity-weighted mixture — see
    # :func:`repro.sim.distributions.resolve_probs`.
    model_distributions: Optional[Dict[str, str]] = None
    # steady protocol:
    offered_load: float = 0.85  # fraction of slice capacity offered concurrently
    warmup_horizons: int = 3    # warmup = this * T slots
    measure_horizons: int = 2   # measurement window = this * T slots
    # cumulative protocol:
    max_demand: float = 1.0
    demand_grid: Sequence[float] = tuple(np.round(np.arange(0.05, 1.001, 0.05), 3))
    # steady-queued protocol (multi-tenant waiting queue):
    num_tenants: int = 4       # tenant ids sampled uniformly per arrival
    num_priorities: int = 2    # priority classes (0 = most urgent)
    wait_capacity: int = 8     # waiting-queue slots per cluster
    wait_patience: int = 16    # max slots a request may wait before final reject
    # steady-faulted protocol: GPU failure/recovery process (required there,
    # ignored elsewhere)
    fault_model: Optional[mig.FaultModel] = None

    def __post_init__(self):
        if self.cluster_spec is not None:
            self.num_gpus = self.cluster_spec.num_gpus
        if self.wait_patience < 0:
            raise ValueError(
                f"wait_patience must be >= 0 (slots a request may wait), "
                f"got {self.wait_patience}"
            )
        if self.wait_capacity < 0:
            raise ValueError(
                f"wait_capacity must be >= 0 (queue slots), got {self.wait_capacity}"
            )
        if self.num_priorities < 1:
            raise ValueError(
                f"num_priorities must be >= 1 (priority classes are sampled "
                f"from [0, num_priorities)), got {self.num_priorities}"
            )
        if self.num_tenants < 1:
            raise ValueError(
                f"num_tenants must be >= 1, got {self.num_tenants}"
            )

    def spec(self) -> mig.ClusterSpec:
        """The cluster spec (defaulting to the paper's homogeneous fleet)."""
        if self.cluster_spec is not None:
            return self.cluster_spec
        return mig.ClusterSpec.homogeneous(mig.A100_80GB, self.num_gpus)


@dataclasses.dataclass
class SimResult:
    acceptance_rate: float
    allocated_workloads: float   # accepted in measurement window (steady) / total (cumulative)
    active_gpus: float           # time-averaged (steady) / final (cumulative)
    utilization: float           # allocated mem slices / capacity, time-averaged
    frag_severity: float         # cluster-mean F, time-averaged
    rejects_by_profile: np.ndarray  # (P,) counts
    arrivals_by_profile: np.ndarray  # (P,)
    # cumulative-protocol traces on the demand grid (None for steady):
    demand_grid: Optional[np.ndarray] = None
    traces: Optional[Dict[str, np.ndarray]] = None
    # steady-queued protocol only (None otherwise):
    wait_p50: Optional[float] = None   # median wait of accepted requests (slots)
    wait_p99: Optional[float] = None   # p99 wait of accepted requests (slots)
    fairness: Optional[float] = None   # Jain index over per-tenant acceptance
    queue_admits: Optional[float] = None  # accepted after waiting (count)
    # steady-faulted protocol only (None otherwise):
    goodput: Optional[float] = None    # measured arrivals whose lease completed
    evictions: Optional[float] = None  # workloads torn off failing GPUs (count)
    recovered_fraction: Optional[float] = None  # evictions later re-admitted
    ttr_p50: Optional[float] = None    # median slots from eviction to re-admit
    ttr_p99: Optional[float] = None    # p99 slots from eviction to re-admit


def request_probs(cfg: SimConfig) -> np.ndarray:
    """Effective demand-class probabilities of a configuration.

    The named Table-II mix by default; the capacity-weighted per-model
    mixture when ``cfg.model_distributions`` is set.  Both engines sample
    arrivals from this one vector, so per-model mixes stay same-stream
    comparable across engines.
    """
    return distributions.resolve_probs(
        cfg.distribution, cfg.spec(), cfg.model_distributions
    )


#: slots between metric samples in the steady measurement window
SAMPLE_EVERY = 10


def steady_params(cfg: SimConfig) -> Tuple[int, int, int, float]:
    """Shared steady-protocol parameters: ``(T, warm, meas, rate)``.

    Both the Python reference loop and the batched JAX engine
    (:mod:`repro.sim.batched`) derive their load model from here so the two
    simulate the *same* arrival process by construction.  Capacity is the
    spec's total slice count; the per-request slice demand is normalized by
    the *canonical* (A100-80GB) class sizes, so offered load retains the
    paper's meaning on the homogeneous fleet and remains a consistent,
    model-independent knob on mixed fleets.
    """
    cap = cfg.spec().total_mem_slices
    mean_mem = distributions.mean_mem_from_probs(request_probs(cfg))
    T = int(np.ceil(cap / mean_mem))
    mean_dur = (1 + T) / 2
    rate = cfg.offered_load * cap / (mean_dur * mean_mem)
    return T, cfg.warmup_horizons * T, cfg.measure_horizons * T, rate


def _apply_migration(cluster: mig.ClusterState, mig_req) -> None:
    """Move a defrag scheduler's pending victim to its new placement."""
    vwid, vg, va = mig_req
    cluster.migrate(vwid, vg, va)


def jain_fairness(values) -> float:
    """Jain's fairness index ``(Σx)² / (n·Σx²)`` of per-tenant rates.

    1.0 = perfectly even; 1/n = maximally skewed.  Empty or all-zero
    inputs (no tenant saw any demand / no tenant was served) return 1.0 —
    nothing was distributed unevenly.
    """
    x = np.asarray(list(values), dtype=np.float64)
    if x.size == 0:
        return 1.0
    sq = float(np.square(x).sum())
    if sq == 0.0:
        return 1.0
    s = float(x.sum())
    return s * s / (x.size * sq)


def _queue_sort_key(order, t):
    """Sort key of a wait-queue entry under a policy's queue order at slot
    ``t`` (``-`` prefixes flip; arrival order is the final tie-break)."""

    def key_fn(entry):
        key = []
        for k in order:
            base = key_base(k)
            if base == "priority":
                v = entry["prio"]
            elif base == "wait-age":
                v = t - entry["arr"]
            else:  # tenant
                v = entry["tenant"]
            key.append(-v if k.startswith("-") else v)
        key.append(entry["seq"])  # FIFO tie-break
        return tuple(key)

    return key_fn


def run_simulation(scheduler: Scheduler, cfg: SimConfig, seed: Optional[int] = None) -> SimResult:
    if cfg.protocol == "steady":
        return _run_steady(scheduler, cfg, cfg.seed if seed is None else seed)
    elif cfg.protocol == "cumulative":
        return _run_cumulative(scheduler, cfg, cfg.seed if seed is None else seed)
    elif cfg.protocol == "steady-queued":
        return _run_steady_queued(scheduler, cfg, cfg.seed if seed is None else seed)
    elif cfg.protocol == "steady-faulted":
        return _run_steady_faulted(scheduler, cfg, cfg.seed if seed is None else seed)
    raise ValueError(f"unknown protocol {cfg.protocol!r}")


def _run_steady(scheduler: Scheduler, cfg: SimConfig, seed: int) -> SimResult:
    rng = np.random.default_rng(seed)
    scheduler.reset()
    spec = cfg.spec()
    cap = spec.total_mem_slices
    probs = request_probs(cfg)
    T, warm, meas, rate = steady_params(cfg)

    cluster = mig.ClusterState(spec=spec)
    expiry: List = []
    wid = 0
    arr = acc = 0
    rejects = np.zeros(mig.NUM_PROFILES)
    arrivals = np.zeros(mig.NUM_PROFILES)
    util_s = gpus_s = frag_s = 0.0
    nsamp = 0

    for t in range(warm + meas):
        while expiry and expiry[0][0] <= t:
            _, w = heapq.heappop(expiry)
            cluster.release(w)
        for _ in range(rng.poisson(rate)):
            pid = int(distributions.sample_profile_probs(probs, 1, rng)[0])
            measuring = t >= warm
            if measuring:
                arr += 1
                arrivals[pid] += 1
            sel = scheduler.select(cluster, pid)
            if sel is not None:
                mig_req = getattr(scheduler, "pending_migration", None)
                if mig_req is not None:  # mfi-defrag: move the victim first
                    _apply_migration(cluster, mig_req)
                cluster.allocate(wid, pid, *sel)
                heapq.heappush(expiry, (t + int(rng.integers(1, T + 1)), wid))
                if measuring:
                    acc += 1
            elif measuring:
                rejects[pid] += 1
            wid += 1
        if t >= warm and (t - warm) % SAMPLE_EVERY == 0:
            util_s += cluster.used_mem_slices / cap
            gpus_s += cluster.active_gpus
            frag_s += fragmentation.cluster_fragmentation(
                cluster.occupancy_matrix(), cfg.metric, spec=spec
            )
            nsamp += 1

    return SimResult(
        acceptance_rate=acc / max(arr, 1),
        allocated_workloads=float(acc),
        active_gpus=gpus_s / max(nsamp, 1),
        utilization=util_s / max(nsamp, 1),
        frag_severity=frag_s / max(nsamp, 1),
        rejects_by_profile=rejects,
        arrivals_by_profile=arrivals,
    )


def _run_steady_queued(scheduler: Scheduler, cfg: SimConfig, seed: int) -> SimResult:
    """Steady-protocol loop with a tenant-aware waiting queue.

    Rejected arrivals park in a bounded queue (``cfg.wait_capacity``) with
    a patience budget (``cfg.wait_patience`` slots).  Every slot, after
    releases, the queue is drained greedily in the policy's queue order
    (:func:`repro.core.policy.queue_order`) until the head no longer fits.
    Requests keep their lease deadline from arrival (``end = arrival +
    duration``), matching the batched engine's wait-ring semantics: a
    queued request past its deadline or patience is a final reject.
    """
    rng = np.random.default_rng(seed)
    scheduler.reset()
    spec = cfg.spec()
    cap = spec.total_mem_slices
    probs = request_probs(cfg)
    T, warm, meas, rate = steady_params(cfg)
    order = queue_order(scheduler.spec) if hasattr(scheduler, "spec") else DEFAULT_QUEUE_ORDER

    cluster = mig.ClusterState(spec=spec)
    expiry: List = []
    queue: List[Dict] = []
    wid = 0
    arr = acc = 0
    rejects = np.zeros(mig.NUM_PROFILES)
    arrivals = np.zeros(mig.NUM_PROFILES)
    util_s = gpus_s = frag_s = 0.0
    nsamp = 0
    waits: List[float] = []
    queue_admits = 0
    tenant_arr = np.zeros(cfg.num_tenants)
    tenant_acc = np.zeros(cfg.num_tenants)

    def reject(entry):
        nonlocal rejects
        if entry["measuring"]:
            rejects[entry["pid"]] += 1

    def dispatch(entry, sel, t):
        nonlocal acc, queue_admits
        mig_req = getattr(scheduler, "pending_migration", None)
        if mig_req is not None:  # mfi-defrag: move the victim first
            _apply_migration(cluster, mig_req)
        cluster.allocate(entry["wid"], entry["pid"], *sel)
        heapq.heappush(expiry, (entry["end"], entry["wid"]))
        if entry["measuring"]:
            acc += 1
            tenant_acc[entry["tenant"]] += 1
            waits.append(float(t - entry["arr"]))
            if t > entry["arr"]:
                queue_admits += 1

    for t in range(warm + meas):
        while expiry and expiry[0][0] <= t:
            _, w = heapq.heappop(expiry)
            cluster.release(w)
        # prune, then drain the queue in queue order until the head blocks
        for entry in [e for e in queue if e["end"] <= t or t - e["arr"] > cfg.wait_patience]:
            queue.remove(entry)
            reject(entry)
        queue.sort(key=_queue_sort_key(order, t))
        while queue:
            sel = scheduler.select(cluster, queue[0]["pid"])
            if sel is None:
                break
            dispatch(queue.pop(0), sel, t)
        for _ in range(rng.poisson(rate)):
            pid = int(distributions.sample_profile_probs(probs, 1, rng)[0])
            tenant = int(rng.integers(0, max(1, cfg.num_tenants)))
            prio = int(rng.integers(0, max(1, cfg.num_priorities)))
            measuring = t >= warm
            if measuring:
                arr += 1
                arrivals[pid] += 1
                tenant_arr[tenant] += 1
            entry = {
                "wid": wid, "pid": pid, "tenant": tenant, "prio": prio,
                "arr": t, "end": t + int(rng.integers(1, T + 1)),
                "measuring": measuring, "seq": wid,
            }
            sel = scheduler.select(cluster, pid)
            if sel is not None:
                dispatch(entry, sel, t)
            elif cfg.wait_patience > 0 and len(queue) < cfg.wait_capacity:
                queue.append(entry)
            else:
                reject(entry)
            wid += 1
        if t >= warm and (t - warm) % SAMPLE_EVERY == 0:
            util_s += cluster.used_mem_slices / cap
            gpus_s += cluster.active_gpus
            frag_s += fragmentation.cluster_fragmentation(
                cluster.occupancy_matrix(), cfg.metric, spec=spec
            )
            nsamp += 1

    for entry in queue:  # still waiting at horizon end: final rejects
        reject(entry)

    rates = [tenant_acc[k] / tenant_arr[k] for k in range(cfg.num_tenants) if tenant_arr[k] > 0]
    return SimResult(
        acceptance_rate=acc / max(arr, 1),
        allocated_workloads=float(acc),
        active_gpus=gpus_s / max(nsamp, 1),
        utilization=util_s / max(nsamp, 1),
        frag_severity=frag_s / max(nsamp, 1),
        rejects_by_profile=rejects,
        arrivals_by_profile=arrivals,
        wait_p50=float(np.percentile(waits, 50)) if waits else 0.0,
        wait_p99=float(np.percentile(waits, 99)) if waits else 0.0,
        fairness=jain_fairness(rates),
        queue_admits=float(queue_admits),
    )


def _fault_schedule(
    spec: mig.ClusterSpec,
    fault_model: mig.FaultModel,
    horizon: int,
    rng: np.random.Generator,
) -> Tuple[np.ndarray, np.ndarray]:
    """Per-GPU alternating fail/recover marks, ``(horizon, M)`` bools each.

    Mirrors :func:`repro.sim.batched.presample_fault_slots` for one run:
    exponential up/down phases (per-model rates), phase lengths ceiled to
    at least one slot so marks strictly alternate; first failure at slot
    >= 1.
    """
    m = spec.num_gpus
    fail = np.zeros((horizon, m), dtype=bool)
    recover = np.zeros((horizon, m), dtype=bool)
    for g in range(m):
        mtbf, mttr = fault_model.rates_for(spec.model_of(g).name)
        t = 0.0
        down = False
        while True:
            t += max(1.0, float(np.ceil(rng.exponential(mttr if down else mtbf))))
            if t >= horizon:
                break
            (recover if down else fail)[int(t), g] = True
            down = not down
    return fail, recover


def _run_steady_faulted(scheduler: Scheduler, cfg: SimConfig, seed: int) -> SimResult:
    """Steady-queued loop under GPU failures (protocol ``steady-faulted``).

    Every slot, after releases: recover lanes come back up, then failing
    GPUs evict their running workloads (each re-queued with ``tries=1``
    and an exponential-backoff ready slot while the retry budget and the
    queue's capacity allow — otherwise a final loss) and stay masked out
    of placement until recovery.  Queue entries past the patience budget
    re-arm with doubled backoff while ``tries < max_retries`` and the
    lease allows, else drop.  The fault schedule is drawn from its own
    seeded stream so the arrival process is identical to the queued
    protocol's at the same seed.
    """
    if cfg.fault_model is None:
        raise ValueError(
            "protocol 'steady-faulted' needs SimConfig.fault_model "
            "(a repro.core.mig.FaultModel describing MTBF/MTTR)"
        )
    fm = cfg.fault_model
    rng = np.random.default_rng(seed)
    scheduler.reset()
    spec = cfg.spec()
    cap = spec.total_mem_slices
    probs = request_probs(cfg)
    T, warm, meas, rate = steady_params(cfg)
    order = queue_order(scheduler.spec) if hasattr(scheduler, "spec") else DEFAULT_QUEUE_ORDER
    horizon = warm + meas
    fail_marks, rec_marks = _fault_schedule(
        spec, fm, horizon, np.random.default_rng(seed + 77003)
    )

    cluster = mig.ClusterState(spec=spec)
    expiry: List = []
    queue: List[Dict] = []
    running: Dict[int, Dict] = {}  # wid -> entry, for eviction bookkeeping
    wid = 0
    arr = acc = 0
    rejects = np.zeros(mig.NUM_PROFILES)
    arrivals = np.zeros(mig.NUM_PROFILES)
    util_s = gpus_s = frag_s = 0.0
    nsamp = 0
    waits: List[float] = []
    queue_admits = 0
    tenant_arr = np.zeros(cfg.num_tenants)
    tenant_acc = np.zeros(cfg.num_tenants)
    n_evict = recovered = lost_meas = 0
    ttrs: List[float] = []

    def reject(entry):
        # evicted entries were already counted as accepted arrivals — their
        # failure to re-admit is a goodput loss, not a (second) reject
        if entry["measuring"] and not entry.get("counted"):
            rejects[entry["pid"]] += 1

    def final_loss(entry):
        # an eviction that will never re-admit: the workload was counted
        # as accepted but its lease did not complete — goodput loss
        nonlocal lost_meas
        if entry["measuring"] and entry.get("counted"):
            lost_meas += 1

    def dispatch(entry, sel, t):
        nonlocal acc, queue_admits, recovered
        cluster.allocate(entry["wid"], entry["pid"], *sel)
        heapq.heappush(expiry, (entry["end"], entry["wid"]))
        running[entry["wid"]] = entry
        evicted_at = entry.pop("evicted_at", None)
        if evicted_at is not None:
            recovered += 1
            ttrs.append(float(t - evicted_at))
        if entry["measuring"] and not entry.get("counted"):
            acc += 1
            tenant_acc[entry["tenant"]] += 1
            waits.append(float(t - entry["arr0"]))
            if t > entry["arr0"]:
                queue_admits += 1
        entry["counted"] = True

    for t in range(horizon):
        while expiry and expiry[0][0] <= t:
            _, w = heapq.heappop(expiry)
            if w in running:  # evicted leases stay in the heap; skip them
                cluster.release(w)
                del running[w]
        for g in np.flatnonzero(rec_marks[t]):
            cluster.recover_gpu(int(g))
        for g in np.flatnonzero(fail_marks[t]):
            for w in cluster.fail_gpu(int(g)):
                entry = running.pop(w)
                n_evict += 1
                if fm.max_retries >= 1 and len(queue) < cfg.wait_capacity:
                    entry["arr"] = t
                    entry["tries"] = 1
                    entry["rdy"] = t + fm.backoff(1)
                    entry["evicted_at"] = t
                    queue.append(entry)
                else:
                    final_loss(entry)
        # prune / re-arm, then drain ready entries in queue order until
        # the head no longer fits
        kept: List[Dict] = []
        for entry in queue:
            if t - entry["arr"] > cfg.wait_patience:
                if entry.get("tries", 0) < fm.max_retries and entry["end"] > t:
                    entry["arr"] = t
                    entry["tries"] = entry.get("tries", 0) + 1
                    entry["rdy"] = t + fm.backoff(entry["tries"])
                    kept.append(entry)
                else:
                    reject(entry)
                    final_loss(entry)
            elif entry["end"] <= t:
                reject(entry)
                final_loss(entry)
            else:
                kept.append(entry)
        queue = kept
        queue.sort(key=_queue_sort_key(order, t))
        while True:
            ready = [e for e in queue if e.get("rdy", 0) <= t]
            if not ready:
                break
            sel = scheduler.select(cluster, ready[0]["pid"])
            if sel is None:
                break
            queue.remove(ready[0])
            dispatch(ready[0], sel, t)
        for _ in range(rng.poisson(rate)):
            pid = int(distributions.sample_profile_probs(probs, 1, rng)[0])
            tenant = int(rng.integers(0, max(1, cfg.num_tenants)))
            prio = int(rng.integers(0, max(1, cfg.num_priorities)))
            measuring = t >= warm
            if measuring:
                arr += 1
                arrivals[pid] += 1
                tenant_arr[tenant] += 1
            entry = {
                "wid": wid, "pid": pid, "tenant": tenant, "prio": prio,
                "arr": t, "arr0": t, "end": t + int(rng.integers(1, T + 1)),
                "measuring": measuring, "seq": wid, "tries": 0, "rdy": t,
            }
            sel = scheduler.select(cluster, pid)
            if sel is not None:
                dispatch(entry, sel, t)
            elif cfg.wait_patience > 0 and len(queue) < cfg.wait_capacity:
                queue.append(entry)
            else:
                reject(entry)
            wid += 1
        if t >= warm and (t - warm) % SAMPLE_EVERY == 0:
            util_s += cluster.used_mem_slices / cap
            gpus_s += cluster.active_gpus
            frag_s += fragmentation.cluster_fragmentation(
                cluster.occupancy_matrix(), cfg.metric, spec=spec
            )
            nsamp += 1

    for entry in queue:  # still waiting at horizon end
        reject(entry)
        if entry.get("evicted_at") is not None:
            final_loss(entry)

    rates = [tenant_acc[k] / tenant_arr[k] for k in range(cfg.num_tenants) if tenant_arr[k] > 0]
    return SimResult(
        acceptance_rate=acc / max(arr, 1),
        allocated_workloads=float(acc),
        active_gpus=gpus_s / max(nsamp, 1),
        utilization=util_s / max(nsamp, 1),
        frag_severity=frag_s / max(nsamp, 1),
        rejects_by_profile=rejects,
        arrivals_by_profile=arrivals,
        wait_p50=float(np.percentile(waits, 50)) if waits else 0.0,
        wait_p99=float(np.percentile(waits, 99)) if waits else 0.0,
        fairness=jain_fairness(rates),
        queue_admits=float(queue_admits),
        goodput=(acc - lost_meas) / max(arr, 1),
        evictions=float(n_evict),
        recovered_fraction=(recovered / n_evict) if n_evict else 1.0,
        ttr_p50=float(np.percentile(ttrs, 50)) if ttrs else 0.0,
        ttr_p99=float(np.percentile(ttrs, 99)) if ttrs else 0.0,
    )


def _run_cumulative(scheduler: Scheduler, cfg: SimConfig, seed: int) -> SimResult:
    rng = np.random.default_rng(seed)
    scheduler.reset()
    spec = cfg.spec()
    cap = spec.total_mem_slices
    probs = request_probs(cfg)
    mean_mem = distributions.mean_mem_from_probs(probs)
    T = int(np.ceil(cap / mean_mem))
    n = int(np.ceil(cfg.max_demand * cap / mean_mem)) + 20

    profiles = distributions.sample_profile_probs(probs, n, rng)
    durations = rng.integers(1, T + 1, size=n)

    cluster = mig.ClusterState(spec=spec)
    expiry: List = []
    grid = np.asarray(cfg.demand_grid, dtype=np.float64)
    G = len(grid)
    traces = {
        k: np.zeros(G)
        for k in ("acceptance_rate", "allocated_workloads", "active_gpus", "utilization", "frag_severity")
    }
    gi = 0
    arr = acc = 0
    cum = 0.0
    rejects = np.zeros(mig.NUM_PROFILES)
    arrivals = np.zeros(mig.NUM_PROFILES)

    for w in range(n):
        t = w
        while expiry and expiry[0][0] <= t:
            _, wid = heapq.heappop(expiry)
            cluster.release(wid)
        pid = int(profiles[w])
        arr += 1
        arrivals[pid] += 1
        cum += mig.PROFILE_MEM[pid]
        sel = scheduler.select(cluster, pid)
        if sel is not None:
            mig_req = getattr(scheduler, "pending_migration", None)
            if mig_req is not None:  # mfi-defrag: move the victim first
                _apply_migration(cluster, mig_req)
            cluster.allocate(w, pid, *sel)
            heapq.heappush(expiry, (t + int(durations[w]), w))
            acc += 1
        else:
            rejects[pid] += 1
        frac = cum / cap
        while gi < G and frac >= grid[gi]:
            traces["acceptance_rate"][gi] = acc / arr
            traces["allocated_workloads"][gi] = acc
            traces["active_gpus"][gi] = cluster.active_gpus
            traces["utilization"][gi] = cluster.used_mem_slices / cap
            traces["frag_severity"][gi] = fragmentation.cluster_fragmentation(
                cluster.occupancy_matrix(), cfg.metric, spec=spec
            )
            gi += 1
        if frac >= cfg.max_demand and gi >= G:
            break

    for k, v in traces.items():
        for i in range(gi, G):
            v[i] = v[gi - 1] if gi > 0 else 0.0

    return SimResult(
        acceptance_rate=acc / max(arr, 1),
        allocated_workloads=float(acc),
        active_gpus=float(cluster.active_gpus),
        utilization=cluster.used_mem_slices / cap,
        frag_severity=fragmentation.cluster_fragmentation(
            cluster.occupancy_matrix(), cfg.metric, spec=spec
        ),
        rejects_by_profile=rejects,
        arrivals_by_profile=arrivals,
        demand_grid=grid,
        traces=traces,
    )


def run_many(scheduler_name: PolicyLike, cfg: SimConfig, runs: int = 100) -> Dict[str, float]:
    """Average ``runs`` independent simulations (paper uses 500).

    ``scheduler_name`` is any registered policy name or an ad-hoc
    :class:`~repro.core.policy.PolicySpec`; each run compiles a fresh host
    scheduler through the registry (stateful cursors start at 0).
    """
    keys = ("acceptance_rate", "allocated_workloads", "active_gpus", "utilization", "frag_severity")
    if cfg.protocol == "steady-queued":
        keys = keys + ("wait_p50", "wait_p99", "fairness", "queue_admits")
    elif cfg.protocol == "steady-faulted":
        keys = keys + (
            "wait_p50", "wait_p99", "fairness", "queue_admits",
            "goodput", "evictions", "recovered_fraction", "ttr_p50", "ttr_p99",
        )
    acc = {k: 0.0 for k in keys}
    rej = np.zeros(mig.NUM_PROFILES)
    arrp = np.zeros(mig.NUM_PROFILES)
    traces_acc = None
    for r in range(runs):
        sched = make_scheduler(scheduler_name, cfg.metric)
        res = run_simulation(sched, cfg, seed=cfg.seed + r * 9973)
        for k in keys:
            acc[k] += getattr(res, k)
        rej += res.rejects_by_profile
        arrp += res.arrivals_by_profile
        if res.traces is not None:
            if traces_acc is None:
                traces_acc = {k: v.copy() for k, v in res.traces.items()}
            else:
                for k in res.traces:
                    traces_acc[k] += res.traces[k]
    out = {k: v / runs for k, v in acc.items()}
    out["rejects_by_profile"] = rej / runs
    out["arrivals_by_profile"] = arrp / runs
    if traces_acc is not None:
        out["traces"] = {k: v / runs for k, v in traces_acc.items()}
        out["demand_grid"] = np.asarray(cfg.demand_grid)
    return out
