"""Host-side replay and validation of batched-engine decision traces.

The batched engine (:mod:`repro.sim.batched`) emits one decision per event
(``EventTrace``); together with the host-known stream annotations
(``EventStream``/``EventMeta``) the full occupancy trajectory of every
replica is reproducible in plain numpy.  :func:`replay` re-executes the
commits and releases and asserts the scheduling invariants the engine must
uphold:

* an accepted placement uses a *legal Table-I anchor* for its profile;
* it never *double-books* a memory slice (its window is fully free);
* a *release after expiry restores the exact pre-allocation occupancy*
  (the window is fully occupied right before release and fully free after).

Tests use this to cross-check the device scan against an independent
host implementation; it is also handy for debugging new policies.
"""

from __future__ import annotations

from typing import Tuple

import numpy as np

from repro.core import mig
from repro.sim.batched import EventMeta, EventStream, EventTrace


def _walk(
    events: EventStream,
    meta: EventMeta,
    trace: EventTrace,
    num_gpus: int,
    check: bool,
):
    """Shared event walk: returns (final_occ (R, M, 8), alive sets per replica).

    Each alive entry is ``(end_slot, gpu, anchor, mem)`` for a workload
    still allocated when the stream ends.
    """
    e_max, runs = np.asarray(events.pid).shape
    pid = np.asarray(events.pid)
    new_slot = np.asarray(events.new_slot)
    ok = np.asarray(trace.ok)
    gpu = np.asarray(trace.gpu)
    aidx = np.asarray(trace.aidx)
    slot = np.asarray(meta.slot)
    end = np.asarray(meta.end)

    final = np.zeros((runs, num_gpus, mig.NUM_MEM_SLICES), dtype=np.int32)
    alive_sets = []
    for r in range(runs):
        occ = final[r]
        alive = []  # (end_slot, gpu, anchor, mem)
        for e in range(e_max):
            if new_slot[e, r]:
                t = slot[e, r]
                expired = [w for w in alive if w[0] <= t]
                alive = [w for w in alive if w[0] > t]
                for _, g, a, m in expired:
                    if check:
                        assert (occ[g, a : a + m] == 1).all(), (
                            f"replica {r} event {e}: release of [{a},{a + m}) on "
                            f"GPU {g} does not match a fully-occupied window"
                        )
                    occ[g, a : a + m] = 0
            p = pid[e, r]
            if p < 0 or not ok[e, r]:
                continue
            prof = mig.PROFILES[p]
            g, j = int(gpu[e, r]), int(aidx[e, r])
            if check:
                assert 0 <= j < prof.num_placements, (
                    f"replica {r} event {e}: anchor index {j} illegal for "
                    f"profile {prof.name}"
                )
            anchor = prof.anchors[j]
            if check:
                assert (occ[g, anchor : anchor + prof.mem] == 0).all(), (
                    f"replica {r} event {e}: {prof.name}@{anchor} double-books "
                    f"slices on GPU {g}"
                )
            occ[g, anchor : anchor + prof.mem] = 1
            alive.append((int(end[e, r]), g, anchor, prof.mem))
        alive_sets.append(alive)
    return final, alive_sets


def replay(
    events: EventStream,
    meta: EventMeta,
    trace: EventTrace,
    num_gpus: int,
    check: bool = True,
) -> np.ndarray:
    """Re-execute a decision trace on host; returns final occupancy (R, M, 8).

    With ``check=True`` (default), raises ``AssertionError`` on any
    invariant violation (illegal anchor, double-booking, inexact release).
    """
    final, _ = _walk(events, meta, trace, num_gpus, check)
    return final


def drain_all(
    events: EventStream,
    meta: EventMeta,
    trace: EventTrace,
    num_gpus: int,
) -> Tuple[np.ndarray, np.ndarray]:
    """Replay, then release every still-active workload.

    Returns ``(final_occ, drained_occ)``; ``drained_occ`` must be all-zero
    if and only if every release restores its exact allocation window —
    the end-to-end form of the release-restores-occupancy invariant.
    """
    final, alive_sets = _walk(events, meta, trace, num_gpus, check=True)
    drained = final.copy()
    for r, alive in enumerate(alive_sets):
        for _, g, a, m in alive:
            assert (drained[r, g, a : a + m] == 1).all()
            drained[r, g, a : a + m] = 0
    return final, drained
