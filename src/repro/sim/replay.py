"""Host-side replay and validation of batched-engine decision traces.

The batched engine (:mod:`repro.sim.batched`) emits one decision per event
(``EventTrace``); together with the host-known stream annotations
(``EventStream``/``EventMeta``) the full occupancy trajectory of every
replica is reproducible in plain numpy.  :func:`replay` re-executes the
commits and releases and asserts the scheduling invariants the engine must
uphold:

* an accepted placement uses a *legal placement-table anchor* for its
  profile **on the model of the chosen GPU** (Table I on the A100-80GB,
  the model's own table on mixed fleets);
* it never *double-books* a memory slice (its window is fully free);
* a *release after expiry restores the exact pre-allocation occupancy*
  (the window is fully occupied right before release and fully free after).

:func:`host_decisions` additionally drives the *Python* schedulers over the
same presampled event stream, producing a decision trace that must match
the device trace decision-for-decision (the engines are exact-parity per
step, and the stream fixes the arrival process) — the strongest
cross-engine check we have, and it works on any ClusterSpec.

Tests use this to cross-check the device scan against an independent
host implementation; it is also handy for debugging new policies.
"""

from __future__ import annotations

from typing import Optional, Tuple

import numpy as np

from repro.core import mig
from repro.core.policy import PolicyLike
from repro.core.schedulers import make_scheduler
from repro.sim.batched import EventMeta, EventStream, EventTrace


def _spec_or_default(spec: Optional[mig.ClusterSpec], num_gpus: int) -> mig.ClusterSpec:
    if spec is None:
        return mig.ClusterSpec.homogeneous(mig.A100_80GB, num_gpus)
    assert spec.num_gpus == num_gpus
    return spec


def _walk(
    events: EventStream,
    meta: EventMeta,
    trace: EventTrace,
    num_gpus: int,
    check: bool,
    spec: Optional[mig.ClusterSpec] = None,
):
    """Shared event walk: returns (final_occ (R, M, S), alive sets per replica).

    Each alive entry is ``(end_slot, gpu, anchor, mem)`` for a workload
    still allocated when the stream ends.
    """
    spec = _spec_or_default(spec, num_gpus)
    e_max, runs = np.asarray(events.pid).shape
    pid = np.asarray(events.pid)
    new_slot = np.asarray(events.new_slot)
    ok = np.asarray(trace.ok)
    gpu = np.asarray(trace.gpu)
    aidx = np.asarray(trace.aidx)
    slot = np.asarray(meta.slot)
    end = np.asarray(meta.end)

    final = np.zeros((runs, num_gpus, spec.num_mem_slices), dtype=np.int32)
    alive_sets = []
    for r in range(runs):
        occ = final[r]
        alive = []  # (end_slot, gpu, anchor, mem)
        for e in range(e_max):
            if new_slot[e, r]:
                t = slot[e, r]
                expired = [w for w in alive if w[0] <= t]
                alive = [w for w in alive if w[0] > t]
                for _, g, a, m in expired:
                    if check:
                        assert (occ[g, a : a + m] == 1).all(), (
                            f"replica {r} event {e}: release of [{a},{a + m}) on "
                            f"GPU {g} does not match a fully-occupied window"
                        )
                    occ[g, a : a + m] = 0
            p = pid[e, r]
            if p < 0 or not ok[e, r]:
                continue
            g, j = int(gpu[e, r]), int(aidx[e, r])
            prof = spec.model_of(g).profiles[p]
            if check:
                assert 0 <= j < prof.num_placements, (
                    f"replica {r} event {e}: anchor index {j} illegal for "
                    f"profile {prof.name} on {spec.model_of(g).name}"
                )
            anchor = prof.anchors[j]
            if check:
                assert (occ[g, anchor : anchor + prof.mem] == 0).all(), (
                    f"replica {r} event {e}: {prof.name}@{anchor} double-books "
                    f"slices on GPU {g}"
                )
            occ[g, anchor : anchor + prof.mem] = 1
            alive.append((int(end[e, r]), g, anchor, prof.mem))
        alive_sets.append(alive)
    return final, alive_sets


def replay(
    events: EventStream,
    meta: EventMeta,
    trace: EventTrace,
    num_gpus: int,
    check: bool = True,
    spec: Optional[mig.ClusterSpec] = None,
) -> np.ndarray:
    """Re-execute a decision trace on host; returns final occupancy (R, M, S).

    With ``check=True`` (default), raises ``AssertionError`` on any
    invariant violation (illegal anchor, double-booking, inexact release).
    ``spec`` selects the fleet (default: homogeneous A100-80GB).
    """
    final, _ = _walk(events, meta, trace, num_gpus, check, spec)
    return final


def drain_all(
    events: EventStream,
    meta: EventMeta,
    trace: EventTrace,
    num_gpus: int,
    spec: Optional[mig.ClusterSpec] = None,
) -> Tuple[np.ndarray, np.ndarray]:
    """Replay, then release every still-active workload.

    Returns ``(final_occ, drained_occ)``; ``drained_occ`` must be all-zero
    if and only if every release restores its exact allocation window —
    the end-to-end form of the release-restores-occupancy invariant.
    """
    final, alive_sets = _walk(events, meta, trace, num_gpus, check=True, spec=spec)
    drained = final.copy()
    for r, alive in enumerate(alive_sets):
        for _, g, a, m in alive:
            assert (drained[r, g, a : a + m] == 1).all()
            drained[r, g, a : a + m] = 0
    return final, drained


def host_decisions(
    events: EventStream,
    meta: EventMeta,
    policy: PolicyLike,
    num_gpus: int,
    metric: str = "blocked",
    spec: Optional[mig.ClusterSpec] = None,
) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Drive the *Python* scheduler over a presampled event stream.

    ``policy`` is any registered policy name or ad-hoc
    :class:`~repro.core.policy.PolicySpec` (compiled per replica through
    the registry).  Returns ``(ok, gpu, anchor)`` arrays shaped like the
    stream (``(E_max, R)``): the reference decision for every arrival,
    produced by the host-compiled scheduler on a
    :class:`repro.core.mig.ClusterState` with the same arrivals, durations
    and release schedule the batched engine consumed.  Since single-step
    selection is exact-parity, the device trace must agree
    element-for-element (``ok`` everywhere; ``gpu`` and ``anchor`` wherever
    accepted).
    """
    spec = _spec_or_default(spec, num_gpus)
    e_max, runs = np.asarray(events.pid).shape
    pid = np.asarray(events.pid)
    new_slot = np.asarray(events.new_slot)
    slot = np.asarray(meta.slot)
    end = np.asarray(meta.end)

    ok = np.zeros((e_max, runs), dtype=bool)
    gpu = np.full((e_max, runs), -1, dtype=np.int32)
    anchor = np.full((e_max, runs), -1, dtype=np.int32)
    for r in range(runs):
        cluster = mig.ClusterState(spec=spec)
        scheduler = make_scheduler(policy, metric)
        alive = []  # (end_slot, workload_id)
        for e in range(e_max):
            if new_slot[e, r]:
                t = slot[e, r]
                for tend, wid in [w for w in alive if w[0] <= t]:
                    cluster.release(wid)
                alive = [w for w in alive if w[0] > t]
            p = int(pid[e, r])
            if p < 0:
                continue
            sel = scheduler.select(cluster, p)
            if sel is None:
                continue
            g, a = sel
            wid = e  # unique per replica stream
            cluster.allocate(wid, p, g, a)
            alive.append((int(end[e, r]), wid))
            ok[e, r] = True
            gpu[e, r] = g
            anchor[e, r] = a
    return ok, gpu, anchor
