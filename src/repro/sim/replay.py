"""Host-side replay and validation of batched-engine decision traces.

The batched engine (:mod:`repro.sim.batched`) emits one decision per event
(``EventTrace``); together with the host-known stream annotations
(``EventStream``/``EventMeta``) the full occupancy trajectory of every
replica is reproducible in plain numpy.  :func:`replay` re-executes the
commits, releases — and, for defrag specs, the migrations — and asserts
the scheduling invariants the engine must uphold:

* an accepted placement uses a *legal placement-table anchor* for its
  profile **on the model of the chosen GPU** (Table I on the A100-80GB,
  the model's own table on mixed fleets);
* it never *double-books* a memory slice (its window is fully free);
* a *release after expiry restores the exact pre-allocation occupancy*
  (the window is fully occupied right before release and fully free after);
* a *migration never double-books or strands a workload*: the victim named
  by the trace is a uniquely identified running workload, its old window
  is fully occupied before the move, its new window is legal for its class
  on the target model and fully free, and the workload stays tracked (same
  expiry) at its new placement.

:func:`host_decisions` additionally drives the *Python* schedulers over the
same presampled event stream, producing a decision trace that must match
the device trace decision-for-decision — migrations included
(:func:`host_decisions_full` also returns the chosen migrations) — the
strongest cross-engine check we have, and it works on any ClusterSpec and
either protocol's stream.

Tests use this to cross-check the device scan against an independent
host implementation; it is also handy for debugging new policies.
"""

from __future__ import annotations

from typing import List, NamedTuple, Optional, Tuple

import numpy as np

from repro.core import mig
from repro.core.policy import PolicyLike, key_base, queue_order, resolve
from repro.core.schedulers import make_scheduler
from repro.sim.batched import EventMeta, EventStream, EventTrace


def _spec_or_default(spec: Optional[mig.ClusterSpec], num_gpus: int) -> mig.ClusterSpec:
    if spec is None:
        return mig.ClusterSpec.homogeneous(mig.A100_80GB, num_gpus)
    assert spec.num_gpus == num_gpus
    return spec


class _Alive(NamedTuple):
    """One still-allocated workload during a replay walk."""

    end: int
    gpu: int
    anchor: int
    mem: int
    pid: int


def _walk(
    events: EventStream,
    meta: EventMeta,
    trace: EventTrace,
    num_gpus: int,
    check: bool,
    spec: Optional[mig.ClusterSpec] = None,
):
    """Shared event walk: returns (final_occ (R, M, S), alive sets per replica).

    Each alive entry is an :class:`_Alive` for a workload still allocated
    when the stream ends.  Migrations recorded in the trace are re-executed
    (and, with ``check``, validated) exactly like commits and releases.
    """
    spec = _spec_or_default(spec, num_gpus)
    e_max, runs = np.asarray(events.pid).shape
    pid = np.asarray(events.pid)
    new_slot = np.asarray(events.new_slot)
    ok = np.asarray(trace.ok)
    gpu = np.asarray(trace.gpu)
    aidx = np.asarray(trace.aidx)
    slot = np.asarray(meta.slot)
    end = np.asarray(meta.end)
    has_mig = trace.mig is not None
    if has_mig:
        mig_flag = np.asarray(trace.mig)
        mig_from_gpu = np.asarray(trace.mig_from_gpu)
        mig_from_anchor = np.asarray(trace.mig_from_anchor)
        mig_to_gpu = np.asarray(trace.mig_to_gpu)
        mig_to_anchor = np.asarray(trace.mig_to_anchor)
    has_wadm = trace.wadm_eidx is not None
    if has_wadm:
        wadm_eidx = np.asarray(trace.wadm_eidx)
        wadm_gpu = np.asarray(trace.wadm_gpu)
        wadm_aidx = np.asarray(trace.wadm_aidx)

    final = np.zeros((runs, num_gpus, spec.num_mem_slices), dtype=np.int32)
    alive_sets = []
    for r in range(runs):
        occ = final[r]
        alive: List[_Alive] = []
        for e in range(e_max):
            if new_slot[e, r]:
                t = slot[e, r]
                expired = [w for w in alive if w.end <= t]
                alive = [w for w in alive if w.end > t]
                for w in expired:
                    if check:
                        assert (occ[w.gpu, w.anchor : w.anchor + w.mem] == 1).all(), (
                            f"replica {r} event {e}: release of "
                            f"[{w.anchor},{w.anchor + w.mem}) on GPU {w.gpu} "
                            f"does not match a fully-occupied window"
                        )
                    occ[w.gpu, w.anchor : w.anchor + w.mem] = 0
            if has_wadm and wadm_eidx[e, r] >= 0:
                # a parked arrival admits from the wait ring at this event:
                # commit it with its ORIGINAL profile and end slot (the
                # lease deadline is unchanged by waiting)
                e0 = int(wadm_eidx[e, r])
                p0 = int(pid[e0, r])
                g0, j0 = int(wadm_gpu[e, r]), int(wadm_aidx[e, r])
                prof0 = spec.model_of(g0).profiles[p0]
                if check:
                    assert p0 >= 0 and not ok[e0, r], (
                        f"replica {r} event {e}: wait-admit references event "
                        f"{e0}, which is not a rejected arrival"
                    )
                    assert int(end[e0, r]) > int(slot[e, r]), (
                        f"replica {r} event {e}: wait-admit past the lease "
                        f"deadline of event {e0}"
                    )
                    assert 0 <= j0 < prof0.num_placements, (
                        f"replica {r} event {e}: wait-admit anchor index "
                        f"{j0} illegal for {prof0.name}"
                    )
                a0 = prof0.anchors[j0]
                if check:
                    assert (occ[g0, a0 : a0 + prof0.mem] == 0).all(), (
                        f"replica {r} event {e}: wait-admit {prof0.name}@{a0} "
                        f"double-books slices on GPU {g0}"
                    )
                occ[g0, a0 : a0 + prof0.mem] = 1
                alive.append(_Alive(int(end[e0, r]), g0, a0, prof0.mem, p0))
            p = pid[e, r]
            if p < 0 or not ok[e, r]:
                continue
            if has_mig and mig_flag[e, r]:
                # the migration commits before the request: find the unique
                # victim, free its old window, re-place it on the target
                vg, va = int(mig_from_gpu[e, r]), int(mig_from_anchor[e, r])
                ng, na = int(mig_to_gpu[e, r]), int(mig_to_anchor[e, r])
                victims = [
                    i for i, w in enumerate(alive) if w.gpu == vg and w.anchor == va
                ]
                if check:
                    assert len(victims) == 1, (
                        f"replica {r} event {e}: migration victim at "
                        f"GPU {vg} anchor {va} matches {len(victims)} running "
                        f"workloads (must be exactly one)"
                    )
                w = alive[victims[0]]
                vprof = spec.model_of(ng).profiles[w.pid]
                if check:
                    assert (occ[vg, va : va + w.mem] == 1).all(), (
                        f"replica {r} event {e}: migration evicts a window "
                        f"that is not fully occupied"
                    )
                occ[vg, va : va + w.mem] = 0
                if check:
                    assert na in vprof.anchors, (
                        f"replica {r} event {e}: migration target anchor {na} "
                        f"illegal for {vprof.name} on {spec.model_of(ng).name}"
                    )
                    assert (occ[ng, na : na + vprof.mem] == 0).all(), (
                        f"replica {r} event {e}: migration double-books "
                        f"slices on GPU {ng}"
                    )
                occ[ng, na : na + vprof.mem] = 1
                alive[victims[0]] = _Alive(w.end, ng, na, vprof.mem, w.pid)
            g, j = int(gpu[e, r]), int(aidx[e, r])
            prof = spec.model_of(g).profiles[p]
            if check:
                assert 0 <= j < prof.num_placements, (
                    f"replica {r} event {e}: anchor index {j} illegal for "
                    f"profile {prof.name} on {spec.model_of(g).name}"
                )
            anchor = prof.anchors[j]
            if check:
                assert (occ[g, anchor : anchor + prof.mem] == 0).all(), (
                    f"replica {r} event {e}: {prof.name}@{anchor} double-books "
                    f"slices on GPU {g}"
                )
            occ[g, anchor : anchor + prof.mem] = 1
            alive.append(_Alive(int(end[e, r]), g, anchor, prof.mem, int(p)))
        alive_sets.append(alive)
    return final, alive_sets


def replay(
    events: EventStream,
    meta: EventMeta,
    trace: EventTrace,
    num_gpus: int,
    check: bool = True,
    spec: Optional[mig.ClusterSpec] = None,
) -> np.ndarray:
    """Re-execute a decision trace on host; returns final occupancy (R, M, S).

    With ``check=True`` (default), raises ``AssertionError`` on any
    invariant violation (illegal anchor, double-booking, inexact release,
    inconsistent migration).  ``spec`` selects the fleet (default:
    homogeneous A100-80GB).
    """
    final, _ = _walk(events, meta, trace, num_gpus, check, spec)
    return final


def drain_all(
    events: EventStream,
    meta: EventMeta,
    trace: EventTrace,
    num_gpus: int,
    spec: Optional[mig.ClusterSpec] = None,
) -> Tuple[np.ndarray, np.ndarray]:
    """Replay, then release every still-active workload.

    Returns ``(final_occ, drained_occ)``; ``drained_occ`` must be all-zero
    if and only if every release restores its exact allocation window —
    the end-to-end form of the release-restores-occupancy invariant (and,
    for defrag specs, the no-stranded-workload half of the migration
    invariant: a migrated workload still drains from its *new* placement).
    """
    final, alive_sets = _walk(events, meta, trace, num_gpus, check=True, spec=spec)
    drained = final.copy()
    for r, alive in enumerate(alive_sets):
        for w in alive:
            assert (drained[r, w.gpu, w.anchor : w.anchor + w.mem] == 1).all()
            drained[r, w.gpu, w.anchor : w.anchor + w.mem] = 0
    return final, drained


class HostTrace(NamedTuple):
    """Reference decisions of the Python schedulers, shaped ``(E_max, R)``."""

    ok: np.ndarray
    gpu: np.ndarray
    anchor: np.ndarray
    mig: np.ndarray            # a migration accompanied the accept
    mig_from_gpu: np.ndarray   # victim's old GPU (-1 where no migration)
    mig_from_anchor: np.ndarray
    mig_to_gpu: np.ndarray
    mig_to_anchor: np.ndarray


def host_decisions_full(
    events: EventStream,
    meta: EventMeta,
    policy: PolicyLike,
    num_gpus: int,
    metric: str = "blocked",
    spec: Optional[mig.ClusterSpec] = None,
    **scheduler_kwargs,
) -> HostTrace:
    """Drive the *Python* scheduler over a presampled event stream.

    ``policy`` is any registered policy name or ad-hoc
    :class:`~repro.core.policy.PolicySpec` (compiled per replica through
    the registry).  Returns a :class:`HostTrace` with the reference
    decision for every arrival — and, for defrag schedulers, the chosen
    migration — produced on a :class:`repro.core.mig.ClusterState` with the
    same arrivals, durations and release schedule the batched engine
    consumed.  Since single-step selection is exact-parity, the device
    trace must agree element-for-element (``ok`` everywhere; ``gpu``,
    ``anchor`` and the migration wherever accepted).  ``scheduler_kwargs``
    reach the compiled scheduler (e.g. ``max_candidates=None`` to lift the
    defrag budget to the batched engine's exhaustive search).
    """
    spec = _spec_or_default(spec, num_gpus)
    e_max, runs = np.asarray(events.pid).shape
    pid = np.asarray(events.pid)
    new_slot = np.asarray(events.new_slot)
    slot = np.asarray(meta.slot)
    end = np.asarray(meta.end)

    ok = np.zeros((e_max, runs), dtype=bool)
    gpu = np.full((e_max, runs), -1, dtype=np.int32)
    anchor = np.full((e_max, runs), -1, dtype=np.int32)
    mig_flag = np.zeros((e_max, runs), dtype=bool)
    mig_fg = np.full((e_max, runs), -1, dtype=np.int32)
    mig_fa = np.full((e_max, runs), -1, dtype=np.int32)
    mig_tg = np.full((e_max, runs), -1, dtype=np.int32)
    mig_ta = np.full((e_max, runs), -1, dtype=np.int32)
    for r in range(runs):
        cluster = mig.ClusterState(spec=spec)
        scheduler = _make(policy, metric, scheduler_kwargs)
        alive = []  # (end_slot, workload_id)
        for e in range(e_max):
            if new_slot[e, r]:
                t = slot[e, r]
                for tend, wid in [w for w in alive if w[0] <= t]:
                    cluster.release(wid)
                alive = [w for w in alive if w[0] > t]
            p = int(pid[e, r])
            if p < 0:
                continue
            sel = scheduler.select(cluster, p)
            if sel is None:
                continue
            pending = getattr(scheduler, "pending_migration", None)
            if pending is not None:
                vwid, ng, na = pending
                old_gpu, old_anchor, _ = cluster.migrate(vwid, ng, na)
                mig_flag[e, r] = True
                mig_fg[e, r] = old_gpu
                mig_fa[e, r] = old_anchor
                mig_tg[e, r] = ng
                mig_ta[e, r] = na
            g, a = sel
            wid = e  # unique per replica stream
            cluster.allocate(wid, p, g, a)
            alive.append((int(end[e, r]), wid))
            ok[e, r] = True
            gpu[e, r] = g
            anchor[e, r] = a
    return HostTrace(ok, gpu, anchor, mig_flag, mig_fg, mig_fa, mig_tg, mig_ta)


def _make(policy, metric, scheduler_kwargs):
    if scheduler_kwargs:
        from repro.core.policy import resolve
        from repro.core.schedulers import MFIDefrag

        spec = resolve(policy, engine="python")
        if spec.defrag:
            return MFIDefrag(metric=metric, spec=spec, **scheduler_kwargs)
    return make_scheduler(policy, metric)


def host_decisions(
    events: EventStream,
    meta: EventMeta,
    policy: PolicyLike,
    num_gpus: int,
    metric: str = "blocked",
    spec: Optional[mig.ClusterSpec] = None,
    **scheduler_kwargs,
) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Back-compat 3-tuple form of :func:`host_decisions_full`:
    ``(ok, gpu, anchor)`` arrays shaped like the stream (``(E_max, R)``)."""
    t = host_decisions_full(
        events, meta, policy, num_gpus, metric=metric, spec=spec,
        **scheduler_kwargs,
    )
    return t.ok, t.gpu, t.anchor


class QueuedHostTrace(NamedTuple):
    """Reference decisions of the queued protocol, shaped ``(E_max, R)``.

    ``ok`` is the in-place accept of each arrival; ``parked`` marks
    rejected arrivals that entered the wait queue; ``wadm_*`` record, per
    *event*, the wait-queue admission that happened there (the original
    arrival's event index, its GPU and its anchor VALUE; ``-1`` when
    none).
    """

    ok: np.ndarray
    gpu: np.ndarray
    anchor: np.ndarray
    parked: np.ndarray
    wadm_eidx: np.ndarray
    wadm_gpu: np.ndarray
    wadm_anchor: np.ndarray


class _Waiting(NamedTuple):
    """One parked request in the queued host reference."""

    eidx: int   # original event index (= its workload id)
    pid: int
    arr: int    # arrival slot
    end: int    # absolute lease deadline
    prio: int
    tenant: int


def queued_host_decisions(
    events: EventStream,
    meta: EventMeta,
    policy: PolicyLike,
    num_gpus: int,
    metric: str = "blocked",
    spec: Optional[mig.ClusterSpec] = None,
    capacity: int = 8,
    patience: int = 16,
) -> QueuedHostTrace:
    """Drive the Python scheduler over a queued presampled stream.

    The independent host reference of the batched ``steady-queued``
    protocol (:mod:`repro.sim.batched`), event-for-event: at every live
    event, *before* the arrival, prune wait entries past their lease
    deadline or the patience budget, then attempt ONE admission of the
    queue head — the lexicographic minimum of the policy's queue order
    (:func:`repro.core.policy.queue_order`; arrival order breaks ties) —
    committing it with its original profile and deadline.  The arrival
    then selects as usual; a rejected arrival parks if the queue
    (``capacity`` entries) has room.  The device trace must agree
    element-for-element: ``ok``/``parked`` everywhere, placements wherever
    accepted, and the wait admissions (event, origin, placement) exactly.

    The stream must have been presampled with ``queued=True``
    (:func:`repro.sim.batched.presample_arrivals`).
    """
    if events.prio is None:
        raise ValueError(
            "queued_host_decisions needs a queued stream "
            "(presample_arrivals(..., queued=True))"
        )
    spec = _spec_or_default(spec, num_gpus)
    pspec = resolve(policy, engine="python")
    order = queue_order(pspec)
    e_max, runs = np.asarray(events.pid).shape
    pid = np.asarray(events.pid)
    new_slot = np.asarray(events.new_slot)
    slot = np.asarray(meta.slot)
    end = np.asarray(meta.end)
    prio = np.asarray(events.prio)
    tenant = np.asarray(events.tenant)
    wlive = np.asarray(events.wlive)

    ok = np.zeros((e_max, runs), dtype=bool)
    gpu = np.full((e_max, runs), -1, dtype=np.int32)
    anchor = np.full((e_max, runs), -1, dtype=np.int32)
    parked = np.zeros((e_max, runs), dtype=bool)
    wadm_eidx = np.full((e_max, runs), -1, dtype=np.int32)
    wadm_gpu = np.full((e_max, runs), -1, dtype=np.int32)
    wadm_anchor = np.full((e_max, runs), -1, dtype=np.int32)

    def head_key(t):
        def key_fn(w: _Waiting):
            key = []
            for k in order:
                base = key_base(k)
                if base == "priority":
                    v = w.prio
                elif base == "wait-age":
                    v = t - w.arr
                else:  # tenant
                    v = w.tenant
                key.append(-v if k.startswith("-") else v)
            key.append(w.eidx)  # FIFO tie-break
            return tuple(key)

        return key_fn

    for r in range(runs):
        cluster = mig.ClusterState(spec=spec)
        scheduler = make_scheduler(pspec, metric)
        alive = []  # (end_slot, workload_id)
        waiting: List[_Waiting] = []
        for e in range(e_max):
            if new_slot[e, r]:
                t = slot[e, r]
                for tend, wid in [w for w in alive if w[0] <= t]:
                    cluster.release(wid)
                alive = [w for w in alive if w[0] > t]
            if wlive[e, r]:
                t = int(slot[e, r])
                # prune, then one admission attempt of the queue head
                waiting = [
                    w for w in waiting
                    if w.end > t and t - w.arr <= patience
                ]
                if waiting:
                    w = min(waiting, key=head_key(t))
                    sel = scheduler.select(cluster, w.pid)
                    if sel is not None:
                        waiting.remove(w)
                        g, a = sel
                        cluster.allocate(w.eidx, w.pid, g, a)
                        alive.append((w.end, w.eidx))
                        wadm_eidx[e, r] = w.eidx
                        wadm_gpu[e, r] = g
                        wadm_anchor[e, r] = a
            p = int(pid[e, r])
            if p < 0:
                continue
            sel = scheduler.select(cluster, p)
            if sel is not None:
                g, a = sel
                cluster.allocate(e, p, g, a)
                alive.append((int(end[e, r]), e))
                ok[e, r] = True
                gpu[e, r] = g
                anchor[e, r] = a
            elif wlive[e, r] and len(waiting) < capacity:
                waiting.append(
                    _Waiting(
                        eidx=e, pid=p, arr=int(slot[e, r]),
                        end=int(end[e, r]), prio=int(prio[e, r]),
                        tenant=int(tenant[e, r]),
                    )
                )
                parked[e, r] = True
    return QueuedHostTrace(
        ok, gpu, anchor, parked, wadm_eidx, wadm_gpu, wadm_anchor
    )


class FaultedHostTrace(NamedTuple):
    """Reference decisions of the faulted protocol, shaped ``(E_max, R)``.

    The :class:`QueuedHostTrace` fields plus the fault stage's eviction
    accounting: ``evicted`` live entries torn off failing GPUs at this
    event, ``evict_lost`` of which were final losses (wait ring full or a
    zero retry budget), and ``evict_esum`` the sum of their original event
    indexes (an order-insensitive identity check against the device).
    """

    ok: np.ndarray
    gpu: np.ndarray
    anchor: np.ndarray
    parked: np.ndarray
    wadm_eidx: np.ndarray
    wadm_gpu: np.ndarray
    wadm_anchor: np.ndarray
    evicted: np.ndarray
    evict_lost: np.ndarray
    evict_esum: np.ndarray


class _FWaiting(NamedTuple):
    """One parked or evicted request in the faulted host reference."""

    eidx: int   # original event index (= its workload id)
    pid: int
    arr: int    # arrival (or last re-arm) slot — the wait-age clock
    end: int    # absolute lease deadline
    prio: int
    tenant: int
    row: int    # original expiry-ring coordinates (unchanged for life)
    col: int
    tries: int  # re-queue attempts consumed (0 = fresh park)
    rdy: int    # earliest slot this entry may be picked as queue head


class _FAlive(NamedTuple):
    """One running workload in the faulted host reference."""

    end: int
    wid: int
    gpu: int
    row: int
    col: int
    pid: int
    prio: int
    tenant: int


def faulted_host_decisions(
    events: EventStream,
    meta: EventMeta,
    policy: PolicyLike,
    num_gpus: int,
    metric: str = "blocked",
    spec: Optional[mig.ClusterSpec] = None,
    capacity: int = 8,
    patience: int = 16,
    max_retries: int = 2,
    backoff_base: int = 2,
) -> FaultedHostTrace:
    """Drive the Python scheduler over a faulted presampled stream.

    The independent host reference of the batched ``steady-faulted``
    protocol, event-for-event.  Per event, in the engine's stage order:

    1. on a slot boundary, release leases whose end slot arrived (a lease
       ending the very slot its GPU dies still completes);
    2. apply the slot's recover-then-fail lanes: a failing GPU evicts its
       live workloads in flat expiry-ring ``(row, col)`` order, re-queuing
       each (``tries=1``, ready after ``backoff_base`` slots) until the
       wait queue's ``capacity``; the overflow — or everything, when
       ``max_retries < 1`` — is a final loss;
    3. the wait stage: entries past their lease are dropped; entries past
       the ``patience`` budget re-arm with exponential backoff
       (``backoff_base * 2**(tries-1)``) while ``tries < max_retries`` and
       the lease allows, else drop; one admission attempt of the head —
       the queue-order minimum among entries whose backoff expired;
    4. the arrival selects (failed GPUs masked); a reject parks if the
       queue has room (``tries=0``, immediately ready).

    The device trace must agree element-for-element, eviction accounting
    included.  The stream must have been presampled with ``queued=True``
    and a fault model (:func:`repro.sim.batched.presample_arrivals`).
    """
    if events.prio is None or events.fail is None:
        raise ValueError(
            "faulted_host_decisions needs a faulted stream "
            "(presample_arrivals(..., queued=True, fault_model=...))"
        )
    spec = _spec_or_default(spec, num_gpus)
    pspec = resolve(policy, engine="python")
    order = queue_order(pspec)
    e_max, runs = np.asarray(events.pid).shape
    pid = np.asarray(events.pid)
    new_slot = np.asarray(events.new_slot)
    exp_row = np.asarray(events.exp_row)
    exp_col = np.asarray(events.exp_col)
    slot = np.asarray(meta.slot)
    end = np.asarray(meta.end)
    prio = np.asarray(events.prio)
    tenant = np.asarray(events.tenant)
    wlive = np.asarray(events.wlive)
    fail = np.asarray(events.fail)      # (E, R, M)
    recover = np.asarray(events.recover)

    def backoff(k: int) -> int:
        return backoff_base * 2 ** max(0, k - 1)

    ok = np.zeros((e_max, runs), dtype=bool)
    gpu = np.full((e_max, runs), -1, dtype=np.int32)
    anchor = np.full((e_max, runs), -1, dtype=np.int32)
    parked = np.zeros((e_max, runs), dtype=bool)
    wadm_eidx = np.full((e_max, runs), -1, dtype=np.int32)
    wadm_gpu = np.full((e_max, runs), -1, dtype=np.int32)
    wadm_anchor = np.full((e_max, runs), -1, dtype=np.int32)
    evicted = np.zeros((e_max, runs), dtype=np.int32)
    evict_lost = np.zeros((e_max, runs), dtype=np.int32)
    evict_esum = np.zeros((e_max, runs), dtype=np.int32)

    def head_key(t):
        def key_fn(w: _FWaiting):
            key = []
            for k in order:
                base = key_base(k)
                if base == "priority":
                    v = w.prio
                elif base == "wait-age":
                    v = t - w.arr
                else:  # tenant
                    v = w.tenant
                key.append(-v if k.startswith("-") else v)
            key.append(w.eidx)  # FIFO tie-break
            return tuple(key)

        return key_fn

    for r in range(runs):
        cluster = mig.ClusterState(spec=spec)
        scheduler = make_scheduler(pspec, metric)
        alive: List[_FAlive] = []
        waiting: List[_FWaiting] = []
        for e in range(e_max):
            if new_slot[e, r]:
                t = int(slot[e, r])
                for w in [w for w in alive if w.end <= t]:
                    cluster.release(w.wid)
                alive = [w for w in alive if w.end > t]
            ups = np.flatnonzero(recover[e, r])
            for g in ups:  # recover-then-fail, like the device's up update
                cluster.recover_gpu(int(g))
            downs = np.flatnonzero(fail[e, r])
            if len(downs):
                t = int(slot[e, r])
                ds = set(int(g) for g in downs)
                # device flat ring order: evictions fill the wait queue in
                # ascending (row, col) until capacity
                evs = sorted(
                    (w for w in alive if w.gpu in ds),
                    key=lambda w: (w.row, w.col),
                )
                alive = [w for w in alive if w.gpu not in ds]
                for g in ds:
                    cluster.fail_gpu(g)
                evicted[e, r] = len(evs)
                evict_esum[e, r] = sum(w.wid for w in evs)
                lost = 0
                for w in evs:
                    if max_retries >= 1 and len(waiting) < capacity:
                        waiting.append(
                            _FWaiting(
                                eidx=w.wid, pid=w.pid, arr=t, end=w.end,
                                prio=w.prio, tenant=w.tenant,
                                row=w.row, col=w.col,
                                tries=1, rdy=t + backoff(1),
                            )
                        )
                    else:
                        lost += 1
                evict_lost[e, r] = lost
            if wlive[e, r]:
                t = int(slot[e, r])
                # prune / re-arm, then one admission attempt of the head
                kept: List[_FWaiting] = []
                for w in waiting:
                    if t - w.arr > patience:
                        if w.tries < max_retries and w.end > t:
                            k = w.tries + 1
                            kept.append(
                                w._replace(arr=t, tries=k, rdy=t + backoff(k))
                            )
                        # else: retry budget or lease exhausted — final drop
                    elif w.end > t:
                        kept.append(w)
                waiting = kept
                ready = [w for w in waiting if w.rdy <= t]
                if ready:
                    w = min(ready, key=head_key(t))
                    sel = scheduler.select(cluster, w.pid)
                    if sel is not None:
                        waiting.remove(w)
                        g, a = sel
                        cluster.allocate(w.eidx, w.pid, g, a)
                        alive.append(
                            _FAlive(
                                w.end, w.eidx, g, w.row, w.col, w.pid,
                                w.prio, w.tenant,
                            )
                        )
                        wadm_eidx[e, r] = w.eidx
                        wadm_gpu[e, r] = g
                        wadm_anchor[e, r] = a
            p = int(pid[e, r])
            if p < 0:
                continue
            t = int(slot[e, r])
            sel = scheduler.select(cluster, p)
            if sel is not None:
                g, a = sel
                cluster.allocate(e, p, g, a)
                alive.append(
                    _FAlive(
                        int(end[e, r]), e, g, int(exp_row[e, r]),
                        int(exp_col[e, r]), p, int(prio[e, r]),
                        int(tenant[e, r]),
                    )
                )
                ok[e, r] = True
                gpu[e, r] = g
                anchor[e, r] = a
            elif wlive[e, r] and len(waiting) < capacity:
                waiting.append(
                    _FWaiting(
                        eidx=e, pid=p, arr=t, end=int(end[e, r]),
                        prio=int(prio[e, r]), tenant=int(tenant[e, r]),
                        row=int(exp_row[e, r]), col=int(exp_col[e, r]),
                        tries=0, rdy=t,
                    )
                )
                parked[e, r] = True
    return FaultedHostTrace(
        ok, gpu, anchor, parked, wadm_eidx, wadm_gpu, wadm_anchor,
        evicted, evict_lost, evict_esum,
    )
