"""Public policy-and-simulation facade.

One import surface for the declarative policy layer (see
``docs/POLICIES.md``):

    from repro import api

    # run a registered policy through either engine
    api.simulate("mfi", engine="batched", runs=64, num_gpus=50)

    # define + register a custom policy once, run it everywhere
    spec = api.PolicySpec(
        name="pack-new-gen",
        keys=("model-group", "free-slices", "gpu", "-anchor"),
        description="prefer newest device model, then pack tightly",
    )
    api.register_policy(spec)
    api.simulate("pack-new-gen", engine="batched", runs=64)
    sched = api.make_policy("pack-new-gen")   # host Scheduler object

Every entry point validates through the registry's single path
(:func:`repro.core.policy.resolve`), so unknown policies and
policy/engine mismatches raise the same helpful error everywhere.
"""

from __future__ import annotations

from typing import Dict, Optional

from repro.core.mig import FaultModel  # noqa: F401  (re-exported API)
from repro.core.policy import (  # noqa: F401  (re-exported API)
    ENGINES,
    KEY_VOCABULARY,
    PolicyLike,
    PolicySpec,
    get_policy,
    list_policies,
    policy_engines,
    register_policy,
    resolve,
    unregister_policy,
)
from repro.core.schedulers import Scheduler, compile_policy, make_scheduler


def make_policy(policy: PolicyLike, metric: str = "blocked") -> Scheduler:
    """Compile a registered policy name (or ad-hoc spec) for the host
    engine — alias of :func:`repro.core.schedulers.make_scheduler`."""
    return make_scheduler(policy, metric=metric)


def simulate(
    policy: PolicyLike = "mfi",
    cfg=None,
    *,
    engine: str = "python",
    runs: int = 100,
    use_kernel: Optional[bool] = None,
    chunk_size: Optional[int] = None,
    stream: Optional[bool] = None,
    **cfg_kwargs,
) -> Dict[str, float]:
    """Monte-Carlo evaluate one policy on one configuration point.

    Args:
      policy: registered policy name or an ad-hoc :class:`PolicySpec`.
      cfg: a :class:`repro.sim.SimConfig`; built from ``cfg_kwargs``
        (``num_gpus``, ``offered_load``, ``distribution``,
        ``cluster_spec``, ...) when omitted.
      engine: ``"python"`` (reference loop) or ``"batched"`` (single
        XLA-program staged scan).  Both engines run every registered
        policy (defrag variants included — the batched engine compiles a
        migrate stage into its scan) and both protocols (``steady`` |
        ``cumulative``); a spec may still opt out of an engine via its
        ``engines`` field, validated here like everywhere else.
      runs: replicas to average (the paper uses 500).  The batched engine
        auto-shards the replica axis across visible devices when ``runs``
        divides evenly (see :func:`repro.sim.batched.shard_events`).
      use_kernel: batched engine only — route scoring through the Pallas
        kernels (default: auto on TPU): the fused ``delta_from_base`` ΔF
        kernel with per-model dispatch on any fleet, plus the occupancy
        ``fragscore`` rescore on homogeneous specs.  Specs with
        ``kernel_lowering=False`` opt out (requesting it raises).
      chunk_size: batched engine only — run the event scan through the
        chunked streaming driver
        (:func:`repro.sim.batched.simulate_chunked`): device memory is
        bounded by one replica carry plus two staged event chunks instead
        of the full event tensor, with bit-identical results for any
        chunk size.  ``None`` (default) keeps the single-chunk monolithic
        scan.
      stream: chunked runs only — ``True`` (default) fetches each chunk's
        decision trace back to host as it completes so traces never
        accumulate on device; ``False`` keeps them on device.

    Returns the same aggregate dict as :func:`repro.sim.run_many` /
    :func:`repro.sim.batched.run_batched`.
    """
    from repro.sim import SimConfig, run_many
    from repro.sim.batched import run_batched

    spec = resolve(policy, engine=engine)  # one validation path
    if cfg is None:
        cfg = SimConfig(**cfg_kwargs)
    elif cfg_kwargs:
        raise ValueError("pass either cfg or SimConfig kwargs, not both")
    if chunk_size is not None and chunk_size <= 0:
        raise ValueError(
            f"chunk_size must be a positive event count (or None for the "
            f"monolithic scan), got {chunk_size}"
        )
    if engine == "batched":
        return run_batched(
            spec, cfg, runs=runs, use_kernel=use_kernel,
            chunk_size=chunk_size, stream=stream,
        )
    if chunk_size is not None or stream is not None:
        raise ValueError(
            "chunk_size/stream are batched-engine knobs; pass engine='batched'"
        )
    return run_many(spec, cfg, runs=runs)
