"""Paper Fig. 5: scheduler comparison at heavy load (85%) across the four
MIG-profile distributions of Table II.

``--engine batched`` (default ``python``) runs each sweep point through the
batched JAX engine (:mod:`repro.sim.batched`; all five policies, RR's
cursor rides in the scan state).  ``--cluster`` selects the fleet (see
:mod:`benchmarks.fig4_load_sweep`).
"""

from __future__ import annotations

import argparse

from benchmarks.common import (
    CLUSTERS,
    ENGINES,
    MODEL_DISTS,
    PAPER_POLICIES,
    resolve_cluster,
    resolve_model_dist,
    resolve_policies,
    run_engine,
)
from repro.sim import SimConfig
from repro.sim.distributions import DISTRIBUTIONS

SCHEDULERS = PAPER_POLICIES


def run(runs: int = 30, num_gpus: int = 100, load: float = 0.85, seed: int = 0,
        engine: str = "python", cluster: str | None = None,
        policies: str | None = None, model_dist: str | None = None,
        chunk_size: int | None = None):
    spec, num_gpus = resolve_cluster(cluster, num_gpus)
    names = resolve_policies(policies)
    model_dists = resolve_model_dist(model_dist, spec)
    rows, results = [], {}
    for dist in DISTRIBUTIONS:
        for name in names:
            cfg = SimConfig(
                num_gpus=num_gpus, distribution=dist, offered_load=load,
                seed=seed, cluster_spec=spec,
                model_distributions=model_dists,
            )
            r = run_engine(engine, name, cfg, runs=runs, chunk_size=chunk_size)
            results[(name, dist)] = r
            rows.append(
                f"fig5,{name},{dist},{r['acceptance_rate']:.4f},"
                f"{r['allocated_workloads']:.1f},{r['utilization']:.4f},"
                f"{r['active_gpus']:.1f},{r['frag_severity']:.2f}"
            )
    return rows, results


def main(runs: int = 30, engine: str = "python", cluster: str | None = None,
         policies: str | None = None, model_dist: str | None = None,
         chunk_size: int | None = None):
    print("table,scheduler,distribution,acceptance,allocated,utilization,active_gpus,frag")
    rows, results = run(runs=runs, engine=engine, cluster=cluster,
                        policies=policies, model_dist=model_dist,
                        chunk_size=chunk_size)
    for row in rows:
        print(row)
    names = resolve_policies(policies)
    for dist in DISTRIBUTIONS:
        accs = {s: results[(s, dist)]["acceptance_rate"] for s in names}
        best = max(accs, key=accs.get)
        mfi_note = f"; mfi = {accs['mfi']:.4f}" if "mfi" in accs else ""
        print(f"# {dist}: best acceptance = {best} ({accs[best]:.4f}){mfi_note}")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--runs", type=int, default=30)
    ap.add_argument("--engine", choices=ENGINES, default="python")
    ap.add_argument(
        "--cluster", default=None,
        help=f"named scenario {sorted(CLUSTERS)} or spec string 'a100-80:50,a100-40:50'",
    )
    ap.add_argument(
        "--policies", default=None,
        help="comma list of registered policies, or 'all' (default: paper set)",
    )
    ap.add_argument(
        "--model-dist", default=None,
        help=f"per-model demand mix: named scenario {sorted(MODEL_DISTS)} or "
             "'model=dist,model=dist' (default: the swept fleet-wide mix)",
    )
    ap.add_argument(
        "--chunk-size", type=int, default=None,
        help="batched engine only: stream the event scan in chunks of this "
             "many events (bounded device memory, bit-identical results)",
    )
    args = ap.parse_args()
    main(runs=args.runs, engine=args.engine, cluster=args.cluster,
         policies=args.policies, model_dist=args.model_dist,
         chunk_size=args.chunk_size)
