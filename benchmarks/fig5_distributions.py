"""Paper Fig. 5: scheduler comparison at heavy load (85%) across the four
MIG-profile distributions of Table II."""

from __future__ import annotations

import numpy as np

from repro.sim import SimConfig, run_many
from repro.sim.distributions import DISTRIBUTIONS

SCHEDULERS = ("ff", "rr", "bf-bi", "wf-bi", "mfi")


def run(runs: int = 30, num_gpus: int = 100, load: float = 0.85, seed: int = 0):
    rows, results = [], {}
    for dist in DISTRIBUTIONS:
        for name in SCHEDULERS:
            cfg = SimConfig(
                num_gpus=num_gpus, distribution=dist, offered_load=load, seed=seed
            )
            r = run_many(name, cfg, runs=runs)
            results[(name, dist)] = r
            rows.append(
                f"fig5,{name},{dist},{r['acceptance_rate']:.4f},"
                f"{r['allocated_workloads']:.1f},{r['utilization']:.4f},"
                f"{r['active_gpus']:.1f},{r['frag_severity']:.2f}"
            )
    return rows, results


def main(runs: int = 30):
    print("table,scheduler,distribution,acceptance,allocated,utilization,active_gpus,frag")
    rows, results = run(runs=runs)
    for row in rows:
        print(row)
    for dist in DISTRIBUTIONS:
        accs = {s: results[(s, dist)]["acceptance_rate"] for s in SCHEDULERS}
        best = max(accs, key=accs.get)
        print(f"# {dist}: best acceptance = {best} ({accs[best]:.4f}); "
              f"mfi = {accs['mfi']:.4f}")


if __name__ == "__main__":
    main()
