"""Benchmark harness: one module per paper figure + system benches.

``python -m benchmarks.run [--quick]`` prints ``name,...`` CSV per table.
"""

from __future__ import annotations

import argparse
import sys


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--runs", type=int, default=6, help="MC runs per point (paper: 500; full record: experiments/paper_figures.csv @ 30)")
    ap.add_argument("--quick", action="store_true", help="runs=5 for CI")
    ap.add_argument("--engine", choices=("python", "batched"), default="python",
                    help="Monte-Carlo engine for fig4/fig5 sweep points")
    ap.add_argument("--only", default=None, help="comma list: fig4,fig5,fig6,scaling,kernels,roofline,engine")
    args = ap.parse_args()
    runs = 5 if args.quick else args.runs
    only = set(args.only.split(",")) if args.only else None

    from benchmarks import (batched_engine_bench, fig4_load_sweep,
                            fig5_distributions, fig6_fragscore, kernels_bench,
                            roofline_report, scheduler_scaling)

    def want(name):
        return only is None or name in only

    if want("fig4"):
        print("=== Fig. 4: load sweep (uniform) ===")
        fig4_load_sweep.main(runs=runs, engine=args.engine)
    if want("fig5"):
        print("=== Fig. 5: distributions @ 85% ===")
        fig5_distributions.main(runs=runs, engine=args.engine)
    if want("fig6"):
        print("=== Fig. 6: fragmentation severity ===")
        fig6_fragscore.main(runs=runs)
    if want("scaling"):
        print("=== Scheduler scaling O(kM) ===")
        scheduler_scaling.main()
    if want("kernels"):
        print("=== Kernel microbench ===")
        kernels_bench.main()
    if want("roofline"):
        print("=== Roofline (from dry-run artifacts) ===")
        roofline_report.main()
    if want("engine"):
        print("=== Batched engine replica throughput ===")
        batched_engine_bench.main(runs=max(runs, 16))


if __name__ == "__main__":
    main()
