"""Queued-admission load sweep (fig4-style, beyond-paper).

Sweeps offered load through the ``steady-queued`` protocol — the
multi-tenant waiting-queue front-end layered on the paper's steady-state
churn — and reports acceptance alongside the queue-delay/fairness metrics
(p50/p99 wait, Jain fairness over per-tenant acceptance, wait-queue
admissions per replica).  A second pass runs the same points through the
plain accept-or-drop ``steady`` protocol, so each row quantifies exactly
how much acceptance the waiting queue buys at that load (queueing only
matters above saturation; below it the queue stays empty and the deltas
collapse to zero).

``--engine batched`` (default ``python``) runs each point through the
batched JAX engine's wait/park stages (:mod:`repro.sim.batched`); the
Python engine drains greedily per slot, so small statistical differences
between engines are expected — decision-for-decision parity is asserted
by the test suite, not here.

``--policies`` accepts any registered non-defrag policy set; the default
adds ``mfi-queued`` (priority + wait-age queue ordering on top of MFI
placement) to the paper set.
"""

from __future__ import annotations

import argparse
import dataclasses

from benchmarks.common import (
    CLUSTERS,
    ENGINES,
    PAPER_POLICIES,
    resolve_cluster,
    resolve_policies,
    run_engine,
)
from repro.core.policy import resolve
from repro.sim import SimConfig

QUEUED_POLICIES = PAPER_POLICIES + ("mfi-queued",)

#: queueing is interesting above saturation — the sweep brackets it
DEFAULT_LOADS = (0.9, 1.0, 1.1, 1.25, 1.4)


def run(runs: int = 30, num_gpus: int = 100, loads=DEFAULT_LOADS,
        seed: int = 0, engine: str = "python", cluster: str | None = None,
        policies: str | None = None, wait_capacity: int = 8,
        wait_patience: int = 16, num_tenants: int = 4,
        chunk_size: int | None = None):
    spec, num_gpus = resolve_cluster(cluster, num_gpus)
    names = resolve_policies(policies, default=QUEUED_POLICIES)
    for name in names:
        if resolve(name).defrag:
            raise ValueError(
                f"policy {name!r}: defrag composes with the waiting queue "
                "only on the Python engine; drop it from --policies"
            )
    rows = []
    results = {}
    for load in loads:
        for name in names:
            cfg = SimConfig(
                num_gpus=num_gpus, distribution="uniform",
                offered_load=load, seed=seed, cluster_spec=spec,
                protocol="steady-queued", wait_capacity=wait_capacity,
                wait_patience=wait_patience, num_tenants=num_tenants,
            )
            r = run_engine(engine, name, cfg, runs=runs, chunk_size=chunk_size)
            drop = run_engine(
                engine, name, dataclasses.replace(cfg, protocol="steady"),
                runs=runs, chunk_size=chunk_size,
            )
            r = dict(r, acceptance_drop=drop["acceptance_rate"])
            results[(name, load)] = r
            rows.append(
                f"fig4q,{name},{load},{r['acceptance_rate']:.4f},"
                f"{r['acceptance_drop']:.4f},{r['wait_p50']:.2f},"
                f"{r['wait_p99']:.2f},{r['fairness']:.4f},"
                f"{r['queue_admits']:.1f}"
            )
    return rows, results


def main(runs: int = 30, engine: str = "python", cluster: str | None = None,
         policies: str | None = None, wait_capacity: int = 8,
         wait_patience: int = 16, num_tenants: int = 4,
         chunk_size: int | None = None):
    print(
        "table,scheduler,load,acceptance_queued,acceptance_drop,"
        "wait_p50,wait_p99,fairness,queue_admits"
    )
    rows, results = run(
        runs=runs, engine=engine, cluster=cluster, policies=policies,
        wait_capacity=wait_capacity, wait_patience=wait_patience,
        num_tenants=num_tenants, chunk_size=chunk_size,
    )
    for row in rows:
        print(row)
    names = resolve_policies(policies, default=QUEUED_POLICIES)
    heavy = max(load for (_, load) in results)
    gains = {
        name: results[(name, heavy)]["acceptance_rate"]
        - results[(name, heavy)]["acceptance_drop"]
        for name in names
    }
    best = max(gains, key=gains.get)
    print(
        f"# queueing gain @ {heavy:.0%} load (acceptance, queued - drop): "
        + ", ".join(f"{n}={g:+.4f}" for n, g in sorted(gains.items()))
    )
    print(f"# largest gain: {best} ({gains[best]:+.4f})")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--runs", type=int, default=30)
    ap.add_argument("--engine", choices=ENGINES, default="python")
    ap.add_argument(
        "--cluster", default=None,
        help=f"named scenario {sorted(CLUSTERS)} or spec string "
             "'a100-80:50,a100-40:50'",
    )
    ap.add_argument(
        "--policies", default=None,
        help="comma list of registered non-defrag policies, or 'all' "
             "(default: paper set + mfi-queued)",
    )
    ap.add_argument("--wait-capacity", type=int, default=8,
                    help="waiting-queue slots per cluster")
    ap.add_argument("--wait-patience", type=int, default=16,
                    help="max slots a request may wait before final reject")
    ap.add_argument("--num-tenants", type=int, default=4,
                    help="tenant ids sampled per arrival (fairness metric)")
    ap.add_argument("--chunk-size", type=int, default=None,
                    help="batched engine only: stream the event scan in "
                         "chunks of this many events (bounded device memory, "
                         "bit-identical results)")
    args = ap.parse_args()
    main(runs=args.runs, engine=args.engine, cluster=args.cluster,
         policies=args.policies, wait_capacity=args.wait_capacity,
         wait_patience=args.wait_patience, num_tenants=args.num_tenants,
         chunk_size=args.chunk_size)
