"""Aggregate dry-run artifacts into the §Roofline table (reads
experiments/dryrun/*.json produced by repro.launch.dryrun)."""

from __future__ import annotations

import json
from pathlib import Path


def load(out_dir="experiments/dryrun"):
    arts = []
    for f in sorted(Path(out_dir).glob("*.json")):
        arts.append(json.loads(f.read_text()))
    return arts


def main():
    arts = load()
    if not arts:
        print("# no dry-run artifacts found — run: python -m repro.launch.dryrun --all")
        return
    print("table,arch,shape,mesh,compute_ms,memory_ms,collective_ms,bottleneck,"
          "useful_ratio,mem_gib_per_chip")
    for a in arts:
        if a.get("tag"):
            continue  # perf-iteration artifacts reported in §Perf
        r = a["roofline"]
        print(
            f"roofline,{a['arch']},{a['shape']},{a['mesh']},"
            f"{r['compute_s']*1e3:.2f},{r['memory_s']*1e3:.2f},"
            f"{r['collective_s']*1e3:.2f},{r['bottleneck']},"
            f"{r['useful_flops_ratio']:.3f},"
            f"{a['memory']['total_bytes_per_chip']/2**30:.2f}"
        )


if __name__ == "__main__":
    main()
