"""Fault-intensity sweep (beyond-paper robustness figure).

Sweeps the GPU failure rate through the ``steady-faulted`` protocol — the
queued multi-tenant front-end overlaid with an exponential per-GPU
fail/recover process (:class:`repro.core.mig.FaultModel`) — and reports,
per (policy, MTBF) point: acceptance, goodput (completed measured work
over measured arrivals), evictions per replica, the fraction of evicted
workloads that re-admitted before their retry budget or lease ran out,
and the p50/p99 time-to-recovery of those re-admissions.  A fault-free
``steady-queued`` pass at the same load anchors each row, so
``acceptance - acceptance_nofault`` isolates what the fault process costs
and ``recovered_fraction`` shows how much of it the backoff re-queue
claws back.

``--engine batched`` (default ``python``) runs each point through the
batched JAX engine's fault/wait/park stages (:mod:`repro.sim.batched`);
decision-for-decision parity between the engines' fault paths is
asserted by the test suite (``tests/test_faults.py``), not here.
"""

from __future__ import annotations

import argparse
import dataclasses

from benchmarks.common import (
    CLUSTERS,
    ENGINES,
    resolve_cluster,
    resolve_policies,
    run_engine,
)
from repro.core.mig import FaultModel
from repro.core.policy import resolve
from repro.sim import SimConfig

FAULT_POLICIES = ("ff", "mfi", "mfi-queued")

#: MTBF sweep in slots, hottest first — at MTTR 10 and a ~200-slot horizon
#: these bracket "a GPU is down ~14% of the time" down to "faults are rare"
DEFAULT_MTBFS = (30.0, 60.0, 120.0, 240.0, 480.0)


def run(runs: int = 30, num_gpus: int = 100, mtbfs=DEFAULT_MTBFS,
        mttr: float = 10.0, load: float = 1.1, seed: int = 0,
        engine: str = "python", cluster: str | None = None,
        policies: str | None = None, wait_capacity: int = 8,
        wait_patience: int = 16, num_tenants: int = 4,
        max_retries: int = 2):
    spec, num_gpus = resolve_cluster(cluster, num_gpus)
    names = resolve_policies(policies, default=FAULT_POLICIES)
    for name in names:
        if resolve(name).defrag:
            raise ValueError(
                f"policy {name!r}: defrag composes with the fault protocol "
                "only on the Python engine; drop it from --policies"
            )
    rows = []
    results = {}
    for name in names:
        base_cfg = SimConfig(
            num_gpus=num_gpus, distribution="uniform", offered_load=load,
            seed=seed, cluster_spec=spec, protocol="steady-queued",
            wait_capacity=wait_capacity, wait_patience=wait_patience,
            num_tenants=num_tenants,
        )
        nofault = run_engine(engine, name, base_cfg, runs=runs)
        for mtbf in mtbfs:
            cfg = dataclasses.replace(
                base_cfg, protocol="steady-faulted",
                fault_model=FaultModel(
                    mtbf=mtbf, mttr=mttr, max_retries=max_retries
                ),
            )
            r = run_engine(engine, name, cfg, runs=runs)
            r = dict(r, acceptance_nofault=nofault["acceptance_rate"])
            results[(name, mtbf)] = r
            rows.append(
                f"faults,{name},{mtbf:g},{r['acceptance_rate']:.4f},"
                f"{r['acceptance_nofault']:.4f},{r['goodput']:.4f},"
                f"{r['evictions']:.2f},{r['recovered_fraction']:.4f},"
                f"{r['ttr_p50']:.2f},{r['ttr_p99']:.2f}"
            )
    return rows, results


def main(runs: int = 30, num_gpus: int = 100, engine: str = "python",
         cluster: str | None = None, policies: str | None = None,
         mtbfs=DEFAULT_MTBFS, mttr: float = 10.0, load: float = 1.1,
         wait_capacity: int = 8, wait_patience: int = 16,
         num_tenants: int = 4, max_retries: int = 2):
    print(
        "table,scheduler,mtbf,acceptance,acceptance_nofault,goodput,"
        "evictions,recovered_fraction,ttr_p50,ttr_p99"
    )
    rows, results = run(
        runs=runs, num_gpus=num_gpus, mtbfs=mtbfs, mttr=mttr, load=load,
        engine=engine, cluster=cluster, policies=policies,
        wait_capacity=wait_capacity, wait_patience=wait_patience,
        num_tenants=num_tenants, max_retries=max_retries,
    )
    for row in rows:
        print(row)
    names = resolve_policies(policies, default=FAULT_POLICIES)
    hottest = min(mtbf for (_, mtbf) in results)
    costs = {
        name: results[(name, hottest)]["acceptance_nofault"]
        - results[(name, hottest)]["acceptance_rate"]
        for name in names
    }
    recov = {
        name: results[(name, hottest)]["recovered_fraction"] for name in names
    }
    print(
        f"# fault cost @ MTBF {hottest:g} (acceptance, no-fault - faulted): "
        + ", ".join(f"{n}={c:+.4f}" for n, c in sorted(costs.items()))
    )
    print(
        "# recovered fraction at the same point: "
        + ", ".join(f"{n}={r:.4f}" for n, r in sorted(recov.items()))
    )


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--runs", type=int, default=30)
    ap.add_argument("--num-gpus", type=int, default=100)
    ap.add_argument("--engine", choices=ENGINES, default="python")
    ap.add_argument(
        "--cluster", default=None,
        help=f"named scenario {sorted(CLUSTERS)} or spec string "
             "'a100-80:50,a100-40:50'",
    )
    ap.add_argument(
        "--policies", default=None,
        help="comma list of registered non-defrag policies, or 'all' "
             "(default: ff, mfi, mfi-queued)",
    )
    ap.add_argument("--mtbfs", default=None,
                    help="comma list of MTBF values in slots "
                         f"(default {','.join(f'{m:g}' for m in DEFAULT_MTBFS)})")
    ap.add_argument("--mttr", type=float, default=10.0,
                    help="mean slots a failed GPU stays down")
    ap.add_argument("--load", type=float, default=1.1,
                    help="offered load (above saturation so the queue and "
                         "the fault path both matter)")
    ap.add_argument("--wait-capacity", type=int, default=8,
                    help="waiting-queue slots per cluster")
    ap.add_argument("--wait-patience", type=int, default=16,
                    help="max slots a request may wait before final reject")
    ap.add_argument("--num-tenants", type=int, default=4,
                    help="tenant ids sampled per arrival (fairness metric)")
    ap.add_argument("--max-retries", type=int, default=2,
                    help="re-queue budget for evicted workloads")
    args = ap.parse_args()
    mtbfs = (
        tuple(float(m) for m in args.mtbfs.split(",") if m.strip())
        if args.mtbfs else DEFAULT_MTBFS
    )
    main(runs=args.runs, num_gpus=args.num_gpus, engine=args.engine,
         cluster=args.cluster, policies=args.policies, mtbfs=mtbfs,
         mttr=args.mttr, load=args.load, wait_capacity=args.wait_capacity,
         wait_patience=args.wait_patience, num_tenants=args.num_tenants,
         max_retries=args.max_retries)
