"""Paper Fig. 6: average cluster fragmentation score per scheduler per
distribution (85% load) — validates that MFI's acceptance advantage
corresponds to the lowest fragmentation severity."""

from __future__ import annotations

from benchmarks.common import PAPER_POLICIES
from repro.sim import SimConfig, run_many
from repro.sim.distributions import DISTRIBUTIONS

SCHEDULERS = PAPER_POLICIES


def run(runs: int = 30, num_gpus: int = 100, load: float = 0.85, seed: int = 0):
    rows, frag = [], {}
    for dist in DISTRIBUTIONS:
        for name in SCHEDULERS:
            cfg = SimConfig(num_gpus=num_gpus, distribution=dist, offered_load=load, seed=seed)
            r = run_many(name, cfg, runs=runs)
            frag[(name, dist)] = r["frag_severity"]
            rows.append(f"fig6,{name},{dist},{r['frag_severity']:.3f}")
    return rows, frag


def main(runs: int = 30):
    print("table,scheduler,distribution,frag_severity")
    rows, frag = run(runs=runs)
    for row in rows:
        print(row)
    for dist in DISTRIBUTIONS:
        vals = {s: frag[(s, dist)] for s in SCHEDULERS}
        low = min(vals, key=vals.get)
        print(f"# {dist}: lowest frag = {low} ({vals[low]:.2f}); mfi = {vals['mfi']:.2f}")


if __name__ == "__main__":
    main()
