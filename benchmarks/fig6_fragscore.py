"""Paper Fig. 6: average cluster fragmentation score per scheduler per
distribution (85% load) — validates that MFI's acceptance advantage
corresponds to the lowest fragmentation severity.

``--fused`` drives the sweep through the batched engine with
``use_kernel=True``: the fused Pallas select kernels (in-kernel
lexicographic argmin; interpret mode on CPU) replace the Python reference
scheduler.  Decisions are engine-parity-tested bit-for-bit, so the figure
is the same — the flag benchmarks the fused path at paper scale.
"""

from __future__ import annotations

import argparse

from benchmarks.common import PAPER_POLICIES
from repro.sim import SimConfig, run_many
from repro.sim.distributions import DISTRIBUTIONS

SCHEDULERS = PAPER_POLICIES


def run(runs: int = 30, num_gpus: int = 100, load: float = 0.85, seed: int = 0,
        fused: bool = False):
    if fused:
        from repro.sim.batched import run_batched
    rows, frag = [], {}
    for dist in DISTRIBUTIONS:
        for name in SCHEDULERS:
            cfg = SimConfig(num_gpus=num_gpus, distribution=dist, offered_load=load, seed=seed)
            if fused:
                r = run_batched(name, cfg, runs=runs, use_kernel=True)
            else:
                r = run_many(name, cfg, runs=runs)
            frag[(name, dist)] = r["frag_severity"]
            rows.append(f"fig6,{name},{dist},{r['frag_severity']:.3f}")
    return rows, frag


def main(runs: int = 30, fused: bool = False):
    print("table,scheduler,distribution,frag_severity")
    rows, frag = run(runs=runs, fused=fused)
    for row in rows:
        print(row)
    for dist in DISTRIBUTIONS:
        vals = {s: frag[(s, dist)] for s in SCHEDULERS}
        low = min(vals, key=vals.get)
        print(f"# {dist}: lowest frag = {low} ({vals[low]:.2f}); mfi = {vals['mfi']:.2f}")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--runs", type=int, default=30)
    ap.add_argument("--fused", action="store_true",
                    help="batched engine with the fused Pallas select "
                         "kernels (use_kernel=True) instead of the Python "
                         "reference")
    args = ap.parse_args()
    main(runs=args.runs, fused=args.fused)
