"""Shared benchmark utilities."""

from __future__ import annotations

import time
from typing import Callable


def time_fn(fn: Callable, *args, warmup: int = 2, iters: int = 10) -> float:
    """Median wall time per call in microseconds."""
    for _ in range(warmup):
        fn(*args)
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        fn(*args)
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e6


def csv_row(name: str, us: float, derived: str = "") -> str:
    return f"{name},{us:.1f},{derived}"


ENGINES = ("python", "batched")

#: named fleet scenarios (--cluster flags also accept raw spec strings
#: such as "a100-80:40,a100-40:40,h100-96:20")
CLUSTERS = {
    "homogeneous": None,
    "mixed": "a100-80:50,a100-40:50",
}


def resolve_cluster(cluster, num_gpus: int):
    """``--cluster`` value -> (ClusterSpec | None, effective num_gpus)."""
    from repro.core import mig

    text = CLUSTERS.get(cluster, cluster) if cluster else None
    if text is None:
        return None, num_gpus
    spec = mig.ClusterSpec.parse(text)
    return spec, spec.num_gpus


def run_engine(engine: str, scheduler: str, cfg, runs: int):
    """Dispatch a Monte-Carlo sweep point to the chosen simulation engine.

    ``batched`` covers the five scan policies (mfi/ff/bf-bi/wf-bi/rr — RR's
    cursor rides in the scan state) on the steady protocol, homogeneous or
    mixed ``cfg.cluster_spec``; anything else (mfi-defrag, cumulative)
    falls back to the Python reference loop so sweeps stay complete.
    """
    from repro.sim import run_many
    from repro.sim.batched import POLICIES, run_batched

    if engine not in ENGINES:
        raise ValueError(f"unknown engine {engine!r}; options {ENGINES}")
    if (
        engine == "batched"
        and scheduler in POLICIES
        and cfg.protocol == "steady"
    ):
        return run_batched(scheduler, cfg, runs=runs)
    return run_many(scheduler, cfg, runs=runs)
