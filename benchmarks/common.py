"""Shared benchmark utilities."""

from __future__ import annotations

import time
from typing import Callable


def time_fn(fn: Callable, *args, warmup: int = 2, iters: int = 10) -> float:
    """Median wall time per call in microseconds."""
    for _ in range(warmup):
        fn(*args)
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        fn(*args)
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e6


def csv_row(name: str, us: float, derived: str = "") -> str:
    return f"{name},{us:.1f},{derived}"


ENGINES = ("python", "batched")

#: the paper's evaluation set (Figs. 4-6); ``--policies`` accepts any
#: registered policy name (see ``repro.core.policy.list_policies``)
PAPER_POLICIES = ("ff", "rr", "bf-bi", "wf-bi", "mfi")

#: named fleet scenarios (--cluster flags also accept raw spec strings
#: such as "a100-80:40,a100-40:40,h100-96:20").  The ``mixed`` scenario is
#: a four-model fleet — both A100 SKUs plus both H100 SKUs — so every
#: sweep exercises the registry's per-model placement tables end to end;
#: ``mixed-h200`` adds the stylized 12-slice H200-141GB, exercising the
#: padded-width (non-8-slice) table path.
CLUSTERS = {
    "homogeneous": None,
    "mixed": "a100-80:30,a100-40:30,h100-96:20,h100-80:20",
    "mixed-h200": "a100-80:25,a100-40:25,h100-96:20,h100-80:15,h200-141:15",
}

#: named per-model demand-mix scenarios for `--model-dist` (raw
#: "model=dist,model=dist" strings are also accepted): newer SKUs attract
#: the big classes, the A100-40s see the small ones
MODEL_DISTS = {
    "generation-skew": (
        "a100-40=skew-small,h100-96=skew-big,h100-80=skew-big,"
        "h200-141=skew-big"
    ),
}


def resolve_policies(arg, default=PAPER_POLICIES):
    """``--policies`` value -> validated tuple of registered policy names.

    ``None``/empty keeps the paper set; ``"all"`` expands to every
    registered policy; otherwise a comma-separated list.  Unknown names
    raise through the registry's single validation path.
    """
    from repro.core.policy import list_policies, resolve

    if not arg:
        names = tuple(default)
    elif arg == "all":
        names = list_policies()
    else:
        names = tuple(p.strip() for p in arg.split(",") if p.strip())
    for name in names:
        resolve(name)
    return names


def resolve_cluster(cluster, num_gpus: int):
    """``--cluster`` value -> (ClusterSpec | None, effective num_gpus)."""
    from repro.core import mig

    text = CLUSTERS.get(cluster, cluster) if cluster else None
    if text is None:
        return None, num_gpus
    spec = mig.ClusterSpec.parse(text)
    return spec, spec.num_gpus


def resolve_model_dist(arg, spec=None):
    """``--model-dist`` value -> per-model distribution dict (or None).

    Accepts a named scenario (see :data:`MODEL_DISTS`) or a raw
    ``"a100-40=skew-small,h100-96=skew-big"`` string; distribution names
    validate in :func:`repro.sim.distributions.resolve_probs` when the
    config is used.  With ``spec``, entries for models outside the fleet
    are dropped (named scenarios cover the superset of all scenarios'
    models; the strict core-layer validation stays for direct API users).
    """
    if not arg:
        return None
    from repro.core import mig

    text = MODEL_DISTS.get(arg, arg)
    out = {}
    for part in text.split(","):
        model, sep, dist = part.strip().partition("=")
        if not sep:
            raise ValueError(
                f"--model-dist entry {part!r} is not 'model=distribution'"
            )
        out[model] = dist
    for name in out:
        if name not in mig.DEVICE_MODELS:  # typos raise; never drop silently
            raise ValueError(
                f"unknown device model {name!r} in --model-dist; options "
                f"{sorted(set(mig.DEVICE_MODELS))}"
            )
    if spec is not None:
        fleet = {m.name for m in spec.models}
        out = {
            k: v for k, v in out.items() if mig.DEVICE_MODELS[k].name in fleet
        }
    return out or None


def run_engine(engine: str, scheduler, cfg, runs: int, chunk_size=None):
    """Dispatch a Monte-Carlo sweep point to the chosen simulation engine.

    ``scheduler`` is any registered policy name (or ad-hoc ``PolicySpec``);
    the policy registry decides batched capability.  ``batched`` runs every
    batched-capable policy — the defrag variants (migrate stage in the
    scan) and the cumulative protocol included — on homogeneous or mixed
    ``cfg.cluster_spec``; engine-restricted specs fall back to the Python
    reference loop so sweeps stay complete.  ``chunk_size`` routes batched
    points through the chunked streaming driver (bounded device memory,
    bit-identical results; see ``repro.sim.batched.simulate_chunked``) and
    is ignored on the Python fallback.
    """
    from repro.core.policy import resolve
    from repro.sim import run_many
    from repro.sim.batched import run_batched

    if engine not in ENGINES:
        raise ValueError(f"unknown engine {engine!r}; options {ENGINES}")
    spec = resolve(scheduler)
    if engine == "batched" and spec.supports("batched"):
        return run_batched(spec, cfg, runs=runs, chunk_size=chunk_size)
    return run_many(spec, cfg, runs=runs)
