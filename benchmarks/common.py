"""Shared benchmark utilities."""

from __future__ import annotations

import time
from typing import Callable


def time_fn(fn: Callable, *args, warmup: int = 2, iters: int = 10) -> float:
    """Median wall time per call in microseconds."""
    for _ in range(warmup):
        fn(*args)
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        fn(*args)
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e6


def csv_row(name: str, us: float, derived: str = "") -> str:
    return f"{name},{us:.1f},{derived}"
