"""Replica-throughput benchmark: batched JAX engine vs the Python reference.

Measures both engines back-to-back on the same point — by default the
paper-scale heavy-load point (M=100, uniform, 85% offered load) with 64
replicas — and reports replicas/second.  The batched engine is reported
twice: *cold* (first call, includes XLA compilation — what a one-shot
script sees) and *steady-state* (what any sweep beyond one point sees:
the compiled program is reused across loads, distributions and seeds,
only shapes recompile).  The headline speedup is the steady-state number;
the acceptance bar is >= 10x on CPU.

``--smoke`` shrinks the point (M=16, 8 replicas) so CI can track the perf
trajectory per-PR in ~a minute; ``--json PATH`` dumps the metrics for the
workflow artifact.  Smoke mode records the numbers without enforcing the
10x bar (tiny clusters under-utilize the batched engine by design), and
additionally sweeps **every registered batched-capable policy**
(``repro.core.policy.list_policies(engine="batched")``) for warm per-policy
throughput — ``mfi-defrag``'s migrate stage included — plus one
**cumulative-protocol** run and one **steady-queued** run (above
saturation, recording p50/p99 wait, fairness and queue admits next to
throughput), so the uploaded artifact tracks the perf trajectory of every
engine configuration, including policies registered after this benchmark
was written (``--sweep``/``--no-sweep`` overrides).

``--profile`` adds a per-stage wall-time breakdown of the ``EngineCore``
pipeline (select / migrate / commit / expire, µs per event across the
replica batch) for a defrag and a non-defrag spec, emitted under
``stage_profile`` in the JSON payload — the view that shows *where* an
engine configuration spends its scan step.

``--baseline PATH`` diffs the run against a committed reference artifact
(``benchmarks/BENCH_baseline.json``): the headline ``speedup_warm`` (the
batched-vs-python ratio, machine-normalized) must not regress by more than
20%, per-policy warm-throughput ratios are recorded under ``vs_baseline``
in the payload, and the process exits non-zero on a gate failure — this is
the CI perf-trajectory gate.
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import sys
import time

from repro.core.policy import list_policies
from repro.sim import SimConfig, run_many
from repro.sim.batched import run_batched

#: maximum tolerated relative drop of speedup_warm vs the baseline artifact
REGRESSION_GATE = 0.20

#: queue metrics are deterministic for a fixed seed/config — tolerate only
#: float noise, so behavioral drift in the wait/park stages fails the gate
QUEUED_METRIC_TOL = 1e-6


def sweep_policies(cfg: SimConfig, runs: int):
    """Warm replica throughput of every registered batched-capable policy."""
    out = {}
    for policy in list_policies(engine="batched"):
        run_batched(policy, cfg, runs=runs)  # compile + warm the cache
        t0 = time.perf_counter()
        r = run_batched(policy, cfg, runs=runs)
        dt = time.perf_counter() - t0
        out[policy] = {
            "warm_rps": runs / dt,
            "acceptance_rate": float(r["acceptance_rate"]),
        }
    return out


def bench_cumulative(cfg: SimConfig, runs: int):
    """Warm throughput of one cumulative-protocol batched run (mfi)."""
    ccfg = dataclasses.replace(cfg, protocol="cumulative")
    run_batched("mfi", ccfg, runs=runs)  # compile + warm the cache
    t0 = time.perf_counter()
    r = run_batched("mfi", ccfg, runs=runs)
    dt = time.perf_counter() - t0
    return {
        "warm_rps": runs / dt,
        "acceptance_rate": float(r["acceptance_rate"]),
        "final_utilization": float(r["utilization"]),
    }


def bench_queued(cfg: SimConfig, runs: int):
    """Warm throughput + queue metrics of one steady-queued batched run.

    Run above saturation (load >= 1.1) so the wait ring actually cycles;
    the metrics are deterministic for a fixed seed/config, so the baseline
    diff can gate on them tightly — a silent change to the wait/park
    stages shows up as metric drift here before any parity test runs.
    """
    qcfg = dataclasses.replace(
        cfg, protocol="steady-queued", offered_load=max(cfg.offered_load, 1.1)
    )
    run_batched("mfi", qcfg, runs=runs)  # compile + warm the cache
    t0 = time.perf_counter()
    r = run_batched("mfi", qcfg, runs=runs)
    dt = time.perf_counter() - t0
    return {
        "warm_rps": runs / dt,
        "acceptance_rate": float(r["acceptance_rate"]),
        "wait_p50": float(r["wait_p50"]),
        "wait_p99": float(r["wait_p99"]),
        "fairness": float(r["fairness"]),
        "queue_admits": float(r["queue_admits"]),
    }


def profile_stages(cfg: SimConfig, runs: int, policies=("mfi", "mfi-defrag")):
    """Per-stage warm wall-time of the ``EngineCore`` pipeline.

    Builds each policy's staged core, drives one full warm run to obtain a
    *representative* replica state (steady state at the configured load),
    then times every stage as its own jitted + vmapped program: µs per
    event across the whole replica batch — exactly the work one scan step
    does per stage.  The defrag spec's ``migrate`` row is the one the
    factored search optimizes; non-defrag specs have no migrate stage.
    """
    import jax
    import jax.numpy as jnp

    from repro.core.policy import resolve
    from repro.sim import batched

    spec = cfg.spec()
    tables = batched.spec_tables(spec)
    midx = jnp.asarray(spec.model_index)
    vg = tables.V[midx]
    events, _, ring_rows, ring_cols = batched.presample_arrivals(cfg, runs)
    dev = jax.tree.map(jnp.asarray, events)

    def timeit(fn, *args, iters=20):
        jax.block_until_ready(fn(*args))  # compile + warm
        t0 = time.perf_counter()
        for _ in range(iters):
            out = fn(*args)
        jax.block_until_ready(out)
        return (time.perf_counter() - t0) / iters * 1e6  # µs / event batch

    out = {}
    for policy in policies:
        pspec = resolve(policy, engine="batched")
        core = batched.EngineCore(
            spec=pspec,
            protocol=batched.resolve_protocol("steady"),
            metric=cfg.metric,
            tables=tables,
            midx=midx,
            vg=vg,
        )
        state, _ = batched._simulate(
            dev, policy=policy, metric=cfg.metric, num_gpus=cfg.num_gpus,
            ring_rows=ring_rows, ring_cols=ring_cols, use_kernel=False,
            midx=midx, tables=tables,
        )  # final (R,)-vmapped state: steady-state occupancy at this load
        pid = jnp.full((runs,), 2, jnp.int32)
        valid = jnp.ones((runs,), bool)
        zeros = jnp.zeros((runs,), jnp.int32)
        new_slot = jnp.ones((runs,), bool)

        expire = jax.jit(jax.vmap(core._stage_expire))
        select = jax.jit(jax.vmap(core._stage_select))
        stages = {
            "expire_us": timeit(expire, state, zeros, new_slot),
            "select_us": timeit(select, state, pid, valid),
        }
        gpu, aidx, ok = select(state, pid, valid)
        mig_res = None
        if pspec.defrag:
            migrate = jax.jit(jax.vmap(core._stage_migrate))
            stages["migrate_us"] = timeit(migrate, state, pid, valid, gpu, aidx, ok)
            state, gpu, aidx, ok, mig_res = migrate(state, pid, valid, gpu, aidx, ok)
        commit = jax.jit(
            jax.vmap(
                lambda st, p, g, a, o, er, ec, mr=None: core._stage_commit(
                    st, p, g, a, o, er, ec, mr
                )
            )
            if mig_res is None
            else jax.vmap(core._stage_commit)
        )
        args = (state, pid, gpu, aidx, ok, zeros, zeros)
        if mig_res is not None:
            args = args + (mig_res,)
        stages["commit_us"] = timeit(commit, *args)
        out[policy] = stages
    return out


def compare_baseline(payload: dict, baseline_path: str, gate: float = REGRESSION_GATE):
    """Diff this run against a committed baseline artifact.

    Returns ``(vs_baseline, ok)``: the comparison dict recorded in the JSON
    payload, and whether the headline ``speedup_warm`` (machine-normalized:
    batched warm throughput over the same host's Python engine) stayed
    within ``gate`` of the baseline.  Per-policy raw warm-rps ratios are
    informational (they compare across machines when the artifact was
    recorded elsewhere).
    """
    with open(baseline_path) as fh:
        base = json.load(fh)
    cur, ref = payload["speedup_warm"], base["speedup_warm"]
    vs = {
        "baseline_path": baseline_path,
        "speedup_warm": {"baseline": ref, "current": cur, "ratio": cur / ref},
        "gate": gate,
    }
    mismatch = {
        k: {"baseline": base.get(k), "current": payload.get(k)}
        for k in ("num_gpus", "runs", "load", "smoke")
        if base.get(k) != payload.get(k)
    }
    if mismatch:  # different problem size — ratios are meaningless, no gate
        vs["config_mismatch"] = mismatch
        vs["pass"] = True
        print(
            f"# vs baseline {baseline_path}: CONFIG MISMATCH "
            f"({', '.join(sorted(mismatch))}) — comparison recorded, "
            "regression gate skipped"
        )
        return vs, True
    pol = {}
    for name, p in (payload.get("policies") or {}).items():
        b = (base.get("policies") or {}).get(name)
        if b:
            pol[name] = {
                "baseline_rps": b["warm_rps"],
                "current_rps": p["warm_rps"],
                "ratio": p["warm_rps"] / b["warm_rps"],
            }
    if pol:
        vs["policies"] = pol
    ok = cur >= (1.0 - gate) * ref
    qb, qc = base.get("queued"), payload.get("queued")
    if qb and qc:
        # queue metrics are seed-deterministic: any drift means the wait or
        # park stage changed behavior, not just performance
        drift = {
            k: {"baseline": qb[k], "current": qc[k]}
            for k in (
                "acceptance_rate", "wait_p50", "wait_p99", "fairness",
                "queue_admits",
            )
            if k in qb
            and abs(qc[k] - qb[k]) > QUEUED_METRIC_TOL * max(1.0, abs(qb[k]))
        }
        vs["queued"] = {"tolerance": QUEUED_METRIC_TOL, "drift": drift,
                        "pass": not drift}
        if drift:
            ok = False
    vs["pass"] = ok
    return vs, ok


def bench_point(policy: str, cfg: SimConfig, runs: int, py_runs: int):
    t0 = time.perf_counter()
    rp = run_many(policy, cfg, runs=py_runs)
    t_python = (time.perf_counter() - t0) / py_runs  # sec / replica

    t0 = time.perf_counter()
    rb = run_batched(policy, cfg, runs=runs)
    t_cold = time.perf_counter() - t0
    t0 = time.perf_counter()
    run_batched(policy, cfg, runs=runs)
    t_warm = time.perf_counter() - t0

    return {
        "python_rps": 1.0 / t_python,
        "cold_rps": runs / t_cold,
        "warm_rps": runs / t_warm,
        "speedup_cold": t_python * runs / t_cold,
        "speedup_warm": t_python * runs / t_warm,
        "acc_python": rp["acceptance_rate"],
        "acc_batched": rb["acceptance_rate"],
    }


def main(runs: int = 64, num_gpus: int = 100, load: float = 0.85,
         policy: str = "mfi", py_runs: int = 3, smoke: bool = False,
         json_path: str | None = None, sweep: bool | None = None,
         profile: bool = False, baseline: str | None = None):
    if smoke:
        runs, num_gpus, py_runs = min(runs, 8), min(num_gpus, 16), min(py_runs, 2)
    if sweep is None:
        sweep = smoke  # CI artifact tracks all batched-capable policies
    cfg = SimConfig(
        num_gpus=num_gpus, distribution="uniform", offered_load=load, seed=0
    )
    print("table,engine,policy,num_gpus,runs,replicas_per_sec,speedup")
    r = bench_point(policy, cfg, runs, py_runs)
    print(f"engine,python,{policy},{num_gpus},{py_runs},{r['python_rps']:.2f},1.0")
    print(
        f"engine,batched-cold,{policy},{num_gpus},{runs},"
        f"{r['cold_rps']:.2f},{r['speedup_cold']:.1f}"
    )
    print(
        f"engine,batched,{policy},{num_gpus},{runs},"
        f"{r['warm_rps']:.2f},{r['speedup_warm']:.1f}"
    )
    print(
        f"# acceptance parity: python={r['acc_python']:.4f} "
        f"batched={r['acc_batched']:.4f}"
    )
    ok = smoke or r["speedup_warm"] >= 10.0
    print(
        f"# replica-throughput speedup (steady-state) @ "
        f"(M={num_gpus}, runs={runs}, uniform, {load:.2f} load): "
        f"{r['speedup_warm']:.1f}x (cold incl. compile: {r['speedup_cold']:.1f}x) "
        f"-> {'PASS' if ok else 'FAIL'}"
        f"{' (smoke mode: recorded, not enforced)' if smoke else ' (>= 10x required)'}"
    )
    per_policy = cumulative = None
    if sweep:
        per_policy = sweep_policies(cfg, runs)
        print("table,engine,policy,num_gpus,runs,replicas_per_sec,acceptance")
        for name, p in per_policy.items():
            print(
                f"sweep,batched,{name},{num_gpus},{runs},"
                f"{p['warm_rps']:.2f},{p['acceptance_rate']:.4f}"
            )
        cumulative = bench_cumulative(cfg, runs)
        print(
            f"sweep,batched-cumulative,mfi,{num_gpus},{runs},"
            f"{cumulative['warm_rps']:.2f},{cumulative['acceptance_rate']:.4f}"
        )
        queued = bench_queued(cfg, runs)
        print(
            f"sweep,batched-queued,mfi,{num_gpus},{runs},"
            f"{queued['warm_rps']:.2f},{queued['acceptance_rate']:.4f}"
        )
        print(
            f"# queued point: wait_p50={queued['wait_p50']:.2f} "
            f"wait_p99={queued['wait_p99']:.2f} "
            f"fairness={queued['fairness']:.4f} "
            f"queue_admits={queued['queue_admits']:.2f}"
        )
    else:
        queued = None
    payload = dict(
        r, policy=policy, num_gpus=num_gpus, runs=runs, load=load, smoke=smoke
    )
    if per_policy is not None:
        payload["policies"] = per_policy
    if cumulative is not None:
        payload["cumulative"] = cumulative
    if queued is not None:
        payload["queued"] = queued
    if profile:
        stage_profile = profile_stages(cfg, runs)
        payload["stage_profile"] = stage_profile
        print("table,stage-profile,policy,stage,us_per_event")
        for name, stages in stage_profile.items():
            for stage, us in sorted(stages.items()):
                print(f"profile,batched,{name},{stage.removesuffix('_us')},{us:.1f}")
    gate_ok = True
    if baseline:
        vs, gate_ok = compare_baseline(payload, baseline)
        payload["vs_baseline"] = vs
        s = vs["speedup_warm"]
        print(
            f"# vs baseline {baseline}: speedup_warm {s['current']:.1f}x / "
            f"{s['baseline']:.1f}x = {s['ratio']:.2f} "
            f"-> {'PASS' if gate_ok else 'FAIL'} "
            f"(>= {1 - REGRESSION_GATE:.2f} required)"
        )
        for name, p in sorted(vs.get("policies", {}).items()):
            print(
                f"# vs baseline {name}: {p['current_rps']:.2f} rps / "
                f"{p['baseline_rps']:.2f} rps = {p['ratio']:.2f}x"
            )
        q = vs.get("queued")
        if q is not None:
            drifted = ", ".join(sorted(q["drift"])) or "none"
            print(
                f"# vs baseline queued point: drifted metrics: {drifted} "
                f"-> {'PASS' if q['pass'] else 'FAIL'} "
                f"(tolerance {q['tolerance']:g})"
            )
    if json_path:
        with open(json_path, "w") as fh:
            json.dump(payload, fh, indent=2, sort_keys=True)
        print(f"# wrote {json_path}")
    if not gate_ok:
        sys.exit(
            f"FAIL: perf or queued-metric regression vs {baseline} "
            f"(speedup_warm gate {REGRESSION_GATE:.0%}; queued metric "
            f"tolerance {QUEUED_METRIC_TOL:g})"
        )
    return r


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--runs", type=int, default=64)
    ap.add_argument("--num-gpus", type=int, default=100)
    ap.add_argument("--load", type=float, default=0.85)
    ap.add_argument("--policy", default="mfi")
    ap.add_argument("--py-runs", type=int, default=3)
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized point (M=16, 8 replicas); records without "
                         "enforcing the 10x bar (--baseline can still fail "
                         "the run on a regression)")
    ap.add_argument("--json", dest="json_path", default=None,
                    help="write metrics JSON here (workflow artifact)")
    ap.add_argument("--sweep", dest="sweep", action="store_true", default=None,
                    help="per-policy warm throughput over every registered "
                         "batched-capable policy (default: on in smoke mode)")
    ap.add_argument("--no-sweep", dest="sweep", action="store_false")
    ap.add_argument("--profile", action="store_true",
                    help="per-stage wall-time breakdown of the EngineCore "
                         "pipeline (select/migrate/commit/expire) for a "
                         "defrag and a non-defrag spec")
    ap.add_argument("--baseline", default=None,
                    help="diff against a committed artifact (e.g. "
                         "benchmarks/BENCH_baseline.json); exits non-zero on "
                         ">20%% speedup_warm regression")
    args = ap.parse_args()
    main(
        runs=args.runs, num_gpus=args.num_gpus, load=args.load,
        policy=args.policy, py_runs=args.py_runs, smoke=args.smoke,
        json_path=args.json_path, sweep=args.sweep,
        profile=args.profile, baseline=args.baseline,
    )
