"""Replica-throughput benchmark: batched JAX engine vs the Python reference.

Measures both engines back-to-back on the same point — by default the
paper-scale heavy-load point (M=100, uniform, 85% offered load) with 64
replicas — and reports replicas/second.  The batched engine is reported
twice: *cold* (first call, includes XLA compilation — what a one-shot
script sees) and *steady-state* (what any sweep beyond one point sees:
the compiled program is reused across loads, distributions and seeds,
only shapes recompile).  The headline speedup is the steady-state number;
the acceptance bar is >= 10x on CPU.

``--smoke`` shrinks the point (M=16, 8 replicas) so CI can track the perf
trajectory per-PR in ~a minute; ``--json PATH`` dumps the metrics for the
workflow artifact.  Smoke mode records the numbers without enforcing the
10x bar (tiny clusters under-utilize the batched engine by design), and
additionally sweeps **every registered batched-capable policy**
(``repro.core.policy.list_policies(engine="batched")``) for warm per-policy
throughput — ``mfi-defrag``'s migrate stage included — plus one
**cumulative-protocol** run, so the uploaded artifact tracks the perf
trajectory of every engine configuration, including policies registered
after this benchmark was written (``--sweep``/``--no-sweep`` overrides).
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import time

from repro.core.policy import list_policies
from repro.sim import SimConfig, run_many
from repro.sim.batched import run_batched


def sweep_policies(cfg: SimConfig, runs: int):
    """Warm replica throughput of every registered batched-capable policy."""
    out = {}
    for policy in list_policies(engine="batched"):
        run_batched(policy, cfg, runs=runs)  # compile + warm the cache
        t0 = time.perf_counter()
        r = run_batched(policy, cfg, runs=runs)
        dt = time.perf_counter() - t0
        out[policy] = {
            "warm_rps": runs / dt,
            "acceptance_rate": float(r["acceptance_rate"]),
        }
    return out


def bench_cumulative(cfg: SimConfig, runs: int):
    """Warm throughput of one cumulative-protocol batched run (mfi)."""
    ccfg = dataclasses.replace(cfg, protocol="cumulative")
    run_batched("mfi", ccfg, runs=runs)  # compile + warm the cache
    t0 = time.perf_counter()
    r = run_batched("mfi", ccfg, runs=runs)
    dt = time.perf_counter() - t0
    return {
        "warm_rps": runs / dt,
        "acceptance_rate": float(r["acceptance_rate"]),
        "final_utilization": float(r["utilization"]),
    }


def bench_point(policy: str, cfg: SimConfig, runs: int, py_runs: int):
    t0 = time.perf_counter()
    rp = run_many(policy, cfg, runs=py_runs)
    t_python = (time.perf_counter() - t0) / py_runs  # sec / replica

    t0 = time.perf_counter()
    rb = run_batched(policy, cfg, runs=runs)
    t_cold = time.perf_counter() - t0
    t0 = time.perf_counter()
    run_batched(policy, cfg, runs=runs)
    t_warm = time.perf_counter() - t0

    return {
        "python_rps": 1.0 / t_python,
        "cold_rps": runs / t_cold,
        "warm_rps": runs / t_warm,
        "speedup_cold": t_python * runs / t_cold,
        "speedup_warm": t_python * runs / t_warm,
        "acc_python": rp["acceptance_rate"],
        "acc_batched": rb["acceptance_rate"],
    }


def main(runs: int = 64, num_gpus: int = 100, load: float = 0.85,
         policy: str = "mfi", py_runs: int = 3, smoke: bool = False,
         json_path: str | None = None, sweep: bool | None = None):
    if smoke:
        runs, num_gpus, py_runs = min(runs, 8), min(num_gpus, 16), min(py_runs, 2)
    if sweep is None:
        sweep = smoke  # CI artifact tracks all batched-capable policies
    cfg = SimConfig(
        num_gpus=num_gpus, distribution="uniform", offered_load=load, seed=0
    )
    print("table,engine,policy,num_gpus,runs,replicas_per_sec,speedup")
    r = bench_point(policy, cfg, runs, py_runs)
    print(f"engine,python,{policy},{num_gpus},{py_runs},{r['python_rps']:.2f},1.0")
    print(
        f"engine,batched-cold,{policy},{num_gpus},{runs},"
        f"{r['cold_rps']:.2f},{r['speedup_cold']:.1f}"
    )
    print(
        f"engine,batched,{policy},{num_gpus},{runs},"
        f"{r['warm_rps']:.2f},{r['speedup_warm']:.1f}"
    )
    print(
        f"# acceptance parity: python={r['acc_python']:.4f} "
        f"batched={r['acc_batched']:.4f}"
    )
    ok = smoke or r["speedup_warm"] >= 10.0
    print(
        f"# replica-throughput speedup (steady-state) @ "
        f"(M={num_gpus}, runs={runs}, uniform, {load:.2f} load): "
        f"{r['speedup_warm']:.1f}x (cold incl. compile: {r['speedup_cold']:.1f}x) "
        f"-> {'PASS' if ok else 'FAIL'}"
        f"{' (smoke mode: recorded, not enforced)' if smoke else ' (>= 10x required)'}"
    )
    per_policy = cumulative = None
    if sweep:
        per_policy = sweep_policies(cfg, runs)
        print("table,engine,policy,num_gpus,runs,replicas_per_sec,acceptance")
        for name, p in per_policy.items():
            print(
                f"sweep,batched,{name},{num_gpus},{runs},"
                f"{p['warm_rps']:.2f},{p['acceptance_rate']:.4f}"
            )
        cumulative = bench_cumulative(cfg, runs)
        print(
            f"sweep,batched-cumulative,mfi,{num_gpus},{runs},"
            f"{cumulative['warm_rps']:.2f},{cumulative['acceptance_rate']:.4f}"
        )
    if json_path:
        payload = dict(
            r, policy=policy, num_gpus=num_gpus, runs=runs, load=load, smoke=smoke
        )
        if per_policy is not None:
            payload["policies"] = per_policy
        if cumulative is not None:
            payload["cumulative"] = cumulative
        with open(json_path, "w") as fh:
            json.dump(payload, fh, indent=2, sort_keys=True)
        print(f"# wrote {json_path}")
    return r


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--runs", type=int, default=64)
    ap.add_argument("--num-gpus", type=int, default=100)
    ap.add_argument("--load", type=float, default=0.85)
    ap.add_argument("--policy", default="mfi")
    ap.add_argument("--py-runs", type=int, default=3)
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized point (M=16, 8 replicas); records, never fails")
    ap.add_argument("--json", dest="json_path", default=None,
                    help="write metrics JSON here (workflow artifact)")
    ap.add_argument("--sweep", dest="sweep", action="store_true", default=None,
                    help="per-policy warm throughput over every registered "
                         "batched-capable policy (default: on in smoke mode)")
    ap.add_argument("--no-sweep", dest="sweep", action="store_false")
    args = ap.parse_args()
    main(
        runs=args.runs, num_gpus=args.num_gpus, load=args.load,
        policy=args.policy, py_runs=args.py_runs, smoke=args.smoke,
        json_path=args.json_path, sweep=args.sweep,
    )
