"""Replica-throughput benchmark: batched JAX engine vs the Python reference.

Measures both engines back-to-back on the same point — by default the
paper-scale heavy-load point (M=100, uniform, 85% offered load) with 64
replicas — and reports replicas/second.  The batched engine is reported
twice: *cold* (first call, includes XLA compilation — what a one-shot
script sees) and *steady-state* (what any sweep beyond one point sees:
the compiled program is reused across loads, distributions and seeds,
only shapes recompile).  The headline speedup is the steady-state number;
the acceptance bar is >= 10x on CPU.

``--smoke`` shrinks the point (M=16, 8 replicas) so CI can track the perf
trajectory per-PR in ~a minute; ``--json PATH`` dumps the metrics for the
workflow artifact.  Smoke mode records the numbers without enforcing the
10x bar (tiny clusters under-utilize the batched engine by design), and
additionally sweeps **every registered batched-capable policy**
(``repro.core.policy.list_policies(engine="batched")``) for warm per-policy
throughput — ``mfi-defrag``'s migrate stage included — plus one
**cumulative-protocol** run, one **steady-queued** run (above
saturation, recording p50/p99 wait, fairness and queue admits next to
throughput) and one **steady-faulted** run (the same point overlaid with
a deterministic hot fault process, recording goodput, evictions,
recovered fraction and TTR p99 — all gated against the baseline, since
they are seed-deterministic), so the uploaded artifact tracks the perf
trajectory of every
engine configuration, including policies registered after this benchmark
was written (``--sweep``/``--no-sweep`` overrides).

The smoke sweep also records a **chunked streaming** point (same
seed/load as the headline point, ``chunk_size`` ≪ the stream length,
through ``run_batched(chunk_size=...)``): chunking is bit-exact, so its
acceptance must equal the monolithic point exactly and its warm
throughput must stay within 10% — both gated by ``--baseline`` — and the
recorded ``h2d_overlap_frac`` tracks how much of the host→device event
feed overlapped chunk compute.

``--profile`` adds a per-stage wall-time breakdown of the ``EngineCore``
pipeline (select / migrate / commit / expire, µs per event across the
replica batch) for a defrag and a non-defrag spec, plus the queued
protocol's ``wait`` / ``park`` stages (``mfi@steady-queued``), emitted
under ``stage_profile`` in the JSON payload — the view that shows *where*
an engine configuration spends its scan step.

``--baseline PATH`` diffs the run against a committed reference artifact
(``benchmarks/BENCH_baseline.json``): the headline ``speedup_warm`` (the
batched-vs-python ratio, machine-normalized) must not regress by more than
20%, per-policy warm-throughput ratios are recorded under ``vs_baseline``
in the payload, and the process exits non-zero on a gate failure — this is
the CI perf-trajectory gate.

``--compile-cache DIR`` points JAX's persistent compilation cache at
``DIR`` (CI keeps it under the workflow cache), so the *cold* call hits
compiled programs on disk instead of re-lowering from scratch —
``speedup_cold`` then measures dispatch, not compilation.  ``--stress``
runs only the memory-bound chunked stress point (≥ 20k events per
replica; CI caps ``XLA_PYTHON_CLIENT_MEM_FRACTION`` and skips the
monolithic path, which would materialize the full event/trace tensors).
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import sys
import time

from repro.core.policy import list_policies
from repro.sim import SimConfig, run_many
from repro.sim.batched import run_batched

#: maximum tolerated relative drop of speedup_warm vs the baseline artifact
REGRESSION_GATE = 0.20

#: queue metrics are deterministic for a fixed seed/config — tolerate only
#: float noise, so behavioral drift in the wait/park stages fails the gate
QUEUED_METRIC_TOL = 1e-6

#: the chunked smoke point must stay within this of the monolithic point's
#: warm throughput (same run, same machine — per-chunk dispatch overhead is
#: the only legitimate cost) and match its acceptance bit-for-bit
CHUNKED_WARM_TOL = 0.10


def enable_compile_cache(cache_dir: str) -> str:
    """Point JAX's persistent compilation cache at ``cache_dir``.

    Keyed into the CI workflow cache, this turns the cold call's XLA
    compilation into a disk hit on every run after the first —
    ``speedup_cold`` then tracks dispatch overhead instead of compile time.
    Thresholds are zeroed so even the small smoke-point programs persist.
    """
    import os

    import jax

    cache_dir = os.path.expanduser(cache_dir)
    os.makedirs(cache_dir, exist_ok=True)
    jax.config.update("jax_compilation_cache_dir", cache_dir)
    jax.config.update("jax_persistent_cache_min_entry_size_bytes", -1)
    jax.config.update("jax_persistent_cache_min_compile_time_secs", 0)
    return cache_dir


def sweep_policies(cfg: SimConfig, runs: int):
    """Warm replica throughput of every registered batched-capable policy."""
    out = {}
    for policy in list_policies(engine="batched"):
        run_batched(policy, cfg, runs=runs)  # compile + warm the cache
        t0 = time.perf_counter()
        r = run_batched(policy, cfg, runs=runs)
        dt = time.perf_counter() - t0
        out[policy] = {
            "warm_rps": runs / dt,
            "acceptance_rate": float(r["acceptance_rate"]),
        }
    return out


def bench_cumulative(cfg: SimConfig, runs: int):
    """Warm throughput of one cumulative-protocol batched run (mfi)."""
    ccfg = dataclasses.replace(cfg, protocol="cumulative")
    run_batched("mfi", ccfg, runs=runs)  # compile + warm the cache
    t0 = time.perf_counter()
    r = run_batched("mfi", ccfg, runs=runs)
    dt = time.perf_counter() - t0
    return {
        "warm_rps": runs / dt,
        "acceptance_rate": float(r["acceptance_rate"]),
        "final_utilization": float(r["utilization"]),
    }


def bench_queued(cfg: SimConfig, runs: int):
    """Warm throughput + queue metrics of one steady-queued batched run.

    Run above saturation (load >= 1.1) so the wait ring actually cycles;
    the metrics are deterministic for a fixed seed/config, so the baseline
    diff can gate on them tightly — a silent change to the wait/park
    stages shows up as metric drift here before any parity test runs.
    """
    qcfg = dataclasses.replace(
        cfg, protocol="steady-queued", offered_load=max(cfg.offered_load, 1.1)
    )
    run_batched("mfi", qcfg, runs=runs)  # compile + warm the cache
    t0 = time.perf_counter()
    r = run_batched("mfi", qcfg, runs=runs)
    dt = time.perf_counter() - t0
    return {
        "warm_rps": runs / dt,
        "acceptance_rate": float(r["acceptance_rate"]),
        "wait_p50": float(r["wait_p50"]),
        "wait_p99": float(r["wait_p99"]),
        "fairness": float(r["fairness"]),
        "queue_admits": float(r["queue_admits"]),
    }


def bench_faulted(cfg: SimConfig, runs: int):
    """Warm throughput + fault stats of one steady-faulted batched run.

    The queued benchmark's above-saturation point overlaid with a hot
    fault process (MTBF 60 slots, MTTR 10) so evictions, backoff
    re-queues and recoveries all fire within the smoke horizon.  Like the
    queued point the metrics are seed-deterministic, so the baseline diff
    gates on them tightly — behavioral drift in the fault/wait stages
    fails CI here before any parity test runs.
    """
    from repro.core.mig import FaultModel

    fcfg = dataclasses.replace(
        cfg, protocol="steady-faulted",
        offered_load=max(cfg.offered_load, 1.1),
        fault_model=FaultModel(mtbf=60.0, mttr=10.0),
    )
    run_batched("mfi", fcfg, runs=runs)  # compile + warm the cache
    t0 = time.perf_counter()
    r = run_batched("mfi", fcfg, runs=runs)
    dt = time.perf_counter() - t0
    return {
        "warm_rps": runs / dt,
        "acceptance_rate": float(r["acceptance_rate"]),
        "goodput": float(r["goodput"]),
        "evictions": float(r["evictions"]),
        "recovered_fraction": float(r["recovered_fraction"]),
        "ttr_p99": float(r["ttr_p99"]),
    }


def bench_fused(cfg: SimConfig, runs: int, policies=("mfi", "mfi-defrag")):
    """Warm throughput of the fused Pallas select/migrate lowering vs jnp.

    Interleaved best-of-3 per policy (same-machine comparison, so the
    ``speedup_vs_jnp`` ratio is machine-normalized and the baseline gate
    can compare it across runners).  The fused kernels are a pure lowering
    change, so the acceptance rate must match the jnp path bit-for-bit —
    ``acceptance_identical`` is a hard gate under ``--baseline``.  On CPU
    the kernels run in interpret mode (traced to XLA inside jit); on TPU
    they compile to real Mosaic launches.
    """
    out = {}
    for policy in policies:
        run_batched(policy, cfg, runs=runs, use_kernel=True)  # compile
        run_batched(policy, cfg, runs=runs, use_kernel=False)
        dt_k = dt_j = float("inf")
        for _ in range(3):
            t0 = time.perf_counter()
            rj = run_batched(policy, cfg, runs=runs, use_kernel=False)
            dt_j = min(dt_j, time.perf_counter() - t0)
            t0 = time.perf_counter()
            rk = run_batched(policy, cfg, runs=runs, use_kernel=True)
            dt_k = min(dt_k, time.perf_counter() - t0)
        out[policy] = {
            "warm_rps": runs / dt_k,
            "jnp_warm_rps": runs / dt_j,
            "speedup_vs_jnp": dt_j / dt_k,
            "acceptance_rate": float(rk["acceptance_rate"]),
            "acceptance_identical": (
                float(rk["acceptance_rate"]) == float(rj["acceptance_rate"])
            ),
        }
    return out


def bench_chunked(cfg: SimConfig, runs: int, chunk_size: int | None = None):
    """Warm throughput of the chunked streaming driver on the smoke point.

    Same seed/load/policy as the monolithic headline point, with the event
    scan split into ``chunk_size``-event chunks (default: two chunks with a
    ragged tail — a smoke-sized stream is too short to amortize a deep
    chunk pipeline; the ``chunk_size`` ≪ T regime is what ``--stress``
    exercises).  Chunking is bit-exact, so the acceptance rate must equal
    the monolithic point *exactly*; the recorded ``h2d_overlap_frac`` is
    the fraction of host→device bytes staged while a chunk compute was in
    flight.

    The throughput gate compares against ``monolithic_warm_rps`` measured
    *here*, interleaved best-of-5 with the chunked pass: shared CI runners
    drift by tens of percent over a bench run, so comparing two
    single-sample timings taken minutes apart gates noise, not code.
    """
    from repro.sim import batched

    events, _, _, _ = batched.presample_arrivals(cfg, runs)
    e_max = events.pid.shape[0]
    if chunk_size is None:
        chunk_size = max(1, e_max // 2 + 1)
    stats: dict = {}
    run_batched("mfi", cfg, runs=runs, chunk_size=chunk_size)  # compile + warm
    run_batched("mfi", cfg, runs=runs)
    dt_chunked = dt_mono = float("inf")
    for _ in range(5):
        t0 = time.perf_counter()
        run_batched("mfi", cfg, runs=runs)
        dt_mono = min(dt_mono, time.perf_counter() - t0)
        t0 = time.perf_counter()
        r = run_batched(
            "mfi", cfg, runs=runs, chunk_size=chunk_size, stats=stats
        )
        dt_chunked = min(dt_chunked, time.perf_counter() - t0)
    return {
        "warm_rps": runs / dt_chunked,
        "monolithic_warm_rps": runs / dt_mono,
        "acceptance_rate": float(r["acceptance_rate"]),
        "chunk_size": chunk_size,
        "chunks": stats["chunks"],
        "events": stats["events"],
        "h2d_overlap_frac": stats["h2d_overlap_frac"],
    }


def bench_stress(num_gpus: int = 16, load: float = 0.85, runs: int = 2,
                 chunk_size: int = 512, min_events: int = 20000):
    """Memory-bound stress point: a chunked run over >= ``min_events`` events.

    Scales the measurement window until the presampled stream holds at
    least ``min_events`` events per replica, then drives it through the
    chunked path only — device memory stays bounded by ``chunk_size``
    (one carry + two staged chunks) while the monolithic path would
    materialize the full ``(E, R)`` event and trace tensors; run under a
    capped ``XLA_PYTHON_CLIENT_MEM_FRACTION`` in CI, where the monolithic
    equivalent is deliberately skipped.
    """
    import dataclasses as _dc

    from repro.sim import batched

    cfg = SimConfig(
        num_gpus=num_gpus, distribution="uniform", offered_load=load, seed=0
    )
    while True:
        events, _, _, _ = batched.presample_arrivals(cfg, runs)
        e_max = events.pid.shape[0]
        if e_max >= min_events:
            break
        grow = min_events / e_max
        cfg = _dc.replace(
            cfg,
            measure_horizons=max(
                cfg.measure_horizons + 1,
                int(cfg.measure_horizons * grow * 1.05) + 1,
            ),
        )
    stats: dict = {}
    t0 = time.perf_counter()
    r = run_batched("mfi", cfg, runs=runs, chunk_size=chunk_size, stats=stats)
    dt = time.perf_counter() - t0
    chunk_frac = chunk_size / e_max
    return {
        "events": e_max,
        "runs": runs,
        "num_gpus": num_gpus,
        "measure_horizons": cfg.measure_horizons,
        "chunk_size": chunk_size,
        "chunks": stats["chunks"],
        "device_feed_fraction": chunk_frac,  # staged chunk vs full tensor
        "cold_rps": runs / dt,
        "acceptance_rate": float(r["acceptance_rate"]),
        "h2d_overlap_frac": stats["h2d_overlap_frac"],
        "completed": True,
    }


def profile_stages(cfg: SimConfig, runs: int, policies=("mfi", "mfi-defrag")):
    """Per-stage warm wall-time of the ``EngineCore`` pipeline.

    Builds each policy's staged core, drives one full warm run to obtain a
    *representative* replica state (steady state at the configured load),
    then times every stage as its own jitted + vmapped program: µs per
    event across the whole replica batch — exactly the work one scan step
    does per stage.  The defrag spec's ``migrate`` row is the one the
    factored search optimizes; non-defrag specs have no migrate stage.

    The select and migrate stages are attributed per lowering:
    ``select_jnp_us`` / ``migrate_jnp_us`` time the pure-jnp masked
    refinement, ``select_kernel_us`` / ``migrate_kernel_us`` the fused
    Pallas kernels (in-kernel lexicographic argmin; interpret mode when
    the benchmark runs on CPU) on the *same* representative state — the
    side-by-side view of what the fusion buys per event.

    The queued protocol's extra stages are attributed too: an
    ``mfi@steady-queued`` entry times ``wait`` (wait-ring prune +
    head-of-line admission attempt) and ``park`` (rejected-arrival
    insert) against a representative above-saturation queued state.
    """
    import jax
    import jax.numpy as jnp

    from repro.core.policy import resolve
    from repro.sim import batched

    spec = cfg.spec()
    tables = batched.spec_tables(spec)
    midx = jnp.asarray(spec.model_index)
    vg = tables.V[midx]
    events, _, ring_rows, ring_cols = batched.presample_arrivals(cfg, runs)
    dev = jax.tree.map(jnp.asarray, events)

    def timeit(fn, *args, iters=20):
        jax.block_until_ready(fn(*args))  # compile + warm
        t0 = time.perf_counter()
        for _ in range(iters):
            out = fn(*args)
        jax.block_until_ready(out)
        return (time.perf_counter() - t0) / iters * 1e6  # µs / event batch

    out = {}
    for policy in policies:
        pspec = resolve(policy, engine="batched")
        core = batched.EngineCore(
            spec=pspec,
            protocol=batched.resolve_protocol("steady"),
            metric=cfg.metric,
            tables=tables,
            midx=midx,
            vg=vg,
        )
        state, _ = batched._simulate(
            dev, policy=policy, metric=cfg.metric, num_gpus=cfg.num_gpus,
            ring_rows=ring_rows, ring_cols=ring_cols, use_kernel=False,
            midx=midx, tables=tables,
        )  # final (R,)-vmapped state: steady-state occupancy at this load
        pid = jnp.full((runs,), 2, jnp.int32)
        valid = jnp.ones((runs,), bool)
        zeros = jnp.zeros((runs,), jnp.int32)
        new_slot = jnp.ones((runs,), bool)

        expire = jax.jit(jax.vmap(core._stage_expire))
        select = jax.jit(jax.vmap(core._stage_select))
        stages = {
            "expire_us": timeit(expire, state, zeros, new_slot),
            "select_jnp_us": timeit(select, state, pid, valid),
        }
        core_k = None
        if pspec.fused_argmin:  # fused Pallas lowering on the same state
            core_k = batched._build_core(
                policy=policy, metric=cfg.metric, num_gpus=cfg.num_gpus,
                use_kernel=True, kernel_spec=spec, midx=midx, tables=tables,
            )[0]
            select_k = jax.jit(jax.vmap(core_k._stage_select))
            stages["select_kernel_us"] = timeit(select_k, state, pid, valid)
        gpu, aidx, ok = select(state, pid, valid)
        mig_res = None
        if pspec.defrag:
            migrate = jax.jit(jax.vmap(core._stage_migrate))
            stages["migrate_jnp_us"] = timeit(
                migrate, state, pid, valid, gpu, aidx, ok
            )
            if core_k is not None:
                migrate_k = jax.jit(jax.vmap(core_k._stage_migrate))
                stages["migrate_kernel_us"] = timeit(
                    migrate_k, state, pid, valid, gpu, aidx, ok
                )
            state, gpu, aidx, ok, mig_res = migrate(state, pid, valid, gpu, aidx, ok)
        commit = jax.jit(
            jax.vmap(
                lambda st, p, g, a, o, er, ec, mr=None: core._stage_commit(
                    st, p, g, a, o, er, ec, mr
                )
            )
            if mig_res is None
            else jax.vmap(core._stage_commit)
        )
        args = (state, pid, gpu, aidx, ok, zeros, zeros)
        if mig_res is not None:
            args = args + (mig_res,)
        stages["commit_us"] = timeit(commit, *args)
        out[policy] = stages

    # queued protocol: attribute the wait/park stages against a
    # representative above-saturation state (the wait ring actually cycles)
    qcfg = dataclasses.replace(
        cfg, protocol="steady-queued", offered_load=max(cfg.offered_load, 1.1)
    )
    qevents, _, qrr, qrc = batched.presample_arrivals(qcfg, runs, queued=True)
    qdev = jax.tree.map(
        lambda x: None if x is None else jnp.asarray(x), qevents
    )
    qcore = batched.EngineCore(
        spec=resolve("mfi", engine="batched"),
        protocol=batched.resolve_protocol("steady-queued"),
        metric=qcfg.metric,
        tables=tables,
        midx=midx,
        vg=vg,
        wait_patience=qcfg.wait_patience,
    )
    qstate, _ = batched._simulate(
        qdev, policy="mfi", metric=qcfg.metric, num_gpus=qcfg.num_gpus,
        ring_rows=qrr, ring_cols=qrc, use_kernel=False,
        protocol="steady-queued", wait_slots=qcfg.wait_capacity,
        wait_patience=qcfg.wait_patience, midx=midx, tables=tables,
    )
    t = jnp.ones((runs,), jnp.int32)
    wlive = jnp.ones((runs,), bool)
    pid = jnp.full((runs,), 2, jnp.int32)
    can = (qstate.wait_pid < 0).any(axis=1)  # park only where a slot is free
    end = t + 5
    zeros = jnp.zeros((runs,), jnp.int32)
    wait = jax.jit(jax.vmap(qcore._stage_wait))
    park = jax.jit(jax.vmap(qcore._stage_park))
    out["mfi@steady-queued"] = {
        "wait_us": timeit(wait, qstate, t, wlive),
        "park_us": timeit(
            park, qstate, pid, can, t, end, zeros, zeros, zeros, zeros
        ),
    }
    return out


def compare_baseline(payload: dict, baseline_path: str, gate: float = REGRESSION_GATE):
    """Diff this run against a committed baseline artifact.

    Returns ``(vs_baseline, ok)``: the comparison dict recorded in the JSON
    payload, and whether the headline ``speedup_warm`` (machine-normalized:
    batched warm throughput over the same host's Python engine) stayed
    within ``gate`` of the baseline.  Per-policy raw warm-rps ratios are
    informational (they compare across machines when the artifact was
    recorded elsewhere).
    """
    with open(baseline_path) as fh:
        base = json.load(fh)
    cur, ref = payload["speedup_warm"], base["speedup_warm"]
    vs = {
        "baseline_path": baseline_path,
        "speedup_warm": {"baseline": ref, "current": cur, "ratio": cur / ref},
        "gate": gate,
    }
    mismatch = {
        k: {"baseline": base.get(k), "current": payload.get(k)}
        for k in ("num_gpus", "runs", "load", "smoke")
        if base.get(k) != payload.get(k)
    }
    if mismatch:  # different problem size — ratios are meaningless, no gate
        vs["config_mismatch"] = mismatch
        vs["pass"] = True
        print(
            f"# vs baseline {baseline_path}: CONFIG MISMATCH "
            f"({', '.join(sorted(mismatch))}) — comparison recorded, "
            "regression gate skipped"
        )
        return vs, True
    pol = {}
    for name, p in (payload.get("policies") or {}).items():
        b = (base.get("policies") or {}).get(name)
        if b:
            pol[name] = {
                "baseline_rps": b["warm_rps"],
                "current_rps": p["warm_rps"],
                "ratio": p["warm_rps"] / b["warm_rps"],
            }
    if pol:
        vs["policies"] = pol
    ok = cur >= (1.0 - gate) * ref
    ch = payload.get("chunked")
    if ch is not None:
        # chunking is bit-exact and near-free: acceptance must equal the
        # monolithic point exactly, warm throughput must stay within
        # CHUNKED_WARM_TOL of the interleaved monolithic comparator
        # (measured back-to-back inside bench_chunked — the headline
        # warm_rps was timed minutes earlier under different load)
        mono_rps = ch["monolithic_warm_rps"]
        acc_match = ch["acceptance_rate"] == payload["acc_batched"]
        thr_ok = ch["warm_rps"] >= (1.0 - CHUNKED_WARM_TOL) * mono_rps
        vs["chunked"] = {
            "acceptance": {
                "monolithic": payload["acc_batched"],
                "chunked": ch["acceptance_rate"],
                "identical": acc_match,
            },
            "warm_rps": {
                "monolithic": mono_rps,
                "chunked": ch["warm_rps"],
                "ratio": ch["warm_rps"] / mono_rps,
            },
            "tolerance": CHUNKED_WARM_TOL,
            "pass": acc_match and thr_ok,
        }
        if not (acc_match and thr_ok):
            ok = False
    fb, fc = base.get("fused"), payload.get("fused")
    if fc is not None:
        # the fused lowering is bit-exact by construction: acceptance drift
        # is a correctness failure, and the machine-normalized
        # speedup_vs_jnp ratio must not regress past the gate
        entries, fok = {}, True
        for name, p in sorted(fc.items()):
            e = {
                "speedup_vs_jnp": p["speedup_vs_jnp"],
                "acceptance_identical": p["acceptance_identical"],
            }
            if not p["acceptance_identical"]:
                fok = False
            b = (fb or {}).get(name)
            if b:
                e["baseline_speedup_vs_jnp"] = b["speedup_vs_jnp"]
                e["ratio"] = p["speedup_vs_jnp"] / b["speedup_vs_jnp"]
                if e["ratio"] < 1.0 - gate:
                    fok = False
            entries[name] = e
        vs["fused"] = {"gate": gate, "entries": entries, "pass": fok}
        if not fok:
            ok = False
    qb, qc = base.get("queued"), payload.get("queued")
    if qb and qc:
        # queue metrics are seed-deterministic: any drift means the wait or
        # park stage changed behavior, not just performance
        drift = {
            k: {"baseline": qb[k], "current": qc[k]}
            for k in (
                "acceptance_rate", "wait_p50", "wait_p99", "fairness",
                "queue_admits",
            )
            if k in qb
            and abs(qc[k] - qb[k]) > QUEUED_METRIC_TOL * max(1.0, abs(qb[k]))
        }
        vs["queued"] = {"tolerance": QUEUED_METRIC_TOL, "drift": drift,
                        "pass": not drift}
        if drift:
            ok = False
    fb2, fc2 = base.get("faulted"), payload.get("faulted")
    if fb2 and fc2:
        # fault stats are seed-deterministic too: drift means the fault,
        # wait or park stage changed eviction/re-queue behavior
        drift = {
            k: {"baseline": fb2[k], "current": fc2[k]}
            for k in (
                "acceptance_rate", "goodput", "evictions",
                "recovered_fraction", "ttr_p99",
            )
            if k in fb2
            and abs(fc2[k] - fb2[k]) > QUEUED_METRIC_TOL * max(1.0, abs(fb2[k]))
        }
        vs["faulted"] = {"tolerance": QUEUED_METRIC_TOL, "drift": drift,
                         "pass": not drift}
        if drift:
            ok = False
    vs["pass"] = ok
    return vs, ok


def bench_point(policy: str, cfg: SimConfig, runs: int, py_runs: int):
    t0 = time.perf_counter()
    rp = run_many(policy, cfg, runs=py_runs)
    t_python = (time.perf_counter() - t0) / py_runs  # sec / replica

    t0 = time.perf_counter()
    rb = run_batched(policy, cfg, runs=runs)
    t_cold = time.perf_counter() - t0
    t0 = time.perf_counter()
    run_batched(policy, cfg, runs=runs)
    t_warm = time.perf_counter() - t0

    return {
        "python_rps": 1.0 / t_python,
        "cold_rps": runs / t_cold,
        "warm_rps": runs / t_warm,
        "speedup_cold": t_python * runs / t_cold,
        "speedup_warm": t_python * runs / t_warm,
        "acc_python": rp["acceptance_rate"],
        "acc_batched": rb["acceptance_rate"],
    }


def main(runs: int = 64, num_gpus: int = 100, load: float = 0.85,
         policy: str = "mfi", py_runs: int = 3, smoke: bool = False,
         json_path: str | None = None, sweep: bool | None = None,
         profile: bool = False, baseline: str | None = None,
         compile_cache: str | None = None, stress: bool = False):
    if compile_cache:
        compile_cache = enable_compile_cache(compile_cache)
    if stress:  # memory-bound chunked stress point only (CI runs it under a
        # capped XLA_PYTHON_CLIENT_MEM_FRACTION; the monolithic path is
        # skipped by design at this stream length)
        s = bench_stress()
        print(
            f"stress,batched-chunked,mfi,{s['num_gpus']},{s['runs']},"
            f"{s['cold_rps']:.3f},{s['acceptance_rate']:.4f}"
        )
        print(
            f"# chunked stress: {s['events']} events x {s['runs']} replicas "
            f"in {s['chunks']} chunks of {s['chunk_size']} "
            f"(device feed = {s['device_feed_fraction']:.1%} of the stream), "
            f"h2d_overlap_frac={s['h2d_overlap_frac']:.2f} -> COMPLETED"
        )
        if json_path:
            with open(json_path, "w") as fh:
                json.dump(
                    dict(s, compile_cache=compile_cache),
                    fh, indent=2, sort_keys=True,
                )
            print(f"# wrote {json_path}")
        return s
    if smoke:
        runs, num_gpus, py_runs = min(runs, 8), min(num_gpus, 16), min(py_runs, 2)
    if sweep is None:
        sweep = smoke  # CI artifact tracks all batched-capable policies
    cfg = SimConfig(
        num_gpus=num_gpus, distribution="uniform", offered_load=load, seed=0
    )
    print("table,engine,policy,num_gpus,runs,replicas_per_sec,speedup")
    r = bench_point(policy, cfg, runs, py_runs)
    print(f"engine,python,{policy},{num_gpus},{py_runs},{r['python_rps']:.2f},1.0")
    print(
        f"engine,batched-cold,{policy},{num_gpus},{runs},"
        f"{r['cold_rps']:.2f},{r['speedup_cold']:.1f}"
    )
    print(
        f"engine,batched,{policy},{num_gpus},{runs},"
        f"{r['warm_rps']:.2f},{r['speedup_warm']:.1f}"
    )
    print(
        f"# acceptance parity: python={r['acc_python']:.4f} "
        f"batched={r['acc_batched']:.4f}"
    )
    ok = smoke or r["speedup_warm"] >= 10.0
    print(
        f"# replica-throughput speedup (steady-state) @ "
        f"(M={num_gpus}, runs={runs}, uniform, {load:.2f} load): "
        f"{r['speedup_warm']:.1f}x (cold incl. compile: {r['speedup_cold']:.1f}x) "
        f"-> {'PASS' if ok else 'FAIL'}"
        f"{' (smoke mode: recorded, not enforced)' if smoke else ' (>= 10x required)'}"
    )
    per_policy = cumulative = None
    if sweep:
        per_policy = sweep_policies(cfg, runs)
        print("table,engine,policy,num_gpus,runs,replicas_per_sec,acceptance")
        for name, p in per_policy.items():
            print(
                f"sweep,batched,{name},{num_gpus},{runs},"
                f"{p['warm_rps']:.2f},{p['acceptance_rate']:.4f}"
            )
        cumulative = bench_cumulative(cfg, runs)
        print(
            f"sweep,batched-cumulative,mfi,{num_gpus},{runs},"
            f"{cumulative['warm_rps']:.2f},{cumulative['acceptance_rate']:.4f}"
        )
        queued = bench_queued(cfg, runs)
        print(
            f"sweep,batched-queued,mfi,{num_gpus},{runs},"
            f"{queued['warm_rps']:.2f},{queued['acceptance_rate']:.4f}"
        )
        print(
            f"# queued point: wait_p50={queued['wait_p50']:.2f} "
            f"wait_p99={queued['wait_p99']:.2f} "
            f"fairness={queued['fairness']:.4f} "
            f"queue_admits={queued['queue_admits']:.2f}"
        )
        faulted = bench_faulted(cfg, runs)
        print(
            f"sweep,batched-faulted,mfi,{num_gpus},{runs},"
            f"{faulted['warm_rps']:.2f},{faulted['acceptance_rate']:.4f}"
        )
        print(
            f"# faulted point: goodput={faulted['goodput']:.4f} "
            f"evictions={faulted['evictions']:.2f} "
            f"recovered_fraction={faulted['recovered_fraction']:.4f} "
            f"ttr_p99={faulted['ttr_p99']:.2f}"
        )
        chunked = bench_chunked(cfg, runs)
        print(
            f"sweep,batched-chunked,mfi,{num_gpus},{runs},"
            f"{chunked['warm_rps']:.2f},{chunked['acceptance_rate']:.4f}"
        )
        print(
            f"# chunked point: {chunked['chunks']} chunks of "
            f"{chunked['chunk_size']} over {chunked['events']} events, "
            f"h2d_overlap_frac={chunked['h2d_overlap_frac']:.2f}, "
            f"interleaved monolithic {chunked['monolithic_warm_rps']:.2f} rps"
        )
        fused = bench_fused(cfg, runs)
        for name, p in sorted(fused.items()):
            print(
                f"sweep,batched-fused,{name},{num_gpus},{runs},"
                f"{p['warm_rps']:.2f},{p['acceptance_rate']:.4f}"
            )
            print(
                f"# fused {name}: {p['speedup_vs_jnp']:.2f}x vs jnp "
                f"({p['jnp_warm_rps']:.2f} rps), acceptance "
                f"{'identical' if p['acceptance_identical'] else 'DRIFTED'}"
            )
    else:
        queued = faulted = chunked = fused = None
    payload = dict(
        r, policy=policy, num_gpus=num_gpus, runs=runs, load=load, smoke=smoke,
        compile_cache=compile_cache,
    )
    if per_policy is not None:
        payload["policies"] = per_policy
    if cumulative is not None:
        payload["cumulative"] = cumulative
    if queued is not None:
        payload["queued"] = queued
    if faulted is not None:
        payload["faulted"] = faulted
    if chunked is not None:
        payload["chunked"] = chunked
    if fused is not None:
        payload["fused"] = fused
    if profile:
        stage_profile = profile_stages(cfg, runs)
        payload["stage_profile"] = stage_profile
        print("table,stage-profile,policy,stage,us_per_event")
        for name, stages in stage_profile.items():
            for stage, us in sorted(stages.items()):
                print(f"profile,batched,{name},{stage.removesuffix('_us')},{us:.1f}")
    gate_ok = True
    if baseline:
        vs, gate_ok = compare_baseline(payload, baseline)
        c = vs.get("chunked")
        if c is not None and not c["pass"] and c["acceptance"]["identical"]:
            # throughput-only chunked failure: the interleaved ratio sits
            # a few percent above the gate in expectation but its sampling
            # noise straddles it — one re-measure drops the flake rate by
            # an order of magnitude without weakening the gate
            print(
                f"# chunked warm {c['warm_rps']['ratio']:.2f}x below gate, "
                "re-measuring once"
            )
            payload["chunked"] = bench_chunked(cfg, runs)
            vs, gate_ok = compare_baseline(payload, baseline)
        payload["vs_baseline"] = vs
        s = vs["speedup_warm"]
        print(
            f"# vs baseline {baseline}: speedup_warm {s['current']:.1f}x / "
            f"{s['baseline']:.1f}x = {s['ratio']:.2f} "
            f"-> {'PASS' if gate_ok else 'FAIL'} "
            f"(>= {1 - REGRESSION_GATE:.2f} required)"
        )
        for name, p in sorted(vs.get("policies", {}).items()):
            print(
                f"# vs baseline {name}: {p['current_rps']:.2f} rps / "
                f"{p['baseline_rps']:.2f} rps = {p['ratio']:.2f}x"
            )
        fz = vs.get("fused")
        if fz is not None:
            for name, e in sorted(fz["entries"].items()):
                ratio = (
                    f", {e['ratio']:.2f}x of baseline" if "ratio" in e else ""
                )
                print(
                    f"# vs baseline fused {name}: "
                    f"{e['speedup_vs_jnp']:.2f}x vs jnp{ratio}, acceptance "
                    f"{'identical' if e['acceptance_identical'] else 'DRIFTED'}"
                )
            print(
                f"# fused gate -> {'PASS' if fz['pass'] else 'FAIL'} "
                f"(acceptance identical + >= {1 - fz['gate']:.2f} of "
                "baseline speedup_vs_jnp)"
            )
        q = vs.get("queued")
        if q is not None:
            drifted = ", ".join(sorted(q["drift"])) or "none"
            print(
                f"# vs baseline queued point: drifted metrics: {drifted} "
                f"-> {'PASS' if q['pass'] else 'FAIL'} "
                f"(tolerance {q['tolerance']:g})"
            )
        f = vs.get("faulted")
        if f is not None:
            drifted = ", ".join(sorted(f["drift"])) or "none"
            print(
                f"# vs baseline faulted point: drifted metrics: {drifted} "
                f"-> {'PASS' if f['pass'] else 'FAIL'} "
                f"(tolerance {f['tolerance']:g})"
            )
        c = vs.get("chunked")
        if c is not None:
            print(
                f"# chunked vs monolithic: acceptance "
                f"{'identical' if c['acceptance']['identical'] else 'DRIFTED'}, "
                f"warm {c['warm_rps']['ratio']:.2f}x "
                f"-> {'PASS' if c['pass'] else 'FAIL'} "
                f"(>= {1 - CHUNKED_WARM_TOL:.2f} required)"
            )
    if json_path:
        with open(json_path, "w") as fh:
            json.dump(payload, fh, indent=2, sort_keys=True)
        print(f"# wrote {json_path}")
    if not gate_ok:
        sys.exit(
            f"FAIL: perf or queued-metric regression vs {baseline} "
            f"(speedup_warm gate {REGRESSION_GATE:.0%}; queued metric "
            f"tolerance {QUEUED_METRIC_TOL:g})"
        )
    return r


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--runs", type=int, default=64)
    ap.add_argument("--num-gpus", type=int, default=100)
    ap.add_argument("--load", type=float, default=0.85)
    ap.add_argument("--policy", default="mfi")
    ap.add_argument("--py-runs", type=int, default=3)
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized point (M=16, 8 replicas); records without "
                         "enforcing the 10x bar (--baseline can still fail "
                         "the run on a regression)")
    ap.add_argument("--json", dest="json_path", default=None,
                    help="write metrics JSON here (workflow artifact)")
    ap.add_argument("--sweep", dest="sweep", action="store_true", default=None,
                    help="per-policy warm throughput over every registered "
                         "batched-capable policy (default: on in smoke mode)")
    ap.add_argument("--no-sweep", dest="sweep", action="store_false")
    ap.add_argument("--profile", action="store_true",
                    help="per-stage wall-time breakdown of the EngineCore "
                         "pipeline (select/migrate/commit/expire) for a "
                         "defrag and a non-defrag spec")
    ap.add_argument("--baseline", default=None,
                    help="diff against a committed artifact (e.g. "
                         "benchmarks/BENCH_baseline.json); exits non-zero on "
                         ">20%% speedup_warm regression")
    ap.add_argument("--compile-cache", default=None, metavar="DIR",
                    help="enable JAX's persistent compilation cache at DIR "
                         "(kept under the CI workflow cache so cold calls "
                         "hit disk instead of recompiling)")
    ap.add_argument("--stress", action="store_true",
                    help="memory-bound chunked stress point only: stream "
                         ">= 20k events per replica through the chunked "
                         "driver (run under a capped "
                         "XLA_PYTHON_CLIENT_MEM_FRACTION in CI)")
    args = ap.parse_args()
    main(
        runs=args.runs, num_gpus=args.num_gpus, load=args.load,
        policy=args.policy, py_runs=args.py_runs, smoke=args.smoke,
        json_path=args.json_path, sweep=args.sweep,
        profile=args.profile, baseline=args.baseline,
        compile_cache=args.compile_cache, stress=args.stress,
    )
