"""Kernel microbenchmarks: Pallas (interpret) vs pure-jnp oracle.

CPU-interpret timings are NOT TPU performance — they validate shapes and give
the oracle-relative sanity curve.  TPU-targeted blocking is what matters
(see kernels/*/ for BlockSpecs); roofline projections live in §Roofline.

``--fused`` adds the fused select/migrate kernels (ΔF + in-kernel
lexicographic argmin): ``select_from_base`` per-model dispatch vs the
jnp ``_lower_select`` lowering, and ``migrate_refine``'s combined
class + victim launch vs the jnp per-class/per-victim refinements.
"""

from __future__ import annotations

import argparse

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import time_fn
from repro.kernels.decode_attention.decode_attention import decode_attention
from repro.kernels.decode_attention.ref import decode_attention_ref
from repro.kernels.fragscore import ops as frag_ops
from repro.kernels.fragscore.ref import fragscore_ref


def _engine_state(spec, tables, rng, fill=0.45):
    """Randomized occupancy -> engine-layout (base, free, f)."""
    from repro.sim import batched

    midx = np.asarray(spec.model_index)
    occ = np.zeros((spec.num_gpus, spec.num_mem_slices), np.int32)
    for g in range(spec.num_gpus):
        s = spec.models[midx[g]].num_mem_slices
        occ[g, :s] = (rng.random(s) < fill).astype(np.int32)
    base = jnp.einsum(
        "ms,mns->mn", jnp.asarray(occ, jnp.float32), tables.W[midx]
    )
    free = jnp.asarray(tables.slices[midx] - occ.sum(axis=1), jnp.int32)
    f = batched._frag_from_base(base, free, "blocked", tables.V[midx])
    return base, free, f


def bench_fused(rng, rows=None):
    """Fused select / migrate-search kernels vs the pure-jnp lowering."""
    from repro.core import mig
    from repro.core.policy import resolve
    from repro.sim import batched

    interp = jax.default_backend() != "tpu"
    print("table,kernel,shape,us_fused_pallas,us_jnp")
    pid = 2
    for m in (1024, 4096):
        spec = mig.ClusterSpec.homogeneous(mig.A100_80GB, m)
        tables = batched.spec_tables(spec)
        midx = jnp.asarray(spec.model_index)
        vg = tables.V[midx]
        base, free, f = _engine_state(spec, tables, rng)
        pspec = resolve("mfi", engine="batched")
        select_fn = batched.make_select_fn(spec, pspec, interpret=interp)
        fused = jax.jit(lambda b, fr, ff: select_fn(b, fr, ff, pid))
        ref = jax.jit(
            lambda b, fr, ff: batched._select(
                pspec, b, fr, ff, "blocked", tables, midx, vg, pid,
                jnp.int32(0),
            )
        )
        us_k = time_fn(lambda: jax.block_until_ready(fused(base, free, f)), iters=5)
        us_r = time_fn(lambda: jax.block_until_ready(ref(base, free, f)), iters=5)
        print(f"kernels,select_from_base,M={m},{us_k:.0f},{us_r:.0f}")
        if rows is not None:
            rows.append({"kernel": "select_from_base", "shape": f"M={m}",
                         "us_fused_pallas": us_k, "us_jnp": us_r})

    # migrate_refine: per-class top-2 + per-victim patched rows, one launch
    m, c = 1024, 64
    spec = mig.ClusterSpec.homogeneous(mig.A100_80GB, m)
    tables = batched.spec_tables(spec)
    base, free, f = _engine_state(spec, tables, rng)
    vspec = mig.ClusterSpec.homogeneous(mig.A100_80GB, c)
    base2, free2, f2 = _engine_state(vspec, tables, rng)
    rg = jnp.asarray(rng.integers(0, m, size=c), jnp.int32)
    rp = jnp.asarray(rng.integers(0, mig.NUM_PROFILES, size=c), jnp.int32)
    kc = jnp.zeros((c,), jnp.int32)
    migrate_fn = batched.make_migrate_fn(
        spec, resolve("mfi-defrag", engine="batched"), interpret=interp
    )
    mig_j = jax.jit(lambda *a: migrate_fn(*a))
    us_k = time_fn(
        lambda: jax.block_until_ready(
            mig_j(base, free, f, base2, free2, f2, rg, rp, kc)
        ),
        iters=3,
    )
    print(f"kernels,migrate_refine,M={m}/C={c},{us_k:.0f},")
    if rows is not None:
        rows.append({"kernel": "migrate_refine", "shape": f"M={m}/C={c}",
                     "us_fused_pallas": us_k, "us_jnp": None})


def main(fused: bool = False, json_path: str | None = None):
    print("table,kernel,shape,us_pallas_interpret,us_ref")
    rng = np.random.default_rng(0)
    rows = []

    for m in (1024, 16384):
        occ = jnp.asarray((rng.random((m, 8)) < 0.4).astype(np.float32))
        us_k = time_fn(lambda: jax.block_until_ready(frag_ops.fragmentation_scores(occ)), iters=5)
        refj = jax.jit(fragscore_ref)
        us_r = time_fn(lambda: jax.block_until_ready(refj(occ)), iters=5)
        print(f"kernels,fragscore,M={m},{us_k:.0f},{us_r:.0f}")
        rows.append({"kernel": "fragscore", "shape": f"M={m}",
                     "us_pallas_interpret": us_k, "us_ref": us_r})

    for (b, h, kv, d, s) in [(4, 8, 2, 64, 1024), (1, 16, 8, 128, 4096)]:
        q = jnp.asarray(rng.standard_normal((b, h, d)), jnp.float32)
        k = jnp.asarray(rng.standard_normal((b, s, kv, d)), jnp.float32)
        v = jnp.asarray(rng.standard_normal((b, s, kv, d)), jnp.float32)
        ln = jnp.full((b,), s, jnp.int32)
        us_k = time_fn(lambda: jax.block_until_ready(decode_attention(q, k, v, ln)), iters=3)
        refj = jax.jit(lambda q, k, v, ln: decode_attention_ref(q, k, v, length=ln))
        us_r = time_fn(lambda: jax.block_until_ready(refj(q, k, v, ln)), iters=3)
        print(f"kernels,decode_attention,b{b}h{h}kv{kv}d{d}s{s},{us_k:.0f},{us_r:.0f}")
        rows.append({"kernel": "decode_attention",
                     "shape": f"b{b}h{h}kv{kv}d{d}s{s}",
                     "us_pallas_interpret": us_k, "us_ref": us_r})

    if fused:
        bench_fused(rng, rows=rows)

    if json_path:
        import json

        payload = {"backend": jax.default_backend(), "fused": fused,
                   "rows": rows}
        with open(json_path, "w") as fh:
            json.dump(payload, fh, indent=1, sort_keys=True)
        print(f"# wrote {json_path}")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--fused", action="store_true",
                    help="also bench the fused select/migrate kernels "
                         "(in-kernel lexicographic argmin) vs the jnp path")
    ap.add_argument("--json", default=None, help="write rows to this JSON file")
    args = ap.parse_args()
    main(fused=args.fused, json_path=args.json)
