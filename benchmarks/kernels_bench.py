"""Kernel microbenchmarks: Pallas (interpret) vs pure-jnp oracle.

CPU-interpret timings are NOT TPU performance — they validate shapes and give
the oracle-relative sanity curve.  TPU-targeted blocking is what matters
(see kernels/*/ for BlockSpecs); roofline projections live in §Roofline.
"""

from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import time_fn
from repro.kernels.decode_attention.decode_attention import decode_attention
from repro.kernels.decode_attention.ref import decode_attention_ref
from repro.kernels.fragscore import ops as frag_ops
from repro.kernels.fragscore.ref import fragscore_ref


def main():
    print("table,kernel,shape,us_pallas_interpret,us_ref")
    rng = np.random.default_rng(0)

    for m in (1024, 16384):
        occ = jnp.asarray((rng.random((m, 8)) < 0.4).astype(np.float32))
        us_k = time_fn(lambda: jax.block_until_ready(frag_ops.fragmentation_scores(occ)), iters=5)
        refj = jax.jit(fragscore_ref)
        us_r = time_fn(lambda: jax.block_until_ready(refj(occ)), iters=5)
        print(f"kernels,fragscore,M={m},{us_k:.0f},{us_r:.0f}")

    for (b, h, kv, d, s) in [(4, 8, 2, 64, 1024), (1, 16, 8, 128, 4096)]:
        q = jnp.asarray(rng.standard_normal((b, h, d)), jnp.float32)
        k = jnp.asarray(rng.standard_normal((b, s, kv, d)), jnp.float32)
        v = jnp.asarray(rng.standard_normal((b, s, kv, d)), jnp.float32)
        ln = jnp.full((b,), s, jnp.int32)
        us_k = time_fn(lambda: jax.block_until_ready(decode_attention(q, k, v, ln)), iters=3)
        refj = jax.jit(lambda q, k, v, ln: decode_attention_ref(q, k, v, length=ln))
        us_r = time_fn(lambda: jax.block_until_ready(refj(q, k, v, ln)), iters=3)
        print(f"kernels,decode_attention,b{b}h{h}kv{kv}d{d}s{s},{us_k:.0f},{us_r:.0f}")


if __name__ == "__main__":
    main()
