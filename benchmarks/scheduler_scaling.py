"""Scheduler decision latency vs cluster size (paper §V complexity claim:
O(kM) per decision) — numpy reference vs jitted JAX vs Pallas kernel path,
plus the batched engine's single-decision path on both a homogeneous and a
mixed half-A100-80/half-A100-40 fleet (``--engine batched`` limits the
sweep to the batched paths; default ``python`` times everything)."""

from __future__ import annotations

import argparse
import functools

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import ENGINES, time_fn
from repro.core import cluster as jcluster
from repro.core import mig
from repro.core.schedulers import make_scheduler
from repro.sim.batched import policy_select


def main(engine: str = "python"):
    print("table,impl,num_gpus,us_per_decision,decisions_per_sec")
    rng = np.random.default_rng(0)
    for m in (100, 1000, 10_000):
        occ_np = (rng.random((m, 8)) < 0.45).astype(np.int32)
        occ = jnp.asarray(occ_np)
        pid = jnp.int32(2)

        if engine == "python":
            # numpy reference (paper's python algorithm, vectorized)
            cl = mig.ClusterState(m)
            for g in range(m):
                cl.gpus[g].occupancy[:] = occ_np[g]
            sched = make_scheduler("mfi")
            us = time_fn(lambda: sched.select(cl, 2), warmup=1, iters=5)
            print(f"scaling,numpy,{m},{us:.1f},{1e6/us:.0f}")

            # jitted jnp
            f = jax.jit(lambda o, p: jcluster.mfi_select(o, p))
            us = time_fn(lambda: jax.block_until_ready(f(occ, pid)), warmup=2, iters=10)
            print(f"scaling,jax-jit,{m},{us:.1f},{1e6/us:.0f}")

            # pallas kernel via the unified entry point (interpret mode on
            # CPU — TPU-shaped, not TPU-timed)
            us = time_fn(
                lambda: jax.block_until_ready(
                    jcluster.mfi_select(occ, pid, use_kernel=True)
                ),
                warmup=1, iters=3,
            )
            print(f"scaling,pallas-interpret,{m},{us:.1f},{1e6/us:.0f}")

        # batched engine's decision path (window-count state, linear ΔF)
        g = jax.jit(lambda o, p: policy_select(o, p, "mfi"))
        us = time_fn(lambda: jax.block_until_ready(g(occ, pid)), warmup=2, iters=10)
        print(f"scaling,batched-select,{m},{us:.1f},{1e6/us:.0f}")

        # same path on a mixed fleet (stacked tables + model-index gather)
        spec = mig.ClusterSpec(
            ((mig.A100_80GB, m // 2), (mig.A100_40GB, m - m // 2))
        )
        h = jax.jit(functools.partial(policy_select, policy="mfi", spec=spec))
        us = time_fn(lambda: jax.block_until_ready(h(occ, pid)), warmup=2, iters=10)
        print(f"scaling,batched-select-mixed,{m},{us:.1f},{1e6/us:.0f}")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--engine", choices=ENGINES, default="python")
    args = ap.parse_args()
    main(engine=args.engine)
