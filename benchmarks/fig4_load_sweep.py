"""Paper Fig. 4: scheduling performance vs load (uniform distribution).

Sweeps offered load over the steady-state protocol and reports all five paper
metrics per scheduler.  Paper claims to validate: MFI highest allocated
workloads + acceptance ~ highest across loads; RR/WF-BI degrade sharply;
FF/BF-BI pack but fragment.

``--engine batched`` (default ``python``) runs each sweep point through the
batched JAX engine (:mod:`repro.sim.batched`) — same aggregates, one device
program per point; mfi-defrag (if requested) falls back to the Python loop.

``--cluster`` selects the fleet: ``homogeneous`` (the paper's A100-80GB
fleet of ``--num-gpus``), the named ``mixed`` scenario (half A100-80GB,
half A100-40GB), or any explicit spec string such as
``a100-80:40,a100-40:40,h100-96:20``.
"""

from __future__ import annotations

import argparse

import numpy as np

from benchmarks.common import (
    CLUSTERS,
    ENGINES,
    MODEL_DISTS,
    PAPER_POLICIES,
    resolve_cluster,
    resolve_model_dist,
    resolve_policies,
    run_engine,
)
from repro.sim import SimConfig

SCHEDULERS = PAPER_POLICIES


def run(runs: int = 30, num_gpus: int = 100, loads=(0.5, 0.7, 0.85, 1.0),
        seed: int = 0, engine: str = "python", cluster: str | None = None,
        policies: str | None = None, model_dist: str | None = None,
        chunk_size: int | None = None):
    spec, num_gpus = resolve_cluster(cluster, num_gpus)
    names = resolve_policies(policies)
    model_dists = resolve_model_dist(model_dist, spec)
    rows = []
    results = {}
    for load in loads:
        for name in names:
            cfg = SimConfig(
                num_gpus=num_gpus, distribution="uniform",
                offered_load=load, seed=seed, cluster_spec=spec,
                model_distributions=model_dists,
            )
            r = run_engine(engine, name, cfg, runs=runs, chunk_size=chunk_size)
            results[(name, load)] = r
            rows.append(
                f"fig4,{name},{load},{r['acceptance_rate']:.4f},"
                f"{r['allocated_workloads']:.1f},{r['utilization']:.4f},"
                f"{r['active_gpus']:.1f},{r['frag_severity']:.2f}"
            )
    return rows, results


def main(runs: int = 30, engine: str = "python", cluster: str | None = None,
         policies: str | None = None, model_dist: str | None = None,
         chunk_size: int | None = None):
    print("table,scheduler,load,acceptance,allocated,utilization,active_gpus,frag")
    rows, results = run(runs=runs, engine=engine, cluster=cluster,
                        policies=policies, model_dist=model_dist,
                        chunk_size=chunk_size)
    for row in rows:
        print(row)
    # headline check at heavy load
    heavy = 0.85
    names = resolve_policies(policies)
    if "mfi" in names and len(names) > 1:
        mfi = results[("mfi", heavy)]["allocated_workloads"]
        base = np.mean([results[(s, heavy)]["allocated_workloads"] for s in names if s != "mfi"])
        print(f"# MFI vs baseline-mean allocated @ {heavy:.0%}: {100*(mfi/base-1):+.1f}% "
              f"(paper claims ~+10% in heavy load)")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--runs", type=int, default=30)
    ap.add_argument("--engine", choices=ENGINES, default="python")
    ap.add_argument(
        "--cluster", default=None,
        help=f"named scenario {sorted(CLUSTERS)} or spec string 'a100-80:50,a100-40:50'",
    )
    ap.add_argument(
        "--policies", default=None,
        help="comma list of registered policies, or 'all' (default: paper set)",
    )
    ap.add_argument(
        "--model-dist", default=None,
        help=f"per-model demand mix: named scenario {sorted(MODEL_DISTS)} or "
             "'model=dist,model=dist' (default: fleet-wide Table II)",
    )
    ap.add_argument(
        "--chunk-size", type=int, default=None,
        help="batched engine only: stream the event scan in chunks of this "
             "many events (bounded device memory, bit-identical results)",
    )
    args = ap.parse_args()
    main(runs=args.runs, engine=args.engine, cluster=args.cluster,
         policies=args.policies, model_dist=args.model_dist,
         chunk_size=args.chunk_size)
